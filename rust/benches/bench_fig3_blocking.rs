//! Bench: regenerate the paper's **Figure 3** (see
//! `experiments::fig3_blocking`).  Sweeps the 12 reconfiguration pairs of
//! §V-A at full problem scale; tune with PROTEO_BENCH_REPS/_SCALE/_PAIRS.

use proteo::experiments::{fig3_blocking, FigOptions};

fn main() {
    let opts = FigOptions::bench();
    eprintln!(
        "bench fig3: reps={} scale={} pairs={}",
        opts.reps,
        opts.scale,
        if opts.pairs.is_empty() { "all-12".to_string() } else { format!("{:?}", opts.pairs) }
    );
    let wall = std::time::Instant::now();
    let table = fig3_blocking(&opts);
    println!("{}", table.render());
    eprintln!("harness wall time: {:.2}s", wall.elapsed().as_secs_f64());
}
