//! Microbenchmarks of every layer's hot path (the §Perf baseline):
//!
//! * DES engine — event throughput, activity handoff latency;
//! * simmpi — collective schedule computation at 160 ranks, window
//!   create/free round-trips, Rget post rate;
//! * MaM — Algorithm 1 plans, payload slicing for the send matrix;
//! * runtime — PJRT `cg_step`/`spmv` latency (skipped without
//!   artifacts);
//! * ablations — single fused window vs per-structure windows, and the
//!   registration-rate sweep (§VI).

use proteo::experiments::{ablation, FigOptions};
use proteo::linalg::EllMatrix;
use proteo::mam::{drain_plan, source_plan, Method, Strategy};
use proteo::netmodel::{CostModel, NetParams, Placement, Topology, TransferClass};
use proteo::proteo::{run_once, RunSpec};
use proteo::runtime::{artifacts_dir, runtime_available, CgRuntime, CgState};
use proteo::simcluster::{Engine, LiteStep, QueueKind};
use proteo::simmpi::{MpiSim, Payload, WinCreateOpts, WORLD};
use proteo::util::benchkit::Bench;

fn engine_benches(b: &mut Bench) {
    b.bench("engine: 100k advance events (1 activity)", || {
        let mut e = Engine::new();
        e.spawn_at(0.0, "spin", |ctx| {
            for _ in 0..100_000 {
                ctx.advance(1e-6);
            }
        });
        e.run().unwrap();
    });
    b.bench("engine: 200 ranks x 500 events", || {
        let mut e = Engine::new();
        for i in 0..200 {
            e.spawn_at(0.0, format!("r{i}"), |ctx| {
                for _ in 0..500 {
                    ctx.advance(1e-6);
                }
            });
        }
        e.run().unwrap();
    });
    // Queue microbenchmark: the same event mix through both queue
    // implementations — the calendar queue's win over the seed heap is
    // the measured quantity.  Timer offsets cycle a coarse grid so the
    // calendar hits its bucket-rotation path, with equal-time ties.
    for (name, kind) in [
        ("queue: heap, 50k lite timers", QueueKind::Heap),
        ("queue: calendar, 50k lite timers", QueueKind::Calendar),
    ] {
        b.bench(name, move || {
            let mut e = Engine::with_queue(kind);
            for i in 0..50_000u64 {
                let mut fired = false;
                let at = (i % 97) as f64 * 1e-5;
                e.spawn_lite_at(at, "t", move |_| {
                    if fired {
                        return LiteStep::Done;
                    }
                    fired = true;
                    LiteStep::AdvanceUntil(at + 1e-3)
                });
            }
            e.run().unwrap();
        });
    }
    // Batched collective wakeup vs. one queue event per rank, with the
    // engine's counters attached to the rows (events, peak queue,
    // batch sizes) — the observability satellite of the wakeup path.
    for (name, batched) in [
        ("engine: 10k-rank wakeup, batched", true),
        ("engine: 10k-rank wakeup, per-rank events", false),
    ] {
        b.bench_metric_counters(name, "virt_s", move || {
            let mut e = Engine::new();
            let ids: Vec<_> = (0..10_000)
                .map(|r| {
                    let mut fresh = true;
                    e.spawn_lite_at(0.0, format!("r{r}"), move |_| {
                        if fresh {
                            fresh = false;
                            LiteStep::Park
                        } else {
                            LiteStep::Done
                        }
                    })
                })
                .collect();
            e.spawn_lite_at(0.0, "root", move |ctx| {
                if ids.is_empty() {
                    return LiteStep::Done;
                }
                let now = ctx.now();
                let entries: Vec<_> = ids.drain(..).map(|id| (id, now + 1.0)).collect();
                if batched {
                    ctx.unpark_batch(entries);
                } else {
                    for (id, at) in entries {
                        ctx.unpark_at(id, at);
                    }
                }
                LiteStep::Done
            });
            let t = e.run().unwrap();
            let s = e.stats();
            (
                t,
                vec![
                    ("events".to_string(), s.events as f64),
                    ("peak_queue".to_string(), s.peak_queue as f64),
                    ("wakeup_max".to_string(), s.wakeup_max_batch as f64),
                ],
            )
        });
    }
}

fn simmpi_benches(b: &mut Bench) {
    b.bench("simmpi: barrier x32 @160 ranks", || {
        let mut s = MpiSim::new(Topology::sarteco25(), NetParams::sarteco25());
        s.launch(160, |p| {
            for _ in 0..32 {
                p.barrier(WORLD);
            }
        });
        s.run().unwrap();
    });
    b.bench("simmpi: alltoallv @160 ranks (sparse resize pattern)", || {
        let mut s = MpiSim::new(Topology::sarteco25(), NetParams::sarteco25());
        s.launch(160, |p| {
            let r = p.rank(WORLD);
            let sends = (0..160)
                .map(|j| Payload::virt(if j == r / 8 { 1_000_000 } else { 0 }))
                .collect();
            let _ = p.alltoallv(WORLD, sends);
        });
        s.run().unwrap();
    });
    b.bench("simmpi: win create+free @160 ranks", || {
        let mut s = MpiSim::new(Topology::sarteco25(), NetParams::sarteco25());
        s.launch(160, |p| {
            let w = p.win_create_with(WORLD, Payload::virt(1_000_000), WinCreateOpts::blocking());
            p.win_free(w);
        });
        s.run().unwrap();
    });
    b.bench("simmpi: win pool cold+warm acquire/release @160 ranks", || {
        let mut s = MpiSim::new(Topology::sarteco25(), NetParams::sarteco25());
        s.launch(160, |p| {
            let w1 = p.win_acquire(WORLD, Payload::virt(1_000_000), 0xA);
            p.win_release(w1);
            // Second acquire rides the registration cache (warm).
            let w2 = p.win_acquire(WORLD, Payload::virt(1_000_000), 0xA);
            p.win_release(w2);
        });
        s.run().unwrap();
    });
    b.bench("simmpi: pipelined win create+free @160 ranks (64 segs)", || {
        let mut s = MpiSim::new(Topology::sarteco25(), NetParams::sarteco25());
        s.launch(160, |p| {
            let w = p.win_create_with(WORLD, Payload::virt(1_000_000), WinCreateOpts::pipelined(16_384));
            p.win_free(w);
        });
        s.run().unwrap();
    });
    b.bench("costmodel: 100k transfers", || {
        let topo = Topology::sarteco25();
        let pl = Placement::cyclic(&topo, 160);
        let mut cm = CostModel::new(NetParams::sarteco25(), 8);
        let mut t = 0.0;
        for i in 0..100_000u64 {
            let tt = cm.transfer(
                t,
                &pl,
                (i % 160) as usize,
                ((i * 7) % 160) as usize,
                (i % 1_000_000) + 1,
                TransferClass::TwoSided,
            );
            t = tt.arrival * 1e-6 + t;
        }
        std::hint::black_box(t);
    });
}

fn mam_benches(b: &mut Bench) {
    b.bench("alg1: 160 drain plans from 160 sources", || {
        for d in 0..160 {
            std::hint::black_box(drain_plan(8_000_000_000, 160, 160, d));
        }
    });
    b.bench("alg1: source plans 20->160", || {
        for s in 0..20 {
            std::hint::black_box(source_plan(8_000_000_000, 20, 160, s));
        }
    });
    b.bench("end-to-end run_once: COL blocking 20->160 (virtual 64GB)", || {
        let spec = RunSpec::sarteco25(20, 160, Method::Collective, Strategy::Blocking);
        std::hint::black_box(run_once(&spec));
    });
    b.bench("end-to-end run_once: RMA-Lockall WD 160->20", || {
        let spec = RunSpec::sarteco25(160, 20, Method::RmaLockall, Strategy::WaitDrains);
        std::hint::black_box(run_once(&spec));
    });
}

fn runtime_benches(b: &mut Bench) {
    if !runtime_available() {
        eprintln!("runtime benches skipped: need `make artifacts` and `--features pjrt`");
        return;
    }
    let rt = CgRuntime::load(artifacts_dir()).expect("artifacts");
    let a = EllMatrix::laplacian_2d(rt.manifest.grid);
    let x: Vec<f32> = (0..rt.manifest.n).map(|i| (i as f32).sin()).collect();
    b.bench("pjrt: spmv n=4096", || {
        std::hint::black_box(rt.spmv(&a, &x).unwrap());
    });
    let st = CgState::init(&x);
    b.bench("pjrt: cg_step n=4096 (cold: re-upload matrix)", || {
        std::hint::black_box(rt.cg_step(&a, &st).unwrap());
    });
    let dev = rt.upload(&a).expect("upload");
    b.bench("pjrt: cg_step n=4096 (hot: device-resident matrix)", || {
        std::hint::black_box(rt.cg_step_dev(&dev, &st).unwrap());
    });
}

fn main() {
    let mut b = Bench::new();
    engine_benches(&mut b);
    simmpi_benches(&mut b);
    mam_benches(&mut b);
    runtime_benches(&mut b);
    b.print_report("microbenchmarks (all layers)");

    // §VI ablations at reduced scale so the bench stays quick.  The
    // PROTEO_BENCH_* env vars (scale, pairs, seed) apply here too so CI
    // can shrink the sweep without recompiling; reps stay at 1.
    let mut opts = FigOptions::bench();
    opts.reps = 1;
    if opts.pairs.is_empty() {
        opts.pairs = vec![(20, 160), (160, 20), (160, 40)];
    }
    println!("{}", ablation::single_window(&opts).render());
    println!("{}", ablation::registration_sweep(&opts, 20, 160).render());
    // §VI window pool: cold vs warm reconfiguration latency head-to-head.
    println!("{}", ablation::win_pool(&opts).render());
    // Spawn strategies: the other half of the initialization cost.
    println!("{}", ablation::spawn_strategies(&opts).render());
    // Chunked pipelined registration: cold blocking vs pipelined vs
    // warm, per chunk size (the `--rma-chunk` sweet-spot table).
    println!("{}", ablation::rma_chunk(&opts).render());
}
