//! Bench: regenerate the paper's **Figure 7** (see
//! `experiments::fig7_threading`).  Sweeps the 12 reconfiguration pairs of
//! §V-A at full problem scale; tune with PROTEO_BENCH_REPS/_SCALE/_PAIRS.

use proteo::experiments::{fig7_threading, FigOptions};

fn main() {
    let opts = FigOptions::bench();
    eprintln!(
        "bench fig7: reps={} scale={} pairs={}",
        opts.reps,
        opts.scale,
        if opts.pairs.is_empty() { "all-12".to_string() } else { format!("{:?}", opts.pairs) }
    );
    let wall = std::time::Instant::now();
    let table = fig7_threading(&opts);
    println!("{}", table.render());
    eprintln!("harness wall time: {:.2}s", wall.elapsed().as_secs_f64());
}
