//! Bench: the closed-loop RMS scenario (see `experiments::scenario`) —
//! total makespan of the adaptive job trace under the cost-model
//! planner versus fixed anchor versions.  The measured quantity is
//! deterministic virtual time; wall time is reported for harness
//! throughput.  `PROTEO_BENCH_QUICK=1` shrinks the workload 10000×
//! (the CI configuration), otherwise the CI-friendly 100× scale runs.

use proteo::experiments::scenario::{run_scenario, ScenarioSpec};
use proteo::mam::{Method, PlannerMode, Strategy, WinPoolPolicy};
use proteo::util::benchkit::Bench;

fn main() {
    let quick = std::env::var("PROTEO_BENCH_QUICK").map(|v| v != "0").unwrap_or(false);
    let base = ScenarioSpec::rms_trace(quick);
    eprintln!("bench scenario: quick={quick} trace={}", base.name);
    let wall = std::time::Instant::now();
    let mut b = Bench::new();
    let configs: [(&str, PlannerMode, Method, Strategy, WinPoolPolicy); 4] = [
        ("auto", PlannerMode::Auto, Method::Collective, Strategy::Blocking, WinPoolPolicy::off()),
        (
            "col-blocking",
            PlannerMode::Fixed,
            Method::Collective,
            Strategy::Blocking,
            WinPoolPolicy::off(),
        ),
        (
            "rma-lockall+pool",
            PlannerMode::Fixed,
            Method::RmaLockall,
            Strategy::Blocking,
            WinPoolPolicy::on(),
        ),
        (
            "rma-lockall-wd",
            PlannerMode::Fixed,
            Method::RmaLockall,
            Strategy::WaitDrains,
            WinPoolPolicy::off(),
        ),
    ];
    for (name, planner, method, strategy, pool) in configs {
        let mut spec = base.clone();
        spec.planner = planner;
        spec.method = method;
        spec.strategy = strategy;
        spec.win_pool = pool;
        b.bench_metric(&format!("scenario/{name}"), "makespan_s", || {
            run_scenario(&spec).makespan
        });
    }
    b.print_report("closed-loop RMS scenario makespan (virtual seconds)");
    // One full accuracy table for the planner run.
    let mut auto = base.clone();
    auto.planner = PlannerMode::Auto;
    println!("{}", run_scenario(&auto).render());
    eprintln!("harness wall time: {:.2}s", wall.elapsed().as_secs_f64());
}
