//! Bench: **Figure 10** (beyond the paper) — grow reconfiguration time
//! under the Sequential / Parallel / Async spawn strategies (see
//! `experiments::fig10_spawn`).  Sweeps the grow pairs of §V-A at full
//! problem scale; tune with PROTEO_BENCH_REPS/_SCALE/_PAIRS.

use proteo::experiments::{fig10_spawn, FigOptions};

fn main() {
    let opts = FigOptions::bench();
    eprintln!(
        "bench fig10: reps={} scale={} pairs={}",
        opts.reps,
        opts.scale,
        if opts.pairs.is_empty() { "all-grows".to_string() } else { format!("{:?}", opts.pairs) }
    );
    let wall = std::time::Instant::now();
    let table = fig10_spawn(&opts);
    println!("{}", table.render());
    eprintln!("harness wall time: {:.2}s", wall.elapsed().as_secs_f64());
}
