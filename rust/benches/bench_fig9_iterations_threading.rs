//! Bench: regenerate the paper's **Figure 9** (see
//! `experiments::fig9_iterations_threading`).  Sweeps the 12 reconfiguration pairs of
//! §V-A at full problem scale; tune with PROTEO_BENCH_REPS/_SCALE/_PAIRS.

use proteo::experiments::{fig9_iterations_threading, FigOptions};

fn main() {
    let opts = FigOptions::bench();
    eprintln!(
        "bench fig9: reps={} scale={} pairs={}",
        opts.reps,
        opts.scale,
        if opts.pairs.is_empty() { "all-12".to_string() } else { format!("{:?}", opts.pairs) }
    );
    let wall = std::time::Instant::now();
    let table = fig9_iterations_threading(&opts);
    println!("{}", table.render());
    eprintln!("harness wall time: {:.2}s", wall.elapsed().as_secs_f64());
}
