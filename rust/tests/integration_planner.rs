//! Integration: the cost-model-driven planner end to end.
//!
//! The acceptance bar of the planner subsystem: on the fig3 quick-mode
//! pairs, `--planner auto`'s simulated reconfiguration time is no
//! worse than the best fixed `(method × strategy)` version (ties
//! allowed), the fixed path stays bit-identical to seed behaviour, and
//! the closed-loop scenario harness is deterministic across runs while
//! reporting predicted-vs-observed cost per resize.

use proteo::config::ExperimentConfig;
use proteo::experiments::{blocking_versions, scenario, FigOptions};
use proteo::mam::{Method, PlannerMode, Strategy};
use proteo::proteo::{run_once, RunResult};

/// The acceptance criterion: for every fig3 quick-mode pair, the
/// planner's choice — executed through the full simulation — must not
/// lose to any fixed blocking version on the reconfiguration span.
/// The planner probes exactly these candidates with an isolated DES
/// micro-simulation, and warm-up skew shifts every version's span by
/// the same pair-constant offset, so up to float noise the planner's
/// argmin is the simulator's argmin; the 1% band is the numerical
/// reading of "ties allowed".
#[test]
fn auto_matches_the_best_fixed_version_on_fig3_quick_pairs() {
    let opts = FigOptions::quick();
    for (ns, nd) in opts.pairs() {
        let fixed: Vec<RunResult> = blocking_versions()
            .iter()
            .map(|v| run_once(&opts.spec(ns, nd, v.method, v.strategy)))
            .collect();
        let best = fixed
            .iter()
            .map(|r| r.reconf_total)
            .fold(f64::INFINITY, f64::min);
        let mut auto_spec = opts.spec(ns, nd, Method::Collective, Strategy::Blocking);
        auto_spec.planner = PlannerMode::Auto;
        let auto = run_once(&auto_spec);
        assert!(
            auto.reconf_total.is_finite() && auto.reconf_total > 0.0,
            "{ns}->{nd}: auto produced no reconfiguration span"
        );
        assert!(
            auto.reconf_total <= best * 1.01 + 1e-9,
            "{ns}->{nd}: auto ({}) {} loses to the best fixed version {} \
             (fixed spans: {:?})",
            auto.label,
            auto.reconf_total,
            best,
            fixed.iter().map(|r| (r.label.clone(), r.reconf_total)).collect::<Vec<_>>()
        );
    }
}

#[test]
fn fixed_planner_via_config_is_bit_identical_to_direct_specs() {
    // `"planner": "fixed"` must change nothing: same spec, same bits
    // as a config that never mentions the planner.
    let src_plain = r#"{"preset": "tiny", "method": "rma-lockall", "strategy": "wd",
                        "pairs": [[8, 4]], "scale": 10000}"#;
    let src_fixed = r#"{"preset": "tiny", "method": "rma-lockall", "strategy": "wd",
                        "pairs": [[8, 4]], "scale": 10000, "planner": "fixed"}"#;
    let a = ExperimentConfig::from_str(src_plain).unwrap();
    let b = ExperimentConfig::from_str(src_fixed).unwrap();
    assert_eq!(a.planner, PlannerMode::Fixed);
    assert_eq!(b.planner, PlannerMode::Fixed);
    let ra = run_once(&a.spec_for(8, 4));
    let rb = run_once(&b.spec_for(8, 4));
    assert_eq!(ra.label, rb.label);
    assert_eq!(ra.redist_time.to_bits(), rb.redist_time.to_bits());
    assert_eq!(ra.reconf_total.to_bits(), rb.reconf_total.to_bits());
    assert_eq!(ra.virt_end.to_bits(), rb.virt_end.to_bits());
    assert_eq!(ra.events, rb.events);
}

#[test]
fn auto_planner_via_config_runs_and_is_deterministic() {
    let src = r#"{"preset": "tiny", "pairs": [[8, 4]], "scale": 10000,
                  "planner": "auto"}"#;
    let cfg = ExperimentConfig::from_str(src).unwrap();
    assert_eq!(cfg.planner, PlannerMode::Auto);
    let spec = cfg.spec_for(8, 4);
    let a = run_once(&spec);
    let b = run_once(&spec);
    assert!(a.label.starts_with("auto["), "{}", a.label);
    assert_eq!(a.label, b.label);
    assert_eq!(a.reconf_total.to_bits(), b.reconf_total.to_bits());
    assert_eq!(a.events, b.events);
}

#[test]
fn scenario_reports_predicted_vs_observed_and_is_deterministic() {
    // The closed-loop harness (auto planner, quick trace): every
    // resize carries a finite prediction and observation, and two runs
    // produce byte-identical reports.
    let spec = scenario::ScenarioSpec::rms_trace(true);
    let a = scenario::run_scenario(&spec);
    let b = scenario::run_scenario(&spec);
    assert_eq!(a.to_json().to_pretty(), b.to_json().to_pretty());
    assert_eq!(a.resizes.len(), 5, "the default trace drives five resizes");
    for r in &a.resizes {
        assert!(
            r.predicted_reconf.is_finite() && r.predicted_reconf > 0.0,
            "resize {} missing prediction",
            r.index
        );
        assert!(
            r.observed_reconf.is_finite() && r.observed_reconf > 0.0,
            "resize {} missing observation",
            r.index
        );
    }
    // The accuracy table renders both columns.
    let rendered = a.render();
    assert!(rendered.contains("predicted") && rendered.contains("observed"), "{rendered}");
}
