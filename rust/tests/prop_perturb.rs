//! Schedule-perturbation determinism: the dynamic check backing the
//! static `proteo audit` pass.
//!
//! The DES promises that simulated outputs are a pure function of the
//! `RunSpec` — *never* of OS scheduling.  The strongest way to shake
//! that promise without changing any input is to perturb worker wakeup
//! order: the engine's pooled OS workers are handed out from a shared
//! process-global pool, so flooding that pool from concurrent decoy
//! simulations changes which physical worker picks up which simulated
//! process, in what order, with what reuse pattern.  If any ordering
//! leaked into virtual time, the scenario JSON would differ.  It must
//! not — on either event-queue implementation.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use proteo::experiments::scenario::{run_scenario, ScenarioSpec};
use proteo::simcluster::{set_default_queue_kind, QueueKind};

/// Serializes queue-kind flips across the tests in this binary.
static QUEUE_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` under the given process-default queue kind, restoring the
/// calendar default afterwards (also on panic).
fn with_queue_kind<T>(kind: QueueKind, f: impl FnOnce() -> T) -> T {
    let _guard = QUEUE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    struct Restore;
    impl Drop for Restore {
        fn drop(&mut self) {
            set_default_queue_kind(QueueKind::Calendar);
        }
    }
    let _restore = Restore;
    set_default_queue_kind(kind);
    f()
}

/// The reference scenario: the quick RMS trace with the auto planner
/// (planner probes exercise snapshot/rollback too).
fn scenario_json() -> String {
    let mut sp = ScenarioSpec::rms_trace(true);
    sp.planner = proteo::mam::PlannerMode::Auto;
    run_scenario(&sp).to_json().to_pretty()
}

/// The same scenario, run while `n_decoys` adversarial simulations
/// hammer the shared worker pool from plain OS threads.  The decoys
/// perturb pool handout order, worker reuse, and wakeup interleaving
/// — every schedule degree of freedom the engine has — while the
/// `RunSpec` stays bit-identical.
fn perturbed_scenario_json(n_decoys: usize) -> String {
    let stop = Arc::new(AtomicBool::new(false));
    let decoys: Vec<_> = (0..n_decoys)
        .map(|k| {
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || {
                // Stagger decoy starts so contention keeps shifting.
                for _ in 0..k {
                    std::thread::yield_now();
                }
                while !stop.load(Ordering::Relaxed) {
                    let sp = ScenarioSpec::rms_trace(true);
                    let _ = run_scenario(&sp);
                }
            })
        })
        .collect();
    let out = scenario_json();
    stop.store(true, Ordering::Relaxed);
    for d in decoys {
        d.join().expect("decoy simulation panicked");
    }
    out
}

/// Same `RunSpec`, adversarially jittered worker wakeup order →
/// byte-identical scenario JSON, on both queue kinds.
#[test]
fn scenario_json_survives_wakeup_perturbation_on_both_queues() {
    for kind in [QueueKind::Calendar, QueueKind::Heap] {
        let (quiet, noisy) = with_queue_kind(kind, || {
            // The quiet run goes second so it also starts from a
            // pool pre-warmed (and reordered) by the perturbed run.
            let noisy = perturbed_scenario_json(3);
            let quiet = scenario_json();
            (quiet, noisy)
        });
        assert_eq!(
            quiet, noisy,
            "worker wakeup order leaked into the scenario output ({kind:?})"
        );
    }
}

/// Repeatability under contention: two perturbed runs (different
/// decoy pressure) agree with each other, not just with a quiet run.
#[test]
fn perturbed_runs_agree_with_each_other() {
    let (a, b) = with_queue_kind(QueueKind::Calendar, || {
        (perturbed_scenario_json(1), perturbed_scenario_json(4))
    });
    assert_eq!(a, b, "decoy pressure level changed the scenario output");
}
