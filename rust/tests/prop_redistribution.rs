//! Property tests over the redistribution machinery: for *random*
//! (NS, ND, total, method, strategy) the full reconfiguration must be a
//! content-preserving re-partition — no element lost, duplicated,
//! reordered or altered — and virtual-mode runs must follow the exact
//! same control flow (same collective counts) as real-mode runs.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use proteo::mam::{
    block_of, is_valid_version, DataKind, Mam, MamStatus, Method, PlannerMode, ReconfigCfg,
    Registry, SpawnStrategy, Strategy, WinPoolPolicy,
};
use proteo::netmodel::{NetParams, Topology};
use proteo::simmpi::{CommId, MpiProc, MpiSim, Payload, WORLD};
use proteo::util::proptest_lite::{check_seeded, one_of, usizes, Strategy as PStrategy};

/// Run one reconfiguration, collecting every drain's final block into a
/// global vector; returns (reassembled, events).
fn run_and_collect(
    ns: usize,
    nd: usize,
    total: u64,
    method: Method,
    strategy: Strategy,
    real: bool,
) -> (Option<Vec<f64>>, u64) {
    let collected: Arc<Mutex<Vec<Option<Vec<f64>>>>> = Arc::new(Mutex::new(vec![None; nd]));
    let c2 = collected.clone();
    let mut sim = MpiSim::new(Topology::new(4, 5), NetParams::test_simple());
    let drains_done = Arc::new(AtomicUsize::new(0));
    let dd = drains_done.clone();
    sim.launch(ns, move |p: MpiProc| {
        let rank = p.rank(WORLD);
        let b = block_of(total, ns, rank);
        let local = if real {
            Payload::real((b.ini..b.end).map(|i| (i as f64) * 1.5 - 3.0).collect())
        } else {
            Payload::virt(b.len())
        };
        let mut reg = Registry::new();
        reg.register("A", DataKind::Constant, total, local);
        let decls = reg.decls();
        let cfg = ReconfigCfg {
            method,
            strategy,
            spawn_cost: 0.001,
            spawn_strategy: SpawnStrategy::Sequential,
            win_pool: WinPoolPolicy::off(),
            rma_chunk_kib: 0,
            rma_dereg: true,
            rma_sync: proteo::simmpi::RmaSync::Epoch,
            sched_cache: false,
            planner: PlannerMode::Fixed,
            recalib: false,
        };
        let mut mam = Mam::new(reg, cfg.clone());
        let c3 = c2.clone();
        let dd2 = dd.clone();
        let cfg2 = cfg.clone();
        let body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
            Arc::new(move |dp: MpiProc, merged: CommId| {
                let dmam = Mam::drain_join(&dp, merged, ns, nd, &decls, cfg2.clone());
                let dr = dp.rank(merged);
                let e = dmam.registry.entry(0);
                c3.lock().unwrap()[dr] = Some(
                    e.local
                        .as_slice()
                        .map(|s| s.to_vec())
                        .unwrap_or_else(|| vec![f64::NAN; e.local.elems() as usize]),
                );
                dd2.fetch_add(1, Ordering::SeqCst);
            });
        let mut status = mam.reconfigure(&p, WORLD, nd, body);
        while status == MamStatus::InProgress {
            p.compute(1e-4);
            status = mam.checkpoint(&p);
        }
        let out = mam.finish(&p, WORLD);
        if let Some(comm) = out.app_comm {
            let nr = p.rank(comm);
            let e = mam.registry.entry(0);
            c2.lock().unwrap()[nr] = Some(
                e.local
                    .as_slice()
                    .map(|s| s.to_vec())
                    .unwrap_or_else(|| vec![f64::NAN; e.local.elems() as usize]),
            );
            dd.fetch_add(1, Ordering::SeqCst);
        }
    });
    sim.run().expect("simulation");
    let events = {
        // events metric recorded by the sim driver
        drains_done.load(Ordering::SeqCst) as u64
    };
    let shards = collected.lock().unwrap();
    if shards.iter().any(|s| s.is_none()) {
        return (None, events);
    }
    let mut out = Vec::with_capacity(total as usize);
    for s in shards.iter() {
        out.extend_from_slice(s.as_ref().unwrap());
    }
    (Some(out), events)
}

fn methods() -> Vec<(Method, Strategy)> {
    let mut v = Vec::new();
    for m in Method::all() {
        for s in Strategy::all() {
            if is_valid_version(m, s) {
                v.push((m, s));
            }
        }
    }
    v
}

#[test]
fn prop_redistribution_is_identity_on_contents() {
    let versions = methods();
    check_seeded(
        "redistribution == content-preserving repartition",
        usizes(1, 10)
            .pair(usizes(1, 10))
            .pair(usizes(0, 2_000))
            .pair(one_of(&versions)),
        |(((ns, nd), total), (m, s))| {
            if ns == nd {
                return true; // resize to the same size is rejected by Mam
            }
            let total = total as u64;
            let (got, _) = run_and_collect(ns, nd, total, m, s, true);
            let Some(got) = got else { return false };
            if got.len() as u64 != total {
                return false;
            }
            got.iter()
                .enumerate()
                .all(|(i, v)| *v == (i as f64) * 1.5 - 3.0)
        },
        0xDEC0DE,
    );
}

#[test]
fn prop_block_sizes_after_resize_match_block_of() {
    let versions = methods();
    check_seeded(
        "per-drain block length == block_of(total, nd, r)",
        usizes(1, 12)
            .pair(usizes(1, 12))
            .pair(usizes(1, 5_000))
            .pair(one_of(&versions)),
        |(((ns, nd), total), (m, s))| {
            if ns == nd {
                return true;
            }
            let total = total as u64;
            let collected: Arc<Mutex<Vec<Option<u64>>>> = Arc::new(Mutex::new(vec![None; nd]));
            let c2 = collected.clone();
            let mut sim = MpiSim::new(Topology::new(4, 5), NetParams::test_simple());
            sim.launch(ns, move |p: MpiProc| {
                let rank = p.rank(WORLD);
                let b = block_of(total, ns, rank);
                let mut reg = Registry::new();
                reg.register("A", DataKind::Constant, total, Payload::virt(b.len()));
                let decls = reg.decls();
                let cfg = ReconfigCfg {
                    method: m,
                    strategy: s,
                    spawn_cost: 0.001,
                    spawn_strategy: SpawnStrategy::Sequential,
                    win_pool: WinPoolPolicy::off(),
                    rma_chunk_kib: 0,
                    rma_dereg: true,
                    rma_sync: proteo::simmpi::RmaSync::Epoch,
                    sched_cache: false,
                    planner: PlannerMode::Fixed,
                    recalib: false,
                };
                let mut mam = Mam::new(reg, cfg.clone());
                let c3 = c2.clone();
                let cfg2 = cfg.clone();
                let body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
                    Arc::new(move |dp: MpiProc, merged: CommId| {
                        let dmam = Mam::drain_join(&dp, merged, ns, nd, &decls, cfg2.clone());
                        c3.lock().unwrap()[dp.rank(merged)] =
                            Some(dmam.registry.entry(0).local.elems());
                    });
                let mut status = mam.reconfigure(&p, WORLD, nd, body);
                while status == MamStatus::InProgress {
                    p.compute(1e-4);
                    status = mam.checkpoint(&p);
                }
                let out = mam.finish(&p, WORLD);
                if let Some(comm) = out.app_comm {
                    c2.lock().unwrap()[p.rank(comm)] =
                        Some(mam.registry.entry(0).local.elems());
                }
            });
            sim.run().expect("sim");
            let c = collected.lock().unwrap();
            (0..nd).all(|r| c[r] == Some(block_of(total, nd, r).len()))
        },
        0xBEEF,
    );
}

#[test]
fn prop_virtual_and_real_modes_share_control_flow() {
    // Virtual payloads must take the same schedule (identical virtual
    // end times) as real payloads of the same sizes — DESIGN.md §1's
    // "control flow is identical in both modes".
    let versions = methods();
    check_seeded(
        "virtual mode ≡ real mode timing",
        usizes(1, 8)
            .pair(usizes(1, 8))
            .pair(usizes(1, 3_000))
            .pair(one_of(&versions)),
        |(((ns, nd), total), (m, s))| {
            if ns == nd {
                return true;
            }
            let total = total as u64;
            fn end_time(
                ns: usize,
                nd: usize,
                total: u64,
                m: Method,
                s: Strategy,
                real: bool,
            ) -> f64 {
                let mut sim = MpiSim::new(Topology::new(4, 5), NetParams::test_simple());
                sim.launch(ns, move |p: MpiProc| {
                    let rank = p.rank(WORLD);
                    let b = block_of(total, ns, rank);
                    let local = if real {
                        Payload::real(vec![0.25; b.len() as usize])
                    } else {
                        Payload::virt(b.len())
                    };
                    let mut reg = Registry::new();
                    reg.register("A", DataKind::Constant, total, local);
                    let decls = reg.decls();
                    let cfg = ReconfigCfg {
                        method: m,
                        strategy: s,
                        spawn_cost: 0.001,
                        spawn_strategy: SpawnStrategy::Sequential,
                        win_pool: WinPoolPolicy::off(),
                        rma_chunk_kib: 0,
                        rma_dereg: true,
                        rma_sync: proteo::simmpi::RmaSync::Epoch,
                        sched_cache: false,
                        planner: PlannerMode::Fixed,
                        recalib: false,
                    };
                    let mut mam = Mam::new(reg, cfg.clone());
                    let cfg2 = cfg.clone();
                    let body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
                        Arc::new(move |dp: MpiProc, merged: CommId| {
                            let _ = Mam::drain_join(&dp, merged, ns, nd, &decls, cfg2.clone());
                        });
                    let mut status = mam.reconfigure(&p, WORLD, nd, body);
                    while status == MamStatus::InProgress {
                        p.compute(1e-4);
                        status = mam.checkpoint(&p);
                    }
                    let _ = mam.finish(&p, WORLD);
                });
                sim.run().expect("sim")
            }
            let tv = end_time(ns, nd, total, m, s, false);
            let tr = end_time(ns, nd, total, m, s, true);
            (tv - tr).abs() < 1e-9
        },
        0xFEED,
    );
}
