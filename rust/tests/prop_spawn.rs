//! Property tests over the spawn-strategy subsystem: for *random*
//! (NS, ND, total, method, strategy) grows, the redistributed payloads
//! must be identical across Sequential / Parallel / Async spawning —
//! the strategy only reshapes virtual time, never data — and the
//! Sequential strategy must be byte-identical to the default
//! configuration (the seed's single-constant model).

use std::sync::{Arc, Mutex};

use proteo::mam::{
    block_of, is_valid_version, DataKind, Mam, MamStatus, Method, PlannerMode, ReconfigCfg,
    Registry, SpawnStrategy, Strategy, WinPoolPolicy,
};
use proteo::netmodel::{NetParams, Topology};
use proteo::simmpi::{CommId, MpiProc, MpiSim, Payload, WORLD};
use proteo::util::proptest_lite::{check_seeded, one_of, usizes, Strategy as PStrategy};

/// Run one grow under the given spawn strategy and return the
/// reassembled contents (drain-rank order) plus the final virtual time.
fn run_grow(
    ns: usize,
    nd: usize,
    total: u64,
    method: Method,
    strategy: Strategy,
    spawn_strategy: SpawnStrategy,
) -> (Option<Vec<f64>>, f64) {
    let collected: Arc<Mutex<Vec<Option<Vec<f64>>>>> = Arc::new(Mutex::new(vec![None; nd]));
    let c2 = collected.clone();
    let mut sim = MpiSim::new(Topology::new(4, 5), NetParams::test_simple());
    sim.launch(ns, move |p: MpiProc| {
        let rank = p.rank(WORLD);
        let b = block_of(total, ns, rank);
        let mut reg = Registry::new();
        reg.register(
            "A",
            DataKind::Constant,
            total,
            Payload::real((b.ini..b.end).map(|i| (i as f64) * 0.5 + 1.0).collect()),
        );
        let decls = reg.decls();
        let cfg = ReconfigCfg {
            method,
            strategy,
            spawn_cost: 0.02,
            spawn_strategy,
            win_pool: WinPoolPolicy::off(),
            rma_chunk_kib: 0,
            rma_dereg: true,
            rma_sync: proteo::simmpi::RmaSync::Epoch,
            sched_cache: false,
            planner: PlannerMode::Fixed,
            recalib: false,
        };
        let mut mam = Mam::new(reg, cfg.clone());
        let c3 = c2.clone();
        let cfg2 = cfg.clone();
        let body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
            Arc::new(move |dp: MpiProc, merged: CommId| {
                let dmam = Mam::drain_join(&dp, merged, ns, nd, &decls, cfg2.clone());
                let dr = dp.rank(merged);
                let e = dmam.registry.entry(0);
                c3.lock().unwrap()[dr] = e.local.as_slice().map(|s| s.to_vec());
            });
        let mut status = mam.reconfigure(&p, WORLD, nd, body);
        while status == MamStatus::InProgress {
            p.compute(1e-4);
            status = mam.checkpoint(&p);
        }
        let out = mam.finish(&p, WORLD);
        if let Some(comm) = out.app_comm {
            let nr = p.rank(comm);
            let e = mam.registry.entry(0);
            c2.lock().unwrap()[nr] = e.local.as_slice().map(|s| s.to_vec());
        }
    });
    let end = sim.run().expect("simulation");
    let shards = collected.lock().unwrap();
    if shards.iter().any(|s| s.is_none()) {
        return (None, end);
    }
    let mut out = Vec::with_capacity(total as usize);
    for s in shards.iter() {
        out.extend_from_slice(s.as_ref().unwrap());
    }
    (Some(out), end)
}

fn grow_versions() -> Vec<(Method, Strategy)> {
    let mut v = Vec::new();
    for m in Method::all() {
        for s in Strategy::all() {
            if is_valid_version(m, s) {
                v.push((m, s));
            }
        }
    }
    v
}

#[test]
fn prop_payloads_identical_across_spawn_strategies() {
    let versions = grow_versions();
    check_seeded(
        "spawn strategies move identical payloads",
        usizes(1, 5)
            .pair(usizes(2, 9))
            .pair(usizes(1, 1_500))
            .pair(one_of(&versions)),
        |(((ns, nd), total), (m, s))| {
            if nd <= ns {
                return true; // property targets grows (spawning)
            }
            let total = total as u64;
            let (seq, _) = run_grow(ns, nd, total, m, s, SpawnStrategy::Sequential);
            let (par, _) = run_grow(ns, nd, total, m, s, SpawnStrategy::Parallel);
            let (asy, _) = run_grow(ns, nd, total, m, s, SpawnStrategy::Async);
            let (Some(seq), Some(par), Some(asy)) = (seq, par, asy) else {
                return false;
            };
            // Bitwise-identical contents, and the right contents.
            seq.len() as u64 == total
                && seq == par
                && seq == asy
                && seq.iter().enumerate().all(|(i, v)| *v == (i as f64) * 0.5 + 1.0)
        },
        0x5BA11,
    );
}

#[test]
fn prop_sequential_matches_default_cfg_bit_for_bit() {
    // The acceptance bar: Sequential reproduces the single-constant
    // model exactly — same payloads *and* same virtual end time as a
    // default-configured run (whose spawn_strategy is Sequential).
    let versions = grow_versions();
    check_seeded(
        "explicit Sequential == default cfg (time bit-identical)",
        usizes(1, 4).pair(usizes(2, 8)).pair(one_of(&versions)),
        |((ns, nd), (m, s))| {
            if nd <= ns {
                return true;
            }
            let (a, ta) = run_grow(ns, nd, 800, m, s, SpawnStrategy::Sequential);
            let (b, tb) = run_grow(ns, nd, 800, m, s, SpawnStrategy::default());
            a.is_some() && a == b && ta.to_bits() == tb.to_bits()
        },
        0xB17,
    );
}
