//! Integration: the full malleability pipeline — RMS decisions → MaM
//! reconfigurations → SAM application — composed over multiple resizes.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use proteo::mam::{
    Mam, MamStatus, Method, PlannerMode, ReconfigCfg, Registry, SpawnStrategy, Strategy,
    WinPoolPolicy,
};
use proteo::netmodel::{NetParams, Topology};
use proteo::proteo::{run_once, RunSpec};
use proteo::rms::{Policy, Rms};
use proteo::sam::{Sam, SamConfig};
use proteo::simmpi::{CommId, MpiProc, MpiSim, RmaSync, WORLD};

fn tiny_spec(ns: usize, nd: usize, m: Method, s: Strategy) -> RunSpec {
    let mut sam = SamConfig::sarteco25();
    sam.matrix_elems /= 1000;
    sam.colind_elems /= 1000;
    sam.rowptr_elems /= 1000;
    sam.vector_elems /= 1000;
    sam.flops_per_iter /= 1000.0;
    RunSpec {
        ns,
        nd,
        method: m,
        strategy: s,
        sam,
        net: NetParams::sarteco25(),
        cores_per_node: 20,
        warmup_iters: 2,
        post_iters: 2,
        spawn_cost: 0.05,
        spawn_strategy: SpawnStrategy::Sequential,
        seed: 11,
        win_pool: WinPoolPolicy::off(),
        rma_chunk_kib: 0,
        rma_dereg: true,
        planner: PlannerMode::Fixed,
        recalib: false,
        rma_sync: RmaSync::Epoch,
        sched_cache: false,
        faults: None,
    }
}

#[test]
fn rms_plan_drives_a_resize_sequence() {
    // The RMS's Plan policy issues 20→80→40; the job follows it through
    // real reconfigurations (scripted in the runner: we check each step
    // produces sane metrics and the final size matches).
    let mut rms = Rms::new(160, 20, Policy::Plan(vec![80, 40]));
    let job = rms.submit("cg", 20, 20, 160);
    let mut current = 20usize;
    let mut steps = Vec::new();
    while let Some(d) = rms.checkpoint_decision(job) {
        let r = run_once(&tiny_spec(d.from, d.to, Method::Collective, Strategy::WaitDrains));
        assert!(r.redist_time > 0.0, "resize {d:?} did nothing");
        rms.apply(d);
        current = d.to;
        steps.push((d.from, d.to, r.redist_time));
    }
    assert_eq!(current, 40);
    assert_eq!(steps.len(), 2);
    assert_eq!((steps[0].0, steps[0].1), (20, 80));
    assert_eq!((steps[1].0, steps[1].1), (80, 40));
}

#[test]
fn sam_iterations_speed_up_after_grow() {
    let r = run_once(&tiny_spec(20, 80, Method::Collective, Strategy::Blocking));
    assert!(
        r.t_it_nd < r.t_base * 0.5,
        "4x more ranks must speed iterations: base={} nd={}",
        r.t_base,
        r.t_it_nd
    );
}

#[test]
fn sam_iterations_slow_down_after_shrink() {
    let r = run_once(&tiny_spec(80, 20, Method::Collective, Strategy::Blocking));
    assert!(
        r.t_it_nd > r.t_base * 2.0,
        "4x fewer ranks must slow iterations: base={} nd={}",
        r.t_base,
        r.t_it_nd
    );
}

#[test]
fn background_strategies_overlap_blocking_do_not() {
    for (s, expect_overlap) in [
        (Strategy::Blocking, false),
        (Strategy::NonBlocking, true),
        (Strategy::WaitDrains, true),
    ] {
        let r = run_once(&tiny_spec(8, 4, Method::Collective, s));
        if expect_overlap {
            assert!(r.n_it >= 1.0, "{s:?} must overlap iterations");
        } else {
            assert_eq!(r.n_it, 0.0, "{s:?} must not overlap");
        }
    }
}

#[test]
fn reconf_total_includes_spawn_and_finish() {
    let r = run_once(&tiny_spec(4, 8, Method::Collective, Strategy::Blocking));
    assert!(
        r.reconf_total >= r.redist_time,
        "total {} < redistribution {}",
        r.reconf_total,
        r.redist_time
    );
}

#[test]
fn multi_resize_marathon_with_sam() {
    // Drive SAM+MaM through three resizes by hand (grow, shrink, grow)
    // and count every iteration tick across phases.
    let seq = [(4usize, 8usize), (8, 2), (2, 6)];
    let sam_cfg = {
        let mut c = SamConfig::tiny_real();
        c.jitter = 0.0;
        c
    };
    let ticks = Arc::new(AtomicUsize::new(0));
    let t2 = ticks.clone();
    let sizes_seen: Arc<Mutex<Vec<usize>>> = Arc::new(Mutex::new(Vec::new()));
    let sz2 = sizes_seen.clone();

    fn app_phase(
        sam: &mut Sam,
        p: &MpiProc,
        comm: CommId,
        iters: usize,
        ticks: &Arc<AtomicUsize>,
    ) {
        for _ in 0..iters {
            sam.iteration(p, comm);
            ticks.fetch_add(1, Ordering::SeqCst);
        }
    }

    // One shared recursive driver used by both original and spawned
    // ranks: runs phases from `stage` onward.
    fn run_stages(
        p: &MpiProc,
        comm: CommId,
        stage: usize,
        seq: &[(usize, usize)],
        sam_cfg: &SamConfig,
        ticks: &Arc<AtomicUsize>,
        sizes: &Arc<Mutex<Vec<usize>>>,
        mut mam: Mam,
    ) {
        let mut comm = comm;
        let mut sam = Sam::new(sam_cfg.clone(), 5, p.gpid());
        for (k, &(ns, nd)) in seq.iter().enumerate().skip(stage) {
            assert_eq!(p.size(comm), ns, "stage {k}");
            app_phase(&mut sam, p, comm, 2, ticks);
            let cfg = mam.cfg.clone();
            let decls = mam.registry.decls();
            let seq2 = seq.to_vec();
            let sam2 = sam_cfg.clone();
            let t3 = ticks.clone();
            let sz3 = sizes.clone();
            let body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
                Arc::new(move |dp: MpiProc, merged: CommId| {
                    let dmam = Mam::drain_join(&dp, merged, ns, nd, &decls, cfg.clone());
                    run_stages(&dp, merged, k + 1, &seq2, &sam2, &t3, &sz3, dmam);
                });
            let mut status = mam.reconfigure(p, comm, nd, body);
            while status == MamStatus::InProgress {
                sam.iteration_with_flag(p, comm, false);
                status = mam.checkpoint(p);
                // flag protocol shortened: tiny problems finish fast and
                // every rank polls in lock-step here (no early exit).
                if status == MamStatus::Completed {
                    break;
                }
            }
            // Drain the flag consensus: everyone iterates until all done.
            loop {
                let (_, all) = sam.iteration_with_flag(p, comm, true);
                if all {
                    break;
                }
            }
            let out = mam.finish(p, comm);
            match out.app_comm {
                Some(c) => comm = c,
                None => return, // retired by a shrink
            }
            sizes.lock().unwrap().push(p.size(comm));
        }
        app_phase(&mut sam, p, comm, 2, ticks);
    }

    let mut sim = MpiSim::new(Topology::new(2, 6), NetParams::test_simple());
    let cfg0 = sam_cfg.clone();
    sim.launch(4, move |p: MpiProc| {
        let rank = p.rank(WORLD);
        let mut reg = Registry::new();
        let sam = Sam::new(cfg0.clone(), 5, p.gpid());
        sam.register_data(&mut reg, 4, rank);
        let mam = Mam::new(
            reg,
            ReconfigCfg {
                method: Method::RmaLockall,
                strategy: Strategy::WaitDrains,
                spawn_cost: 0.01,
                spawn_strategy: SpawnStrategy::Sequential,
                win_pool: WinPoolPolicy::off(),
                rma_chunk_kib: 0,
                rma_dereg: true,
                rma_sync: RmaSync::Epoch,
                sched_cache: false,
                planner: PlannerMode::Fixed,
                recalib: false,
            },
        );
        run_stages(&p, WORLD, 0, &seq, &cfg0, &t2, &sz2, mam);
    });
    sim.run().unwrap();
    assert!(ticks.load(Ordering::SeqCst) > 0);
    let sizes = sizes_seen.lock().unwrap();
    assert!(sizes.contains(&8) && sizes.contains(&2) && sizes.contains(&6), "{sizes:?}");
}
