//! Property tests for `proteo audit` (the static determinism &
//! concurrency lint engine in `proteo::analysis`):
//!
//! * a fixture seeded with one violation per lint class is flagged
//!   with the right lint name at the right line,
//! * `audit:allow` suppression round-trips (and goes stale loudly),
//! * the audit is deterministic — over repeated runs, over file order,
//!   and over the real `src/**` tree,
//! * the real tree is clean: `proteo audit --deny` would exit 0.

use proteo::analysis::{audit_sources, audit_tree, Finding};

/// One violation per lint class, each tagged with a `MARK:` comment so
/// the expectations below track line numbers by content, not by magic
/// constants.
const FIXTURE: &str = r#"//! Audit fixture: one violation per lint class.

use std::collections::HashMap; // MARK:hashmap
use std::time::Instant; // MARK:clock-import

fn wall() -> Instant { // MARK:clock-sig
    Instant::now() // MARK:clock-call
}

fn entropy() -> u64 {
    let state = RandomState::new(); // MARK:rng
    0
}

fn bare() {
    std::thread::spawn(|| {}); // MARK:spawn
}

fn order(world: &std::sync::Mutex<u32>, worker_pool: &std::sync::Mutex<u32>) {
    let mut pool = worker_pool.lock().unwrap();
    let w = world.lock().unwrap(); // MARK:lock-order
}

#[deprecated(note = "use new_api")]
fn old_api() {}

fn caller() {
    old_api(); // MARK:shim-call
}

// audit:allow(det::unseeded-rng, nothing to suppress) MARK:stale
fn quiet() {}

fn suppressed() {
    // audit:allow(conc::bare-thread-spawn, fixture proves suppression)
    std::thread::spawn(|| {}); // MARK:suppressed
}
"#;

/// 1-based line of the first fixture line containing `marker`.
fn line_of(marker: &str) -> usize {
    FIXTURE
        .lines()
        .position(|l| l.contains(marker))
        .unwrap_or_else(|| panic!("marker {marker} missing from fixture"))
        + 1
}

fn audit_fixture() -> Vec<Finding> {
    audit_sources(&[("fixture.rs".to_string(), FIXTURE.to_string())])
}

fn has(findings: &[Finding], lint: &str, line: usize) -> bool {
    findings.iter().any(|f| f.lint == lint && f.line == line)
}

#[test]
fn fixture_fires_every_lint_class_at_the_right_line() {
    let f = audit_fixture();
    let expect = [
        ("det::hashmap-iter-escapes", "MARK:hashmap"),
        ("det::wall-clock-in-sim", "MARK:clock-import"),
        ("det::wall-clock-in-sim", "MARK:clock-sig"),
        ("det::wall-clock-in-sim", "MARK:clock-call"),
        ("det::unseeded-rng", "MARK:rng"),
        ("conc::bare-thread-spawn", "MARK:spawn"),
        ("conc::lock-order", "MARK:lock-order"),
        ("api::deprecated-shim", "MARK:shim-call"),
        ("audit::stale-allow", "MARK:stale"),
    ];
    for (lint, marker) in expect {
        assert!(
            has(&f, lint, line_of(marker)),
            "{lint} missing at {marker} (line {}); got: {f:#?}",
            line_of(marker)
        );
    }
    assert_eq!(f.len(), expect.len(), "unexpected extra findings: {f:#?}");
}

#[test]
fn allow_suppression_round_trips() {
    // The suppressed spawn never surfaces...
    let f = audit_fixture();
    assert!(
        !has(&f, "conc::bare-thread-spawn", line_of("MARK:suppressed")),
        "allow directive failed to suppress"
    );
    // ...removing the directive resurfaces exactly that finding...
    let stripped: String = FIXTURE
        .lines()
        .filter(|l| !l.contains("audit:allow(conc::bare-thread-spawn"))
        .map(|l| format!("{l}\n"))
        .collect();
    let f2 = audit_sources(&[("fixture.rs".to_string(), stripped.clone())]);
    assert_eq!(f2.len(), f.len() + 1, "exactly one finding resurfaces");
    assert!(
        f2.iter().any(|x| x.lint == "conc::bare-thread-spawn"
            && stripped.lines().nth(x.line - 1).unwrap().contains("MARK:suppressed")),
        "the resurfaced finding is the previously suppressed spawn"
    );
    // ...and a directive whose violation was fixed goes stale loudly
    // (the fixture's MARK:stale directive proves this path already).
    assert!(has(&f, "audit::stale-allow", line_of("MARK:stale")));
}

#[test]
fn reasonless_allow_is_flagged_and_never_suppresses() {
    let src = concat!(
        "fn f() {\n    // audit:allow(conc::bare-thread-spawn)\n",
        "    std::thread::spawn(|| {});\n}\n"
    );
    let f = audit_sources(&[("a.rs".to_string(), src.to_string())]);
    assert!(has(&f, "conc::bare-thread-spawn", 3), "reasonless allow must not suppress");
    assert!(has(&f, "audit::stale-allow", 2), "reasonless allow is itself flagged");
}

#[test]
fn closures_are_lock_order_barriers_but_reentry_is_not() {
    // The closure body runs later on another activity: holding the
    // world lock while *constructing* a closure that locks it is fine.
    let ok = concat!(
        "fn f(world: &M) {\n    let w = world.lock().unwrap();\n",
        "    let job = move || {\n        let w2 = world.lock().unwrap();\n    };\n}\n"
    );
    let f = audit_sources(&[("a.rs".to_string(), ok.to_string())]);
    assert!(
        !f.iter().any(|x| x.lint == "conc::lock-order"),
        "closure must act as a barrier: {f:#?}"
    );
    // Straight-line re-entry deadlocks and is flagged.
    let bad = concat!(
        "fn f(world: &M) {\n    let w = world.lock().unwrap();\n",
        "    let w2 = world.lock().unwrap();\n}\n"
    );
    let f = audit_sources(&[("a.rs".to_string(), bad.to_string())]);
    assert!(has(&f, "conc::lock-order", 3), "re-entrant world lock: {f:#?}");
}

#[test]
fn deprecated_twin_names_never_false_positive() {
    // `helper` exists both as a deprecated shim (in old.rs) and as an
    // unrelated non-deprecated fn (in col.rs).  Unqualified calls are
    // ambiguous without type info and must not be flagged; a call
    // qualified with the shim's module must.
    let old = "#[deprecated(note = \"gone\")]\npub fn helper() {}\n";
    let col = "pub fn helper() {}\nfn caller() { helper(); }\n";
    let user = "fn f() { old::helper(); }\nfn g() { col::helper(); }\n";
    let f = audit_sources(&[
        ("old.rs".to_string(), old.to_string()),
        ("col.rs".to_string(), col.to_string()),
        ("user.rs".to_string(), user.to_string()),
    ]);
    let dep: Vec<_> = f.iter().filter(|x| x.lint == "api::deprecated-shim").collect();
    assert_eq!(dep.len(), 1, "only the old::-qualified call is certain: {f:#?}");
    assert_eq!((dep[0].file.as_str(), dep[0].line), ("user.rs", 1));
}

#[test]
fn audit_is_deterministic_and_file_order_independent() {
    let files: Vec<(String, String)> = vec![
        ("b.rs".to_string(), "use std::time::Instant;\n".to_string()),
        ("a.rs".to_string(), FIXTURE.to_string()),
        ("c.rs".to_string(), "use std::collections::HashSet;\n".to_string()),
    ];
    let mut rev = files.clone();
    rev.reverse();
    let fwd = audit_sources(&files);
    assert_eq!(fwd, audit_sources(&rev), "file order leaked into findings");
    assert_eq!(fwd, audit_sources(&files), "audit not reproducible");
    // Sorted output: (file, line) non-decreasing.
    for pair in fwd.windows(2) {
        assert!((&pair[0].file, pair[0].line) <= (&pair[1].file, pair[1].line));
    }
}

#[test]
fn real_tree_is_clean_and_audit_tree_is_deterministic() {
    // Integration tests run with CWD = the crate root, so `src` is the
    // tree `proteo audit --deny` gates in CI.
    let root = std::path::Path::new("src");
    assert!(root.is_dir(), "expected to run from the crate root");
    let a = audit_tree(root).expect("audit walks the tree");
    let b = audit_tree(root).expect("audit walks the tree");
    assert_eq!(a, b, "tree audit not reproducible");
    assert!(
        a.is_empty(),
        "src/** violates the determinism contract:\n{}",
        a.iter().map(|f| format!("  {f}\n")).collect::<String>()
    );
}
