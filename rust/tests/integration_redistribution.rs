//! Integration: every redistribution version moves real data
//! bit-for-bit across grow, shrink and multi-structure registries.
//!
//! This is the correctness backbone for the whole method × strategy
//! matrix — the unit tests cover each method in isolation; here the
//! full `Mam` driver (Merge process management + state machine +
//! variable-data phase) runs end to end.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use proteo::mam::{
    block_of, is_valid_version, DataKind, Mam, MamStatus, Method, PlannerMode, ReconfigCfg,
    Registry, SpawnStrategy, Strategy, WinPoolPolicy,
};
use proteo::netmodel::{NetParams, Topology};
use proteo::simmpi::{CommId, MpiProc, MpiSim, Payload, WORLD};

/// Expected value of element `i` of structure `s` after any number of
/// redistributions (content must be preserved exactly).
fn val(s: usize, i: u64) -> f64 {
    (s * 1_000_000) as f64 + i as f64
}

/// Run one full reconfiguration over `n_structs` real structures and
/// verify every continuing rank holds exactly its new block.
fn verify_roundtrip(ns: usize, nd: usize, method: Method, strategy: Strategy, n_structs: usize) {
    let totals: Vec<u64> = (0..n_structs).map(|s| 400 + 37 * s as u64).collect();
    let mut sim = MpiSim::new(Topology::new(2, 8), NetParams::test_simple());
    let verified = Arc::new(AtomicUsize::new(0));
    let v2 = verified.clone();
    let totals2 = totals.clone();
    sim.launch(ns, move |p: MpiProc| {
        let rank = p.rank(WORLD);
        let mut reg = Registry::new();
        for (s, &total) in totals2.iter().enumerate() {
            let b = block_of(total, ns, rank);
            let kind = if s == 0 { DataKind::Variable } else { DataKind::Constant };
            reg.register(
                &format!("S{s}"),
                kind,
                total,
                Payload::real((b.ini..b.end).map(|i| val(s, i)).collect()),
            );
        }
        let decls = reg.decls();
        let cfg = ReconfigCfg {
            method,
            strategy,
            spawn_cost: 0.01,
            spawn_strategy: SpawnStrategy::Sequential,
            win_pool: WinPoolPolicy::off(),
            rma_chunk_kib: 0,
            rma_dereg: true,
            rma_sync: proteo::simmpi::RmaSync::Epoch,
            sched_cache: false,
            planner: PlannerMode::Fixed,
            recalib: false,
        };
        let mut mam = Mam::new(reg, cfg.clone());
        let totals3 = totals2.clone();
        let v3 = v2.clone();
        let cfg2 = cfg.clone();
        let drain_body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
            Arc::new(move |dp: MpiProc, merged: CommId| {
                let dmam = Mam::drain_join(&dp, merged, ns, nd, &decls, cfg2.clone());
                let dr = dp.rank(merged);
                for (s, &total) in totals3.iter().enumerate() {
                    let b = block_of(total, nd, dr);
                    let got = dmam.registry.entry(s).local.as_slice().unwrap();
                    let want: Vec<f64> = (b.ini..b.end).map(|i| val(s, i)).collect();
                    assert_eq!(got, &want[..], "spawned drain {dr} S{s}");
                }
                v3.fetch_add(1, Ordering::SeqCst);
            });
        let mut status = mam.reconfigure(&p, WORLD, nd, drain_body);
        while status == MamStatus::InProgress {
            p.compute(1e-3);
            status = mam.checkpoint(&p);
        }
        let out = mam.finish(&p, WORLD);
        if let Some(comm) = out.app_comm {
            let nr = p.rank(comm);
            for (s, &total) in totals2.iter().enumerate() {
                let b = block_of(total, nd, nr);
                let got = mam.registry.entry(s).local.as_slice().unwrap();
                let want: Vec<f64> = (b.ini..b.end).map(|i| val(s, i)).collect();
                assert_eq!(got, &want[..], "rank {nr} S{s} after {ns}->{nd}");
            }
            v2.fetch_add(1, Ordering::SeqCst);
        } else {
            assert!(rank >= nd);
        }
    });
    sim.run().unwrap_or_else(|e| panic!("{method:?}×{strategy:?} {ns}->{nd}: {e}"));
    assert_eq!(verified.load(Ordering::SeqCst), nd, "{method:?}×{strategy:?}");
}

#[test]
fn all_versions_grow_preserve_data() {
    for m in Method::all() {
        for s in Strategy::all() {
            if is_valid_version(m, s) {
                verify_roundtrip(3, 9, m, s, 2);
            }
        }
    }
}

#[test]
fn all_versions_shrink_preserve_data() {
    for m in Method::all() {
        for s in Strategy::all() {
            if is_valid_version(m, s) {
                verify_roundtrip(9, 3, m, s, 2);
            }
        }
    }
}

#[test]
fn many_structures_with_uneven_sizes() {
    verify_roundtrip(4, 7, Method::RmaLockall, Strategy::WaitDrains, 5);
    verify_roundtrip(7, 4, Method::Collective, Strategy::NonBlocking, 5);
}

#[test]
fn extreme_ratios() {
    verify_roundtrip(1, 12, Method::RmaLock, Strategy::WaitDrains, 2);
    verify_roundtrip(12, 1, Method::Collective, Strategy::WaitDrains, 2);
    verify_roundtrip(2, 16, Method::Collective, Strategy::Threading, 1);
    verify_roundtrip(16, 2, Method::RmaLockall, Strategy::Threading, 1);
}

#[test]
fn back_to_back_reconfigurations_compose() {
    // 4 -> 8 -> 2 with real data: the second resize redistributes what
    // the first one produced.
    let total = 555u64;
    let mut sim = MpiSim::new(Topology::new(2, 8), NetParams::test_simple());
    let done = Arc::new(AtomicUsize::new(0));
    let d2 = done.clone();
    sim.launch(4, move |p: MpiProc| {
        let rank = p.rank(WORLD);
        let b = block_of(total, 4, rank);
        let mut reg = Registry::new();
        reg.register(
            "A",
            DataKind::Constant,
            total,
            Payload::real((b.ini..b.end).map(|i| i as f64).collect()),
        );
        let decls = reg.decls();
        let cfg = ReconfigCfg {
            method: Method::RmaLockall,
            strategy: Strategy::WaitDrains,
            spawn_cost: 0.01,
            ..ReconfigCfg::default()
        };
        let mut mam = Mam::new(reg, cfg.clone());
        let d3 = d2.clone();
        let cfg2 = cfg.clone();
        // Spawned drains (first resize): join, verify, then take part in
        // the second resize as sources.
        let drain_body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
            Arc::new(move |dp: MpiProc, merged: CommId| {
                let mut dmam = Mam::drain_join(&dp, merged, 4, 8, &decls, cfg2.clone());
                // Second resize: 8 -> 2 (shrink; no spawns).
                let nobody: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> = Arc::new(|_, _| {});
                let mut st = dmam.reconfigure(&dp, merged, 2, nobody);
                while st == MamStatus::InProgress {
                    dp.compute(1e-3);
                    st = dmam.checkpoint(&dp);
                }
                let out = dmam.finish(&dp, merged);
                if let Some(c) = out.app_comm {
                    let nr = dp.rank(c);
                    let nb = block_of(total, 2, nr);
                    let got = dmam.registry.entry(0).local.as_slice().unwrap();
                    let want: Vec<f64> = (nb.ini..nb.end).map(|i| i as f64).collect();
                    assert_eq!(got, &want[..]);
                    d3.fetch_add(1, Ordering::SeqCst);
                }
            });
        // First resize: 4 -> 8.
        let mut status = mam.reconfigure(&p, WORLD, 8, drain_body);
        while status == MamStatus::InProgress {
            p.compute(1e-3);
            status = mam.checkpoint(&p);
        }
        let out = mam.finish(&p, WORLD);
        let comm = out.app_comm.expect("grow keeps all");
        // Second resize: 8 -> 2.
        let nobody: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> = Arc::new(|_, _| {});
        let mut st = mam.reconfigure(&p, comm, 2, nobody);
        while st == MamStatus::InProgress {
            p.compute(1e-3);
            st = mam.checkpoint(&p);
        }
        let out2 = mam.finish(&p, comm);
        if let Some(c) = out2.app_comm {
            let nr = p.rank(c);
            let nb = block_of(total, 2, nr);
            let got = mam.registry.entry(0).local.as_slice().unwrap();
            let want: Vec<f64> = (nb.ini..nb.end).map(|i| i as f64).collect();
            assert_eq!(got, &want[..]);
            d2.fetch_add(1, Ordering::SeqCst);
        } else {
            assert!(rank >= 2);
        }
    });
    sim.run().unwrap();
    assert_eq!(done.load(Ordering::SeqCst), 2, "both final ranks verified");
}

#[test]
fn fused_single_window_preserves_data() {
    // The §VI future-work variant must be exactly as correct.
    use proteo::mam::{rma, Roles};
    let totals = [250u64, 97, 41];
    let (ns, nd) = (5usize, 3usize);
    let mut sim = MpiSim::new(Topology::new(1, 6), NetParams::test_simple());
    sim.launch(ns, move |p: MpiProc| {
        let rank = p.rank(WORLD);
        let roles = Roles { ns, nd, rank };
        let mut reg = Registry::new();
        for (s, &total) in totals.iter().enumerate() {
            let b = block_of(total, ns, rank);
            reg.register(
                &format!("S{s}"),
                DataKind::Constant,
                total,
                Payload::real((b.ini..b.end).map(|i| val(s, i)).collect()),
            );
        }
        let out = rma::redistribute_blocking_fused(&p, WORLD, &roles, &reg, &[0, 1, 2], true);
        if roles.is_drain() {
            for (s, &total) in totals.iter().enumerate() {
                let b = block_of(total, nd, rank);
                let got = out[s].as_ref().unwrap().as_slice().unwrap();
                let want: Vec<f64> = (b.ini..b.end).map(|i| val(s, i)).collect();
                assert_eq!(got, &want[..], "fused S{s}");
            }
        }
    });
    sim.run().unwrap();
}
