//! Property tests over the fault-injection + recovery machinery: for
//! *random* shapes, versions and fault weather, a reconfiguration
//! either completes with byte-identical payloads (retries heal, data is
//! never corrupted) or aborts cleanly (rollback leaves the sources'
//! data untouched and the simulation finishes) — and inactive specs
//! leave every run bit-identical to a run with no spec at all.

use std::sync::{Arc, Mutex};

use proteo::experiments::scenario::{run_scenario, ScenarioSpec};
use proteo::mam::{
    block_of, is_valid_version, DataKind, Mam, MamStatus, Method, PlannerMode, ReconfigCfg,
    Registry, SpawnStrategy, Strategy, WinPoolPolicy,
};
use proteo::netmodel::{NetParams, Topology};
use proteo::simmpi::{CommId, FaultPlan, FaultSpec, MpiProc, MpiSim, Payload, WORLD};
use proteo::util::proptest_lite::{check_seeded, one_of, usizes, Strategy as PStrategy};

/// Most dispatches the test driver re-queues an aborted resize.
const MAX_DISPATCHES: u64 = 4;

struct FaultyOutcome {
    /// Reassembled drain-side contents when the resize completed.
    payload: Option<Vec<f64>>,
    /// Source-side contents when every dispatch aborted (rollback must
    /// have left them untouched).
    survivors: Option<Vec<f64>>,
    /// Virtual end time of the whole simulation.
    end: f64,
}

/// Run one resize under `faults`, re-dispatching on abort like the RMS
/// loop does, and report what the data looks like afterwards.
fn run_faulty(
    ns: usize,
    nd: usize,
    total: u64,
    method: Method,
    strategy: Strategy,
    faults: Option<&str>,
) -> FaultyOutcome {
    let collected: Arc<Mutex<Vec<Option<Vec<f64>>>>> = Arc::new(Mutex::new(vec![None; nd]));
    let aborted: Arc<Mutex<Vec<Option<Vec<f64>>>>> = Arc::new(Mutex::new(vec![None; ns]));
    let c2 = collected.clone();
    let a2 = aborted.clone();
    let mut sim = MpiSim::new(Topology::new(4, 5), NetParams::test_simple());
    if let Some(s) = faults {
        sim.set_faults(FaultPlan::new(FaultSpec::parse(s).expect("test fault spec")));
    }
    sim.launch(ns, move |p: MpiProc| {
        let rank = p.rank(WORLD);
        let b = block_of(total, ns, rank);
        let mut reg = Registry::new();
        reg.register(
            "A",
            DataKind::Constant,
            total,
            Payload::real((b.ini..b.end).map(|i| (i as f64) * 0.5 + 1.0).collect()),
        );
        let decls = reg.decls();
        let cfg = ReconfigCfg {
            method,
            strategy,
            spawn_cost: 0.02,
            spawn_strategy: SpawnStrategy::Sequential,
            win_pool: WinPoolPolicy::off(),
            rma_chunk_kib: 0,
            rma_dereg: true,
            rma_sync: proteo::simmpi::RmaSync::Epoch,
            sched_cache: false,
            planner: PlannerMode::Fixed,
            recalib: false,
        };
        let mut mam = Mam::new(reg, cfg.clone());
        let mut dispatch: u64 = 0;
        let status = loop {
            mam.cfg = cfg.clone();
            mam.set_fault_ctx(0, dispatch);
            let c3 = c2.clone();
            let decls2 = decls.clone();
            let cfg2 = cfg.clone();
            let body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
                Arc::new(move |dp: MpiProc, merged: CommId| {
                    let dmam = Mam::drain_join(&dp, merged, ns, nd, &decls2, cfg2.clone());
                    let dr = dp.rank(merged);
                    let e = dmam.registry.entry(0);
                    c3.lock().unwrap()[dr] = e.local.as_slice().map(|s| s.to_vec());
                });
            let mut status = mam.reconfigure(&p, WORLD, nd, body);
            while status == MamStatus::InProgress {
                p.compute(1e-4);
                status = mam.checkpoint(&p);
            }
            if status == MamStatus::Aborted {
                dispatch += 1;
                if dispatch >= MAX_DISPATCHES {
                    break status;
                }
                continue;
            }
            break status;
        };
        if status == MamStatus::Aborted {
            // Abandoned for good: the rollback must have left this
            // source's shard exactly as registered.
            let e = mam.registry.entry(0);
            a2.lock().unwrap()[rank] = e.local.as_slice().map(|s| s.to_vec());
            return;
        }
        let out = mam.finish(&p, WORLD);
        if let Some(comm) = out.app_comm {
            let nr = p.rank(comm);
            let e = mam.registry.entry(0);
            c2.lock().unwrap()[nr] = e.local.as_slice().map(|s| s.to_vec());
        }
    });
    let end = sim.run().expect("simulation");
    let reassemble = |shards: &[Option<Vec<f64>>]| -> Option<Vec<f64>> {
        if shards.iter().any(|s| s.is_none()) {
            return None;
        }
        let mut out = Vec::with_capacity(total as usize);
        for s in shards {
            out.extend_from_slice(s.as_ref().unwrap());
        }
        Some(out)
    };
    FaultyOutcome {
        payload: reassemble(&collected.lock().unwrap()),
        survivors: reassemble(&aborted.lock().unwrap()),
        end,
    }
}

fn expected(total: u64) -> Vec<f64> {
    (0..total).map(|i| (i as f64) * 0.5 + 1.0).collect()
}

fn grow_versions() -> Vec<(Method, Strategy)> {
    let mut v = Vec::new();
    for m in Method::all() {
        for s in Strategy::all() {
            if is_valid_version(m, s) {
                v.push((m, s));
            }
        }
    }
    v
}

#[test]
fn prop_spawn_retry_heals_and_preserves_payloads() {
    // `spawn=first2` with the default retry budget (retries=2): the
    // first two attempts of the grow fail, the third succeeds within
    // dispatch 0 — payloads identical to a healthy run, virtual time
    // strictly later (detection + backoff are real).
    let versions = grow_versions();
    check_seeded(
        "first2 heals inside the retry budget",
        usizes(1, 4)
            .pair(usizes(2, 8))
            .pair(usizes(1, 1_000))
            .pair(one_of(&versions)),
        |(((ns, nd), total), (m, s))| {
            if nd <= ns {
                return true; // spawn faults only exist on grows
            }
            let total = total as u64;
            let faulty = run_faulty(ns, nd, total, m, s, Some("spawn=first2,mode=wave"));
            let healthy = run_faulty(ns, nd, total, m, s, None);
            let (Some(a), Some(b)) = (faulty.payload, healthy.payload) else {
                return false;
            };
            a == expected(total) && a == b && faulty.end > healthy.end
        },
        0xFA17,
    );
}

#[test]
fn prop_random_fault_weather_never_corrupts_data() {
    // Random seeds and fault mixes over random shapes: whatever the
    // weather does, the resize either completes with exactly the right
    // bytes or is abandoned with the sources' shards untouched — and
    // the simulation itself always terminates.
    let weather = [
        "spawn=0.4,mode=wave",
        "spawn=0.6,mode=rank,kind=hang,timeout=0.1",
        "spawn=1.0,mode=wave,retries=1",
        "spawn=0.3,mode=rank,reg=0.5x3,straggler=0.4@0.01",
        "reg=1.0x2,straggler=1.0@0.02",
    ];
    let versions = grow_versions();
    check_seeded(
        "faults never corrupt payloads",
        usizes(1, 4)
            .pair(usizes(2, 8))
            .pair(usizes(1, 800))
            .pair(one_of(&versions))
            .pair(one_of(&weather))
            .pair(usizes(1, 1_000)),
        |(((((ns, nd), total), (m, s)), w), seed)| {
            let total = total as u64;
            let spec = format!("seed={seed},{w}");
            let out = run_faulty(ns, nd, total, m, s, Some(&spec));
            if !out.end.is_finite() {
                return false;
            }
            match (out.payload, out.survivors) {
                // Completed: the drains hold exactly the declared data.
                (Some(p), None) => p == expected(total),
                // Abandoned: the rollback left the sources' data as
                // registered, ready for the next re-dispatch.
                (None, Some(sv)) => sv == expected(total),
                _ => false,
            }
        },
        0xC4A05,
    );
}

#[test]
fn prop_inactive_specs_are_bit_identical_to_no_spec() {
    // A spec that injects nothing (probabilities all zero — recovery
    // knobs alone don't count) must not perturb a single bit of the
    // simulation, exactly like passing no `--faults` at all.
    let versions = grow_versions();
    check_seeded(
        "inactive spec == no spec, bit for bit",
        usizes(1, 4).pair(usizes(2, 8)).pair(one_of(&versions)),
        |((ns, nd), (m, s))| {
            let off = run_faulty(ns, nd, 600, m, s, None);
            let inert = run_faulty(
                ns,
                nd,
                600,
                m,
                s,
                Some("seed=9,retries=5,backoff=0.5,kind=hang,timeout=0.9"),
            );
            off.payload.is_some()
                && off.payload == inert.payload
                && off.end.to_bits() == inert.end.to_bits()
        },
        0x0FF,
    );
}

#[test]
fn prop_faulty_scenarios_are_deterministic_and_report_recovery() {
    // The closed-loop scenario under random fault seeds: every run is
    // byte-deterministic (same JSON twice), and unrecoverable weather
    // still finishes the job while reporting its rollbacks.
    for seed in [3u64, 77, 512] {
        let mut sp = ScenarioSpec::rms_trace(true);
        sp.planner = PlannerMode::Fixed;
        sp.faults =
            Some(FaultSpec::parse(&format!("seed={seed},spawn=0.7,mode=wave,retries=1")).unwrap());
        let a = run_scenario(&sp);
        let b = run_scenario(&sp);
        assert_eq!(
            a.to_json().to_pretty(),
            b.to_json().to_pretty(),
            "seed {seed}: faulty scenario must be byte-deterministic"
        );
        assert!(a.makespan.is_finite() && a.makespan > 0.0, "seed {seed}");
        let f = a.faults.expect("active faults must be summarized");
        assert_eq!(f.scheduled_resizes as usize, a.resizes.len(), "seed {seed}");
        // With p=0.7 per dispatch and a 2-attempt budget, some retry or
        // rollback activity is all but certain; require the report to
        // show *something* happened (retries or rollbacks) so the
        // summary is not silently zeroed.
        assert!(
            f.spawn_retries > 0 || f.rollbacks > 0,
            "seed {seed}: no recovery activity reported: {f:?}"
        );
    }
}
