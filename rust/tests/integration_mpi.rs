//! Integration: simmpi semantics across modules — mixed p2p +
//! collective traffic, derived communicators, dynamic spawning, RMA
//! epochs and the threaded progress model, all at once.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use proteo::netmodel::{NetParams, Topology};
use proteo::simmpi::{recv_buf_real, CommId, MpiProc, MpiSim, Payload, WinCreateOpts, WORLD};

fn sim(nodes: usize, cores: usize) -> MpiSim {
    MpiSim::new(Topology::new(nodes, cores), NetParams::test_simple())
}

#[test]
fn ring_pipeline_with_collective_checkpoints() {
    // Token passes around a ring; every 4 hops the ring barriers.
    let n = 8;
    let mut s = sim(2, 4);
    let hops = Arc::new(AtomicUsize::new(0));
    let h2 = hops.clone();
    s.launch(n, move |p: MpiProc| {
        let r = p.rank(WORLD);
        for round in 0..4 {
            if r == 0 {
                p.send(WORLD, 1, round, Payload::real(vec![round as f64]));
                let m = p.recv(WORLD, Some(n - 1), round);
                assert_eq!(m.as_slice().unwrap()[0], round as f64);
            } else {
                let m = p.recv(WORLD, Some(r - 1), round);
                p.send(WORLD, (r + 1) % n, round, m);
            }
            h2.fetch_add(1, Ordering::SeqCst);
            p.barrier(WORLD);
        }
    });
    s.run().unwrap();
    assert_eq!(hops.load(Ordering::SeqCst), 4 * n);
}

#[test]
fn sub_communicator_collectives_are_independent() {
    // Two halves run different collective sequences concurrently.
    let mut s = sim(2, 4);
    s.launch(8, |p: MpiProc| {
        let sub = p.comm_sub(WORLD, 4);
        if p.in_comm(sub) {
            // Lower half: alltoallv among 4.
            let r = p.rank(sub) as f64;
            let sends = (0..4).map(|j| Payload::real(vec![10.0 * r + j as f64])).collect();
            let got = p.alltoallv(sub, sends);
            let vals: Vec<f64> = got.iter().map(|b| b.as_slice().unwrap()[0]).collect();
            assert_eq!(vals, vec![r, 10.0 + r, 20.0 + r, 30.0 + r]);
        } else {
            // Upper half: a chain of barriers + allgathers on WORLD
            // would deadlock; use p2p among themselves instead.
            let r = p.rank(WORLD);
            let peer = if r % 2 == 0 { r + 1 } else { r - 1 };
            if r % 2 == 0 {
                p.send(WORLD, peer, 9, Payload::virt(100));
            } else {
                let _ = p.recv(WORLD, Some(peer), 9);
            }
        }
        p.barrier(WORLD);
    });
    s.run().unwrap();
}

#[test]
fn nested_spawn_then_shrink_topology() {
    // 2 ranks spawn 4 more, then the 6 shrink to 3.
    let reached = Arc::new(AtomicUsize::new(0));
    let r2 = reached.clone();
    let mut s = sim(2, 4);
    s.launch(2, move |p: MpiProc| {
        let r3 = r2.clone();
        let body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
            Arc::new(move |child: MpiProc, mc: CommId| {
                assert_eq!(child.size(mc), 6);
                child.barrier(mc);
                let sub = child.comm_sub(mc, 3);
                if child.in_comm(sub) {
                    child.barrier(sub);
                    r3.fetch_add(1, Ordering::SeqCst);
                }
            });
        let mc = p.spawn_merge(WORLD, 4, 0.1, body);
        assert_eq!(p.size(mc), 6);
        p.barrier(mc);
        let sub = p.comm_sub(mc, 3);
        if p.in_comm(sub) {
            p.barrier(sub);
            r2.fetch_add(1, Ordering::SeqCst);
        }
    });
    s.run().unwrap();
    assert_eq!(reached.load(Ordering::SeqCst), 3);
}

#[test]
fn rma_epochs_interleave_with_two_sided_traffic() {
    // Rank 1 reads rank 0's window while ranks 2,3 exchange messages
    // and all four run a concurrent ibarrier.
    let mut s = sim(2, 2);
    s.launch(4, |p: MpiProc| {
        let r = p.rank(WORLD);
        let expose = if r == 0 {
            Payload::real((0..64).map(|i| i as f64).collect())
        } else {
            Payload::virt(0)
        };
        let win = p.win_create_with(WORLD, expose, WinCreateOpts::blocking());
        let req = p.ibarrier(WORLD);
        match r {
            1 => {
                let dest = recv_buf_real(32);
                p.win_lock(win, 0);
                p.get(win, 0, 16, 32, &dest, 0);
                p.win_unlock(win, 0);
                let d = dest.lock().unwrap();
                assert_eq!(d.as_ref().unwrap()[0], 16.0);
                assert_eq!(d.as_ref().unwrap()[31], 47.0);
            }
            2 => p.send(WORLD, 3, 5, Payload::virt(200_000)),
            3 => {
                let _ = p.recv(WORLD, Some(2), 5);
            }
            _ => {}
        }
        p.req_wait(req);
        p.win_free(win);
    });
    s.run().unwrap();
}

#[test]
fn rget_completion_is_ordered_with_virtual_time() {
    // A large and a small Rget posted together: the small one's data is
    // available earlier in virtual time.
    let completions: Arc<Mutex<Vec<(&'static str, f64)>>> = Arc::new(Mutex::new(Vec::new()));
    let c2 = completions.clone();
    let mut s = sim(2, 2);
    s.launch(2, move |p: MpiProc| {
        let r = p.rank(WORLD);
        let expose = if r == 0 {
            Payload::virt(10_000_000)
        } else {
            Payload::virt(0)
        };
        let win = p.win_create_with(WORLD, expose, WinCreateOpts::blocking());
        if r == 1 {
            let big = proteo::simmpi::recv_buf_virtual();
            let small = proteo::simmpi::recv_buf_virtual();
            p.win_lock_all(win);
            let q_big = p.rget(win, 0, 0, 9_000_000, &big, 0);
            let q_small = p.rget(win, 0, 9_000_000, 10, &small, 0);
            while !p.req_test(q_small) {
                p.compute(1e-4);
            }
            c2.lock().unwrap().push(("small", p.now()));
            while !p.req_test(q_big) {
                p.compute(1e-4);
            }
            c2.lock().unwrap().push(("big", p.now()));
            p.win_unlock_all(win);
        }
        p.win_free(win);
    });
    s.run().unwrap();
    let c = completions.lock().unwrap();
    assert_eq!(c[0].0, "small");
    assert!(c[1].1 > c[0].1, "big must complete later: {c:?}");
}

#[test]
fn aux_thread_collective_with_main_thread_p2p() {
    // Aux threads run a barrier among all ranks while main threads
    // exchange p2p — the progress model must allow the main's sends to
    // slot into the gaps (aux-priority, not a hard lock).
    let mut s = sim(1, 4);
    s.launch(2, |p: MpiProc| {
        let r = p.rank(WORLD);
        p.spawn_aux(move |aux| {
            aux.compute(0.5);
            aux.barrier(WORLD);
        });
        // p2p while the aux computes (token free during compute).
        if r == 0 {
            p.send(WORLD, 1, 1, Payload::real(vec![42.0]));
        } else {
            let m = p.recv(WORLD, Some(0), 1);
            assert_eq!(m.as_slice().unwrap()[0], 42.0);
        }
        p.aux_join();
    });
    s.run().unwrap();
}

#[test]
fn hundredsixty_rank_world_smoke() {
    // Full paper-scale rank count through a mixed workload.
    let mut s = MpiSim::new(Topology::sarteco25(), NetParams::sarteco25());
    let sum = Arc::new(AtomicUsize::new(0));
    let s2 = sum.clone();
    s.launch(160, move |p: MpiProc| {
        let r = p.rank(WORLD);
        let got = p.allgather(WORLD, Payload::virt(2));
        assert_eq!(got.len(), 160);
        p.barrier(WORLD);
        let sends = (0..160)
            .map(|j| Payload::virt(if j == (r + 1) % 160 { 1000 } else { 0 }))
            .collect();
        let recv = p.alltoallv(WORLD, sends);
        let total: u64 = recv.iter().map(|b| b.elems()).sum();
        assert_eq!(total, 1000);
        s2.fetch_add(1, Ordering::SeqCst);
    });
    s.run().unwrap();
    assert_eq!(sum.load(Ordering::SeqCst), 160);
}
