//! Property tests over the engine's batched collective wakeups: for
//! *random* mixes of collective-style release rounds (random
//! participant subsets, random — frequently colliding — release
//! times), delivering a round through one `unpark_batch` must produce
//! bit-identical per-rank release times *and* execution order to
//! delivering it as individual `unpark_at` calls, on both the calendar
//! queue and the seed binary heap.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use proteo::simcluster::{Engine, QueueKind};
use proteo::util::proptest_lite::{check_seeded, Strategy as PStrategy};
use proteo::util::rng::Rng;

/// One randomized schedule: per round, the participating ranks and
/// their release offsets (quantized so equal-time ties are common).
#[derive(Clone, Debug)]
struct Mix {
    ranks: usize,
    /// `rounds[i][r] = Some(offset)` ⇔ rank `r` is released in round
    /// `i` at `round_start + offset`.
    rounds: Vec<Vec<Option<f64>>>,
}

struct MixStrat;

impl PStrategy for MixStrat {
    type Value = Mix;
    fn generate(&self, rng: &mut Rng) -> Mix {
        let ranks = rng.gen_range(2, 24);
        let rounds = (0..rng.gen_range(1, 8))
            .map(|_| {
                (0..ranks)
                    .map(|_| {
                        // ~1/4 of the ranks sit a round out; offsets
                        // land on a coarse 0.25 grid so distinct ranks
                        // collide at equal virtual times routinely.
                        rng.gen_bool(0.75)
                            .then(|| 0.25 * rng.gen_range(0, 8) as f64)
                    })
                    .collect()
            })
            .collect();
        Mix { ranks, rounds }
    }
}

/// Execute the mix and return the observed wake log: `(rank, time)` in
/// global execution order, times as exact bits.
fn run_mix(mix: &Mix, kind: QueueKind, batched: bool) -> Vec<(usize, u64)> {
    let mut e = Engine::with_queue(kind);
    let log: Arc<Mutex<Vec<(usize, u64)>>> = Arc::new(Mutex::new(Vec::new()));
    let stop = Arc::new(AtomicBool::new(false));
    let ids: Vec<_> = (0..mix.ranks)
        .map(|r| {
            let (log, stop) = (log.clone(), stop.clone());
            e.spawn_at(0.0, format!("rank{r}"), move |ctx| loop {
                ctx.park();
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                log.lock().unwrap().push((r, ctx.now().to_bits()));
            })
        })
        .collect();
    let rounds = mix.rounds.clone();
    let stop2 = stop.clone();
    e.spawn_at(0.0, "root", move |ctx| {
        for round in &rounds {
            // Let every released rank wake and re-park before the next
            // round: offsets are < 2.0, the inter-round gap is 2.0.
            ctx.advance(2.0);
            let now = ctx.now();
            let entries: Vec<_> = round
                .iter()
                .enumerate()
                .filter_map(|(r, off)| off.map(|off| (ids[r], now + off)))
                .collect();
            if batched {
                ctx.unpark_batch(entries);
            } else {
                for (id, at) in entries {
                    ctx.unpark_at(id, at);
                }
            }
        }
        ctx.advance(2.0);
        stop2.store(true, Ordering::SeqCst);
        ctx.unpark_batch(ids.iter().map(|&id| (id, ctx.now())).collect());
    });
    e.run().expect("mix must run to completion");
    let out = log.lock().unwrap().clone();
    out
}

#[test]
fn batched_wakeups_preserve_release_times_and_order() {
    check_seeded(
        "batched wakeups ≡ individual unparks",
        MixStrat,
        |mix| {
            let base = run_mix(&mix, QueueKind::Calendar, false);
            // Releases happened at all (vacuous mixes prove nothing).
            let released = mix
                .rounds
                .iter()
                .flatten()
                .filter(|o| o.is_some())
                .count();
            if base.len() != released {
                return false;
            }
            run_mix(&mix, QueueKind::Calendar, true) == base
                && run_mix(&mix, QueueKind::Heap, true) == base
                && run_mix(&mix, QueueKind::Heap, false) == base
        },
        0xE6_17_2E,
    );
}

#[test]
fn equal_time_batch_ties_resolve_in_entry_order() {
    // All ranks released at the *same* instant: the batch must deliver
    // them in entry (rank) order, exactly like sequential unparks.
    let mix = Mix { ranks: 16, rounds: vec![vec![Some(1.0); 16]; 3] };
    let a = run_mix(&mix, QueueKind::Calendar, true);
    let b = run_mix(&mix, QueueKind::Calendar, false);
    assert_eq!(a, b);
    for w in a.chunks(16) {
        let order: Vec<_> = w.iter().map(|&(r, _)| r).collect();
        assert_eq!(order, (0..16).collect::<Vec<_>>(), "ties must keep entry order");
    }
}
