//! Old-vs-new engine equivalence: the calendar-queue engine must be
//! *byte-identical* to the seed binary-heap engine on every simulated
//! output — same virtual times, same metrics documents, same scenario
//! JSON.  The queue is swapped through the process-global default
//! (`set_default_queue_kind`), so the tests serialize on a file-local
//! mutex and restore the calendar default when done.

use std::sync::Mutex;

use proteo::experiments::{scenario, smoke};
use proteo::simcluster::{set_default_queue_kind, QueueKind};
use proteo::util::json::Json;

/// Serializes queue-kind flips across the tests in this binary.
static QUEUE_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` under the given process-default queue kind, restoring the
/// calendar default afterwards (also on panic).
fn with_queue_kind<T>(kind: QueueKind, f: impl FnOnce() -> T) -> T {
    let _guard = QUEUE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    struct Restore;
    impl Drop for Restore {
        fn drop(&mut self) {
            set_default_queue_kind(QueueKind::Calendar);
        }
    }
    let _restore = Restore;
    set_default_queue_kind(kind);
    f()
}

/// Drop the soft `*.wall_s` entries — wall clock is the one quantity
/// allowed (expected, even) to differ across the queue swap.
fn strip_wall(doc: &Json) -> Json {
    let mut d = doc.clone();
    if let Json::Obj(top) = &mut d {
        if let Some(Json::Obj(entries)) = top.get_mut("entries") {
            entries.retain(|k, _| !k.ends_with(".wall_s"));
        }
    }
    d
}

/// The full bench-smoke document — window-pool ablations, spawn
/// strategies, chunk sweeps, end-to-end runs, planner scenarios, drift
/// benchmarks — is byte-identical across the queue swap.  This is the
/// broadest single determinism surface the repo has: it exercises
/// every method × strategy family, the planner's incremental probe
/// sessions (snapshot/rollback) and the in-sim recalibrator.
#[test]
fn bench_smoke_is_byte_identical_across_queue_swap() {
    let heap = with_queue_kind(QueueKind::Heap, || smoke::collect(true));
    let cal = with_queue_kind(QueueKind::Calendar, || smoke::collect(true));
    assert_eq!(
        strip_wall(&heap).to_pretty(),
        strip_wall(&cal).to_pretty(),
        "calendar queue changed a virtual-time bench metric"
    );
}

/// The closed-loop scenario JSON — per-resize predicted/observed
/// spans, n_it, registration throughput, makespan *and* the engine
/// observability counters — matches across the queue swap.  Counter
/// equality is the strong half: events processed, peak queue depth and
/// wakeup batching must not depend on the queue data structure.
#[test]
fn scenario_json_is_byte_identical_across_queue_swap() {
    let run = || {
        let mut sp = scenario::ScenarioSpec::rms_trace(true);
        sp.planner = proteo::mam::PlannerMode::Auto;
        scenario::run_scenario(&sp).to_json().to_pretty()
    };
    let heap = with_queue_kind(QueueKind::Heap, run);
    let cal = with_queue_kind(QueueKind::Calendar, run);
    assert_eq!(heap, cal, "calendar queue changed the scenario output");
}
