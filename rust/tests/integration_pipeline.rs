//! Integration: the chunked pipelined RMA redistribution end to end.
//!
//! The acceptance bar of the pipelining subsystem: on a fig3
//! quick-pair (20→160) with the default calibrated `NetParams`, a
//! *cold* pipelined resize must beat the cold blocking baseline by at
//! least 20% on the full reconfiguration span — hiding the
//! `Win_create` registration behind the wire is exactly the
//! initialization-cost fix the paper calls for — while
//! `rma_chunk_kib = 0` stays bit-identical to the pre-existing path.

use proteo::config::ExperimentConfig;
use proteo::mam::{Method, Strategy};
use proteo::proteo::{run_once, RunSpec};

/// The acceptance criterion.  Full-scale problem (the paper's 64 GB
/// CSR), the fig3 quick pair 20→160, default `NetParams::sarteco25`.
/// One rank per node isolates the per-NIC contention that is
/// orthogonal to registration pipelining, so the measured gap is the
/// registration term itself: blocking pays `T_reg + T_wire` serially,
/// pipelined pays `fill + max(T_reg, T_wire)`.
#[test]
fn cold_pipelined_beats_cold_blocking_by_20_percent_on_fig3_quick_pair() {
    let mut base = RunSpec::sarteco25(20, 160, Method::RmaLockall, Strategy::Blocking);
    base.cores_per_node = 1;
    base.warmup_iters = 1;
    base.post_iters = 1;
    let blocking = run_once(&base);
    let mut piped = base.clone();
    piped.rma_chunk_kib = 4096; // 4 MiB segments
    let piped = run_once(&piped);
    assert!(
        blocking.reconf_total.is_finite() && blocking.reconf_total > 0.0,
        "no blocking span"
    );
    assert!(
        piped.reconf_total <= 0.80 * blocking.reconf_total,
        "pipelining saved less than 20%: pipelined {} vs blocking {}",
        piped.reconf_total,
        blocking.reconf_total
    );
    // Sanity: the wire still has to move every byte — the pipelined
    // span cannot collapse below the blocking span minus its full
    // registration+teardown budget.
    assert!(
        piped.reconf_total > 0.3 * blocking.reconf_total,
        "implausible pipelined span {} vs blocking {}",
        piped.reconf_total,
        blocking.reconf_total
    );
}

/// The shrink-side acceptance criterion.  Full-scale problem, the fig3
/// pair 160→20, default `NetParams::sarteco25`, one rank per node.  On
/// a shrink the registration is spread over 160 sources while 20 drain
/// NICs carry all the moved bytes, so the span is wire-bound: the
/// whole-lifecycle ceiling is roughly
/// `(T_reg + T_dereg) / (T_wire + T_reg + T_dereg)` ≈
/// `(4/3)·(ND/NS)·(β_reg/β_inter)` ≈ 10% at this pair — the issue's
/// 15% target is unreachable on the wire-dominated 160→20 (a 80→20 or
/// 160→40 shrink clears it).  The assertions therefore pin (a) a ≥ 7%
/// whole-lifecycle win over the fully blocking path, and (b) that the
/// teardown pipeline specifically — dereg-on vs the registration-only
/// dereg-off pipeline — contributes a strictly positive, ≥ 1%-of-span
/// share of it, i.e. the `windereg` streams pull the serial `Win_free`
/// term off the critical path.
#[test]
fn cold_pipelined_shrink_160_to_20_beats_cold_blocking_teardown() {
    let mut base = RunSpec::sarteco25(160, 20, Method::RmaLockall, Strategy::Blocking);
    base.cores_per_node = 1;
    base.warmup_iters = 1;
    base.post_iters = 1;
    let blocking = run_once(&base); // chunk 0: serial registration + teardown
    let mut piped = base.clone();
    piped.rma_chunk_kib = 4096; // 4 MiB segments, full lifecycle
    let full = run_once(&piped);
    let mut reg_only = piped.clone();
    reg_only.rma_dereg = false; // registration pipelined, teardown blocking
    let reg_only = run_once(&reg_only);
    assert!(
        blocking.reconf_total.is_finite() && blocking.reconf_total > 0.0,
        "no blocking span"
    );
    // (a) Whole lifecycle vs the cold blocking teardown baseline.
    assert!(
        full.reconf_total <= 0.93 * blocking.reconf_total,
        "lifecycle pipeline saved less than 7%: full {} vs blocking {}",
        full.reconf_total,
        blocking.reconf_total
    );
    // (b) The teardown half specifically: dereg-on strictly beats the
    // registration-only pipeline, by at least 1% of the blocking span
    // (the serial dereg term at 160→20 is ~2.5% of it).
    assert!(
        full.reconf_total < reg_only.reconf_total,
        "teardown pipeline bought nothing: full {} vs reg-only {}",
        full.reconf_total,
        reg_only.reconf_total
    );
    assert!(
        reg_only.reconf_total - full.reconf_total >= 0.01 * blocking.reconf_total,
        "teardown saving too small: full {} reg-only {} blocking {}",
        full.reconf_total,
        reg_only.reconf_total,
        blocking.reconf_total
    );
    // Ordering sanity: reg-only sits between the two.
    assert!(reg_only.reconf_total <= blocking.reconf_total + 1e-9);
    // The wire still has to move every byte: the pipelined span cannot
    // collapse below the blocking span minus its full lifecycle budget.
    assert!(
        full.reconf_total > 0.5 * blocking.reconf_total,
        "implausible pipelined span {} vs blocking {}",
        full.reconf_total,
        blocking.reconf_total
    );
}

#[test]
fn chunk_zero_via_config_is_bit_identical_to_an_unchunked_config() {
    // `"rma_chunk_kib": 0` must change nothing: same spec, same bits
    // as a config that never mentions the chunk.
    let src_plain = r#"{"preset": "tiny", "method": "rma-lockall", "strategy": "wd",
                        "pairs": [[8, 4]], "scale": 10000}"#;
    let src_chunk0 = r#"{"preset": "tiny", "method": "rma-lockall", "strategy": "wd",
                         "pairs": [[8, 4]], "scale": 10000, "rma_chunk_kib": 0}"#;
    let a = ExperimentConfig::from_str(src_plain).unwrap();
    let b = ExperimentConfig::from_str(src_chunk0).unwrap();
    assert_eq!(a.rma_chunk_kib, 0);
    assert_eq!(b.rma_chunk_kib, 0);
    let ra = run_once(&a.spec_for(8, 4));
    let rb = run_once(&b.spec_for(8, 4));
    assert_eq!(ra.redist_time.to_bits(), rb.redist_time.to_bits());
    assert_eq!(ra.reconf_total.to_bits(), rb.reconf_total.to_bits());
    assert_eq!(ra.virt_end.to_bits(), rb.virt_end.to_bits());
    assert_eq!(ra.events, rb.events);
}

#[test]
fn chunked_wait_drains_still_overlaps_iterations() {
    // The pipelined path composes with the background strategies: a
    // chunked RMA-WD run completes, overlaps iterations, and is
    // deterministic.
    let cfg = ExperimentConfig::from_str(
        r#"{"preset": "tiny", "method": "rma-lockall", "strategy": "wd",
            "pairs": [[16, 4]], "scale": 100, "rma_chunk_kib": 256}"#,
    )
    .unwrap();
    let spec = cfg.spec_for(16, 4);
    assert_eq!(spec.rma_chunk_kib, 256);
    let a = run_once(&spec);
    assert!(a.redist_time > 0.0 && a.t_it_nd > 0.0);
    assert!(a.n_it >= 1.0, "WD should overlap ≥1 iteration, got {}", a.n_it);
    let b = run_once(&spec);
    assert_eq!(a.redist_time.to_bits(), b.redist_time.to_bits());
    assert_eq!(a.events, b.events);
}
