//! Integration: the PJRT runtime executing the AOT-compiled JAX/Pallas
//! artifacts, cross-validated against the pure-Rust linalg substrate.
//!
//! Requires `make artifacts`; every test skips (with a notice) when the
//! artifacts have not been built.

use proteo::linalg::{self, EllMatrix};
use proteo::runtime::{artifacts_dir, runtime_available, CgRuntime, CgState};

fn runtime_or_skip() -> Option<CgRuntime> {
    if !runtime_available() {
        eprintln!(
            "SKIP: PJRT runtime unavailable (needs `make artifacts` and `--features pjrt`)"
        );
        return None;
    }
    Some(CgRuntime::load(artifacts_dir()).expect("load artifacts"))
}

#[test]
fn manifest_describes_default_problem() {
    let Some(rt) = runtime_or_skip() else { return };
    let m = &rt.manifest;
    assert_eq!(m.n, m.grid * m.grid);
    assert_eq!(m.nbr * m.br, m.n);
    assert_eq!(m.k, 3);
    assert!(m.vmem_bytes_per_step > 0);
    assert_eq!(rt.platform().to_lowercase().contains("cpu"), true);
}

#[test]
fn spmv_artifact_matches_rust_reference() {
    let Some(rt) = runtime_or_skip() else { return };
    let a = EllMatrix::laplacian_2d(rt.manifest.grid);
    let x: Vec<f32> = (0..rt.manifest.n).map(|i| ((i as f32) * 0.37).sin()).collect();
    let y_pjrt = rt.spmv(&a, &x).expect("spmv exec");
    let y_rust = a.spmv(&x);
    for (i, (a, b)) in y_pjrt.iter().zip(&y_rust).enumerate() {
        assert!((a - b).abs() < 1e-3, "elem {i}: pjrt={a} rust={b}");
    }
}

#[test]
fn spmv_artifact_matches_csr_f64_reference() {
    let Some(rt) = runtime_or_skip() else { return };
    let grid = rt.manifest.grid;
    let csr = linalg::laplacian_2d(grid);
    let ell = EllMatrix::laplacian_2d(grid);
    let x: Vec<f64> = (0..csr.n).map(|i| ((i as f64) * 0.11).cos()).collect();
    let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
    let mut y64 = vec![0.0; csr.n];
    linalg::spmv(&csr, &x, &mut y64);
    let y_pjrt = rt.spmv(&ell, &xf).expect("spmv exec");
    for (a, b) in y_pjrt.iter().zip(&y64) {
        assert!((f64::from(*a) - b).abs() < 1e-3, "{a} vs {b}");
    }
}

#[test]
fn cg_step_artifact_matches_rust_cg_step() {
    let Some(rt) = runtime_or_skip() else { return };
    let grid = rt.manifest.grid;
    let csr = linalg::laplacian_2d(grid);
    let ell = EllMatrix::laplacian_2d(grid);
    let b: Vec<f64> = (0..csr.n).map(|i| ((i * 7 % 13) as f64) / 13.0).collect();
    let bf: Vec<f32> = b.iter().map(|&v| v as f32).collect();

    // One step on each side from the same initial state.
    let st0 = CgState::init(&bf);
    let st1 = rt.cg_step(&ell, &st0).expect("cg_step exec");
    let rr0 = linalg::dot(&b, &b);
    let x0 = vec![0.0; csr.n];
    let (_, _, _, rr1) = linalg::cg_step(&csr, &x0, &b, &b, rr0);
    let rel = (f64::from(st1.rr) - rr1).abs() / rr1.max(1e-30);
    assert!(rel < 1e-3, "rr after 1 step: pjrt={} rust={rr1}", st1.rr);
}

#[test]
fn cg_solve_through_pjrt_converges_like_rust_cg() {
    let Some(rt) = runtime_or_skip() else { return };
    let grid = rt.manifest.grid;
    let csr = linalg::laplacian_2d(grid);
    let ell = EllMatrix::laplacian_2d(grid);
    let b: Vec<f64> = (0..csr.n).map(|i| 1.0 + ((i % 5) as f64) * 0.1).collect();
    let bf: Vec<f32> = b.iter().map(|&v| v as f32).collect();

    let (st, history) = rt.cg_solve(&ell, &bf, 1e-5, 400).expect("cg solve");
    assert!(
        *history.last().unwrap() < 1e-5,
        "PJRT CG did not converge: {:?}",
        history.last()
    );

    let mut x = vec![0.0; csr.n];
    let trace = linalg::cg(&csr, &b, &mut x, 1e-5, 400);
    assert!(trace.converged);
    // Iteration counts agree within f32-vs-f64 slack.
    let pjrt_iters = history.len() as i64 - 1;
    let rust_iters = trace.iterations as i64;
    assert!(
        (pjrt_iters - rust_iters).abs() <= rust_iters / 4 + 8,
        "iteration counts diverge: pjrt={pjrt_iters} rust={rust_iters}"
    );
    // And the PJRT solution really solves the f64 system.
    let xf: Vec<f64> = st.x.iter().map(|&v| f64::from(v)).collect();
    let mut ax = vec![0.0; csr.n];
    linalg::spmv(&csr, &xf, &mut ax);
    let res: f64 = ax
        .iter()
        .zip(&b)
        .map(|(a, bb)| (a - bb) * (a - bb))
        .sum::<f64>()
        .sqrt();
    assert!(res / linalg::norm2(&b) < 1e-3, "residual {res}");
}

#[test]
fn wrong_shape_matrix_is_rejected() {
    let Some(rt) = runtime_or_skip() else { return };
    let wrong = EllMatrix::laplacian_2d(rt.manifest.grid / 2);
    let x = vec![0.0f32; rt.manifest.n];
    assert!(rt.spmv(&wrong, &x).is_err());
}
