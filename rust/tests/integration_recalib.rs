//! Integration: online NetParams recalibration end to end.
//!
//! The acceptance bar of the self-tuning planner: on every full-size
//! drift scenario the recalibrating arm's cumulative reconfiguration
//! cost beats the static planner's by at least 10% AND its per-resize
//! predicted-vs-observed error falls below 15% within 5 resizes; with
//! recalibration off, everything stays bit-identical to the static
//! planner.

use proteo::config::ExperimentConfig;
use proteo::experiments::{drift, scenario};
use proteo::mam::PlannerMode;
use proteo::proteo::run_once;

/// The headline acceptance criterion, on the full-size (non-quick)
/// scenarios: in all three drift environments — a 2x-miscalibrated
/// seed, heterogeneous NICs, and transient congestion — the online
/// planner must save >= 10% of the static planner's cumulative cost
/// and settle its prediction error under [`drift::CONVERGE_TOL`]
/// within 5 resizes.
#[test]
fn full_size_drift_scenarios_meet_the_acceptance_bar() {
    for sc in drift::DriftScenario::all(false) {
        let rep = drift::run_drift(&sc);
        let win = rep.win_frac();
        let k = rep.converge_resizes();
        assert!(
            rep.static_arm.cum_cost.is_finite() && rep.static_arm.cum_cost > 0.0,
            "{}: static arm cost {}",
            sc.name,
            rep.static_arm.cum_cost
        );
        assert!(
            win >= 0.10,
            "{}: recalibration saved only {:.1}% (static {}, recalib {})\n{}",
            sc.name,
            100.0 * win,
            rep.static_arm.cum_cost,
            rep.recalib_arm.cum_cost,
            rep.render(true)
        );
        assert!(
            k <= 5,
            "{}: prediction error settled only at resize {k}\n{}",
            sc.name,
            rep.render(true)
        );
    }
}

/// Drift runs are pure functions of the scenario: two runs must agree
/// bit for bit (the report JSON carries every predicted/observed span
/// verbatim).
#[test]
fn drift_reports_are_bit_deterministic() {
    for name in ["miscal", "hetero", "congest"] {
        let sc = drift::DriftScenario::by_name(name, true).unwrap();
        let a = drift::run_drift(&sc).to_json().to_pretty();
        let b = drift::run_drift(&sc).to_json().to_pretty();
        assert_eq!(a, b, "{name}: drift run not deterministic");
    }
}

/// `"recalib": "off"` must change nothing: same config otherwise, same
/// bits as a config that never mentions recalibration — under both the
/// fixed and the auto planner.
#[test]
fn recalib_off_is_bit_identical_through_config_and_run() {
    for planner in ["fixed", "auto"] {
        let src_plain = format!(
            r#"{{"preset": "tiny", "method": "rma-lockall", "strategy": "wd",
                "planner": "{planner}", "pairs": [[8, 4]], "scale": 10000}}"#
        );
        let src_off = format!(
            r#"{{"preset": "tiny", "method": "rma-lockall", "strategy": "wd",
                "planner": "{planner}", "recalib": "off", "pairs": [[8, 4]], "scale": 10000}}"#
        );
        let plain = ExperimentConfig::from_str(&src_plain).unwrap();
        let off = ExperimentConfig::from_str(&src_off).unwrap();
        let (a, b) = (run_once(&plain.spec_for(8, 4)), run_once(&off.spec_for(8, 4)));
        assert_eq!(a.label, b.label, "{planner}");
        assert_eq!(
            a.reconf_total.to_bits(),
            b.reconf_total.to_bits(),
            "{planner}: recalib-off diverged from the static planner"
        );
        assert_eq!(a.redist_time.to_bits(), b.redist_time.to_bits(), "{planner}");
    }
}

/// The closed-loop RMS trace with recalibration off is byte-identical
/// to the plain auto scenario — the off path takes no extra
/// collectives and consults no live estimate.
#[test]
fn recalib_off_scenario_report_matches_the_plain_auto_scenario() {
    let mut plain = scenario::ScenarioSpec::rms_trace(true);
    plain.planner = PlannerMode::Auto;
    let mut off = plain.clone();
    off.recalib = false;
    let a = scenario::run_scenario(&plain).to_json().to_pretty();
    let b = scenario::run_scenario(&off).to_json().to_pretty();
    assert_eq!(a, b);
}

/// Recalib-on on the same trace: deterministic across runs, every
/// resize re-planned live, and the report still carries finite
/// predicted/observed spans.
#[test]
fn recalib_on_scenario_is_deterministic_and_replans_live() {
    let mut spec = scenario::ScenarioSpec::rms_trace(true);
    spec.planner = PlannerMode::Auto;
    spec.recalib = true;
    let a = scenario::run_scenario(&spec);
    let b = scenario::run_scenario(&spec);
    assert_eq!(a.to_json().to_pretty(), b.to_json().to_pretty());
    assert!(!a.resizes.is_empty());
    for r in &a.resizes {
        assert!(r.label.starts_with("live["), "label: {}", r.label);
        assert!(r.predicted_reconf.is_finite() && r.predicted_reconf > 0.0);
        assert!(r.observed_reconf.is_finite() && r.observed_reconf > 0.0);
    }
}
