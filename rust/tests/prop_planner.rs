//! Property tests for the reconfiguration planner: predictions are
//! finite and positive over the whole input space, the chosen plan is
//! always a member of the candidate set (and a valid version), and
//! planning is a pure function of its inputs.

use proteo::mam::planner::{plan, predict_candidate, Candidate, PlannerInputs};
use proteo::mam::{
    is_valid_version, DataDecl, DataKind, Method, Objective, SpawnStrategy, Strategy,
    WinPoolPolicy,
};
use proteo::netmodel::NetParams;
use proteo::util::proptest_lite::{check, one_of, usizes, Strategy as PropStrategy};

fn inputs(ns: usize, nd: usize, elems: usize, warm: bool) -> PlannerInputs {
    PlannerInputs {
        decls: vec![
            DataDecl {
                name: "A".into(),
                kind: DataKind::Constant,
                total_elems: elems as u64,
                real: false,
            },
            DataDecl {
                name: "x".into(),
                kind: DataKind::Variable,
                total_elems: (elems as u64 / 8).max(1),
                real: false,
            },
        ],
        ns,
        nd,
        cores_per_node: 4,
        net: NetParams::sarteco25(),
        spawn_cost: 0.25,
        warm,
        t_iter_src: 1e-3,
        t_iter_dst: 2e-3,
        objective: Objective::ReconfTime,
        probe: false,
        extra_chunks_kib: Vec::new(),
        rma_sync: proteo::simmpi::RmaSync::Epoch,
        sched_cache: false,
        sched_warm: false,
        future_resizes: 0,
        fail_p: 0.0,
    }
}

/// Random (ns, nd, elems, warm) with ns ≠ nd.
fn case_strategy() -> impl PropStrategy<Value = (usize, usize, usize, usize)> {
    usizes(1, 24).pair(usizes(1, 24)).pair(usizes(1, 2_000_000).pair(usizes(0, 1))).map_gen(
        |((ns, nd), (elems, warm))| (ns, nd, elems, warm),
    )
}

#[test]
fn predictions_are_finite_and_positive_for_every_candidate() {
    check("predicted costs finite/positive", case_strategy(), |(ns, nd, elems, warm)| {
        if ns == nd {
            return true; // not a resize
        }
        let inp = inputs(ns, nd, elems, warm == 1);
        for m in Method::all() {
            for s in Strategy::all() {
                if !is_valid_version(m, s) {
                    continue;
                }
                for pool in [WinPoolPolicy::off(), WinPoolPolicy::on()] {
                    for ss in SpawnStrategy::all() {
                        let cand = Candidate {
                            method: m,
                            strategy: s,
                            spawn_strategy: ss,
                            win_pool: pool,
                            rma_chunk_kib: 0,
                        };
                        let p = predict_candidate(&inp, &cand);
                        let ok = p.reconf_time.is_finite()
                            && p.reconf_time > 0.0
                            && p.redist > 0.0
                            && p.effective.is_finite()
                            && p.effective <= p.reconf_time + 1e-15
                            && p.overlap_credit >= 0.0;
                        if !ok {
                            return false;
                        }
                    }
                }
            }
        }
        true
    });
}

#[test]
fn chosen_plan_is_always_in_the_candidate_set_and_valid() {
    let objectives = one_of(&[0usize, 1]);
    check(
        "plan choice membership",
        case_strategy().pair(objectives),
        |((ns, nd, elems, warm), obj)| {
            if ns == nd {
                return true;
            }
            let mut inp = inputs(ns, nd, elems, warm == 1);
            inp.objective = if obj == 0 { Objective::ReconfTime } else { Objective::Effective };
            let p = plan(&inp);
            let member = p.candidates.iter().any(|cc| cc.candidate == p.choice);
            member
                && is_valid_version(p.choice.method, p.choice.strategy)
                && p.predicted_reconf.is_finite()
                && p.predicted_reconf > 0.0
                // Shrinks never spawn: the spawn strategy stays at the
                // Sequential default.
                && (nd > ns || p.choice.spawn_strategy == SpawnStrategy::Sequential)
        },
    );
}

#[test]
fn planning_is_a_pure_function_of_its_inputs() {
    check("plan determinism", case_strategy(), |(ns, nd, elems, warm)| {
        if ns == nd {
            return true;
        }
        let inp = inputs(ns, nd, elems, warm == 1);
        let a = plan(&inp);
        let b = plan(&inp);
        a.choice == b.choice
            && a.predicted_reconf.to_bits() == b.predicted_reconf.to_bits()
            && a.candidates.len() == b.candidates.len()
    });
}

#[test]
fn recalib_off_is_bit_identical_to_the_static_planner() {
    // `--recalib off` reaches the planner as an empty chunk injection
    // (and the static calibration), i.e. exactly the pre-recalibration
    // inputs; and a recalibrator that measured nothing beyond the
    // static grid must leave every bit of the plan unchanged too.
    check("recalib-off planner bit-identity", case_strategy(), |(ns, nd, elems, warm)| {
        if ns == nd {
            return true;
        }
        let base = plan(&inputs(ns, nd, elems, warm == 1));
        let mut dup = inputs(ns, nd, elems, warm == 1);
        dup.extra_chunks_kib = vec![0, 256, 1024, 4096]; // ⊆ static grid
        let dup = plan(&dup);
        let mut novel = inputs(ns, nd, elems, warm == 1);
        novel.extra_chunks_kib = vec![512, 2048]; // measured sweet spots
        let novel = plan(&novel);
        dup.choice == base.choice
            && dup.predicted_reconf.to_bits() == base.predicted_reconf.to_bits()
            && dup.candidates.len() == base.candidates.len()
            // A genuinely new measured chunk only ever widens the grid.
            && novel.candidates.len() >= base.candidates.len()
            && is_valid_version(novel.choice.method, novel.choice.strategy)
    });
}

#[test]
fn span_objective_never_picks_a_background_strategy() {
    // Background strategies cannot shorten the reconfiguration span
    // (completion is iteration-quantized and the variable tail still
    // moves), so the span objective must always land on Blocking.
    check("span objective picks blocking", case_strategy(), |(ns, nd, elems, warm)| {
        if ns == nd {
            return true;
        }
        let p = plan(&inputs(ns, nd, elems, warm == 1));
        p.choice.strategy == Strategy::Blocking
    });
}
