//! Property tests for the chunked pipelined RMA redistribution
//! (`rma_chunk_kib > 0`):
//!
//! * the pipelined path is **payload-byte-identical** to the blocking
//!   path across random grow/shrink shapes, chunk sizes, epoch styles
//!   and strategies — no element lost, duplicated, reordered or
//!   altered by the per-segment reads;
//! * `rma_chunk_kib = 0` reproduces the pre-existing path
//!   **bit-identically, virtual times included** (the delegation
//!   guard: chunk 0 must route through the exact seed code path).

use std::sync::{Arc, Mutex};

use proteo::mam::{
    block_of, rma, DataKind, Mam, MamStatus, Method, PlannerMode, ReconfigCfg, Registry, Roles,
    SpawnStrategy, Strategy, WinPoolPolicy,
};
use proteo::netmodel::{NetParams, Topology};
use proteo::simmpi::{CommId, MpiProc, MpiSim, Payload, WORLD};
use proteo::util::proptest_lite::{check_seeded, one_of, usizes, Strategy as PStrategy};

/// Run one full Mam reconfiguration with the given chunk size and
/// collect every continuing rank's final block of entry "A"; returns
/// the reassembled global vector (None if any drain failed to report).
fn run_and_collect(
    ns: usize,
    nd: usize,
    total: u64,
    method: Method,
    strategy: Strategy,
    pool: bool,
    rma_chunk_kib: u64,
) -> Option<Vec<f64>> {
    run_and_collect_cfg(
        ns,
        nd,
        total,
        method,
        strategy,
        pool,
        rma_chunk_kib,
        SpawnStrategy::Sequential,
    )
}

/// [`run_and_collect`] with the spawn strategy explicit (Async grows
/// exercise the spawn-overlapped eager registration streams).
#[allow(clippy::too_many_arguments)]
fn run_and_collect_cfg(
    ns: usize,
    nd: usize,
    total: u64,
    method: Method,
    strategy: Strategy,
    pool: bool,
    rma_chunk_kib: u64,
    spawn_strategy: SpawnStrategy,
) -> Option<Vec<f64>> {
    let collected: Arc<Mutex<Vec<Option<Vec<f64>>>>> = Arc::new(Mutex::new(vec![None; nd]));
    let c2 = collected.clone();
    let mut sim = MpiSim::new(Topology::new(4, 5), NetParams::test_simple());
    sim.launch(ns, move |p: MpiProc| {
        let rank = p.rank(WORLD);
        let b = block_of(total, ns, rank);
        let mut reg = Registry::new();
        reg.register(
            "A",
            DataKind::Constant,
            total,
            Payload::real((b.ini..b.end).map(|i| (i as f64) * 1.25 - 7.0).collect()),
        );
        let decls = reg.decls();
        let cfg = ReconfigCfg {
            method,
            strategy,
            spawn_cost: 0.001,
            spawn_strategy,
            win_pool: if pool { WinPoolPolicy::on() } else { WinPoolPolicy::off() },
            rma_chunk_kib,
            rma_dereg: true,
            rma_sync: proteo::simmpi::RmaSync::Epoch,
            sched_cache: false,
            planner: PlannerMode::Fixed,
            recalib: false,
        };
        let mut mam = Mam::new(reg, cfg.clone());
        let c3 = c2.clone();
        let cfg2 = cfg.clone();
        let body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
            Arc::new(move |dp: MpiProc, merged: CommId| {
                let dmam = Mam::drain_join(&dp, merged, ns, nd, &decls, cfg2.clone());
                let e = dmam.registry.entry(0);
                c3.lock().unwrap()[dp.rank(merged)] =
                    Some(e.local.as_slice().map(|s| s.to_vec()).unwrap_or_default());
            });
        let mut status = mam.reconfigure(&p, WORLD, nd, body);
        while status == MamStatus::InProgress {
            p.compute(1e-4);
            status = mam.checkpoint(&p);
        }
        let out = mam.finish(&p, WORLD);
        if let Some(comm) = out.app_comm {
            let e = mam.registry.entry(0);
            c2.lock().unwrap()[p.rank(comm)] =
                Some(e.local.as_slice().map(|s| s.to_vec()).unwrap_or_default());
        }
    });
    sim.run().expect("simulation failed");
    let shards = collected.lock().unwrap();
    if shards.iter().any(|s| s.is_none()) {
        return None;
    }
    let mut out = Vec::with_capacity(total as usize);
    for s in shards.iter() {
        out.extend_from_slice(s.as_ref().unwrap());
    }
    Some(out)
}

/// RMA versions the chunked path applies to.
fn rma_versions() -> Vec<(Method, Strategy)> {
    vec![
        (Method::RmaLock, Strategy::Blocking),
        (Method::RmaLockall, Strategy::Blocking),
        (Method::RmaLock, Strategy::WaitDrains),
        (Method::RmaLockall, Strategy::WaitDrains),
        (Method::RmaLockall, Strategy::Threading),
    ]
}

#[test]
fn prop_pipelined_is_payload_byte_identical_to_blocking() {
    let versions = rma_versions();
    // 1 KiB = 128-element segments: totals up to 20k elements over up
    // to 9 ranks give per-rank blocks well past one segment.
    let chunks: Vec<u64> = vec![1, 2, 8];
    check_seeded(
        "chunked pipelined redistribution == blocking payloads",
        usizes(1, 9)
            .pair(usizes(1, 9))
            .pair(usizes(0, 20_000))
            .pair(one_of(&versions))
            .pair(one_of(&chunks)),
        |((((ns, nd), total), (m, s)), chunk_kib)| {
            if ns == nd {
                return true;
            }
            let total = total as u64;
            let pool = (ns + nd + total as usize) % 2 == 0; // alternate pool on/off
            let chunked = run_and_collect(ns, nd, total, m, s, pool, chunk_kib);
            let blocking = run_and_collect(ns, nd, total, m, s, pool, 0);
            let (Some(chunked), Some(blocking)) = (chunked, blocking) else {
                return false;
            };
            if chunked.len() as u64 != total || chunked != blocking {
                return false;
            }
            // Both must also be the identity repartition.
            chunked
                .iter()
                .enumerate()
                .all(|(i, v)| *v == (i as f64) * 1.25 - 7.0)
        },
        0x9A9A,
    );
}

/// Simulated end time of one direct (harness-free) blocking RMA
/// redistribution, via the seed function or the chunked entry point.
fn direct_end_time(ns: usize, nd: usize, total: u64, lockall: bool, chunked_entry: bool) -> f64 {
    let mut sim = MpiSim::new(Topology::new(4, 5), NetParams::test_simple());
    sim.launch(ns.max(nd), move |p: MpiProc| {
        let rank = p.rank(WORLD);
        let roles = Roles { ns, nd, rank };
        let local = if roles.is_source() {
            Payload::virt(block_of(total, ns, rank).len())
        } else {
            Payload::virt(0)
        };
        let mut reg = Registry::new();
        reg.register("A", DataKind::Constant, total, local);
        let _ = if chunked_entry {
            rma::redistribute_with(
                &p,
                WORLD,
                &roles,
                &reg,
                &[0],
                rma::RedistOpts::new(lockall, WinPoolPolicy::off())
                    .lifecycle(rma::LifecycleOpts::reg_only(0)),
            )
        } else {
            rma::redistribute_with(
                &p,
                WORLD,
                &roles,
                &reg,
                &[0],
                rma::RedistOpts::new(lockall, WinPoolPolicy::off()),
            )
        };
    });
    sim.run().expect("simulation failed")
}

#[test]
fn prop_chunk_zero_reproduces_the_seed_path_bit_identically() {
    check_seeded(
        "rma_chunk_kib = 0 == seed path, virtual times included",
        usizes(1, 8).pair(usizes(1, 8)).pair(usizes(1, 10_000)).pair(one_of(&[false, true])),
        |(((ns, nd), total), lockall)| {
            if ns == nd {
                return true;
            }
            let total = total as u64;
            let a = direct_end_time(ns, nd, total, lockall, false);
            let b = direct_end_time(ns, nd, total, lockall, true);
            a.to_bits() == b.to_bits()
        },
        0xB1B1,
    );
}

/// Simulated end time of one direct blocking RMA-Lockall lifecycle run
/// with the teardown pipeline on or off (registration pipeline on in
/// both — the delta isolates the `windereg` streams).
fn lifecycle_end_time(ns: usize, nd: usize, total: u64, chunk_kib: u64, dereg: bool) -> f64 {
    let mut sim = MpiSim::new(Topology::new(4, 5), NetParams::test_simple());
    sim.launch(ns.max(nd), move |p: MpiProc| {
        let rank = p.rank(WORLD);
        let roles = Roles { ns, nd, rank };
        let local = if roles.is_source() {
            Payload::virt(block_of(total, ns, rank).len())
        } else {
            Payload::virt(0)
        };
        let mut reg = Registry::new();
        reg.register("A", DataKind::Constant, total, local);
        let chunk_elems = chunk_kib * 1024 / 8;
        let opts = if dereg {
            rma::LifecycleOpts::full(chunk_elems)
        } else {
            rma::LifecycleOpts::reg_only(chunk_elems)
        };
        let _ = rma::redistribute_with(
            &p,
            WORLD,
            &roles,
            &reg,
            &[0],
            rma::RedistOpts::new(true, WinPoolPolicy::off()).lifecycle(opts),
        );
    });
    sim.run().expect("simulation failed")
}

#[test]
fn prop_pipelined_teardown_never_slows_a_run() {
    // Shrink-side acceptance property: across random shapes and chunk
    // sizes, the background deregistration streams can only pull the
    // virtual end time earlier (or tie) — segments unpin as their last
    // reads land instead of serially after the closing barrier — and
    // both paths stay bit-deterministic.
    check_seeded(
        "dereg-on end time <= dereg-off end time",
        usizes(1, 8).pair(usizes(1, 8)).pair(usizes(1, 12_000)).pair(one_of(&[1u64, 2, 8])),
        |(((ns, nd), total), chunk_kib)| {
            if ns == nd {
                return true;
            }
            let total = total as u64;
            let on = lifecycle_end_time(ns, nd, total, chunk_kib, true);
            let off = lifecycle_end_time(ns, nd, total, chunk_kib, false);
            let on2 = lifecycle_end_time(ns, nd, total, chunk_kib, true);
            on <= off + 1e-12 && on.to_bits() == on2.to_bits()
        },
        0xD3D3,
    );
}

#[test]
fn prop_async_spawn_overlap_preserves_payloads() {
    // Spawn-overlapped (eager) registration streams change *when*
    // segments register, never *what* the drains read: Async grows
    // must produce the exact identity repartition that Sequential
    // grows do, for every chunked RMA version.
    let versions = rma_versions();
    check_seeded(
        "async eager streams == sequential payloads",
        usizes(1, 6).pair(usizes(1, 6)).pair(usizes(0, 12_000)).pair(one_of(&versions)),
        |(((ns, extra), total), (m, s))| {
            let nd = ns + extra; // grows only: shrinks never spawn
            let total = total as u64;
            let asy = run_and_collect_cfg(ns, nd, total, m, s, false, 1, SpawnStrategy::Async);
            let seq =
                run_and_collect_cfg(ns, nd, total, m, s, false, 1, SpawnStrategy::Sequential);
            let (Some(asy), Some(seq)) = (asy, seq) else {
                return false;
            };
            asy == seq && asy.iter().enumerate().all(|(i, v)| *v == (i as f64) * 1.25 - 7.0)
        },
        0xE4E4,
    );
}

#[test]
fn prop_pipelined_virtual_times_are_deterministic() {
    // Two identical chunked runs must agree bit for bit (the
    // background registration streams are deterministic activities).
    let versions = rma_versions();
    check_seeded(
        "chunked runs are bit-deterministic",
        usizes(1, 6).pair(usizes(1, 6)).pair(usizes(1, 8_000)).pair(one_of(&versions)),
        |(((ns, nd), total), (m, s))| {
            if ns == nd {
                return true;
            }
            let total = total as u64;
            let a = run_and_collect(ns, nd, total, m, s, false, 1);
            let b = run_and_collect(ns, nd, total, m, s, false, 1);
            a == b && a.is_some()
        },
        0xC2C2,
    );
}
