//! Integration: the experiment harnesses reproduce the paper's
//! qualitative claims at full problem scale (single rep, corner pairs —
//! the full 12-pair, multi-rep sweeps live in the bench targets).

use proteo::experiments::{self, ablation, FigOptions};
use proteo::mam::{Method, Strategy};
use proteo::proteo::{analysis, run_once, RunSpec};

fn full_scale(pairs: Vec<(usize, usize)>) -> FigOptions {
    FigOptions { reps: 1, scale: 1, pairs, seed: 7, ..FigOptions::default() }
}

#[test]
fn fig3_blocking_band_matches_paper() {
    // §V-B: RMA-Lock and RMA-Lockall are 0.73×–0.99× of COL, and the
    // two RMA variants are nearly identical.
    let t = experiments::fig3_blocking(&full_scale(vec![
        (20, 160),
        (160, 20),
        (40, 80),
        (160, 40),
    ]));
    for row in 0..t.rows.len() {
        for col in 1..=2 {
            let s = t.speedup(row, col);
            assert!(
                (0.60..=1.05).contains(&s),
                "blocking RMA/COL speedup out of band at row {row}: {s:.3}"
            );
        }
        let lock = t.value(row, 1);
        let lockall = t.value(row, 2);
        let gap = (lock - lockall).abs() / lock;
        assert!(gap < 0.05, "RMA-Lock vs Lockall gap too large: {gap:.3}");
    }
    // The grow-from-few case pays the most registration: strictly < 1.
    assert!(t.speedup(0, 1) < 1.0, "20->160 must favour COL");
}

#[test]
fn fig56_omega_and_overlap_shapes() {
    // §V-C: RMA background redistribution barely slows the sources
    // (ω ≈ 1) and overlaps far fewer iterations than COL on grow.
    let opts = full_scale(vec![(20, 160)]);
    let omega = experiments::fig5_omega(&opts);
    let iters = experiments::fig6_iterations(&opts);
    // columns: COL-NB, COL-WD, RMA-Lock-WD, RMA-Lockall-WD
    let omega_col = omega.value(0, 0);
    let omega_rma = omega.value(0, 3);
    assert!(omega_rma <= omega_col + 1e-9, "RMA ω must not exceed COL ω");
    assert!((0.9..2.0).contains(&omega_rma), "ω(RMA)≈1 expected: {omega_rma}");
    let it_col = iters.value(0, 0);
    let it_rma = iters.value(0, 2);
    assert!(
        it_rma < it_col * 0.8,
        "RMA must overlap fewer iterations on grow: rma={it_rma} col={it_col}"
    );
}

#[test]
fn fig5_omega_peaks_when_drains_shrink() {
    // §V-C: "the largest ω values occur when the number of drains is
    // reduced (160→20), likely due to increased contention".
    let t = experiments::fig5_omega(&full_scale(vec![(160, 20), (20, 160)]));
    let omega_shrink = t.value(0, 0); // COL-NB at 160->20
    let omega_grow = t.value(1, 0); // COL-NB at 20->160
    assert!(
        omega_shrink > omega_grow,
        "shrink must contend more: {omega_shrink} vs {omega_grow}"
    );
}

#[test]
fn fig789_threading_is_catastrophic() {
    // §V-D: COL-T overlaps exactly one iteration; RMA-T costs several
    // times more than COL-T; ω is enormous for both.
    let opts = full_scale(vec![(160, 40)]);
    let totals = experiments::fig7_threading(&opts);
    let omega = experiments::fig8_omega_threading(&opts);
    let iters = experiments::fig9_iterations_threading(&opts);
    // columns: COL-T, RMA-Lock-T, RMA-Lockall-T
    let rma_speedup = totals.speedup(0, 1);
    assert!(
        rma_speedup < 0.6,
        "RMA-T must be much slower than COL-T (paper: 0.09–0.42): {rma_speedup:.2}"
    );
    assert_eq!(iters.value(0, 0), 1.0, "COL-T overlaps exactly 1 iteration");
    assert!(omega.value(0, 0) > 20.0, "ω(COL-T) must be huge");
    assert!(omega.value(0, 1) > 100.0, "ω(RMA-T) ≥ 100 (paper §V-D)");
}

#[test]
fn eq2_analysis_is_internally_consistent() {
    // f(V,P) ≥ R for every version, equality exactly for the arg-max
    // iteration count.
    let opts = full_scale(vec![(160, 40)]);
    let sweep = opts.sweep(&experiments::nbwd_versions());
    let set = &sweep[0].results;
    let m = analysis::eq1_max_iters(set);
    let totals = analysis::eq2_totals(set);
    for (r, f) in set.iter().zip(&totals) {
        assert!(*f >= r.redist_time - 1e-9, "{}: f < R", r.label);
        if (r.n_it - m).abs() < 1e-9 {
            assert!((*f - r.redist_time).abs() < 1e-9, "arg-max version pays no penalty");
        }
    }
    let best = analysis::eq3_best(set);
    assert!(best < set.len());
}

#[test]
fn ablation_single_window_saves_setup_not_registration() {
    // §VI: fusing the windows removes the per-structure collective
    // creations; the residual (registration) dominates, so the gain is
    // real but bounded.
    let t = ablation::single_window(&full_scale(vec![(20, 160)]));
    let per_struct = t.value(0, 0);
    let fused = t.value(0, 1);
    assert!(fused <= per_struct, "fused must not lose: {fused} vs {per_struct}");
    assert!(
        fused > per_struct * 0.5,
        "fusing cannot beat the registration floor: {fused} vs {per_struct}"
    );
}

#[test]
fn register_sweep_shows_crossover() {
    // With fast enough registration RMA overtakes COL — the paper's
    // conclusion that initialization cost is the blocker.
    let opts = FigOptions { reps: 1, scale: 10, pairs: vec![], seed: 7, ..FigOptions::default() };
    let t = ablation::registration_sweep(&opts, 20, 160);
    let slow = t.value(0, 0); // COL/RMA at 0.5 GB/s registration
    let fast = t.value(0, 4); // at 8 GB/s
    assert!(slow < fast, "ratio must improve with registration rate");
    assert!(slow < 1.0, "slow registration must favour COL");
}

#[test]
fn deterministic_across_processes() {
    // Same spec, same seed → identical figures (DES determinism at the
    // harness level).
    let spec = RunSpec::sarteco25(20, 160, Method::RmaLockall, Strategy::WaitDrains);
    let a = run_once(&spec);
    let b = run_once(&spec);
    assert_eq!(a.redist_time.to_bits(), b.redist_time.to_bits());
    assert_eq!(a.n_it, b.n_it);
    assert_eq!(a.events, b.events);
}
