//! Nonblocking-operation requests (MPI_Request equivalents).
//!
//! A request is created by `ibarrier`, `ialltoallv` or `rget` and
//! completed through `req_test` / `req_wait` / `req_testall` on the
//! owning process.  Collective-backed requests point at the shared
//! [`CollState`](super::collective::CollState); Rget requests carry
//! their completion time (known at post time — the flow schedule is
//! computed eagerly, matching hardware-offloaded RDMA reads).

use crate::simcluster::{ActivityId, Time};

use super::types::{CommId, RecvBuf, WinId};

/// Request handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ReqId(pub usize);

#[derive(Clone, Debug)]
pub(crate) enum ReqBody {
    /// Ibarrier / Ialltoallv: completion comes from the collective
    /// instance `(comm, seq)` at the owner's rank.
    Coll { key: (CommId, u64), rank: usize },
    /// Rget: one-sided read, completion known at post.
    Rget {
        /// Originating window (diagnostics; epochs close via WinState).
        #[allow(dead_code)]
        win: WinId,
        complete_at: Time,
        /// Real-mode data to deliver on completion.
        data: Option<Vec<f64>>,
        dest: RecvBuf,
        dest_off: u64,
        applied: bool,
    },
}

#[derive(Clone)]
pub(crate) struct ReqState {
    /// Owning process (diagnostics).
    #[allow(dead_code)]
    pub owner_gpid: usize,
    pub body: ReqBody,
    pub done: bool,
    /// Activity parked in `req_wait` (reserved for targeted wakeups).
    #[allow(dead_code)]
    pub waiter: Option<ActivityId>,
}

impl ReqState {
    pub fn new(owner_gpid: usize, body: ReqBody) -> ReqState {
        ReqState { owner_gpid, body, done: false, waiter: None }
    }

    /// Deliver Rget data into the destination buffer (once).
    pub fn apply_rget_data(&mut self) {
        if let ReqBody::Rget { data, dest, dest_off, applied, .. } = &mut self.body {
            if *applied {
                return;
            }
            *applied = true;
            if let Some(src) = data.take() {
                let mut guard = dest.lock().unwrap();
                if let Some(buf) = guard.as_mut() {
                    let off = *dest_off as usize;
                    assert!(
                        off + src.len() <= buf.len(),
                        "rget destination overflow: off={} len={} buf={}",
                        off,
                        src.len(),
                        buf.len()
                    );
                    buf[off..off + src.len()].copy_from_slice(&src);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simmpi::types::recv_buf_real;

    #[test]
    fn rget_apply_writes_at_offset() {
        let dest = recv_buf_real(5);
        let mut r = ReqState::new(
            0,
            ReqBody::Rget {
                win: WinId(0),
                complete_at: 1.0,
                data: Some(vec![7.0, 8.0]),
                dest: dest.clone(),
                dest_off: 2,
                applied: false,
            },
        );
        r.apply_rget_data();
        assert_eq!(dest.lock().unwrap().as_ref().unwrap(), &vec![0.0, 0.0, 7.0, 8.0, 0.0]);
        // Second apply is a no-op.
        r.apply_rget_data();
    }

    #[test]
    #[should_panic(expected = "destination overflow")]
    fn rget_overflow_panics() {
        let dest = recv_buf_real(2);
        let mut r = ReqState::new(
            0,
            ReqBody::Rget {
                win: WinId(0),
                complete_at: 1.0,
                data: Some(vec![1.0, 2.0, 3.0]),
                dest,
                dest_off: 0,
                applied: false,
            },
        );
        r.apply_rget_data();
    }
}
