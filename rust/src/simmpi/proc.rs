//! `MpiProc` — the per-process MPI handle that simulated rank bodies
//! program against.
//!
//! Locking discipline (see `world.rs`): the world mutex is never held
//! across `advance`/`park`.  Holding it across `unpark_at`/`spawn` is
//! safe — those engine requests return control to the caller without
//! scheduling another activity.

use std::sync::{Arc, Mutex};

use crate::netmodel::{NetParams, SpawnSchedule, TransferClass};
use crate::simcluster::{ActivityCtx, Time};

use super::collective::{CollKind, CollResult, CollState, Contrib};
use super::request::{ReqBody, ReqId, ReqState};
use super::rma::WinState;
use super::types::{CommId, Payload, RecvBuf, WinCreateOpts, WinId};
use super::winpool::{size_class, EvictedPin, WinPoolStats};
use super::world::{MpiWorld, PendingMsg, RecvWait};

/// Size class of a window's largest exposure (free-list filing key).
fn exposure_class(ws: &WinState) -> u32 {
    size_class(ws.exposures.iter().map(|e| e.bytes()).max().unwrap_or(0))
}

/// Segment-registration plan of one chunked pipelined exposure.
struct SegPlan {
    /// Setup + first segment — the only part gating the collective.
    first: f64,
    /// Remaining segments, registered in the background (0.0 = warm).
    rest: Vec<f64>,
    /// Total registration seconds charged (first + rest).
    charged: f64,
    /// Bytes that actually registered (cold segments only).
    cold_bytes: u64,
    cold_segs: u64,
    warm_segs: u64,
}

/// Chunk an exposure of `elems` elements into `chunk`-element segments
/// and price each segment's registration.  `warm_prefix_bytes` marks
/// how many leading bytes a previous pin still covers (window-pool
/// per-segment warmth): segments fully inside it cost nothing — the
/// first one pays the fixed window setup only.
fn segment_regs(
    cost: &crate::netmodel::CostModel,
    elems: u64,
    chunk: u64,
    warm_prefix_bytes: u64,
) -> SegPlan {
    let n_seg = elems.div_ceil(chunk);
    let seg_len = |s: u64| (elems - s * chunk).min(chunk);
    let seg_warm =
        |s: u64| (s * chunk + seg_len(s)) * super::types::ELEM_BYTES <= warm_prefix_bytes;
    let mut plan = SegPlan {
        first: cost.window_acquire(seg_len(0) * super::types::ELEM_BYTES, seg_warm(0)),
        rest: Vec::with_capacity(n_seg.saturating_sub(1) as usize),
        charged: 0.0,
        cold_bytes: 0,
        cold_segs: 0,
        warm_segs: 0,
    };
    if seg_warm(0) {
        plan.warm_segs += 1;
    } else {
        plan.cold_segs += 1;
        plan.cold_bytes += seg_len(0) * super::types::ELEM_BYTES;
    }
    for s in 1..n_seg {
        let bytes = seg_len(s) * super::types::ELEM_BYTES;
        if seg_warm(s) {
            plan.warm_segs += 1;
            plan.rest.push(0.0);
        } else {
            plan.cold_segs += 1;
            plan.cold_bytes += bytes;
            plan.rest.push(cost.window_registration(bytes));
        }
    }
    plan.charged = plan.first + plan.rest.iter().sum::<f64>();
    plan
}

/// Per-segment deregistration durations of a chunked exposure (the
/// teardown mirror of [`segment_regs`]): segment `s`'s per-byte unpin
/// time, aligned to the same chunk boundaries the registration stream
/// used.  The fixed window-teardown cost is charged separately, once.
fn segment_deregs(cost: &crate::netmodel::CostModel, elems: u64, chunk: u64) -> Vec<f64> {
    let n_seg = elems.div_ceil(chunk);
    (0..n_seg)
        .map(|s| {
            let len = (elems - s * chunk).min(chunk);
            (len * super::types::ELEM_BYTES) as f64 * cost.params.beta_register / 3.0
        })
        .collect()
}

/// Serial walk of one rank's per-segment deregistration stream:
/// segment `s` begins at `max(previous segment's end, elig[s])` and
/// takes `segs[s]` seconds on the rank's dereg engine.  Returns each
/// segment's absolute completion time (empty iff `segs` is empty).
fn dereg_stream(elig: &[Time], segs: &[f64]) -> Vec<Time> {
    let mut t = 0.0f64;
    let mut done = Vec::with_capacity(segs.len());
    for (s, d) in segs.iter().enumerate() {
        t = t.max(elig.get(s).copied().unwrap_or(0.0)) + d;
        done.push(t);
    }
    done
}

/// Bounded sample of a stream's completion times — the `winreg-*` /
/// `windereg-*` engine activities walk these instead of every segment
/// (keeps the event count O(1) per stream regardless of chunk count).
fn sample_stream(done: &[Time]) -> Vec<Time> {
    let Some(&last) = done.last() else {
        return Vec::new();
    };
    let stride = done.len().div_ceil(32).max(1);
    let mut pts: Vec<Time> = done.iter().copied().step_by(stride).collect();
    if pts.last() != Some(&last) {
        pts.push(last);
    }
    pts
}

/// Handle to one simulated MPI process (or its auxiliary thread).
pub struct MpiProc {
    pub(crate) ctx: ActivityCtx,
    pub(crate) world: Arc<Mutex<MpiWorld>>,
    pub(crate) gpid: usize,
    pub(crate) is_aux: bool,
}

impl MpiProc {
    pub(crate) fn main(ctx: ActivityCtx, world: Arc<Mutex<MpiWorld>>, gpid: usize) -> MpiProc {
        MpiProc { ctx, world, gpid, is_aux: false }
    }

    /// Clone for passing into nested scopes (same activity).
    pub fn clone_handle(&self) -> MpiProc {
        MpiProc {
            ctx: self.ctx.clone(),
            world: self.world.clone(),
            gpid: self.gpid,
            is_aux: self.is_aux,
        }
    }

    /// Called by the launcher when the rank body returns.
    pub(crate) fn on_exit(&self) {
        if !self.is_aux {
            let mut w = self.world.lock().unwrap();
            w.retire_proc(self.gpid);
        }
    }

    // ------------------------------------------------------- identity

    pub fn gpid(&self) -> usize {
        self.gpid
    }

    pub fn is_aux(&self) -> bool {
        self.is_aux
    }

    pub fn now(&self) -> Time {
        self.ctx.now()
    }

    /// Rank of this process within `comm`; panics if not a member.
    pub fn rank(&self, comm: CommId) -> usize {
        let w = self.world.lock().unwrap();
        w.comm(comm)
            .rank_of(self.gpid)
            .unwrap_or_else(|| panic!("gpid {} not in {:?}", self.gpid, comm))
    }

    /// Membership test.
    pub fn in_comm(&self, comm: CommId) -> bool {
        let w = self.world.lock().unwrap();
        w.comm(comm).rank_of(self.gpid).is_some()
    }

    pub fn size(&self, comm: CommId) -> usize {
        let w = self.world.lock().unwrap();
        w.comm(comm).gpids.len()
    }

    // ------------------------------------------------------- app side

    /// Model `dt` seconds of application compute.  Stretched by the
    /// oversubscription factor while this process has a live auxiliary
    /// thread (Threading strategy, §V-D).
    pub fn compute(&self, dt: f64) {
        let stretched = {
            let w = self.world.lock().unwrap();
            if w.oversubscription && w.procs[self.gpid].aux_alive {
                dt * w.cost.params.oversub_factor
            } else {
                dt
            }
        };
        self.ctx.advance(stretched);
    }

    /// Count one application iteration (read by the monitor).
    pub fn iter_tick(&self) {
        let mut w = self.world.lock().unwrap();
        w.procs[self.gpid].iters_done += 1;
    }

    /// Iterations completed so far by this process.
    pub fn iters_done(&self) -> u64 {
        self.world.lock().unwrap().procs[self.gpid].iters_done
    }

    /// Record into the world metrics.
    pub fn metrics<R>(&self, f: impl FnOnce(&mut crate::monitor::Metrics) -> R) -> R {
        let mut w = self.world.lock().unwrap();
        f(&mut w.metrics)
    }

    /// Snapshot of the calibrated model constants (read-only; MaM uses
    /// this to derive spawn schedules from the cost model).
    pub fn net_params(&self) -> NetParams {
        self.world.lock().unwrap().cost.params.clone()
    }

    /// Cores per node of the simulated allocation (read-only; the
    /// planner uses this to predict per-NIC contention).
    pub fn cores_per_node(&self) -> usize {
        self.world.lock().unwrap().placement.cores_per_node
    }

    // --------------------------------------------- MPI call machinery

    /// Progress model (MPICH CH4): every MPI call drains one chunk of
    /// pending nonblocking-collective CPU work (pack/unpack).
    fn drain_nb(&self) {
        let work: Option<f64> = {
            let mut w = self.world.lock().unwrap();
            let chunk = w.cost.params.progress_chunk;
            let beta = w.cost.params.beta_memcpy;
            let open = w.procs[self.gpid].open_nb_reqs.clone();
            let mut found = None;
            for rid in open {
                let (key, rank) = match &w.requests[rid].body {
                    ReqBody::Coll { key, rank } => (*key, *rank),
                    _ => continue,
                };
                if let Some(cs) = w.colls.get_mut(&key) {
                    if cs.completion.is_some() && cs.cpu_remaining[rank] > 0 {
                        let take = cs.cpu_remaining[rank].min(chunk);
                        cs.cpu_remaining[rank] -= take;
                        found = Some(take as f64 * beta);
                        break;
                    }
                }
            }
            found
        };
        if let Some(dt) = work {
            self.ctx.advance(dt);
        }
    }

    /// Progress-engine contention model (MPICH 4.2.0 serialized
    /// `MPI_THREAD_MULTIPLE` progress, §V-D).  The auxiliary thread
    /// never waits — while it is inside a blocking MPI call it owns the
    /// progress engine (depth-counted) and drives everyone's progress.
    /// The *main* thread's MPI calls stall until the aux op completes;
    /// in the gaps between the aux's blocking calls the main thread
    /// sneaks its own operations through.  This reproduces the paper's
    /// §V-D observations: COL-T overlaps exactly one iteration (the aux
    /// runs a single long `Alltoallv`), while the RMA-T variants
    /// overlap ~3 (one gap after each window-create/free collective).
    fn progress_acquire(&self) {
        if self.is_aux {
            let mut w = self.world.lock().unwrap();
            w.procs[self.gpid].aux_busy += 1;
            return;
        }
        loop {
            {
                let mut w = self.world.lock().unwrap();
                let p = &mut w.procs[self.gpid];
                if !p.aux_alive || p.aux_busy == 0 {
                    return;
                }
                p.progress_waiters.push(self.ctx.id());
            }
            self.ctx.park();
        }
    }

    fn progress_release(&self) {
        if !self.is_aux {
            return;
        }
        let waiters = {
            let mut w = self.world.lock().unwrap();
            let p = &mut w.procs[self.gpid];
            debug_assert!(p.aux_busy > 0, "unbalanced progress_release");
            p.aux_busy = p.aux_busy.saturating_sub(1);
            if p.aux_busy == 0 {
                std::mem::take(&mut p.progress_waiters)
            } else {
                Vec::new()
            }
        };
        for aid in waiters {
            self.ctx.unpark_now(aid);
        }
    }

    /// Standard prologue of every MPI call.
    fn mpi_prologue(&self) {
        self.drain_nb();
    }

    // ------------------------------------------------------------ p2p

    /// Blocking standard-mode send.  Eager messages return when the
    /// local copy is done; rendezvous messages when delivered.
    pub fn send(&self, comm: CommId, dst_rank: usize, tag: i32, payload: Payload) {
        self.mpi_prologue();
        self.progress_acquire();
        let (block_until, wake): (Time, Option<crate::simcluster::ActivityId>) = {
            let mut w = self.world.lock().unwrap();
            let my_rank = w.comm(comm).rank_of(self.gpid).expect("sender not in comm");
            let dst_gpid = w.comm(comm).gpids[dst_rank];
            let bytes = payload.bytes().max(1);
            let eager = bytes < w.cost.params.eager_threshold;
            let MpiWorld { cost, placement, .. } = &mut *w;
            let tt = cost.transfer(
                self.ctx.now(),
                placement,
                self.gpid,
                dst_gpid,
                bytes,
                TransferClass::TwoSided,
            );
            let msg = PendingMsg {
                src_rank: my_rank,
                comm,
                tag,
                payload,
                arrival: tt.arrival,
            };
            let dst = &mut w.procs[dst_gpid];
            // Wake a matching parked receiver, if any.
            let pos = dst.recv_waits.iter().position(|rw| {
                rw.comm == comm
                    && rw.tag == tag
                    && (rw.src_rank.is_none() || rw.src_rank == Some(my_rank))
            });
            let wake = pos.map(|p| dst.recv_waits.remove(p).waiter);
            dst.inbox.push(msg);
            (if eager { tt.cpu_done } else { tt.arrival }, wake)
        };
        if let Some(aid) = wake {
            self.ctx.unpark_at(aid, block_until.max(self.ctx.now()));
        }
        self.ctx.advance_until(block_until);
        self.progress_release();
    }

    /// Blocking receive; `src_rank = None` means MPI_ANY_SOURCE.
    pub fn recv(&self, comm: CommId, src_rank: Option<usize>, tag: i32) -> Payload {
        self.mpi_prologue();
        self.progress_acquire();
        loop {
            let found: Option<(Payload, Time)> = {
                let mut w = self.world.lock().unwrap();
                let p = &mut w.procs[self.gpid];
                let pos = p.inbox.iter().position(|m| {
                    m.comm == comm
                        && m.tag == tag
                        && (src_rank.is_none() || src_rank == Some(m.src_rank))
                });
                match pos {
                    Some(i) => {
                        let m = p.inbox.remove(i);
                        Some((m.payload, m.arrival))
                    }
                    None => {
                        p.recv_waits.push(RecvWait {
                            src_rank,
                            comm,
                            tag,
                            waiter: self.ctx.id(),
                        });
                        None
                    }
                }
            };
            match found {
                Some((payload, arrival)) => {
                    // Drop any stale wait registrations from earlier
                    // loop iterations (spurious wakeups).
                    {
                        let mut w = self.world.lock().unwrap();
                        let me = self.ctx.id();
                        w.procs[self.gpid].recv_waits.retain(|rw| rw.waiter != me);
                    }
                    self.ctx.advance_until(arrival);
                    // Receiver-side unpack charge for real bulk data.
                    let unpack = {
                        let w = self.world.lock().unwrap();
                        if payload.is_real() {
                            payload.bytes() as f64 * w.cost.params.beta_memcpy * 0.0
                        } else {
                            0.0
                        }
                    };
                    if unpack > 0.0 {
                        self.ctx.advance(unpack);
                    }
                    self.progress_release();
                    return payload;
                }
                None => self.ctx.park(),
            }
        }
    }

    // ----------------------------------------------------- collectives

    /// Post a contribution to a collective instance; schedules it if
    /// this rank is the last to arrive.  Returns (key, my_rank).
    fn coll_post(
        &self,
        comm: CommId,
        kind: CollKind,
        contrib: Contrib,
        setup: impl FnOnce(&mut MpiWorld, &mut CollState, usize),
    ) -> ((CommId, u64), usize) {
        let (key, my_rank, waiters) = {
            let mut w = self.world.lock().unwrap();
            let my_rank = w.comm(comm).rank_of(self.gpid).expect("not in comm");
            let seq = w.comm(comm).coll_seq[my_rank];
            w.comm_mut(comm).coll_seq[my_rank] += 1;
            let key = (comm, seq);
            let n = w.comm(comm).gpids.len();
            let arrive_t = self.ctx.now() + w.cost.params.op_overhead;
            let mt = self.is_aux || w.procs[self.gpid].aux_alive;
            let mut cs = w
                .colls
                .remove(&key)
                .unwrap_or_else(|| CollState::new(kind, n));
            assert_eq!(
                cs.kind, kind,
                "collective call order mismatch on {comm:?} seq {seq}"
            );
            cs.mt |= mt;
            setup(&mut w, &mut cs, my_rank);
            let last = cs.arrive(my_rank, arrive_t, contrib);
            let mut waiters = Vec::new();
            if last {
                let gpids = w.comm(comm).gpids.clone();
                let MpiWorld { cost, placement, .. } = &mut *w;
                cs.schedule(cost, placement, &gpids);
                waiters = std::mem::take(&mut cs.waiters);
                // Pipelined Win_create: materialize every rank's
                // background segment-registration stream as absolute
                // ready times *before any participant resumes* — Gets
                // posted right after the collective gate on these (the
                // chunked pipelined redistribution path).  An `eager`
                // contribution starts its stream at the rank's own
                // fill end instead of the collective exit: pinning is
                // local, so under asynchronous spawning the sources
                // register while the spawned ranks are still starting.
                if cs.kind == CollKind::WinCreate {
                    if let (Some(win), Some(completion)) = (cs.win_id, cs.completion.as_ref()) {
                        for (r, c) in cs.contribs.iter().enumerate() {
                            if let Some(Contrib::RegPipeline { first, rest, eager }) = c {
                                if rest.is_empty() {
                                    continue;
                                }
                                let mut t = if *eager {
                                    cs.arrivals[r].expect("arrived") + first
                                } else {
                                    completion[r]
                                };
                                let mut ready = Vec::with_capacity(rest.len() + 1);
                                ready.push(t);
                                for d in rest {
                                    t += d;
                                    ready.push(t);
                                }
                                w.windows[win.0].seg_ready[r] = ready;
                            }
                        }
                    }
                }
                // Pipelined Win_free: the schedule above charged the
                // closing barrier only.  Reconcile each pipelined
                // rank's per-segment deregistration stream against the
                // window's read/registration record: segment `s`
                // deregisters once the last read touching it has
                // landed (and its own registration finished), the
                // stream runs serially on the rank's dereg engine as a
                // `windereg-*` background activity, and only its
                // excess over the barrier — plus the fixed teardown —
                // lands on the rank's completion.  Retiring ranks on a
                // shrink thus exit after `max(T_dereg, T_wire)`
                // instead of `T_wire + T_dereg`.
                if cs.kind == CollKind::WinFree {
                    if let (Some(win), Some(completion)) = (cs.win_id, cs.completion.as_mut()) {
                        for (r, c) in cs.contribs.iter().enumerate() {
                            if let Some(Contrib::DeregPipeline { segs, fixed }) = c {
                                let elig = w.windows[win.0].dereg_eligibility(r);
                                let done = dereg_stream(&elig, segs);
                                let end = done.last().copied().unwrap_or(0.0);
                                completion[r] = completion[r].max(end) + fixed;
                                let pts = sample_stream(&done);
                                if !pts.is_empty() {
                                    let gp = w.comm(comm).gpids[r];
                                    self.ctx.spawn(
                                        format!("windereg-g{gp}-w{}", win.0),
                                        move |ctx| {
                                            for t in pts {
                                                ctx.advance_until(t);
                                            }
                                        },
                                    );
                                }
                            }
                        }
                    }
                }
            }
            let completion = cs.completion.clone();
            w.colls.insert(key, cs);
            // Wake parked participants at their completion times.
            let waiters: Vec<(crate::simcluster::ActivityId, Time)> = waiters
                .into_iter()
                .map(|(r, aid)| (aid, completion.as_ref().unwrap()[r]))
                .collect();
            (key, my_rank, waiters)
        };
        // One engine event + O(N) release sweep for the whole
        // collective, instead of N per-waiter handoff round-trips.
        // Entry order and clamping match the seed per-waiter loop, so
        // release order is bit-identical.
        let now = self.ctx.now();
        self.ctx
            .unpark_batch(waiters.into_iter().map(|(aid, t)| (aid, t.max(now))).collect());
        (key, my_rank)
    }

    /// Block until the collective completes; returns this rank's result.
    fn coll_block(&self, key: (CommId, u64), my_rank: usize) -> CollResult {
        loop {
            let state: Option<Time> = {
                let mut w = self.world.lock().unwrap();
                let cs = w.colls.get_mut(&key).expect("collective vanished");
                match cs.completion_of(my_rank) {
                    Some(t) => Some(t),
                    None => {
                        cs.waiters.push((my_rank, self.ctx.id()));
                        None
                    }
                }
            };
            match state {
                Some(t) => {
                    self.ctx.advance_until(t);
                    return self.coll_take(key, my_rank);
                }
                None => self.ctx.park(),
            }
        }
    }

    /// Consume this rank's result and GC the instance when everyone has.
    fn coll_take(&self, key: (CommId, u64), my_rank: usize) -> CollResult {
        let mut w = self.world.lock().unwrap();
        let cs = w.colls.get_mut(&key).expect("collective vanished");
        let res = cs.results[my_rank].take().expect("result already taken");
        cs.taken += 1;
        if cs.taken == cs.n {
            w.colls.remove(&key);
        }
        res
    }

    /// MPI_Barrier.
    pub fn barrier(&self, comm: CommId) {
        self.mpi_prologue();
        self.progress_acquire();
        let (key, r) = self.coll_post(comm, CollKind::Barrier, Contrib::None, |_, _, _| {});
        self.coll_block(key, r);
        self.progress_release();
    }

    /// MPI_Allgather: returns every rank's block, in rank order.
    pub fn allgather(&self, comm: CommId, block: Payload) -> Vec<Payload> {
        self.mpi_prologue();
        self.progress_acquire();
        let (key, r) =
            self.coll_post(comm, CollKind::Allgather, Contrib::Block(block), |_, _, _| {});
        let res = self.coll_block(key, r);
        self.progress_release();
        match res {
            CollResult::Gathered(v) => v,
            _ => unreachable!(),
        }
    }

    /// MPI_Alltoallv (blocking): `sends[j]` goes to rank j; returns what
    /// this rank received from each rank.
    pub fn alltoallv(&self, comm: CommId, sends: Vec<Payload>) -> Vec<Payload> {
        self.mpi_prologue();
        self.progress_acquire();
        assert_eq!(sends.len(), self.size(comm), "alltoallv send width");
        let (key, r) =
            self.coll_post(comm, CollKind::Alltoallv, Contrib::Scatter(sends), |_, _, _| {});
        let res = self.coll_block(key, r);
        self.progress_release();
        match res {
            CollResult::Received(v) => v,
            _ => unreachable!(),
        }
    }

    /// MPI_Ibarrier.
    pub fn ibarrier(&self, comm: CommId) -> ReqId {
        self.mpi_prologue();
        let (key, r) = self.coll_post(comm, CollKind::Ibarrier, Contrib::None, |_, _, _| {});
        self.new_coll_request(key, r, false)
    }

    /// MPI_Ialltoallv.
    pub fn ialltoallv(&self, comm: CommId, sends: Vec<Payload>) -> ReqId {
        self.mpi_prologue();
        assert_eq!(sends.len(), self.size(comm), "ialltoallv send width");
        let (key, r) =
            self.coll_post(comm, CollKind::Ialltoallv, Contrib::Scatter(sends), |_, _, _| {});
        self.new_coll_request(key, r, true)
    }

    fn new_coll_request(&self, key: (CommId, u64), rank: usize, has_cpu_work: bool) -> ReqId {
        let mut w = self.world.lock().unwrap();
        let rid = w.requests.len();
        w.requests.push(ReqState::new(self.gpid, ReqBody::Coll { key, rank }));
        if has_cpu_work {
            w.procs[self.gpid].open_nb_reqs.push(rid);
        }
        ReqId(rid)
    }

    // ------------------------------------------------------- requests

    /// MPI_Test: nonblocking completion check (charges one poll).
    pub fn req_test(&self, req: ReqId) -> bool {
        self.mpi_prologue();
        let poll = {
            let w = self.world.lock().unwrap();
            w.cost.params.poll_cost
        };
        self.ctx.advance(poll);
        self.req_check(req)
    }

    /// Completion check without the poll charge (internal + testall).
    fn req_check(&self, req: ReqId) -> bool {
        let now = self.ctx.now();
        let mut w = self.world.lock().unwrap();
        if w.requests[req.0].done {
            return true;
        }
        let done = match &w.requests[req.0].body {
            ReqBody::Coll { key, rank } => match w.colls.get(key) {
                Some(cs) => {
                    cs.completion_of(*rank).is_some_and(|t| now >= t)
                        && cs.cpu_remaining[*rank] == 0
                }
                // Instance GC'd: all results taken → long complete.
                None => true,
            },
            ReqBody::Rget { complete_at, .. } => now >= *complete_at,
        };
        if done {
            self.finish_request(&mut w, req);
        }
        done
    }

    fn finish_request(&self, w: &mut MpiWorld, req: ReqId) {
        // Mark done, deliver Rget data, release coll result slot.
        let body_key = {
            let r = &mut w.requests[req.0];
            r.done = true;
            r.apply_rget_data();
            match &r.body {
                ReqBody::Coll { key, rank } => Some((*key, *rank)),
                _ => None,
            }
        };
        w.procs[self.gpid].open_nb_reqs.retain(|&x| x != req.0);
        if let Some((key, rank)) = body_key {
            if let Some(cs) = w.colls.get_mut(&key) {
                if cs.results[rank].is_some() {
                    // Leave the payload retrievable via req_result; mark
                    // taken so the instance can be GC'd when consumed.
                    let _ = rank;
                }
            }
        }
    }

    /// Retrieve the received payloads of a completed Ialltoallv.
    pub fn req_result_alltoallv(&self, req: ReqId) -> Vec<Payload> {
        let (key, rank) = {
            let w = self.world.lock().unwrap();
            assert!(w.requests[req.0].done, "request not complete");
            match &w.requests[req.0].body {
                ReqBody::Coll { key, rank } => (*key, *rank),
                _ => panic!("not an ialltoallv request"),
            }
        };
        match self.coll_take(key, rank) {
            CollResult::Received(v) => v,
            _ => panic!("not an alltoallv collective"),
        }
    }

    /// MPI_Wait.
    pub fn req_wait(&self, req: ReqId) {
        loop {
            if self.req_test(req) {
                return;
            }
            // Decide how to make progress.
            enum Plan {
                AdvanceTo(Time),
                Park,
                Drain,
            }
            let plan = {
                let mut w = self.world.lock().unwrap();
                match &w.requests[req.0].body {
                    ReqBody::Rget { complete_at, .. } => Plan::AdvanceTo(*complete_at),
                    ReqBody::Coll { key, rank } => {
                        let (key, rank) = (*key, *rank);
                        match w.colls.get_mut(&key) {
                            Some(cs) => match cs.completion_of(rank) {
                                Some(t) if cs.cpu_remaining[rank] == 0 => Plan::AdvanceTo(t),
                                Some(_) => Plan::Drain, // test() drains a chunk
                                None => {
                                    cs.waiters.push((rank, self.ctx.id()));
                                    Plan::Park
                                }
                            },
                            None => Plan::Drain,
                        }
                    }
                }
            };
            match plan {
                Plan::AdvanceTo(t) => self.ctx.advance_until(t),
                Plan::Park => self.ctx.park(),
                Plan::Drain => {} // loop; req_test drains a chunk each call
            }
        }
    }

    /// MPI_Testall over a set of requests.
    pub fn req_testall(&self, reqs: &[ReqId]) -> bool {
        self.mpi_prologue();
        let poll = {
            let w = self.world.lock().unwrap();
            w.cost.params.poll_cost * reqs.len().max(1) as f64
        };
        self.ctx.advance(poll);
        reqs.iter().all(|r| self.req_check(*r))
    }

    /// MPI_Waitall.
    pub fn req_waitall(&self, reqs: &[ReqId]) {
        for r in reqs {
            self.req_wait(*r);
        }
    }

    // ------------------------------------------------------------ RMA

    /// Shared body of every window create (`win_create`/`win_acquire`
    /// and their pipelined variants): the collective that materializes
    /// the window (first arriver allocates — from the pool's free list
    /// when `pooled` — every rank installs its exposure) and charges
    /// this rank's registration `contrib`.  A pipelined contribution
    /// (`Contrib::RegPipeline`) gates the collective on its first
    /// segment only; the remaining segments register in the background
    /// — their absolute ready times are filled in by the last arriver
    /// (Gets gate on them per segment) and the stream runs as a real
    /// `winreg` engine activity after the collective exits.
    fn win_open(
        &self,
        comm: CommId,
        payload: Payload,
        contrib: Contrib,
        pooled: bool,
        chunk_elems: u64,
    ) -> WinId {
        let bytes = payload.bytes();
        let is_aux = self.is_aux;
        let gpid = self.gpid;
        let (key, r) = self.coll_post(comm, CollKind::WinCreate, contrib, {
            let payload = payload.clone();
            move |w, cs, my_rank| {
                let win = *cs.win_id.get_or_insert_with(|| {
                    let n = w.comm(comm).gpids.len();
                    let slot = if pooled {
                        w.win_pool.take_slot(comm, size_class(bytes))
                    } else {
                        None
                    };
                    match slot {
                        Some(wid) => {
                            w.windows[wid.0].reset(comm, n);
                            wid
                        }
                        None => {
                            w.windows.push(WinState::new(comm, n));
                            WinId(w.windows.len() - 1)
                        }
                    }
                });
                w.windows[win.0].exposures[my_rank] = payload;
                // Segmented ranks publish the window's chunk size;
                // unsegmented participants (e.g. drains exposing NULL
                // in a pipelined window) must not clear it.
                if chunk_elems > 0 {
                    w.windows[win.0].seg_elems = chunk_elems;
                }
                // Propagate the MT flag: accesses to a window created
                // from a threaded context pay the MT penalty (§V-D).
                if is_aux || w.procs[gpid].aux_alive {
                    w.windows[win.0].mt = true;
                }
            }
        });
        // Window id is fixed once the first rank arrives.
        let win = {
            let w = self.world.lock().unwrap();
            w.colls.get(&key).and_then(|c| c.win_id).expect("win id")
        };
        self.coll_block(key, r);
        // Pipelined contributions: materialize the background
        // registration stream as a real engine activity walking a
        // bounded sample of the segment ready times (empty for
        // unsegmented contributions — nothing registers past the
        // collective).  Gets gate on the precomputed per-segment ready
        // times; `win_free`/`win_release` wait for the stream's end.
        let stream: Vec<Time> = {
            let w = self.world.lock().unwrap();
            let ready = &w.windows[win.0].seg_ready[r];
            if ready.len() <= 1 {
                Vec::new()
            } else {
                let tail = &ready[1..];
                let stride = tail.len().div_ceil(32).max(1);
                let mut v: Vec<Time> = tail.iter().copied().step_by(stride).collect();
                let last = *tail.last().unwrap();
                if v.last() != Some(&last) {
                    v.push(last);
                }
                v
            }
        };
        if !stream.is_empty() {
            self.ctx.spawn(format!("winreg-g{gpid}-w{}", win.0), move |ctx| {
                for t in stream {
                    ctx.advance_until(t);
                }
            });
        }
        win
    }

    /// Unified `MPI_Win_create` entrypoint (collective; §IV-A).  Each
    /// rank exposes `payload`; pass `Payload::virt(0)` to expose
    /// nothing (drain-only ranks, §IV-B).  [`WinCreateOpts`] selects
    /// the registration strategy:
    ///
    /// * `WinCreateOpts::blocking()` (the default) registers the whole
    ///   exposure inside the collective — the paper's baseline, whose
    ///   cost is the dominant RMA overhead (§V);
    /// * `WinCreateOpts::pipelined(chunk)` splits the exposure into
    ///   `chunk`-element segments and registers only the first one
    ///   inside the collective (§VI) — later segments register while
    ///   Gets on earlier ones are already flowing, dropping a cold
    ///   resize from `T_reg + T_wire` toward `max(T_reg, T_wire)`;
    /// * `.eager(true)` starts this rank's background stream at its
    ///   *own* fill end instead of the collective exit (pinning is
    ///   local), so under `--spawn-strategy async` source streams
    ///   overlap the spawned ranks' staggered startup.
    ///
    /// `chunk_elems = 0` (or a single-segment exposure) is
    /// bit-identical to the seed blocking path.
    pub fn win_create_with(&self, comm: CommId, payload: Payload, opts: WinCreateOpts) -> WinId {
        if opts.chunk_elems == 0 || payload.elems() <= opts.chunk_elems {
            return self.win_create_blocking(comm, payload);
        }
        self.mpi_prologue();
        self.progress_acquire();
        let (first, rest) = {
            let mut w = self.world.lock().unwrap();
            let plan = segment_regs(&w.cost, payload.elems(), opts.chunk_elems, 0);
            Self::note_registration(&mut w, plan.cold_bytes, plan.charged);
            (plan.first, plan.rest)
        };
        let contrib = Contrib::RegPipeline { first, rest, eager: opts.eager_reg };
        let win = self.win_open(comm, payload, contrib, false, opts.chunk_elems);
        self.progress_release();
        win
    }

    /// The seed blocking body (`chunk_elems = 0` arm of
    /// [`MpiProc::win_create_with`]).
    fn win_create_blocking(&self, comm: CommId, payload: Payload) -> WinId {
        self.mpi_prologue();
        self.progress_acquire();
        let reg = {
            let mut w = self.world.lock().unwrap();
            let reg = w.cost.window_registration(payload.bytes());
            Self::note_registration(&mut w, payload.bytes(), reg);
            reg
        };
        let win = self.win_open(comm, payload, Contrib::RegTime(reg), false, 0);
        self.progress_release();
        win
    }

    /// MPI_Win_create with blocking registration.
    #[deprecated(note = "use win_create_with(comm, payload, WinCreateOpts::blocking())")]
    pub fn win_create(&self, comm: CommId, payload: Payload) -> WinId {
        self.win_create_blocking(comm, payload)
    }

    /// Record registration work into the world metrics — the observed
    /// registration-throughput hook (`rma.reg_bytes` / `rma.reg_time`)
    /// the scenario reports derive `bytes_registered / reg_span` from.
    fn note_registration(w: &mut MpiWorld, bytes: u64, secs: f64) {
        if bytes > 0 {
            w.metrics.add_counter("rma.reg_bytes", bytes as f64);
            w.metrics.add_counter("rma.reg_time", secs);
        }
    }

    /// Chunked pipelined `MPI_Win_create`.
    #[deprecated(note = "use win_create_with(comm, payload, WinCreateOpts::pipelined(chunk_elems))")]
    pub fn win_create_pipelined(&self, comm: CommId, payload: Payload, chunk_elems: u64) -> WinId {
        self.win_create_with(comm, payload, WinCreateOpts::pipelined(chunk_elems))
    }

    /// Chunked pipelined `MPI_Win_create` with a stream-start policy.
    #[deprecated(
        note = "use win_create_with(comm, payload, WinCreateOpts::pipelined(chunk_elems).eager(eager))"
    )]
    pub fn win_create_pipelined_opts(
        &self,
        comm: CommId,
        payload: Payload,
        chunk_elems: u64,
        eager: bool,
    ) -> WinId {
        self.win_create_with(comm, payload, WinCreateOpts::pipelined(chunk_elems).eager(eager))
    }

    /// Unified pooled acquire: [`MpiProc::win_create_with`] through the
    /// persistent window pool.  With `WinCreateOpts::pipelined(chunk)`
    /// warmth is *per-segment* — a previous pin covering a prefix of
    /// the exposure keeps those segments free, only the tail registers
    /// (in the background); when every segment is warm the pipeline
    /// collapses to the plain warm acquire: pure wire time, no
    /// background stream at all.  `chunk_elems = 0` is the plain pooled
    /// acquire ([`MpiProc::win_acquire_capped`], bit-identical).
    pub fn win_acquire_with(
        &self,
        comm: CommId,
        payload: Payload,
        pin: u64,
        cap: usize,
        opts: WinCreateOpts,
    ) -> WinId {
        let chunk_elems = opts.chunk_elems;
        let eager = opts.eager_reg;
        if chunk_elems == 0 || payload.elems() <= chunk_elems {
            return self.win_acquire_capped(comm, payload, pin, cap);
        }
        self.mpi_prologue();
        self.progress_acquire();
        let bytes = payload.bytes();
        let (first, rest, evicted) = {
            let mut w = self.world.lock().unwrap();
            if w.win_pool.is_warm(self.gpid, pin, bytes) {
                // Whole exposure still pinned: identical to a plain
                // warm acquire — fixed setup, no background stream.
                let reg = w.cost.window_acquire(bytes, true);
                let saved = w.cost.window_acquire(bytes, false) - reg;
                w.win_pool.touch(self.gpid, pin);
                w.win_pool.note_acquire(true, 0.0, saved);
                (reg, Vec::new(), Vec::new())
            } else {
                let prefix = w.win_pool.warm_prefix_bytes(self.gpid, pin);
                let plan = segment_regs(&w.cost, payload.elems(), chunk_elems, prefix);
                let evicted = w.win_pool.record_pin(self.gpid, pin, bytes, cap);
                w.win_pool.note_acquire(false, plan.charged, 0.0);
                w.win_pool.note_pipelined(plan.cold_segs, plan.warm_segs);
                Self::note_registration(&mut w, plan.cold_bytes, plan.charged);
                (plan.first, plan.rest, evicted)
            }
        };
        self.spawn_evict_deregs(evicted);
        let contrib = Contrib::RegPipeline { first, rest, eager };
        let win = self.win_open(comm, payload, contrib, true, chunk_elems);
        // Record when this pin's background stream completes, so a
        // later LRU eviction of the token cannot deregister segments
        // that are still being pinned.
        {
            let mut w = self.world.lock().unwrap();
            let my_rank = w.comm(comm).rank_of(self.gpid).expect("not in win comm");
            if let Some(t) = w.windows[win.0].reg_done(my_rank) {
                w.win_pool.set_reg_done(self.gpid, pin, t);
            }
        }
        self.progress_release();
        win
    }

    /// Pooled chunked pipelined acquire.
    #[deprecated(note = "use win_acquire_with(.., WinCreateOpts::pipelined(chunk_elems))")]
    pub fn win_acquire_pipelined(
        &self,
        comm: CommId,
        payload: Payload,
        pin: u64,
        cap: usize,
        chunk_elems: u64,
    ) -> WinId {
        self.win_acquire_with(comm, payload, pin, cap, WinCreateOpts::pipelined(chunk_elems))
    }

    /// Pooled chunked pipelined acquire with a stream-start policy.
    #[deprecated(
        note = "use win_acquire_with(.., WinCreateOpts::pipelined(chunk_elems).eager(eager))"
    )]
    pub fn win_acquire_pipelined_opts(
        &self,
        comm: CommId,
        payload: Payload,
        pin: u64,
        cap: usize,
        chunk_elems: u64,
        eager: bool,
    ) -> WinId {
        self.win_acquire_with(comm, payload, pin, cap, WinCreateOpts::pipelined(chunk_elems).eager(eager))
    }

    /// Pipelined windows: block until this rank's background segment
    /// registration finished — a window cannot be torn down while its
    /// memory is still being pinned.  No-op for unsegmented windows.
    fn await_reg_done(&self, win: WinId) {
        let done = {
            let w = self.world.lock().unwrap();
            let comm = w.windows[win.0].comm;
            let my_rank = w.comm(comm).rank_of(self.gpid).expect("not in win comm");
            w.windows[win.0].reg_done(my_rank)
        };
        if let Some(t) = done {
            if t > self.ctx.now() {
                self.ctx.advance_until(t);
            }
        }
    }

    /// Pooled `MPI_Win_create` (§VI window pool): collective like
    /// [`MpiProc::win_create`], but the exposed buffer's registration
    /// is looked up in the persistent pool first.  A rank whose `pin`
    /// token still covers `payload` is *warm* and pays only the fixed
    /// window setup; cold ranks pay the full registration and populate
    /// the cache for the next acquire.  The first arriver reuses a
    /// released slot of this communicator when one fits.
    pub fn win_acquire(&self, comm: CommId, payload: Payload, pin: u64) -> WinId {
        self.win_acquire_capped(comm, payload, pin, 0)
    }

    /// [`MpiProc::win_acquire`] with a bound on this process's
    /// registration cache: `cap` is the maximum number of pinned
    /// tokens kept per rank (0 = unbounded).  When a cold pin would
    /// exceed the cap, the least-recently-used token is evicted — its
    /// buffer is deregistered and the next acquire under it is cold
    /// again.
    pub fn win_acquire_capped(
        &self,
        comm: CommId,
        payload: Payload,
        pin: u64,
        cap: usize,
    ) -> WinId {
        self.mpi_prologue();
        self.progress_acquire();
        let bytes = payload.bytes();
        let (reg, evicted) = {
            let mut w = self.world.lock().unwrap();
            let warm = w.win_pool.is_warm(self.gpid, pin, bytes);
            let reg = w.cost.window_acquire(bytes, warm);
            if warm {
                let saved = w.cost.window_acquire(bytes, false) - reg;
                w.win_pool.touch(self.gpid, pin);
                w.win_pool.note_acquire(true, 0.0, saved);
                (reg, Vec::new())
            } else {
                let evicted = w.win_pool.record_pin(self.gpid, pin, bytes, cap);
                w.win_pool.note_acquire(false, reg, 0.0);
                Self::note_registration(&mut w, bytes, reg);
                (reg, evicted)
            }
        };
        self.spawn_evict_deregs(evicted);
        let win = self.win_open(comm, payload, Contrib::RegTime(reg), true, 0);
        self.progress_release();
        win
    }

    /// Release a pooled window (collective): the closing
    /// synchronization of `MPI_Win_free`, but the slot returns to the
    /// pool with its memory still pinned — no per-byte deregistration.
    pub fn win_release(&self, win: WinId) {
        self.mpi_prologue();
        self.progress_acquire();
        self.await_reg_done(win);
        let (comm, dt) = {
            let mut w = self.world.lock().unwrap();
            let comm = w.windows[win.0].comm;
            let my_rank = w.comm(comm).rank_of(self.gpid).expect("not in win comm");
            let dt = w.cost.window_release();
            w.windows[win.0].freed_local[my_rank] = true;
            (comm, dt)
        };
        // The *last arriver* files the slot, inside the collective
        // matching step: every rank has arrived and none has resumed,
        // so no re-acquire of the same slot can interleave.  (A latch
        // on `freed` in a post-block epilogue would race: the first
        // resumed rank's next `win_acquire` may take the slot and
        // reset it before the other ranks run their epilogue, making
        // them re-file a live window.)
        let (key, r) =
            self.coll_post(comm, CollKind::WinFree, Contrib::RegTime(dt), move |w, cs, _| {
                if cs.pending_arrivals() == 1 {
                    w.windows[win.0].freed = true;
                    let class = exposure_class(&w.windows[win.0]);
                    w.win_pool.put_slot(comm, class, win);
                }
            });
        self.coll_block(key, r);
        self.progress_release();
    }

    /// Local-only pooled release (Wait-Drains path, the pooled analog
    /// of [`MpiProc::win_free_local`]): the closing barrier already
    /// synchronized; the last rank to release files the slot.
    pub fn win_release_local(&self, win: WinId) {
        self.mpi_prologue();
        self.await_reg_done(win);
        let (dt, my_rank) = {
            let w = self.world.lock().unwrap();
            let comm = w.windows[win.0].comm;
            let my_rank = w.comm(comm).rank_of(self.gpid).expect("not in win comm");
            (w.cost.window_release(), my_rank)
        };
        self.ctx.advance(dt);
        let mut w = self.world.lock().unwrap();
        if w.windows[win.0].free_local(my_rank) {
            let comm = w.windows[win.0].comm;
            let class = exposure_class(&w.windows[win.0]);
            w.win_pool.put_slot(comm, class, win);
        }
    }

    /// Pre-register a buffer under `pin` (window-pool path): charges
    /// the registration time *now*, locally, unless the token already
    /// covers `bytes`.  MaM uses this to pin an entry's freshly
    /// received block off the collective critical path
    /// (register-on-receive), so the next resize's `win_acquire` is
    /// warm for every rank.  `cap` bounds this rank's pinned-token
    /// cache (0 = unbounded, LRU eviction otherwise).
    pub fn pin_buffer(&self, pin: u64, bytes: u64, cap: usize) {
        let (dt, evicted) = {
            let mut w = self.world.lock().unwrap();
            if w.win_pool.is_warm(self.gpid, pin, bytes) {
                w.win_pool.touch(self.gpid, pin);
                (0.0, Vec::new())
            } else {
                let dt = w.cost.window_registration(bytes);
                let evicted = w.win_pool.record_pin(self.gpid, pin, bytes, cap);
                w.win_pool.note_pre_pin(dt);
                Self::note_registration(&mut w, bytes, dt);
                (dt, evicted)
            }
        };
        self.spawn_evict_deregs(evicted);
        if dt > 0.0 {
            self.ctx.advance(dt);
        }
    }

    /// Deregister LRU-evicted pins through the teardown pipeline: each
    /// victim's unpin runs as a background `evictdereg-*` engine
    /// activity — starting once the victim's in-flight registration
    /// stream finishes (memory cannot be unpinned while it is still
    /// being pinned), off the evicting rank's critical path, so an
    /// eviction storm overlaps whatever the rank does next (including
    /// the closing barrier) instead of serializing in front of it.
    fn spawn_evict_deregs(&self, victims: Vec<EvictedPin>) {
        for ev in victims {
            let (seq, dereg) = {
                let mut w = self.world.lock().unwrap();
                let dereg = w.cost.window_free(ev.bytes);
                w.win_pool.note_evict_dereg(dereg);
                (w.win_pool.next_evict_seq(), dereg)
            };
            let start = ev.reg_done_at.max(self.ctx.now());
            let gpid = self.gpid;
            self.ctx.spawn(format!("evictdereg-g{gpid}-e{seq}"), move |ctx| {
                ctx.advance_until(start);
                ctx.advance(dereg);
            });
        }
    }

    /// Snapshot of the window pool's warm/cold accounting.
    pub fn win_pool_stats(&self) -> WinPoolStats {
        self.world.lock().unwrap().win_pool.stats()
    }

    /// The installed fault plan, if any (`--faults`; None = the
    /// fault-free fast path, bit-identical to pre-fault builds).
    pub fn fault_plan(&self) -> Option<Arc<crate::simcluster::faults::FaultPlan>> {
        self.world.lock().unwrap().faults.clone()
    }

    /// Poison every rank's window-pool pin of `token` (abort-and-
    /// rollback: a half-registered structure must re-register cold).
    /// Returns the number of pins dropped.
    pub fn win_pool_poison(&self, token: u64) -> u64 {
        self.world.lock().unwrap().win_pool.poison_token(token)
    }

    /// Invalidate the job-level persistent-schedule descriptor `key`
    /// for **every** rank slot (abort-and-rollback): an aborted resize
    /// may have left the negotiated schedule half-built on any subset
    /// of slots, so the next occurrence must cold-build, not replay.
    pub fn sched_invalidate(&self, key: u64) {
        let mut w = self.world.lock().unwrap();
        w.sched_pins.retain(|&(_, k)| k != key);
    }

    /// MPI_Win_free (collective): closing barrier + local deregistration.
    pub fn win_free(&self, win: WinId) {
        self.mpi_prologue();
        self.progress_acquire();
        self.await_reg_done(win);
        let (comm, dereg) = {
            let mut w = self.world.lock().unwrap();
            let ws = &w.windows[win.0];
            let comm = ws.comm;
            let my_rank = w.comm(comm).rank_of(self.gpid).expect("not in win comm");
            let bytes = ws.exposures[my_rank].bytes();
            let dereg = w.cost.window_free(bytes);
            w.windows[win.0].freed_local[my_rank] = true;
            (comm, dereg)
        };
        let (key, r) =
            self.coll_post(comm, CollKind::WinFree, Contrib::RegTime(dereg), |_, _, _| {});
        self.coll_block(key, r);
        {
            let mut w = self.world.lock().unwrap();
            w.windows[win.0].freed = true;
        }
        self.progress_release();
    }

    /// Chunked pipelined `MPI_Win_free` (the teardown half of the
    /// `--rma-chunk` lifecycle pipeline): the closing synchronization
    /// is the same collective as [`MpiProc::win_free`] — mixed
    /// participants match — but this rank's per-byte deregistration
    /// runs as a per-segment background stream (`windereg-*`, the
    /// teardown mirror of `winreg-*`): segment `s` unpins once its own
    /// registration finished and the last read touching it landed, so
    /// on a shrink the retiring sources exit after
    /// `max(T_dereg, T_wire)` instead of `T_wire + T_dereg`.  Ranks
    /// whose exposure is unsegmented (NULL exposures, single-segment
    /// exposures, unchunked windows) delegate to the seed
    /// [`MpiProc::win_free`] path bit-identically.
    pub fn win_free_pipelined(&self, win: WinId) {
        if !self.teardown_segmented(win) {
            return self.win_free(win);
        }
        self.mpi_prologue();
        self.progress_acquire();
        // No up-front await_reg_done: the per-segment eligibility
        // (registration-ready ∨ last-read-done) gates the stream
        // instead — that is exactly what makes the teardown overlap
        // the wire.
        let (comm, segs, fixed) = {
            let mut w = self.world.lock().unwrap();
            let comm = w.windows[win.0].comm;
            let my_rank = w.comm(comm).rank_of(self.gpid).expect("not in win comm");
            let elems = w.windows[win.0].exposures[my_rank].elems();
            let chunk = w.windows[win.0].seg_elems;
            let segs = Self::mt_stretch_segs(&w, self.gpid, segment_deregs(&w.cost, elems, chunk));
            let fixed = w.cost.window_free(0);
            w.windows[win.0].freed_local[my_rank] = true;
            (comm, segs, fixed)
        };
        let (key, r) = self.coll_post(
            comm,
            CollKind::WinFree,
            Contrib::DeregPipeline { segs, fixed },
            move |_, cs, _| {
                // The last arriver needs the window to reconcile the
                // dereg streams (WinFree instances otherwise carry no
                // window id).
                if cs.win_id.is_none() {
                    cs.win_id = Some(win);
                }
            },
        );
        self.coll_block(key, r);
        {
            let mut w = self.world.lock().unwrap();
            w.windows[win.0].freed = true;
        }
        self.progress_release();
    }

    /// Local-only pipelined free (Wait-Drains path, the teardown
    /// mirror of [`MpiProc::win_free_local`]): the confirmation
    /// barrier already synchronized, and this rank's segments have
    /// been deregistering in the background since their last reads
    /// landed — only the stream's residual beyond `now` plus the
    /// fixed teardown is charged.  Unsegmented ranks delegate to the
    /// seed path bit-identically.
    pub fn win_free_local_pipelined(&self, win: WinId) {
        if !self.teardown_segmented(win) {
            return self.win_free_local(win);
        }
        self.mpi_prologue();
        let (end, fixed, my_rank, pts) = {
            let w = self.world.lock().unwrap();
            let comm = w.windows[win.0].comm;
            let my_rank = w.comm(comm).rank_of(self.gpid).expect("not in win comm");
            let elems = w.windows[win.0].exposures[my_rank].elems();
            let chunk = w.windows[win.0].seg_elems;
            let segs = Self::mt_stretch_segs(&w, self.gpid, segment_deregs(&w.cost, elems, chunk));
            let elig = w.windows[win.0].dereg_eligibility(my_rank);
            let done = dereg_stream(&elig, &segs);
            let end = done.last().copied().unwrap_or(0.0);
            (end, w.cost.window_free(0), my_rank, sample_stream(&done))
        };
        if !pts.is_empty() {
            let gpid = self.gpid;
            self.ctx.spawn(format!("windereg-g{gpid}-w{}", win.0), move |ctx| {
                for t in pts {
                    ctx.advance_until(t);
                }
            });
        }
        if end > self.ctx.now() {
            self.ctx.advance_until(end);
        }
        self.ctx.advance(fixed);
        let mut w = self.world.lock().unwrap();
        w.windows[win.0].free_local(my_rank);
    }

    /// MT-stretch of a deregistration stream (Threading, §V-D): while
    /// this process's auxiliary thread is alive the unpin work shares
    /// the oversubscribed core, so every segment's duration stretches
    /// by the same factor [`MpiProc::compute`] applies — the teardown
    /// mirror of the compute stretch.  A no-op (the exact same `Vec`)
    /// without a live aux thread.
    fn mt_stretch_segs(w: &MpiWorld, gpid: usize, mut segs: Vec<f64>) -> Vec<f64> {
        if w.oversubscription && w.procs[gpid].aux_alive {
            let f = w.cost.params.oversub_factor;
            for s in &mut segs {
                *s *= f;
            }
        }
        segs
    }

    /// Precondition of the pipelined teardown: this rank's exposure in
    /// `win` is segmented (more than one segment) and carries a
    /// registration stream whose per-segment ready times gate the
    /// deregistration.  Everything else takes the seed free path.
    fn teardown_segmented(&self, win: WinId) -> bool {
        let w = self.world.lock().unwrap();
        let ws = &w.windows[win.0];
        let comm = ws.comm;
        let my_rank = w.comm(comm).rank_of(self.gpid).expect("not in win comm");
        ws.n_segs(my_rank) > 1 && !ws.seg_ready[my_rank].is_empty()
    }

    /// Local-only window release (Wait-Drains path: the closing
    /// synchronization already happened via MPI_Ibarrier, §IV-C).
    pub fn win_free_local(&self, win: WinId) {
        self.mpi_prologue();
        self.await_reg_done(win);
        let (dereg, my_rank) = {
            let w = self.world.lock().unwrap();
            let comm = w.windows[win.0].comm;
            let my_rank = w.comm(comm).rank_of(self.gpid).expect("not in win comm");
            let bytes = w.windows[win.0].exposures[my_rank].bytes();
            (w.cost.window_free(bytes), my_rank)
        };
        self.ctx.advance(dereg);
        let mut w = self.world.lock().unwrap();
        w.windows[win.0].free_local(my_rank);
    }

    // ------------------------------------------- notified completion

    /// Arm the notified teardown (`--rma-sync notify`) for this rank's
    /// exposure in `win`: the redistribution schedule's sync plan says
    /// exactly `expected` read operations will target it.  Pure
    /// bookkeeping — the expectation rides the schedule descriptor, so
    /// no time is charged here.
    pub fn win_arm_notify(&self, win: WinId, expected: u64) {
        let wake = {
            let mut w = self.world.lock().unwrap();
            let comm = w.windows[win.0].comm;
            let my_rank = w.comm(comm).rank_of(self.gpid).expect("not in win comm");
            w.windows[win.0].arm_notify(my_rank, expected)
        };
        for aid in wake {
            self.ctx.unpark_now(aid);
        }
    }

    /// Nonblocking probe of the notified teardown gate: has this
    /// rank's armed notification count been reached?  (A local flag
    /// read — the NIC delivered the counters with the data, so nothing
    /// is charged.)
    pub fn win_notify_ready(&self, win: WinId) -> bool {
        let w = self.world.lock().unwrap();
        let comm = w.windows[win.0].comm;
        let my_rank = w.comm(comm).rank_of(self.gpid).expect("not in win comm");
        w.windows[win.0].notify_ready(my_rank).is_some()
    }

    /// Park until this rank's armed notification count is reached,
    /// then drain to the last read's completion instant.  The Get/Rget
    /// that satisfies the expectation wakes the parked rank.
    fn notify_wait(&self, win: WinId) {
        loop {
            let state: Option<Time> = {
                let mut w = self.world.lock().unwrap();
                let comm = w.windows[win.0].comm;
                let my_rank = w.comm(comm).rank_of(self.gpid).expect("not in win comm");
                match w.windows[win.0].notify_ready(my_rank) {
                    Some(t) => Some(t),
                    None => {
                        debug_assert!(
                            w.windows[win.0].notify_expected[my_rank].is_some(),
                            "notified free without arming — would park forever"
                        );
                        let aid = self.ctx.id();
                        w.windows[win.0].notify_waiters.push((my_rank, aid));
                        None
                    }
                }
            };
            match state {
                Some(t) => {
                    if t > self.ctx.now() {
                        self.ctx.advance_until(t);
                    }
                    return;
                }
                None => self.ctx.park(),
            }
        }
    }

    /// Notified `MPI_Win_free`: no closing collective at all.  Each
    /// rank waits (locally) until its own exposure's expected
    /// notification count is reached, then deregisters — through the
    /// per-segment teardown stream when the exposure is segmented.
    /// Drain-only ranks (NULL exposures, zero expected reads) free
    /// immediately; sources leave as soon as *their* data has been
    /// drained, not when the slowest rank's has.
    pub fn win_free_notified(&self, win: WinId) {
        self.notify_wait(win);
        if self.teardown_segmented(win) {
            self.win_free_local_pipelined(win)
        } else {
            self.win_free_local(win)
        }
    }

    /// Notified release of a pooled window (the notify analog of
    /// [`MpiProc::win_release_local`]): wait for this rank's expected
    /// notification count, pay the fixed release, and let the last
    /// releasing rank file the slot back into the pool.
    pub fn win_release_notified(&self, win: WinId) {
        self.notify_wait(win);
        self.win_release_local(win)
    }

    /// Charge the origin-side software cost of `n_ops` notified read
    /// operations (`--rma-sync notify`): the per-op counter flag rides
    /// the data packet, replacing the epoch open/close bookkeeping.
    pub fn rma_notify_charge(&self, n_ops: u64) {
        if n_ops == 0 {
            return;
        }
        let dt = {
            let mut w = self.world.lock().unwrap();
            let dt = w.cost.params.notify_overhead * n_ops as f64;
            w.metrics.add_counter("rma.sync_time", dt);
            dt
        };
        self.ctx.advance(dt);
    }

    // ------------------------------------------ persistent schedules

    /// Job-level persistent-schedule cache (mechanism half; policy
    /// lives in `mam::schedcache`).  Looks up the descriptor keyed by
    /// (this rank's slot in `comm`, `key`): a miss charges the cold
    /// build — fixed term plus `targets` per-target computations — and
    /// publishes the descriptor; a hit charges only the validation
    /// handshake.  Returns `true` on a warm replay.
    ///
    /// Keyed by *rank slot*, not process id: a drain respawned into
    /// the same slot on an oscillating trace inherits the schedule its
    /// predecessor negotiated (persistent collectives survive process
    /// churn at the job level).
    pub fn sched_acquire(&self, comm: CommId, key: u64, targets: u64) -> bool {
        let (warm, dt) = {
            let mut w = self.world.lock().unwrap();
            let my_rank = w.comm(comm).rank_of(self.gpid).expect("not in comm");
            let warm = !w.sched_pins.insert((my_rank, key));
            let dt = if warm {
                w.sched_stats.warm_replays += 1;
                w.sched_stats.validate_time += w.cost.params.sched_validate;
                w.cost.params.sched_validate
            } else {
                let dt = w.cost.params.sched_build
                    + w.cost.params.sched_per_target * targets as f64;
                w.sched_stats.cold_builds += 1;
                w.sched_stats.build_time += dt;
                dt
            };
            w.metrics.add_counter("sched.time", dt);
            (warm, dt)
        };
        self.ctx.advance(dt);
        warm
    }

    /// Snapshot of the persistent-schedule cache's accounting.
    pub fn sched_stats(&self) -> super::rma::SchedStats {
        self.world.lock().unwrap().sched_stats
    }

    /// MPI_Win_lock (shared + MPI_MODE_NOCHECK: local bookkeeping only).
    pub fn win_lock(&self, win: WinId, _target: usize) {
        self.mpi_prologue();
        let dt = {
            let mut w = self.world.lock().unwrap();
            assert!(!w.windows[win.0].freed, "lock on freed window");
            let dt = w.cost.params.epoch_cost;
            w.metrics.add_counter("rma.sync_time", dt);
            dt
        };
        self.ctx.advance(dt);
    }

    /// MPI_Win_lock_all (one epoch over all targets; §IV-B Alg. 3).
    pub fn win_lock_all(&self, win: WinId) {
        self.mpi_prologue();
        let dt = {
            let mut w = self.world.lock().unwrap();
            assert!(!w.windows[win.0].freed, "lock_all on freed window");
            // Cheaper than per-target: one local epoch + amortized setup.
            let dt = w.cost.params.epoch_cost * 2.0;
            w.metrics.add_counter("rma.sync_time", dt);
            dt
        };
        self.ctx.advance(dt);
    }

    /// MPI_Get: post a one-sided read of `count` elements at `disp`
    /// from `target`'s exposure, delivered into `dest[dest_off..]`.
    /// Completion is deferred to the closing `win_unlock*`.
    pub fn get(
        &self,
        win: WinId,
        target: usize,
        disp: u64,
        count: u64,
        dest: &RecvBuf,
        dest_off: u64,
    ) {
        self.mpi_prologue();
        let (cpu_done, data, wake) = {
            let mut w = self.world.lock().unwrap();
            let comm = w.windows[win.0].comm;
            let target_gpid = w.comm(comm).gpids[target];
            let bytes = (count * super::types::ELEM_BYTES).max(1);
            let now = self.ctx.now();
            // Pipelined windows: the flow cannot start before the last
            // touched segment of the target's exposure is registered.
            let start = match w.windows[win.0].seg_gate(target, disp, count) {
                Some(g) if g > now => g,
                _ => now,
            };
            let MpiWorld { cost, placement, .. } = &mut *w;
            // One-sided read: data moves target → origin.
            let tt = cost.transfer(
                start,
                placement,
                target_gpid,
                self.gpid,
                bytes,
                TransferClass::Rma,
            );
            // The origin posts the Get now either way: its CPU charge
            // is independent of the target-side registration gate.
            let cpu_done = if start > now { now + (tt.cpu_done - start) } else { tt.cpu_done };
            // MT window (§V-D): passive-target progress crawls under
            // MPICH's contended lock — stretch the completion.
            let arrival = if w.windows[win.0].mt {
                start + (tt.arrival - start) * w.cost.params.mt_rma_penalty
            } else {
                tt.arrival
            };
            let data = w.windows[win.0].read(target, disp, count);
            w.windows[win.0].track_get(self.gpid, target, arrival);
            // Pipelined teardown bookkeeping: the target segment may
            // deregister once this (and every other) read has landed.
            w.windows[win.0].note_read(target, disp, count, arrival);
            // Notified completion: count the read against the target's
            // notification record and collect any parked notified
            // teardowns this read satisfies (no-op under epoch sync).
            let wake = w.windows[win.0].note_notify(target, arrival);
            (cpu_done, data, wake)
        };
        for aid in wake {
            self.ctx.unpark_now(aid);
        }
        // Deliver data now (window exposures are constant during the
        // epoch); virtual-time completion is enforced by unlock.
        if let Some(src) = data {
            let mut guard = dest.lock().unwrap();
            if let Some(buf) = guard.as_mut() {
                let off = dest_off as usize;
                buf[off..off + src.len()].copy_from_slice(&src);
            }
        }
        self.ctx.advance_until(cpu_done);
    }

    /// MPI_Rget: like [`MpiProc::get`] but returns a request that can
    /// be tested/waited independently (the Wait-Drains building block,
    /// §IV-C).
    pub fn rget(
        &self,
        win: WinId,
        target: usize,
        disp: u64,
        count: u64,
        dest: &RecvBuf,
        dest_off: u64,
    ) -> ReqId {
        self.mpi_prologue();
        let (cpu_done, rid, wake) = {
            let mut w = self.world.lock().unwrap();
            let comm = w.windows[win.0].comm;
            let target_gpid = w.comm(comm).gpids[target];
            let bytes = (count * super::types::ELEM_BYTES).max(1);
            let now = self.ctx.now();
            // Pipelined windows: gate on the target segment's
            // registration stream, as in `get`.
            let start = match w.windows[win.0].seg_gate(target, disp, count) {
                Some(g) if g > now => g,
                _ => now,
            };
            let MpiWorld { cost, placement, .. } = &mut *w;
            let tt = cost.transfer(
                start,
                placement,
                target_gpid,
                self.gpid,
                bytes,
                TransferClass::Rma,
            );
            let cpu_done = if start > now { now + (tt.cpu_done - start) } else { tt.cpu_done };
            // MT window (§V-D): stretched completion, as in `get`.
            let complete_at = if w.windows[win.0].mt {
                start + (tt.arrival - start) * w.cost.params.mt_rma_penalty
            } else {
                tt.arrival
            };
            let data = w.windows[win.0].read(target, disp, count);
            // Pipelined teardown bookkeeping (as in `get`).
            w.windows[win.0].note_read(target, disp, count, complete_at);
            // Notified completion bookkeeping (as in `get`).
            let wake = w.windows[win.0].note_notify(target, complete_at);
            let rid = w.requests.len();
            w.requests.push(ReqState::new(
                self.gpid,
                ReqBody::Rget {
                    win,
                    complete_at,
                    data,
                    dest: dest.clone(),
                    dest_off,
                    applied: false,
                },
            ));
            (cpu_done, rid, wake)
        };
        for aid in wake {
            self.ctx.unpark_now(aid);
        }
        self.ctx.advance_until(cpu_done);
        ReqId(rid)
    }

    /// MPI_Win_unlock: blocks until this origin's pending Gets to
    /// `target` have landed, then closes the epoch.
    pub fn win_unlock(&self, win: WinId, target: usize) {
        self.mpi_prologue();
        self.progress_acquire();
        let (flush_t, epoch) = {
            let mut w = self.world.lock().unwrap();
            let t = w.windows[win.0].flush_target(self.gpid, target);
            let epoch = w.cost.params.epoch_cost;
            w.metrics.add_counter("rma.sync_time", epoch);
            (t, epoch)
        };
        if let Some(t) = flush_t {
            self.ctx.advance_until(t);
        }
        self.ctx.advance(epoch);
        self.progress_release();
    }

    /// MPI_Win_unlock_all.
    pub fn win_unlock_all(&self, win: WinId) {
        self.mpi_prologue();
        self.progress_acquire();
        let (flush_t, epoch) = {
            let mut w = self.world.lock().unwrap();
            let t = w.windows[win.0].flush_all(self.gpid);
            let epoch = w.cost.params.epoch_cost;
            w.metrics.add_counter("rma.sync_time", epoch);
            (t, epoch)
        };
        if let Some(t) = flush_t {
            self.ctx.advance_until(t);
        }
        self.ctx.advance(epoch);
        self.progress_release();
    }

    /// Exposed size of `target`'s window slice (drain-side Algorithm 1
    /// needs the source ranges; MaM queries them through the registry,
    /// but tests use this).
    pub fn win_exposed_elems(&self, win: WinId, target: usize) -> u64 {
        let w = self.world.lock().unwrap();
        w.windows[win.0].exposures[target].elems()
    }

    // -------------------------------------------- process management

    /// MaM's Merge (grow) with the legacy single-constant timing: all
    /// sources blocked for `spawn_dur`, spawned ranks up atomically.
    /// Delegates to [`MpiProc::spawn_merge_scheduled`] with an atomic
    /// schedule — the seed/paper behaviour, bit for bit.
    pub fn spawn_merge(
        &self,
        comm: CommId,
        n_new: usize,
        spawn_dur: f64,
        body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync>,
    ) -> CommId {
        self.spawn_merge_scheduled(comm, n_new, &SpawnSchedule::atomic(spawn_dur), body)
    }

    /// MaM's Merge (grow): collective over `comm`; spawns `n_new`
    /// processes running `body(proc, merged_comm)` and returns the
    /// merged communicator (members of `comm` first, spawned after —
    /// the intracomm produced by MPI_Comm_spawn + MPI_Intercomm_merge).
    ///
    /// `sched` controls the virtual-time shape of the phase.  Under the
    /// atomic (legacy) schedule every source is blocked for the same
    /// constant and children start when the sources resume.  Under a
    /// staggered schedule (parallel/async spawning) the spawn root
    /// resumes after `sched.initiate`, creates each spawned rank as a
    /// real engine activity that begins at its own `child_up` offset,
    /// then rejoins the other sources at `sched.source_block`.
    pub fn spawn_merge_scheduled(
        &self,
        comm: CommId,
        n_new: usize,
        sched: &SpawnSchedule,
        body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync>,
    ) -> CommId {
        self.mpi_prologue();
        self.progress_acquire();
        let contrib = if self.rank(comm) == 0 {
            Contrib::SpawnTime { initiate: sched.initiate, block: sched.source_block }
        } else {
            Contrib::None
        };
        let (key, r) = self.coll_post(comm, CollKind::Spawn, contrib, |_, _, _| {});
        self.coll_block(key, r);
        // The root creates the processes and the merged communicator.
        if r == 0 {
            // Entry-synchronization instant the child offsets are
            // relative to (the root resumed `initiate` past it).
            let base = self.ctx.now() - sched.initiate;
            let spawn_list: Vec<(usize, CommId)> = {
                let mut w = self.world.lock().unwrap();
                let old = w.comm(comm).gpids.clone();
                let new_gpids: Vec<usize> = (0..n_new).map(|_| w.create_proc()).collect();
                let mut merged = old;
                merged.extend(&new_gpids);
                let mc = w.create_comm(merged);
                w.derived_comms.insert(key, mc);
                let waiters = w.derived_waiters.remove(&key).unwrap_or_default();
                drop(w);
                for aid in waiters {
                    self.ctx.unpark_now(aid);
                }
                new_gpids.into_iter().map(|g| (g, mc)).collect()
            };
            for (idx, (gpid, mc)) in spawn_list.into_iter().enumerate() {
                let world = self.world.clone();
                let b = body.clone();
                let up = sched.child_up.get(idx).map(|off| base + off);
                self.ctx.spawn(format!("spawned-g{gpid}"), move |ctx| {
                    let proc = MpiProc::main(ctx, world, gpid);
                    if let Some(t) = up {
                        // Staggered startup: the rank exists but is
                        // still launching until its wave completes.
                        proc.ctx.advance_until(t);
                    }
                    b(proc.clone_handle(), mc);
                    proc.on_exit();
                });
            }
            // Staggered schedules release the root early so the child
            // activities can start at past-relative offsets; the root
            // itself still observes the full blocking duration.
            if sched.source_block > sched.initiate {
                self.ctx.advance_until(base + sched.source_block);
            }
        }
        let mc = self.wait_derived(key);
        self.progress_release();
        mc
    }

    /// Sub-communicator of the first `keep` ranks (MaM's Merge-shrink).
    /// Collective over `comm`; every caller gets the new CommId, even
    /// ranks that are not members of it.
    pub fn comm_sub(&self, comm: CommId, keep: usize) -> CommId {
        self.mpi_prologue();
        self.progress_acquire();
        let (key, r) = self.coll_post(comm, CollKind::CommSub, Contrib::None, move |w, cs, _| {
            // First arriver materializes the communicator (metadata
            // only); the id rides in the instance's spare slot.
            if cs.win_id.is_none() {
                let sub: Vec<usize> = w.comm(comm).gpids[..keep].to_vec();
                let sc = w.create_comm(sub);
                cs.win_id = Some(WinId(sc.0));
            }
        });
        // Read before blocking: the instance may be GC'd after takes.
        let sc = {
            let w = self.world.lock().unwrap();
            CommId(w.colls.get(&key).and_then(|c| c.win_id).expect("sub comm id").0)
        };
        self.coll_block(key, r);
        self.progress_release();
        sc
    }

    fn wait_derived(&self, key: (CommId, u64)) -> CommId {
        loop {
            let found = {
                let mut w = self.world.lock().unwrap();
                match w.derived_comms.get(&key) {
                    Some(c) => Some(*c),
                    None => {
                        w.derived_waiters.entry(key).or_default().push(self.ctx.id());
                        None
                    }
                }
            };
            match found {
                Some(c) => return c,
                None => self.ctx.park(),
            }
        }
    }

    /// Process exit for ranks removed by a shrink: retire and return.
    /// (The body should return right after calling this.)
    pub fn finalize(&self) {
        // on_exit is called by the launcher wrapper; nothing extra here.
    }

    // ----------------------------------------------- auxiliary thread

    /// Spawn this process's auxiliary redistribution thread (Threading
    /// strategy, §IV-C.1).  At most one at a time.
    pub fn spawn_aux<F>(&self, body: F)
    where
        F: FnOnce(MpiProc) + Send + 'static,
    {
        assert!(!self.is_aux, "aux thread cannot spawn aux threads");
        {
            let mut w = self.world.lock().unwrap();
            let p = &mut w.procs[self.gpid];
            assert!(!p.aux_alive, "aux thread already running");
            p.aux_alive = true;
        }
        let world = self.world.clone();
        let gpid = self.gpid;
        self.ctx.spawn(format!("aux-g{gpid}"), move |ctx| {
            let proc = MpiProc { ctx, world: world.clone(), gpid, is_aux: true };
            body(proc.clone_handle());
            let waiters = {
                let mut w = world.lock().unwrap();
                let p = &mut w.procs[gpid];
                p.aux_alive = false;
                // Release the engine if the aux died mid-operation.
                p.aux_busy = 0;
                let mut ws = std::mem::take(&mut p.aux_waiters);
                ws.extend(std::mem::take(&mut p.progress_waiters));
                ws
            };
            for aid in waiters {
                proc.ctx.unpark_now(aid);
            }
        });
    }

    /// Is this process's auxiliary thread still running?
    pub fn aux_alive(&self) -> bool {
        self.world.lock().unwrap().procs[self.gpid].aux_alive
    }

    /// Block until the auxiliary thread finishes.
    pub fn aux_join(&self) {
        loop {
            {
                let mut w = self.world.lock().unwrap();
                let p = &mut w.procs[self.gpid];
                if !p.aux_alive {
                    return;
                }
                p.aux_waiters.push(self.ctx.id());
            }
            self.ctx.park();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netmodel::{NetParams, Topology};
    use crate::simmpi::types::{recv_buf_real, recv_buf_virtual};
    use crate::simmpi::world::{MpiSim, WORLD};
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn sim(n_nodes: usize, cores: usize) -> MpiSim {
        MpiSim::new(Topology::new(n_nodes, cores), NetParams::test_simple())
    }

    #[test]
    fn send_recv_roundtrip_real_data() {
        let mut s = sim(2, 2);
        s.launch(2, |p| {
            if p.rank(WORLD) == 0 {
                p.send(WORLD, 1, 7, Payload::real(vec![1.0, 2.0, 3.0]));
            } else {
                let m = p.recv(WORLD, Some(0), 7);
                assert_eq!(m.as_slice().unwrap(), &[1.0, 2.0, 3.0]);
                assert!(p.now() > 0.0, "recv must take time");
            }
        });
        s.run().unwrap();
    }

    #[test]
    fn recv_blocks_until_send() {
        let mut s = sim(1, 2);
        s.launch(2, |p| {
            if p.rank(WORLD) == 0 {
                p.compute(5.0);
                p.send(WORLD, 1, 0, Payload::virt(10));
            } else {
                let _ = p.recv(WORLD, Some(0), 0);
                assert!(p.now() >= 5.0, "recv returned at {}", p.now());
            }
        });
        s.run().unwrap();
    }

    #[test]
    fn tag_matching_is_selective() {
        let mut s = sim(1, 2);
        s.launch(2, |p| {
            if p.rank(WORLD) == 0 {
                p.send(WORLD, 1, 1, Payload::real(vec![1.0]));
                p.send(WORLD, 1, 2, Payload::real(vec![2.0]));
            } else {
                // Receive in reverse tag order.
                let b = p.recv(WORLD, Some(0), 2);
                let a = p.recv(WORLD, Some(0), 1);
                assert_eq!(b.as_slice().unwrap(), &[2.0]);
                assert_eq!(a.as_slice().unwrap(), &[1.0]);
            }
        });
        s.run().unwrap();
    }

    #[test]
    fn barrier_synchronizes_ranks() {
        let mut s = sim(2, 4);
        s.launch(6, |p| {
            let r = p.rank(WORLD);
            p.compute(r as f64); // staggered arrivals 0..5 s
            p.barrier(WORLD);
            assert!(p.now() >= 5.0, "rank {r} left barrier at {}", p.now());
        });
        s.run().unwrap();
    }

    #[test]
    fn allgather_returns_all_blocks() {
        let mut s = sim(1, 4);
        s.launch(4, |p| {
            let r = p.rank(WORLD);
            let got = p.allgather(WORLD, Payload::real(vec![r as f64]));
            let vals: Vec<f64> = got.iter().map(|b| b.as_slice().unwrap()[0]).collect();
            assert_eq!(vals, vec![0.0, 1.0, 2.0, 3.0]);
        });
        s.run().unwrap();
    }

    #[test]
    fn alltoallv_routes_data() {
        let mut s = sim(2, 2);
        s.launch(3, |p| {
            let r = p.rank(WORLD) as f64;
            // rank r sends value 10r+j to rank j.
            let sends = (0..3)
                .map(|j| Payload::real(vec![10.0 * r + j as f64]))
                .collect();
            let recv = p.alltoallv(WORLD, sends);
            let vals: Vec<f64> = recv.iter().map(|b| b.as_slice().unwrap()[0]).collect();
            // from rank i we get 10i + r.
            assert_eq!(vals, vec![r, 10.0 + r, 20.0 + r]);
        });
        s.run().unwrap();
    }

    #[test]
    fn ibarrier_test_then_complete() {
        let mut s = sim(1, 2);
        s.launch(2, |p| {
            if p.rank(WORLD) == 0 {
                let req = p.ibarrier(WORLD);
                // Other rank arrives at t=2; not complete right away.
                assert!(!p.req_test(req));
                p.req_wait(req);
                assert!(p.now() >= 2.0);
            } else {
                p.compute(2.0);
                let req = p.ibarrier(WORLD);
                p.req_wait(req);
            }
        });
        s.run().unwrap();
    }

    #[test]
    fn ialltoallv_progress_requires_mpi_calls() {
        let mut s = sim(2, 2);
        let w = s.world();
        s.launch(2, |p| {
            let r = p.rank(WORLD);
            let sends = vec![
                Payload::virt(if r == 0 { 0 } else { 4_000_000 }),
                Payload::virt(if r == 0 { 4_000_000 } else { 0 }),
            ];
            let req = p.ialltoallv(WORLD, sends);
            let mut tests = 0;
            while !p.req_test(req) {
                tests += 1;
                p.compute(0.01);
                assert!(tests < 1000, "never completed");
            }
            // 4 M elems * 8 B * 2 (pack+unpack) at 1 MiB/chunk → many calls.
            assert!(tests > 10, "completed too fast: {tests} tests");
            let _ = p.req_result_alltoallv(req);
        });
        s.run().unwrap();
        let w = w.lock().unwrap();
        assert_eq!(w.live_procs(), 0);
    }

    #[test]
    fn win_create_get_unlock_roundtrip() {
        let mut s = sim(2, 2);
        s.launch(2, |p| {
            let r = p.rank(WORLD);
            let expose = if r == 0 {
                Payload::real(vec![5.0, 6.0, 7.0, 8.0])
            } else {
                Payload::virt(0)
            };
            let win = p.win_create_with(WORLD, expose, WinCreateOpts::blocking());
            if r == 1 {
                let dest = recv_buf_real(2);
                p.win_lock(win, 0);
                p.get(win, 0, 1, 2, &dest, 0);
                p.win_unlock(win, 0);
                assert_eq!(dest.lock().unwrap().as_ref().unwrap(), &vec![6.0, 7.0]);
            }
            p.win_free(win);
        });
        s.run().unwrap();
    }

    #[test]
    fn win_create_cost_scales_with_exposure() {
        fn run(elems: u64) -> f64 {
            let mut s = sim(2, 2);
            let w = s.world();
            s.launch(2, move |p| {
                let r = p.rank(WORLD);
                let expose = if r == 0 { Payload::virt(elems) } else { Payload::virt(0) };
                let win = p.win_create_with(WORLD, expose, WinCreateOpts::blocking());
                if r == 0 {
                    p.metrics(|m| m.mark("created", 0.0));
                }
                let t = p.now();
                p.metrics(|m| m.mark("win_done", t));
                p.win_free(win);
            });
            s.run().unwrap();
            let t = w.lock().unwrap().metrics.mark_at("win_done").unwrap();
            t
        }
        let small = run(1);
        let big = run(100_000_000);
        // 100M elems * 8 B at 1 GB/s registration = 0.8 s extra.
        assert!(big > small + 0.5, "big={big} small={small}");
    }

    #[test]
    fn rget_testall_completes() {
        let mut s = sim(2, 2);
        s.launch(2, |p| {
            let r = p.rank(WORLD);
            let expose = if r == 0 {
                Payload::real((0..100).map(|i| i as f64).collect())
            } else {
                Payload::virt(0)
            };
            let win = p.win_create_with(WORLD, expose, WinCreateOpts::blocking());
            if r == 1 {
                let dest = recv_buf_real(100);
                p.win_lock_all(win);
                let q1 = p.rget(win, 0, 0, 50, &dest, 0);
                let q2 = p.rget(win, 0, 50, 50, &dest, 50);
                while !p.req_testall(&[q1, q2]) {
                    p.compute(0.001);
                }
                p.win_unlock_all(win);
                let d = dest.lock().unwrap();
                let buf = d.as_ref().unwrap();
                assert_eq!(buf[0], 0.0);
                assert_eq!(buf[99], 99.0);
            }
            p.win_free(win);
        });
        s.run().unwrap();
    }

    #[test]
    fn win_acquire_roundtrips_data_like_win_create() {
        let mut s = sim(2, 2);
        s.launch(2, |p| {
            let r = p.rank(WORLD);
            let expose = if r == 0 {
                Payload::real(vec![5.0, 6.0, 7.0, 8.0])
            } else {
                Payload::virt(0)
            };
            let win = p.win_acquire(WORLD, expose, 0xA);
            if r == 1 {
                let dest = recv_buf_real(2);
                p.win_lock(win, 0);
                p.get(win, 0, 1, 2, &dest, 0);
                p.win_unlock(win, 0);
                assert_eq!(dest.lock().unwrap().as_ref().unwrap(), &vec![6.0, 7.0]);
            }
            p.win_release(win);
        });
        s.run().unwrap();
    }

    #[test]
    fn warm_reacquire_skips_registration_time() {
        // Same exposure, same pin token: the second acquire must reuse
        // the released slot and charge no per-byte registration.
        let mut s = sim(2, 2);
        let w = s.world();
        s.launch(2, |p| {
            let elems = 100_000_000u64; // 0.8 s of registration at 1 GB/s
            let r = p.rank(WORLD);
            let expose = || if r == 0 { Payload::virt(elems) } else { Payload::virt(0) };
            let t0 = p.now();
            let w1 = p.win_acquire(WORLD, expose(), 0xA);
            let cold_dt = p.now() - t0;
            p.win_release(w1);
            let t1 = p.now();
            let w2 = p.win_acquire(WORLD, expose(), 0xA);
            let warm_dt = p.now() - t1;
            assert_eq!(w1, w2, "released slot must be reused");
            assert!(
                warm_dt < cold_dt / 10.0,
                "warm acquire not cheap: cold={cold_dt} warm={warm_dt}"
            );
            p.win_release(w2);
        });
        s.run().unwrap();
        let w = w.lock().unwrap();
        let st = w.win_pool_stats();
        // Rank 0's first exposure is the only cold one — rank 1 exposes
        // NULL (always warm), and the re-acquires ride the pin cache.
        assert_eq!(st.cold_acquires, 1);
        assert_eq!(st.warm_acquires, 3);
        assert_eq!(st.slot_reuses, 1);
        assert_eq!(st.releases, 2);
        assert!(st.warm_reg_saved > 0.5, "saved {}", st.warm_reg_saved);
    }

    #[test]
    fn pin_tokens_and_comms_are_isolated() {
        // A different pin token stays cold even after a release, and a
        // slot released on one communicator is invisible to another.
        let mut s = sim(1, 4);
        let w = s.world();
        s.launch(2, |p| {
            let win = p.win_acquire(WORLD, Payload::virt(1000), 0xA);
            p.win_release(win);
            // Different token: cold again (different buffer).
            let win2 = p.win_acquire(WORLD, Payload::virt(1000), 0xB);
            p.win_release(win2);
            // Different communicator: the pooled slot must not cross.
            let sub = p.comm_sub(WORLD, 2);
            let win3 = p.win_acquire(sub, Payload::virt(1000), 0xC);
            assert_ne!(win3, win, "slot leaked across communicators");
            p.win_release(win3);
        });
        s.run().unwrap();
        let w = w.lock().unwrap();
        assert_eq!(w.win_pool_stats().warm_acquires, 0);
        assert_eq!(w.win_pool_stats().cold_acquires, 6);
    }

    #[test]
    fn release_local_files_slot_once_all_ranks_released() {
        let mut s = sim(1, 4);
        let w = s.world();
        s.launch(3, |p| {
            let win = p.win_acquire(WORLD, Payload::virt(64), 0x1);
            p.barrier(WORLD);
            p.win_release_local(win);
            p.barrier(WORLD);
            // Reacquire must find the slot filed by the last releaser.
            let win2 = p.win_acquire(WORLD, Payload::virt(64), 0x1);
            assert_eq!(win, win2);
            p.win_release(win2);
        });
        s.run().unwrap();
        assert_eq!(w.lock().unwrap().win_pool_stats().slot_reuses, 1);
    }

    #[test]
    fn retirement_drops_pins() {
        // After a rank's process exits, a new process on the same gpid
        // index cannot inherit its warmth (fresh memory).
        let mut s = sim(1, 2);
        let w = s.world();
        s.launch(1, |p| {
            let win = p.win_acquire(WORLD, Payload::virt(512), 0x9);
            p.win_release(win);
        });
        s.run().unwrap();
        let w = w.lock().unwrap();
        assert!(!w.win_pool.is_warm(0, 0x9, 512), "pins must die with the process");
    }

    #[test]
    fn spawn_merge_grows_comm() {
        let mut s = sim(2, 4);
        let spawned = Arc::new(AtomicUsize::new(0));
        let sp = spawned.clone();
        s.launch(2, move |p| {
            let sp2 = sp.clone();
            let body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
                Arc::new(move |child: MpiProc, mc: CommId| {
                    sp2.fetch_add(1, Ordering::SeqCst);
                    assert_eq!(child.size(mc), 4);
                    assert!(child.rank(mc) >= 2, "spawned ranks come after sources");
                    child.barrier(mc);
                });
            let mc = p.spawn_merge(WORLD, 2, 0.5, body);
            assert_eq!(p.size(mc), 4);
            assert_eq!(p.rank(mc), p.rank(WORLD));
            assert!(p.now() >= 0.5, "spawn cost not charged");
            p.barrier(mc);
        });
        s.run().unwrap();
        assert_eq!(spawned.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn sequential_spawn_is_bit_identical_to_the_legacy_constant() {
        // The PR-1 model: Spawn completion[r] = dissemination-sync[r] +
        // spawn_cost.  A Barrier uses the *same* dissemination schedule
        // over the same cost-model state, so with staggered arrivals
        // the spawn must exit exactly `spawn_cost` later than the
        // barrier exits — bit for bit, per rank.
        const COST: f64 = 0.37;
        fn exit_times(spawn: bool) -> Vec<f64> {
            let mut s = sim(2, 4);
            let out: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(vec![0.0; 3]));
            let o2 = out.clone();
            s.launch(3, move |p| {
                let r = p.rank(WORLD);
                p.compute(r as f64 * 0.01); // staggered arrivals
                if spawn {
                    let body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
                        Arc::new(|_, _| {});
                    let _ = p.spawn_merge(WORLD, 2, COST, body);
                } else {
                    p.barrier(WORLD);
                }
                o2.lock().unwrap()[r] = p.now();
            });
            s.run().unwrap();
            let v = out.lock().unwrap().clone();
            v
        }
        let spawned = exit_times(true);
        let barrier = exit_times(false);
        for r in 0..3 {
            assert_eq!(
                spawned[r].to_bits(),
                (barrier[r] + COST).to_bits(),
                "rank {r}: spawn exit {} != barrier exit {} + {COST}",
                spawned[r],
                barrier[r]
            );
        }
    }

    #[test]
    fn staggered_spawn_brings_children_up_in_waves() {
        let mut s = sim(2, 4);
        let ups: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));
        let u2 = ups.clone();
        let source_done = Arc::new(Mutex::new(0.0f64));
        let sd = source_done.clone();
        s.launch(1, move |p| {
            let u3 = u2.clone();
            let body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
                Arc::new(move |child: MpiProc, mc: CommId| {
                    u3.lock().unwrap().push(child.now());
                    child.barrier(mc);
                });
            let sched = SpawnSchedule {
                initiate: 0.1,
                source_block: 0.5,
                child_up: vec![0.2, 0.3, 0.4],
            };
            let mc = p.spawn_merge_scheduled(WORLD, 3, &sched, body);
            *sd.lock().unwrap() = p.now();
            p.barrier(mc);
        });
        s.run().unwrap();
        let mut ups = ups.lock().unwrap().clone();
        ups.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(ups.len(), 3);
        // Children come up staggered (0.1 s apart), not atomically.
        assert!((ups[1] - ups[0] - 0.1).abs() < 1e-9, "{ups:?}");
        assert!((ups[2] - ups[1] - 0.1).abs() < 1e-9, "{ups:?}");
        // All of them before the source resumes at +0.5.
        let done = *source_done.lock().unwrap();
        assert!(ups[2] < done, "last child {} vs source {}", ups[2], done);
        assert!((done - ups[0] - 0.3).abs() < 1e-9, "{done} vs {ups:?}");
    }

    #[test]
    fn async_schedule_releases_sources_before_children_are_up() {
        let mut s = sim(1, 4);
        let child_up: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));
        let c2 = child_up.clone();
        s.launch(2, move |p| {
            let c3 = c2.clone();
            let body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
                Arc::new(move |child: MpiProc, mc: CommId| {
                    c3.lock().unwrap().push(child.now());
                    child.barrier(mc);
                });
            let sched = SpawnSchedule {
                initiate: 0.05,
                source_block: 0.05,
                child_up: vec![0.25],
            };
            let mc = p.spawn_merge_scheduled(WORLD, 1, &sched, body);
            let resumed = p.now();
            p.barrier(mc); // synchronizes with the late-arriving child
            assert!(
                p.now() - resumed > 0.15,
                "barrier must wait for the child: resumed {resumed}, now {}",
                p.now()
            );
        });
        s.run().unwrap();
        assert_eq!(child_up.lock().unwrap().len(), 1);
    }

    #[test]
    fn capped_acquire_evicts_and_recolds() {
        let mut s = sim(1, 2);
        let w = s.world();
        s.launch(1, |p| {
            // Cap 2: pinning a third token evicts the least recent.
            for token in [0xA, 0xB, 0xC] {
                let win = p.win_acquire_capped(WORLD, Payload::virt(1000), token, 2);
                p.win_release(win);
            }
            // 0xA was evicted: cold again.  0xC is still warm.
            let win = p.win_acquire_capped(WORLD, Payload::virt(1000), 0xC, 2);
            p.win_release(win);
            let win = p.win_acquire_capped(WORLD, Payload::virt(1000), 0xA, 2);
            p.win_release(win);
        });
        s.run().unwrap();
        let w = w.lock().unwrap();
        let st = w.win_pool_stats();
        // Cold: initial 0xA/0xB/0xC, then re-pin of evicted 0xA.
        assert_eq!(st.cold_acquires, 4, "{st:?}");
        assert_eq!(st.warm_acquires, 1, "{st:?}");
        assert!(st.evictions >= 1, "{st:?}");
        assert!(st.evict_dereg_time > 0.0, "evictions must charge dereg: {st:?}");
    }

    /// Shared body: rank 0 exposes `elems`, rank 1 reads everything in
    /// `chunk`-sized Gets (same read pattern for the blocking control,
    /// so only the window path differs); returns the final sim time.
    fn pipelined_read_all(elems: u64, chunk: u64) -> f64 {
        let mut s = sim(2, 1); // one rank per node: inter-node wire
        s.launch(2, move |p| {
            let r = p.rank(WORLD);
            let expose = if r == 0 { Payload::virt(elems) } else { Payload::virt(0) };
            let win = p.win_create_with(WORLD, expose, WinCreateOpts::pipelined(chunk));
            if r == 1 {
                let dest = recv_buf_virtual();
                let step = if chunk == 0 { 1_000_000 } else { chunk };
                p.win_lock_all(win);
                let mut off = 0u64;
                while off < elems {
                    let take = (elems - off).min(step);
                    p.get(win, 0, off, take, &dest, 0);
                    off += take;
                }
                p.win_unlock_all(win);
            }
            p.win_free(win);
        });
        s.run().unwrap()
    }

    #[test]
    fn pipelined_create_hides_registration_behind_the_wire() {
        // 100M elems = 0.8 GB: registration 0.8 s at 1 GB/s, wire 0.8 s
        // at 1 GB/s.  Blocking pays reg + wire serially; pipelined pays
        // fill + max(reg, wire) — a large, structural gap.
        let elems = 100_000_000u64;
        let blocking = pipelined_read_all(elems, 0);
        let chunked = pipelined_read_all(elems, 1_000_000);
        assert!(
            chunked < blocking * 0.75,
            "pipelining did not hide registration: chunked={chunked} blocking={blocking}"
        );
        // Correct lower bound: the wire still has to move every byte.
        assert!(chunked > 0.5, "chunked={chunked} implausibly fast");
    }

    #[test]
    fn pipelined_runs_are_bit_deterministic() {
        let a = pipelined_read_all(4_000_000, 500_000);
        let b = pipelined_read_all(4_000_000, 500_000);
        assert_eq!(a.to_bits(), b.to_bits());
    }

    #[test]
    fn pipelined_chunk_zero_is_the_plain_create() {
        // chunk = 0 (and single-segment exposures) must route through
        // the seed win_create bit-identically.
        fn plain(elems: u64) -> f64 {
            let mut s = sim(2, 1);
            s.launch(2, move |p| {
                let r = p.rank(WORLD);
                let expose = if r == 0 { Payload::virt(elems) } else { Payload::virt(0) };
                let win = p.win_create_with(WORLD, expose, WinCreateOpts::blocking());
                p.win_free(win);
            });
            s.run().unwrap()
        }
        fn piped(elems: u64, chunk: u64) -> f64 {
            let mut s = sim(2, 1);
            s.launch(2, move |p| {
                let r = p.rank(WORLD);
                let expose = if r == 0 { Payload::virt(elems) } else { Payload::virt(0) };
                let win = p.win_create_with(WORLD, expose, WinCreateOpts::pipelined(chunk));
                p.win_free(win);
            });
            s.run().unwrap()
        }
        assert_eq!(plain(1_000_000).to_bits(), piped(1_000_000, 0).to_bits());
        // Exposure fits one segment: also the plain path.
        assert_eq!(plain(1_000).to_bits(), piped(1_000, 2_000).to_bits());
    }

    #[test]
    fn pipelined_create_roundtrips_data() {
        let n = 1000u64;
        let mut s = sim(2, 2);
        s.launch(2, move |p| {
            let r = p.rank(WORLD);
            let expose = if r == 0 {
                Payload::real((0..n).map(|i| i as f64 * 0.5).collect())
            } else {
                Payload::real(Vec::new())
            };
            let win = p.win_create_with(WORLD, expose, WinCreateOpts::pipelined(64));
            if r == 1 {
                let dest = recv_buf_real(n as usize);
                p.win_lock_all(win);
                let mut off = 0u64;
                while off < n {
                    let take = (n - off).min(64);
                    p.get(win, 0, off, take, &dest, off);
                    off += take;
                }
                p.win_unlock_all(win);
                let d = dest.lock().unwrap();
                let buf = d.as_ref().unwrap();
                for (i, v) in buf.iter().enumerate() {
                    assert_eq!(*v, i as f64 * 0.5, "element {i}");
                }
            }
            p.win_free(win);
        });
        s.run().unwrap();
    }

    #[test]
    fn pipelined_free_waits_for_background_registration() {
        // Nobody reads the exposure: the free must still wait for the
        // background stream (memory cannot be unpinned mid-pinning).
        let elems = 100_000_000u64; // 0.8 s of registration
        let mut s = sim(1, 1);
        s.launch(1, move |p| {
            let win = p.win_create_with(WORLD, Payload::virt(elems), WinCreateOpts::pipelined(1_000_000));
            // The create itself exits after the fill only.
            assert!(p.now() < 0.1, "create blocked on the full stream: {}", p.now());
            p.win_free(win);
            assert!(p.now() >= 0.79, "free did not wait for registration: {}", p.now());
        });
        s.run().unwrap();
    }

    /// Shared body of the teardown tests: rank 0 exposes `elems`
    /// chunked, rank 1 reads everything per segment, both free —
    /// through the pipelined teardown or the seed blocking one.
    fn lifecycle_end(elems: u64, chunk: u64, dereg_pipeline: bool) -> f64 {
        let mut s = sim(2, 1); // one rank per node: inter-node wire
        s.launch(2, move |p| {
            let r = p.rank(WORLD);
            let expose = if r == 0 { Payload::virt(elems) } else { Payload::virt(0) };
            let win = p.win_create_with(WORLD, expose, WinCreateOpts::pipelined(chunk));
            if r == 1 {
                let dest = recv_buf_virtual();
                p.win_lock_all(win);
                let mut off = 0u64;
                while off < elems {
                    let take = (elems - off).min(chunk);
                    p.get(win, 0, off, take, &dest, 0);
                    off += take;
                }
                p.win_unlock_all(win);
            }
            if dereg_pipeline {
                p.win_free_pipelined(win);
            } else {
                p.win_free(win);
            }
        });
        s.run().unwrap()
    }

    #[test]
    fn pipelined_free_hides_dereg_behind_the_wire() {
        // 100M elems = 0.8 GB: wire 0.8 s, dereg 0.8/3 ≈ 0.27 s.  The
        // blocking free serializes the dereg after the last read; the
        // pipelined free deregisters each segment as its last read
        // lands, leaving only the final segment's residual.
        let elems = 100_000_000u64;
        let blocking = lifecycle_end(elems, 1_000_000, false);
        let piped = lifecycle_end(elems, 1_000_000, true);
        assert!(
            piped < blocking - 0.2,
            "pipelined teardown saved too little: piped={piped} blocking={blocking}"
        );
        // The wire still has to move every byte.
        assert!(piped > 0.7, "piped={piped} implausibly fast");
    }

    #[test]
    fn pipelined_free_is_deterministic_and_unsegmented_ranks_delegate() {
        let a = lifecycle_end(4_000_000, 500_000, true);
        let b = lifecycle_end(4_000_000, 500_000, true);
        assert_eq!(a.to_bits(), b.to_bits());
        // Single-segment exposures route through the seed win_free.
        let plain = lifecycle_end(400_000, 500_000, false);
        let via_pipe = lifecycle_end(400_000, 500_000, true);
        assert_eq!(plain.to_bits(), via_pipe.to_bits());
    }

    #[test]
    fn mt_dereg_stream_is_stretched_across_the_aux_window() {
        // Threading strategy: while the auxiliary thread is alive the
        // dereg stream shares the oversubscribed core, so each
        // segment's unpin stretches by the same factor `compute` uses.
        // Free promptly after the pipelined create so the stream is
        // gated by live eligibility times (a long-idle window's stream
        // completes in the past and the stretch would be unobservable).
        fn free_exit(with_aux: bool) -> f64 {
            let mut s = sim(1, 2);
            let exit = Arc::new(Mutex::new(0.0f64));
            let e2 = exit.clone();
            s.launch(1, move |p| {
                let elems = 100_000_000u64; // ~0.8 s registration stream
                let opts = WinCreateOpts::pipelined(1_000_000);
                let win = p.win_create_with(WORLD, Payload::virt(elems), opts);
                if with_aux {
                    // Pure compute: holds aux_alive through the free
                    // without touching the MPI progress token.
                    p.spawn_aux(|aux| aux.compute(10.0));
                }
                p.win_free_local_pipelined(win);
                *e2.lock().unwrap() = p.now();
                p.aux_join();
            });
            s.run().unwrap();
            let t = exit.lock().unwrap();
            *t
        }
        let plain = free_exit(false);
        let stretched = free_exit(true);
        assert!(
            stretched > plain + 1e-9,
            "aux window must stretch the dereg stream: plain={plain} stretched={stretched}"
        );
        // Determinism of the stretched path.
        assert_eq!(free_exit(true).to_bits(), stretched.to_bits());
    }

    #[test]
    fn evicting_an_inflight_stream_defers_its_dereg_to_background() {
        // Token A's background registration stream runs ~0.8 s; a
        // capped pin of token B evicts A while the stream is still
        // pinning.  The dereg still cannot begin before the stream ends
        // (deregistering memory that is not yet registered would be
        // nonsense), but it rides a background `evictdereg-*` activity:
        // the evicting rank no longer blocks on it.
        let mut s = sim(1, 2);
        let w = s.world();
        s.launch(1, |p| {
            let elems = 100_000_000u64; // 0.8 s of registration
            let wa = p.win_acquire_with(WORLD, Payload::virt(elems), 0xA, 1, WinCreateOpts::pipelined(1_000_000));
            assert!(p.now() < 0.1, "acquire must exit at the fill: {}", p.now());
            let wb = p.win_acquire_with(WORLD, Payload::virt(1_000_000), 0xB, 1, WinCreateOpts::pipelined(1_000_000));
            assert!(
                p.now() < 0.1,
                "eviction must not block the evicting rank: {}",
                p.now()
            );
            p.win_release(wb);
            p.win_release(wa);
        });
        let end = s.run().unwrap();
        let st = w.lock().unwrap().win_pool_stats();
        assert_eq!(st.evictions, 1, "{st:?}");
        assert!(st.evict_dereg_time > 0.0, "{st:?}");
        // The background dereg started only after A's stream finished
        // at ~0.8 s, so the engine ran past that point.
        assert!(end >= 0.8 + st.evict_dereg_time - 1e-9, "end={end} {st:?}");
    }

    #[test]
    fn eviction_storm_overlaps_the_closing_barrier() {
        // Rank 0 pins three 800 MB tokens under cap 1 (each pin evicts
        // the previous ~1 GiB-class victim), then a small token, then
        // meets rank 1 at a barrier.  The storm's deregistrations ride
        // background streams: the barrier closes on the registration
        // timeline alone, with the last dereg (~0.36 s) still draining
        // past it — before this change the deregs serialized in front
        // of the barrier.
        let mut s = sim(1, 2);
        let w = s.world();
        let exit = Arc::new(Mutex::new(0.0f64));
        let e2 = exit.clone();
        s.launch(2, move |p| {
            if p.rank(WORLD) == 0 {
                for token in 0..3u64 {
                    p.pin_buffer(token, 100_000_000 * 8, 1);
                }
                p.pin_buffer(99, 1024, 1);
            }
            p.barrier(WORLD);
            if p.rank(WORLD) == 0 {
                *e2.lock().unwrap() = p.now();
            }
        });
        let end = s.run().unwrap();
        let exit = *exit.lock().unwrap();
        let st = w.lock().unwrap().win_pool_stats();
        assert_eq!(st.evictions, 3, "{st:?}");
        // Barrier exit is gated by the three registrations (~2.4 s),
        // not the deregs on top of them.
        assert!(exit < 2.5, "deregs must not delay the barrier: exit={exit}");
        // The final eviction's dereg stream drains past the barrier:
        // the engine outlives the ranks by roughly one dereg.
        assert!(end > exit + 0.3, "no overlap: end={end} exit={exit}");
    }

    #[test]
    fn eager_stream_starts_at_own_fill_end() {
        // Two ranks arrive staggered at a pipelined create (the late
        // rank stands in for a spawned process still starting).  Under
        // the eager policy the early source's background stream starts
        // at its own fill end instead of the collective exit, so the
        // registration completes earlier and the free right after the
        // create returns sooner.
        fn end(eager: bool) -> f64 {
            let mut s = sim(1, 2);
            s.launch(2, move |p| {
                let r = p.rank(WORLD);
                if r == 1 {
                    p.compute(0.5);
                }
                let expose = if r == 0 { Payload::virt(100_000_000) } else { Payload::virt(0) };
                let win = p.win_create_with(WORLD, expose, WinCreateOpts::pipelined(1_000_000).eager(eager));
                p.win_free(win); // waits for the stream
            });
            s.run().unwrap()
        }
        let lazy = end(false);
        let eager = end(true);
        assert!(eager < lazy - 0.3, "eager={eager} lazy={lazy}");
        // The default policy is bit-identical to the 3-arg entry point.
        fn end_default() -> f64 {
            let mut s = sim(1, 2);
            s.launch(2, move |p| {
                let r = p.rank(WORLD);
                if r == 1 {
                    p.compute(0.5);
                }
                let expose = if r == 0 { Payload::virt(100_000_000) } else { Payload::virt(0) };
                let win = p.win_create_with(WORLD, expose, WinCreateOpts::pipelined(1_000_000));
                p.win_free(win);
            });
            s.run().unwrap()
        }
        assert_eq!(end(false).to_bits(), end_default().to_bits());
    }

    #[test]
    fn warm_pipelined_acquire_collapses_to_pure_setup() {
        let elems = 10_000_000u64; // 80 MB
        let mut s = sim(1, 2);
        let w = s.world();
        s.launch(1, move |p| {
            p.pin_buffer(0xA, elems * 8, 0);
            let t0 = p.now();
            let win = p.win_acquire_with(WORLD, Payload::virt(elems), 0xA, 0, WinCreateOpts::pipelined(1_000_000));
            // All segments warm: fixed setup only, no background stream.
            assert!(p.now() - t0 < 1e-3, "warm pipelined acquire cost {}", p.now() - t0);
            let t1 = p.now();
            p.win_release(win);
            assert!(p.now() - t1 < 1e-3, "release waited on a phantom stream");
        });
        s.run().unwrap();
        let w = w.lock().unwrap();
        let st = w.win_pool_stats();
        assert_eq!(st.warm_acquires, 1, "{st:?}");
        assert_eq!(st.seg_cold_regs + st.seg_warm_regs, 0, "{st:?}");
    }

    #[test]
    fn partially_warm_pipelined_acquire_skips_prefix_segments() {
        let mut s = sim(1, 2);
        let w = s.world();
        s.launch(1, move |p| {
            // Pin 4096 B (class 12): covers exactly the first segment.
            p.pin_buffer(0xB, 4096, 0);
            // 2048 elems = 16 KiB in 512-elem (4 KiB) segments → 4
            // segments, the first warm, the tail cold.
            let win = p.win_acquire_with(WORLD, Payload::virt(2048), 0xB, 0, WinCreateOpts::pipelined(512));
            p.win_release(win);
            // The grown pin makes a re-acquire fully warm.
            let win = p.win_acquire_with(WORLD, Payload::virt(2048), 0xB, 0, WinCreateOpts::pipelined(512));
            p.win_release(win);
        });
        s.run().unwrap();
        let w = w.lock().unwrap();
        let st = w.win_pool_stats();
        assert_eq!(st.seg_warm_regs, 1, "{st:?}");
        assert_eq!(st.seg_cold_regs, 3, "{st:?}");
        assert_eq!(st.warm_acquires, 1, "re-acquire must ride the grown pin: {st:?}");
    }

    #[test]
    fn comm_sub_selects_prefix() {
        let mut s = sim(1, 4);
        s.launch(4, |p| {
            let sc = p.comm_sub(WORLD, 2);
            if p.rank(WORLD) < 2 {
                assert!(p.in_comm(sc));
                assert_eq!(p.rank(sc), p.rank(WORLD));
                assert_eq!(p.size(sc), 2);
                p.barrier(sc);
            } else {
                assert!(!p.in_comm(sc));
            }
        });
        s.run().unwrap();
    }

    #[test]
    fn aux_thread_runs_and_joins() {
        let mut s = sim(1, 2);
        s.launch(1, |p| {
            assert!(!p.aux_alive());
            p.spawn_aux(|aux| {
                assert!(aux.is_aux());
                aux.compute(2.0);
            });
            assert!(p.aux_alive());
            // Oversubscribed compute is stretched 2x.
            let t0 = p.now();
            p.compute(1.0);
            assert!((p.now() - t0 - 2.0).abs() < 1e-9);
            p.aux_join();
            assert!(!p.aux_alive());
        });
        s.run().unwrap();
    }

    #[test]
    fn progress_token_serializes_main_and_aux() {
        // Aux does a long blocking alltoallv; main's barrier must wait
        // (MPICH MPI_THREAD_MULTIPLE emulation, §V-D).
        let mut s = sim(2, 2);
        s.launch(2, |p| {
            let r = p.rank(WORLD);
            let world_comm = WORLD;
            p.spawn_aux(move |aux| {
                let sends = (0..2)
                    .map(|j| Payload::virt(if j == r { 0 } else { 2_000_000 }))
                    .collect();
                let _ = aux.alltoallv(world_comm, sends);
            });
            p.compute(1e-6);
            let t0 = p.now();
            p.barrier(WORLD); // must stall behind aux's collective
            let barrier_wait = p.now() - t0;
            assert!(
                barrier_wait > 1e-3,
                "main barrier did not stall: {barrier_wait}"
            );
            p.aux_join();
        });
        s.run().unwrap();
    }
}
