//! `simmpi` — an MPI-4-like runtime implemented on the discrete-event
//! simulator.
//!
//! The paper's redistribution algorithms (§IV) are written against the
//! MPI API; this module provides the same surface with the same
//! semantics so MaM's code is a faithful port:
//!
//! * communicators & groups, dynamic process spawning + merge
//!   (MaM's *Merge* process-management method),
//! * two-sided p2p with eager/rendezvous regimes,
//! * blocking collectives (Barrier, Bcast, Allgather, Alltoallv)
//!   whose completion schedule is computed from the calibrated cost
//!   model using the textbook algorithms (dissemination, ring,
//!   pairwise-exchange),
//! * nonblocking operations (Ibarrier, Ialltoallv, Rget) with
//!   request-based Test/Wait and an MPICH-CH4-style *progress model*:
//!   pending CPU work of nonblocking collectives is drained in chunks
//!   by subsequent MPI calls — this is what makes the ω ratios of §V-C
//!   emerge rather than being hard-coded,
//! * the full passive-target RMA chapter: `Win_create`/`Win_free`
//!   (collective, with memory-registration cost — the paper's dominant
//!   RMA overhead), `Lock`/`Unlock`, `Lock_all`/`Unlock_all`, `Get`,
//!   `Rget`, plus the pooled `win_acquire`/`win_release` pair backed by
//!   the persistent [`winpool`] (warm acquires skip re-registration —
//!   the §VI fix),
//! * a per-process *progress token* emulating MPICH 4.2.0's effective
//!   serialization of `MPI_THREAD_MULTIPLE` progress (§V-D): while an
//!   auxiliary thread is inside a blocking call, main-thread MPI calls
//!   stall.
//!
//! Simulated ranks run as engine activities; the world state lives in
//! one mutex that is **never held across a virtual-time suspension**.

pub mod collective;
pub mod proc;
pub mod request;
pub mod rma;
pub mod types;
pub mod winpool;
pub mod world;

pub use crate::simcluster::faults::{FaultPlan, FaultSpec};
pub use proc::MpiProc;
pub use request::ReqId;
pub use rma::SchedStats;
pub use types::{
    recv_buf_real, recv_buf_virtual, CommId, MpiError, Payload, RecvBuf, RmaSync, WinCreateOpts,
    WinId, ELEM_BYTES,
};
pub use winpool::WinPoolStats;
pub use world::{MpiSim, MpiWorld, WorldSnapshot, WORLD};
