//! The shared world state of the simulated MPI job and the `MpiSim`
//! launcher.
//!
//! Locking discipline: the world mutex is only ever held for
//! *zero-virtual-time* bookkeeping; it is **never** held across an
//! engine suspension (`advance`/`park`).  Since the engine runs exactly
//! one activity at a time, the mutex is uncontended in practice — it
//! exists to satisfy `Send`/`Sync`, not for parallelism.
//!
//! Determinism contract: all keyed collections here are `BTreeMap`/
//! `BTreeSet`, never std hash tables — iteration order is the sorted
//! key order, so no randomized ordering can leak into virtual time,
//! counters, or reports (`det::hashmap-iter-escapes` in
//! [`crate::analysis`] enforces this tree-wide).

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Mutex};

use crate::netmodel::{CostModel, NetParams, Placement, Topology};
use crate::simcluster::faults::FaultPlan;
use crate::simcluster::{ActivityId, Engine, EngineError, Time};

use super::collective::CollState;
use super::proc::MpiProc;
use super::request::ReqState;
use super::rma::{SchedStats, WinState};
use super::types::{CommId, Payload};
use super::winpool::{WinPool, WinPoolStats};

/// The initial world communicator.
pub const WORLD: CommId = CommId(0);

/// A message posted to a destination process.
#[derive(Clone, Debug)]
pub(crate) struct PendingMsg {
    pub src_rank: usize, // rank within `comm`
    pub comm: CommId,
    pub tag: i32,
    pub payload: Payload,
    pub arrival: Time,
}

/// A receiver parked waiting for a matching message.
#[derive(Clone, Debug)]
pub(crate) struct RecvWait {
    pub src_rank: Option<usize>,
    pub comm: CommId,
    pub tag: i32,
    pub waiter: ActivityId,
}

/// Per-process runtime state.
#[derive(Clone)]
pub(crate) struct ProcState {
    /// Global process id (== index in `procs`; kept for diagnostics).
    #[allow(dead_code)]
    pub gpid: usize,
    pub core_slot: usize,
    pub exited: bool,
    /// Live auxiliary activity (Threading strategy)?
    pub aux_alive: bool,
    // ---- p2p
    pub inbox: Vec<PendingMsg>,
    pub recv_waits: Vec<RecvWait>,
    // ---- MPICH MPI_THREAD_MULTIPLE progress model (§V-D): while the
    // auxiliary thread is inside a blocking MPI call it owns the
    // progress engine (depth-counted); main-thread MPI calls stall
    // until the aux op completes.  The aux never waits — it *is* the
    // progress driver — which is what lets MaM's Threading strategy
    // complete while every main thread is blocked in its first
    // collective (the paper's COL-T observation).
    pub aux_busy: u32,
    pub progress_waiters: Vec<ActivityId>,
    // ---- iteration accounting (read by the monitor)
    pub iters_done: u64,
    /// Open nonblocking requests with pending CPU (progress-model) work.
    pub open_nb_reqs: Vec<usize>,
    /// Activities parked in `aux_join`.
    pub aux_waiters: Vec<ActivityId>,
}

impl ProcState {
    fn new(gpid: usize, core_slot: usize) -> ProcState {
        ProcState {
            gpid,
            core_slot,
            exited: false,
            aux_alive: false,
            inbox: Vec::new(),
            recv_waits: Vec::new(),
            aux_busy: 0,
            progress_waiters: Vec::new(),
            iters_done: 0,
            open_nb_reqs: Vec::new(),
            aux_waiters: Vec::new(),
        }
    }
}

/// A communicator: ordered list of member gpids.
#[derive(Clone)]
pub(crate) struct CommState {
    pub gpids: Vec<usize>,
    /// Next collective sequence number, per member slot (local count —
    /// matching relies on every member calling collectives in the same
    /// order, as MPI requires).
    pub coll_seq: Vec<u64>,
}

impl CommState {
    pub fn rank_of(&self, gpid: usize) -> Option<usize> {
        self.gpids.iter().position(|&g| g == gpid)
    }
}

/// Global simulation state shared by all simulated processes.
pub struct MpiWorld {
    pub cost: CostModel,
    pub placement: Placement,
    pub topology: Topology,
    pub(crate) procs: Vec<ProcState>,
    pub(crate) comms: Vec<CommState>,
    pub(crate) windows: Vec<WinState>,
    /// Persistent window pool: registration cache + released slots
    /// (§VI; see [`crate::simmpi::winpool`]).
    pub(crate) win_pool: WinPool,
    /// Job-level persistent-schedule descriptors, keyed by (merged
    /// rank, schedule-key hash).  Rank-keyed rather than gpid-keyed:
    /// the descriptor is a property of the *job's* rank slot — a drain
    /// respawned into the same slot on an oscillating trace inherits
    /// the schedule negotiated by its predecessor and only validates
    /// it (the persistent-collective model of arXiv 2604.05099).
    pub(crate) sched_pins: BTreeSet<(usize, u64)>,
    /// Warm/cold accounting of the schedule cache.
    pub(crate) sched_stats: SchedStats,
    pub(crate) colls: BTreeMap<(CommId, u64), CollState>,
    pub(crate) requests: Vec<ReqState>,
    /// Communicators produced by `spawn_merge` / `comm_sub`, keyed by
    /// the collective instance that produced them.
    pub(crate) derived_comms: BTreeMap<(CommId, u64), CommId>,
    /// Activities parked waiting for a derived communicator.
    pub(crate) derived_waiters: BTreeMap<(CommId, u64), Vec<ActivityId>>,
    /// Core-slot occupancy: slot index → gpid.
    core_slots: Vec<Option<usize>>,
    /// Free-form counters/series for experiment harnesses.
    pub metrics: crate::monitor::Metrics,
    /// Oversubscription model toggle (always on; tests may disable).
    pub oversubscription: bool,
    /// Installed fault plan (`--faults`).  Immutable configuration —
    /// deliberately excluded from [`WorldSnapshot`]: a rollback must
    /// not change which faults fire.
    pub(crate) faults: Option<Arc<FaultPlan>>,
}

impl MpiWorld {
    fn new(topology: Topology, params: NetParams) -> MpiWorld {
        let n_nodes = topology.nodes;
        MpiWorld {
            cost: CostModel::new(params, n_nodes),
            placement: Placement {
                cores_per_node: topology.cores_per_node,
                node_of: Vec::new(),
            },
            core_slots: vec![None; topology.total_cores()],
            topology,
            procs: Vec::new(),
            comms: Vec::new(),
            windows: Vec::new(),
            win_pool: WinPool::new(),
            sched_pins: BTreeSet::new(),
            sched_stats: SchedStats::default(),
            colls: BTreeMap::new(),
            requests: Vec::new(),
            derived_comms: BTreeMap::new(),
            derived_waiters: BTreeMap::new(),
            metrics: crate::monitor::Metrics::new(),
            oversubscription: true,
            faults: None,
        }
    }

    /// Allocate a core slot and create a process record; returns gpid.
    pub(crate) fn create_proc(&mut self) -> usize {
        let slot = self
            .core_slots
            .iter()
            .position(|s| s.is_none())
            .expect("cluster out of cores");
        let gpid = self.procs.len();
        self.core_slots[slot] = Some(gpid);
        // placement is indexed by gpid.
        let node = self.topology.node_of_slot(slot);
        debug_assert_eq!(self.placement.node_of.len(), gpid);
        self.placement.node_of.push(node);
        self.procs.push(ProcState::new(gpid, slot));
        gpid
    }

    /// Mark a process exited and release its core slot.  Its pinned
    /// registrations die with it — a later process must re-register.
    pub(crate) fn retire_proc(&mut self, gpid: usize) {
        let slot = self.procs[gpid].core_slot;
        self.procs[gpid].exited = true;
        self.core_slots[slot] = None;
        self.win_pool.unpin_all(gpid);
    }

    /// Warm/cold accounting of the window pool (experiment harnesses
    /// read this through the world handle after `run`).
    pub fn win_pool_stats(&self) -> WinPoolStats {
        self.win_pool.stats()
    }

    /// Warm/cold accounting of the persistent-schedule cache.
    pub fn sched_stats(&self) -> SchedStats {
        self.sched_stats
    }

    /// Create a communicator over the given gpids; returns its id.
    pub(crate) fn create_comm(&mut self, gpids: Vec<usize>) -> CommId {
        let n = gpids.len();
        self.comms.push(CommState { gpids, coll_seq: vec![0; n] });
        CommId(self.comms.len() - 1)
    }

    pub(crate) fn comm(&self, c: CommId) -> &CommState {
        &self.comms[c.0]
    }

    pub(crate) fn comm_mut(&mut self, c: CommId) -> &mut CommState {
        &mut self.comms[c.0]
    }

    /// Number of live (non-exited) processes.
    pub fn live_procs(&self) -> usize {
        self.procs.iter().filter(|p| !p.exited).count()
    }

    /// Iterations completed by a process (monitor hook).
    pub fn iters_of(&self, gpid: usize) -> u64 {
        self.procs[gpid].iters_done
    }

    /// Deep-copy the persistent world state at quiescence.
    ///
    /// Panics if anything transient is in flight (open collectives,
    /// parked receivers, undelivered messages, pending requests) —
    /// a snapshot is only meaningful between engine runs, when every
    /// live activity is parked and the world holds no cross-rank state.
    /// Together with [`crate::simcluster::Engine::rollback_to`] this is
    /// the planner's incremental-probe mechanism: capture the world
    /// once after launch, then rewind instead of rebuilding per
    /// candidate.
    pub fn snapshot(&self) -> WorldSnapshot {
        assert!(self.colls.is_empty(), "snapshot with in-flight collectives");
        assert!(
            self.derived_waiters.values().all(|w| w.is_empty()),
            "snapshot with parked comm waiters"
        );
        assert!(
            self.requests.iter().all(|r| r.done),
            "snapshot with pending nonblocking requests"
        );
        for p in &self.procs {
            assert!(p.inbox.is_empty(), "snapshot with undelivered messages");
            assert!(p.recv_waits.is_empty(), "snapshot with parked receivers");
            assert!(p.progress_waiters.is_empty() && p.aux_waiters.is_empty());
            assert_eq!(p.aux_busy, 0, "snapshot while aux thread in MPI");
        }
        WorldSnapshot {
            cost: self.cost.clone(),
            placement: self.placement.clone(),
            procs: self.procs.clone(),
            comms: self.comms.clone(),
            windows: self.windows.clone(),
            win_pool: self.win_pool.clone(),
            sched_pins: self.sched_pins.clone(),
            sched_stats: self.sched_stats,
            requests: self.requests.clone(),
            derived_comms: self.derived_comms.clone(),
            core_slots: self.core_slots.clone(),
            metrics: self.metrics.clone(),
        }
    }

    /// Rewind the world to a previously captured [`WorldSnapshot`].
    /// Transient maps are cleared; processes, communicators, windows,
    /// the pool, request slots, the cost model's occupancy state and
    /// the metrics all return to the captured instant bit-for-bit.
    pub fn restore(&mut self, snap: &WorldSnapshot) {
        self.cost = snap.cost.clone();
        self.placement = snap.placement.clone();
        self.procs = snap.procs.clone();
        self.comms = snap.comms.clone();
        self.windows = snap.windows.clone();
        self.win_pool = snap.win_pool.clone();
        self.sched_pins = snap.sched_pins.clone();
        self.sched_stats = snap.sched_stats;
        self.requests = snap.requests.clone();
        self.derived_comms = snap.derived_comms.clone();
        self.core_slots = snap.core_slots.clone();
        self.metrics = snap.metrics.clone();
        self.colls.clear();
        self.derived_waiters.clear();
    }
}

/// A quiescent deep copy of [`MpiWorld`] (see [`MpiWorld::snapshot`]).
pub struct WorldSnapshot {
    cost: CostModel,
    placement: Placement,
    procs: Vec<ProcState>,
    comms: Vec<CommState>,
    windows: Vec<WinState>,
    win_pool: WinPool,
    sched_pins: BTreeSet<(usize, u64)>,
    sched_stats: SchedStats,
    requests: Vec<ReqState>,
    derived_comms: BTreeMap<(CommId, u64), CommId>,
    core_slots: Vec<Option<usize>>,
    metrics: crate::monitor::Metrics,
}

/// Builder/driver: wires an [`Engine`] to a shared [`MpiWorld`] and
/// launches the initial ranks.
pub struct MpiSim {
    engine: Engine,
    world: Arc<Mutex<MpiWorld>>,
}

impl MpiSim {
    pub fn new(topology: Topology, params: NetParams) -> MpiSim {
        MpiSim {
            engine: Engine::new(),
            world: Arc::new(Mutex::new(MpiWorld::new(topology, params))),
        }
    }

    /// Shared handle to the world (inspect metrics after `run`).
    pub fn world(&self) -> Arc<Mutex<MpiWorld>> {
        self.world.clone()
    }

    /// Install a fault plan (`--faults`).  Must be called before
    /// `launch`-ed bodies start reading it; inactive plans are not
    /// installed at all, so the fault-free fast path stays untouched.
    pub fn set_faults(&mut self, plan: FaultPlan) {
        let mut w = self.world.lock().unwrap();
        w.faults = plan.spec.is_active().then(|| Arc::new(plan));
    }

    /// Launch the initial `n` ranks as communicator [`WORLD`].  Every
    /// rank runs `body`; use `proc.rank(WORLD)` inside to specialize.
    /// Returns the rank activity ids in rank order (probe sessions wake
    /// parked ranks through them; normal callers ignore the result).
    pub fn launch<F>(&mut self, n: usize, body: F) -> Vec<crate::simcluster::ActivityId>
    where
        F: Fn(MpiProc) + Send + Sync + 'static,
    {
        let body = Arc::new(body);
        let gpids: Vec<usize> = {
            let mut w = self.world.lock().unwrap();
            let g: Vec<usize> = (0..n).map(|_| w.create_proc()).collect();
            let c = w.create_comm(g.clone());
            assert_eq!(c, WORLD, "launch must create the first communicator");
            g
        };
        let mut ids = Vec::with_capacity(n);
        for (rank, gpid) in gpids.into_iter().enumerate() {
            let world = self.world.clone();
            let b = body.clone();
            ids.push(self.engine.spawn_at(0.0, format!("rank{rank}"), move |ctx| {
                let proc = MpiProc::main(ctx, world, gpid);
                b(proc.clone_handle());
                proc.on_exit();
            }));
        }
        ids
    }

    /// Publish the engine's counters into the world metrics (read by
    /// scenario reports and the bench harness).
    fn publish_engine_stats(&self) {
        let s = self.engine.stats();
        let mut w = self.world.lock().unwrap();
        w.metrics.set_counter("engine.events", s.events as f64);
        w.metrics.set_counter("engine.peak_queue", s.peak_queue as f64);
        w.metrics.set_counter("engine.wakeup_batches", s.wakeup_batches as f64);
        w.metrics.set_counter("engine.wakeup_ranks", s.wakeup_batched as f64);
        w.metrics.set_counter("engine.wakeup_max", s.wakeup_max_batch as f64);
        w.metrics.set_counter("engine.sweep_direct", s.direct_sweeps as f64);
        w.metrics.set_counter("engine.rollbacks", s.rollbacks as f64);
        w.metrics.set_counter("engine.snapshots", s.snapshots as f64);
    }

    /// Drive the simulation to completion; returns the final virtual
    /// time.
    pub fn run(mut self) -> Result<Time, EngineError> {
        let t = self.engine.run()?;
        self.publish_engine_stats();
        Ok(t)
    }

    /// Drive until every live activity is parked (quiescence) without
    /// consuming the sim — the probe-session stepping primitive.  The
    /// engine stays usable: park/`unpark`/run again, or [`Self::run`]
    /// to finish.
    pub fn run_until_idle(&mut self) -> Result<Time, EngineError> {
        let t = self.engine.run_until_idle()?;
        self.publish_engine_stats();
        Ok(t)
    }

    /// Schedule a wakeup for a parked activity (host side).
    pub fn unpark(&mut self, target: crate::simcluster::ActivityId, at: Time) {
        self.engine.unpark(target, at);
    }

    /// Rewind the virtual clock to `t` (quiescence only; see
    /// [`Engine::rollback_to`]).  Pair with [`MpiWorld::restore`].
    pub fn rollback_to(&mut self, t: Time) {
        self.engine.rollback_to(t);
    }

    /// Engine counters (events, queue depth, wakeup batching, …).
    pub fn engine_stats(&self) -> crate::simcluster::EngineStats {
        self.engine.stats()
    }

    /// Count a world snapshot against the engine's stats (the prober
    /// calls this next to [`MpiWorld::snapshot`]).
    pub fn note_snapshot(&mut self) {
        self.engine.stats_mut().snapshots += 1;
    }

    /// Events processed so far (simulator throughput metric).
    pub fn events_processed(&self) -> u64 {
        self.engine.events_processed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netmodel::NodeId;

    fn tiny_sim() -> MpiSim {
        MpiSim::new(Topology::new(2, 4), NetParams::test_simple())
    }

    #[test]
    fn launch_creates_world_comm() {
        let mut sim = tiny_sim();
        sim.launch(4, |p| {
            assert_eq!(p.size(WORLD), 4);
            assert!(p.rank(WORLD) < 4);
        });
        sim.run().unwrap();
    }

    #[test]
    fn core_slots_are_block_placed() {
        let mut sim = tiny_sim();
        let w = sim.world();
        sim.launch(6, |_p| {});
        sim.run().unwrap();
        let w = w.lock().unwrap();
        assert_eq!(w.placement.node_of(0), NodeId(0));
        assert_eq!(w.placement.node_of(3), NodeId(0));
        assert_eq!(w.placement.node_of(4), NodeId(1));
        assert_eq!(w.placement.node_of(5), NodeId(1));
    }

    #[test]
    fn retire_frees_slot_for_reuse() {
        let mut w = MpiWorld::new(Topology::new(1, 2), NetParams::test_simple());
        let a = w.create_proc();
        let b = w.create_proc();
        assert_eq!((a, b), (0, 1));
        w.retire_proc(0);
        let c = w.create_proc();
        // gpid grows, but the slot (and hence node) is recycled.
        assert_eq!(c, 2);
        assert_eq!(w.procs[c].core_slot, 0);
        assert_eq!(w.live_procs(), 2);
    }

    #[test]
    #[should_panic(expected = "out of cores")]
    fn exhausting_cores_panics() {
        let mut w = MpiWorld::new(Topology::new(1, 2), NetParams::test_simple());
        w.create_proc();
        w.create_proc();
        w.create_proc();
    }

    /// Regression for `det::hashmap-iter-escapes`: the world's keyed
    /// tables are `BTreeMap`/`BTreeSet`, so iteration order is a pure
    /// function of the keys — never of insertion history.  Before the
    /// switch these were std hash tables whose `RandomState` order
    /// could leak into anything that walks them.
    #[test]
    fn world_table_iteration_is_insertion_order_independent() {
        let pins = [(3usize, 7u64), (0, 1), (3, 2), (1, 9), (0, 0)];
        let mut fwd = MpiWorld::new(Topology::new(1, 2), NetParams::test_simple());
        let mut rev = MpiWorld::new(Topology::new(1, 2), NetParams::test_simple());
        for &p in &pins {
            fwd.sched_pins.insert(p);
        }
        for &p in pins.iter().rev() {
            rev.sched_pins.insert(p);
        }
        let a: Vec<_> = fwd.sched_pins.iter().copied().collect();
        let b: Vec<_> = rev.sched_pins.iter().copied().collect();
        assert_eq!(a, b, "pin order must not depend on insertion order");
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(a, sorted, "pins iterate in key order");

        let keys = [(CommId(2), 5u64), (CommId(0), 3), (CommId(2), 1), (CommId(1), 8)];
        for &k in &keys {
            fwd.derived_comms.insert(k, CommId(99));
        }
        for &k in keys.iter().rev() {
            rev.derived_comms.insert(k, CommId(99));
        }
        let a: Vec<_> = fwd.derived_comms.keys().copied().collect();
        let b: Vec<_> = rev.derived_comms.keys().copied().collect();
        assert_eq!(a, b, "derived-comm order must not depend on insertion order");
    }
}
