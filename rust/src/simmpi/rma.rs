//! RMA window state (passive-target model, MPI-3 §11).
//!
//! A window is created collectively over a communicator; each member
//! exposes a payload (possibly empty — drains expose `NULL`, §IV-B).
//! Origins open epochs with `Lock`/`Lock_all` (modeled with
//! `MPI_MODE_NOCHECK` semantics: local bookkeeping only), post
//! `Get`/`Rget` reads whose flow times come from the one-sided branch
//! of the cost model (no target CPU involvement), and close epochs with
//! `Unlock`/`Unlock_all`, which block until the pending reads to the
//! target(s) have landed.
//!
//! Window payloads are snapshots of *constant* application data — the
//! only class MaM redistributes without blocking the application (§III)
//! — so reads may be satisfied from the exposure regardless of when
//! the flow completes in virtual time.

use std::collections::BTreeMap;

use crate::simcluster::{ActivityId, Time};

use super::types::Payload;

/// Warm/cold accounting of the job-level persistent-schedule cache
/// (the schedule analog of `WinPoolStats`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SchedStats {
    /// Cold schedule builds (first occurrence of a shape per rank).
    pub cold_builds: u64,
    /// Warm replays (descriptor found — only a validation was charged).
    pub warm_replays: u64,
    /// Virtual seconds charged building cold descriptors.
    pub build_time: f64,
    /// Virtual seconds charged validating cached descriptors.
    pub validate_time: f64,
}

/// Per-window state.
#[derive(Clone)]
pub(crate) struct WinState {
    pub comm: super::types::CommId,
    /// Exposed payload per communicator rank (virt(0) = nothing).
    pub exposures: Vec<Payload>,
    /// Pending blocking-Get arrival times, keyed by (origin gpid,
    /// target rank) — consumed by `Unlock`/`Unlock_all`.
    pub pending_gets: BTreeMap<(usize, usize), Vec<Time>>,
    /// Ranks that called `win_free_local` (WD path GC).
    pub freed_local: Vec<bool>,
    pub freed: bool,
    /// Window created from an `MPI_THREAD_MULTIPLE` context (§V-D):
    /// one-sided accesses crawl under MPICH's contended lock — their
    /// wire contribution is scaled by `mt_rma_penalty`.
    pub mt: bool,
    /// Chunked pipelined registration: segment size in *elements*
    /// (0 = unsegmented, the seed behaviour).
    pub seg_elems: u64,
    /// Per-rank absolute virtual times at which each of the rank's
    /// exposure segments finishes registering (empty = everything
    /// registered when the creating collective exits — unsegmented
    /// windows and NULL exposures).  Gets targeting segment `s` cannot
    /// start before `seg_ready[target][s]`; filled by the last arriver
    /// of the pipelined `Win_create` before any participant resumes.
    pub seg_ready: Vec<Vec<Time>>,
    /// Per-rank, per-segment latest *read completion* targeting that
    /// segment of the rank's exposure (empty = no segmented reads).
    /// Unlike `pending_gets` this survives the epoch flush — it feeds
    /// the pipelined teardown: a segment may deregister once its last
    /// read has landed (and its own registration finished), so on
    /// shrinks the `Win_free` per-byte deregistration rides the wire
    /// instead of serializing after it.
    pub seg_read_done: Vec<Vec<Time>>,
    /// Notified-completion sync (`--rma-sync notify`): the number of
    /// read operations each rank *expects* against its own exposure,
    /// armed from the redistribution schedule's sync plan (`None` =
    /// not armed — epoch mode, or the schedule has not arrived yet).
    pub notify_expected: Vec<Option<u64>>,
    /// Read operations posted so far against each rank's exposure.
    /// Counted unconditionally (a counter bump charges nothing), so
    /// arming order does not matter and epoch mode is unaffected.
    pub notify_seen: Vec<u64>,
    /// Latest read-completion instant per target rank (the notified
    /// teardown drains to this before deregistering).
    pub notify_last: Vec<Time>,
    /// Ranks parked in a notified free, waiting for their expected
    /// count — woken by the Get/Rget that reaches it.
    pub notify_waiters: Vec<(usize, ActivityId)>,
}

impl WinState {
    pub fn new(comm: super::types::CommId, n: usize) -> WinState {
        WinState {
            comm,
            exposures: (0..n).map(|_| Payload::virt(0)).collect(),
            pending_gets: BTreeMap::new(),
            freed_local: vec![false; n],
            freed: false,
            mt: false,
            seg_elems: 0,
            seg_ready: (0..n).map(|_| Vec::new()).collect(),
            seg_read_done: (0..n).map(|_| Vec::new()).collect(),
            notify_expected: vec![None; n],
            notify_seen: vec![0; n],
            notify_last: vec![0.0; n],
            notify_waiters: Vec::new(),
        }
    }

    /// Re-arm a pooled slot for a fresh acquire on the same
    /// communicator (window-pool path): exposures are replaced by the
    /// acquiring ranks, epoch/free bookkeeping starts over.  The MT
    /// flag resets too — warmth of the *registration* does not carry
    /// the threaded-context penalty of a previous epoch (§V-D).
    pub fn reset(&mut self, comm: super::types::CommId, n: usize) {
        debug_assert!(self.pending_gets.is_empty(), "reset with pending gets");
        self.comm = comm;
        self.exposures = (0..n).map(|_| Payload::virt(0)).collect();
        self.pending_gets.clear();
        self.freed_local = vec![false; n];
        self.freed = false;
        self.mt = false;
        self.seg_elems = 0;
        self.seg_ready = (0..n).map(|_| Vec::new()).collect();
        self.seg_read_done = (0..n).map(|_| Vec::new()).collect();
        debug_assert!(self.notify_waiters.is_empty(), "reset with notify waiters");
        self.notify_expected = vec![None; n];
        self.notify_seen = vec![0; n];
        self.notify_last = vec![0.0; n];
        self.notify_waiters.clear();
    }

    /// Arm the notified teardown for `rank`'s exposure: the schedule's
    /// sync plan says exactly `expected` read operations will target
    /// it.  Returns the parked waiters to wake if the count is already
    /// met (reads may have been posted before the schedule arrived).
    pub fn arm_notify(&mut self, rank: usize, expected: u64) -> Vec<ActivityId> {
        self.notify_expected[rank] = Some(expected);
        self.take_notify_waiters(rank)
    }

    /// Count one posted read operation against `target`'s exposure and
    /// fold its completion instant into the notification record.
    /// Returns the waiters to wake when the expected count is reached.
    pub fn note_notify(&mut self, target: usize, arrival: Time) -> Vec<ActivityId> {
        self.notify_seen[target] += 1;
        self.notify_last[target] = self.notify_last[target].max(arrival);
        self.take_notify_waiters(target)
    }

    /// `Some(latest read completion)` once `rank`'s armed expectation
    /// is met; `None` while reads are still outstanding (or unarmed).
    pub fn notify_ready(&self, rank: usize) -> Option<Time> {
        match self.notify_expected[rank] {
            Some(exp) if self.notify_seen[rank] >= exp => Some(self.notify_last[rank]),
            _ => None,
        }
    }

    fn take_notify_waiters(&mut self, rank: usize) -> Vec<ActivityId> {
        if self.notify_ready(rank).is_none() {
            return Vec::new();
        }
        let mut woken = Vec::new();
        self.notify_waiters.retain(|(r, aid)| {
            if *r == rank {
                woken.push(*aid);
                false
            } else {
                true
            }
        });
        woken
    }

    /// Number of segments of `rank`'s exposure under the window's
    /// chunking (0 for unsegmented windows and NULL exposures).
    pub fn n_segs(&self, rank: usize) -> u64 {
        if self.seg_elems == 0 {
            0
        } else {
            self.exposures[rank].elems().div_ceil(self.seg_elems)
        }
    }

    /// Record the completion of a read of `[disp, disp+count)` from
    /// `target`'s exposure (pipelined teardown bookkeeping; no-op for
    /// unsegmented windows).  Uses a commutative `max` per segment, so
    /// the record is deterministic regardless of posting order.
    pub fn note_read(&mut self, target: usize, disp: u64, count: u64, arrival: Time) {
        if self.seg_elems == 0 || count == 0 {
            return;
        }
        let n_seg = self.n_segs(target) as usize;
        if n_seg == 0 {
            return;
        }
        let done = &mut self.seg_read_done[target];
        if done.is_empty() {
            done.resize(n_seg, 0.0);
        }
        let first = (disp / self.seg_elems) as usize;
        let last = ((disp + count - 1) / self.seg_elems) as usize;
        for d in done.iter_mut().take(last + 1).skip(first) {
            *d = d.max(arrival);
        }
    }

    /// Per-segment earliest instants `rank`'s exposure segments may
    /// deregister: a segment is eligible once its own background
    /// registration finished (`seg_ready`) *and* the last read touching
    /// it has landed (`seg_read_done`).  Empty for unsegmented ranks.
    pub fn dereg_eligibility(&self, rank: usize) -> Vec<Time> {
        let n_seg = self.n_segs(rank) as usize;
        (0..n_seg)
            .map(|s| {
                let reg = self.seg_ready[rank].get(s).copied().unwrap_or(0.0);
                let read = self.seg_read_done[rank].get(s).copied().unwrap_or(0.0);
                reg.max(read)
            })
            .collect()
    }

    /// Earliest instant a Get of `[disp, disp+count)` from `target`'s
    /// exposure may start flowing: the registration-ready time of the
    /// last segment the range touches.  `None` for unsegmented windows
    /// (and for targets whose whole exposure was registered inside the
    /// creating collective) — the seed behaviour, no gating at all.
    pub fn seg_gate(&self, target: usize, disp: u64, count: u64) -> Option<Time> {
        let ready = &self.seg_ready[target];
        if ready.is_empty() || self.seg_elems == 0 {
            return None;
        }
        let last = (disp + count.max(1) - 1) / self.seg_elems;
        // Ready times are cumulative, so the last touched segment
        // dominates the whole range.
        Some(ready[(last as usize).min(ready.len() - 1)])
    }

    /// When this rank's background segment registration finishes
    /// (`None` = nothing registers in the background).  `Win_free` /
    /// `win_release` must not run before this instant — a window
    /// cannot be torn down while its memory is still being pinned.
    pub fn reg_done(&self, rank: usize) -> Option<Time> {
        self.seg_ready.get(rank).and_then(|v| v.last()).copied()
    }

    /// Read `count` elements at `disp` from `target`'s exposure;
    /// returns real data when the exposure is real.
    pub fn read(&self, target: usize, disp: u64, count: u64) -> Option<Vec<f64>> {
        let exp = &self.exposures[target];
        assert!(
            disp + count <= exp.elems(),
            "get out of range: disp={} count={} exposed={} (target {})",
            disp,
            count,
            exp.elems(),
            target
        );
        exp.as_slice()
            .map(|s| s[disp as usize..(disp + count) as usize].to_vec())
    }

    /// Register a blocking Get's arrival for epoch flushing.
    pub fn track_get(&mut self, origin_gpid: usize, target: usize, arrival: Time) {
        self.pending_gets
            .entry((origin_gpid, target))
            .or_default()
            .push(arrival);
    }

    /// Drain pending arrivals for (origin, target); returns the latest.
    pub fn flush_target(&mut self, origin_gpid: usize, target: usize) -> Option<Time> {
        self.pending_gets
            .remove(&(origin_gpid, target))
            .and_then(|v| v.into_iter().reduce(f64::max))
    }

    /// Drain pending arrivals for all targets of `origin`.
    pub fn flush_all(&mut self, origin_gpid: usize) -> Option<Time> {
        let keys: Vec<_> = self
            .pending_gets
            .keys()
            .filter(|(o, _)| *o == origin_gpid)
            .cloned()
            .collect();
        let mut latest = None;
        for k in keys {
            if let Some(v) = self.pending_gets.remove(&k) {
                for t in v {
                    latest = Some(latest.map_or(t, |l: f64| l.max(t)));
                }
            }
        }
        latest
    }

    /// Mark one rank's local free; returns true when all freed.
    pub fn free_local(&mut self, rank: usize) -> bool {
        self.freed_local[rank] = true;
        if self.freed_local.iter().all(|&f| f) {
            self.freed = true;
        }
        self.freed
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simmpi::types::CommId;

    #[test]
    fn read_real_exposure() {
        let mut w = WinState::new(CommId(0), 2);
        w.exposures[0] = Payload::real(vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(w.read(0, 1, 2).unwrap(), vec![2.0, 3.0]);
        // Virtual exposure yields no data.
        w.exposures[1] = Payload::virt(10);
        assert!(w.read(1, 0, 5).is_none());
    }

    #[test]
    #[should_panic(expected = "get out of range")]
    fn read_out_of_range_panics() {
        let mut w = WinState::new(CommId(0), 1);
        w.exposures[0] = Payload::virt(10);
        w.read(0, 8, 3);
    }

    #[test]
    fn flush_returns_latest_arrival() {
        let mut w = WinState::new(CommId(0), 3);
        w.track_get(7, 0, 1.0);
        w.track_get(7, 0, 3.0);
        w.track_get(7, 1, 2.0);
        w.track_get(8, 0, 9.0); // different origin
        assert_eq!(w.flush_target(7, 0), Some(3.0));
        assert_eq!(w.flush_target(7, 0), None); // drained
        assert_eq!(w.flush_all(7), Some(2.0));
        assert_eq!(w.flush_all(8), Some(9.0));
    }

    /// Regression for `det::hashmap-iter-escapes`: `pending_gets` is a
    /// `BTreeMap`, so the epoch flush visits (origin, target) pairs in
    /// key order and its result is a pure max — identical no matter in
    /// which order the Gets were tracked.
    #[test]
    fn flush_all_is_insertion_order_independent() {
        let gets = [(7usize, 2usize, 4.0), (7, 0, 1.0), (7, 1, 6.0), (7, 0, 3.0), (8, 2, 9.0)];
        let mut fwd = WinState::new(CommId(0), 3);
        let mut rev = WinState::new(CommId(0), 3);
        for &(o, t, at) in &gets {
            fwd.track_get(o, t, at);
        }
        for &(o, t, at) in gets.iter().rev() {
            rev.track_get(o, t, at);
        }
        let fk: Vec<_> = fwd.pending_gets.keys().copied().collect();
        let rk: Vec<_> = rev.pending_gets.keys().copied().collect();
        assert_eq!(fk, rk, "pending-get order must not depend on tracking order");
        assert_eq!(fwd.flush_all(7), Some(6.0));
        assert_eq!(rev.flush_all(7), Some(6.0));
        assert_eq!(fwd.flush_all(8), rev.flush_all(8));
    }

    #[test]
    fn reset_rearms_a_released_slot() {
        let mut w = WinState::new(CommId(0), 2);
        w.exposures[0] = Payload::real(vec![1.0]);
        w.mt = true;
        w.seg_elems = 4;
        w.seg_ready[0] = vec![1.0, 2.0];
        w.seg_read_done[0] = vec![3.0, 4.0];
        assert!(!w.free_local(0));
        assert!(w.free_local(1));
        w.reset(CommId(3), 3);
        assert_eq!(w.comm, CommId(3));
        assert_eq!(w.exposures.len(), 3);
        assert!(w.exposures.iter().all(|e| e.elems() == 0));
        assert!(!w.freed && !w.mt);
        assert_eq!(w.freed_local, vec![false; 3]);
        assert_eq!(w.seg_elems, 0);
        assert!(w.seg_ready.iter().all(Vec::is_empty));
        assert!(w.seg_read_done.iter().all(Vec::is_empty));
    }

    #[test]
    fn note_read_tracks_latest_arrival_per_segment() {
        let mut w = WinState::new(CommId(0), 2);
        w.exposures[0] = Payload::virt(25);
        // Unsegmented: nothing recorded.
        w.note_read(0, 0, 10, 5.0);
        assert!(w.seg_read_done[0].is_empty());
        w.seg_elems = 10; // segments: [0,10), [10,20), [20,25)
        assert_eq!(w.n_segs(0), 3);
        assert_eq!(w.n_segs(1), 0, "NULL exposures have no segments");
        w.note_read(0, 0, 10, 1.0); // seg 0
        w.note_read(0, 5, 10, 2.0); // segs 0..=1
        w.note_read(0, 22, 3, 4.0); // seg 2
        w.note_read(0, 0, 5, 0.5); // earlier read must not regress seg 0
        assert_eq!(w.seg_read_done[0], vec![2.0, 2.0, 4.0]);
        // Eligibility: max of registration-ready and last read.
        w.seg_ready[0] = vec![3.0, 1.0, 1.0];
        assert_eq!(w.dereg_eligibility(0), vec![3.0, 2.0, 4.0]);
        // A rank without a registration stream gates on reads only.
        w.seg_ready[0].clear();
        assert_eq!(w.dereg_eligibility(0), vec![2.0, 2.0, 4.0]);
        // Never-read, never-streamed segments are immediately eligible.
        w.seg_read_done[0].clear();
        assert_eq!(w.dereg_eligibility(0), vec![0.0, 0.0, 0.0]);
        assert!(w.dereg_eligibility(1).is_empty());
    }

    #[test]
    fn seg_gate_selects_the_last_touched_segment() {
        let mut w = WinState::new(CommId(0), 2);
        // Unsegmented: never gates.
        assert_eq!(w.seg_gate(0, 0, 100), None);
        w.seg_elems = 10;
        w.seg_ready[0] = vec![1.0, 2.0, 3.0];
        // Range inside segment 0.
        assert_eq!(w.seg_gate(0, 0, 10), Some(1.0));
        // Range spanning segments 0..2 gates on the last one.
        assert_eq!(w.seg_gate(0, 5, 20), Some(3.0));
        // Past-the-end ranges clamp to the last segment.
        assert_eq!(w.seg_gate(0, 25, 100), Some(3.0));
        // A target without a stream never gates.
        assert_eq!(w.seg_gate(1, 0, 10), None);
        // Registration completion is the last segment's ready time.
        assert_eq!(w.reg_done(0), Some(3.0));
        assert_eq!(w.reg_done(1), None);
    }

    #[test]
    fn notify_counts_and_arming_commute() {
        let mut w = WinState::new(CommId(0), 2);
        // Reads before arming count silently.
        assert!(w.note_notify(0, 2.0).is_empty());
        assert!(w.note_notify(0, 5.0).is_empty());
        assert_eq!(w.notify_ready(0), None, "unarmed ranks never report ready");
        // Arming after the fact sees the count already met.
        assert!(w.arm_notify(0, 2).is_empty());
        assert_eq!(w.notify_ready(0), Some(5.0));
        // Arming first, counting after.
        assert!(w.arm_notify(1, 2).is_empty());
        assert_eq!(w.notify_ready(1), None);
        assert!(w.note_notify(1, 1.0).is_empty());
        assert_eq!(w.notify_ready(1), None);
        assert!(w.note_notify(1, 3.0).is_empty());
        assert_eq!(w.notify_ready(1), Some(3.0));
        // Zero-expectation ranks (NULL exposures) are ready at once.
        let mut v = WinState::new(CommId(0), 1);
        assert!(v.arm_notify(0, 0).is_empty());
        assert_eq!(v.notify_ready(0), Some(0.0));
    }

    #[test]
    fn notify_reset_clears_counters() {
        let mut w = WinState::new(CommId(0), 2);
        w.arm_notify(0, 1);
        w.note_notify(0, 4.0);
        w.reset(CommId(1), 2);
        assert_eq!(w.notify_expected, vec![None, None]);
        assert_eq!(w.notify_seen, vec![0, 0]);
        assert_eq!(w.notify_last, vec![0.0, 0.0]);
        assert_eq!(w.notify_ready(0), None);
    }

    #[test]
    fn free_local_completes_when_all_freed() {
        let mut w = WinState::new(CommId(0), 2);
        assert!(!w.free_local(0));
        assert!(!w.freed);
        assert!(w.free_local(1));
        assert!(w.freed);
    }
}
