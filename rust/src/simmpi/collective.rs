//! Collective operations: matching state and completion schedules.
//!
//! A collective instance is keyed by `(comm, seq)` where `seq` is the
//! per-member call counter — MPI requires every member to call
//! collectives in the same order, so equal counters identify the same
//! instance.  Each arriving rank records its arrival time and
//! contribution; the *last* arriver computes the completion schedule
//! for everyone using the textbook algorithm cost over the calibrated
//! [`CostModel`] (dissemination barrier, ring allgather, pairwise
//! alltoallv) and wakes parked waiters.
//!
//! Schedules are computed arithmetically — no engine events per
//! message — which keeps the event count per collective at `O(P)`
//! instead of `O(P²)` and makes 160-rank simulations fast.

use crate::netmodel::{CostModel, Placement, TransferClass};
use crate::simcluster::{ActivityId, Time};

use super::types::Payload;

/// What a rank contributes when it enters a collective.
#[derive(Debug)]
pub(crate) enum Contrib {
    /// Barrier / Ibarrier / communicator ops: nothing.
    None,
    /// Win_create: local registration duration (already computed from
    /// the exposed size by the caller).
    RegTime(f64),
    /// Chunked pipelined Win_create: only `first` (window setup + the
    /// first segment's registration) gates the collective exit; `rest`
    /// holds the remaining segments' durations, registered in the
    /// background after the rank resumes — the pipelined-redistribution
    /// mechanism that hides registration latency behind the wire.
    /// `eager` starts the background stream at this rank's *own* fill
    /// end (`arrival + first`) instead of the collective exit: under
    /// asynchronous spawning the sources' registration streams then
    /// overlap the spawned ranks' staggered startup and merge round
    /// (pinning is local — it needs no remote participant).
    RegPipeline { first: f64, rest: Vec<f64>, eager: bool },
    /// Chunked pipelined Win_free: the closing barrier alone gates the
    /// dissemination schedule; the per-segment deregistrations (`segs`)
    /// run as a background stream gated per segment on the last read
    /// touching it (see `WinState::dereg_eligibility`), and only the
    /// stream's excess over the barrier — plus the `fixed` window
    /// teardown — lands on the rank's completion (computed by the last
    /// arriver in `MpiProc::coll_post`, which has the window state).
    DeregPipeline { segs: Vec<f64>, fixed: f64 },
    /// Allgather: this rank's block.
    Block(Payload),
    /// Alltoallv / Ialltoallv: payload destined to each member.
    Scatter(Vec<Payload>),
    /// Spawn: the process-launch durations (the spawn root supplies
    /// them).  `initiate` is how long the root itself stays blocked
    /// (it resumes early under staggered schedules to create the
    /// spawned activities); `block` is how long every other source
    /// waits.  The legacy single-constant model has the two equal.
    SpawnTime { initiate: f64, block: f64 },
}

/// Per-rank outcome of a completed collective.
#[derive(Debug, Clone)]
pub(crate) enum CollResult {
    None,
    /// Allgather: every rank's block, in rank order.
    Gathered(Vec<Payload>),
    /// Alltoallv: what this rank received from each member.
    Received(Vec<Payload>),
}

/// Which algorithm/semantics an instance uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum CollKind {
    Barrier,
    Ibarrier,
    Allgather,
    Alltoallv,
    Ialltoallv,
    WinCreate,
    WinFree,
    Spawn,
    CommSub,
}

/// One in-flight collective instance.
pub(crate) struct CollState {
    pub kind: CollKind,
    pub n: usize,
    pub arrivals: Vec<Option<Time>>,
    pub contribs: Vec<Option<Contrib>>,
    /// Per-rank completion time; `Some` once the last rank arrived.
    pub completion: Option<Vec<Time>>,
    /// Ranks parked waiting for the schedule, with their activity ids.
    pub waiters: Vec<(usize, ActivityId)>,
    /// Results, populated together with `completion`.
    pub results: Vec<Option<CollResult>>,
    /// How many ranks have consumed their result (for GC).
    pub taken: usize,
    /// Ialltoallv progress model: pack/unpack bytes left per rank.
    pub cpu_remaining: Vec<u64>,
    /// Window id allocated by the first arriver (WinCreate only).
    pub win_id: Option<super::types::WinId>,
    /// Any participant posted from an `MPI_THREAD_MULTIPLE` context
    /// (auxiliary thread alive): the completion schedule is stretched
    /// by `mt_coll_penalty` — MPICH 4.2.0's degraded multithreaded
    /// progress (§V-D).
    pub mt: bool,
}

impl CollState {
    pub fn new(kind: CollKind, n: usize) -> CollState {
        CollState {
            kind,
            n,
            arrivals: vec![None; n],
            contribs: (0..n).map(|_| None).collect(),
            completion: None,
            waiters: Vec::new(),
            results: vec![None; n],
            taken: 0,
            cpu_remaining: vec![0; n],
            win_id: None,
            mt: false,
        }
    }

    pub fn all_arrived(&self) -> bool {
        self.arrivals.iter().all(|a| a.is_some())
    }

    /// Ranks that have not arrived yet.  A setup closure observing
    /// `pending_arrivals() == 1` is running on the *last* arriver
    /// (setup runs before that rank's own `arrive`).
    pub fn pending_arrivals(&self) -> usize {
        self.arrivals.iter().filter(|a| a.is_none()).count()
    }

    /// Record one rank's arrival; returns true if it was the last.
    pub fn arrive(&mut self, rank: usize, t: Time, contrib: Contrib) -> bool {
        assert!(self.arrivals[rank].is_none(), "rank {rank} re-entered collective");
        self.arrivals[rank] = Some(t);
        self.contribs[rank] = Some(contrib);
        self.all_arrived()
    }

    /// Compute per-rank completion times and results.  Called exactly
    /// once, by the last arriver, under the world lock.
    pub fn schedule(&mut self, cost: &mut CostModel, placement: &Placement, gpids: &[usize]) {
        assert!(self.all_arrived());
        assert!(self.completion.is_none());
        let arrivals: Vec<Time> = self.arrivals.iter().map(|a| a.unwrap()).collect();
        let (completion, results) = match self.kind {
            CollKind::Barrier | CollKind::Ibarrier | CollKind::CommSub => {
                let t = dissemination(cost, placement, gpids, &arrivals);
                (t, vec![CollResult::None; self.n])
            }
            CollKind::Allgather => {
                let blocks: Vec<Payload> = self
                    .contribs
                    .iter()
                    .map(|c| match c {
                        Some(Contrib::Block(p)) => p.clone(),
                        _ => panic!("allgather without Block contribution"),
                    })
                    .collect();
                // MPICH: recursive doubling for small blocks, ring for
                // bandwidth-bound large ones.
                let max_bytes = blocks.iter().map(|b| b.bytes()).max().unwrap_or(0);
                let t = if max_bytes * self.n as u64 <= cost.params.eager_threshold {
                    rd_allgather(cost, placement, gpids, &arrivals, &blocks)
                } else {
                    ring_allgather(cost, placement, gpids, &arrivals, &blocks)
                };
                let gathered = CollResult::Gathered(blocks);
                (t, vec![gathered; self.n])
            }
            CollKind::Alltoallv | CollKind::Ialltoallv => {
                let sends: Vec<&Vec<Payload>> = self
                    .contribs
                    .iter()
                    .map(|c| match c {
                        Some(Contrib::Scatter(v)) => v,
                        _ => panic!("alltoallv without Scatter contribution"),
                    })
                    .collect();
                let t = pairwise_alltoallv(cost, placement, gpids, &arrivals, &sends);
                // results[i] = column i of the send matrix.
                let results = (0..self.n)
                    .map(|i| CollResult::Received(sends.iter().map(|row| row[i].clone()).collect()))
                    .collect();
                if self.kind == CollKind::Ialltoallv {
                    // Progress-model CPU work: pack+unpack of non-self bytes.
                    for i in 0..self.n {
                        let sent: u64 = sends[i]
                            .iter()
                            .enumerate()
                            .filter(|(j, _)| *j != i)
                            .map(|(_, p)| p.bytes())
                            .sum();
                        let recvd: u64 = sends
                            .iter()
                            .enumerate()
                            .filter(|(j, _)| *j != i)
                            .map(|(_, row)| row[i].bytes())
                            .sum();
                        self.cpu_remaining[i] = sent + recvd;
                    }
                }
                (t, results)
            }
            CollKind::WinCreate => {
                // All ranks pin locally in parallel after arriving, then
                // exchange rkeys (dissemination-style sync).  Everyone
                // leaves at the same instant — Win_create is collective
                // blocking, the paper's central RMA pain point.  A
                // pipelined contribution gates the exit on its *first*
                // segment only; the rest registers after the exit.
                let regs: Vec<f64> = self
                    .contribs
                    .iter()
                    .map(|c| match c {
                        Some(Contrib::RegTime(r)) => *r,
                        Some(Contrib::RegPipeline { first, .. }) => *first,
                        _ => panic!("win_create without RegTime"),
                    })
                    .collect();
                let ready: Vec<Time> = arrivals
                    .iter()
                    .zip(&regs)
                    .map(|(a, r)| a + r)
                    .collect();
                let t = dissemination(cost, placement, gpids, &ready);
                (t, vec![CollResult::None; self.n])
            }
            CollKind::WinFree => {
                // Deregistration after a closing barrier.  Pipelined
                // contributions add nothing here: their per-segment
                // stream is reconciled against the window's read/
                // registration record by the last arriver (coll_post),
                // which raises the rank's completion only by the
                // stream's residual.
                let t0 = dissemination(cost, placement, gpids, &arrivals);
                let t = t0
                    .iter()
                    .zip(self.contribs.iter())
                    .map(|(t, c)| match c {
                        Some(Contrib::RegTime(r)) => t + r,
                        Some(Contrib::DeregPipeline { .. }) => *t,
                        _ => *t,
                    })
                    .collect();
                (t, vec![CollResult::None; self.n])
            }
            CollKind::Spawn => {
                // The spawn root (the rank that posted SpawnTime) may
                // resume earlier than the other sources: under a
                // staggered schedule it creates the spawned activities
                // and then advances to the common release point itself.
                let (root, initiate, block) = self
                    .contribs
                    .iter()
                    .enumerate()
                    .find_map(|(r, c)| match c {
                        Some(Contrib::SpawnTime { initiate, block }) => {
                            Some((r, *initiate, *block))
                        }
                        _ => None,
                    })
                    .unwrap_or((0, 0.0, 0.0));
                let sync = dissemination(cost, placement, gpids, &arrivals);
                let t = sync
                    .iter()
                    .enumerate()
                    .map(|(r, t)| t + if r == root { initiate } else { block })
                    .collect();
                (t, vec![CollResult::None; self.n])
            }
        };
        // MPICH MPI_THREAD_MULTIPLE degradation (§V-D): the whole
        // operation crawls under the contended global lock.
        let completion = if self.mt {
            let pen = cost.params.mt_coll_penalty;
            completion
                .iter()
                .zip(&arrivals)
                .map(|(c, a)| a + (c - a).max(0.0) * pen)
                .collect()
        } else {
            completion
        };
        self.completion = Some(completion);
        self.results = results.into_iter().map(Some).collect();
    }

    pub fn completion_of(&self, rank: usize) -> Option<Time> {
        self.completion.as_ref().map(|c| c[rank])
    }
}

// ---------------------------------------------------------------------
// Algorithm schedules
// ---------------------------------------------------------------------

/// Dissemination barrier: ⌈log2 n⌉ rounds; in round k rank i sends to
/// (i + 2^k) mod n and receives from (i − 2^k) mod n.  Returns per-rank
/// completion times.
pub fn dissemination(
    cost: &mut CostModel,
    placement: &Placement,
    gpids: &[usize],
    arrivals: &[Time],
) -> Vec<Time> {
    let n = gpids.len();
    if n <= 1 {
        return arrivals.to_vec();
    }
    let mut t = arrivals.to_vec();
    let rounds = usize::BITS - (n - 1).leading_zeros();
    for k in 0..rounds {
        let dist = 1usize << k;
        let prev = t.clone();
        for i in 0..n {
            let from = (i + n - dist % n) % n;
            let tt = cost.transfer(
                prev[from],
                placement,
                gpids[from],
                gpids[i],
                16, // 16-byte control message
                TransferClass::TwoSided,
            );
            t[i] = t[i].max(tt.arrival);
        }
    }
    t
}

/// Recursive-doubling allgather (MPICH's algorithm for small blocks):
/// ⌈log2 n⌉ rounds; in round k rank i exchanges its accumulated 2^k
/// blocks with partner i⊕2^k.  Small-lane messages, so the rounds see
/// the bounded contention wait when bulk redistribution traffic is in
/// flight — the source of the paper's ω growth (§V-C, Fig. 5).
pub fn rd_allgather(
    cost: &mut CostModel,
    placement: &Placement,
    gpids: &[usize],
    arrivals: &[Time],
    blocks: &[Payload],
) -> Vec<Time> {
    let n = gpids.len();
    if n <= 1 {
        return arrivals.to_vec();
    }
    let mut t = arrivals.to_vec();
    let avg_bytes = (blocks.iter().map(|b| b.bytes()).sum::<u64>() / n as u64).max(1);
    let rounds = usize::BITS - (n - 1).leading_zeros();
    for k in 0..rounds {
        let prev = t.clone();
        for i in 0..n {
            let partner = i ^ (1usize << k);
            if partner >= n {
                continue; // non-power-of-two remainder: approximate
            }
            let bytes = avg_bytes.saturating_mul(1 << k);
            let tt = cost.transfer(
                prev[i].max(prev[partner]),
                placement,
                gpids[partner],
                gpids[i],
                bytes,
                TransferClass::TwoSided,
            );
            t[i] = t[i].max(tt.arrival);
        }
    }
    t
}

/// Ring allgather: n−1 rounds; each round rank i sends the block it
/// received last round to (i+1) mod n.
pub fn ring_allgather(
    cost: &mut CostModel,
    placement: &Placement,
    gpids: &[usize],
    arrivals: &[Time],
    blocks: &[Payload],
) -> Vec<Time> {
    let n = gpids.len();
    if n <= 1 {
        return arrivals.to_vec();
    }
    let mut t = arrivals.to_vec();
    for round in 0..(n - 1) {
        let prev = t.clone();
        for i in 0..n {
            let from = (i + n - 1) % n;
            // Block originating at (from - round) mod n travels this hop.
            let origin = (from + n - (round % n)) % n;
            let bytes = blocks[origin].bytes().max(1);
            let tt = cost.transfer(
                prev[from].max(prev[i]),
                placement,
                gpids[from],
                gpids[i],
                bytes,
                TransferClass::TwoSided,
            );
            t[i] = t[i].max(tt.arrival);
        }
    }
    t
}

/// Pairwise-exchange alltoallv: n−1 rounds of ring-shifted exchanges,
/// plus the local self-copy.  `sends[i][j]` is what i sends to j.
pub fn pairwise_alltoallv(
    cost: &mut CostModel,
    placement: &Placement,
    gpids: &[usize],
    arrivals: &[Time],
    sends: &[&Vec<Payload>],
) -> Vec<Time> {
    let n = gpids.len();
    let mut t = arrivals.to_vec();
    // Sender injection chains (the NIC fluid queues in `CostModel`
    // provide the contention; rounds are NOT barriers — MPICH posts the
    // next exchange as soon as the local send completes, so sparse
    // resize patterns run at aggregate NIC bandwidth).
    let mut cpu = arrivals.to_vec();
    for i in 0..n {
        let bytes = sends[i][i].bytes();
        if bytes > 0 {
            cpu[i] += cost.memcpy_time(bytes);
            t[i] = t[i].max(cpu[i]);
        }
    }
    for round in 1..n {
        for i in 0..n {
            let dst = (i + round) % n;
            let bytes = sends[i][dst].bytes();
            if bytes == 0 {
                continue;
            }
            let tt = cost.transfer(
                cpu[i],
                placement,
                gpids[i],
                gpids[dst],
                bytes,
                TransferClass::TwoSided,
            );
            // Sender occupied until its CPU is done; receiver until arrival.
            cpu[i] = tt.cpu_done;
            t[i] = t[i].max(tt.cpu_done);
            t[dst] = t[dst].max(tt.arrival);
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netmodel::{NetParams, Topology};

    fn setup(n_ranks: usize) -> (CostModel, Placement, Vec<usize>) {
        let topo = Topology::new(4, 8);
        let placement = Placement::block(&topo, n_ranks);
        let gpids = (0..n_ranks).collect();
        (CostModel::new(NetParams::test_simple(), 4), placement, gpids)
    }

    #[test]
    fn dissemination_single_rank_is_noop() {
        let (mut cost, pl, g) = setup(1);
        let t = dissemination(&mut cost, &pl, &g[..1], &[3.0]);
        assert_eq!(t, vec![3.0]);
    }

    #[test]
    fn dissemination_completion_after_last_arrival() {
        let (mut cost, pl, g) = setup(8);
        let arrivals: Vec<Time> = (0..8).map(|i| i as f64 * 0.01).collect();
        let t = dissemination(&mut cost, &pl, &g, &arrivals);
        let last = 0.07;
        for ti in &t {
            assert!(*ti >= last, "barrier exit {ti} before last arrival");
        }
        // log2(8)=3 rounds of small messages: bounded overhead.
        for ti in &t {
            assert!(*ti < last + 0.1, "barrier too slow: {ti}");
        }
    }

    #[test]
    fn ring_allgather_costs_grow_with_block_size() {
        let (mut cost, pl, g) = setup(4);
        let small: Vec<Payload> = (0..4).map(|_| Payload::virt(10)).collect();
        let t_small = ring_allgather(&mut cost, &pl, &g, &[0.0; 4], &small);
        let mut cost2 = CostModel::new(NetParams::test_simple(), 4);
        let big: Vec<Payload> = (0..4).map(|_| Payload::virt(1_000_000)).collect();
        let t_big = ring_allgather(&mut cost2, &pl, &g, &[0.0; 4], &big);
        assert!(t_big[0] > t_small[0] * 2.0);
    }

    #[test]
    fn pairwise_moves_all_data() {
        let (mut cost, pl, g) = setup(3);
        let row0 = vec![Payload::virt(0), Payload::virt(100), Payload::virt(100)];
        let row1 = vec![Payload::virt(100), Payload::virt(0), Payload::virt(100)];
        let row2 = vec![Payload::virt(100), Payload::virt(100), Payload::virt(0)];
        let sends = [&row0, &row1, &row2];
        let t = pairwise_alltoallv(&mut cost, &pl, &g, &[0.0; 3], &sends);
        for ti in &t {
            assert!(*ti > 0.0);
        }
    }

    #[test]
    fn empty_sends_are_nearly_free() {
        let (mut cost, pl, g) = setup(4);
        let zero = vec![Payload::virt(0); 4];
        let sends = [&zero, &zero, &zero, &zero];
        let t = pairwise_alltoallv(&mut cost, &pl, &g, &[1.0; 4], &sends);
        for ti in &t {
            assert!((ti - 1.0).abs() < 1e-9);
        }
    }

    #[test]
    fn coll_state_lifecycle() {
        let (mut cost, pl, g) = setup(2);
        let mut cs = CollState::new(CollKind::Barrier, 2);
        assert!(!cs.arrive(0, 0.0, Contrib::None));
        assert!(cs.completion_of(0).is_none());
        assert!(cs.arrive(1, 1.0, Contrib::None));
        cs.schedule(&mut cost, &pl, &g);
        assert!(cs.completion_of(0).unwrap() >= 1.0);
        assert!(cs.completion_of(1).unwrap() >= 1.0);
    }

    #[test]
    #[should_panic(expected = "re-entered")]
    fn double_arrival_panics() {
        let mut cs = CollState::new(CollKind::Barrier, 2);
        cs.arrive(0, 0.0, Contrib::None);
        cs.arrive(0, 0.5, Contrib::None);
    }

    #[test]
    fn win_create_waits_for_slowest_registration() {
        let (mut cost, pl, g) = setup(2);
        let mut cs = CollState::new(CollKind::WinCreate, 2);
        cs.arrive(0, 0.0, Contrib::RegTime(5.0));
        cs.arrive(1, 0.0, Contrib::RegTime(0.1));
        cs.schedule(&mut cost, &pl, &g);
        // Both leave only after the 5 s registration.
        assert!(cs.completion_of(0).unwrap() >= 5.0);
        assert!(cs.completion_of(1).unwrap() >= 5.0);
    }

    #[test]
    fn pipelined_win_create_gates_on_the_first_segment_only() {
        let (mut cost, pl, g) = setup(2);
        let mut cs = CollState::new(CollKind::WinCreate, 2);
        // Pipelined source: 0.1 s fill, 5 s of background segments.
        cs.arrive(
            0,
            0.0,
            Contrib::RegPipeline { first: 0.1, rest: vec![2.5, 2.5], eager: false },
        );
        cs.arrive(1, 0.0, Contrib::RegTime(0.05));
        cs.schedule(&mut cost, &pl, &g);
        // Exit is gated by the 0.1 s fill, not the 5 s stream.
        assert!(cs.completion_of(0).unwrap() < 1.0);
        assert!(cs.completion_of(1).unwrap() < 1.0);
        assert!(cs.completion_of(0).unwrap() >= 0.1);
    }

    #[test]
    fn win_free_dereg_pipeline_gates_on_the_barrier_only() {
        let (mut cost, pl, g) = setup(2);
        // Blocking free: barrier + the full serial deregistration.
        let mut blocking = CollState::new(CollKind::WinFree, 2);
        blocking.arrive(0, 0.0, Contrib::RegTime(5.0));
        blocking.arrive(1, 0.0, Contrib::RegTime(0.0));
        blocking.schedule(&mut cost, &pl, &g);
        let b0 = blocking.completion_of(0).unwrap();
        // Pipelined free: the same 5 s of deregistration rides in the
        // background — the schedule itself charges the barrier only
        // (the residual is reconciled later by the last arriver).
        let (mut cost2, pl2, g2) = setup(2);
        let mut piped = CollState::new(CollKind::WinFree, 2);
        piped.arrive(0, 0.0, Contrib::DeregPipeline { segs: vec![2.5, 2.5], fixed: 0.0 });
        piped.arrive(1, 0.0, Contrib::RegTime(0.0));
        piped.schedule(&mut cost2, &pl2, &g2);
        let p0 = piped.completion_of(0).unwrap();
        assert!(p0 < 1.0, "pipelined free must not serialize the dereg: {p0}");
        assert!(b0 >= 5.0, "blocking free must serialize the dereg: {b0}");
        assert_eq!(
            piped.completion_of(1).unwrap().to_bits(),
            blocking.completion_of(1).unwrap().to_bits(),
            "non-pipelined participants see the same barrier"
        );
    }

    #[test]
    fn ialltoallv_sets_cpu_work() {
        let (mut cost, pl, g) = setup(2);
        let mut cs = CollState::new(CollKind::Ialltoallv, 2);
        let row0 = vec![Payload::virt(5), Payload::virt(100)];
        let row1 = vec![Payload::virt(200), Payload::virt(7)];
        cs.arrive(0, 0.0, Contrib::Scatter(row0));
        cs.arrive(1, 0.0, Contrib::Scatter(row1));
        cs.schedule(&mut cost, &pl, &g);
        // rank0: sends 100 elems, receives 200 → (100+200)*8 bytes.
        assert_eq!(cs.cpu_remaining[0], 300 * 8);
        assert_eq!(cs.cpu_remaining[1], 300 * 8);
        match cs.results[0].as_ref().unwrap() {
            CollResult::Received(v) => {
                assert_eq!(v[0].elems(), 5);
                assert_eq!(v[1].elems(), 200);
            }
            _ => panic!("wrong result kind"),
        }
    }
}
