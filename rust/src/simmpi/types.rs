//! Core identifiers and the data payload abstraction.

use std::sync::{Arc, Mutex};

/// Element size of all simulated application data (f64).
pub const ELEM_BYTES: u64 = 8;

/// Communicator handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct CommId(pub usize);

/// RMA window handle.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct WinId(pub usize);

/// Runtime errors (programming errors panic instead, like real MPI
/// aborts).
#[derive(Debug)]
pub enum MpiError {
    NotInComm { rank: usize, comm: CommId },
    WindowFreed(WinId),
    UnknownRequest(usize),
}

impl std::fmt::Display for MpiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MpiError::NotInComm { rank, comm } => {
                write!(f, "rank {rank} is not a member of communicator {comm:?}")
            }
            MpiError::WindowFreed(w) => write!(f, "window {w:?} already freed"),
            MpiError::UnknownRequest(r) => write!(f, "request {r} not found"),
        }
    }
}

impl std::error::Error for MpiError {}

/// Application data travelling through the runtime.
///
/// `Virtual` payloads carry only a size — the DES moves "bytes" at
/// modeled cost, which is how the paper-scale 64 GB experiments run in
/// milliseconds.  `Real` payloads carry actual f64 data that is copied
/// end-to-end, letting integration tests verify redistribution
/// *correctness* bit-for-bit.  Control flow is identical for both
/// (DESIGN.md §1).
#[derive(Clone, Debug)]
pub enum Payload {
    Virtual { elems: u64 },
    Real(Arc<Vec<f64>>),
}

impl Payload {
    pub fn virt(elems: u64) -> Payload {
        Payload::Virtual { elems }
    }

    pub fn real(data: Vec<f64>) -> Payload {
        Payload::Real(Arc::new(data))
    }

    pub fn elems(&self) -> u64 {
        match self {
            Payload::Virtual { elems } => *elems,
            Payload::Real(v) => v.len() as u64,
        }
    }

    pub fn bytes(&self) -> u64 {
        self.elems() * ELEM_BYTES
    }

    pub fn is_real(&self) -> bool {
        matches!(self, Payload::Real(_))
    }

    /// Sub-range view `[off, off+len)`; clones data for real payloads.
    pub fn slice(&self, off: u64, len: u64) -> Payload {
        match self {
            Payload::Virtual { elems } => {
                assert!(off + len <= *elems, "slice out of range");
                Payload::Virtual { elems: len }
            }
            Payload::Real(v) => {
                let (off, len) = (off as usize, len as usize);
                assert!(off + len <= v.len(), "slice out of range");
                Payload::Real(Arc::new(v[off..off + len].to_vec()))
            }
        }
    }

    /// Concatenate payloads (all must be the same mode).
    pub fn concat(parts: &[Payload]) -> Payload {
        assert!(!parts.is_empty());
        if parts.iter().all(|p| p.is_real()) {
            let mut out = Vec::new();
            for p in parts {
                if let Payload::Real(v) = p {
                    out.extend_from_slice(v);
                }
            }
            Payload::real(out)
        } else {
            Payload::virt(parts.iter().map(|p| p.elems()).sum())
        }
    }

    /// View as a slice (real payloads only).
    pub fn as_slice(&self) -> Option<&[f64]> {
        match self {
            Payload::Real(v) => Some(v),
            Payload::Virtual { .. } => None,
        }
    }
}

/// Options for the unified window-creation entrypoints
/// (`MpiProc::win_create_with` / `MpiProc::win_acquire_with`) — the
/// single knob set the old `win_create` / `win_create_pipelined` /
/// `win_create_pipelined_opts` trio spread over three signatures.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WinCreateOpts {
    /// Segment size (elements) for chunked pipelined registration;
    /// `0` registers the whole exposure inside the collective (the
    /// seed blocking path, bit-identical).
    pub chunk_elems: u64,
    /// Start this rank's background registration stream at its *own*
    /// fill end instead of the collective exit (pinning is local), so
    /// under asynchronous spawning source streams overlap spawned-rank
    /// startup.  Only meaningful when `chunk_elems > 0`.
    pub eager_reg: bool,
}

impl WinCreateOpts {
    /// The seed blocking registration (whole exposure in-collective).
    pub fn blocking() -> WinCreateOpts {
        WinCreateOpts::default()
    }

    /// Chunked pipelined registration with `chunk_elems`-element
    /// segments (`0` falls back to blocking).
    pub fn pipelined(chunk_elems: u64) -> WinCreateOpts {
        WinCreateOpts { chunk_elems, eager_reg: false }
    }

    /// Set the eager stream-start policy.
    pub fn eager(mut self, eager: bool) -> WinCreateOpts {
        self.eager_reg = eager;
        self
    }
}

/// Completion-synchronization mode of one redistribution epoch.
///
/// `Epoch` is the paper's passive-target pattern: drains bracket their
/// Gets in `Win_lock`/`Win_unlock` (or `lock_all`) and teardown closes
/// with a collective.  `Notify` models notified access (Quo Vadis MPI
/// RMA?): each Get flags a per-target notification counter, drains
/// complete through plain request waits, and sources tear their window
/// down as soon as their own exposure's expected notification count is
/// reached — no epochs, no closing collective.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum RmaSync {
    /// Passive-target epochs + collective teardown (seed behavior).
    #[default]
    Epoch,
    /// Per-segment notification counters; local notified teardown.
    Notify,
}

impl RmaSync {
    pub fn parse(s: &str) -> Option<RmaSync> {
        match s.to_ascii_lowercase().as_str() {
            "epoch" | "epochs" => Some(RmaSync::Epoch),
            "notify" | "notified" => Some(RmaSync::Notify),
            _ => None,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            RmaSync::Epoch => "epoch",
            RmaSync::Notify => "notify",
        }
    }

    pub fn all() -> [RmaSync; 2] {
        [RmaSync::Epoch, RmaSync::Notify]
    }
}

/// A destination buffer that deferred one-sided reads (Rget) write
/// into at completion time.  `None` inside = virtual mode.
pub type RecvBuf = Arc<Mutex<Option<Vec<f64>>>>;

/// Allocate a real receive buffer of `n` zeros.
pub fn recv_buf_real(n: usize) -> RecvBuf {
    Arc::new(Mutex::new(Some(vec![0.0; n])))
}

/// Allocate a virtual receive buffer.
pub fn recv_buf_virtual() -> RecvBuf {
    Arc::new(Mutex::new(None))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_sizes() {
        assert_eq!(Payload::virt(10).elems(), 10);
        assert_eq!(Payload::virt(10).bytes(), 80);
        let p = Payload::real(vec![1.0, 2.0, 3.0]);
        assert_eq!(p.elems(), 3);
        assert_eq!(p.bytes(), 24);
        assert!(p.is_real());
        assert!(!Payload::virt(1).is_real());
    }

    #[test]
    fn slice_real() {
        let p = Payload::real(vec![0.0, 1.0, 2.0, 3.0, 4.0]);
        let s = p.slice(1, 3);
        assert_eq!(s.as_slice().unwrap(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn slice_virtual() {
        let p = Payload::virt(100);
        assert_eq!(p.slice(40, 25).elems(), 25);
    }

    #[test]
    #[should_panic(expected = "slice out of range")]
    fn slice_out_of_range_panics() {
        Payload::virt(10).slice(5, 6);
    }

    #[test]
    fn concat_mixed_goes_virtual() {
        let c = Payload::concat(&[Payload::real(vec![1.0]), Payload::virt(2)]);
        assert!(!c.is_real());
        assert_eq!(c.elems(), 3);
    }

    #[test]
    fn rma_sync_parse_roundtrips_labels() {
        for s in RmaSync::all() {
            assert_eq!(RmaSync::parse(s.label()), Some(s));
        }
        assert_eq!(RmaSync::parse("notified"), Some(RmaSync::Notify));
        assert_eq!(RmaSync::parse("fence"), None);
        assert_eq!(RmaSync::default(), RmaSync::Epoch);
    }

    #[test]
    fn concat_real_preserves_order() {
        let c = Payload::concat(&[
            Payload::real(vec![1.0, 2.0]),
            Payload::real(vec![3.0]),
        ]);
        assert_eq!(c.as_slice().unwrap(), &[1.0, 2.0, 3.0]);
    }
}
