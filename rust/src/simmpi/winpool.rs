//! Persistent RMA window pool (§VI future work: amortizing the
//! `Win_create` initialization cost).
//!
//! The paper's conclusion is that one-sided redistribution matches the
//! collective baseline *except* for the window-initialization overhead
//! charged at every reconfiguration: `MPI_Win_create` must pin
//! (`ibv_reg_mr`) every exposed byte.  Pinning, however, is a property
//! of the **buffer**, not of the window object — memory that stays
//! registered with the NIC can back a new window for the price of the
//! fixed setup (rkey exchange, bookkeeping) alone.
//!
//! This module models exactly that split, with explicit warm/cold
//! accounting in virtual time:
//!
//! * a **registration cache** keyed by `(gpid, pin token)` → pinned
//!   size-class.  A rank's acquire is *warm* when the pin token's
//!   cached class covers the new exposure; only *cold* acquires charge
//!   `beta_register × bytes` (see [`CostModel::window_acquire`]).
//!   Size-classes are power-of-two byte buckets so a slightly smaller
//!   re-exposure still reuses the pinned region.
//! * a **free list** of released, epoch-capable [`WinState`] slots
//!   keyed by `(communicator, size-class)`.  `win_acquire` reuses a
//!   pooled slot instead of growing the window table; `win_release`
//!   returns the slot without deregistering.
//!
//! The pool is pure mechanism: `MpiProc::win_create`/`win_free` (the
//! paper's cold path) never touch it, so pool-off behaviour is
//! bit-identical to the seed model.  Policy — which MaM registry
//! entries pin their windows — lives in [`crate::mam::winpool`].
//!
//! [`CostModel::window_acquire`]: crate::netmodel::CostModel::window_acquire
//! [`WinState`]: super::rma::WinState

use std::collections::BTreeMap;

use super::types::{CommId, WinId};

/// Power-of-two size class of an exposure: smallest `c` with
/// `2^c >= bytes` (0 for empty exposures).
pub fn size_class(bytes: u64) -> u32 {
    if bytes <= 1 {
        0
    } else {
        u64::BITS - (bytes - 1).leading_zeros()
    }
}

/// Warm/cold accounting of the pool, in counts and virtual seconds.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WinPoolStats {
    /// Acquires that paid the full registration cost.
    pub cold_acquires: u64,
    /// Acquires satisfied from the registration cache.
    pub warm_acquires: u64,
    /// Windows returned to the free list by `win_release`.
    pub releases: u64,
    /// Pooled `WinState` slots reused (vs freshly allocated).
    pub slot_reuses: u64,
    /// Virtual seconds of registration charged by cold acquires.
    pub cold_reg_time: f64,
    /// Virtual seconds of registration *avoided* by warm acquires
    /// (what the cold path would have charged, minus the warm attach).
    pub warm_reg_saved: f64,
    /// Register-on-receive pre-pins (MaM pinning a freshly received
    /// block off the collective critical path).
    pub pre_pins: u64,
    /// Virtual seconds charged by those pre-pins (local, overlappable).
    pub pre_pin_time: f64,
    /// Pins evicted by the per-rank LRU cap (`win_pool_cap`).
    pub evictions: u64,
    /// Virtual seconds spent deregistering evicted pins.
    pub evict_dereg_time: f64,
    /// Segments registered cold by pipelined acquires (`--rma-chunk`).
    pub seg_cold_regs: u64,
    /// Segments skipped warm by pipelined acquires (per-segment
    /// warmth: a previous pin covered them).
    pub seg_warm_regs: u64,
    /// Pins invalidated by an aborted resize (`FaultPlan` rollback):
    /// a half-registered window must not be treated as warm later.
    pub poisoned: u64,
}

/// One pinned token: its covered size class and an LRU stamp.
#[derive(Clone, Copy, Debug)]
struct PinEntry {
    class: u32,
    stamp: u64,
    /// Absolute virtual time at which the token's background
    /// registration stream finishes (0.0 = registered synchronously).
    /// A pipelined acquire records it after the collective resolves;
    /// an LRU eviction must not deregister segments that are still
    /// being pinned, so the victim's background dereg stream starts
    /// only past this instant.
    reg_done_at: f64,
}

/// What an LRU eviction hands back to the evicting rank: the victim's
/// pinned-region size (size-class bytes, for the dereg charge) and the
/// absolute time its in-flight registration stream completes (0.0 if
/// none) — the dereg cannot begin before that instant.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EvictedPin {
    pub bytes: u64,
    pub reg_done_at: f64,
}

/// The world-global window pool (one per [`MpiWorld`]).
///
/// [`MpiWorld`]: super::world::MpiWorld
#[derive(Clone, Debug, Default)]
pub struct WinPool {
    /// Registration cache: (gpid, pin token) → pinned size class + LRU
    /// stamp.  BTreeMaps keep every lookup order-deterministic — the
    /// DES guarantees bit-identical reruns and the pool must not break
    /// that.
    pinned: BTreeMap<(usize, u64), PinEntry>,
    /// Monotone LRU clock (incremented on every pin/touch).
    tick: u64,
    /// Released window slots: (comm, size class) → slot ids.
    free: BTreeMap<(CommId, u32), Vec<WinId>>,
    /// Monotone id source for the background `evictdereg-*` engine
    /// activities (unique, deterministic names).
    evict_seq: u64,
    stats: WinPoolStats,
}

impl WinPool {
    pub fn new() -> WinPool {
        WinPool::default()
    }

    /// Is an acquire of `bytes` under `token` warm for `gpid`?  Empty
    /// exposures (`NULL`, the drain side of Alg. 2 L3) are always warm:
    /// there is nothing to register.
    pub fn is_warm(&self, gpid: usize, token: u64, bytes: u64) -> bool {
        bytes == 0
            || self
                .pinned
                .get(&(gpid, token))
                .is_some_and(|e| e.class >= size_class(bytes))
    }

    /// Leading bytes of a buffer under `token` that a previous pin
    /// still covers for `gpid` (0 = nothing pinned).  Pipelined
    /// acquires use this for *per-segment* warmth: a re-exposure larger
    /// than the cached class is cold only for the tail segments — the
    /// pinned prefix rides the cache, exactly like [`WinPool::is_warm`]
    /// does for whole exposures (`bytes <= 2^class`).
    pub fn warm_prefix_bytes(&self, gpid: usize, token: u64) -> u64 {
        self.pinned
            .get(&(gpid, token))
            .map_or(0, |e| 1u64.checked_shl(e.class).unwrap_or(u64::MAX))
    }

    /// Account one pipelined acquire's segment split.
    pub fn note_pipelined(&mut self, cold_segs: u64, warm_segs: u64) {
        self.stats.seg_cold_regs += cold_segs;
        self.stats.seg_warm_regs += warm_segs;
    }

    /// Refresh a token's LRU recency (warm hits keep their pin young).
    pub fn touch(&mut self, gpid: usize, token: u64) {
        self.tick += 1;
        let tick = self.tick;
        if let Some(e) = self.pinned.get_mut(&(gpid, token)) {
            e.stamp = tick;
        }
    }

    /// Record a cold registration: the token now covers `bytes`.
    /// `cap` bounds how many tokens `gpid` may keep pinned
    /// (0 = unbounded); beyond it the least-recently-used token of
    /// this rank is evicted — deregistered, so its next acquire is
    /// cold again.  Returns every evicted token's pinned-region size
    /// and in-flight registration deadline so the caller can launch
    /// the deregistration (after any remaining pinning) as a
    /// background stream.
    pub fn record_pin(
        &mut self,
        gpid: usize,
        token: u64,
        bytes: u64,
        cap: usize,
    ) -> Vec<EvictedPin> {
        let class = size_class(bytes);
        self.tick += 1;
        let stamp = self.tick;
        let e = self
            .pinned
            .entry((gpid, token))
            .or_insert(PinEntry { class, stamp, reg_done_at: 0.0 });
        e.class = e.class.max(class);
        e.stamp = stamp;
        // A re-pin starts a fresh registration; any previously recorded
        // stream deadline is stale until the caller re-records it.
        e.reg_done_at = 0.0;
        let mut evicted = Vec::new();
        if cap == 0 {
            return evicted;
        }
        loop {
            let mine = self
                .pinned
                .range((gpid, u64::MIN)..=(gpid, u64::MAX))
                .count();
            if mine <= cap {
                break;
            }
            // Evict this rank's least-recently-used token (never the
            // one just pinned — it carries the freshest stamp).
            let victim = self
                .pinned
                .range((gpid, u64::MIN)..=(gpid, u64::MAX))
                .min_by_key(|(_, e)| e.stamp)
                .map(|(&k, e)| (k, *e))
                .expect("over-cap cache cannot be empty");
            self.pinned.remove(&victim.0);
            evicted.push(EvictedPin {
                bytes: 1u64.checked_shl(victim.1.class).unwrap_or(u64::MAX),
                reg_done_at: victim.1.reg_done_at,
            });
            self.stats.evictions += 1;
        }
        evicted
    }

    /// Record when a token's background registration stream completes
    /// (pipelined acquires call this once the collective resolves the
    /// stream's absolute times).  Idempotent per pin; keeps the latest.
    pub fn set_reg_done(&mut self, gpid: usize, token: u64, at: f64) {
        if let Some(e) = self.pinned.get_mut(&(gpid, token)) {
            e.reg_done_at = e.reg_done_at.max(at);
        }
    }

    /// Drop every pin of `gpid` (process retirement: its memory is
    /// gone, a later process reusing the gpid must re-register).
    pub fn unpin_all(&mut self, gpid: usize) {
        self.pinned.retain(|&(g, _), _| g != gpid);
    }

    /// Poison every rank's pin of `token` (abort-and-rollback): an
    /// aborted resize may have left the structure's registration
    /// half-complete on any subset of ranks, and pins survive
    /// `retire_proc` only for ranks that stay — so the safe
    /// invalidation is global per structure.  The next acquire under
    /// the token is cold (rebuilt, not replayed).  Returns the number
    /// of pins dropped.
    pub fn poison_token(&mut self, token: u64) -> u64 {
        let before = self.pinned.len();
        self.pinned.retain(|&(_, t), _| t != token);
        let dropped = (before - self.pinned.len()) as u64;
        self.stats.poisoned += dropped;
        dropped
    }

    /// Account one acquire.  `saved` is the registration time a warm
    /// acquire avoided (cold charge minus warm attach).
    pub fn note_acquire(&mut self, warm: bool, charged: f64, saved: f64) {
        if warm {
            self.stats.warm_acquires += 1;
            self.stats.warm_reg_saved += saved;
        } else {
            self.stats.cold_acquires += 1;
            self.stats.cold_reg_time += charged;
        }
    }

    /// Account one register-on-receive pre-pin of `dt` virtual seconds.
    pub fn note_pre_pin(&mut self, dt: f64) {
        self.stats.pre_pins += 1;
        self.stats.pre_pin_time += dt;
    }

    /// Account the deregistration time of LRU-evicted pins (performed
    /// by a background `evictdereg-*` stream off the evicting rank's
    /// critical path).
    pub fn note_evict_dereg(&mut self, dt: f64) {
        self.stats.evict_dereg_time += dt;
    }

    /// Next unique id for a background eviction-deregistration stream.
    pub fn next_evict_seq(&mut self) -> u64 {
        self.evict_seq += 1;
        self.evict_seq
    }

    /// Take a released slot usable for a window on `comm` whose largest
    /// exposure has class `class` — smallest adequate class wins.
    pub fn take_slot(&mut self, comm: CommId, class: u32) -> Option<WinId> {
        let cl = self
            .free
            .range((comm, class)..=(comm, u32::MAX))
            .find(|(_, v)| !v.is_empty())
            .map(|(&(_, cl), _)| cl)?;
        let win = self.free.get_mut(&(comm, cl)).and_then(|v| v.pop());
        if win.is_some() {
            self.stats.slot_reuses += 1;
        }
        win
    }

    /// File a released window slot for reuse.
    pub fn put_slot(&mut self, comm: CommId, class: u32, win: WinId) {
        self.free.entry((comm, class)).or_default().push(win);
        self.stats.releases += 1;
    }

    /// Snapshot of the warm/cold accounting.
    pub fn stats(&self) -> WinPoolStats {
        self.stats
    }

    /// Free-list population (diagnostics).
    pub fn free_slots(&self) -> usize {
        self.free.values().map(Vec::len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_classes_are_pow2_buckets() {
        assert_eq!(size_class(0), 0);
        assert_eq!(size_class(1), 0);
        assert_eq!(size_class(2), 1);
        assert_eq!(size_class(3), 2);
        assert_eq!(size_class(4), 2);
        assert_eq!(size_class(1024), 10);
        assert_eq!(size_class(1025), 11);
    }

    #[test]
    fn pins_warm_same_class_and_below() {
        let mut p = WinPool::new();
        assert!(p.is_warm(0, 7, 0), "NULL exposure registers nothing");
        assert!(!p.is_warm(0, 7, 100));
        p.record_pin(0, 7, 100, 0); // class 7 (128 B)
        assert!(p.is_warm(0, 7, 100));
        assert!(p.is_warm(0, 7, 128)); // same class
        assert!(p.is_warm(0, 7, 10)); // below
        assert!(!p.is_warm(0, 7, 129)); // above
        assert!(!p.is_warm(1, 7, 10)); // other rank
        assert!(!p.is_warm(0, 8, 10)); // other token
    }

    #[test]
    fn pin_class_only_grows() {
        let mut p = WinPool::new();
        p.record_pin(3, 1, 1 << 20, 0);
        p.record_pin(3, 1, 16, 0); // smaller re-pin must not shrink
        assert!(p.is_warm(3, 1, 1 << 20));
    }

    #[test]
    fn unpin_all_clears_one_rank() {
        let mut p = WinPool::new();
        p.record_pin(0, 1, 64, 0);
        p.record_pin(1, 1, 64, 0);
        p.unpin_all(0);
        assert!(!p.is_warm(0, 1, 64));
        assert!(p.is_warm(1, 1, 64));
    }

    #[test]
    fn slots_prefer_smallest_adequate_class() {
        let mut p = WinPool::new();
        let c = CommId(0);
        p.put_slot(c, 10, WinId(1));
        p.put_slot(c, 20, WinId(2));
        assert_eq!(p.free_slots(), 2);
        // Class 12 request: skip the class-10 slot, take class-20.
        assert_eq!(p.take_slot(c, 12), Some(WinId(2)));
        // Class 4 request: the class-10 slot is the smallest adequate.
        assert_eq!(p.take_slot(c, 4), Some(WinId(1)));
        assert_eq!(p.take_slot(c, 0), None);
        // Other communicators never match.
        p.put_slot(c, 5, WinId(3));
        assert_eq!(p.take_slot(CommId(1), 0), None);
    }

    #[test]
    fn stats_track_warm_and_cold() {
        let mut p = WinPool::new();
        p.note_acquire(false, 2.5, 0.0);
        p.note_acquire(true, 0.0, 2.0);
        p.note_acquire(true, 0.0, 1.0);
        let s = p.stats();
        assert_eq!(s.cold_acquires, 1);
        assert_eq!(s.warm_acquires, 2);
        assert!((s.cold_reg_time - 2.5).abs() < 1e-12);
        assert!((s.warm_reg_saved - 3.0).abs() < 1e-12);
    }

    #[test]
    fn cap_evicts_least_recently_used_token() {
        let mut p = WinPool::new();
        assert!(p.record_pin(0, 1, 64, 2).is_empty());
        assert!(p.record_pin(0, 2, 64, 2).is_empty());
        // Touch token 1 so token 2 becomes the LRU victim.
        p.touch(0, 1);
        // The eviction reports the victim's pinned-region size (its
        // size-class bytes) so the caller can charge the unpin.
        assert_eq!(
            p.record_pin(0, 3, 64, 2),
            vec![EvictedPin { bytes: 64, reg_done_at: 0.0 }]
        );
        assert!(p.is_warm(0, 1, 64), "touched token must survive");
        assert!(!p.is_warm(0, 2, 64), "LRU token must be evicted");
        assert!(p.is_warm(0, 3, 64), "fresh pin never self-evicts");
        assert_eq!(p.stats().evictions, 1);
    }

    #[test]
    fn cap_is_per_rank_and_zero_means_unbounded() {
        let mut p = WinPool::new();
        for t in 0..16 {
            p.record_pin(0, t, 64, 0); // unbounded
            p.record_pin(1, t, 64, 4); // capped
        }
        assert_eq!(p.stats().evictions, 12);
        for t in 0..16 {
            assert!(p.is_warm(0, t, 64), "unbounded rank keeps all pins");
        }
        // Rank 1 keeps only its 4 most recent tokens.
        for t in 0..12 {
            assert!(!p.is_warm(1, t, 64), "token {t} should be evicted");
        }
        for t in 12..16 {
            assert!(p.is_warm(1, t, 64), "token {t} should survive");
        }
    }

    #[test]
    fn warm_prefix_tracks_the_pinned_class() {
        let mut p = WinPool::new();
        assert_eq!(p.warm_prefix_bytes(0, 7), 0);
        p.record_pin(0, 7, 1000, 0); // class 10 → 1024 B covered
        assert_eq!(p.warm_prefix_bytes(0, 7), 1024);
        // Prefix is per (rank, token).
        assert_eq!(p.warm_prefix_bytes(1, 7), 0);
        assert_eq!(p.warm_prefix_bytes(0, 8), 0);
        // Growing the pin grows the prefix.
        p.record_pin(0, 7, 5000, 0); // class 13 → 8192 B
        assert_eq!(p.warm_prefix_bytes(0, 7), 8192);
        // Retirement clears it.
        p.unpin_all(0);
        assert_eq!(p.warm_prefix_bytes(0, 7), 0);
    }

    #[test]
    fn pipelined_segment_stats_accumulate() {
        let mut p = WinPool::new();
        p.note_pipelined(3, 1);
        p.note_pipelined(0, 4);
        let s = p.stats();
        assert_eq!(s.seg_cold_regs, 3);
        assert_eq!(s.seg_warm_regs, 5);
    }

    #[test]
    fn eviction_reports_the_victims_inflight_registration_deadline() {
        let mut p = WinPool::new();
        p.record_pin(0, 1, 64, 2);
        // Token 1's background stream is still running until t=7.5.
        p.set_reg_done(0, 1, 7.5);
        p.record_pin(0, 2, 64, 2);
        let ev = p.record_pin(0, 3, 64, 2);
        assert_eq!(ev, vec![EvictedPin { bytes: 64, reg_done_at: 7.5 }]);
        // Unknown tokens are ignored; re-pinning clears a stale deadline.
        p.set_reg_done(0, 99, 1.0);
        p.record_pin(0, 2, 64, 0); // re-pin: stale deadline cleared
        p.set_reg_done(0, 2, 3.0);
        p.touch(0, 3); // make token 2 the LRU victim
        let ev = p.record_pin(0, 4, 64, 2);
        assert_eq!(ev, vec![EvictedPin { bytes: 64, reg_done_at: 3.0 }]);
    }

    #[test]
    fn poisoning_a_token_clears_every_ranks_pin() {
        let mut p = WinPool::new();
        p.record_pin(0, 7, 64, 0);
        p.record_pin(1, 7, 64, 0);
        p.record_pin(0, 8, 64, 0);
        assert_eq!(p.poison_token(7), 2);
        assert!(!p.is_warm(0, 7, 64));
        assert!(!p.is_warm(1, 7, 64));
        assert!(p.is_warm(0, 8, 64), "other tokens survive");
        assert_eq!(p.stats().poisoned, 2);
        assert_eq!(p.poison_token(7), 0, "idempotent");
    }

    #[test]
    fn repinning_an_existing_token_does_not_evict() {
        let mut p = WinPool::new();
        p.record_pin(0, 1, 64, 2);
        p.record_pin(0, 2, 64, 2);
        // Re-pin of a cached token (class growth) stays within the cap.
        p.record_pin(0, 1, 4096, 2);
        assert_eq!(p.stats().evictions, 0);
        assert!(p.is_warm(0, 1, 4096));
        assert!(p.is_warm(0, 2, 64));
    }
}
