//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts from
//! `artifacts/` and execute them from Rust — Python never runs on this
//! path (`make artifacts` is the only Python invocation).
//!
//! The interchange format is **HLO text** (see `python/compile/aot.py`
//! and `/opt/xla-example/README.md`): `HloModuleProto::from_text_file`
//! re-parses and re-numbers instruction ids, sidestepping the 64-bit-id
//! protos that xla_extension 0.5.1 rejects.
//!
//! The execution backend binds to the `xla` crate (xla_extension),
//! which is only present in vendored builds; it is gated behind the
//! `pjrt` cargo feature so the default build has **zero external
//! dependencies**.  Without the feature, [`CgRuntime::load`] reports
//! the missing backend, and artifact-dependent tests/benches guard on
//! [`runtime_available`] (artifacts built **and** backend compiled)
//! to skip rather than panic.
//!
//! ```no_run
//! use proteo::runtime::{CgRuntime, CgState};
//! use proteo::linalg::EllMatrix;
//! let rt = CgRuntime::load("artifacts").unwrap();
//! let a = EllMatrix::laplacian_2d(rt.manifest.grid);
//! let b = vec![1.0f32; rt.manifest.n];
//! let mut st = CgState::init(&b);
//! for _ in 0..32 { st = rt.cg_step(&a, &st).unwrap(); }
//! println!("residual² = {}", st.rr);
//! ```

use std::path::{Path, PathBuf};

use crate::linalg::EllMatrix;
use crate::util::json::Json;

/// Error of the runtime layer: a contextualized message, rendered the
/// same under `{}` and `{:#}` (anyhow-style call sites keep working).
#[derive(Debug, Clone)]
pub struct RuntimeError(String);

impl RuntimeError {
    pub fn new(msg: impl Into<String>) -> RuntimeError {
        RuntimeError(msg.into())
    }

    /// Prepend context, like `anyhow::Context`.
    pub fn context(self, ctx: impl std::fmt::Display) -> RuntimeError {
        RuntimeError(format!("{ctx}: {}", self.0))
    }
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Result alias used throughout the runtime layer.
pub type Result<T> = std::result::Result<T, RuntimeError>;

/// Parsed `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub grid: usize,
    pub n: usize,
    pub nbr: usize,
    pub k: usize,
    pub br: usize,
    pub bc: usize,
    pub vmem_bytes_per_step: u64,
    pub mxu_flops_per_step: u64,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let src = std::fs::read_to_string(&path).map_err(|e| {
            RuntimeError::new(format!(
                "reading {} (run `make artifacts`): {e}",
                path.display()
            ))
        })?;
        let j = Json::parse(&src).map_err(|e| RuntimeError::new(format!("manifest: {e}")))?;
        let u = |k: &str| -> Result<usize> {
            j.get(k)
                .and_then(|v| v.as_usize())
                .ok_or_else(|| RuntimeError::new(format!("manifest missing '{k}'")))
        };
        Ok(Manifest {
            grid: u("grid")?,
            n: u("n")?,
            nbr: u("nbr")?,
            k: u("k")?,
            br: u("br")?,
            bc: u("bc")?,
            vmem_bytes_per_step: j
                .get_path("perf_model.vmem_bytes_per_step")
                .and_then(|v| v.as_u64())
                .unwrap_or(0),
            mxu_flops_per_step: j
                .get_path("perf_model.mxu_flops_per_step")
                .and_then(|v| v.as_u64())
                .unwrap_or(0),
        })
    }

    /// Does `m` describe matrices this artifact can multiply?
    pub fn accepts(&self, m: &EllMatrix) -> bool {
        m.nbr == self.nbr && m.k == self.k && m.br == self.br && m.bc == self.bc
    }
}

/// CG iteration state (f32, matching the artifact's dtype).
#[derive(Clone, Debug)]
pub struct CgState {
    pub x: Vec<f32>,
    pub r: Vec<f32>,
    pub p: Vec<f32>,
    pub rr: f32,
}

impl CgState {
    /// x₀ = 0 initialization: r = p = b, rr = b·b.
    pub fn init(b: &[f32]) -> CgState {
        let rr = b.iter().map(|v| v * v).sum();
        CgState { x: vec![0.0; b.len()], r: b.to_vec(), p: b.to_vec(), rr }
    }

    /// Relative residual vs the initial rr.
    pub fn rel_residual(&self, rr0: f32) -> f32 {
        (self.rr / rr0.max(f32::MIN_POSITIVE)).sqrt()
    }
}

pub use backend::{CgRuntime, DeviceMatrix};

#[cfg(feature = "pjrt")]
mod backend {
    //! xla_extension-backed execution (vendored builds only).

    use std::path::{Path, PathBuf};

    use super::{CgState, Manifest, Result, RuntimeError};
    use crate::linalg::EllMatrix;

    fn xe(e: impl std::fmt::Display) -> RuntimeError {
        RuntimeError::new(e.to_string())
    }

    /// A matrix resident in device memory (see [`CgRuntime::upload`]).
    pub struct DeviceMatrix {
        data: xla::PjRtBuffer,
        idx: xla::PjRtBuffer,
    }

    /// The loaded CG executables on the PJRT CPU client.
    pub struct CgRuntime {
        pub manifest: Manifest,
        client: xla::PjRtClient,
        cg_step: xla::PjRtLoadedExecutable,
        spmv: xla::PjRtLoadedExecutable,
    }

    impl CgRuntime {
        /// Load `cg_step.hlo.txt` + `spmv.hlo.txt` from `dir` and compile
        /// them on the PJRT CPU client.
        pub fn load(dir: impl AsRef<Path>) -> Result<CgRuntime> {
            let dir = dir.as_ref();
            let manifest = Manifest::load(dir)?;
            let client = xla::PjRtClient::cpu()
                .map_err(|e| xe(e).context("create PJRT CPU client"))?;
            let compile = |file: &str| -> Result<xla::PjRtLoadedExecutable> {
                let path: PathBuf = dir.join(file);
                let text = path
                    .to_str()
                    .ok_or_else(|| RuntimeError::new("artifact path not utf-8"))?;
                let proto = xla::HloModuleProto::from_text_file(text)
                    .map_err(|e| xe(e).context(format!("parse {}", path.display())))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                client
                    .compile(&comp)
                    .map_err(|e| xe(e).context(format!("compile {}", path.display())))
            };
            let cg_step = compile("cg_step.hlo.txt")?;
            let spmv = compile("spmv.hlo.txt")?;
            Ok(CgRuntime { manifest, client, cg_step, spmv })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        fn matrix_literals(&self, a: &EllMatrix) -> Result<(xla::Literal, xla::Literal)> {
            if !self.manifest.accepts(a) {
                return Err(RuntimeError::new(format!(
                    "matrix shape ({}, {}, {}, {}) does not match artifact ({}, {}, {}, {})",
                    a.nbr,
                    a.k,
                    a.br,
                    a.bc,
                    self.manifest.nbr,
                    self.manifest.k,
                    self.manifest.br,
                    self.manifest.bc
                )));
            }
            let dims = [a.nbr as i64, a.k as i64, a.br as i64, a.bc as i64];
            let data = xla::Literal::vec1(&a.data).reshape(&dims).map_err(xe)?;
            let idx = xla::Literal::vec1(&a.idx)
                .reshape(&[a.nbr as i64, a.k as i64])
                .map_err(xe)?;
            Ok((data, idx))
        }

        /// Upload a matrix to device memory once; subsequent
        /// [`CgRuntime::cg_step_dev`] calls reuse the resident buffers —
        /// the §Perf fix that removes the dominant per-iteration cost
        /// (re-uploading the 3 MB block data every call).
        pub fn upload(&self, a: &EllMatrix) -> Result<DeviceMatrix> {
            if !self.manifest.accepts(a) {
                return Err(RuntimeError::new("matrix shape does not match artifact"));
            }
            let data = self
                .client
                .buffer_from_host_buffer(&a.data, &[a.nbr, a.k, a.br, a.bc], None)
                .map_err(xe)?;
            let idx = self
                .client
                .buffer_from_host_buffer(&a.idx, &[a.nbr, a.k], None)
                .map_err(xe)?;
            Ok(DeviceMatrix { data, idx })
        }

        /// One CG iteration through the compiled artifact.
        pub fn cg_step(&self, a: &EllMatrix, st: &CgState) -> Result<CgState> {
            let dev = self.upload(a)?;
            self.cg_step_dev(&dev, st)
        }

        /// One CG iteration with a device-resident matrix (hot path): only
        /// the four small state tensors cross the host↔device boundary.
        pub fn cg_step_dev(&self, m: &DeviceMatrix, st: &CgState) -> Result<CgState> {
            let n = st.x.len();
            let up = |v: &[f32]| {
                self.client
                    .buffer_from_host_buffer(v, &[n], None)
                    .map_err(xe)
            };
            let rr = self
                .client
                .buffer_from_host_buffer(&[st.rr], &[], None)
                .map_err(xe)?;
            let result = self
                .cg_step
                .execute_b::<&xla::PjRtBuffer>(&[
                    &m.data,
                    &m.idx,
                    &up(&st.x)?,
                    &up(&st.r)?,
                    &up(&st.p)?,
                    &rr,
                ])
                .map_err(xe)?[0][0]
                .to_literal_sync()
                .map_err(xe)?;
            let parts = result.to_tuple().map_err(xe)?;
            if parts.len() != 4 {
                return Err(RuntimeError::new(format!(
                    "cg_step returned {} outputs, expected 4",
                    parts.len()
                )));
            }
            let mut it = parts.into_iter();
            let x = it.next().unwrap().to_vec::<f32>().map_err(xe)?;
            let r = it.next().unwrap().to_vec::<f32>().map_err(xe)?;
            let p = it.next().unwrap().to_vec::<f32>().map_err(xe)?;
            let rr = it.next().unwrap().to_vec::<f32>().map_err(xe)?[0];
            Ok(CgState { x, r, p, rr })
        }

        /// Bare SpMV through the compiled artifact.
        pub fn spmv(&self, a: &EllMatrix, x: &[f32]) -> Result<Vec<f32>> {
            if x.len() != self.manifest.n {
                return Err(RuntimeError::new(format!(
                    "x length {} != artifact n {}",
                    x.len(),
                    self.manifest.n
                )));
            }
            let (data, idx) = self.matrix_literals(a)?;
            let result = self
                .spmv
                .execute::<xla::Literal>(&[data, idx, xla::Literal::vec1(x)])
                .map_err(xe)?[0][0]
                .to_literal_sync()
                .map_err(xe)?;
            let out = result.to_tuple1().map_err(xe)?;
            out.to_vec::<f32>().map_err(xe)
        }

        /// Run CG to `tol` (relative residual) or `max_iters`; returns the
        /// state and the residual history — the signature mirrors
        /// [`linalg::cg`](crate::linalg::cg) for cross-layer comparison.
        /// The matrix is uploaded once and stays device-resident.
        pub fn cg_solve(
            &self,
            a: &EllMatrix,
            b: &[f32],
            tol: f32,
            max_iters: usize,
        ) -> Result<(CgState, Vec<f32>)> {
            let dev = self.upload(a)?;
            let mut st = CgState::init(b);
            let rr0 = st.rr;
            let mut history = vec![st.rel_residual(rr0)];
            for _ in 0..max_iters {
                if *history.last().unwrap() < tol {
                    break;
                }
                st = self.cg_step_dev(&dev, &st)?;
                history.push(st.rel_residual(rr0));
            }
            Ok((st, history))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backend {
    //! Stub backend: same surface, but [`CgRuntime::load`] reports the
    //! disabled feature.  The `Infallible` member makes the accessor
    //! bodies trivially diverging — a constructed `CgRuntime` cannot
    //! exist without the real backend.

    use std::convert::Infallible;
    use std::path::Path;

    use super::{CgState, Manifest, Result, RuntimeError};
    use crate::linalg::EllMatrix;

    /// A matrix resident in device memory (stub: never constructed).
    pub struct DeviceMatrix {
        #[allow(dead_code)]
        never: Infallible,
    }

    /// Stub runtime handle; see the module docs of [`crate::runtime`].
    pub struct CgRuntime {
        pub manifest: Manifest,
        never: Infallible,
    }

    impl CgRuntime {
        pub fn load(dir: impl AsRef<Path>) -> Result<CgRuntime> {
            // Surface manifest problems first — they are actionable
            // (`make artifacts`) even without the execution backend.
            let _ = Manifest::load(dir.as_ref())?;
            Err(RuntimeError::new(
                "PJRT backend disabled: add the vendored `xla` crate as a path \
                 dependency in rust/Cargo.toml (see the `pjrt` feature notes \
                 there), then rebuild with `--features pjrt`",
            ))
        }

        pub fn platform(&self) -> String {
            match self.never {}
        }

        pub fn upload(&self, _a: &EllMatrix) -> Result<DeviceMatrix> {
            match self.never {}
        }

        pub fn cg_step(&self, _a: &EllMatrix, _st: &CgState) -> Result<CgState> {
            match self.never {}
        }

        pub fn cg_step_dev(&self, _m: &DeviceMatrix, _st: &CgState) -> Result<CgState> {
            match self.never {}
        }

        pub fn spmv(&self, _a: &EllMatrix, _x: &[f32]) -> Result<Vec<f32>> {
            match self.never {}
        }

        pub fn cg_solve(
            &self,
            _a: &EllMatrix,
            _b: &[f32],
            _tol: f32,
            _max_iters: usize,
        ) -> Result<(CgState, Vec<f32>)> {
            match self.never {}
        }
    }
}

/// Default artifacts directory: `$PROTEO_ARTIFACTS` or `artifacts/`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("PROTEO_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

/// Artifacts present? (tests skip gracefully when not built yet).
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

/// Is the PJRT execution backend compiled in (`--features pjrt`)?
pub fn pjrt_available() -> bool {
    cfg!(feature = "pjrt")
}

/// Can [`CgRuntime::load`] succeed: artifacts built **and** backend
/// compiled?  The skip guard for artifact-dependent tests, benches and
/// examples — checking only [`artifacts_available`] would panic the
/// default (stub-backend) build once `make artifacts` has run.
pub fn runtime_available() -> bool {
    pjrt_available() && artifacts_available()
}

#[cfg(test)]
mod tests {
    use super::*;

    // Execution tests live in rust/tests/integration_runtime.rs (they
    // need `make artifacts`); here: pure manifest/state logic.

    #[test]
    fn cg_state_init_values() {
        let st = CgState::init(&[3.0, 4.0]);
        assert_eq!(st.rr, 25.0);
        assert_eq!(st.x, vec![0.0, 0.0]);
        assert_eq!(st.r, vec![3.0, 4.0]);
        assert!((st.rel_residual(25.0) - 1.0).abs() < 1e-7);
    }

    #[test]
    fn manifest_missing_is_graceful() {
        let err = Manifest::load(Path::new("/nonexistent-dir")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    #[test]
    fn runtime_error_context_chains() {
        let e = RuntimeError::new("inner").context("outer");
        assert_eq!(e.to_string(), "outer: inner");
        assert_eq!(format!("{e:#}"), "outer: inner");
    }

    #[test]
    fn manifest_accepts_matching_shapes() {
        let m = Manifest {
            grid: 8,
            n: 64,
            nbr: 8,
            k: 3,
            br: 8,
            bc: 8,
            vmem_bytes_per_step: 0,
            mxu_flops_per_step: 0,
        };
        let a = EllMatrix::laplacian_2d(8);
        assert!(m.accepts(&a));
        let b = EllMatrix::laplacian_2d(4);
        assert!(!m.accepts(&b));
    }
}
