//! RMS — a miniature Resource Manager System driving stage 1 of the
//! reconfiguration pipeline (§I): *reconfiguration feasibility*.
//!
//! The RMS owns the node pool, tracks running jobs and a FIFO queue of
//! pending ones, and applies a dynamic resource-allocation policy to
//! decide whether (and to what size) a malleable job should be
//! resized at its next checkpoint:
//!
//! * [`Policy::Static`] — never resize (rigid jobs).
//! * [`Policy::FillIdle`] — expand the malleable job over every idle
//!   core; the paper's "scale up when resources are available".
//! * [`Policy::MakeRoom`] — shrink the malleable job to the smallest
//!   size that lets the head of the queue start; "scale down when
//!   demand is high".
//! * [`Policy::Plan`] — a scripted sequence of target sizes, used by
//!   the experiment harnesses to reproduce a specific `(NS → ND)`.
//!
//! Targets are clamped to the job's min/max and rounded to multiples
//! of `granularity` (the paper resizes in multiples of 20 — full
//! nodes).

use std::collections::{BTreeMap, VecDeque};

/// A job known to the RMS.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Job {
    pub id: usize,
    pub name: String,
    /// Currently allocated cores (== MPI ranks at 1 rank/core).
    pub cores: usize,
    /// Resizing bounds for malleable jobs; `min == max` means rigid.
    pub min_cores: usize,
    pub max_cores: usize,
}

impl Job {
    pub fn is_malleable(&self) -> bool {
        self.min_cores < self.max_cores
    }
}

/// Dynamic resource-allocation policy (§I stage 1).
#[derive(Clone, Debug)]
pub enum Policy {
    Static,
    FillIdle,
    MakeRoom,
    /// MakeRoom while jobs are queued, FillIdle otherwise — the
    /// "scale down when demand is high, up when resources are free"
    /// behaviour the paper's introduction describes.
    Adaptive,
    Plan(Vec<usize>),
}

/// A resize decision for one job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Decision {
    pub job: usize,
    pub from: usize,
    pub to: usize,
}

/// The resource manager.
pub struct Rms {
    pub total_cores: usize,
    pub granularity: usize,
    policy: Policy,
    jobs: Vec<Job>,
    queue: VecDeque<Job>,
    next_id: usize,
    /// `Policy::Plan` progress, keyed by job id: each malleable job
    /// consumes the scripted sizes independently.
    plan_cursors: BTreeMap<usize, usize>,
}

impl Rms {
    pub fn new(total_cores: usize, granularity: usize, policy: Policy) -> Rms {
        assert!(granularity >= 1 && total_cores >= granularity);
        Rms {
            total_cores,
            granularity,
            policy,
            jobs: Vec::new(),
            queue: VecDeque::new(),
            next_id: 0,
            plan_cursors: BTreeMap::new(),
        }
    }

    /// Cores currently allocated to running jobs.
    pub fn used_cores(&self) -> usize {
        self.jobs.iter().map(|j| j.cores).sum()
    }

    pub fn idle_cores(&self) -> usize {
        self.total_cores - self.used_cores()
    }

    /// Utilization in [0, 1].
    pub fn utilization(&self) -> f64 {
        self.used_cores() as f64 / self.total_cores as f64
    }

    pub fn jobs(&self) -> &[Job] {
        &self.jobs
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Submit a job; starts immediately if `cores` fit **and** no
    /// earlier job is still queued (FIFO, no backfilling — a fitting
    /// newcomer must not overtake the queue head, or the head could be
    /// starved by a stream of small jobs).  Returns the job id.
    pub fn submit(&mut self, name: &str, cores: usize, min: usize, max: usize) -> usize {
        assert!(min <= cores && cores <= max && max <= self.total_cores);
        let id = self.next_id;
        self.next_id += 1;
        let job = Job { id, name: name.to_string(), cores, min_cores: min, max_cores: max };
        if self.queue.is_empty() && cores <= self.idle_cores() {
            self.jobs.push(job);
        } else {
            self.queue.push_back(job);
        }
        id
    }

    /// A running job finished: free its cores, start queued jobs that
    /// now fit (FIFO, no backfilling).
    pub fn finish(&mut self, job_id: usize) {
        self.jobs.retain(|j| j.id != job_id);
        self.admit_from_queue();
    }

    fn admit_from_queue(&mut self) {
        while let Some(head) = self.queue.front() {
            if head.cores <= self.idle_cores() {
                let j = self.queue.pop_front().unwrap();
                self.jobs.push(j);
            } else {
                break;
            }
        }
    }

    fn round_down(&self, n: usize) -> usize {
        (n / self.granularity) * self.granularity
    }

    /// Stage 1: should `job_id` resize at its next checkpoint?
    /// Returns `None` when no resize is warranted.
    pub fn checkpoint_decision(&mut self, job_id: usize) -> Option<Decision> {
        let job = self.jobs.iter().find(|j| j.id == job_id)?.clone();
        if !job.is_malleable() {
            return None;
        }
        let fill_idle = |s: &Rms| {
            let grown = job.cores + s.round_down(s.idle_cores());
            grown.min(job.max_cores)
        };
        let make_room = |s: &Rms| match s.queue.front() {
            Some(head) => {
                let needed = head.cores.saturating_sub(s.idle_cores());
                let shrunk = job
                    .cores
                    .saturating_sub(needed.div_ceil(s.granularity) * s.granularity);
                shrunk.max(job.min_cores)
            }
            None => job.cores,
        };
        let target = match &self.policy {
            Policy::Static => job.cores,
            Policy::FillIdle => fill_idle(self),
            Policy::MakeRoom => make_room(self),
            Policy::Adaptive => {
                if self.queue.is_empty() {
                    fill_idle(self)
                } else {
                    make_room(self)
                }
            }
            Policy::Plan(sizes) => {
                // Per-job cursor: concurrent malleable jobs must not
                // consume each other's scripted sizes.
                let cursor = self.plan_cursors.entry(job_id).or_insert(0);
                if *cursor < sizes.len() {
                    let t = sizes[*cursor];
                    *cursor += 1;
                    t.clamp(job.min_cores, job.max_cores)
                } else {
                    job.cores
                }
            }
        };
        if target == job.cores || target == 0 {
            return None;
        }
        Some(Decision { job: job_id, from: job.cores, to: target })
    }

    /// Stage 2 hand-back: the job committed to the new size.
    pub fn apply(&mut self, d: Decision) {
        let job = self
            .jobs
            .iter_mut()
            .find(|j| j.id == d.job)
            .expect("apply for unknown job");
        assert_eq!(job.cores, d.from, "stale decision");
        job.cores = d.to;
        // Shrinks may let queued jobs start.
        if d.to < d.from {
            self.admit_from_queue();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rms(policy: Policy) -> Rms {
        Rms::new(160, 20, policy)
    }

    #[test]
    fn submit_runs_or_queues() {
        let mut r = rms(Policy::Static);
        let a = r.submit("a", 120, 120, 120);
        let b = r.submit("b", 80, 80, 80);
        assert_eq!(r.jobs().len(), 1);
        assert_eq!(r.queue_len(), 1);
        assert_eq!(r.used_cores(), 120);
        r.finish(a);
        assert_eq!(r.jobs().len(), 1);
        assert_eq!(r.jobs()[0].id, b);
        assert_eq!(r.queue_len(), 0);
    }

    #[test]
    fn submit_never_backfills_past_a_queued_job() {
        // Regression: a fitting newcomer must queue behind the queue
        // head (FIFO, no backfilling) instead of starting immediately.
        let mut r = rms(Policy::Static);
        let a = r.submit("a", 100, 100, 100); // runs (160 total)
        let b = r.submit("b", 100, 100, 100); // queued: only 60 idle
        let c = r.submit("c", 20, 20, 20); // fits 60 idle, but behind b
        assert_eq!(r.jobs().len(), 1, "c must not overtake b");
        assert_eq!(r.queue_len(), 2);
        r.finish(a);
        // FIFO admission: b first, then c (both fit now).
        assert_eq!(r.queue_len(), 0);
        let ids: Vec<usize> = r.jobs().iter().map(|j| j.id).collect();
        assert_eq!(ids, vec![b, c]);
        assert_eq!(r.used_cores(), 120);
    }

    #[test]
    fn submit_with_empty_queue_still_starts_immediately() {
        let mut r = rms(Policy::Static);
        let a = r.submit("a", 60, 60, 60);
        let b = r.submit("b", 60, 60, 60);
        assert_eq!(r.jobs().len(), 2);
        assert_eq!(r.queue_len(), 0);
        let _ = (a, b);
    }

    #[test]
    fn fill_idle_grows_to_capacity() {
        let mut r = rms(Policy::FillIdle);
        let j = r.submit("malleable", 40, 20, 160);
        let d = r.checkpoint_decision(j).expect("should grow");
        assert_eq!(d, Decision { job: j, from: 40, to: 160 });
        r.apply(d);
        assert_eq!(r.idle_cores(), 0);
        assert!(r.checkpoint_decision(j).is_none(), "no more room");
    }

    #[test]
    fn fill_idle_respects_max_and_granularity() {
        let mut r = rms(Policy::FillIdle);
        let _rigid = r.submit("rigid", 30, 30, 30); // leaves 130 idle
        let j = r.submit("malleable", 20, 20, 80);
        let d = r.checkpoint_decision(j).unwrap();
        // 130 idle → rounded to 120; clamped to max 80.
        assert_eq!(d.to, 80);
    }

    #[test]
    fn make_room_shrinks_for_queue_head() {
        let mut r = rms(Policy::MakeRoom);
        let j = r.submit("malleable", 160, 20, 160);
        r.submit("incoming", 60, 60, 60); // queued: no idle cores
        let d = r.checkpoint_decision(j).unwrap();
        assert_eq!(d.to, 100, "shrink by exactly ⌈60/20⌉ nodes");
        r.apply(d);
        // Queue admission happens on shrink.
        assert_eq!(r.jobs().len(), 2);
        assert_eq!(r.queue_len(), 0);
        assert_eq!(r.used_cores(), 160);
    }

    #[test]
    fn make_room_respects_min() {
        let mut r = rms(Policy::MakeRoom);
        let j = r.submit("malleable", 40, 40, 160);
        r.submit("incoming", 160, 160, 160);
        // Cannot shrink below min 40 (40 == current → None).
        assert!(r.checkpoint_decision(j).is_none());
    }

    #[test]
    fn plan_yields_scripted_sizes() {
        let mut r = rms(Policy::Plan(vec![80, 20]));
        let j = r.submit("malleable", 40, 20, 160);
        let d1 = r.checkpoint_decision(j).unwrap();
        assert_eq!(d1.to, 80);
        r.apply(d1);
        let d2 = r.checkpoint_decision(j).unwrap();
        assert_eq!((d2.from, d2.to), (80, 20));
        r.apply(d2);
        assert!(r.checkpoint_decision(j).is_none(), "plan exhausted");
    }

    #[test]
    fn plan_cursors_are_per_job() {
        // Regression: two malleable jobs under Policy::Plan each walk
        // the scripted sizes from the start — a shared cursor would
        // hand job 2 the sizes job 1 already consumed.
        let mut r = rms(Policy::Plan(vec![60, 20]));
        let j1 = r.submit("m1", 40, 20, 160);
        let j2 = r.submit("m2", 40, 20, 160);
        let d1 = r.checkpoint_decision(j1).unwrap();
        assert_eq!(d1.to, 60, "job 1 first scripted size");
        r.apply(d1);
        let d2 = r.checkpoint_decision(j2).unwrap();
        assert_eq!(d2.to, 60, "job 2 must also start at the first size");
        r.apply(d2);
        let d1b = r.checkpoint_decision(j1).unwrap();
        assert_eq!((d1b.from, d1b.to), (60, 20));
        r.apply(d1b);
        let d2b = r.checkpoint_decision(j2).unwrap();
        assert_eq!((d2b.from, d2b.to), (60, 20));
        r.apply(d2b);
        assert!(r.checkpoint_decision(j1).is_none(), "plan exhausted per job");
        assert!(r.checkpoint_decision(j2).is_none());
    }

    #[test]
    fn static_never_resizes() {
        let mut r = rms(Policy::Static);
        let j = r.submit("m", 40, 20, 160);
        assert!(r.checkpoint_decision(j).is_none());
    }

    #[test]
    fn rigid_job_never_resizes_under_any_policy() {
        let mut r = rms(Policy::FillIdle);
        let j = r.submit("rigid", 40, 40, 40);
        assert!(r.checkpoint_decision(j).is_none());
    }

    #[test]
    fn utilization_tracks_allocations() {
        let mut r = rms(Policy::Static);
        assert_eq!(r.utilization(), 0.0);
        r.submit("a", 80, 80, 80);
        assert!((r.utilization() - 0.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "stale decision")]
    fn stale_apply_panics() {
        let mut r = rms(Policy::FillIdle);
        let j = r.submit("m", 40, 20, 160);
        let d = r.checkpoint_decision(j).unwrap();
        r.apply(d);
        r.apply(d); // same decision twice
    }
}
