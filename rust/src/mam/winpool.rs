//! MaM's window-pool layer (§VI): registry entries *pin* their RMA
//! windows so successive redistributions reuse registered memory.
//!
//! The paper names window initialization as the one overhead that
//! keeps the RMA methods from beating the collective baseline: every
//! reconfiguration pays `Win_create`'s memory registration for every
//! exposed structure.  MaM's registry makes the fix natural — each
//! entry is a long-lived, named buffer, so the entry's *name* is a
//! stable pin token across ranks **and** across resizes.  With the
//! pool enabled, `init_rma`/`Complete_RMA` and the blocking RMA paths
//! acquire epoch-capable windows through
//! [`MpiProc::win_acquire`]/[`MpiProc::win_release`] instead of
//! `win_create`/`win_free`: the first resize registers (cold), every
//! later exposure of the same entry at the same rank rides the cached
//! registration (warm) and skips the per-byte pinning entirely.
//!
//! Policy lives here; mechanism (registration cache, slot free lists,
//! warm/cold virtual-time accounting) lives in
//! [`crate::simmpi::winpool`].
//!
//! [`MpiProc::win_acquire`]: crate::simmpi::MpiProc::win_acquire
//! [`MpiProc::win_release`]: crate::simmpi::MpiProc::win_release

use crate::simmpi::{CommId, MpiProc, Payload, WinCreateOpts, WinId};

use super::reconfig::Roles;
use super::registry::Registry;

/// Per-reconfiguration window-pool policy (set from `ReconfigCfg`;
/// `--win-pool on|off` / `--win-pool-cap N` on the CLI).  Off is the
/// paper's cold path and is bit-identical to the seed behaviour.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WinPoolPolicy {
    pub enabled: bool,
    /// Per-rank bound on the registration cache (`win_pool_cap`):
    /// at most this many pinned tokens are kept per process, evicting
    /// least-recently-used beyond it.  0 = unbounded (the default).
    pub cap: usize,
}

impl WinPoolPolicy {
    pub fn on() -> WinPoolPolicy {
        WinPoolPolicy { enabled: true, cap: 0 }
    }

    pub fn off() -> WinPoolPolicy {
        WinPoolPolicy { enabled: false, cap: 0 }
    }

    /// Builder-style cap override (0 = unbounded).
    pub fn with_cap(mut self, cap: usize) -> WinPoolPolicy {
        self.cap = cap;
        self
    }

    /// Parse the CLI/config toggle — one grammar, shared via
    /// [`parse_toggle`](crate::util::cli::parse_toggle).
    pub fn parse(s: &str) -> Option<WinPoolPolicy> {
        crate::util::cli::parse_toggle(s)
            .map(|on| if on { WinPoolPolicy::on() } else { WinPoolPolicy::off() })
    }

    pub fn label(self) -> &'static str {
        if self.enabled {
            "on"
        } else {
            "off"
        }
    }
}

/// Stable pin token of a registry entry: FNV-1a of its name.  Every
/// rank derives the same token for the same entry, and the token
/// survives reconfigurations — which is exactly the lifetime of the
/// pinned buffer it stands for.
pub fn pin_token(name: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The window exposure of registry entry `i` for this rank: sources
/// expose their local block, everyone else `NULL` (Alg. 2 L3) — real
/// or virtual matching the entry's payload mode.
pub fn entry_exposure(roles: &Roles, registry: &Registry, i: usize) -> Payload {
    let e = registry.entry(i);
    if roles.is_source() {
        e.local.clone()
    } else if e.local.is_real() {
        Payload::real(Vec::new())
    } else {
        Payload::virt(0)
    }
}

/// Unified entry-window acquisition: collectively create (pool off) or
/// acquire (pool on) the window of registry entry `i` over `comm`,
/// with the registration strategy carried by [`WinCreateOpts`] —
/// `blocking()` is the seed path bit for bit, `pipelined(chunk)`
/// registers the exposure in segments behind the collective, and
/// `.eager(true)` starts each rank's background stream at its own fill
/// end (the spawn-overlap policy for chunked RMA grows under
/// `--spawn-strategy async`).
pub fn acquire_entry_window_with(
    proc: &MpiProc,
    comm: CommId,
    roles: &Roles,
    registry: &Registry,
    i: usize,
    policy: WinPoolPolicy,
    opts: WinCreateOpts,
) -> WinId {
    let exposure = entry_exposure(roles, registry, i);
    if policy.enabled {
        proc.win_acquire_with(comm, exposure, pin_token(&registry.entry(i).name), policy.cap, opts)
    } else {
        proc.win_create_with(comm, exposure, opts)
    }
}

/// Blocking entry-window acquisition.
#[deprecated(note = "use acquire_entry_window_with(.., WinCreateOpts::blocking())")]
pub fn acquire_entry_window(
    proc: &MpiProc,
    comm: CommId,
    roles: &Roles,
    registry: &Registry,
    i: usize,
    policy: WinPoolPolicy,
) -> WinId {
    acquire_entry_window_with(proc, comm, roles, registry, i, policy, WinCreateOpts::blocking())
}

/// Chunked pipelined entry-window acquisition.
#[deprecated(note = "use acquire_entry_window_with(.., WinCreateOpts::pipelined(chunk_elems))")]
pub fn acquire_entry_window_pipelined(
    proc: &MpiProc,
    comm: CommId,
    roles: &Roles,
    registry: &Registry,
    i: usize,
    policy: WinPoolPolicy,
    chunk_elems: u64,
) -> WinId {
    acquire_entry_window_with(
        proc,
        comm,
        roles,
        registry,
        i,
        policy,
        WinCreateOpts::pipelined(chunk_elems),
    )
}

/// Chunked pipelined entry-window acquisition with a stream-start
/// policy.
#[deprecated(
    note = "use acquire_entry_window_with(.., WinCreateOpts::pipelined(chunk_elems).eager(eager_reg))"
)]
#[allow(clippy::too_many_arguments)]
pub fn acquire_entry_window_cfg(
    proc: &MpiProc,
    comm: CommId,
    roles: &Roles,
    registry: &Registry,
    i: usize,
    policy: WinPoolPolicy,
    chunk_elems: u64,
    eager_reg: bool,
) -> WinId {
    acquire_entry_window_with(
        proc,
        comm,
        roles,
        registry,
        i,
        policy,
        WinCreateOpts::pipelined(chunk_elems).eager(eager_reg),
    )
}

/// Options for [`close_windows_with`] — the single window-teardown
/// entrypoint the old `close_windows{,_cfg,_local,_local_cfg}` quartet
/// collapsed into.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CloseOpts {
    /// Route pool-off frees through the background deregistration
    /// pipeline: segments deregister as their last reads land instead
    /// of serially after the closing barrier.  Pooled releases skip
    /// per-byte deregistration entirely (the slot keeps its memory
    /// pinned), so they take the plain release either way.
    pub dereg_pipeline: bool,
    /// Local-only close (Wait-Drains path: the confirmation barrier
    /// already synchronized, §IV-C) instead of the collective close.
    pub local: bool,
}

impl CloseOpts {
    /// Collective close, serial deregistration (the seed path).
    pub fn collective() -> CloseOpts {
        CloseOpts::default()
    }

    /// Local-only close (Wait-Drains path).
    pub fn local_only() -> CloseOpts {
        CloseOpts { dereg_pipeline: false, local: true }
    }

    /// Set the pipelined-teardown policy.
    pub fn pipelined(mut self, dereg_pipeline: bool) -> CloseOpts {
        self.dereg_pipeline = dereg_pipeline;
        self
    }
}

/// Unified window teardown: `win_release*` keeps the registrations
/// pooled, `win_free*` (pool off) deregisters — serially or through
/// the background pipeline, collectively or locally, per
/// [`CloseOpts`].
pub fn close_windows_with(proc: &MpiProc, wins: &[WinId], policy: WinPoolPolicy, opts: CloseOpts) {
    for win in wins {
        match (policy.enabled, opts.local) {
            (true, false) => proc.win_release(*win),
            (true, true) => proc.win_release_local(*win),
            (false, false) => {
                if opts.dereg_pipeline {
                    proc.win_free_pipelined(*win);
                } else {
                    proc.win_free(*win);
                }
            }
            (false, true) => {
                if opts.dereg_pipeline {
                    proc.win_free_local_pipelined(*win);
                } else {
                    proc.win_free_local(*win);
                }
            }
        }
    }
}

/// Notified window teardown (`--rma-sync notify`): no closing
/// collective — each rank waits until its own exposure's expected
/// notification count is reached (armed from the redistribution
/// schedule's sync plan), then frees or releases locally.
pub fn close_windows_notified(proc: &MpiProc, wins: &[WinId], policy: WinPoolPolicy) {
    for win in wins {
        if policy.enabled {
            proc.win_release_notified(*win);
        } else {
            proc.win_free_notified(*win);
        }
    }
}

/// Collective close, serial deregistration.
#[deprecated(note = "use close_windows_with(.., CloseOpts::collective())")]
pub fn close_windows(proc: &MpiProc, wins: &[WinId], policy: WinPoolPolicy) {
    close_windows_with(proc, wins, policy, CloseOpts::collective())
}

/// Collective close with the pipelined-teardown policy.
#[deprecated(note = "use close_windows_with(.., CloseOpts::collective().pipelined(dereg_pipeline))")]
pub fn close_windows_cfg(
    proc: &MpiProc,
    wins: &[WinId],
    policy: WinPoolPolicy,
    dereg_pipeline: bool,
) {
    close_windows_with(proc, wins, policy, CloseOpts::collective().pipelined(dereg_pipeline))
}

/// Local-only close, serial deregistration.
#[deprecated(note = "use close_windows_with(.., CloseOpts::local_only())")]
pub fn close_windows_local(proc: &MpiProc, wins: &[WinId], policy: WinPoolPolicy) {
    close_windows_with(proc, wins, policy, CloseOpts::local_only())
}

/// Local-only close with the pipelined-teardown policy.
#[deprecated(note = "use close_windows_with(.., CloseOpts::local_only().pipelined(dereg_pipeline))")]
pub fn close_windows_local_cfg(
    proc: &MpiProc,
    wins: &[WinId],
    policy: WinPoolPolicy,
    dereg_pipeline: bool,
) {
    close_windows_with(proc, wins, policy, CloseOpts::local_only().pipelined(dereg_pipeline))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_parses_cli_spellings() {
        assert_eq!(WinPoolPolicy::parse("on"), Some(WinPoolPolicy::on()));
        assert_eq!(WinPoolPolicy::parse("ON"), Some(WinPoolPolicy::on()));
        assert_eq!(WinPoolPolicy::parse("true"), Some(WinPoolPolicy::on()));
        assert_eq!(WinPoolPolicy::parse("off"), Some(WinPoolPolicy::off()));
        assert_eq!(WinPoolPolicy::parse("0"), Some(WinPoolPolicy::off()));
        assert_eq!(WinPoolPolicy::parse("maybe"), None);
        assert_eq!(WinPoolPolicy::default(), WinPoolPolicy::off());
        assert_eq!(WinPoolPolicy::on().label(), "on");
        assert_eq!(WinPoolPolicy::off().label(), "off");
    }

    #[test]
    fn cap_defaults_unbounded_and_composes() {
        assert_eq!(WinPoolPolicy::on().cap, 0);
        assert_eq!(WinPoolPolicy::parse("on").unwrap().cap, 0);
        let p = WinPoolPolicy::on().with_cap(3);
        assert!(p.enabled);
        assert_eq!(p.cap, 3);
        assert_ne!(p, WinPoolPolicy::on(), "cap is part of the policy identity");
    }

    #[test]
    fn pin_tokens_are_stable_and_distinct() {
        assert_eq!(pin_token("A_vals"), pin_token("A_vals"));
        assert_ne!(pin_token("A_vals"), pin_token("A_cols"));
        assert_ne!(pin_token(""), pin_token("x"));
        // FNV-1a of the empty string is the offset basis.
        assert_eq!(pin_token(""), 0xcbf2_9ce4_8422_2325);
    }

    #[test]
    fn exposure_follows_roles_and_mode() {
        use crate::mam::registry::DataKind;
        let mut reg = Registry::new();
        reg.register("real", DataKind::Constant, 10, Payload::real(vec![1.0, 2.0]));
        reg.register("virt", DataKind::Constant, 10, Payload::virt(2));
        let src = Roles { ns: 2, nd: 4, rank: 0 };
        let drain = Roles { ns: 2, nd: 4, rank: 3 };
        assert_eq!(entry_exposure(&src, &reg, 0).elems(), 2);
        assert!(entry_exposure(&src, &reg, 0).is_real());
        // Drain-only ranks expose NULL in the entry's mode.
        assert_eq!(entry_exposure(&drain, &reg, 0).elems(), 0);
        assert!(entry_exposure(&drain, &reg, 0).is_real());
        assert_eq!(entry_exposure(&drain, &reg, 1).elems(), 0);
        assert!(!entry_exposure(&drain, &reg, 1).is_real());
    }
}
