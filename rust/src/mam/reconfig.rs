//! The reconfiguration driver: Merge process management + the
//! method × strategy dispatch, including the split
//! `Init_RMA`/`Complete_RMA` protocol for background redistributions
//! (§IV-C, Figs. 1–2).
//!
//! ## Life of a reconfiguration
//!
//! 1. The application (all `NS` ranks of the current communicator)
//!    calls [`Mam::reconfigure`] at a checkpoint.
//! 2. **Process management** (*Merge*, [22]): growing spawns `ND−NS`
//!    ranks via `MPI_Comm_spawn` + intercomm merge (sources keep their
//!    ranks, spawned ranks follow); shrinking duplicates the
//!    communicator so the redistribution traffic cannot cross-match
//!    with application collectives.
//! 3. **Data redistribution** over the merged/duplicated communicator
//!    using the configured method (COL / RMA-Lock / RMA-Lockall) and
//!    strategy (Blocking / NB / WD / Threading).  Blocking returns
//!    `Completed`; background strategies return `InProgress` and the
//!    application keeps iterating, polling [`Mam::checkpoint`] once per
//!    iteration.
//! 4. When `Completed`, the application calls [`Mam::finish`]: growing
//!    continues on the merged communicator; shrinking performs the
//!    collective prefix-split and ranks `≥ ND` exit.
//!
//! Spawned drains run [`Mam::drain_join`], which mirrors the source
//! collective call sequence exactly (MPI matches collectives by call
//! order per communicator).

use std::sync::{Arc, Mutex};

use crate::simcluster::faults::FaultPlan;
use crate::simcluster::Time;
use crate::simmpi::{CommId, MpiProc, Payload, ReqId, RmaSync};

use super::collective as col;
use super::planner::{self, PlannerMode};
use super::registry::{DataDecl, DataKind, Registry};
use super::resilience;
use super::rma::{self, RmaInit};
use super::schedcache::{SchedCache, SchedKey};
use super::spawn::SpawnStrategy;
use super::winpool::{self, WinPoolPolicy};
use super::{Method, Strategy};

/// Rank roles during a reconfiguration (§I stage 2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Roles {
    pub ns: usize,
    pub nd: usize,
    /// Rank within the merged communicator (sources first).
    pub rank: usize,
}

impl Roles {
    /// Existed before the resize.
    pub fn is_source(&self) -> bool {
        self.rank < self.ns
    }

    /// Continues after the resize.
    pub fn is_drain(&self) -> bool {
        self.rank < self.nd
    }

    /// Will be retired once redistribution completes (shrink tail).
    pub fn is_source_only(&self) -> bool {
        self.is_source() && !self.is_drain()
    }

    /// Newly spawned by the resize (grow tail).
    pub fn is_drain_only(&self) -> bool {
        self.is_drain() && !self.is_source()
    }

    pub fn is_grow(&self) -> bool {
        self.nd > self.ns
    }
}

/// Static reconfiguration configuration.
#[derive(Clone, Debug)]
pub struct ReconfigCfg {
    pub method: Method,
    pub strategy: Strategy,
    /// Modeled `MPI_Comm_spawn` duration (process launch, PMI exchange)
    /// of the Sequential spawn strategy — the paper's opaque constant.
    pub spawn_cost: f64,
    /// How the Merge grow path executes `MPI_Comm_spawn`
    /// (`--spawn-strategy`): Sequential reproduces the single-constant
    /// model bit-identically; Parallel/Async use the decomposed
    /// launch/startup/merge cost terms of the network model.
    pub spawn_strategy: SpawnStrategy,
    /// Persistent window pool (§VI): registry entries pin their RMA
    /// windows so later resizes acquire them warm.  Off = the paper's
    /// cold `Win_create` path (seed behaviour).
    pub win_pool: WinPoolPolicy,
    /// Chunked pipelined RMA registration (`--rma-chunk`): segment
    /// size in KiB.  Each exposure registers segment by segment — only
    /// the first segment gates the collective `Win_create`, later
    /// segments register while earlier segments' reads are on the
    /// wire, and drains read one `Get`/`Rget` per touched segment.
    /// `0` (default) = the seed unchunked path, bit for bit.  Ignored
    /// by the COL method (no windows to register).
    pub rma_chunk_kib: u64,
    /// Teardown half of the chunked lifecycle pipeline
    /// (`--rma-dereg`): with `rma_chunk_kib > 0`, pool-off `Win_free`s
    /// deregister per segment in the background as the last reads
    /// land (retiring ranks on a shrink exit after
    /// `max(T_dereg, T_wire)` instead of `T_wire + T_dereg`).  `false`
    /// keeps the registration-only pipeline (the pre-teardown chunked
    /// behaviour).  Meaningless when `rma_chunk_kib == 0`.  Default:
    /// `true`.
    pub rma_dereg: bool,
    /// RMA completion synchronization (`--rma-sync`): `Epoch` (default)
    /// is the paper's collective epoch/barrier protocol, bit-identical
    /// to the seed; `Notify` replaces it with notified completion —
    /// drains observe per-segment readiness through the windows'
    /// notification counters, `Complete_RMA` gates teardown on
    /// per-segment notify counts, and the confirmation barrier is
    /// never issued.  Ignored by the COL method (no windows).
    pub rma_sync: RmaSync,
    /// Persistent redistribution schedules (`--sched-cache`): memoize
    /// the block-distribution targets, per-drain read lists, segment
    /// layout and sync plan per `(from, to, structure, chunk)` shape,
    /// charging the cold schedule build once and only a validation
    /// handshake on every replay.  Off (default) recomputes per resize
    /// and charges nothing — the seed behaviour, bit for bit.
    pub sched_cache: bool,
    /// `Fixed` uses the fields above verbatim (seed behaviour).
    /// `Auto` lets the cost-model planner override
    /// method/strategy/spawn/pool per resize: `Mam` resolves it with
    /// the analytic planner at every `reconfigure`/`drain_join` from
    /// rank-independent inputs (declared sizes + calibrated network
    /// parameters), so sources and spawned drains always agree.
    /// Harnesses that know more (pool warmth, iteration times) resolve
    /// with `mam::planner::plan` up front and pass a `Fixed`
    /// configuration down instead.
    pub planner: PlannerMode,
    /// Online recalibration (`--recalib`): when `true`, `Auto`
    /// planning consults the live `NetParams` estimate installed via
    /// [`Mam::set_live_params`] (fed by the scenario/RMS loop from the
    /// spans and counters of completed resizes) instead of the static
    /// calibration the simulation was launched with.  `false`
    /// (default) is bit-identical to the pre-recalibration planner.
    pub recalib: bool,
}

impl Default for ReconfigCfg {
    fn default() -> Self {
        ReconfigCfg {
            method: Method::Collective,
            strategy: Strategy::Blocking,
            spawn_cost: 0.25,
            spawn_strategy: SpawnStrategy::Sequential,
            win_pool: WinPoolPolicy::off(),
            rma_chunk_kib: 0,
            rma_dereg: true,
            rma_sync: RmaSync::Epoch,
            sched_cache: false,
            planner: PlannerMode::Fixed,
            recalib: false,
        }
    }
}

impl ReconfigCfg {
    /// Builder entry point: the given redistribution version over
    /// default knobs.  Chain the `with_*` setters for the rest —
    /// `ReconfigCfg::version(m, s).with_pool(pool).with_chunk(1024)`
    /// replaces the eleven-field struct literal harnesses used to
    /// spell out.
    pub fn version(method: Method, strategy: Strategy) -> ReconfigCfg {
        ReconfigCfg { method, strategy, ..ReconfigCfg::default() }
    }

    /// Spawn strategy and the Sequential-model constant.
    pub fn with_spawn(mut self, strategy: SpawnStrategy, cost: f64) -> ReconfigCfg {
        self.spawn_strategy = strategy;
        self.spawn_cost = cost;
        self
    }

    /// Persistent window pool policy (§VI).
    pub fn with_pool(mut self, pool: WinPoolPolicy) -> ReconfigCfg {
        self.win_pool = pool;
        self
    }

    /// Chunked pipelined registration segment size (KiB, 0 = off).
    pub fn with_chunk(mut self, kib: u64) -> ReconfigCfg {
        self.rma_chunk_kib = kib;
        self
    }

    /// Pipelined teardown toggle (meaningful only when chunked).
    pub fn with_dereg(mut self, dereg: bool) -> ReconfigCfg {
        self.rma_dereg = dereg;
        self
    }

    /// RMA completion-synchronization mode (`--rma-sync`).
    pub fn with_sync(mut self, sync: RmaSync) -> ReconfigCfg {
        self.rma_sync = sync;
        self
    }

    /// Persistent-schedule cache toggle (`--sched-cache`).
    pub fn with_sched_cache(mut self, sched: bool) -> ReconfigCfg {
        self.sched_cache = sched;
        self
    }

    /// Planner mode (`Fixed` uses the fields verbatim).
    pub fn with_planner(mut self, planner: PlannerMode) -> ReconfigCfg {
        self.planner = planner;
        self
    }

    /// Online recalibration toggle (`Auto` planning only).
    pub fn with_recalib(mut self, recalib: bool) -> ReconfigCfg {
        self.recalib = recalib;
        self
    }

    /// Segment size in elements of the chunked pipelined registration
    /// (0 = unchunked).  Saturating: an absurdly large chunk degrades
    /// to "one segment" (the unchunked path) instead of overflowing.
    pub fn chunk_elems(&self) -> u64 {
        self.rma_chunk_kib.saturating_mul(1024) / crate::simmpi::ELEM_BYTES
    }

    /// The RMA lifecycle-pipeline knobs this configuration implies for
    /// a resize with `roles`: chunk size, pipelined teardown
    /// (`rma_dereg`), and spawn-overlapped registration streams —
    /// eager only for chunked *grows* under asynchronous spawning
    /// (shrinks never spawn, and blocking spawn strategies leave no
    /// startup window to overlap).  Rank-independent, so sources and
    /// spawned drains derive the same opts without communicating.
    pub fn lifecycle(&self, roles: &Roles) -> rma::LifecycleOpts {
        let chunk_elems = self.chunk_elems();
        rma::LifecycleOpts {
            chunk_elems,
            dereg_pipeline: chunk_elems > 0 && self.rma_dereg,
            eager_reg: chunk_elems > 0
                && roles.is_grow()
                && self.spawn_strategy == SpawnStrategy::Async,
        }
    }

    /// The full RMA redistribution options this configuration implies
    /// for a resize with `roles`: lifecycle pipeline, completion
    /// synchronization and schedule-cache routing.  Rank-independent.
    pub fn rma_opts(&self, lockall: bool, roles: &Roles) -> rma::RedistOpts {
        rma::RedistOpts::new(lockall, self.win_pool)
            .lifecycle(self.lifecycle(roles))
            .sync(self.rma_sync)
            .sched(self.sched_cache)
    }
}

/// Result of [`Mam::reconfigure`] / [`Mam::checkpoint`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MamStatus {
    /// No reconfiguration in progress.
    Idle,
    /// Background redistribution still running — keep iterating.
    InProgress,
    /// Redistribution done; call [`Mam::finish`].
    Completed,
    /// The resize unwound to the previous layout (`--faults`: spawn
    /// retries exhausted).  The application resumes on its *old*
    /// communicator; do **not** call [`Mam::finish`].  The RMS loop
    /// may re-queue or re-target the resize.
    Aborted,
}

/// Background-redistribution progress state.
enum State {
    /// Everything already done (blocking strategies).
    Done,
    /// COL-NB: completion = local `ialltoallv` requests done (§V-A: a
    /// source deems communication complete once its sends are out).
    ColNb { reqs: Vec<ReqId> },
    /// COL-WD: local requests, then the global confirmation barrier.
    ColWd { reqs: Vec<ReqId>, barrier: Option<ReqId> },
    /// RMA-WD (`Complete_RMA`, Fig. 2): local read phase, then barrier,
    /// then local window frees.
    RmaWd { init: RmaInit, barrier: Option<ReqId> },
    /// Threading: the blocking method runs on the auxiliary thread; the
    /// result is dropped into the shared slot on completion.
    Threading { slot: Arc<Mutex<Option<Vec<Option<Payload>>>>> },
}

/// An in-flight (or just-completed) reconfiguration.
pub struct Reconfiguration {
    pub merged: CommId,
    pub roles: Roles,
    pub started_at: Time,
    /// The configuration actually executed by this resize — equal to
    /// `Mam::cfg` under `PlannerMode::Fixed`, the planner's per-resize
    /// choice under `Auto`.
    pub cfg: ReconfigCfg,
    state: State,
    /// Registry indices being redistributed in this phase (§III: only
    /// *constant* data may move in the background; *variable* data is
    /// redistributed while the application is blocked, in `finish`).
    which: Vec<usize>,
    /// New local payloads (parallel to `which`), set once data is in.
    new_locals: Option<Vec<Option<Payload>>>,
}

/// Outcome of [`Mam::finish`].
#[derive(Clone, Copy, Debug)]
pub struct FinishOutcome {
    /// Communicator the application resumes on (`None` for retired
    /// ranks, which must return from their body after this call).
    pub app_comm: Option<CommId>,
    pub roles: Roles,
}

/// The per-rank Malleability Module handle.
pub struct Mam {
    pub registry: Registry,
    pub cfg: ReconfigCfg,
    inflight: Option<Reconfiguration>,
    /// Live recalibrated `NetParams` ([`ReconfigCfg::recalib`]): when
    /// set and `cfg.recalib` is on, `Auto` planning prices candidates
    /// against this belief instead of the simulation's static
    /// calibration.  Must be fed identically on every rank (the
    /// recalibrator digests global metrics, so it is) to preserve the
    /// planner's rank-independence contract.
    live: Option<crate::netmodel::calibration::NetParams>,
    /// Persistent redistribution schedules ([`ReconfigCfg::sched_cache`]):
    /// the Rust-side memo of built plans, one per
    /// `(from, to, structure, chunk)` shape this handle has resized
    /// through.  The virtual-time warmth lives in the simulated world
    /// (`MpiProc::sched_acquire`), keyed by rank slot so it survives
    /// process churn.
    sched: SchedCache,
    /// Fault-decision context (`--faults`): the `(resize, dispatch)`
    /// pair identifying the current reconfiguration attempt.  Set by
    /// the harness before each `reconfigure` so fault draws agree
    /// across ranks and change on every re-dispatch of an aborted
    /// resize; `(0, 0)` when the harness never resizes twice.
    fault_ctx: (u64, u64),
}

impl Mam {
    pub fn new(registry: Registry, cfg: ReconfigCfg) -> Mam {
        Mam {
            registry,
            cfg,
            inflight: None,
            live: None,
            sched: SchedCache::new(),
            fault_ctx: (0, 0),
        }
    }

    /// Identify the upcoming reconfiguration attempt for fault
    /// injection: `resize` is the scenario-level resize index,
    /// `dispatch` counts re-dispatches of the same resize after
    /// aborts.  Must be called identically on every source rank.
    pub fn set_fault_ctx(&mut self, resize: u64, dispatch: u64) {
        self.fault_ctx = (resize, dispatch);
    }

    /// Schedule-memo counters `(hits, misses)` — the observable the
    /// cross-resize investment credit is validated against.
    pub fn sched_cache_counters(&self) -> (u64, u64) {
        (self.sched.hits, self.sched.misses)
    }

    /// Install the online estimator's current belief (no-op for
    /// planning unless `cfg.recalib && cfg.planner == Auto`).
    pub fn set_live_params(&mut self, p: crate::netmodel::calibration::NetParams) {
        self.live = Some(p);
    }

    /// Is a background redistribution currently running?
    pub fn in_progress(&self) -> bool {
        self.inflight.is_some()
    }

    /// Roles of the in-flight reconfiguration, if any.
    pub fn roles(&self) -> Option<Roles> {
        self.inflight.as_ref().map(|r| r.roles)
    }

    /// The configuration this resize executes: the configured fields
    /// under `PlannerMode::Fixed`, the analytic planner's per-resize
    /// choice under `Auto` (resolved from rank-independent inputs, so
    /// every rank — including spawned drains running
    /// [`Mam::drain_join`] with the same `Auto` configuration —
    /// arrives at the same plan without communicating).
    fn active_cfg(&self, proc: &MpiProc, ns: usize, nd: usize) -> ReconfigCfg {
        if self.cfg.planner == PlannerMode::Auto {
            let static_params = proc.net_params();
            let net = match (&self.live, self.cfg.recalib) {
                (Some(live), true) => live,
                _ => &static_params,
            };
            // An installed fault plan's wave-failure probability flows
            // into the pricing so Auto stops preferring late-detecting
            // Async under lossy spawns (same pure inputs on every
            // rank, drains included — the plan is world-global).
            let fail_p = proc.fault_plan().map_or(0.0, |pl| pl.spec.spawn_fail_p);
            planner::resolve_internal(
                net,
                proc.cores_per_node(),
                self.registry.decls(),
                ns,
                nd,
                &self.cfg,
                fail_p,
            )
        } else {
            self.cfg.clone()
        }
    }

    /// Pre-spawn fault charges at resize entry (`--faults`): this
    /// source rank's straggler delay and — for RMA methods — the
    /// extra registration time of a slowed NIC, modeled as local
    /// compute so downstream collectives observe the skew.  Pure
    /// per-rank draws; ranks that draw nothing charge nothing.
    fn charge_entry_faults(
        &self,
        proc: &MpiProc,
        app_comm: CommId,
        cfg: &ReconfigCfg,
        plan: &FaultPlan,
    ) {
        let (resize, dispatch) = self.fault_ctx;
        let me = proc.rank(app_comm);
        let straggle = plan.straggler_delay(resize, dispatch, me);
        if straggle > 0.0 {
            proc.metrics(|m| m.add_counter("faults.straggler_secs", straggle));
            proc.compute(straggle);
        }
        if cfg.method != Method::Collective {
            let f = plan.reg_slow_factor(resize, dispatch, me);
            if f > 1.0 {
                let bytes: u64 = (0..self.registry.len())
                    .map(|i| self.registry.entry(i).local.bytes())
                    .sum();
                let extra = bytes as f64 * proc.net_params().beta_register * (f - 1.0);
                if extra > 0.0 {
                    proc.metrics(|m| m.add_counter("faults.reg_extra_secs", extra));
                    proc.compute(extra);
                }
            }
        }
    }

    /// Lost notify counters (`--faults notify=`): the decision is a
    /// pure function of the resize shape, so sources and the
    /// independently spawned drains (via [`Mam::drain_join`]) agree on
    /// the epoch-sync fallback without communicating.  Every rank pays
    /// the detection timeout before switching protocols.
    fn apply_notify_fallback(
        proc: &MpiProc,
        ns: usize,
        nd: usize,
        cfg: &mut ReconfigCfg,
        plan: &FaultPlan,
    ) {
        if cfg.rma_sync == RmaSync::Notify
            && cfg.method != Method::Collective
            && plan.notify_lost(ns, nd)
        {
            proc.metrics(|m| m.add_counter("faults.notify_timeouts", 1.0));
            proc.compute(plan.spec.notify_timeout);
            cfg.rma_sync = RmaSync::Epoch;
        }
    }

    /// Abort-and-rollback invalidation: drop every `ns → nd` schedule
    /// from the Rust-side memo *and* the simulated world's rank-slot
    /// pin set, and drop the window pool's pins for every registered
    /// structure.  Conservative by design — warm state that merely
    /// *might* span the aborted dispatch is repriced cold on the next
    /// occurrence rather than replayed.
    fn poison_on_abort(&mut self, proc: &MpiProc, ns: usize, nd: usize, cfg: &ReconfigCfg) {
        for h in self.sched.poison(ns, nd) {
            proc.sched_invalidate(h);
        }
        let chunk = cfg.chunk_elems();
        for i in 0..self.registry.len() {
            let e = self.registry.entry(i);
            // The shape may never have entered this handle's memo
            // (fresh Mam after churn) while the world still holds its
            // rank-slot descriptor — invalidate by reconstructed key
            // too.
            let key = SchedKey {
                from: ns,
                to: nd,
                structure: winpool::pin_token(&e.name),
                total_elems: e.total_elems,
                chunk_elems: chunk,
            };
            proc.sched_invalidate(key.hash64());
            proc.win_pool_poison(winpool::pin_token(&e.name));
        }
    }

    /// Start a reconfiguration of `app_comm` (all current ranks call
    /// this) towards `nd` ranks.  `drain_body` is the main function of
    /// newly spawned processes (grow only).
    ///
    /// Returns `Completed` for blocking strategies, `InProgress` for
    /// background ones.
    pub fn reconfigure(
        &mut self,
        proc: &MpiProc,
        app_comm: CommId,
        nd: usize,
        drain_body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync>,
    ) -> MamStatus {
        assert!(self.inflight.is_none(), "reconfiguration already in progress");
        let ns = proc.size(app_comm);
        assert!(nd > 0 && nd != ns, "invalid target size {nd} (ns={ns})");
        let mut cfg = self.active_cfg(proc, ns, nd);
        let t_begin = proc.now();
        let plan = proc.fault_plan();
        if let Some(plan) = &plan {
            self.charge_entry_faults(proc, app_comm, &cfg, plan);
            Self::apply_notify_fallback(proc, ns, nd, &mut cfg, plan);
        }

        // ---- Stage 2: process management (Merge).
        let merged = if nd > ns {
            match &plan {
                None => {
                    let sched = cfg.spawn_strategy.schedule(
                        &proc.net_params(),
                        ns,
                        nd - ns,
                        nd,
                        cfg.spawn_cost,
                    );
                    proc.spawn_merge_scheduled(app_comm, nd - ns, &sched, drain_body)
                }
                Some(plan) => {
                    let out = resilience::spawn_with_recovery(
                        proc,
                        app_comm,
                        ns,
                        nd,
                        &cfg,
                        drain_body,
                        plan,
                        self.fault_ctx,
                    );
                    if out.failed_attempts > 0 && proc.rank(app_comm) == 0 {
                        let (tries, ranks) = (out.failed_attempts, out.failed_ranks);
                        proc.metrics(|m| {
                            m.add_counter("faults.spawn_retries", f64::from(tries));
                            m.add_counter("faults.spawn_failed", ranks as f64);
                        });
                    }
                    match out.merged {
                        Some(mc) => mc,
                        None => {
                            // Retries exhausted: unwind to the previous
                            // layout.  Nothing was spawned and nothing
                            // rebuilt, but this shape's memoized
                            // schedules and window pins can no longer be
                            // trusted warm — poison them so the next
                            // occurrence rebuilds cold, then hand the
                            // decision back to the caller (re-queue,
                            // re-target or give up), app still on its
                            // old communicator.
                            self.poison_on_abort(proc, ns, nd, &cfg);
                            if proc.rank(app_comm) == 0 {
                                proc.metrics(|m| m.add_counter("faults.rollbacks", 1.0));
                            }
                            return MamStatus::Aborted;
                        }
                    }
                }
            }
        } else {
            // Duplicate so redistribution traffic cannot cross-match
            // with application collectives on `app_comm`.
            proc.comm_sub(app_comm, ns)
        };
        let roles = Roles { ns, nd, rank: proc.rank(merged) };
        proc.metrics(|m| {
            m.mark_min("mam.reconf_start", t_begin);
            m.mark_min("mam.redist_start", proc.now());
        });

        // ---- Stage 3: data redistribution.  Blocking strategies move
        // everything now; background strategies move the *constant*
        // entries in the background (§III) and leave variable entries
        // to the blocking phase inside `finish`.
        let which: Vec<usize> = if cfg.strategy == Strategy::Blocking {
            (0..self.registry.len()).collect()
        } else {
            self.registry.of_kind(DataKind::Constant)
        };
        let state = self.start_redistribution(proc, merged, &roles, &which, &cfg);
        let done = matches!(state, State::Done);
        self.inflight = Some(Reconfiguration {
            merged,
            roles,
            started_at: t_begin,
            cfg,
            state,
            which,
            new_locals: None,
        });
        if done {
            Self::record_done(proc);
            MamStatus::Completed
        } else {
            MamStatus::InProgress
        }
    }

    /// Dispatch stage 3 and, for blocking strategies, run it to
    /// completion (applying new payloads).
    fn start_redistribution(
        &mut self,
        proc: &MpiProc,
        merged: CommId,
        roles: &Roles,
        which: &[usize],
        cfg: &ReconfigCfg,
    ) -> State {
        match (cfg.method, cfg.strategy) {
            // ------------------------------------------------ blocking
            (Method::Collective, Strategy::Blocking) => {
                let locals =
                    col::redistribute_blocking(proc, merged, roles, &self.registry, which);
                self.apply_locals(proc, which, locals, roles, cfg.win_pool);
                State::Done
            }
            (m, Strategy::Blocking) => {
                let lockall = m == Method::RmaLockall;
                let locals = rma::redistribute_sched(
                    proc,
                    merged,
                    roles,
                    &self.registry,
                    which,
                    cfg.rma_opts(lockall, roles),
                    &mut self.sched,
                );
                self.apply_locals(proc, which, locals, roles, cfg.win_pool);
                State::Done
            }
            // -------------------------------------------- non-blocking
            (Method::Collective, Strategy::NonBlocking) => {
                let reqs = col::start_nonblocking(proc, merged, roles, &self.registry, which);
                State::ColNb { reqs }
            }
            (_, Strategy::NonBlocking) => {
                panic!("NB is undefined for RMA methods (§V-A); use Wait Drains")
            }
            // ---------------------------------------------- wait drains
            (Method::Collective, Strategy::WaitDrains) => {
                let reqs = col::start_nonblocking(proc, merged, roles, &self.registry, which);
                State::ColWd { reqs, barrier: None }
            }
            (m, Strategy::WaitDrains) => {
                let lockall = m == Method::RmaLockall;
                let init = rma::init_rma_sched(
                    proc,
                    merged,
                    roles,
                    &self.registry,
                    which,
                    cfg.rma_opts(lockall, roles),
                    &mut self.sched,
                );
                // Source-only ranks have no reads: they notify the
                // others right away (Fig. 1) and keep computing.
                // Notified completion never issues the barrier — every
                // rank observes readiness through the notify counters.
                let barrier = if cfg.rma_sync == RmaSync::Epoch && !roles.is_drain() {
                    Some(proc.ibarrier(merged))
                } else {
                    None
                };
                State::RmaWd { init, barrier }
            }
            // ------------------------------------------------ threading
            (m, Strategy::Threading) => {
                let slot: Arc<Mutex<Option<Vec<Option<Payload>>>>> =
                    Arc::new(Mutex::new(None));
                let s2 = slot.clone();
                let reg = self.registry.clone();
                let roles2 = *roles;
                let which2 = which.to_vec();
                // The aux thread gets its own (empty) schedule memo —
                // the Rust-side memo is free in virtual time, and the
                // warmth that matters lives in the simulated world's
                // rank-slot pins, which the aux shares.
                let lock_opts = cfg.rma_opts(false, roles);
                let lockall_opts = cfg.rma_opts(true, roles);
                proc.spawn_aux(move |aux| {
                    let mut memo = SchedCache::new();
                    let locals = match m {
                        Method::Collective => {
                            col::redistribute_blocking(&aux, merged, &roles2, &reg, &which2)
                        }
                        Method::RmaLock => rma::redistribute_sched(
                            &aux,
                            merged,
                            &roles2,
                            &reg,
                            &which2,
                            lock_opts,
                            &mut memo,
                        ),
                        Method::RmaLockall => rma::redistribute_sched(
                            &aux,
                            merged,
                            &roles2,
                            &reg,
                            &which2,
                            lockall_opts,
                            &mut memo,
                        ),
                    };
                    *s2.lock().unwrap() = Some(locals);
                });
                State::Threading { slot }
            }
        }
    }

    /// Per-iteration completion poll (the application calls this once
    /// per iteration while `InProgress` — MaM's checkpoint API).
    pub fn checkpoint(&mut self, proc: &MpiProc) -> MamStatus {
        let Some(rc) = self.inflight.as_mut() else {
            return MamStatus::Idle;
        };
        let roles = rc.roles;
        let merged = rc.merged;
        let which = rc.which.clone();
        let pool = rc.cfg.win_pool;
        // Already completed earlier (e.g. the app re-polls while other
        // ranks catch up): stay Completed without re-recording metrics.
        if matches!(rc.state, State::Done) && rc.new_locals.is_none() {
            return MamStatus::Completed;
        }
        let done = match &mut rc.state {
            State::Done => true,
            State::ColNb { reqs } => {
                if proc.req_testall(reqs) {
                    let locals =
                        col::collect_nonblocking(proc, &roles, &self.registry, &which, reqs);
                    rc.new_locals = Some(locals);
                    rc.state = State::Done;
                    true
                } else {
                    false
                }
            }
            State::ColWd { reqs, barrier } => match barrier {
                None => {
                    if proc.req_testall(reqs) {
                        let locals = col::collect_nonblocking(
                            proc, &roles, &self.registry, &which, reqs,
                        );
                        rc.new_locals = Some(locals);
                        // Local part done: join the confirmation barrier.
                        *barrier = Some(proc.ibarrier(merged));
                    }
                    false
                }
                Some(b) => {
                    if proc.req_test(*b) {
                        rc.state = State::Done;
                        true
                    } else {
                        false
                    }
                }
            },
            State::RmaWd { init, barrier: _ } if init.sync == RmaSync::Notify => {
                // Notified completion (Fig. 2 without the barrier):
                // local phase waits for this rank's own Rgets and
                // charges the notification flags; the global phase
                // polls the per-window notify counters — teardown
                // proceeds as soon as every read into this rank's
                // exposure has been posted, no collective required.
                if rc.new_locals.is_none() {
                    if proc.req_testall(&init.reqs) {
                        proc.rma_notify_charge(init.n_reads);
                        rc.new_locals = Some(rma::take_payloads(init));
                    }
                    false
                } else if rma::notify_all_ready(proc, init) {
                    rma::free_windows_local(proc, init);
                    rc.state = State::Done;
                    true
                } else {
                    false
                }
            }
            State::RmaWd { init, barrier } => match barrier {
                None => {
                    // Local phase (drains): wait for own Rgets.
                    if proc.req_testall(&init.reqs) {
                        rma::close_epochs(proc, init);
                        rc.new_locals = Some(rma::take_payloads(init));
                        *barrier = Some(proc.ibarrier(merged));
                    }
                    false
                }
                Some(b) => {
                    // Global phase: poll the barrier, then free locally.
                    if proc.req_test(*b) {
                        rma::free_windows_local(proc, init);
                        rc.state = State::Done;
                        true
                    } else {
                        false
                    }
                }
            },
            State::Threading { slot } => {
                if proc.aux_alive() {
                    false
                } else {
                    rc.new_locals = slot.lock().unwrap().take();
                    rc.state = State::Done;
                    true
                }
            }
        };
        if done {
            if let Some(locals) = rc.new_locals.take() {
                let roles = rc.roles;
                self.apply_locals(proc, &which, locals, &roles, pool);
            }
            Self::record_done(proc);
            MamStatus::Completed
        } else {
            MamStatus::InProgress
        }
    }

    /// Block until the in-flight redistribution completes (used by
    /// ranks with no application work to overlap).
    pub fn wait_completion(&mut self, proc: &MpiProc) {
        while self.checkpoint(proc) == MamStatus::InProgress {
            proc.compute(0.0);
        }
    }

    /// Stage 4: resume execution.  Collective over the *old* application
    /// communicator's members (and, on grow, the spawned drains, which
    /// mirror it inside `drain_join`).  Background strategies first
    /// redistribute the *variable* entries here, while the application
    /// is blocked (§III), then the communicator is switched.  Consumes
    /// the reconfiguration.
    pub fn finish(&mut self, proc: &MpiProc, app_comm: CommId) -> FinishOutcome {
        let rc = self.inflight.take().expect("no reconfiguration to finish");
        assert!(matches!(rc.state, State::Done), "finish() before completion");
        let roles = rc.roles;
        if rc.cfg.strategy.is_background() {
            let variable = self.registry.of_kind(DataKind::Variable);
            if !variable.is_empty() {
                let locals = col::redistribute_blocking(
                    proc,
                    rc.merged,
                    &roles,
                    &self.registry,
                    &variable,
                );
                self.apply_locals(proc, &variable, locals, &roles, rc.cfg.win_pool);
            }
        }
        proc.metrics(|m| m.mark_max("mam.reconf_end", proc.now()));
        if roles.is_grow() {
            FinishOutcome { app_comm: Some(rc.merged), roles }
        } else {
            // Shrink: collective prefix split of the old communicator;
            // retired ranks get `None` and must return.
            let sub = proc.comm_sub(app_comm, roles.nd);
            let keep = proc.rank(app_comm) < roles.nd;
            FinishOutcome { app_comm: keep.then_some(sub), roles }
        }
    }

    fn record_done(proc: &MpiProc) {
        let t = proc.now();
        proc.metrics(|m| {
            m.mark_max("mam.redist_end", t);
            m.push_series("mam.redist_done_t", t);
        });
    }

    /// Install redistributed payloads into the registry (drain side).
    /// `locals` is parallel to the `which` index list.  With the window
    /// pool on, each installed block is *pre-pinned* (register-on-
    /// receive, §VI): the registration happens here — local time, off
    /// the collective critical path — so the next resize's window
    /// acquires are warm on every rank.
    fn apply_locals(
        &mut self,
        proc: &MpiProc,
        which: &[usize],
        locals: Vec<Option<Payload>>,
        roles: &Roles,
        pool: WinPoolPolicy,
    ) {
        assert_eq!(locals.len(), which.len());
        for (&i, l) in which.iter().zip(locals) {
            if let Some(p) = l {
                debug_assert!(roles.is_drain());
                self.registry.entry_mut(i).local = p;
                if pool.enabled {
                    let e = self.registry.entry(i);
                    proc.pin_buffer(winpool::pin_token(&e.name), e.local.bytes(), pool.cap);
                }
            }
        }
    }

    /// Entry point for spawned drain processes (grow): build the
    /// registry from declarations and mirror the source collective call
    /// sequence of the configured method/strategy until the data is in.
    /// Returns the populated `Mam`; the caller then enters the
    /// application loop on `merged`.
    pub fn drain_join(
        proc: &MpiProc,
        merged: CommId,
        ns: usize,
        nd: usize,
        decls: &[DataDecl],
        cfg: ReconfigCfg,
    ) -> Mam {
        let mut mam = Mam::new(Registry::from_decls(decls), cfg);
        let roles = Roles { ns, nd, rank: proc.rank(merged) };
        assert!(roles.is_drain_only(), "drain_join is for spawned ranks");
        // Mirror the sources' per-resize resolution: under
        // `PlannerMode::Auto` the analytic planner runs on the same
        // rank-independent inputs and lands on the same choice — and
        // the same shape-keyed notify-loss fallback decision.
        let mut active = mam.active_cfg(proc, ns, nd);
        if let Some(plan) = proc.fault_plan() {
            Self::apply_notify_fallback(proc, ns, nd, &mut active, &plan);
        }
        let which: Vec<usize> = if active.strategy == Strategy::Blocking {
            (0..mam.registry.len()).collect()
        } else {
            mam.registry.of_kind(DataKind::Constant)
        };
        let locals = match (active.method, active.strategy) {
            // Blocking + Threading sources run the plain blocking
            // sequence on the merged comm (Threading just moves it to an
            // aux thread — same collective order).
            (Method::Collective, Strategy::Blocking | Strategy::Threading) => {
                col::redistribute_blocking(proc, merged, &roles, &mam.registry, &which)
            }
            (m, Strategy::Blocking | Strategy::Threading) => rma::redistribute_sched(
                proc,
                merged,
                &roles,
                &mam.registry,
                &which,
                active.rma_opts(m == Method::RmaLockall, &roles),
                &mut mam.sched,
            ),
            (Method::Collective, Strategy::NonBlocking) => {
                let reqs = col::start_nonblocking(proc, merged, &roles, &mam.registry, &which);
                proc.req_waitall(&reqs);
                col::collect_nonblocking(proc, &roles, &mam.registry, &which, &reqs)
            }
            (Method::Collective, Strategy::WaitDrains) => {
                let reqs = col::start_nonblocking(proc, merged, &roles, &mam.registry, &which);
                proc.req_waitall(&reqs);
                let locals =
                    col::collect_nonblocking(proc, &roles, &mam.registry, &which, &reqs);
                let b = proc.ibarrier(merged);
                proc.req_wait(b);
                locals
            }
            (m, Strategy::WaitDrains) => {
                // Fig. 2 drain-only path: blocking local phase, then the
                // global sync (barrier, or the notify counters under
                // notified completion), then the local frees.
                let mut init = rma::init_rma_sched(
                    proc,
                    merged,
                    &roles,
                    &mam.registry,
                    &which,
                    active.rma_opts(m == Method::RmaLockall, &roles),
                    &mut mam.sched,
                );
                proc.req_waitall(&init.reqs);
                if init.sync == RmaSync::Notify {
                    proc.rma_notify_charge(init.n_reads);
                    // A spawned drain's own exposure is never read, so
                    // the notified free returns as soon as it is armed.
                    rma::free_windows_local(proc, &init);
                } else {
                    rma::close_epochs(proc, &init);
                    let b = proc.ibarrier(merged);
                    proc.req_wait(b);
                    rma::free_windows_local(proc, &init);
                }
                rma::take_payloads(&mut init)
            }
            (_, Strategy::NonBlocking) => unreachable!("validated at reconfigure()"),
        };
        mam.apply_locals(proc, &which, locals, &roles, active.win_pool);
        Mam::record_done(proc);
        // Mirror the sources' `finish`: blocking redistribution of the
        // variable entries (background strategies only — blocking moved
        // everything already).
        if active.strategy.is_background() {
            let variable = mam.registry.of_kind(DataKind::Variable);
            if !variable.is_empty() {
                let locals =
                    col::redistribute_blocking(proc, merged, &roles, &mam.registry, &variable);
                mam.apply_locals(proc, &variable, locals, &roles, active.win_pool);
            }
        }
        mam
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mam::registry::DataKind;
    use crate::mam::{block_of, Method, Strategy};
    use crate::netmodel::{NetParams, Topology};
    use crate::simmpi::{MpiSim, WORLD};
    use std::sync::atomic::{AtomicUsize, Ordering};

    /// The builder chain must reproduce the full eleven-field struct
    /// literal knob for knob, and `version()` alone must equal
    /// `Default` with only the version overridden.
    #[test]
    fn builder_matches_struct_literal() {
        let pool = WinPoolPolicy { enabled: true, cap: 3 };
        let built = ReconfigCfg::version(Method::RmaLockall, Strategy::WaitDrains)
            .with_spawn(SpawnStrategy::Async, 0.125)
            .with_pool(pool)
            .with_chunk(512)
            .with_dereg(false)
            .with_sync(RmaSync::Notify)
            .with_sched_cache(true)
            .with_planner(PlannerMode::Auto)
            .with_recalib(true);
        let lit = ReconfigCfg {
            method: Method::RmaLockall,
            strategy: Strategy::WaitDrains,
            spawn_cost: 0.125,
            spawn_strategy: SpawnStrategy::Async,
            win_pool: pool,
            rma_chunk_kib: 512,
            rma_dereg: false,
            rma_sync: RmaSync::Notify,
            sched_cache: true,
            planner: PlannerMode::Auto,
            recalib: true,
        };
        assert_eq!(built.method, lit.method);
        assert_eq!(built.strategy, lit.strategy);
        assert_eq!(built.spawn_cost.to_bits(), lit.spawn_cost.to_bits());
        assert_eq!(built.spawn_strategy, lit.spawn_strategy);
        assert_eq!(built.win_pool.enabled, lit.win_pool.enabled);
        assert_eq!(built.win_pool.cap, lit.win_pool.cap);
        assert_eq!(built.rma_chunk_kib, lit.rma_chunk_kib);
        assert_eq!(built.rma_dereg, lit.rma_dereg);
        assert_eq!(built.rma_sync, lit.rma_sync);
        assert_eq!(built.sched_cache, lit.sched_cache);
        assert_eq!(built.planner, lit.planner);
        assert_eq!(built.recalib, lit.recalib);

        let bare = ReconfigCfg::version(Method::RmaLock, Strategy::Threading);
        let def = ReconfigCfg::default();
        assert_eq!(bare.method, Method::RmaLock);
        assert_eq!(bare.strategy, Strategy::Threading);
        assert_eq!(bare.spawn_cost.to_bits(), def.spawn_cost.to_bits());
        assert_eq!(bare.spawn_strategy, def.spawn_strategy);
        assert_eq!(bare.win_pool.enabled, def.win_pool.enabled);
        assert_eq!(bare.rma_chunk_kib, def.rma_chunk_kib);
        assert_eq!(bare.rma_dereg, def.rma_dereg);
        assert_eq!(bare.rma_sync, RmaSync::Epoch);
        assert!(!bare.sched_cache);
        assert_eq!(bare.planner, def.planner);
        assert_eq!(bare.recalib, def.recalib);
    }

    /// Full grow-or-shrink reconfiguration over real payloads; verifies
    /// every continuing rank ends with the exact ND-way block.  The
    /// window-pool variant must be payload-identical to the cold path —
    /// the roundtrip assertions check the exact expected block either
    /// way — and so must every spawn strategy and every chunk size.
    fn roundtrip_chunked(
        ns: usize,
        nd: usize,
        method: Method,
        strategy: Strategy,
        pool: bool,
        spawn_strategy: SpawnStrategy,
        rma_chunk_kib: u64,
    ) {
        roundtrip_lifecycle(ns, nd, method, strategy, pool, spawn_strategy, rma_chunk_kib, true);
    }

    /// [`roundtrip_chunked`] under notified completion and/or the
    /// persistent-schedule cache: the payload assertions are the
    /// sync-mode/cache parity check — every continuing rank must end
    /// with the exact ND-way block either way.
    fn roundtrip_sync(
        ns: usize,
        nd: usize,
        method: Method,
        strategy: Strategy,
        pool: bool,
        rma_chunk_kib: u64,
        rma_sync: RmaSync,
        sched_cache: bool,
    ) {
        roundtrip_cfg_full(ns, nd, pool, SpawnStrategy::Sequential, true, ReconfigCfg {
            method,
            strategy,
            rma_chunk_kib,
            rma_sync,
            sched_cache,
            ..ReconfigCfg::default()
        });
    }

    /// [`roundtrip_chunked`] with the teardown pipeline explicit
    /// (`rma_dereg = false` exercises the registration-only pipeline's
    /// Mam dispatch).
    #[allow(clippy::too_many_arguments)]
    fn roundtrip_lifecycle(
        ns: usize,
        nd: usize,
        method: Method,
        strategy: Strategy,
        pool: bool,
        spawn_strategy: SpawnStrategy,
        rma_chunk_kib: u64,
        rma_dereg: bool,
    ) {
        roundtrip_cfg_full(ns, nd, pool, spawn_strategy, rma_dereg, ReconfigCfg {
            method,
            strategy,
            rma_chunk_kib,
            ..ReconfigCfg::default()
        });
    }

    /// The underlying roundtrip: `base` carries the method/strategy and
    /// the new-knob fields; pool, spawn and dereg are layered on top.
    fn roundtrip_cfg_full(
        ns: usize,
        nd: usize,
        pool: bool,
        spawn_strategy: SpawnStrategy,
        rma_dereg: bool,
        base: ReconfigCfg,
    ) {
        let total = 997u64;
        let mut sim = MpiSim::new(Topology::new(2, 6), NetParams::test_simple());
        let checks = Arc::new(AtomicUsize::new(0));
        let checks2 = checks.clone();
        sim.launch(ns, move |p| {
            let r = p.rank(WORLD);
            let b = block_of(total, ns, r);
            let mut reg = Registry::new();
            reg.register(
                "A",
                DataKind::Constant,
                total,
                Payload::real((b.ini..b.end).map(|i| i as f64).collect()),
            );
            let cfg = base
                .clone()
                .with_spawn(spawn_strategy, 0.01)
                .with_pool(if pool { WinPoolPolicy::on() } else { WinPoolPolicy::off() })
                .with_dereg(rma_dereg);
            let decls = reg.decls();
            let mut mam = Mam::new(reg, cfg.clone());
            let checks3 = checks2.clone();
            let drain_body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
                Arc::new(move |dp: MpiProc, merged: CommId| {
                    let dmam = Mam::drain_join(&dp, merged, ns, nd, &decls, cfg.clone());
                    let dr = dp.rank(merged);
                    let nb = block_of(total, nd, dr);
                    let got = dmam.registry.entry(0).local.as_slice().unwrap().to_vec();
                    let want: Vec<f64> = (nb.ini..nb.end).map(|i| i as f64).collect();
                    assert_eq!(got, want, "spawned drain {dr} wrong block");
                    checks3.fetch_add(1, Ordering::SeqCst);
                });
            let mut status = mam.reconfigure(&p, WORLD, nd, drain_body);
            let mut iters = 0;
            while status == MamStatus::InProgress {
                p.compute(1e-3); // the app keeps iterating
                status = mam.checkpoint(&p);
                iters += 1;
                assert!(iters < 100_000, "redistribution never completes");
            }
            let out = mam.finish(&p, WORLD);
            match out.app_comm {
                Some(c) => {
                    let nr = p.rank(c);
                    assert!(nr < nd);
                    let nb = block_of(total, nd, nr);
                    let got = mam.registry.entry(0).local.as_slice().unwrap().to_vec();
                    let want: Vec<f64> = (nb.ini..nb.end).map(|i| i as f64).collect();
                    assert_eq!(got, want, "rank {nr} wrong block after finish");
                    checks2.fetch_add(1, Ordering::SeqCst);
                }
                None => assert!(r >= nd, "rank {r} wrongly retired"),
            }
        });
        sim.run().unwrap();
        assert_eq!(
            checks.load(Ordering::SeqCst),
            nd,
            "every drain must verify its block"
        );
    }

    fn roundtrip_cfg(
        ns: usize,
        nd: usize,
        method: Method,
        strategy: Strategy,
        pool: bool,
        spawn_strategy: SpawnStrategy,
    ) {
        roundtrip_chunked(ns, nd, method, strategy, pool, spawn_strategy, 0);
    }

    fn roundtrip_pool(ns: usize, nd: usize, method: Method, strategy: Strategy, pool: bool) {
        roundtrip_cfg(ns, nd, method, strategy, pool, SpawnStrategy::Sequential);
    }

    /// Cold-path roundtrip (the paper's configuration; seed behaviour).
    fn roundtrip(ns: usize, nd: usize, method: Method, strategy: Strategy) {
        roundtrip_pool(ns, nd, method, strategy, false);
    }

    #[test]
    fn grow_collective_blocking() {
        roundtrip(2, 5, Method::Collective, Strategy::Blocking);
    }

    #[test]
    fn shrink_collective_blocking() {
        roundtrip(6, 2, Method::Collective, Strategy::Blocking);
    }

    #[test]
    fn grow_rma_lock_blocking() {
        roundtrip(3, 8, Method::RmaLock, Strategy::Blocking);
    }

    #[test]
    fn shrink_rma_lockall_blocking() {
        roundtrip(8, 3, Method::RmaLockall, Strategy::Blocking);
    }

    #[test]
    fn grow_collective_nb() {
        roundtrip(2, 6, Method::Collective, Strategy::NonBlocking);
    }

    #[test]
    fn shrink_collective_nb() {
        roundtrip(6, 3, Method::Collective, Strategy::NonBlocking);
    }

    #[test]
    fn grow_collective_wd() {
        roundtrip(2, 6, Method::Collective, Strategy::WaitDrains);
    }

    #[test]
    fn shrink_collective_wd() {
        roundtrip(5, 2, Method::Collective, Strategy::WaitDrains);
    }

    #[test]
    fn grow_rma_lock_wd() {
        roundtrip(2, 7, Method::RmaLock, Strategy::WaitDrains);
    }

    #[test]
    fn shrink_rma_lock_wd() {
        roundtrip(7, 2, Method::RmaLock, Strategy::WaitDrains);
    }

    #[test]
    fn grow_rma_lockall_wd() {
        roundtrip(3, 9, Method::RmaLockall, Strategy::WaitDrains);
    }

    #[test]
    fn shrink_rma_lockall_wd() {
        roundtrip(9, 4, Method::RmaLockall, Strategy::WaitDrains);
    }

    #[test]
    fn grow_collective_threading() {
        roundtrip(2, 5, Method::Collective, Strategy::Threading);
    }

    #[test]
    fn shrink_collective_threading() {
        roundtrip(5, 2, Method::Collective, Strategy::Threading);
    }

    #[test]
    fn grow_rma_lock_threading() {
        roundtrip(2, 6, Method::RmaLock, Strategy::Threading);
    }

    #[test]
    fn shrink_rma_lockall_threading() {
        roundtrip(6, 2, Method::RmaLockall, Strategy::Threading);
    }

    // ---- window pool on: payloads must match the cold path exactly
    // for expand and shrink across all three methods (satellite: pool
    // on/off payload parity).

    #[test]
    fn pool_grow_collective_blocking_matches() {
        roundtrip_pool(2, 5, Method::Collective, Strategy::Blocking, true);
    }

    #[test]
    fn pool_shrink_collective_blocking_matches() {
        roundtrip_pool(6, 2, Method::Collective, Strategy::Blocking, true);
    }

    #[test]
    fn pool_grow_rma_lock_blocking_matches() {
        roundtrip_pool(3, 8, Method::RmaLock, Strategy::Blocking, true);
    }

    #[test]
    fn pool_shrink_rma_lock_wd_matches() {
        roundtrip_pool(7, 2, Method::RmaLock, Strategy::WaitDrains, true);
    }

    #[test]
    fn pool_grow_rma_lockall_wd_matches() {
        roundtrip_pool(3, 9, Method::RmaLockall, Strategy::WaitDrains, true);
    }

    #[test]
    fn pool_shrink_rma_lockall_blocking_matches() {
        roundtrip_pool(8, 3, Method::RmaLockall, Strategy::Blocking, true);
    }

    #[test]
    fn pool_threading_matches() {
        roundtrip_pool(2, 6, Method::RmaLock, Strategy::Threading, true);
        roundtrip_pool(6, 2, Method::RmaLockall, Strategy::Threading, true);
    }

    // ---- chunked pipelined registration (`rma_chunk_kib > 0`): the
    // payloads must stay the exact ND-way blocks for every RMA method
    // × strategy, grow and shrink, pool on and off — 1 KiB segments
    // (128 elements) force real segmentation of the 997-element blocks.

    /// 1-KiB chunks (128 elements) under the Sequential spawn — the
    /// shape the pipelined roundtrips exercise.
    fn roundtrip_c1(ns: usize, nd: usize, method: Method, strategy: Strategy, pool: bool) {
        roundtrip_chunked(ns, nd, method, strategy, pool, SpawnStrategy::Sequential, 1);
    }

    #[test]
    fn pipelined_grow_rma_blocking_roundtrips() {
        roundtrip_c1(2, 5, Method::RmaLock, Strategy::Blocking, false);
        roundtrip_c1(3, 8, Method::RmaLockall, Strategy::Blocking, false);
    }

    #[test]
    fn pipelined_shrink_rma_blocking_roundtrips() {
        roundtrip_c1(8, 3, Method::RmaLockall, Strategy::Blocking, false);
        let seq = SpawnStrategy::Sequential;
        roundtrip_chunked(6, 2, Method::RmaLock, Strategy::Blocking, true, seq, 2);
    }

    #[test]
    fn pipelined_wd_roundtrips() {
        roundtrip_c1(2, 7, Method::RmaLock, Strategy::WaitDrains, false);
        roundtrip_c1(9, 4, Method::RmaLockall, Strategy::WaitDrains, true);
    }

    #[test]
    fn pipelined_threading_roundtrips() {
        roundtrip_c1(2, 6, Method::RmaLock, Strategy::Threading, false);
        roundtrip_c1(6, 2, Method::RmaLockall, Strategy::Threading, true);
    }

    #[test]
    fn pipelined_teardown_off_roundtrips_identically() {
        // `rma_dereg: false` (the registration-only pipeline) must
        // still deliver the exact ND-way blocks — shrink and grow,
        // blocking and WD — through the same Mam dispatch.
        let seq = SpawnStrategy::Sequential;
        roundtrip_lifecycle(8, 3, Method::RmaLockall, Strategy::Blocking, false, seq, 1, false);
        roundtrip_lifecycle(3, 8, Method::RmaLock, Strategy::WaitDrains, false, seq, 1, false);
    }

    #[test]
    fn pipelined_composes_with_spawn_strategies() {
        let asy = SpawnStrategy::Async;
        roundtrip_chunked(3, 8, Method::RmaLockall, Strategy::Blocking, false, asy, 1);
        let par = SpawnStrategy::Parallel;
        roundtrip_chunked(3, 8, Method::RmaLock, Strategy::WaitDrains, true, par, 1);
    }

    // ---- notified completion (`--rma-sync notify`): drains observe
    // readiness through per-segment notification counters and the
    // confirmation barrier is never issued.  The payloads must stay
    // the exact ND-way blocks for grow and shrink, Blocking / WD /
    // Threading, pool on and off, chunked and unchunked.

    #[test]
    fn notify_blocking_roundtrips() {
        let n = RmaSync::Notify;
        roundtrip_sync(3, 8, Method::RmaLockall, Strategy::Blocking, false, 0, n, false);
        roundtrip_sync(6, 2, Method::RmaLock, Strategy::Blocking, true, 0, n, false);
        roundtrip_sync(8, 3, Method::RmaLockall, Strategy::Blocking, false, 1, n, false);
    }

    #[test]
    fn notify_wd_roundtrips() {
        let n = RmaSync::Notify;
        roundtrip_sync(2, 7, Method::RmaLock, Strategy::WaitDrains, false, 0, n, false);
        roundtrip_sync(9, 4, Method::RmaLockall, Strategy::WaitDrains, true, 1, n, false);
    }

    #[test]
    fn notify_threading_roundtrips() {
        let n = RmaSync::Notify;
        roundtrip_sync(2, 6, Method::RmaLock, Strategy::Threading, false, 0, n, false);
        roundtrip_sync(6, 2, Method::RmaLockall, Strategy::Threading, true, 1, n, false);
    }

    // ---- persistent-schedule cache (`--sched-cache on`): schedule-
    // driven posting must deliver the exact ND-way blocks under the
    // epoch protocol and composed with notified completion.

    #[test]
    fn sched_cache_roundtrips_all_strategies() {
        roundtrip_sync(2, 7, Method::RmaLock, Strategy::WaitDrains, false, 0, RmaSync::Epoch, true);
        roundtrip_sync(8, 3, Method::RmaLockall, Strategy::Blocking, false, 1, RmaSync::Epoch, true);
        roundtrip_sync(3, 8, Method::RmaLockall, Strategy::WaitDrains, true, 1, RmaSync::Notify, true);
        roundtrip_sync(6, 2, Method::RmaLock, Strategy::Threading, false, 0, RmaSync::Notify, true);
    }

    // ---- spawn strategies: payloads must be identical to the
    // Sequential (seed) path for every method × strategy grow; the
    // roundtrip asserts the exact expected block per rank.

    #[test]
    fn parallel_spawn_grow_payloads_match() {
        for (m, s) in [
            (Method::Collective, Strategy::Blocking),
            (Method::Collective, Strategy::WaitDrains),
            (Method::RmaLock, Strategy::WaitDrains),
            (Method::RmaLockall, Strategy::Blocking),
            (Method::RmaLockall, Strategy::Threading),
        ] {
            roundtrip_cfg(3, 8, m, s, false, SpawnStrategy::Parallel);
        }
    }

    #[test]
    fn async_spawn_grow_payloads_match() {
        for (m, s) in [
            (Method::Collective, Strategy::Blocking),
            (Method::Collective, Strategy::NonBlocking),
            (Method::RmaLock, Strategy::WaitDrains),
            (Method::RmaLockall, Strategy::WaitDrains),
            (Method::Collective, Strategy::Threading),
        ] {
            roundtrip_cfg(3, 8, m, s, false, SpawnStrategy::Async);
        }
    }

    #[test]
    fn async_spawn_with_pool_payloads_match() {
        roundtrip_cfg(2, 7, Method::RmaLockall, Strategy::WaitDrains, true, SpawnStrategy::Async);
        roundtrip_cfg(3, 6, Method::RmaLock, Strategy::Blocking, true, SpawnStrategy::Parallel);
    }

    #[test]
    fn spawn_strategies_ignore_shrinks() {
        // Shrinks never spawn: every strategy must behave identically
        // (comm_sub path), including payload placement.
        let par = SpawnStrategy::Parallel;
        roundtrip_cfg(7, 3, Method::RmaLockall, Strategy::WaitDrains, false, par);
        roundtrip_cfg(6, 2, Method::Collective, Strategy::Blocking, false, SpawnStrategy::Async);
    }

    /// `planner: Auto` roundtrip: every rank resolves the plan itself
    /// (sources in `reconfigure`, spawned drains in `drain_join`), so
    /// the collective sequences must match and every continuing rank
    /// must end with the exact ND-way block — regardless of the dummy
    /// fixed fields the configuration carries.
    fn roundtrip_auto(ns: usize, nd: usize) {
        let total = 997u64;
        let mut sim = MpiSim::new(Topology::new(2, 6), NetParams::test_simple());
        let checks = Arc::new(AtomicUsize::new(0));
        let checks2 = checks.clone();
        sim.launch(ns, move |p| {
            let r = p.rank(WORLD);
            let b = block_of(total, ns, r);
            let mut reg = Registry::new();
            reg.register(
                "A",
                DataKind::Constant,
                total,
                Payload::real((b.ini..b.end).map(|i| i as f64).collect()),
            );
            let cfg = ReconfigCfg {
                // Deliberately point the fixed fields at a background
                // RMA version: Auto must override them per resize.
                method: Method::RmaLockall,
                strategy: Strategy::WaitDrains,
                spawn_cost: 0.01,
                spawn_strategy: SpawnStrategy::Sequential,
                win_pool: WinPoolPolicy::off(),
                rma_chunk_kib: 0,
                rma_dereg: true,
                rma_sync: RmaSync::Epoch,
                sched_cache: false,
                planner: PlannerMode::Auto,
                recalib: false,
            };
            let decls = reg.decls();
            let mut mam = Mam::new(reg, cfg.clone());
            let checks3 = checks2.clone();
            let drain_body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
                Arc::new(move |dp: MpiProc, merged: CommId| {
                    let dmam = Mam::drain_join(&dp, merged, ns, nd, &decls, cfg.clone());
                    let dr = dp.rank(merged);
                    let nb = block_of(total, nd, dr);
                    let got = dmam.registry.entry(0).local.as_slice().unwrap().to_vec();
                    let want: Vec<f64> = (nb.ini..nb.end).map(|i| i as f64).collect();
                    assert_eq!(got, want, "spawned drain {dr} wrong block under Auto");
                    checks3.fetch_add(1, Ordering::SeqCst);
                });
            let mut status = mam.reconfigure(&p, WORLD, nd, drain_body);
            let mut iters = 0;
            while status == MamStatus::InProgress {
                p.compute(1e-3);
                status = mam.checkpoint(&p);
                iters += 1;
                assert!(iters < 100_000, "auto redistribution never completes");
            }
            let out = mam.finish(&p, WORLD);
            // The Mam handle keeps the Auto configuration for the next
            // resize — resolution is per-resize, not sticky.
            assert_eq!(mam.cfg.planner, PlannerMode::Auto);
            match out.app_comm {
                Some(c) => {
                    let nr = p.rank(c);
                    let nb = block_of(total, nd, nr);
                    let got = mam.registry.entry(0).local.as_slice().unwrap().to_vec();
                    let want: Vec<f64> = (nb.ini..nb.end).map(|i| i as f64).collect();
                    assert_eq!(got, want, "rank {nr} wrong block under Auto");
                    checks2.fetch_add(1, Ordering::SeqCst);
                }
                None => assert!(r >= nd, "rank {r} wrongly retired"),
            }
        });
        sim.run().unwrap();
        assert_eq!(checks.load(Ordering::SeqCst), nd, "every drain must verify its block");
    }

    #[test]
    fn auto_planner_roundtrips_grow() {
        roundtrip_auto(3, 8);
    }

    #[test]
    fn auto_planner_roundtrips_shrink() {
        roundtrip_auto(8, 3);
    }

    #[test]
    fn live_params_steer_auto_resolution_only_when_recalib_is_on() {
        let mut sim = MpiSim::new(Topology::new(2, 4), NetParams::test_simple());
        sim.launch(1, |p| {
            let mut reg = Registry::new();
            reg.register("A", DataKind::Constant, 100_000, Payload::virt(100_000));
            let cfg = ReconfigCfg {
                spawn_cost: 0.25,
                planner: PlannerMode::Auto,
                ..ReconfigCfg::default()
            };
            let mut mam = Mam::new(reg, cfg);
            let static_choice = mam.active_cfg(&p, 2, 8);
            // Analytically, a grow's cheapest spawn block is Async's
            // bare launch handshake (0.05 s < the 0.25 s sequential
            // constant under `test_simple`).
            assert_eq!(static_choice.spawn_strategy, SpawnStrategy::Async);
            // An absurd live belief — launches cost 10 s, so no
            // decomposed strategy can beat the sequential constant.
            // It must be ignored while recalib is off...
            mam.set_live_params(NetParams::test_simple().with(|n| n.spawn_launch = 10.0));
            let off = mam.active_cfg(&p, 2, 8);
            assert_eq!(off.spawn_strategy, static_choice.spawn_strategy);
            assert_eq!(off.method, static_choice.method);
            // ...and consulted once it is on.
            mam.cfg.recalib = true;
            let on = mam.active_cfg(&p, 2, 8);
            assert_eq!(on.spawn_strategy, SpawnStrategy::Sequential);
        });
        sim.run().unwrap();
    }

    #[test]
    fn async_spawn_overlaps_spawn_with_registration() {
        // Blocking RMA grow with a large source exposure: under Async
        // the sources' window registration runs while the targets are
        // still starting, so the whole reconfiguration finishes
        // strictly earlier than under Sequential (0.25 s constant) and
        // no later than Parallel.
        let total = 200_000_000u64; // ~0.2 s of registration per source
        let (ns, nd) = (2usize, 4usize);
        let time_with = |spawn_strategy: SpawnStrategy| -> f64 {
            let mut sim = MpiSim::new(Topology::new(2, 4), NetParams::test_simple());
            let world = sim.world();
            sim.launch(ns, move |p| {
                let r = p.rank(WORLD);
                let b = block_of(total, ns, r);
                let mut reg = Registry::new();
                reg.register("A", DataKind::Constant, total, Payload::virt(b.len()));
                let cfg = ReconfigCfg {
                    method: Method::RmaLockall,
                    strategy: Strategy::Blocking,
                    spawn_cost: 0.25,
                    spawn_strategy,
                    win_pool: WinPoolPolicy::off(),
                    rma_chunk_kib: 0,
                    rma_dereg: true,
                    rma_sync: RmaSync::Epoch,
                    sched_cache: false,
                    planner: PlannerMode::Fixed,
                    recalib: false,
                };
                let decls = reg.decls();
                let mut mam = Mam::new(reg, cfg.clone());
                let cfg2 = cfg.clone();
                let body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
                    Arc::new(move |dp: MpiProc, merged: CommId| {
                        let _ = Mam::drain_join(&dp, merged, ns, nd, &decls, cfg2.clone());
                    });
                let st = mam.reconfigure(&p, WORLD, nd, body);
                assert_eq!(st, MamStatus::Completed);
                let _ = mam.finish(&p, WORLD);
            });
            sim.run().unwrap();
            let w = world.lock().unwrap();
            w.metrics.span("mam.reconf_start", "mam.reconf_end").unwrap()
        };
        let seq = time_with(SpawnStrategy::Sequential);
        let par = time_with(SpawnStrategy::Parallel);
        let asy = time_with(SpawnStrategy::Async);
        assert!(par < seq, "parallel {par} !< sequential {seq}");
        assert!(asy < seq, "async {asy} !< sequential {seq}");
        assert!(asy <= par + 1e-12, "async {asy} should not lose to parallel {par}");
    }

    #[test]
    fn warm_reconfiguration_charges_zero_registration() {
        // Shrink 4 -> 2, then grow back 2 -> 4, pool on.  Resize 1 is
        // cold; register-on-receive then pins every installed block, so
        // resize 2's window acquires are warm on every rank (survivors
        // re-expose pinned blocks, spawned drains expose NULL): zero
        // cold acquires and zero registration seconds are added to the
        // simulated timeline after resize 1.
        let total = 40_000u64;
        let (ns, nd) = (4usize, 2usize);
        let mut sim = MpiSim::new(Topology::new(1, 8), NetParams::test_simple());
        let world = sim.world();
        sim.launch(ns, move |p| {
            let r = p.rank(WORLD);
            let b = block_of(total, ns, r);
            let mut reg = Registry::new();
            reg.register("A", DataKind::Constant, total, Payload::virt(b.len()));
            let cfg = ReconfigCfg {
                method: Method::RmaLockall,
                strategy: Strategy::Blocking,
                spawn_cost: 0.0,
                spawn_strategy: SpawnStrategy::Sequential,
                win_pool: WinPoolPolicy::on(),
                rma_chunk_kib: 0,
                rma_dereg: true,
                rma_sync: RmaSync::Epoch,
                sched_cache: false,
                planner: PlannerMode::Fixed,
                recalib: false,
            };
            let decls = reg.decls();
            let mut mam = Mam::new(reg, cfg.clone());
            let nobody: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> = Arc::new(|_, _| {});
            // Resize 1: 4 -> 2 (cold: first exposure of "A" anywhere).
            let st = mam.reconfigure(&p, WORLD, nd, nobody);
            assert_eq!(st, MamStatus::Completed);
            let out = mam.finish(&p, WORLD);
            let Some(c1) = out.app_comm else {
                return; // retired by the shrink
            };
            let s1 = p.win_pool_stats();
            assert!(s1.cold_acquires > 0, "first resize must be cold");
            // Resize 2: grow back to 4, re-exposing the pinned blocks.
            let cfg2 = cfg.clone();
            let drain_body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
                Arc::new(move |dp: MpiProc, merged: CommId| {
                    let _ = Mam::drain_join(&dp, merged, nd, ns, &decls, cfg2.clone());
                });
            let st = mam.reconfigure(&p, c1, ns, drain_body);
            assert_eq!(st, MamStatus::Completed);
            let _ = mam.finish(&p, c1);
            let s2 = p.win_pool_stats();
            assert_eq!(
                s2.cold_acquires, s1.cold_acquires,
                "warm resize must add zero cold acquires: {s2:?}"
            );
            assert!(
                (s2.cold_reg_time - s1.cold_reg_time).abs() < 1e-15,
                "warm resize charged registration on the collective path: {s2:?}"
            );
            assert!(s2.warm_acquires > s1.warm_acquires, "{s2:?}");
            assert!(s2.warm_reg_saved > 0.0, "{s2:?}");
        });
        sim.run().unwrap();
        let w = world.lock().unwrap();
        let s = w.win_pool_stats();
        assert!(s.warm_acquires > 0 && s.pre_pins > 0, "{s:?}");
    }

    #[test]
    fn schedule_cache_replays_warm_across_oscillations() {
        // 4 -> 2 -> 4 -> 2 with the schedule cache on.  The third
        // resize re-runs the first one's (4 -> 2) schedule: every rank
        // slot finds a warm pin — including ranks 2 and 3, whose
        // original processes were retired at resize 1 and respawned at
        // resize 2 (schedules are keyed by rank slot, so they outlive
        // process churn) — and charges only the validation handshake.
        // No cold build enters the timeline after the grow.
        let total = 40_000u64;
        let (ns, nd) = (4usize, 2usize);
        let mut sim = MpiSim::new(Topology::new(1, 8), NetParams::test_simple());
        let world = sim.world();
        sim.launch(ns, move |p| {
            let r = p.rank(WORLD);
            let b = block_of(total, ns, r);
            let mut reg = Registry::new();
            reg.register("A", DataKind::Constant, total, Payload::virt(b.len()));
            let cfg = ReconfigCfg::version(Method::RmaLockall, Strategy::Blocking)
                .with_spawn(SpawnStrategy::Sequential, 0.0)
                .with_sched_cache(true);
            let decls = reg.decls();
            let mut mam = Mam::new(reg, cfg.clone());
            let nobody: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> = Arc::new(|_, _| {});
            // Resize 1: 4 -> 2 — the (4, 2) schedule builds cold on
            // every rank.
            let st = mam.reconfigure(&p, WORLD, nd, nobody);
            assert_eq!(st, MamStatus::Completed);
            let out = mam.finish(&p, WORLD);
            let Some(c1) = out.app_comm else {
                return; // ranks 2 and 3 retire here
            };
            let s1 = p.sched_stats();
            assert_eq!(s1.cold_builds, ns as u64, "resize 1 builds cold everywhere: {s1:?}");
            assert_eq!(s1.warm_replays, 0, "{s1:?}");
            assert!(s1.build_time > 0.0, "{s1:?}");
            // Resize 2: grow back to 4 — a different shape (2, 4),
            // cold again.  The spawned drains stay around to take part
            // in resize 3 as retiring sources.
            let cfg2 = cfg.clone();
            let drain_body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
                Arc::new(move |dp: MpiProc, merged: CommId| {
                    let mut dmam = Mam::drain_join(&dp, merged, nd, ns, &decls, cfg2.clone());
                    let nobody2: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
                        Arc::new(|_, _| {});
                    let st = dmam.reconfigure(&dp, merged, nd, nobody2);
                    assert_eq!(st, MamStatus::Completed);
                    let out = dmam.finish(&dp, merged);
                    assert!(out.app_comm.is_none(), "spawned ranks retire at resize 3");
                });
            let st = mam.reconfigure(&p, c1, ns, drain_body);
            assert_eq!(st, MamStatus::Completed);
            let out = mam.finish(&p, c1);
            let c2 = out.app_comm.expect("grow keeps every rank");
            let s2 = p.sched_stats();
            assert_eq!(s2.cold_builds, 2 * ns as u64, "resize 2 is a new shape: {s2:?}");
            assert_eq!(s2.warm_replays, 0, "{s2:?}");
            // Resize 3: 4 -> 2 again — pure replay of resize 1's
            // schedule on all four rank slots.
            let nobody3: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> = Arc::new(|_, _| {});
            let st = mam.reconfigure(&p, c2, nd, nobody3);
            assert_eq!(st, MamStatus::Completed);
            let _ = mam.finish(&p, c2);
            let s3 = p.sched_stats();
            assert_eq!(s3.cold_builds, s2.cold_builds, "replay must add no cold builds: {s3:?}");
            assert_eq!(s3.warm_replays, ns as u64, "{s3:?}");
            assert!(s3.validate_time > 0.0, "{s3:?}");
            assert!(
                s3.validate_time < s3.build_time,
                "replays must be cheaper than builds: {s3:?}"
            );
            // The survivors' Rust-side memo saw (4,2) miss, (2,4) miss,
            // then (4,2) hit — the observable the cross-resize
            // investment credit is validated against.
            assert_eq!(mam.sched_cache_counters(), (1, 2));
        });
        sim.run().unwrap();
        let w = world.lock().unwrap();
        let s = w.sched_stats();
        assert_eq!(s.cold_builds, 8, "{s:?}");
        assert_eq!(s.warm_replays, 4, "{s:?}");
    }

    #[test]
    fn all_wave_spawn_failure_recovers_within_the_retry_budget() {
        // Acceptance bar: `spawn=first2` with the default retries=2
        // fails the grow's first two launch attempts whole-wave; the
        // third succeeds and the resize completes with exact payload
        // identity on every drain — the faults cost time, never data.
        use crate::simmpi::{FaultPlan, FaultSpec};
        let total = 997u64;
        let (ns, nd) = (2usize, 5usize);
        let mut sim = MpiSim::new(Topology::new(2, 6), NetParams::test_simple());
        sim.set_faults(FaultPlan::new(FaultSpec::parse("spawn=first2,mode=wave").unwrap()));
        let world = sim.world();
        let checks = Arc::new(AtomicUsize::new(0));
        let checks2 = checks.clone();
        sim.launch(ns, move |p| {
            let r = p.rank(WORLD);
            let b = block_of(total, ns, r);
            let mut reg = Registry::new();
            reg.register(
                "A",
                DataKind::Constant,
                total,
                Payload::real((b.ini..b.end).map(|i| i as f64).collect()),
            );
            let cfg = ReconfigCfg::version(Method::RmaLockall, Strategy::Blocking)
                .with_spawn(SpawnStrategy::Sequential, 0.01);
            let decls = reg.decls();
            let mut mam = Mam::new(reg, cfg.clone());
            mam.set_fault_ctx(0, 0);
            let checks3 = checks2.clone();
            let drain_body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
                Arc::new(move |dp: MpiProc, merged: CommId| {
                    let dmam = Mam::drain_join(&dp, merged, ns, nd, &decls, cfg.clone());
                    let dr = dp.rank(merged);
                    let nb = block_of(total, nd, dr);
                    let got = dmam.registry.entry(0).local.as_slice().unwrap().to_vec();
                    let want: Vec<f64> = (nb.ini..nb.end).map(|i| i as f64).collect();
                    assert_eq!(got, want, "spawned drain {dr} wrong block");
                    checks3.fetch_add(1, Ordering::SeqCst);
                });
            let t0 = p.now();
            let status = mam.reconfigure(&p, WORLD, nd, drain_body);
            assert_eq!(status, MamStatus::Completed);
            assert!(
                p.now() - t0 > 0.01,
                "two failed attempts must cost detection + backoff time"
            );
            let out = mam.finish(&p, WORLD);
            let c = out.app_comm.expect("grow keeps every rank");
            let nr = p.rank(c);
            let nb = block_of(total, nd, nr);
            let got = mam.registry.entry(0).local.as_slice().unwrap().to_vec();
            let want: Vec<f64> = (nb.ini..nb.end).map(|i| i as f64).collect();
            assert_eq!(got, want, "rank {nr} wrong block after recovery");
            checks2.fetch_add(1, Ordering::SeqCst);
        });
        sim.run().unwrap();
        assert_eq!(checks.load(Ordering::SeqCst), nd, "every drain must verify its block");
        let w = world.lock().unwrap();
        assert_eq!(w.metrics.counter("faults.spawn_retries"), Some(2.0));
        assert_eq!(w.metrics.counter("faults.spawn_failed"), Some(2.0 * (nd - ns) as f64));
        assert_eq!(w.metrics.counter("faults.rollbacks"), None, "recovered, not rolled back");
    }

    #[test]
    fn abort_poisons_warm_schedules_and_the_next_occurrence_rebuilds_cold() {
        // 4 -> 2 -> 4 -> 2, then an *aborted* 2 -> 4, then 2 -> 4 again.
        // The abort must unwind cleanly (status Aborted, nothing
        // inflight, app still on its old communicator) and poison the
        // warm (2, 4) schedule state everywhere: the retried grow
        // rebuilds cold instead of replaying a pin that spans the
        // aborted dispatch.  `spawn=first3` with retries=2 exhausts
        // dispatch 0 of the grow and heals dispatch 1 (the firstK
        // count is cumulative across dispatches).
        use crate::simmpi::{FaultPlan, FaultSpec};
        let total = 40_000u64;
        let (ns, nd) = (4usize, 2usize);
        let mut sim = MpiSim::new(Topology::new(1, 8), NetParams::test_simple());
        sim.set_faults(FaultPlan::new(FaultSpec::parse("spawn=first3,mode=wave").unwrap()));
        let world = sim.world();
        sim.launch(ns, move |p| {
            let r = p.rank(WORLD);
            let b = block_of(total, ns, r);
            let mut reg = Registry::new();
            reg.register("A", DataKind::Constant, total, Payload::virt(b.len()));
            let cfg = ReconfigCfg::version(Method::RmaLockall, Strategy::Blocking)
                .with_spawn(SpawnStrategy::Sequential, 0.0)
                .with_sched_cache(true);
            let decls = reg.decls();
            let mut mam = Mam::new(reg, cfg.clone());
            let nobody: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> = Arc::new(|_, _| {});
            // Resize 1: 4 -> 2 — (4, 2) builds cold (shrink: no spawn,
            // no fault surface).
            mam.set_fault_ctx(0, 0);
            let st = mam.reconfigure(&p, WORLD, nd, nobody);
            assert_eq!(st, MamStatus::Completed);
            let out = mam.finish(&p, WORLD);
            let Some(c1) = out.app_comm else {
                return; // ranks 2 and 3 retire here
            };
            // Resize 2: grow back to 4.  Dispatch 1 keeps the firstK
            // counter past the failure window — this grow is healthy;
            // its drains stick around to retire in resize 3.
            mam.set_fault_ctx(1, 1);
            let cfg2 = cfg.clone();
            let drain_body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
                Arc::new(move |dp: MpiProc, merged: CommId| {
                    let mut dmam = Mam::drain_join(&dp, merged, nd, ns, &decls, cfg2.clone());
                    let nobody2: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
                        Arc::new(|_, _| {});
                    dmam.set_fault_ctx(2, 0);
                    let st = dmam.reconfigure(&dp, merged, nd, nobody2);
                    assert_eq!(st, MamStatus::Completed);
                    let out = dmam.finish(&dp, merged);
                    assert!(out.app_comm.is_none(), "spawned ranks retire at resize 3");
                });
            let st = mam.reconfigure(&p, c1, ns, drain_body);
            assert_eq!(st, MamStatus::Completed);
            let out = mam.finish(&p, c1);
            let c2 = out.app_comm.expect("grow keeps every rank");
            // Resize 3: 4 -> 2 — (4, 2) replays warm, proving warmth
            // was established before the abort.
            mam.set_fault_ctx(2, 0);
            let nobody3: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> = Arc::new(|_, _| {});
            let st = mam.reconfigure(&p, c2, nd, nobody3);
            assert_eq!(st, MamStatus::Completed);
            let out = mam.finish(&p, c2);
            let c3 = out.app_comm.expect("ranks 0 and 1 survive the shrink");
            let s3 = p.sched_stats();
            assert_eq!(s3.warm_replays, ns as u64, "resize 3 replays warm: {s3:?}");
            let cold_before_abort = s3.cold_builds;
            let memo_before_abort = mam.sched_cache_counters();
            // Resize 4: 2 -> 4 again, dispatch 0 — all three attempts
            // fail, retries exhaust, the resize aborts and rolls back.
            mam.set_fault_ctx(3, 0);
            let nobody4: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> = Arc::new(|_, _| {});
            let st = mam.reconfigure(&p, c3, ns, nobody4);
            assert_eq!(st, MamStatus::Aborted);
            assert!(!mam.in_progress(), "an aborted resize must leave nothing inflight");
            assert_eq!(p.size(c3), nd, "the app resumes on its old communicator");
            assert_eq!(
                mam.sched_cache_counters().0,
                memo_before_abort.0,
                "abort must not touch the memo counters"
            );
            // Resize 5: the re-dispatched grow succeeds — and must
            // rebuild the poisoned (2, 4) schedule cold, not replay it.
            mam.set_fault_ctx(3, 1);
            let cfg5 = cfg.clone();
            let decls5 = mam.registry.decls();
            let drain_body5: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
                Arc::new(move |dp: MpiProc, merged: CommId| {
                    let _ = Mam::drain_join(&dp, merged, nd, ns, &decls5, cfg5.clone());
                });
            let st = mam.reconfigure(&p, c3, ns, drain_body5);
            assert_eq!(st, MamStatus::Completed);
            let _ = mam.finish(&p, c3);
            let s5 = p.sched_stats();
            assert_eq!(
                s5.cold_builds,
                cold_before_abort + ns as u64,
                "poisoned schedules must rebuild cold on sources and drains: {s5:?}"
            );
            assert_eq!(s5.warm_replays, ns as u64, "no new warm replays: {s5:?}");
            // The survivors' memo saw the poisoned (2, 4) miss again.
            assert_eq!(mam.sched_cache_counters().1, memo_before_abort.1 + 1);
        });
        sim.run().unwrap();
        let w = world.lock().unwrap();
        assert_eq!(w.metrics.counter("faults.rollbacks"), Some(1.0));
        assert_eq!(w.metrics.counter("faults.spawn_retries"), Some(3.0));
        assert!(w.win_pool_stats().poisoned == 0, "pool off: nothing to poison");
    }

    #[test]
    #[should_panic(expected = "NB is undefined for RMA")]
    fn rma_nb_panics() {
        let mut sim = MpiSim::new(Topology::new(1, 4), NetParams::test_simple());
        sim.launch(2, |p| {
            let mut reg = Registry::new();
            reg.register("A", DataKind::Constant, 10, Payload::virt(5));
            let mut mam = Mam::new(
                reg,
                ReconfigCfg {
                    method: Method::RmaLock,
                    strategy: Strategy::NonBlocking,
                    spawn_cost: 0.0,
                    spawn_strategy: SpawnStrategy::Sequential,
                    win_pool: WinPoolPolicy::off(),
                    rma_chunk_kib: 0,
                    rma_dereg: true,
                    rma_sync: RmaSync::Epoch,
                    sched_cache: false,
                    planner: PlannerMode::Fixed,
                    recalib: false,
                },
            );
            let body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> = Arc::new(|_, _| {});
            mam.reconfigure(&p, WORLD, 4, body);
        });
        let err = sim.run();
        // surface the panic as the test's panic
        if let Err(e) = err {
            panic!("{e}");
        }
    }

    #[test]
    fn variable_data_moves_at_finish_with_fresh_values() {
        // A Variable entry is mutated while the background (WD)
        // redistribution of the Constant entry is in flight; the drains
        // must receive the *final* values (§III: variable data is
        // redistributed while the application is blocked).
        let total = 24u64;
        let (ns, nd) = (4usize, 2usize);
        let mut sim = MpiSim::new(Topology::new(1, 4), NetParams::test_simple());
        sim.launch(ns, move |p| {
            let r = p.rank(WORLD);
            let cb = block_of(100_000, ns, r);
            let vb = block_of(total, ns, r);
            let mut reg = Registry::new();
            reg.register("A", DataKind::Constant, 100_000, Payload::virt(cb.len()));
            reg.register(
                "x",
                DataKind::Variable,
                total,
                Payload::real((vb.ini..vb.end).map(|i| i as f64).collect()),
            );
            let mut mam = Mam::new(
                reg,
                ReconfigCfg {
                    method: Method::Collective,
                    strategy: Strategy::WaitDrains,
                    spawn_cost: 0.0,
                    spawn_strategy: SpawnStrategy::Sequential,
                    win_pool: WinPoolPolicy::off(),
                    rma_chunk_kib: 0,
                    rma_dereg: true,
                    rma_sync: RmaSync::Epoch,
                    sched_cache: false,
                    planner: PlannerMode::Fixed,
                    recalib: false,
                },
            );
            let body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> = Arc::new(|_, _| {});
            let mut status = mam.reconfigure(&p, WORLD, nd, body);
            while status == MamStatus::InProgress {
                // "The application" updates x each iteration.
                let cur = mam.registry.by_name("x").unwrap().local.clone();
                let bumped: Vec<f64> =
                    cur.as_slice().unwrap().iter().map(|v| v + 1000.0).collect();
                mam.registry.entry_mut(1).local = Payload::real(bumped);
                p.compute(1e-3);
                status = mam.checkpoint(&p);
            }
            // Snapshot the final local values right before finish.
            let bumps = mam.registry.by_name("x").unwrap().local.as_slice().unwrap()[0]
                - vb.ini as f64;
            let out = mam.finish(&p, WORLD);
            if let Some(c) = out.app_comm {
                let nr = p.rank(c);
                let nb = block_of(total, nd, nr);
                let got = mam.registry.by_name("x").unwrap().local.as_slice().unwrap().to_vec();
                // Every element must carry at least one bump (sources all
                // iterated ≥1 time before finish) and the right base.
                assert_eq!(got.len() as u64, nb.len());
                for (k, v) in got.iter().enumerate() {
                    let base = (nb.ini + k as u64) as f64;
                    let bump = v - base;
                    assert!(
                        bump >= 1000.0 && (bump % 1000.0).abs() < 1e-9,
                        "rank {nr} elem {k}: value {v} (base {base}) missed updates"
                    );
                }
                let _ = bumps;
            }
        });
        sim.run().unwrap();
    }

    #[test]
    fn wd_sources_iterate_during_redistribution() {
        // A shrink with WD: source-only ranks must complete several app
        // iterations while the (large, virtual) redistribution runs.
        let total = 50_000_000u64; // big enough to take a while
        let (ns, nd) = (6usize, 2usize);
        let mut sim = MpiSim::new(Topology::new(2, 4), NetParams::test_simple());
        let max_iters = Arc::new(AtomicUsize::new(0));
        let mi = max_iters.clone();
        sim.launch(ns, move |p| {
            let r = p.rank(WORLD);
            let b = block_of(total, ns, r);
            let mut reg = Registry::new();
            reg.register("A", DataKind::Constant, total, Payload::virt(b.len()));
            let mut mam = Mam::new(
                reg,
                ReconfigCfg {
                    method: Method::RmaLockall,
                    strategy: Strategy::WaitDrains,
                    spawn_cost: 0.0,
                    spawn_strategy: SpawnStrategy::Sequential,
                    win_pool: WinPoolPolicy::off(),
                    rma_chunk_kib: 0,
                    rma_dereg: true,
                    rma_sync: RmaSync::Epoch,
                    sched_cache: false,
                    planner: PlannerMode::Fixed,
                    recalib: false,
                },
            );
            let body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> = Arc::new(|_, _| {});
            let mut status = mam.reconfigure(&p, WORLD, nd, body);
            let mut iters = 0usize;
            while status == MamStatus::InProgress {
                p.compute(1e-3);
                iters += 1;
                status = mam.checkpoint(&p);
                assert!(iters < 1_000_000);
            }
            mi.fetch_max(iters, Ordering::SeqCst);
            let _ = mam.finish(&p, WORLD);
        });
        sim.run().unwrap();
        assert!(
            max_iters.load(Ordering::SeqCst) >= 2,
            "no overlap happened: {} iters",
            max_iters.load(Ordering::SeqCst)
        );
    }
}
