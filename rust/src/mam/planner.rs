//! Cost-model-driven reconfiguration planning (§VI, and the related
//! dynamic-workload RMS literature).
//!
//! The paper's conclusion is that one-sided redistribution is
//! *conditionally* best: window registration can erase its advantage,
//! so the right `(method × strategy × spawn strategy × window pool)`
//! depends on the resize direction, the data volume and whether the
//! windows are already warm.  This module makes that choice
//! automatically:
//!
//! * every valid candidate version is priced with the closed-form
//!   prediction API of [`crate::netmodel::costmodel`]
//!   ([`predict_reconfig`]), using the same calibrated constants the
//!   simulator charges;
//! * because closed-form contention models have irreducible error on
//!   near-ties (the paper's own Fig. 3 band is 0.73–0.99×), the
//!   *blocking* candidates — the ones that can actually shorten the
//!   reconfiguration span — are optionally refined with **DES
//!   micro-probes**: an isolated simulation of just the
//!   reconfiguration, which is exact by construction (the DES is
//!   deterministic and the probe replays the identical collective
//!   sequence over the identical topology);
//! * the argmin is returned as a [`ReconfigPlan`] that the harnesses
//!   (`proteo::run_once`, `experiments::scenario`) apply per resize.
//!
//! Two objectives are supported.  [`Objective::ReconfTime`] minimizes
//! the reconfiguration span itself and therefore always selects a
//! blocking candidate (background strategies cannot shorten the span —
//! they pay iteration-quantized completion detection plus the variable
//! tail; they pay off through *overlap*).  [`Objective::Effective`]
//! minimizes the Eq. (2)-style effective cost `span − overlap credit`
//! and may select a background strategy.
//!
//! Plan resolution is a **harness-level** operation: every rank (and
//! every spawned drain) must execute the same plan, so the plan is
//! computed from rank-independent inputs (declared sizes, calibrated
//! parameters, the resize pair, pool warmth known from the resize
//! history) before the collective sequence starts.  `Mam` itself
//! resolves `ReconfigCfg::planner == Auto` with the analytic-only
//! variant ([`resolve_internal`]), which depends on nothing but those
//! shared inputs and is therefore consistent across sources and
//! drains.

use std::sync::{Arc, Mutex};

use crate::netmodel::{
    expected_spawn_retry_tail, predict_reconfig, CostPrediction, NetParams, ReconfigCase,
    RedistShape, Topology,
};
use crate::simcluster::faults::FaultSpec;
use crate::simcluster::ActivityId;
use crate::simmpi::{
    CommId, MpiProc, MpiSim, MpiWorld, Payload, RmaSync, WorldSnapshot, ELEM_BYTES, WORLD,
};

use super::blockdist::block_of;
use super::reconfig::{Mam, MamStatus, ReconfigCfg};
use super::registry::{DataDecl, DataKind, Registry};
use super::winpool::{self, WinPoolPolicy};
use super::{is_valid_version, version_label, Method, SpawnStrategy, Strategy};

/// Whether a reconfiguration uses the fixed configured version or the
/// planner's per-resize choice (`--planner auto|fixed`, `"planner"` in
/// JSON configs, [`ReconfigCfg::planner`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PlannerMode {
    /// Use the configured method/strategy/spawn/pool fields verbatim
    /// (seed behaviour; the default).
    #[default]
    Fixed,
    /// Let the planner override the version fields per resize.
    Auto,
}

impl PlannerMode {
    pub fn label(self) -> &'static str {
        match self {
            PlannerMode::Fixed => "fixed",
            PlannerMode::Auto => "auto",
        }
    }

    pub fn parse(s: &str) -> Option<PlannerMode> {
        match s.to_ascii_lowercase().as_str() {
            "fixed" => Some(PlannerMode::Fixed),
            "auto" => Some(PlannerMode::Auto),
            _ => None,
        }
    }
}

/// What the planner minimizes.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Objective {
    /// The reconfiguration span (default): always a blocking pick.
    #[default]
    ReconfTime,
    /// Span minus the overlapped-iteration credit (Eq. (2) analog):
    /// may pick a background strategy.
    Effective,
}

/// Chunk sizes (KiB) the planner prices for the RMA methods — 0 is
/// the unchunked seed path; the others trade per-segment setup
/// overhead against registration/wire overlap.
pub const CHUNK_CANDIDATES_KIB: [u64; 4] = [0, 256, 1024, 4096];

/// One candidate version of the planner's search space.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Candidate {
    pub method: Method,
    pub strategy: Strategy,
    pub spawn_strategy: SpawnStrategy,
    pub win_pool: WinPoolPolicy,
    /// Chunked pipelined registration segment size in KiB (0 = off;
    /// always 0 for the COL method).
    pub rma_chunk_kib: u64,
}

impl Candidate {
    /// Figure-style label, e.g. `RMA-Lockall+pool+c1024k+async`.
    pub fn label(&self) -> String {
        let mut l = version_label(self.method, self.strategy);
        if self.win_pool.enabled {
            l.push_str("+pool");
        }
        if self.rma_chunk_kib > 0 {
            l.push_str(&format!("+c{}k", self.rma_chunk_kib));
        }
        if self.spawn_strategy != SpawnStrategy::Sequential {
            l.push('+');
            l.push_str(self.spawn_strategy.label());
        }
        l
    }

    /// The probe-dedup identity: chunk variants of one
    /// `(method × strategy × spawn × pool)` tuple all share it, so
    /// adding chunk sizes to the search space cannot quadratically
    /// inflate the number of DES micro-probes.
    fn tuple_key(&self) -> (u8, u8, u8, bool) {
        let m = match self.method {
            Method::Collective => 0u8,
            Method::RmaLock => 1,
            Method::RmaLockall => 2,
        };
        let s = match self.strategy {
            Strategy::Blocking => 0u8,
            Strategy::NonBlocking => 1,
            Strategy::WaitDrains => 2,
            Strategy::Threading => 3,
        };
        let ss = match self.spawn_strategy {
            SpawnStrategy::Sequential => 0u8,
            SpawnStrategy::Parallel => 1,
            SpawnStrategy::Async => 2,
        };
        (m, s, ss, self.win_pool.enabled)
    }

    /// Materialize a (resolved, `planner: Fixed`) reconfiguration
    /// configuration for this candidate.
    pub fn cfg(&self, spawn_cost: f64) -> ReconfigCfg {
        ReconfigCfg::version(self.method, self.strategy)
            .with_spawn(self.spawn_strategy, spawn_cost)
            .with_pool(self.win_pool)
            .with_chunk(self.rma_chunk_kib)
    }
}

/// A candidate with its predicted (and optionally probed) cost.
#[derive(Clone, Debug)]
pub struct CandidateCost {
    pub candidate: Candidate,
    pub predicted: CostPrediction,
    /// Exact reconfiguration span from the DES micro-probe, when one
    /// ran (blocking candidates under `probe: true`).
    pub probed_reconf: Option<f64>,
    /// Cross-resize investment credit: what this candidate's warmth
    /// investments (pool register-on-receive pins, cold schedule
    /// builds) are predicted to save over the harness's announced
    /// future resizes ([`PlannerInputs::future_resizes`]).  Subtracted
    /// in the argmin; 0 when the future is unknown.
    pub future_credit: f64,
}

impl CandidateCost {
    /// Best available span estimate: probed when present.
    pub fn reconf_time(&self) -> f64 {
        self.probed_reconf.unwrap_or(self.predicted.reconf_time)
    }

    /// Best available effective cost (span minus overlap credit).
    pub fn effective(&self) -> f64 {
        self.reconf_time() - self.predicted.overlap_credit
    }
}

/// The planner's answer for one resize.
#[derive(Clone, Debug)]
pub struct ReconfigPlan {
    pub ns: usize,
    pub nd: usize,
    /// Pool warmth the plan assumed.
    pub warm: bool,
    pub choice: Candidate,
    /// Decomposed prediction of the chosen candidate.
    pub predicted: CostPrediction,
    /// Planner's span estimate for the choice (probed when available).
    pub predicted_reconf: f64,
    /// Every candidate considered, in enumeration order (stable, so
    /// reports and ties are deterministic).
    pub candidates: Vec<CandidateCost>,
}

impl ReconfigPlan {
    pub fn label(&self) -> String {
        self.choice.label()
    }
}

/// Rank-independent planner inputs for one resize.
#[derive(Clone, Debug)]
pub struct PlannerInputs {
    /// Registered structures (names, kinds, global sizes) — identical
    /// on every rank by MaM's registry contract.
    pub decls: Vec<DataDecl>,
    pub ns: usize,
    pub nd: usize,
    pub cores_per_node: usize,
    pub net: NetParams,
    /// Sequential-spawn constant (`ReconfigCfg::spawn_cost`).
    pub spawn_cost: f64,
    /// A previous resize with the pool enabled pinned every source's
    /// current block (register-on-receive, §VI).
    pub warm: bool,
    /// Application iteration time on NS / ND ranks (0 = unknown;
    /// disables the overlap terms).
    pub t_iter_src: f64,
    pub t_iter_dst: f64,
    pub objective: Objective,
    /// Refine blocking candidates with exact DES micro-probes.
    pub probe: bool,
    /// Extra chunk sizes (KiB) to price for the RMA methods on top of
    /// [`CHUNK_CANDIDATES_KIB`] — the online recalibrator injects its
    /// measured-throughput per-structure choices here
    /// ([`crate::mam::Recalibrator::chunk_candidates`]).  Duplicates
    /// of the static grid are ignored; empty = the static grid alone
    /// (bit-identical to the pre-recalibration enumeration).
    pub extra_chunks_kib: Vec<u64>,
    /// Session RMA synchronization mode (`--rma-sync`): notify replaces
    /// the passive epochs with per-op notification flags in every
    /// one-sided candidate's price.
    pub rma_sync: RmaSync,
    /// Persistent-schedule cache enabled (`--sched-cache`): one-sided
    /// candidates price the cold schedule build — or, warm, only the
    /// validation handshake.
    pub sched_cache: bool,
    /// A previous resize between these sizes already built and pinned
    /// the redistribution schedules (warm replay: validation only).
    pub sched_warm: bool,
    /// Resizes the harness still expects after this one (0 = unknown,
    /// the seed behaviour).  Candidates that invest in warmth — pool
    /// register-on-receive pins, cold schedule builds — earn a credit
    /// of their predicted cold-vs-warm gap per future resize, so a
    /// small grow can value warm-pool / warm-schedule futures it pays
    /// for now and harvests later.
    pub future_resizes: u32,
    /// Per-attempt probability that a grow's spawn wave fails
    /// (`--faults spawn=<p>`; 0 = healthy, the seed behaviour).  Grow
    /// candidates price the expected retry tail — detection latency at
    /// the strategy's observation point plus backoff plus the
    /// re-dispatched block — so late-detecting Async loses its edge
    /// over Sequential/Parallel as the failure rate climbs.
    pub fail_p: f64,
}

/// Price one candidate with the closed-form model.
pub fn predict_candidate(inp: &PlannerInputs, cand: &Candidate) -> CostPrediction {
    let mut bulk = Vec::new();
    let mut tail = Vec::new();
    for d in &inp.decls {
        let bytes = d.total_elems * ELEM_BYTES;
        if cand.strategy == Strategy::Blocking || d.kind == DataKind::Constant {
            bulk.push(bytes);
        } else {
            tail.push(bytes);
        }
    }
    let (spawn_block, spawn_tail, spawn_waves) = if inp.nd > inp.ns {
        let sched = cand.spawn_strategy.schedule(
            &inp.net,
            inp.ns,
            inp.nd - inp.ns,
            inp.nd,
            inp.spawn_cost,
        );
        // Asynchronous spawning releases the sources before the last
        // spawned rank is up: the remainder gates the redistribution
        // (overlappable by one-sided registration — the spawn-overlap
        // term of the lifecycle pipeline).  The per-wave offsets let
        // the model price the eager registration stream wave by wave
        // rather than against the last wave alone.
        let tail = (sched.last_child_up() - sched.source_block).max(0.0);
        let mut waves: Vec<f64> = sched
            .child_up
            .iter()
            .map(|&u| (u - sched.source_block).max(0.0))
            .filter(|&w| w > 0.0)
            .collect();
        waves.sort_by(|a, b| a.partial_cmp(b).unwrap());
        waves.dedup();
        let mut block = sched.source_block;
        if inp.fail_p > 0.0 {
            // Expected retry tail under the configured wave-failure
            // probability, using the retry discipline's defaults
            // (`FaultSpec`): Sequential notices at the first child's
            // slot, Parallel at the end of the blocking launch, Async
            // only once the last child was due up.
            let spec = FaultSpec::default();
            let detect = match cand.spawn_strategy {
                SpawnStrategy::Sequential => {
                    sched.source_block / (inp.nd - inp.ns).max(1) as f64
                }
                SpawnStrategy::Parallel => sched.source_block,
                SpawnStrategy::Async => sched.last_child_up(),
            };
            block += expected_spawn_retry_tail(
                inp.fail_p,
                spec.retries,
                detect,
                spec.backoff,
                spec.backoff_cap,
                sched.source_block,
            );
        }
        (block, tail, waves)
    } else {
        (0.0, 0.0, Vec::new())
    };
    let case = ReconfigCase {
        ns: inp.ns,
        nd: inp.nd,
        cores_per_node: inp.cores_per_node,
        bulk_bytes: bulk,
        tail_bytes: tail,
        warm: inp.warm,
        sched_warm: inp.sched_warm,
        t_iter_src: inp.t_iter_src,
        t_iter_dst: inp.t_iter_dst,
        spawn_block,
        spawn_tail,
        spawn_waves,
    };
    let shape = RedistShape {
        one_sided: cand.method.is_rma(),
        lock_per_target: cand.method == Method::RmaLock,
        background: cand.strategy.is_background(),
        threading: cand.strategy == Strategy::Threading,
        pool: cand.win_pool.enabled,
        chunk_bytes: if cand.method.is_rma() {
            cand.rma_chunk_kib.saturating_mul(1024)
        } else {
            0
        },
        notify_sync: inp.rma_sync == RmaSync::Notify && cand.method.is_rma(),
        sched_cache: inp.sched_cache && cand.method.is_rma(),
    };
    predict_reconfig(&inp.net, &case, &shape)
}

/// Cross-resize investment credit of one candidate: the predicted
/// cold-vs-warm gap — what the candidate's pool pins and schedule
/// builds buy on a later resize — times the announced number of future
/// resizes.  Exactly 0 for candidates that invest nothing (the warm
/// prediction equals the cold one) and whenever the harness announced
/// no future (`future_resizes == 0`, the seed behaviour).
fn future_credit(inp: &PlannerInputs, cand: &Candidate, predicted: &CostPrediction) -> f64 {
    if inp.future_resizes == 0 || (inp.warm && inp.sched_warm) {
        return 0.0;
    }
    let mut warm_inp = inp.clone();
    warm_inp.warm = true;
    warm_inp.sched_warm = true;
    let warm = predict_candidate(&warm_inp, cand);
    (predicted.reconf_time - warm.reconf_time).max(0.0) * f64::from(inp.future_resizes)
}

/// Exact cost of one candidate from an isolated DES micro-probe.
#[derive(Clone, Copy, Debug)]
pub struct ProbeCost {
    /// Full reconfiguration span (spawn + redistribution + finish).
    pub reconf_time: f64,
    /// Redistribution span only.
    pub redist_time: f64,
}

/// Additional measurements of one probe, read by the drift harness:
/// the spawn-block/redistribution split and the registration counters
/// — exactly the feedback the online recalibrator
/// ([`crate::mam::Recalibrator`]) consumes per resize.
#[derive(Clone, Copy, Debug)]
pub struct ProbeExtras {
    /// Reconfigure entry → redistribution start (the spawn block; 0
    /// for shrinks).
    pub spawn_block: f64,
    /// Cumulative `rma.reg_bytes` of the isolated world.
    pub reg_bytes: f64,
    /// Cumulative `rma.reg_time` of the isolated world.
    pub reg_secs: f64,
}

/// Simulate exactly one reconfiguration of the declared data in a
/// fresh world — same topology rule, same calibrated parameters, same
/// collective sequence as the real run — and measure its span.  The
/// DES is bit-deterministic and nothing besides the reconfiguration
/// runs, so for blocking candidates the probed span equals the span
/// the application will observe (warm-up skew shifts every candidate
/// identically and cancels in the comparison).
pub fn probe_reconfiguration(inp: &PlannerInputs, cand: &Candidate) -> ProbeCost {
    probe_metrics(inp, cand, |m| ProbeCost {
        reconf_time: m.span("mam.reconf_start", "mam.reconf_end").unwrap_or(f64::NAN),
        redist_time: m.span("mam.redist_start", "mam.redist_end").unwrap_or(f64::NAN),
    })
}

/// [`probe_reconfiguration`] plus the recalibration feedback: the same
/// isolated episode, read back as `(reconf span, extras)`.
pub fn probe_reconfiguration_extras(
    inp: &PlannerInputs,
    cand: &Candidate,
) -> (f64, ProbeExtras) {
    probe_metrics(inp, cand, |m| {
        (
            m.span("mam.reconf_start", "mam.reconf_end").unwrap_or(f64::NAN),
            ProbeExtras {
                spawn_block: m
                    .span("mam.reconf_start", "mam.redist_start")
                    .unwrap_or(0.0)
                    .max(0.0),
                reg_bytes: m.counter("rma.reg_bytes").unwrap_or(0.0),
                reg_secs: m.counter("rma.reg_time").unwrap_or(0.0),
            },
        )
    })
}

/// The reconfiguration a probe replays on each rank: register the
/// declared data, reproduce pool warmth, reconfigure, poll to
/// completion, finish.  Shared verbatim by the fresh one-shot probe
/// and the [`ProbeSession`] ranks so the two are collective-sequence
/// identical by construction.
fn probe_rank_body(
    p: &MpiProc,
    rank: usize,
    ns: usize,
    nd: usize,
    decls: &[DataDecl],
    warm: bool,
    cfg: ReconfigCfg,
) {
    let mut reg = Registry::new();
    for d in decls {
        let b = block_of(d.total_elems, ns, rank);
        let local = if d.real {
            Payload::real(vec![0.0; b.len() as usize])
        } else {
            Payload::virt(b.len())
        };
        reg.register(&d.name, d.kind, d.total_elems, local);
    }
    if warm && cfg.win_pool.enabled {
        // Reproduce the register-on-receive state left by a
        // previous resize: every source's current block is pinned.
        for e in reg.entries() {
            p.pin_buffer(winpool::pin_token(&e.name), e.local.bytes(), cfg.win_pool.cap);
        }
    }
    let mut mam = Mam::new(reg, cfg.clone());
    let decls2 = decls.to_vec();
    let cfg2 = cfg.clone();
    let body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
        Arc::new(move |dp: MpiProc, merged: CommId| {
            let _ = Mam::drain_join(&dp, merged, ns, nd, &decls2, cfg2.clone());
        });
    let mut st = mam.reconfigure(p, WORLD, nd, body);
    let mut polls = 0u32;
    while st == MamStatus::InProgress {
        p.compute(1e-3);
        st = mam.checkpoint(p);
        polls += 1;
        assert!(polls < 1_000_000, "probe redistribution never completes");
    }
    let _ = mam.finish(p, WORLD);
}

/// Probe topology rule (shared by fresh probes and sessions).
fn probe_topology(inp: &PlannerInputs) -> Topology {
    let n = inp.ns.max(inp.nd);
    let cpn = inp.cores_per_node.max(1);
    Topology::new_cyclic(n.div_ceil(cpn).max(1), cpn)
}

/// Shared probe body: run the isolated reconfiguration and hand the
/// final world metrics to `read`.
fn probe_metrics<R>(
    inp: &PlannerInputs,
    cand: &Candidate,
    read: impl FnOnce(&crate::monitor::Metrics) -> R,
) -> R {
    let (ns, nd) = (inp.ns, inp.nd);
    let mut sim = MpiSim::new(probe_topology(inp), inp.net.clone());
    let world = sim.world();
    let decls = inp.decls.clone();
    let cfg = cand.cfg(inp.spawn_cost);
    let warm = inp.warm;
    sim.launch(ns, move |p: MpiProc| {
        let rank = p.rank(WORLD);
        probe_rank_body(&p, rank, ns, nd, &decls, warm, cfg.clone());
    });
    sim.run().expect("planner probe simulation failed");
    let w = world.lock().unwrap();
    read(&w.metrics)
}

/// Command cell shared between a [`ProbeSession`] host and its parked
/// ranks: a monotone generation counter plus the candidate
/// configuration to replay (`None` = shut the session down).
struct ProbeCmd {
    gen: u64,
    cfg: Option<ReconfigCfg>,
}

/// An incremental micro-probe session: the candidate probes of one
/// [`plan`] call replayed from saved engine state instead of from
/// scratch.
///
/// A fresh probe pays world construction, `ns` activity spawns and
/// their thread handshakes per candidate.  The session pays them once:
/// ranks are launched as long-lived activities that park between
/// generations, the quiescent world is captured with
/// [`MpiWorld::snapshot`], and every candidate starts from
/// [`MpiSim::rollback_to`]`(0.0)` + a restore.  Virtual times are
/// bit-identical to a fresh probe: the rewound world *is* the
/// post-launch world, and the host wakes ranks in rank order at
/// `t = 0`, which assigns the same ascending event order that
/// launching fresh activities would.
pub struct ProbeSession {
    sim: MpiSim,
    world: Arc<Mutex<MpiWorld>>,
    snap: WorldSnapshot,
    ranks: Vec<ActivityId>,
    cmd: Arc<Mutex<ProbeCmd>>,
    spawn_cost: f64,
}

impl ProbeSession {
    /// Build the probe world once: launch the source ranks, let them
    /// reach their first park, snapshot.
    pub fn new(inp: &PlannerInputs) -> ProbeSession {
        let (ns, nd) = (inp.ns, inp.nd);
        let mut sim = MpiSim::new(probe_topology(inp), inp.net.clone());
        let world = sim.world();
        let cmd = Arc::new(Mutex::new(ProbeCmd { gen: 0, cfg: None }));
        let decls = inp.decls.clone();
        let warm = inp.warm;
        let cmd2 = cmd.clone();
        let ranks = sim.launch(ns, move |p: MpiProc| {
            let rank = p.rank(WORLD);
            let mut last_gen = 0u64;
            loop {
                p.ctx.park();
                let (gen, cfg) = {
                    let c = cmd2.lock().unwrap();
                    (c.gen, c.cfg.clone())
                };
                if gen == last_gen {
                    continue; // stale wakeup, nothing new to replay
                }
                last_gen = gen;
                let Some(cfg) = cfg else { return };
                probe_rank_body(&p, rank, ns, nd, &decls, warm, cfg);
            }
        });
        sim.run_until_idle().expect("probe session failed to quiesce");
        let snap = world.lock().unwrap().snapshot();
        sim.note_snapshot();
        ProbeSession { sim, world, snap, ranks, cmd, spawn_cost: inp.spawn_cost }
    }

    /// Rewind to the post-launch state and replay one candidate;
    /// returns what `read` extracts from the final metrics.
    fn run_candidate<R>(
        &mut self,
        cand: &Candidate,
        read: impl FnOnce(&crate::monitor::Metrics) -> R,
    ) -> R {
        self.world.lock().unwrap().restore(&self.snap);
        self.sim.rollback_to(0.0);
        {
            let mut c = self.cmd.lock().unwrap();
            c.gen += 1;
            c.cfg = Some(cand.cfg(self.spawn_cost));
        }
        for &a in &self.ranks {
            self.sim.unpark(a, 0.0);
        }
        self.sim.run_until_idle().expect("probe session candidate failed");
        let w = self.world.lock().unwrap();
        read(&w.metrics)
    }

    /// [`probe_reconfiguration`], replayed incrementally.
    pub fn probe(&mut self, cand: &Candidate) -> ProbeCost {
        self.run_candidate(cand, |m| ProbeCost {
            reconf_time: m.span("mam.reconf_start", "mam.reconf_end").unwrap_or(f64::NAN),
            redist_time: m.span("mam.redist_start", "mam.redist_end").unwrap_or(f64::NAN),
        })
    }
}

impl Drop for ProbeSession {
    fn drop(&mut self) {
        if std::thread::panicking() {
            // A probe died mid-run: ranks are not parked, so a graceful
            // rewind would assert.  Leak the stuck workers (the engine
            // abandoned them already) rather than double-panic.
            return;
        }
        // Wake every rank one last time with no configuration: the
        // loops return, the activities finish, the pooled workers go
        // back to the pool.
        self.world.lock().unwrap().restore(&self.snap);
        self.sim.rollback_to(0.0);
        {
            let mut c = self.cmd.lock().unwrap();
            c.gen += 1;
            c.cfg = None;
        }
        for &a in &self.ranks {
            self.sim.unpark(a, 0.0);
        }
        let _ = self.sim.run_until_idle();
    }
}

/// Analytic spawn-block time of one spawn strategy for this resize
/// (exact: the spawn schedules are the closed forms the DES charges).
fn spawn_block_of(inp: &PlannerInputs, ss: SpawnStrategy) -> f64 {
    if inp.nd <= inp.ns {
        return 0.0;
    }
    ss.schedule(&inp.net, inp.ns, inp.nd - inp.ns, inp.nd, inp.spawn_cost)
        .source_block
}

/// Plan one resize: price every valid candidate (chunk variants
/// included for the RMA methods), refine the most promising blocking
/// ones with micro-probes when requested, and return the argmin under
/// the objective (stable first-wins tie-break in enumeration order).
///
/// Probe budget: candidates are deduped by their
/// `(method × strategy × spawn × pool)` tuple — only the
/// best-predicted chunk variant of each tuple is probe-eligible — and
/// at most the analytic top-3 blocking tuples are probed up front.
/// If the argmin then lands on an unprobed blocking candidate it is
/// probed and the argmin re-taken (so the final choice is always
/// probe-backed), which converges because every probe shrinks the
/// unprobed set.
pub fn plan(inp: &PlannerInputs) -> ReconfigPlan {
    assert!(inp.ns > 0 && inp.nd > 0 && inp.ns != inp.nd, "invalid resize");
    let grow = inp.nd > inp.ns;
    // All probes of this plan share one incremental session (created on
    // first use): the probe world is built and its ranks spawned once,
    // then every candidate replays from the rolled-back engine state.
    let mut session: Option<ProbeSession> = None;
    let mut probe_span = |cand: &Candidate| -> f64 {
        session.get_or_insert_with(|| ProbeSession::new(inp)).probe(cand).reconf_time
    };
    let mut candidates: Vec<CandidateCost> = Vec::new();
    let mut seen: std::collections::BTreeSet<((u8, u8, u8, bool), u64)> =
        std::collections::BTreeSet::new();
    // The static chunk grid, extended by any measured-throughput
    // choices the recalibrator injected (appended, so the enumeration
    // order — and hence every tie-break — is unchanged when empty).
    let mut rma_chunks: Vec<u64> = CHUNK_CANDIDATES_KIB.to_vec();
    for &k in &inp.extra_chunks_kib {
        if !rma_chunks.contains(&k) {
            rma_chunks.push(k);
        }
    }
    for m in Method::all() {
        for s in Strategy::all() {
            if !is_valid_version(m, s) {
                continue;
            }
            for pool in [WinPoolPolicy::off(), WinPoolPolicy::on()] {
                let chunks: &[u64] =
                    if m.is_rma() { &rma_chunks } else { &CHUNK_CANDIDATES_KIB[..1] };
                for &chunk in chunks {
                    let candidate = Candidate {
                        method: m,
                        strategy: s,
                        spawn_strategy: SpawnStrategy::Sequential,
                        win_pool: pool,
                        rma_chunk_kib: chunk,
                    };
                    // Dedupe the full identity: enumeration changes
                    // must never price one candidate twice.
                    if !seen.insert((candidate.tuple_key(), chunk)) {
                        continue;
                    }
                    let predicted = predict_candidate(inp, &candidate);
                    let credit = future_credit(inp, &candidate, &predicted);
                    candidates.push(CandidateCost {
                        candidate,
                        predicted,
                        probed_reconf: None,
                        future_credit: credit,
                    });
                }
            }
        }
    }
    if inp.probe {
        // Probe-eligible set: the best-predicted chunk variant per
        // blocking (method × strategy × spawn × pool) tuple …
        let mut best_of_tuple: std::collections::BTreeMap<(u8, u8, u8, bool), usize> =
            std::collections::BTreeMap::new();
        for (i, cc) in candidates.iter().enumerate() {
            if cc.candidate.strategy != Strategy::Blocking {
                continue;
            }
            let key = cc.candidate.tuple_key();
            match best_of_tuple.get(&key) {
                Some(&j) if candidates[j].predicted.reconf_time <= cc.predicted.reconf_time => {}
                _ => {
                    best_of_tuple.insert(key, i);
                }
            }
        }
        // … capped to the analytic top-3 tuples.
        let mut reps: Vec<usize> = best_of_tuple.into_values().collect();
        reps.sort_by(|&a, &b| {
            candidates[a]
                .predicted
                .reconf_time
                .partial_cmp(&candidates[b].predicted.reconf_time)
                .unwrap()
                .then(a.cmp(&b))
        });
        for &i in reps.iter().take(3) {
            candidates[i].probed_reconf = Some(probe_span(&candidates[i].candidate));
        }
    }
    let argmin = |candidates: &[CandidateCost]| -> usize {
        let mut best: Option<usize> = None;
        let mut best_v = f64::INFINITY;
        for (i, cc) in candidates.iter().enumerate() {
            let v = match inp.objective {
                // Span minimization restricts the pick to blocking
                // candidates: background strategies cannot shorten the
                // span (completion is iteration-quantized and the
                // variable tail still moves) — they pay off via overlap,
                // which is what `Effective` optimizes.
                Objective::ReconfTime => {
                    if cc.candidate.strategy != Strategy::Blocking {
                        continue;
                    }
                    cc.reconf_time()
                }
                Objective::Effective => cc.effective(),
            } - cc.future_credit;
            if v < best_v {
                best_v = v;
                best = Some(i);
            }
        }
        best.expect("candidate set cannot be empty")
    };
    let mut idx = argmin(&candidates);
    if inp.probe {
        // Winner loop (bounded): a chosen blocking candidate must be
        // probe-backed — predictions only shortlist, probes decide.
        // Up to 3 extra probes chase a predicted-better unprobed
        // candidate; past the budget the best *probed* blocking
        // candidate wins (keeps the total probe count capped even when
        // the closed-form model misranks a cluster of near-ties).
        for _ in 0..3 {
            if candidates[idx].candidate.strategy != Strategy::Blocking
                || candidates[idx].probed_reconf.is_some()
            {
                break;
            }
            candidates[idx].probed_reconf = Some(probe_span(&candidates[idx].candidate));
            idx = argmin(&candidates);
        }
        if candidates[idx].candidate.strategy == Strategy::Blocking
            && candidates[idx].probed_reconf.is_none()
        {
            idx = candidates
                .iter()
                .enumerate()
                .filter(|(_, cc)| cc.probed_reconf.is_some())
                .min_by(|(_, a), (_, b)| {
                    (a.reconf_time() - a.future_credit)
                        .partial_cmp(&(b.reconf_time() - b.future_credit))
                        .unwrap()
                })
                .map(|(i, _)| i)
                .unwrap_or(idx);
        }
    }
    let mut choice = candidates[idx].candidate;
    let mut predicted = candidates[idx].predicted;
    let mut predicted_reconf = candidates[idx].reconf_time();
    // Spawn-strategy refinement (grows only; shrinks never spawn).
    if grow {
        if inp.probe && choice.strategy == Strategy::Blocking {
            for ss in [SpawnStrategy::Parallel, SpawnStrategy::Async] {
                let mut cand = choice;
                cand.spawn_strategy = ss;
                let probed = probe_span(&cand);
                let pred = predict_candidate(inp, &cand);
                if probed < predicted_reconf {
                    choice = cand;
                    predicted = pred;
                    predicted_reconf = probed;
                }
                let credit = future_credit(inp, &cand, &pred);
                candidates.push(CandidateCost {
                    candidate: cand,
                    predicted: pred,
                    probed_reconf: Some(probed),
                    future_credit: credit,
                });
            }
        } else {
            // Analytic refinement: the spawn schedules are exact, so
            // the minimal source-block time is the simulator's too.
            let mut best_ss = choice.spawn_strategy;
            let mut best_block = spawn_block_of(inp, best_ss);
            for ss in [SpawnStrategy::Parallel, SpawnStrategy::Async] {
                let b = spawn_block_of(inp, ss);
                if b < best_block {
                    best_block = b;
                    best_ss = ss;
                }
            }
            if best_ss != choice.spawn_strategy {
                choice.spawn_strategy = best_ss;
                predicted = predict_candidate(inp, &choice);
                predicted_reconf = predicted.reconf_time;
                candidates.push(CandidateCost {
                    candidate: choice,
                    predicted,
                    probed_reconf: None,
                    future_credit: future_credit(inp, &choice, &predicted),
                });
            }
        }
    }
    ReconfigPlan {
        ns: inp.ns,
        nd: inp.nd,
        warm: inp.warm,
        choice,
        predicted,
        predicted_reconf,
        candidates,
    }
}

/// Analytic-only resolution used by `Mam` when
/// [`ReconfigCfg::planner`] is [`PlannerMode::Auto`]: every input is
/// rank-independent (declared sizes, calibrated parameters, the
/// resize pair), so sources and spawned drains resolve to the same
/// plan without communicating.  Iteration times are unknown at this
/// level, so the objective is the span and pool warmth is not
/// assumed; harnesses that know more resolve at their own level with
/// [`plan`] and pass the resolved configuration down.
pub fn resolve_internal(
    net: &NetParams,
    cores_per_node: usize,
    decls: Vec<DataDecl>,
    ns: usize,
    nd: usize,
    base: &ReconfigCfg,
    fail_p: f64,
) -> ReconfigCfg {
    let inp = PlannerInputs {
        decls,
        ns,
        nd,
        cores_per_node,
        net: net.clone(),
        spawn_cost: base.spawn_cost,
        warm: false,
        t_iter_src: 0.0,
        t_iter_dst: 0.0,
        objective: Objective::ReconfTime,
        probe: false,
        extra_chunks_kib: Vec::new(),
        rma_sync: base.rma_sync,
        sched_cache: base.sched_cache,
        sched_warm: false,
        future_resizes: 0,
        fail_p,
    };
    // The planner picks the version; the session-level sync/cache
    // knobs ride through from the configured base.
    plan(&inp)
        .choice
        .cfg(base.spawn_cost)
        .with_sync(base.rma_sync)
        .with_sched_cache(base.sched_cache)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_inputs(ns: usize, nd: usize, probe: bool) -> PlannerInputs {
        PlannerInputs {
            decls: vec![
                DataDecl {
                    name: "A".into(),
                    kind: DataKind::Constant,
                    total_elems: 60_000,
                    real: false,
                },
                DataDecl {
                    name: "x".into(),
                    kind: DataKind::Variable,
                    total_elems: 2_000,
                    real: false,
                },
            ],
            ns,
            nd,
            cores_per_node: 4,
            net: NetParams::sarteco25(),
            spawn_cost: 0.05,
            warm: false,
            t_iter_src: 2e-3,
            t_iter_dst: 1e-3,
            objective: Objective::ReconfTime,
            probe,
            extra_chunks_kib: Vec::new(),
            rma_sync: RmaSync::Epoch,
            sched_cache: false,
            sched_warm: false,
            future_resizes: 0,
            fail_p: 0.0,
        }
    }

    #[test]
    fn failure_probability_taxes_late_detecting_strategies_hardest() {
        let cand = |s| Candidate {
            method: Method::Collective,
            strategy: Strategy::Blocking,
            spawn_strategy: s,
            win_pool: WinPoolPolicy::off(),
            rma_chunk_kib: 0,
        };
        let healthy = tiny_inputs(4, 8, false);
        let mut lossy = tiny_inputs(4, 8, false);
        lossy.fail_p = 0.9;
        let s0 = predict_candidate(&healthy, &cand(SpawnStrategy::Sequential));
        let s1 = predict_candidate(&lossy, &cand(SpawnStrategy::Sequential));
        let a0 = predict_candidate(&healthy, &cand(SpawnStrategy::Async));
        let a1 = predict_candidate(&lossy, &cand(SpawnStrategy::Async));
        let seq_tax = s1.reconf_time - s0.reconf_time;
        let asy_tax = a1.reconf_time - a0.reconf_time;
        assert!(seq_tax > 0.0, "retry tail must cost something: {seq_tax}");
        assert!(
            asy_tax > seq_tax,
            "Async detects failures last and must pay the heavier tail: {asy_tax} vs {seq_tax}"
        );
        // Shrinks have no spawn phase — fail_p prices nothing.
        let mut shrink = tiny_inputs(8, 4, false);
        shrink.fail_p = 0.9;
        let sh0 = predict_candidate(&tiny_inputs(8, 4, false), &cand(SpawnStrategy::Sequential));
        let sh1 = predict_candidate(&shrink, &cand(SpawnStrategy::Sequential));
        assert_eq!(sh0.reconf_time.to_bits(), sh1.reconf_time.to_bits());
    }

    #[test]
    fn planner_mode_parses_and_labels() {
        assert_eq!(PlannerMode::parse("fixed"), Some(PlannerMode::Fixed));
        assert_eq!(PlannerMode::parse("AUTO"), Some(PlannerMode::Auto));
        assert_eq!(PlannerMode::parse("maybe"), None);
        assert_eq!(PlannerMode::default(), PlannerMode::Fixed);
        assert_eq!(PlannerMode::Auto.label(), "auto");
        assert_eq!(PlannerMode::Fixed.label(), "fixed");
    }

    #[test]
    fn candidate_labels_compose() {
        let c = Candidate {
            method: Method::RmaLockall,
            strategy: Strategy::Blocking,
            spawn_strategy: SpawnStrategy::Async,
            win_pool: WinPoolPolicy::on(),
            rma_chunk_kib: 0,
        };
        assert_eq!(c.label(), "RMA-Lockall+pool+async");
        let c = Candidate {
            method: Method::Collective,
            strategy: Strategy::WaitDrains,
            spawn_strategy: SpawnStrategy::Sequential,
            win_pool: WinPoolPolicy::off(),
            rma_chunk_kib: 0,
        };
        assert_eq!(c.label(), "COL-WD");
    }

    #[test]
    fn analytic_plan_is_deterministic_and_valid() {
        let inp = tiny_inputs(4, 8, false);
        let a = plan(&inp);
        let b = plan(&inp);
        assert_eq!(a.choice, b.choice, "planning must be deterministic");
        assert!(is_valid_version(a.choice.method, a.choice.strategy));
        // Every valid (method, strategy) appears twice (pool off/on),
        // plus any spawn-refined variant of the grow choice.
        assert!(a.candidates.len() >= 20, "{}", a.candidates.len());
        assert!(a.predicted_reconf.is_finite() && a.predicted_reconf > 0.0);
        // Span objective picks a blocking candidate by construction.
        assert_eq!(a.choice.strategy, Strategy::Blocking);
        // The choice is the predicted argmin over blocking candidates.
        for cc in a.candidates.iter().filter(|c| c.candidate.strategy == Strategy::Blocking) {
            assert!(
                a.predicted_reconf <= cc.reconf_time() + 1e-15,
                "{:?} beats the choice",
                cc.candidate
            );
        }
    }

    #[test]
    fn chunk_variants_are_enumerated_without_duplicates() {
        let p = plan(&tiny_inputs(4, 8, false));
        // RMA methods get chunked variants; COL never does.
        assert!(
            p.candidates
                .iter()
                .any(|cc| cc.candidate.method.is_rma() && cc.candidate.rma_chunk_kib > 0),
            "no chunked RMA candidates priced"
        );
        assert!(
            p.candidates
                .iter()
                .all(|cc| cc.candidate.method.is_rma() || cc.candidate.rma_chunk_kib == 0),
            "COL must not enumerate chunk variants"
        );
        // Full-identity dedupe: no candidate priced twice.
        let mut seen = std::collections::BTreeSet::new();
        for cc in &p.candidates {
            let c = &cc.candidate;
            let key = format!(
                "{:?}|{:?}|{:?}|{:?}|{}",
                c.method, c.strategy, c.spawn_strategy, c.win_pool, c.rma_chunk_kib
            );
            assert!(seen.insert(key), "duplicate candidate {c:?}");
        }
    }

    #[test]
    fn extra_chunks_extend_the_grid_without_perturbing_the_base() {
        let base = plan(&tiny_inputs(4, 8, false));
        // A novel measured chunk is enumerated for the RMA methods.
        let mut inp = tiny_inputs(4, 8, false);
        inp.extra_chunks_kib = vec![512];
        let ext = plan(&inp);
        assert!(
            ext.candidates
                .iter()
                .any(|cc| cc.candidate.method.is_rma() && cc.candidate.rma_chunk_kib == 512),
            "injected chunk not priced"
        );
        assert!(ext.candidates.len() > base.candidates.len());
        // A duplicate of the static grid changes nothing at all.
        let mut inp = tiny_inputs(4, 8, false);
        inp.extra_chunks_kib = vec![1024, 0];
        let dup = plan(&inp);
        assert_eq!(dup.candidates.len(), base.candidates.len());
        assert_eq!(dup.choice, base.choice);
        assert_eq!(dup.predicted_reconf.to_bits(), base.predicted_reconf.to_bits());
    }

    #[test]
    fn probe_budget_is_capped_and_the_choice_is_probe_backed() {
        // Without the cap every blocking candidate would be probed
        // (3 methods × 2 pools × chunk variants = 18 probes); the cap
        // allows the analytic top-3 tuples plus the winner loop.
        let p = plan(&tiny_inputs(4, 2, true));
        let probed = p.candidates.iter().filter(|cc| cc.probed_reconf.is_some()).count();
        assert!((1..=6).contains(&probed), "probe budget blew up: {probed}");
        let chosen = p.candidates.iter().find(|cc| cc.candidate == p.choice).unwrap();
        assert!(
            chosen.candidate.strategy != Strategy::Blocking || chosen.probed_reconf.is_some(),
            "blocking choice must be probe-backed"
        );
    }

    #[test]
    fn chunked_labels_compose() {
        let c = Candidate {
            method: Method::RmaLockall,
            strategy: Strategy::Blocking,
            spawn_strategy: SpawnStrategy::Sequential,
            win_pool: WinPoolPolicy::on(),
            rma_chunk_kib: 1024,
        };
        assert_eq!(c.label(), "RMA-Lockall+pool+c1024k");
        let cfg = c.cfg(0.1);
        assert_eq!(cfg.rma_chunk_kib, 1024);
        assert_eq!(cfg.chunk_elems(), 1024 * 1024 / 8);
    }

    #[test]
    fn effective_objective_can_pick_a_background_strategy() {
        // A big shrink with substantial iteration times: the overlap
        // credit dominates and a background candidate must win the
        // effective objective.
        let mut inp = tiny_inputs(8, 4, false);
        inp.decls[0].total_elems = 40_000_000;
        inp.t_iter_src = 5e-3;
        inp.t_iter_dst = 1e-2;
        inp.objective = Objective::Effective;
        let p = plan(&inp);
        assert!(
            p.choice.strategy.is_background(),
            "expected a background pick, got {:?}",
            p.choice
        );
        assert!(p.predicted.overlap_credit > 0.0);
    }

    #[test]
    fn probed_plan_choice_is_the_probed_argmin() {
        let inp = tiny_inputs(4, 2, true);
        let p = plan(&inp);
        assert_eq!(p.choice.strategy, Strategy::Blocking);
        let choice_cost = p
            .candidates
            .iter()
            .find(|cc| cc.candidate == p.choice)
            .expect("choice must be in the candidate set");
        let probed = choice_cost.probed_reconf.expect("blocking choice must be probed");
        assert!(probed.is_finite() && probed > 0.0);
        for cc in &p.candidates {
            if let Some(other) = cc.probed_reconf {
                assert!(
                    probed <= other + 1e-12,
                    "{:?} probed {} beats choice {}",
                    cc.candidate,
                    other,
                    probed
                );
            }
        }
    }

    #[test]
    fn session_probes_match_fresh_probes_bit_for_bit() {
        // The incremental path (snapshot + rollback + replay) must be
        // observationally identical to building a fresh world per
        // candidate — virtual times included — across methods, pool
        // states and spawn strategies, in both resize directions.
        for (ns, nd) in [(3usize, 6usize), (6, 3)] {
            let inp = tiny_inputs(ns, nd, false);
            let mut session = ProbeSession::new(&inp);
            let cands = [
                Candidate {
                    method: Method::RmaLockall,
                    strategy: Strategy::Blocking,
                    spawn_strategy: SpawnStrategy::Sequential,
                    win_pool: WinPoolPolicy::off(),
                    rma_chunk_kib: 0,
                },
                Candidate {
                    method: Method::Collective,
                    strategy: Strategy::Blocking,
                    spawn_strategy: SpawnStrategy::Parallel,
                    win_pool: WinPoolPolicy::off(),
                    rma_chunk_kib: 0,
                },
                Candidate {
                    method: Method::RmaLock,
                    strategy: Strategy::Blocking,
                    spawn_strategy: SpawnStrategy::Sequential,
                    win_pool: WinPoolPolicy::on(),
                    rma_chunk_kib: 1024,
                },
            ];
            for cand in &cands {
                let fresh = probe_reconfiguration(&inp, cand);
                let inc = session.probe(cand);
                assert_eq!(
                    inc.reconf_time.to_bits(),
                    fresh.reconf_time.to_bits(),
                    "{ns}->{nd} {:?}: session {} vs fresh {}",
                    cand,
                    inc.reconf_time,
                    fresh.reconf_time
                );
                assert_eq!(inc.redist_time.to_bits(), fresh.redist_time.to_bits());
            }
            // Replaying a candidate a second time is a pure rollback
            // replay: nothing from the first run may leak through.
            let again = session.probe(&cands[2]);
            let fresh = probe_reconfiguration(&inp, &cands[2]);
            assert_eq!(again.reconf_time.to_bits(), fresh.reconf_time.to_bits());
        }
    }

    #[test]
    fn session_warm_probe_matches_fresh_warm_probe() {
        let mut inp = tiny_inputs(6, 3, false);
        inp.decls[0].total_elems = 2_000_000;
        inp.warm = true;
        let cand = Candidate {
            method: Method::RmaLockall,
            strategy: Strategy::Blocking,
            spawn_strategy: SpawnStrategy::Sequential,
            win_pool: WinPoolPolicy::on(),
            rma_chunk_kib: 0,
        };
        let mut session = ProbeSession::new(&inp);
        let inc = session.probe(&cand);
        let fresh = probe_reconfiguration(&inp, &cand);
        assert_eq!(inc.reconf_time.to_bits(), fresh.reconf_time.to_bits());
        assert_eq!(inc.redist_time.to_bits(), fresh.redist_time.to_bits());
    }

    #[test]
    fn probed_plan_is_identical_with_and_without_reuse() {
        // `plan` now routes probes through one session; the chosen
        // candidate and every probed span must equal what per-candidate
        // fresh probes produce.  (The probe functions themselves are
        // exercised above; here the end-to-end argmin is on trial.)
        let p = plan(&tiny_inputs(4, 2, true));
        for cc in p.candidates.iter().filter(|cc| cc.probed_reconf.is_some()) {
            let fresh = probe_reconfiguration(&tiny_inputs(4, 2, true), &cc.candidate);
            assert_eq!(
                cc.probed_reconf.unwrap().to_bits(),
                fresh.reconf_time.to_bits(),
                "{:?}",
                cc.candidate
            );
        }
    }

    #[test]
    fn probes_are_bit_deterministic() {
        let inp = tiny_inputs(3, 6, false);
        let cand = Candidate {
            method: Method::RmaLockall,
            strategy: Strategy::Blocking,
            spawn_strategy: SpawnStrategy::Sequential,
            win_pool: WinPoolPolicy::off(),
            rma_chunk_kib: 0,
        };
        let a = probe_reconfiguration(&inp, &cand);
        let b = probe_reconfiguration(&inp, &cand);
        assert_eq!(a.reconf_time.to_bits(), b.reconf_time.to_bits());
        assert_eq!(a.redist_time.to_bits(), b.redist_time.to_bits());
        assert!(a.reconf_time >= a.redist_time);
    }

    #[test]
    fn warm_probe_is_cheaper_for_pooled_rma() {
        let mut inp = tiny_inputs(6, 3, false);
        inp.decls[0].total_elems = 2_000_000;
        let cand = Candidate {
            method: Method::RmaLockall,
            strategy: Strategy::Blocking,
            spawn_strategy: SpawnStrategy::Sequential,
            win_pool: WinPoolPolicy::on(),
            rma_chunk_kib: 0,
        };
        let cold = probe_reconfiguration(&inp, &cand);
        inp.warm = true;
        let warm = probe_reconfiguration(&inp, &cand);
        assert!(
            warm.reconf_time < cold.reconf_time,
            "warm {} !< cold {}",
            warm.reconf_time,
            cold.reconf_time
        );
    }

    #[test]
    fn warm_prediction_prefers_pool_over_cold_rma() {
        let mut inp = tiny_inputs(4, 8, false);
        inp.warm = true;
        let pooled = Candidate {
            method: Method::RmaLockall,
            strategy: Strategy::Blocking,
            spawn_strategy: SpawnStrategy::Sequential,
            win_pool: WinPoolPolicy::on(),
            rma_chunk_kib: 0,
        };
        let cold = Candidate { win_pool: WinPoolPolicy::off(), ..pooled };
        let pw = predict_candidate(&inp, &pooled);
        let pc = predict_candidate(&inp, &cold);
        assert!(pw.reconf_time < pc.reconf_time, "{pw:?} vs {pc:?}");
    }

    #[test]
    fn grow_plans_refine_the_spawn_strategy() {
        // Analytic path: with the decomposed spawn terms cheaper than
        // the 0.25 s sequential constant, a grow plan must not keep
        // Sequential.
        let mut inp = tiny_inputs(8, 16, false);
        inp.spawn_cost = 0.25;
        let p = plan(&inp);
        assert_ne!(p.choice.spawn_strategy, SpawnStrategy::Sequential, "{:?}", p.choice);
        // Shrinks never spawn: strategy selection leaves Sequential.
        let p = plan(&tiny_inputs(16, 8, false));
        assert_eq!(p.choice.spawn_strategy, SpawnStrategy::Sequential);
    }

    #[test]
    fn internal_resolution_is_deterministic_and_resolved() {
        let inp = tiny_inputs(4, 8, false);
        let base = ReconfigCfg { planner: PlannerMode::Auto, ..ReconfigCfg::default() };
        let a = resolve_internal(&inp.net, 4, inp.decls.clone(), 4, 8, &base, 0.0);
        let b = resolve_internal(&inp.net, 4, inp.decls.clone(), 4, 8, &base, 0.0);
        assert_eq!(a.planner, PlannerMode::Fixed, "resolution must terminate");
        assert_eq!(a.method, b.method);
        assert_eq!(a.strategy, b.strategy);
        assert_eq!(a.spawn_strategy, b.spawn_strategy);
        assert_eq!(a.win_pool, b.win_pool);
        assert!(is_valid_version(a.method, a.strategy));
    }

    #[test]
    fn sync_and_sched_knobs_flow_into_predictions() {
        let mut inp = tiny_inputs(4, 8, false);
        let rma = Candidate {
            method: Method::RmaLockall,
            strategy: Strategy::Blocking,
            spawn_strategy: SpawnStrategy::Sequential,
            win_pool: WinPoolPolicy::off(),
            rma_chunk_kib: 0,
        };
        let col = Candidate { method: Method::Collective, ..rma };
        let base_rma = predict_candidate(&inp, &rma);
        let base_col = predict_candidate(&inp, &col);
        // Notify replaces the passive epochs: cheaper protocol.
        inp.rma_sync = RmaSync::Notify;
        assert!(predict_candidate(&inp, &rma).protocol < base_rma.protocol);
        // Schedule caching: cold pays the build, warm only validates.
        inp.rma_sync = RmaSync::Epoch;
        inp.sched_cache = true;
        let cold = predict_candidate(&inp, &rma);
        assert!(cold.protocol > base_rma.protocol);
        inp.sched_warm = true;
        let warm = predict_candidate(&inp, &rma);
        assert!(warm.protocol < cold.protocol && warm.protocol > base_rma.protocol);
        // Two-sided candidates are untouched by either knob.
        inp.rma_sync = RmaSync::Notify;
        let col_knobbed = predict_candidate(&inp, &col);
        assert_eq!(col_knobbed.protocol.to_bits(), base_col.protocol.to_bits());
        assert_eq!(col_knobbed.reconf_time.to_bits(), base_col.reconf_time.to_bits());
    }

    #[test]
    fn future_resize_credit_values_warm_investments() {
        // No announced future: every credit is exactly 0 and the plan
        // is bit-identical to the seed enumeration.
        let base = plan(&tiny_inputs(4, 8, false));
        assert!(base.candidates.iter().all(|cc| cc.future_credit == 0.0));
        // Announce a future: investing candidates (pool pins, cold
        // schedule builds) earn a positive credit, non-investing COL
        // without the pool earns exactly nothing.
        let mut inp = tiny_inputs(4, 8, false);
        inp.future_resizes = 4;
        inp.sched_cache = true;
        let fut = plan(&inp);
        let pooled = fut
            .candidates
            .iter()
            .find(|cc| {
                cc.candidate.method == Method::RmaLockall
                    && cc.candidate.strategy == Strategy::Blocking
                    && cc.candidate.win_pool.enabled
                    && cc.candidate.rma_chunk_kib == 0
            })
            .unwrap();
        assert!(pooled.future_credit > 0.0, "{pooled:?}");
        let bare_col = fut
            .candidates
            .iter()
            .find(|cc| {
                cc.candidate.method == Method::Collective
                    && cc.candidate.strategy == Strategy::Blocking
                    && !cc.candidate.win_pool.enabled
            })
            .unwrap();
        assert_eq!(bare_col.future_credit, 0.0);
        // The credit scales linearly with the announced horizon.
        let mut inp8 = tiny_inputs(4, 8, false);
        inp8.future_resizes = 8;
        inp8.sched_cache = true;
        let fut8 = plan(&inp8);
        let pooled8 = fut8
            .candidates
            .iter()
            .find(|cc| cc.candidate == pooled.candidate)
            .unwrap();
        assert!((pooled8.future_credit - 2.0 * pooled.future_credit).abs() < 1e-12);
        // Already-warm sessions have nothing left to invest in.
        let mut warm_inp = inp.clone();
        warm_inp.warm = true;
        warm_inp.sched_warm = true;
        let warm_plan = plan(&warm_inp);
        assert!(warm_plan.candidates.iter().all(|cc| cc.future_credit == 0.0));
    }

    #[test]
    fn async_grow_predictions_price_spawn_waves() {
        // The async spawn schedule leaves a tail; its per-wave offsets
        // must reach the cost model (one attach handshake per wave on
        // the eager registration stream), so the async prediction is
        // never cheaper than wave-blind and stays finite/ordered.
        let inp = tiny_inputs(4, 16, false);
        let cand = Candidate {
            method: Method::RmaLockall,
            strategy: Strategy::Blocking,
            spawn_strategy: SpawnStrategy::Async,
            win_pool: WinPoolPolicy::off(),
            rma_chunk_kib: 0,
        };
        let p = predict_candidate(&inp, &cand);
        assert!(p.reconf_time.is_finite() && p.reconf_time > 0.0);
        // A sequential-spawn variant has no tail and no waves at all.
        let seq = Candidate { spawn_strategy: SpawnStrategy::Sequential, ..cand };
        let ps = predict_candidate(&inp, &seq);
        assert!(ps.reconf_time.is_finite());
        // Shrinks never spawn: waves are empty, prediction unchanged
        // relative to the spawn strategy.
        let mut shrink = tiny_inputs(16, 4, false);
        shrink.net = inp.net.clone();
        let a = predict_candidate(&shrink, &cand);
        let b = predict_candidate(&shrink, &seq);
        assert_eq!(a.reconf_time.to_bits(), b.reconf_time.to_bits());
    }

    #[test]
    fn internal_resolution_carries_sync_and_cache_knobs() {
        let inp = tiny_inputs(4, 8, false);
        let base = ReconfigCfg {
            planner: PlannerMode::Auto,
            rma_sync: RmaSync::Notify,
            sched_cache: true,
            ..ReconfigCfg::default()
        };
        let r = resolve_internal(&inp.net, 4, inp.decls.clone(), 4, 8, &base, 0.0);
        assert_eq!(r.planner, PlannerMode::Fixed);
        assert_eq!(r.rma_sync, RmaSync::Notify);
        assert!(r.sched_cache);
    }
}
