//! Recovery policy for faulty reconfigurations (`--faults`).
//!
//! The seed model assumed spawning always succeeds; real RMS-driven
//! malleability loses launches to node failures, stale allocations and
//! slow daemons.  This module wraps the Merge grow path's spawn phase
//! with the retry discipline the resize driver ([`Mam::reconfigure`])
//! applies when a [`FaultPlan`] is installed:
//!
//! * every attempt asks the plan how many of the `nd − ns` targets
//!   fail (a pure function of `(resize, dispatch, attempt)`, so every
//!   source rank agrees without communicating),
//! * a failed attempt is *charge-only*: the sources block for the
//!   failed subset's launch up to the strategy's detection point
//!   (plus the hang timeout for `kind=hang` faults), then for the
//!   capped exponential backoff before the retry — no half-created
//!   activities are ever torn down, so virtual time stays exact and
//!   runs stay byte-deterministic,
//! * the first healthy attempt performs the one real
//!   [`spawn_merge_scheduled`] for the full wave.  Under `Async` /
//!   rank-mode faults only the failed subset is re-dispatched, which
//!   the model prices through the subset-sized schedules of the
//!   failed attempts (the economy the planner's retry-tail term
//!   mirrors),
//! * exhausting `retries` yields no communicator: the caller unwinds
//!   via abort-and-rollback instead of panicking the simulation.
//!
//! Detection latency differs per strategy and is what makes `Async`
//! risky under high failure probability: `Sequential` notices at the
//! first child's slot, `Parallel` at the end of the blocking launch,
//! but `Async` sources have already resumed and only learn of the
//! failure once the last child was due up.
//!
//! [`Mam::reconfigure`]: super::reconfig::Mam::reconfigure
//! [`FaultPlan`]: crate::simcluster::faults::FaultPlan
//! [`spawn_merge_scheduled`]: crate::simmpi::MpiProc::spawn_merge_scheduled

use std::sync::Arc;

use crate::netmodel::SpawnSchedule;
use crate::simcluster::faults::FaultPlan;
use crate::simmpi::{CommId, MpiProc};

use super::reconfig::ReconfigCfg;
use super::spawn::SpawnStrategy;

/// Outcome of the fault-aware spawn phase.
pub struct SpawnOutcome {
    /// The merged communicator (`None` = retries exhausted, abort).
    pub merged: Option<CommId>,
    /// Attempts that failed before the outcome (0 on the healthy path).
    pub failed_attempts: u32,
    /// Total target ranks lost across the failed attempts.
    pub failed_ranks: u64,
}

/// Virtual time at which the sources *detect* a failed launch, given
/// the failed subset's schedule.  Base latency only — `kind=hang`
/// extends it to the configured timeout via
/// [`FaultPlan::detect_latency`].
fn detect_base(strategy: SpawnStrategy, sched: &SpawnSchedule, n_failed: usize) -> f64 {
    match strategy {
        // One child per sequential slot: the failure surfaces at the
        // first slot that does not come up.
        SpawnStrategy::Sequential => sched.source_block / n_failed.max(1) as f64,
        // Sources are blocked through the whole launch either way.
        SpawnStrategy::Parallel => sched.source_block,
        // Sources resumed at initiation; the miss is only observable
        // once the last child was due up — late detection is Async's
        // failure-mode tax.
        SpawnStrategy::Async => sched.last_child_up(),
    }
}

/// Execute the grow-path spawn under `plan`, retrying with capped
/// exponential backoff up to `plan.spec.retries` times.  `ctx` is the
/// `(resize, dispatch)` fault context (see `Mam::set_fault_ctx`); all
/// sources must call this collectively with identical arguments.
pub fn spawn_with_recovery(
    proc: &MpiProc,
    app_comm: CommId,
    ns: usize,
    nd: usize,
    cfg: &ReconfigCfg,
    drain_body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync>,
    plan: &FaultPlan,
    ctx: (u64, u64),
) -> SpawnOutcome {
    let n_new = nd - ns;
    let params = proc.net_params();
    let (resize, dispatch) = ctx;
    let mut failed_attempts = 0u32;
    let mut failed_ranks = 0u64;
    for attempt in 0..=plan.spec.retries {
        let n_failed = plan.spawn_failures(resize, dispatch, attempt, n_new);
        if n_failed == 0 {
            let sched = cfg.spawn_strategy.schedule(&params, ns, n_new, nd, cfg.spawn_cost);
            let merged = proc.spawn_merge_scheduled(app_comm, n_new, &sched, drain_body);
            return SpawnOutcome { merged: Some(merged), failed_attempts, failed_ranks };
        }
        failed_attempts += 1;
        failed_ranks += n_failed as u64;
        // Charge-only failed attempt: block every source for the
        // failed subset's launch up to the detection point plus the
        // pre-retry backoff.  The charge is identical on all sources
        // (pure function of shared inputs), so the job stays
        // collectively consistent without creating — and then tearing
        // down — real activities.  Re-dispatching only the failed
        // subset (Async / rank-mode) is what keeps retries of partial
        // failures cheaper than the first full wave.
        let subset = n_failed.min(n_new);
        let sched = cfg.spawn_strategy.schedule(&params, ns, subset, ns + subset, cfg.spawn_cost);
        let detect = plan.detect_latency(detect_base(cfg.spawn_strategy, &sched, subset));
        proc.compute(detect + plan.backoff_before(attempt + 1));
    }
    SpawnOutcome { merged: None, failed_attempts, failed_ranks }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netmodel::NetParams;
    use crate::simcluster::faults::FaultSpec;

    #[test]
    fn detection_is_latest_under_async_and_earliest_under_sequential() {
        let p = NetParams::sarteco25();
        let seq = SpawnStrategy::Sequential.schedule(&p, 8, 8, 16, 0.25);
        let par = SpawnStrategy::Parallel.schedule(&p, 8, 8, 16, 0.25);
        let asy = SpawnStrategy::Async.schedule(&p, 8, 8, 16, 0.25);
        let d_seq = detect_base(SpawnStrategy::Sequential, &seq, 8);
        let d_par = detect_base(SpawnStrategy::Parallel, &par, 8);
        let d_asy = detect_base(SpawnStrategy::Async, &asy, 8);
        assert!(d_seq > 0.0 && d_seq < seq.source_block, "first-slot detection");
        assert_eq!(d_par.to_bits(), par.source_block.to_bits());
        assert_eq!(d_asy.to_bits(), asy.last_child_up().to_bits());
    }

    #[test]
    fn hang_faults_stretch_detection_to_the_timeout() {
        let plan = FaultPlan::new(FaultSpec::parse("spawn=first1,kind=hang,timeout=2.0").unwrap());
        let p = NetParams::test_simple();
        let sched = SpawnStrategy::Parallel.schedule(&p, 4, 4, 8, 0.25);
        let base = detect_base(SpawnStrategy::Parallel, &sched, 4);
        assert!(base < 2.0, "premise: the launch itself is fast");
        assert!((plan.detect_latency(base) - 2.0).abs() < 1e-12);
    }
}
