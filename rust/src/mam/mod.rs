//! MaM — the Malleability Module (§III, §IV).
//!
//! MaM converts an MPI application into a malleable one: at a
//! *checkpoint* the application asks MaM to resize from `NS` sources to
//! `ND` drains; MaM performs process management (the *Merge* method:
//! spawn `ND−NS` ranks or retire `NS−ND`), redistributes every
//! registered data structure from the NS-way to the ND-way block
//! distribution, and hands the application the communicator to resume
//! on.
//!
//! The module implements the paper's full method × strategy matrix:
//!
//! | method        | Blocking | Non-Blocking | Wait Drains | Threading |
//! |---------------|----------|--------------|-------------|-----------|
//! | `Collective`  | ✓        | ✓            | ✓           | ✓         |
//! | `RmaLock`     | ✓        | ✗ (§V-A)     | ✓           | ✓         |
//! | `RmaLockall`  | ✓        | ✗ (§V-A)     | ✓           | ✓         |
//!
//! NB is not applicable to the RMA methods: sources only expose memory
//! and cannot determine themselves when remote accesses have completed
//! (§V-A) — that is exactly what *Wait Drains* adds.
//!
//! * [`blockdist`] — block ownership + the paper's Algorithm 1,
//! * [`registry`]  — the automatic data-redistribution registry,
//! * [`collective`] — the COL method over `MPI_(I)Alltoallv`,
//! * [`rma`]       — RMA-Lock (Alg. 2), RMA-Lockall (Alg. 3) and the
//!   split `Init_RMA`/`Complete_RMA` used for background redistribution,
//! * [`winpool`]   — the persistent window pool (§VI): entries pin
//!   their windows so repeat resizes skip `Win_create` registration,
//! * [`schedcache`] — persistent redistribution schedules: the
//!   per-resize planning (targets, read lists, segment layout, sync
//!   plan) built once per `(from, to, structure, chunk)` and replayed
//!   for the cost of a validation handshake,
//! * [`spawn`]     — spawn strategies for the Merge grow path
//!   (sequential / parallel / async `MPI_Comm_spawn` modeling),
//! * [`planner`]   — the cost-model-driven reconfiguration planner:
//!   prices every `(method × strategy × spawn × pool)` candidate with
//!   `netmodel`'s prediction API (refined by exact DES micro-probes)
//!   and picks the version per resize (`--planner auto`),
//! * [`recalib`]   — online recalibration of the planner's constants
//!   from the spans/counters each resize already measures, plus the
//!   measured-throughput adaptive chunk rule (`--recalib on`),
//! * [`resilience`] — spawn retry/backoff and the abort-and-rollback
//!   recovery path exercised under `--faults`,
//! * [`reconfig`]  — the reconfiguration driver tying it together.

pub mod blockdist;
pub mod collective;
pub mod planner;
pub mod recalib;
pub mod reconfig;
pub mod registry;
pub mod resilience;
pub mod rma;
pub mod schedcache;
pub mod spawn;
pub mod winpool;

pub use blockdist::{block_of, drain_plan, source_plan, Block, DrainPlan, SourcePlan};
pub use planner::{Candidate, Objective, PlannerInputs, PlannerMode, ProbeSession, ReconfigPlan};
pub use recalib::{Observation, RecalibCfg, Recalibrator};
pub use reconfig::{Mam, MamStatus, ReconfigCfg, Reconfiguration, Roles};
pub use registry::{DataDecl, DataEntry, DataKind, Registry};
pub use schedcache::{RedistSchedule, SchedCache, SchedKey, SchedRead};
pub use spawn::SpawnStrategy;
pub use winpool::WinPoolPolicy;

/// Data-redistribution method (§IV, §V-A).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Method {
    /// Baseline two-sided method over `MPI_Alltoallv` ([9]).
    Collective,
    /// Algorithm 2: one passive epoch per accessed target
    /// (`Win_lock`/`Win_unlock`).
    RmaLock,
    /// Algorithm 3: a single passive epoch over all targets
    /// (`Win_lock_all`/`Win_unlock_all`).
    RmaLockall,
}

impl Method {
    pub fn is_rma(self) -> bool {
        !matches!(self, Method::Collective)
    }

    /// Short label used in figures ("COL", "RMA-Lock", "RMA-Lockall").
    pub fn label(self) -> &'static str {
        match self {
            Method::Collective => "COL",
            Method::RmaLock => "RMA-Lock",
            Method::RmaLockall => "RMA-Lockall",
        }
    }

    pub fn all() -> [Method; 3] {
        [Method::Collective, Method::RmaLock, Method::RmaLockall]
    }

    pub fn parse(s: &str) -> Option<Method> {
        match s.to_ascii_lowercase().as_str() {
            "col" | "collective" => Some(Method::Collective),
            "rma-lock" | "rmalock" | "rma1" | "lock" => Some(Method::RmaLock),
            "rma-lockall" | "rmalockall" | "rma2" | "lockall" => Some(Method::RmaLockall),
            _ => None,
        }
    }
}

/// Redistribution strategy (§III, §IV-C, §V-A).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Application blocked for the whole redistribution.
    Blocking,
    /// Overlap via nonblocking collectives; a source deems the
    /// communication complete once it has sent all its messages.
    NonBlocking,
    /// Background redistribution with global completion detection:
    /// drains confirm through a nonblocking barrier (§IV-C.2).
    WaitDrains,
    /// Background redistribution on an auxiliary thread (§IV-C.1).
    Threading,
}

impl Strategy {
    pub fn is_background(self) -> bool {
        !matches!(self, Strategy::Blocking)
    }

    /// Figure label suffix ("", "-NB", "-WD", "-T").
    pub fn suffix(self) -> &'static str {
        match self {
            Strategy::Blocking => "",
            Strategy::NonBlocking => "-NB",
            Strategy::WaitDrains => "-WD",
            Strategy::Threading => "-T",
        }
    }

    pub fn all() -> [Strategy; 4] {
        [
            Strategy::Blocking,
            Strategy::NonBlocking,
            Strategy::WaitDrains,
            Strategy::Threading,
        ]
    }

    pub fn parse(s: &str) -> Option<Strategy> {
        match s.to_ascii_lowercase().as_str() {
            "blocking" | "b" => Some(Strategy::Blocking),
            "nonblocking" | "non-blocking" | "nb" => Some(Strategy::NonBlocking),
            "waitdrains" | "wait-drains" | "wd" => Some(Strategy::WaitDrains),
            "threading" | "t" => Some(Strategy::Threading),
            _ => None,
        }
    }
}

/// Is the (method, strategy) pair part of the paper's version set 𝒱?
/// NB × RMA is undefined (§V-A): sources cannot self-detect completion.
pub fn is_valid_version(method: Method, strategy: Strategy) -> bool {
    !(method.is_rma() && strategy == Strategy::NonBlocking)
}

/// Figure label of a version, e.g. "COL-NB", "RMA-Lockall-WD".
pub fn version_label(method: Method, strategy: Strategy) -> String {
    format!("{}{}", method.label(), strategy.suffix())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validity_matrix_matches_paper() {
        // 3 methods × 4 strategies − 2 invalid (RMA×NB) = 10 versions.
        let mut valid = 0;
        for m in Method::all() {
            for s in Strategy::all() {
                if is_valid_version(m, s) {
                    valid += 1;
                }
            }
        }
        assert_eq!(valid, 10);
        assert!(!is_valid_version(Method::RmaLock, Strategy::NonBlocking));
        assert!(!is_valid_version(Method::RmaLockall, Strategy::NonBlocking));
        assert!(is_valid_version(Method::Collective, Strategy::NonBlocking));
    }

    #[test]
    fn labels_match_figures() {
        assert_eq!(version_label(Method::Collective, Strategy::NonBlocking), "COL-NB");
        assert_eq!(version_label(Method::RmaLock, Strategy::Blocking), "RMA-Lock");
        assert_eq!(
            version_label(Method::RmaLockall, Strategy::WaitDrains),
            "RMA-Lockall-WD"
        );
        assert_eq!(version_label(Method::Collective, Strategy::Threading), "COL-T");
    }

    #[test]
    fn parse_roundtrip() {
        for m in Method::all() {
            assert_eq!(Method::parse(m.label()), Some(m));
        }
        assert_eq!(Strategy::parse("wd"), Some(Strategy::WaitDrains));
        assert_eq!(Strategy::parse("nb"), Some(Strategy::NonBlocking));
        assert_eq!(Strategy::parse("nope"), None);
        assert_eq!(Method::parse("rma2"), Some(Method::RmaLockall));
    }
}
