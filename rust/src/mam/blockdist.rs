//! Block data distribution and the paper's **Algorithm 1**.
//!
//! MaM distributes one-dimensional structures in contiguous blocks:
//! rank `r` of `n` owns `[offset, offset+len)` with the remainder
//! spread over the first ranks.  During a reconfiguration the drain
//! side computes, per source, how many elements to read and where they
//! land in the drain buffer — exactly the `counts`/`displs`/
//! `first_source`/`last_source`/`first_index` computation of
//! Algorithm 1 (§IV-B).

/// Contiguous block `[ini, end)` owned by a rank.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Block {
    pub ini: u64,
    pub end: u64,
}

impl Block {
    pub fn len(&self) -> u64 {
        self.end - self.ini
    }

    pub fn is_empty(&self) -> bool {
        self.ini >= self.end
    }
}

/// Block of rank `r` in an `n`-way distribution of `total` elements
/// (`Block_id` in the paper's pseudocode).
pub fn block_of(total: u64, n: usize, r: usize) -> Block {
    assert!(r < n, "rank {r} out of {n}");
    let n64 = n as u64;
    let base = total / n64;
    let rem = total % n64;
    let r64 = r as u64;
    let ini = r64 * base + r64.min(rem);
    let len = base + u64::from(r64 < rem);
    Block { ini, end: ini + len }
}

/// Output of Algorithm 1 for one drain.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DrainPlan {
    /// Elements to read from each source (len = NS).
    pub counts: Vec<u64>,
    /// Destination offsets in the drain buffer (len = NS + 1;
    /// `displs[i+1] = displs[i] + counts[i]`, as in the paper).
    pub displs: Vec<u64>,
    /// First source with a non-empty intersection (`usize::MAX` if the
    /// drain receives nothing — zero-length block).
    pub first_source: usize,
    /// One past the last source with a non-empty intersection.
    pub last_source: usize,
    /// Offset within `first_source`'s block where reading starts.
    pub first_index: u64,
    /// This drain's target block.
    pub block: Block,
}

/// Algorithm 1: communication parameters on the drain side.
///
/// `total` elements move from an `ns`-way to an `nd`-way block
/// distribution; `my_id` is the drain rank.
pub fn drain_plan(total: u64, ns: usize, nd: usize, my_id: usize) -> DrainPlan {
    let block = block_of(total, nd, my_id); // L2
    let mut counts = vec![0u64; ns]; // L3
    let mut displs = vec![0u64; ns + 1]; // L4
    let mut first_source = usize::MAX; // L5
    let mut last_source = ns;
    let mut first_index = 0u64;
    let (ini, end) = (block.ini, block.end);
    let mut stopped_at = ns;
    for i in 0..ns {
        // L6
        let s = block_of(total, ns, i); // L7
        if ini < s.end && end > s.ini {
            // L8: non-empty intersection
            if first_source == usize::MAX {
                // L9
                first_source = i; // L10
                first_index = ini - s.ini; // L11
            }
            let big_ini = ini.max(s.ini); // L13
            let small_end = end.min(s.end); // L14
            counts[i] = small_end - big_ini; // L15
            displs[i + 1] = displs[i] + counts[i]; // L16
        } else {
            displs[i + 1] = displs[i];
            if first_source != usize::MAX {
                // L18
                last_source = i; // L19
                stopped_at = i + 1;
                break; // L20
            }
        }
    }
    // Carry the prefix sum past the early exit so `displs` stays a
    // complete prefix-sum array (counts are all zero beyond the break).
    for k in stopped_at..ns {
        displs[k + 1] = displs[k];
    }
    if first_source == usize::MAX {
        last_source = 0;
        first_index = 0;
    }
    DrainPlan { counts, displs, first_source, last_source, first_index, block }
}

/// Source-side mirror of Algorithm 1 (used by the collective method to
/// build `MPI_Alltoallv` send counts): how many of source `my_id`'s
/// elements go to each drain, and from which local offset.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SourcePlan {
    /// Elements sent to each drain (len = ND).
    pub counts: Vec<u64>,
    /// Local offsets within this source's block (len = ND + 1).
    pub displs: Vec<u64>,
    /// This source's owned block.
    pub block: Block,
}

pub fn source_plan(total: u64, ns: usize, nd: usize, my_id: usize) -> SourcePlan {
    let block = block_of(total, ns, my_id);
    let mut counts = vec![0u64; nd];
    let mut displs = vec![0u64; nd + 1];
    for j in 0..nd {
        let d = block_of(total, nd, j);
        if block.ini < d.end && block.end > d.ini {
            let big_ini = block.ini.max(d.ini);
            let small_end = block.end.min(d.end);
            counts[j] = small_end - big_ini;
        }
        displs[j + 1] = displs[j] + counts[j];
    }
    SourcePlan { counts, displs, block }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite::*;

    #[test]
    fn block_of_even_split() {
        assert_eq!(block_of(100, 4, 0), Block { ini: 0, end: 25 });
        assert_eq!(block_of(100, 4, 3), Block { ini: 75, end: 100 });
    }

    #[test]
    fn block_of_remainder_goes_first() {
        // 10 over 3: 4,3,3
        assert_eq!(block_of(10, 3, 0).len(), 4);
        assert_eq!(block_of(10, 3, 1).len(), 3);
        assert_eq!(block_of(10, 3, 2).len(), 3);
        assert_eq!(block_of(10, 3, 2).end, 10);
    }

    #[test]
    fn blocks_partition_domain() {
        for &(total, n) in &[(100u64, 7usize), (5, 8), (0, 3), (64, 64)] {
            let mut next = 0;
            for r in 0..n {
                let b = block_of(total, n, r);
                assert_eq!(b.ini, next, "gap at rank {r}");
                next = b.end;
            }
            assert_eq!(next, total);
        }
    }

    #[test]
    fn drain_plan_identity_when_sizes_match() {
        // NS == ND: each drain reads exactly its own block from the
        // matching source.
        let p = drain_plan(100, 4, 4, 2);
        assert_eq!(p.first_source, 2);
        assert_eq!(p.last_source, 3);
        assert_eq!(p.first_index, 0);
        assert_eq!(p.counts, vec![0, 0, 25, 0]);
    }

    #[test]
    fn drain_plan_grow_splits_sources() {
        // 100 elems, 2 sources (50 each), 4 drains (25 each).
        // Drain 1 owns [25,50) — entirely within source 0's [0,50).
        let p = drain_plan(100, 2, 4, 1);
        assert_eq!(p.counts, vec![25, 0]);
        assert_eq!(p.first_source, 0);
        assert_eq!(p.first_index, 25);
        // Drain 2 owns [50,75) — within source 1.
        let p = drain_plan(100, 2, 4, 2);
        assert_eq!(p.counts, vec![0, 25]);
        assert_eq!(p.first_source, 1);
        assert_eq!(p.first_index, 0);
    }

    #[test]
    fn drain_plan_shrink_merges_sources() {
        // 100 elems, 4 sources (25 each), 2 drains (50 each).
        let p = drain_plan(100, 4, 2, 0);
        assert_eq!(p.counts, vec![25, 25, 0, 0]);
        assert_eq!(p.first_source, 0);
        assert_eq!(p.last_source, 2);
        assert_eq!(p.displs, vec![0, 25, 50, 50, 50]);
        let p = drain_plan(100, 4, 2, 1);
        assert_eq!(p.counts, vec![0, 0, 25, 25]);
        assert_eq!(p.first_source, 2);
        assert_eq!(p.last_source, 4);
    }

    #[test]
    fn drain_plan_unaligned_boundaries() {
        // 10 elems: 3 sources → 4,3,3 ; 2 drains → 5,5.
        // Drain 0 [0,5): 4 from s0, 1 from s1.
        let p = drain_plan(10, 3, 2, 0);
        assert_eq!(p.counts, vec![4, 1, 0]);
        assert_eq!(p.first_index, 0);
        // Drain 1 [5,10): 2 from s1 (offset 1), 3 from s2.
        let p = drain_plan(10, 3, 2, 1);
        assert_eq!(p.counts, vec![0, 2, 3]);
        assert_eq!(p.first_source, 1);
        assert_eq!(p.first_index, 1); // s1 owns [4,7); drain starts at 5
    }

    #[test]
    fn drain_plan_empty_block() {
        // More drains than elements: trailing drains own nothing.
        let p = drain_plan(2, 1, 4, 3);
        assert!(p.block.is_empty());
        assert_eq!(p.first_source, usize::MAX);
        assert_eq!(p.counts, vec![0]);
    }

    #[test]
    fn source_plan_mirrors_drain_plan() {
        let (total, ns, nd) = (103u64, 5usize, 3usize);
        for s in 0..ns {
            let sp = source_plan(total, ns, nd, s);
            for d in 0..nd {
                let dp = drain_plan(total, ns, nd, d);
                assert_eq!(
                    sp.counts[d], dp.counts[s],
                    "mismatch source {s} drain {d}"
                );
            }
        }
    }

    // ------------------------------------------------------ properties

    #[test]
    fn prop_counts_sum_to_drain_block() {
        check(
            "Σcounts == drain block length",
            usizes(1, 64).pair(usizes(1, 64)).pair(usizes(0, 10_000)),
            |((ns, nd), total)| {
                let total = total as u64;
                (0..nd).all(|d| {
                    let p = drain_plan(total, ns, nd, d);
                    p.counts.iter().sum::<u64>() == p.block.len()
                        && *p.displs.last().unwrap() == p.block.len()
                })
            },
        );
    }

    #[test]
    fn prop_displs_monotone_and_match_counts() {
        check(
            "displs are prefix sums",
            usizes(1, 32).pair(usizes(1, 32)).pair(usizes(1, 5_000)),
            |((ns, nd), total)| {
                let total = total as u64;
                (0..nd).all(|d| {
                    let p = drain_plan(total, ns, nd, d);
                    (0..ns).all(|i| p.displs[i + 1] == p.displs[i] + p.counts[i])
                })
            },
        );
    }

    #[test]
    fn prop_source_range_is_contiguous() {
        // Non-zero counts appear only in [first_source, last_source).
        check(
            "intersecting sources are contiguous",
            usizes(1, 48).pair(usizes(1, 48)).pair(usizes(1, 9_999)),
            |((ns, nd), total)| {
                let total = total as u64;
                (0..nd).all(|d| {
                    let p = drain_plan(total, ns, nd, d);
                    if p.block.is_empty() {
                        return p.counts.iter().all(|&c| c == 0);
                    }
                    p.counts.iter().enumerate().all(|(i, &c)| {
                        let inside = i >= p.first_source && i < p.last_source;
                        (c > 0) == inside
                    })
                })
            },
        );
    }

    #[test]
    fn prop_every_element_moves_exactly_once() {
        // Union of (source, count) over all drains covers each source
        // block exactly once.
        check(
            "conservation of elements",
            usizes(1, 40).pair(usizes(1, 40)).pair(usizes(0, 8_000)),
            |((ns, nd), total)| {
                let total = total as u64;
                let mut per_source = vec![0u64; ns];
                for d in 0..nd {
                    let p = drain_plan(total, ns, nd, d);
                    for i in 0..ns {
                        per_source[i] += p.counts[i];
                    }
                }
                (0..ns).all(|i| per_source[i] == block_of(total, ns, i).len())
            },
        );
    }

    #[test]
    fn prop_first_index_consistent() {
        check(
            "first_index addresses the drain start inside first_source",
            usizes(1, 40).pair(usizes(1, 40)).pair(usizes(1, 8_000)),
            |((ns, nd), total)| {
                let total = total as u64;
                (0..nd).all(|d| {
                    let p = drain_plan(total, ns, nd, d);
                    if p.block.is_empty() || p.first_source == usize::MAX {
                        return true;
                    }
                    let s = block_of(total, ns, p.first_source);
                    s.ini + p.first_index == p.block.ini
                })
            },
        );
    }
}
