//! MaM's spawn-strategy layer: how the `MPI_Comm_spawn` phase of a
//! Merge-based grow is executed and charged in virtual time.
//!
//! The source paper treats process management as a single opaque
//! constant (`spawn_cost`) paid at every grow, and concludes that
//! reconfiguration initialization costs — window registration *and*
//! spawning — are what keeps one-sided redistribution from winning.
//! The parallel-spawning literature (Martín-Álvarez et al.) shows the
//! spawn half of that cost is itself malleable: who launches the new
//! processes, and whether the sources wait for them, changes the curve
//! qualitatively.  This module names those choices:
//!
//! * [`SpawnStrategy::Sequential`] — the paper's model: one opaque
//!   constant, all sources blocked, spawned ranks up atomically.
//!   **Bit-identical** to the pre-subsystem behaviour; the default.
//! * [`SpawnStrategy::Parallel`] — every source rank is a spawn root
//!   launching ⌈(ND−NS)/NS⌉ targets concurrently; sources stay blocked
//!   through the intercomm merge, but the per-process startups overlap
//!   so the phase shortens as NS grows.  Spawned ranks come up at
//!   staggered virtual times, wave by wave, as real `simcluster`
//!   activities.
//! * [`SpawnStrategy::Async`] — the same parallel launch, but sources
//!   resume right after the launch handshake and proceed into the
//!   redistribution: window registration (cold pins) and — under Wait
//!   Drains — the first application iterations overlap the targets'
//!   startup.  With a warm window pool the registration is already
//!   free, so Async is what hides the *remaining* initialization cost
//!   (the spawn) inside the drain window.
//!
//! Policy lives here; the virtual-time decomposition
//! ([`SpawnSchedule`]) lives in [`crate::netmodel::costmodel`], and the
//! staggered execution mechanism in
//! [`MpiProc::spawn_merge_scheduled`].
//!
//! [`MpiProc::spawn_merge_scheduled`]: crate::simmpi::MpiProc::spawn_merge_scheduled

use crate::netmodel::{NetParams, SpawnSchedule};

/// How MaM executes the `MPI_Comm_spawn` + intercomm-merge phase of a
/// grow (`--spawn-strategy`, `"spawn_strategy"` in JSON configs).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum SpawnStrategy {
    /// The paper's single-constant model (seed behaviour; default).
    #[default]
    Sequential,
    /// All sources spawn concurrently; blocked through the merge.
    Parallel,
    /// Parallel launch, but sources resume after initiation and the
    /// targets come up in the background.
    Async,
}

impl SpawnStrategy {
    /// Label used in figures and JSON provenance.
    pub fn label(self) -> &'static str {
        match self {
            SpawnStrategy::Sequential => "sequential",
            SpawnStrategy::Parallel => "parallel",
            SpawnStrategy::Async => "async",
        }
    }

    pub fn all() -> [SpawnStrategy; 3] {
        [SpawnStrategy::Sequential, SpawnStrategy::Parallel, SpawnStrategy::Async]
    }

    /// Parse the CLI/config spelling.
    pub fn parse(s: &str) -> Option<SpawnStrategy> {
        match s.to_ascii_lowercase().as_str() {
            "sequential" | "seq" => Some(SpawnStrategy::Sequential),
            "parallel" | "par" => Some(SpawnStrategy::Parallel),
            "async" | "asynchronous" => Some(SpawnStrategy::Async),
            _ => None,
        }
    }

    /// Build the virtual-time schedule of a grow spawning `n_new`
    /// targets from `ns` sources towards `nd` total ranks.
    /// `sequential_cost` is the legacy opaque constant
    /// (`ReconfigCfg::spawn_cost`), used only by `Sequential`.
    pub fn schedule(
        self,
        p: &NetParams,
        ns: usize,
        n_new: usize,
        nd: usize,
        sequential_cost: f64,
    ) -> SpawnSchedule {
        match self {
            SpawnStrategy::Sequential => SpawnSchedule::atomic(sequential_cost),
            SpawnStrategy::Parallel => SpawnSchedule::parallel(p, ns, n_new, nd),
            SpawnStrategy::Async => SpawnSchedule::asynchronous(p, ns, n_new, nd),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_cli_spellings_and_rejects_junk() {
        assert_eq!(SpawnStrategy::parse("sequential"), Some(SpawnStrategy::Sequential));
        assert_eq!(SpawnStrategy::parse("SEQ"), Some(SpawnStrategy::Sequential));
        assert_eq!(SpawnStrategy::parse("parallel"), Some(SpawnStrategy::Parallel));
        assert_eq!(SpawnStrategy::parse("par"), Some(SpawnStrategy::Parallel));
        assert_eq!(SpawnStrategy::parse("async"), Some(SpawnStrategy::Async));
        assert_eq!(SpawnStrategy::parse("Asynchronous"), Some(SpawnStrategy::Async));
        assert_eq!(SpawnStrategy::parse("fork"), None);
        assert_eq!(SpawnStrategy::parse(""), None);
    }

    #[test]
    fn labels_roundtrip_through_parse() {
        for s in SpawnStrategy::all() {
            assert_eq!(SpawnStrategy::parse(s.label()), Some(s));
        }
    }

    #[test]
    fn default_is_sequential() {
        assert_eq!(SpawnStrategy::default(), SpawnStrategy::Sequential);
    }

    #[test]
    fn sequential_schedule_is_the_opaque_constant() {
        let p = NetParams::test_simple();
        let s = SpawnStrategy::Sequential.schedule(&p, 8, 8, 16, 0.25);
        assert_eq!(s, SpawnSchedule::atomic(0.25));
    }

    #[test]
    fn parallel_and_async_block_less_than_the_constant_on_8_to_16() {
        // The acceptance bar: on a ≥8→16 grow the decomposed strategies
        // must undercut the paper's 0.25 s constant.
        let p = NetParams::sarteco25();
        let seq = SpawnStrategy::Sequential.schedule(&p, 8, 8, 16, 0.25);
        let par = SpawnStrategy::Parallel.schedule(&p, 8, 8, 16, 0.25);
        let asy = SpawnStrategy::Async.schedule(&p, 8, 8, 16, 0.25);
        assert!(par.source_block < seq.source_block, "{par:?}");
        assert!(asy.source_block < par.source_block, "{asy:?}");
        // Async targets are nonetheless fully up before the sequential
        // constant would have elapsed.
        assert!(asy.last_child_up() < seq.source_block);
    }
}
