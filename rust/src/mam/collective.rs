//! The COL redistribution method — `MPI_Alltoallv` over the merged
//! communicator (the baseline of [9], §III).
//!
//! Every rank of the merged communicator participates.  A rank that is
//! a *source* contributes, for each registered structure, the slice of
//! its local block destined to each drain (the source-side mirror of
//! Algorithm 1); all other send entries are empty.  A rank that is a
//! *drain* receives one slice per intersecting source and concatenates
//! them (they arrive in source-rank order, which is ascending global
//! element order under the block scheme).
//!
//! Blocking mode issues one `alltoallv` per structure; background modes
//! issue `ialltoallv` and poll the requests from the application loop
//! (Non-Blocking / Wait Drains) or run the blocking call on an
//! auxiliary thread (Threading).

use crate::simmpi::{CommId, MpiProc, Payload, ReqId};

use super::blockdist::source_plan;
use super::reconfig::Roles;
use super::registry::Registry;

/// Send vector of one structure for one rank: `sends[j]` is the payload
/// destined to merged-comm rank `j` (empty unless this rank is a source
/// and `j` is a drain).
pub fn build_sends(
    roles: &Roles,
    entry_total: u64,
    local: &Payload,
    merged_size: usize,
) -> Vec<Payload> {
    let mut sends: Vec<Payload> = (0..merged_size)
        .map(|_| {
            if local.is_real() {
                Payload::real(Vec::new())
            } else {
                Payload::virt(0)
            }
        })
        .collect();
    if !roles.is_source() {
        return sends;
    }
    let sp = source_plan(entry_total, roles.ns, roles.nd, roles.rank);
    debug_assert_eq!(
        local.elems(),
        sp.block.len(),
        "source local block size mismatch"
    );
    for j in 0..roles.nd {
        if sp.counts[j] > 0 {
            sends[j] = local.slice(sp.displs[j], sp.counts[j]);
        }
    }
    sends
}

/// Assemble a drain's new local block from the alltoallv result
/// (received payloads indexed by merged-comm rank).
pub fn assemble_received(roles: &Roles, entry_total: u64, received: &[Payload]) -> Payload {
    debug_assert!(roles.is_drain());
    let plan = super::blockdist::drain_plan(entry_total, roles.ns, roles.nd, roles.rank);
    if plan.block.is_empty() {
        return if received.iter().any(|p| p.is_real()) {
            Payload::real(Vec::new())
        } else {
            Payload::virt(0)
        };
    }
    let parts: Vec<Payload> = (plan.first_source..plan.last_source)
        .map(|i| received[i].clone())
        .collect();
    let out = Payload::concat(&parts);
    debug_assert_eq!(out.elems(), plan.block.len(), "assembled block size mismatch");
    out
}

/// Blocking COL: one `MPI_Alltoallv` per selected structure (registry
/// indices in `which`).  Returns the drain's new local payloads (one
/// per selected index, in order); `None` entries for non-drain ranks.
pub fn redistribute_blocking(
    proc: &MpiProc,
    merged: CommId,
    roles: &Roles,
    registry: &Registry,
    which: &[usize],
) -> Vec<Option<Payload>> {
    let p = proc.size(merged);
    let mut out = Vec::with_capacity(which.len());
    for &i in which {
        let e = registry.entry(i);
        let sends = build_sends(roles, e.total_elems, &e.local, p);
        let received = proc.alltoallv(merged, sends);
        out.push(if roles.is_drain() {
            Some(assemble_received(roles, e.total_elems, &received))
        } else {
            None
        });
    }
    out
}

/// Start the background COL: one `MPI_Ialltoallv` per selected
/// structure.  The returned requests are polled by `Mam::checkpoint`.
pub fn start_nonblocking(
    proc: &MpiProc,
    merged: CommId,
    roles: &Roles,
    registry: &Registry,
    which: &[usize],
) -> Vec<ReqId> {
    let p = proc.size(merged);
    which
        .iter()
        .map(|&i| {
            let e = registry.entry(i);
            let sends = build_sends(roles, e.total_elems, &e.local, p);
            proc.ialltoallv(merged, sends)
        })
        .collect()
}

/// Collect the results of completed `ialltoallv` requests into the
/// drain's new local payloads.
pub fn collect_nonblocking(
    proc: &MpiProc,
    roles: &Roles,
    registry: &Registry,
    which: &[usize],
    reqs: &[ReqId],
) -> Vec<Option<Payload>> {
    which
        .iter()
        .zip(reqs)
        .map(|(&i, r)| {
            let e = registry.entry(i);
            let received = proc.req_result_alltoallv(*r);
            if roles.is_drain() {
                Some(assemble_received(roles, e.total_elems, &received))
            } else {
                None
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mam::registry::DataKind;
    use crate::netmodel::{NetParams, Topology};
    use crate::simmpi::{MpiSim, WORLD};

    fn roles(ns: usize, nd: usize, rank: usize) -> Roles {
        Roles { ns, nd, rank }
    }

    #[test]
    fn build_sends_source_splits_block() {
        // 100 elems, 2 sources → 4 drains; source 0 owns [0,50).
        let local = Payload::real((0..50).map(|i| i as f64).collect());
        let sends = build_sends(&roles(2, 4, 0), 100, &local, 4);
        assert_eq!(sends[0].elems(), 25);
        assert_eq!(sends[1].elems(), 25);
        assert_eq!(sends[2].elems(), 0);
        assert_eq!(sends[3].elems(), 0);
        assert_eq!(sends[1].as_slice().unwrap()[0], 25.0);
    }

    #[test]
    fn build_sends_non_source_is_empty() {
        // Grow 2→4: ranks 2,3 are drain-only.
        let local = Payload::virt(0);
        let sends = build_sends(&roles(2, 4, 2), 100, &local, 4);
        assert!(sends.iter().all(|s| s.elems() == 0));
    }

    #[test]
    fn assemble_orders_sources() {
        // Shrink 4→2, drain 0 reads sources 0 and 1.
        let received = vec![
            Payload::real(vec![0.0, 1.0]),
            Payload::real(vec![2.0, 3.0]),
            Payload::real(Vec::new()),
            Payload::real(Vec::new()),
        ];
        let out = assemble_received(&roles(4, 2, 0), 8, &received);
        assert_eq!(out.as_slice().unwrap(), &[0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn blocking_roundtrip_identity_data() {
        // 3 sources → 2 drains over real data; verify bitwise blocks.
        let total = 103u64;
        let mut sim = MpiSim::new(Topology::new(1, 4), NetParams::test_simple());
        sim.launch(3, move |p| {
            let r = p.rank(WORLD);
            let ns = 3;
            let nd = 2;
            let b = super::super::blockdist::block_of(total, ns, r);
            let local =
                Payload::real((b.ini..b.end).map(|i| i as f64).collect());
            let mut reg = Registry::new();
            reg.register("A", DataKind::Constant, total, local);
            let roles = Roles { ns, nd, rank: r };
            let out = redistribute_blocking(&p, WORLD, &roles, &reg, &[0]);
            if r < nd {
                let nb = super::super::blockdist::block_of(total, nd, r);
                let got = out[0].as_ref().unwrap().as_slice().unwrap().to_vec();
                let want: Vec<f64> = (nb.ini..nb.end).map(|i| i as f64).collect();
                assert_eq!(got, want, "drain {r} got wrong block");
            } else {
                assert!(out[0].is_none());
            }
        });
        sim.run().unwrap();
    }

    #[test]
    fn nonblocking_start_collect_roundtrip() {
        let total = 64u64;
        let mut sim = MpiSim::new(Topology::new(1, 4), NetParams::test_simple());
        sim.launch(4, move |p| {
            let r = p.rank(WORLD);
            let (ns, nd) = (2usize, 4usize);
            let roles = Roles { ns, nd, rank: r };
            let local = if r < ns {
                let b = super::super::blockdist::block_of(total, ns, r);
                Payload::real((b.ini..b.end).map(|i| i as f64).collect())
            } else {
                Payload::real(Vec::new())
            };
            let mut reg = Registry::new();
            reg.register("A", DataKind::Constant, total, local);
            let reqs = start_nonblocking(&p, WORLD, &roles, &reg, &[0]);
            while !p.req_testall(&reqs) {
                p.compute(1e-4);
            }
            let out = collect_nonblocking(&p, &roles, &reg, &[0], &reqs);
            let nb = super::super::blockdist::block_of(total, nd, r);
            let got = out[0].as_ref().unwrap().as_slice().unwrap().to_vec();
            let want: Vec<f64> = (nb.ini..nb.end).map(|i| i as f64).collect();
            assert_eq!(got, want);
        });
        sim.run().unwrap();
    }
}
