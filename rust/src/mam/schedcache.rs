//! Persistent redistribution schedules (§VI outlook: what persistent
//! collectives do for repeated communication, applied to resizing).
//!
//! Every RMA redistribution between the same pair of sizes moves the
//! same elements along the same edges: the block-distribution targets,
//! the per-drain read lists, the chunked segment layout and the
//! completion plan are all pure functions of
//! `(from_size, to_size, structure, total_elems, chunk)`.  The seed
//! code recomputed them inside every `redistribute_*`/`init_rma*`
//! call; this module extracts them into a first-class
//! [`RedistSchedule`] built once and memoized in a [`SchedCache`], so
//! an oscillating run (20 ↔ 160 ranks) pays the planning, target
//! computation and sync setup once per direction and afterwards only a
//! cheap validation handshake (`NetParams::sched_validate`) per
//! replay.
//!
//! Two caches cooperate:
//!
//! * the **Rust-side memo** here (per `Mam` instance) avoids
//!   recomputing plans — bookkeeping, free in virtual time;
//! * the **virtual-time warmth** lives in the simulated world
//!   (`MpiProc::sched_acquire`): a per-`(rank, key)` pin set that
//!   charges `sched_build + sched_per_target·targets` on first touch
//!   and `sched_validate` on every replay.  It is keyed by *rank
//!   slot*, not process id, so a drain respawned at the same rank on
//!   the next oscillation inherits the warm schedule — schedules, like
//!   persistent collectives, outlive process churn.

use std::collections::BTreeMap;

use super::blockdist::{drain_plan, DrainPlan};

/// Identity of one reusable redistribution schedule.  Everything a
/// schedule contains is a pure function of these five values.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SchedKey {
    /// Source-side size (NS).
    pub from: usize,
    /// Drain-side size (ND).
    pub to: usize,
    /// Structure identity: the entry's pin token
    /// ([`pin_token`](super::winpool::pin_token) of its name).
    pub structure: u64,
    /// Global element count of the structure.
    pub total_elems: u64,
    /// Segment size of the chunked lifecycle (0 = unchunked).
    pub chunk_elems: u64,
}

impl SchedKey {
    /// Stable 64-bit digest (FNV-1a over the fields) — the key of the
    /// simulated world's schedule-pin set.
    pub fn hash64(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for v in [
            self.from as u64,
            self.to as u64,
            self.structure,
            self.total_elems,
            self.chunk_elems,
        ] {
            for b in v.to_le_bytes() {
                h ^= u64::from(b);
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        }
        h
    }
}

/// One precomputed read: drain pulls `count` elements starting at
/// local displacement `disp` of `target`'s exposure into its own
/// buffer at `dest_off`.  Chunked schedules carry one read per touched
/// segment, in exactly the order the seed code posts them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SchedRead {
    pub target: usize,
    pub disp: u64,
    pub count: u64,
    pub dest_off: u64,
}

/// A fully materialized redistribution schedule for one rank: its
/// drain plan (if it drains), its chunk-split read list, and the
/// global sync plan — how many read operations land in every rank's
/// exposure (`expected`, what notified completion arms its counters
/// with) and how many distinct drains touch each source (`fan_in`,
/// what cold-build pricing scales with).
#[derive(Clone, Debug)]
pub struct RedistSchedule {
    pub key: SchedKey,
    /// Rank (in the merged communicator) this schedule was built for.
    pub rank: usize,
    /// Algorithm 1 output for this rank (None for pure sources).
    pub plan: Option<DrainPlan>,
    /// This rank's read list, chunk-split and ordered as posted.
    pub reads: Vec<SchedRead>,
    /// Expected read-op count into each rank's exposure
    /// (len = `max(from, to)`); counts one op per posted Get/Rget,
    /// i.e. per touched segment when chunked.
    pub expected: Vec<u64>,
    /// Number of distinct drains reading from each source
    /// (len = `from`).
    pub fan_in: Vec<u64>,
}

/// Read operations of one drain's `[pos, pos + count)` range into
/// target-segment-aligned pieces of at most `chunk` elements
/// (`chunk = 0` = one whole-range op).  Mirrors the splitting of
/// `mam::rma::for_each_chunk` arithmetically, without enumerating.
pub fn chunk_ops(pos: u64, count: u64, chunk: u64) -> u64 {
    if count == 0 {
        0
    } else if chunk == 0 {
        1
    } else {
        (pos + count - 1) / chunk - pos / chunk + 1
    }
}

impl RedistSchedule {
    /// Build the schedule for `rank` — deterministic, identical on
    /// every rank for the shared parts (`expected`, `fan_in`).
    pub fn build(key: SchedKey, rank: usize) -> RedistSchedule {
        let (ns, nd) = (key.from, key.to);
        let (total, chunk) = (key.total_elems, key.chunk_elems);
        let mut expected = vec![0u64; ns.max(nd)];
        let mut fan_in = vec![0u64; ns];
        for d in 0..nd {
            let dp = drain_plan(total, ns, nd, d);
            let mut pos = dp.first_index;
            for t in dp.first_source..dp.last_source {
                fan_in[t] += 1;
                expected[t] += chunk_ops(pos, dp.counts[t], chunk);
                pos = 0;
            }
        }
        let (plan, reads) = if rank < nd {
            let dp = drain_plan(total, ns, nd, rank);
            let mut reads = Vec::new();
            let mut pos = dp.first_index;
            for t in dp.first_source..dp.last_source {
                if chunk > 0 {
                    super::rma::for_each_chunk(
                        pos,
                        dp.counts[t],
                        dp.displs[t],
                        chunk,
                        |disp, take, off| {
                            reads.push(SchedRead { target: t, disp, count: take, dest_off: off });
                        },
                    );
                } else {
                    reads.push(SchedRead {
                        target: t,
                        disp: pos,
                        count: dp.counts[t],
                        dest_off: dp.displs[t],
                    });
                }
                pos = 0;
            }
            (Some(dp), reads)
        } else {
            (None, Vec::new())
        };
        RedistSchedule { key, rank, plan, reads, expected, fan_in }
    }

    /// Number of distinct targets this rank reads from.
    pub fn n_targets(&self) -> u64 {
        self.plan
            .as_ref()
            .map(|p| p.last_source.saturating_sub(p.first_source) as u64)
            .unwrap_or(0)
    }

    /// Expected read-op count into this rank's own exposure.
    pub fn expected_here(&self) -> u64 {
        self.expected.get(self.rank).copied().unwrap_or(0)
    }

    /// Edge count the cold build is priced over: targets this rank
    /// reads from plus drains that read from it.
    pub fn price_targets(&self) -> u64 {
        self.n_targets() + self.fan_in.get(self.rank).copied().unwrap_or(0)
    }
}

/// Per-process memo of built schedules with hit/miss accounting (the
/// observable the cross-resize pool-investment credit is validated
/// against).
#[derive(Debug, Default)]
pub struct SchedCache {
    map: BTreeMap<SchedKey, RedistSchedule>,
    pub hits: u64,
    pub misses: u64,
}

impl SchedCache {
    pub fn new() -> SchedCache {
        SchedCache::default()
    }

    /// Fetch the schedule for `key`, building it on first use.
    pub fn get_or_build(&mut self, key: SchedKey, rank: usize) -> &RedistSchedule {
        use std::collections::btree_map::Entry;
        match self.map.entry(key) {
            Entry::Occupied(e) => {
                self.hits += 1;
                let s = e.into_mut();
                debug_assert_eq!(s.rank, rank, "schedule cache shared across ranks");
                s
            }
            Entry::Vacant(v) => {
                self.misses += 1;
                v.insert(RedistSchedule::build(key, rank))
            }
        }
    }

    /// Abort-and-rollback poison: drop every memoized schedule with
    /// shape `from → to`, returning the dropped keys' world digests
    /// (sorted) so the caller can also invalidate the simulated
    /// world's rank-slot pins.  A half-dispatched resize must never be
    /// replayed warm — the next occurrence of the shape rebuilds cold.
    pub fn poison(&mut self, from: usize, to: usize) -> Vec<u64> {
        let keys: Vec<SchedKey> = self
            .map
            .keys()
            .filter(|k| k.from == from && k.to == to)
            .copied()
            .collect();
        let mut digests: Vec<u64> = keys.iter().map(|k| k.hash64()).collect();
        digests.sort_unstable();
        for k in &keys {
            self.map.remove(k);
        }
        digests
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn clear(&mut self) {
        self.map.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mam::blockdist::block_of;

    fn key(from: usize, to: usize, total: u64, chunk: u64) -> SchedKey {
        SchedKey { from, to, structure: 0x5EED, total_elems: total, chunk_elems: chunk }
    }

    #[test]
    fn reads_cover_each_drain_block_exactly() {
        for &(ns, nd, total, chunk) in &[
            (2usize, 5usize, 97u64, 0u64),
            (2, 5, 97, 7),
            (6, 2, 103, 5),
            (3, 7, 211, 1),
            (4, 4, 64, 16),
        ] {
            for r in 0..nd {
                let s = RedistSchedule::build(key(ns, nd, total, chunk), r);
                let got: u64 = s.reads.iter().map(|x| x.count).sum();
                assert_eq!(got, block_of(total, nd, r).len(), "{ns}->{nd} rank {r}");
                // Destination offsets tile the drain buffer in order.
                let mut next = 0u64;
                for x in &s.reads {
                    assert_eq!(x.dest_off, next, "{ns}->{nd} rank {r} gap");
                    next += x.count;
                }
            }
        }
    }

    #[test]
    fn expected_matches_sum_of_per_rank_reads() {
        for &(ns, nd, total, chunk) in
            &[(2usize, 5usize, 97u64, 0u64), (2, 5, 97, 16), (6, 2, 103, 64), (7, 3, 211, 5)]
        {
            let shared = RedistSchedule::build(key(ns, nd, total, chunk), 0);
            let mut recount = vec![0u64; ns.max(nd)];
            for r in 0..nd {
                let s = RedistSchedule::build(key(ns, nd, total, chunk), r);
                assert_eq!(s.expected, shared.expected, "expected differs across ranks");
                for x in &s.reads {
                    recount[x.target] += 1;
                }
            }
            assert_eq!(recount, shared.expected, "{ns}->{nd} chunk {chunk}");
        }
    }

    #[test]
    fn chunk_ops_counts_touched_segments() {
        assert_eq!(chunk_ops(0, 10, 0), 1);
        assert_eq!(chunk_ops(5, 0, 4), 0);
        assert_eq!(chunk_ops(0, 10, 10), 1);
        assert_eq!(chunk_ops(0, 11, 10), 2);
        assert_eq!(chunk_ops(9, 2, 10), 2); // straddles one boundary
        assert_eq!(chunk_ops(10, 10, 10), 1); // aligned interior
    }

    #[test]
    fn pure_sources_have_no_reads_but_share_the_sync_plan() {
        // Shrink 6 -> 2: ranks 2..6 are pure sources.
        let s = RedistSchedule::build(key(6, 2, 103, 8), 4);
        assert!(s.plan.is_none());
        assert!(s.reads.is_empty());
        assert_eq!(s.n_targets(), 0);
        assert!(s.expected_here() > 0, "rank 4's exposure is read");
        assert!(s.price_targets() > 0);
    }

    #[test]
    fn poison_drops_only_the_matching_shape_and_forces_a_rebuild() {
        let mut c = SchedCache::new();
        let grow = key(2, 4, 100, 0);
        let shrink = key(4, 2, 100, 0);
        let _ = c.get_or_build(grow, 1);
        let _ = c.get_or_build(shrink, 1);
        assert_eq!((c.hits, c.misses), (0, 2));
        let dropped = c.poison(2, 4);
        assert_eq!(dropped, vec![grow.hash64()]);
        assert_eq!(c.len(), 1, "the other shape survives");
        // The poisoned shape is rebuilt (a miss), not replayed.
        let _ = c.get_or_build(grow, 1);
        assert_eq!((c.hits, c.misses), (0, 3));
        // The surviving shape still replays warm.
        let _ = c.get_or_build(shrink, 1);
        assert_eq!((c.hits, c.misses), (1, 3));
        assert!(c.poison(9, 9).is_empty(), "unknown shape poisons nothing");
    }

    /// Regression for `det::hashmap-iter-escapes`: the cache map is a
    /// `BTreeMap`, so `poison` visits keys in key order and its digest
    /// list is identical regardless of the order schedules were built.
    #[test]
    fn poison_digests_are_insertion_order_independent() {
        let keys =
            [key(2, 4, 100, 0), key(2, 4, 100, 7), key(2, 4, 200, 0), key(4, 2, 100, 0)];
        let mut fwd = SchedCache::new();
        let mut rev = SchedCache::new();
        for &k in &keys {
            let _ = fwd.get_or_build(k, 1);
        }
        for &k in keys.iter().rev() {
            let _ = rev.get_or_build(k, 1);
        }
        let a = fwd.poison(2, 4);
        let b = rev.poison(2, 4);
        assert_eq!(a, b, "poison digests must not depend on build order");
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(a, sorted, "digests come back sorted");
        assert_eq!(fwd.len(), rev.len());
    }

    #[test]
    fn cache_hits_after_first_build() {
        let mut c = SchedCache::new();
        let k = key(2, 4, 100, 0);
        assert_eq!(c.get_or_build(k, 1).reads.len(), 1);
        assert_eq!((c.hits, c.misses), (0, 1));
        let _ = c.get_or_build(k, 1);
        assert_eq!((c.hits, c.misses), (1, 1));
        let _ = c.get_or_build(key(4, 2, 100, 0), 1);
        assert_eq!((c.hits, c.misses), (1, 2));
        assert_eq!(c.len(), 2);
        c.clear();
        assert!(c.is_empty());
    }

    #[test]
    fn key_hashes_are_stable_and_sensitive() {
        let k = key(20, 160, 1_000_000, 4096);
        assert_eq!(k.hash64(), k.hash64());
        assert_ne!(k.hash64(), key(160, 20, 1_000_000, 4096).hash64());
        assert_ne!(k.hash64(), key(20, 160, 1_000_000, 0).hash64());
        let mut other = k;
        other.structure ^= 1;
        assert_ne!(k.hash64(), other.hash64());
    }
}
