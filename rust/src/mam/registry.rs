//! MaM's automatic data-redistribution registry (§III).
//!
//! Applications register their distributed one-dimensional structures
//! once; MaM then redistributes all of them at every reconfiguration
//! without further user involvement (the *Automatic* category of [3]).
//! Entries are classified **constant** (unchanged during execution —
//! transferable in the background) or **variable** (changes every
//! iteration — must be redistributed while the application is
//! blocked), which decides which redistribution strategies are legal
//! per entry.

use crate::simmpi::Payload;

use super::blockdist::{block_of, Block};

/// Constant/variable classification (§III).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DataKind {
    Constant,
    Variable,
}

/// One registered structure.
#[derive(Clone, Debug)]
pub struct DataEntry {
    pub name: String,
    pub kind: DataKind,
    /// Global element count (distributed block-wise).
    pub total_elems: u64,
    /// This rank's current block payload.
    pub local: Payload,
}

impl DataEntry {
    /// Expected local block for rank `r` of `n`.
    pub fn expected_block(&self, n: usize, r: usize) -> Block {
        block_of(self.total_elems, n, r)
    }
}

/// Declaration used when (re)building a registry on spawned drains.
#[derive(Clone, Debug)]
pub struct DataDecl {
    pub name: String,
    pub kind: DataKind,
    pub total_elems: u64,
    /// Real mode? (drains allocate real buffers to receive into).
    pub real: bool,
}

/// The per-rank registry.
#[derive(Clone, Debug, Default)]
pub struct Registry {
    entries: Vec<DataEntry>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Register a structure with this rank's current block.
    pub fn register(&mut self, name: &str, kind: DataKind, total_elems: u64, local: Payload) {
        assert!(
            self.entries.iter().all(|e| e.name != name),
            "duplicate registration of '{name}'"
        );
        self.entries.push(DataEntry {
            name: name.to_string(),
            kind,
            total_elems,
            local,
        });
    }

    /// Build an empty-local registry from declarations (drain side).
    pub fn from_decls(decls: &[DataDecl]) -> Registry {
        let mut r = Registry::new();
        for d in decls {
            let local = if d.real {
                Payload::real(Vec::new())
            } else {
                Payload::virt(0)
            };
            r.register(&d.name, d.kind, d.total_elems, local);
        }
        r
    }

    /// Declarations mirroring this registry (source side → spawn cfg).
    pub fn decls(&self) -> Vec<DataDecl> {
        self.entries
            .iter()
            .map(|e| DataDecl {
                name: e.name.clone(),
                kind: e.kind,
                total_elems: e.total_elems,
                real: e.local.is_real(),
            })
            .collect()
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn entries(&self) -> &[DataEntry] {
        &self.entries
    }

    pub fn entry(&self, i: usize) -> &DataEntry {
        &self.entries[i]
    }

    pub fn entry_mut(&mut self, i: usize) -> &mut DataEntry {
        &mut self.entries[i]
    }

    pub fn by_name(&self, name: &str) -> Option<&DataEntry> {
        self.entries.iter().find(|e| e.name == name)
    }

    /// Indices of entries of a given kind.
    pub fn of_kind(&self, kind: DataKind) -> Vec<usize> {
        self.entries
            .iter()
            .enumerate()
            .filter(|(_, e)| e.kind == kind)
            .map(|(i, _)| i)
            .collect()
    }

    /// Total bytes registered locally (source exposure size).
    pub fn local_bytes(&self) -> u64 {
        self.entries.iter().map(|e| e.local.bytes()).sum()
    }

    /// Verify every entry's local block has the expected length for
    /// rank `r` of `n`; returns offending names.
    pub fn verify_blocks(&self, n: usize, r: usize) -> Vec<String> {
        self.entries
            .iter()
            .filter(|e| e.local.elems() != e.expected_block(n, r).len())
            .map(|e| e.name.clone())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_lookup() {
        let mut r = Registry::new();
        r.register("A", DataKind::Constant, 1000, Payload::virt(250));
        r.register("x", DataKind::Variable, 100, Payload::virt(25));
        assert_eq!(r.len(), 2);
        assert_eq!(r.by_name("A").unwrap().total_elems, 1000);
        assert!(r.by_name("missing").is_none());
        assert_eq!(r.of_kind(DataKind::Constant), vec![0]);
        assert_eq!(r.of_kind(DataKind::Variable), vec![1]);
        assert_eq!(r.local_bytes(), (250 + 25) * 8);
    }

    #[test]
    #[should_panic(expected = "duplicate registration")]
    fn duplicate_name_panics() {
        let mut r = Registry::new();
        r.register("A", DataKind::Constant, 10, Payload::virt(5));
        r.register("A", DataKind::Constant, 10, Payload::virt(5));
    }

    #[test]
    fn decls_roundtrip() {
        let mut r = Registry::new();
        r.register("A", DataKind::Constant, 1000, Payload::real(vec![0.0; 250]));
        r.register("b", DataKind::Variable, 40, Payload::virt(10));
        let decls = r.decls();
        let drain = Registry::from_decls(&decls);
        assert_eq!(drain.len(), 2);
        assert!(drain.entry(0).local.is_real());
        assert_eq!(drain.entry(0).local.elems(), 0);
        assert!(!drain.entry(1).local.is_real());
        assert_eq!(drain.by_name("b").unwrap().kind, DataKind::Variable);
    }

    #[test]
    fn verify_blocks_flags_wrong_sizes() {
        let mut r = Registry::new();
        r.register("ok", DataKind::Constant, 100, Payload::virt(25));
        r.register("bad", DataKind::Constant, 100, Payload::virt(7));
        let bad = r.verify_blocks(4, 0);
        assert_eq!(bad, vec!["bad".to_string()]);
    }
}
