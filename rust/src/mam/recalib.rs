//! Online recalibration of the planner's `NetParams` (ROADMAP item 1).
//!
//! PRs 1–5 plan every reconfiguration against seed-calibrated constants
//! (`NetParams::sarteco25`) that are never updated, yet every resize
//! already measures the inputs needed to fix them: the `rma.reg_bytes`
//! / `rma.reg_time` counters expose the *actual* registration
//! throughput, and the reconfiguration/spawn spans expose the actual
//! wire and `MPI_Comm_spawn` costs.  [`Recalibrator`] closes that loop:
//! after each resize the scenario harness feeds it one [`Observation`]
//! and the next resize is planned against the updated belief.
//!
//! Three parameter groups are learned, each behind its own
//! [`TermGate`] (confidence + freeze threshold, so one noisy resize
//! cannot wreck the model):
//!
//! * **β_register** — directly observable as `reg_time / reg_bytes`
//!   whenever the chosen method registered windows.
//! * **spawn terms** (`spawn_launch`, `spawn_per_proc`, `merge_round`)
//!   — the decomposed `MPI_Comm_spawn` model is affine in these with
//!   known coefficients (`1`, `waves`, `merge rounds`), so a windowed
//!   ridge least-squares over the observed spawn blocks recovers them.
//! * **β_inter** — the residual span error after removing the spawn
//!   and registration residuals is ≈ affine in β_inter with slope
//!   given by the bottleneck node's serialized inter-node bytes
//!   ([`crate::netmodel::costmodel::wire_slope`]); a trust-region
//!   Newton step converges geometrically even with the slope
//!   misestimated by ~2×.
//!
//! The same measured registration throughput also drives per-structure
//! adaptive chunk sizing ([`Recalibrator::chunk_kib_for`]), replacing
//! the static `rma_chunk_kib` ablation sweep: the pipelined-registration
//! sweet spot balances the per-chunk `win_setup` overhead against the
//! exposure of the first (unoverlapped) chunk, giving the classic
//! square-root rule `c* = sqrt(bytes · win_setup / β_reg)`.

use std::collections::BTreeMap;

use crate::netmodel::calibration::NetParams;

/// Tuning knobs of the estimator.  The defaults are what the drift
/// scenarios and the RMS closed loop use.
#[derive(Clone, Debug)]
pub struct RecalibCfg {
    /// Number of initial (trust-phase) observations per term during
    /// which proposals are accepted as full steps (clamped by
    /// `step_clamp`) instead of EWMA-blended.
    pub min_obs: usize,
    /// EWMA blend factor once a term has left its trust phase.
    pub ewma: f64,
    /// Relative deviation beyond which a post-trust proposal is
    /// rejected as an outlier (the freeze threshold).
    pub freeze: f64,
    /// Number of consecutive *agreeing* outliers accepted as a regime
    /// change (the network really did shift).
    pub regime_hits: usize,
    /// Per-step multiplicative trust region: a single update can move
    /// a term by at most this factor (and at least its inverse).
    pub step_clamp: f64,
    /// Max spawn observations retained for the ridge solve.
    pub spawn_window: usize,
}

impl Default for RecalibCfg {
    fn default() -> Self {
        RecalibCfg {
            min_obs: 3,
            ewma: 0.5,
            freeze: 0.5,
            regime_hits: 2,
            step_clamp: 4.0,
            spawn_window: 8,
        }
    }
}

/// One resize's worth of evidence, fed to [`Recalibrator::observe`].
///
/// All span fields are *virtual-time* seconds taken from the DES
/// metrics of the resize (identical on every rank, so feeding one
/// recalibrator per rank keeps the planner rank-independent).
#[derive(Clone, Debug)]
pub struct Observation {
    /// Source / destination process counts of the resize.
    pub ns: usize,
    pub nd: usize,
    /// Observed reconfiguration span (`mam.reconf_start..reconf_end`).
    pub reconf: f64,
    /// What the belief predicted for that span when the resize was
    /// planned (probe or analytic — same model either way).
    pub predicted: f64,
    /// Observed spawn block (`mam.reconf_start..redist_start`); 0 for
    /// shrinks.
    pub spawn_block: f64,
    /// The belief's prediction of `spawn_block`.
    pub predicted_spawn_block: f64,
    /// Coefficients of the decomposed spawn model for the strategy the
    /// resize actually used: `Some((waves, merge_rounds))` for
    /// Parallel, `Some((0, 0))` for Async (its source block is the bare
    /// launch handshake), `None` for Sequential / shrinks (the atomic
    /// 0.25 s constant is a `ReconfigCfg` field, not a `NetParam` —
    /// nothing to learn).
    pub spawn_waves: Option<(f64, f64)>,
    /// Delta of the `rma.reg_bytes` / `rma.reg_time` counters across
    /// the resize (0 for COL — no registration evidence).
    pub reg_bytes: f64,
    pub reg_secs: f64,
    /// d(span)/d(β_inter) estimate for this resize's shape
    /// ([`crate::netmodel::costmodel::wire_slope`]); ≤ 0 disables the
    /// β_inter update for this observation.
    pub wire_slope: f64,
}

/// Per-term confidence gate: trust phase → EWMA with freeze threshold
/// → regime-change override.
#[derive(Clone, Debug, Default)]
struct TermGate {
    /// Accepted updates so far.
    n: usize,
    /// Consecutive rejected proposals.
    reject_streak: usize,
    /// The first rejected proposal of the current streak.
    held: f64,
}

impl TermGate {
    /// Feed one proposal; returns the new belief for the term.
    fn apply(&mut self, cfg: &RecalibCfg, current: f64, proposal: f64) -> f64 {
        if !proposal.is_finite() || proposal <= 0.0 {
            return current;
        }
        let clamp = |v: f64| v.clamp(current / cfg.step_clamp, current * cfg.step_clamp);
        if self.n < cfg.min_obs {
            // Trust phase: full (clamped) steps while evidence is thin.
            self.n += 1;
            self.reject_streak = 0;
            return clamp(proposal);
        }
        let dev = (proposal - current).abs() / current.abs().max(1e-300);
        if dev <= cfg.freeze {
            self.n += 1;
            self.reject_streak = 0;
            return current + cfg.ewma * (proposal - current);
        }
        // Outlier.  A lone one is frozen out; `regime_hits` consecutive
        // *agreeing* outliers are accepted as a genuine regime change.
        let agrees = self.reject_streak > 0
            && (proposal - self.held).abs() / self.held.abs().max(1e-300) <= cfg.freeze;
        if agrees {
            self.reject_streak += 1;
            if self.reject_streak >= cfg.regime_hits {
                self.n += 1;
                self.reject_streak = 0;
                return proposal; // confirmed regime: jump, no clamp
            }
        } else {
            self.reject_streak = 1;
            self.held = proposal;
        }
        current
    }
}

/// The online estimator: owns the live `NetParams` belief plus the
/// per-structure adaptive chunk hints.
#[derive(Clone, Debug)]
pub struct Recalibrator {
    cfg: RecalibCfg,
    params: NetParams,
    gate_reg: TermGate,
    gate_inter: TermGate,
    gate_launch: TermGate,
    gate_spp: TermGate,
    gate_merge: TermGate,
    /// Ring of spawn evidence rows: coefficients (1, waves, rounds)
    /// against the observed spawn block.
    spawn_rows: Vec<([f64; 3], f64)>,
    /// Per-observation |observed − predicted| / observed trajectory.
    errs: Vec<f64>,
    /// Per-structure adaptive chunk choices (KiB), persisted across
    /// resizes like the window pool itself.
    chunk_hints: BTreeMap<String, u64>,
}

impl Recalibrator {
    pub fn new(seed: NetParams) -> Recalibrator {
        Recalibrator::with_cfg(seed, RecalibCfg::default())
    }

    pub fn with_cfg(seed: NetParams, cfg: RecalibCfg) -> Recalibrator {
        Recalibrator {
            cfg,
            params: seed,
            gate_reg: TermGate::default(),
            gate_inter: TermGate::default(),
            gate_launch: TermGate::default(),
            gate_spp: TermGate::default(),
            gate_merge: TermGate::default(),
            spawn_rows: Vec::new(),
            errs: Vec::new(),
            chunk_hints: BTreeMap::new(),
        }
    }

    /// The live belief.
    pub fn params(&self) -> &NetParams {
        &self.params
    }

    /// Predicted-vs-observed relative error per observation, in order.
    pub fn rel_err_history(&self) -> &[f64] {
        &self.errs
    }

    /// First 1-based observation index from which every relative error
    /// (including later ones) stays below `tol`; `None` if the latest
    /// error is still at or above `tol`.
    pub fn converge_at(&self, tol: f64) -> Option<usize> {
        if self.errs.is_empty() {
            return None;
        }
        let mut idx = None;
        for (i, e) in self.errs.iter().enumerate() {
            if *e < tol {
                if idx.is_none() {
                    idx = Some(i + 1);
                }
            } else {
                idx = None;
            }
        }
        idx
    }

    /// Digest one resize's evidence into the belief.
    pub fn observe(&mut self, obs: &Observation) {
        if obs.reconf > 0.0 && obs.predicted.is_finite() {
            self.errs.push((obs.reconf - obs.predicted).abs() / obs.reconf);
        }

        // --- β_register: directly observable throughput.  The secs
        // counter includes the per-window/segment `win_setup`, a
        // ≤ ~1% bias at the MB-scale exposures we care about.
        let reg_before = self.params.beta_register;
        if obs.reg_bytes > 0.0 && obs.reg_secs > 0.0 {
            let proposal = obs.reg_secs / obs.reg_bytes;
            self.params.beta_register =
                self.gate_reg.apply(&self.cfg, self.params.beta_register, proposal);
        }
        let reg_moved =
            (self.params.beta_register - reg_before).abs() / reg_before.abs().max(1e-300);

        // --- Spawn terms: windowed ridge least-squares on the affine
        // model  block = launch + waves·per_proc + rounds·merge_round.
        if let Some((waves, rounds)) = obs.spawn_waves {
            if obs.spawn_block > 0.0 {
                if self.spawn_rows.len() >= self.cfg.spawn_window {
                    self.spawn_rows.remove(0);
                }
                self.spawn_rows.push(([1.0, waves, rounds], obs.spawn_block));
                let x0 = [
                    self.params.spawn_launch,
                    self.params.spawn_per_proc,
                    self.params.merge_round,
                ];
                if let Some(x) = ridge_solve(&self.spawn_rows, x0) {
                    let cl = |v: f64| v.clamp(1e-6, 10.0);
                    self.params.spawn_launch =
                        self.gate_launch.apply(&self.cfg, x0[0], cl(x[0]));
                    self.params.spawn_per_proc =
                        self.gate_spp.apply(&self.cfg, x0[1], cl(x[1]));
                    self.params.merge_round =
                        self.gate_merge.apply(&self.cfg, x0[2], cl(x[2]));
                }
            }
        }

        // --- β_inter: trust-region Newton on the wire residual.
        // Staged learning: while β_register is still moving (> 20% this
        // step) its share of the span residual is unreliable, so the
        // wire update waits a round rather than chase it.
        if obs.wire_slope > 0.0 && reg_moved <= 0.2 {
            let spawn_resid = obs.spawn_block - obs.predicted_spawn_block;
            let reg_resid = if obs.reg_bytes > 0.0 {
                obs.reg_secs - obs.reg_bytes * reg_before
            } else {
                0.0
            };
            let wire_resid = (obs.reconf - obs.predicted) - spawn_resid - reg_resid;
            if wire_resid.is_finite() {
                let cur = self.params.beta_inter;
                let proposal =
                    (cur + wire_resid / obs.wire_slope).max(cur / self.cfg.step_clamp);
                self.params.beta_inter = self.gate_inter.apply(&self.cfg, cur, proposal);
            }
        }
    }

    /// Adaptive pipelined-registration chunk for a structure whose
    /// per-source exposure is `src_bytes`, from the *measured*
    /// registration throughput: `c* = sqrt(bytes · win_setup / β_reg)`
    /// balances per-chunk `win_setup` against first-chunk exposure.
    /// Returns a power-of-two KiB in `[64, 16384]`, or 0 (unchunked)
    /// when the exposure would not span even two chunks.
    pub fn chunk_kib_for(&self, src_bytes: u64) -> u64 {
        if src_bytes == 0 || self.params.beta_register <= 0.0 {
            return 0;
        }
        let c = (src_bytes as f64 * self.params.win_setup / self.params.beta_register).sqrt();
        let kib = (c / 1024.0).max(1.0);
        // Round to the nearest power of two, then clamp to the range
        // the chunked lifecycle was validated over (PR 4/5 ablations).
        let pow2 = 2f64.powf(kib.log2().round());
        let kib = (pow2 as u64).clamp(64, 16 * 1024);
        if src_bytes <= 2 * kib * 1024 {
            0
        } else {
            kib
        }
    }

    /// Compute-and-persist: the hint survives across resizes alongside
    /// the window pool, so later resizes of the same structure reuse it.
    pub fn note_chunk(&mut self, name: &str, src_bytes: u64) -> u64 {
        let kib = self.chunk_kib_for(src_bytes);
        self.chunk_hints.insert(name.to_string(), kib);
        kib
    }

    /// The persisted per-structure chunk hints (KiB; 0 = unchunked).
    pub fn chunk_hints(&self) -> &BTreeMap<String, u64> {
        &self.chunk_hints
    }

    /// Distinct non-zero chunk hints, for injection into the planner's
    /// candidate enumeration ([`crate::mam::PlannerInputs`]'s
    /// `extra_chunks_kib`).
    pub fn chunk_candidates(&self) -> Vec<u64> {
        let mut v: Vec<u64> = self.chunk_hints.values().copied().filter(|k| *k > 0).collect();
        v.sort_unstable();
        v.dedup();
        v
    }
}

/// Solve `min ‖A x − b‖² + λ‖x − x0‖²` for the 3-term spawn model.
/// The tiny ridge pins the under-determined directions to the current
/// belief (min-deviation fit) while leaving the determined directions
/// essentially exact.
fn ridge_solve(rows: &[([f64; 3], f64)], x0: [f64; 3]) -> Option<[f64; 3]> {
    let mut ata = [[0.0f64; 3]; 3];
    let mut atb = [0.0f64; 3];
    for (a, b) in rows {
        for i in 0..3 {
            for j in 0..3 {
                ata[i][j] += a[i] * a[j];
            }
            atb[i] += a[i] * b;
        }
    }
    let trace = ata[0][0] + ata[1][1] + ata[2][2];
    let lambda = 1e-6 * (1.0 + trace / 3.0);
    for i in 0..3 {
        ata[i][i] += lambda;
        atb[i] += lambda * x0[i];
    }
    solve3(ata, atb)
}

/// Gaussian elimination with partial pivoting for a 3×3 system.
fn solve3(mut m: [[f64; 3]; 3], mut b: [f64; 3]) -> Option<[f64; 3]> {
    for col in 0..3 {
        let piv = (col..3).max_by(|i, j| {
            m[*i][col].abs().partial_cmp(&m[*j][col].abs()).unwrap()
        })?;
        if m[piv][col].abs() < 1e-300 {
            return None;
        }
        m.swap(col, piv);
        b.swap(col, piv);
        for row in col + 1..3 {
            let f = m[row][col] / m[col][col];
            for k in col..3 {
                m[row][k] -= f * m[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = [0.0f64; 3];
    for col in (0..3).rev() {
        let mut s = b[col];
        for k in col + 1..3 {
            s -= m[col][k] * x[k];
        }
        x[col] = s / m[col][col];
    }
    Some(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg_obs(bytes: f64, secs: f64) -> Observation {
        Observation {
            ns: 4,
            nd: 16,
            reconf: 1.0,
            predicted: 1.0,
            spawn_block: 0.0,
            predicted_spawn_block: 0.0,
            spawn_waves: None,
            reg_bytes: bytes,
            reg_secs: secs,
            wire_slope: 0.0,
        }
    }

    #[test]
    fn beta_register_recovers_in_one_observation() {
        let mut r = Recalibrator::new(NetParams::test_simple());
        // 1 GB registered in 2 s → β̂ = 2e-9 (seed was 1e-9): a 2×
        // trust-phase step lands exactly on the measurement.
        r.observe(&reg_obs(1e9, 2.0));
        let b = r.params().beta_register;
        assert!((b - 2e-9).abs() / 2e-9 < 1e-12, "b={b}");
    }

    #[test]
    fn spawn_terms_solve_exactly_from_three_shapes() {
        let mut r = Recalibrator::new(NetParams::test_simple());
        let (launch, spp, mr) = (0.16, 0.036, 2.0e-3);
        let shapes: [(f64, f64); 3] = [(7.0, 4.0), (3.0, 4.0), (0.0, 0.0)];
        // Two sweeps: the first may clamp individual components while
        // evidence accumulates, the second (rows now span the space)
        // settles every gate on the exact fit.
        for _ in 0..2 {
            for (w, rounds) in shapes {
                let block = launch + w * spp + rounds * mr;
                let mut o = reg_obs(0.0, 0.0);
                o.spawn_block = block;
                o.predicted_spawn_block = block;
                o.spawn_waves = Some((w, rounds));
                r.observe(&o);
            }
        }
        let p = r.params();
        assert!((p.spawn_launch - launch).abs() / launch < 0.01, "{}", p.spawn_launch);
        assert!((p.spawn_per_proc - spp).abs() / spp < 0.01, "{}", p.spawn_per_proc);
        assert!((p.merge_round - mr).abs() / mr < 0.01, "{}", p.merge_round);
    }

    #[test]
    fn freeze_blocks_one_outlier_but_two_agreeing_shift_the_regime() {
        let mut r = Recalibrator::new(NetParams::test_simple());
        // Leave the trust phase with consistent observations.
        for _ in 0..3 {
            r.observe(&reg_obs(1e9, 1.0)); // β̂ = 1e-9 = seed
        }
        let settled = r.params().beta_register;
        // One 10× outlier: frozen out, belief bit-unchanged.
        r.observe(&reg_obs(1e9, 10.0));
        assert_eq!(r.params().beta_register.to_bits(), settled.to_bits());
        // A second agreeing outlier: genuine regime change, accepted.
        r.observe(&reg_obs(1e9, 10.0));
        let b = r.params().beta_register;
        assert!((b - 1e-8).abs() / 1e-8 < 1e-12, "b={b}");
    }

    #[test]
    fn beta_inter_newton_step_is_trust_clamped() {
        let mut r = Recalibrator::new(NetParams::test_simple());
        let seed = r.params().beta_inter;
        // Residual implies a 100× jump; the trust region caps it at 4×.
        let mut o = reg_obs(0.0, 0.0);
        o.reconf = 2.0;
        o.predicted = 1.0;
        o.wire_slope = 1.0 / (99.0 * seed); // proposal = 100 × seed
        r.observe(&o);
        let b = r.params().beta_inter;
        assert!((b - 4.0 * seed).abs() / seed < 1e-9, "b={b}");
    }

    #[test]
    fn chunk_rule_scales_with_measured_throughput() {
        let r = Recalibrator::new(NetParams::sarteco25());
        // sarteco25: sqrt(256 MiB · 30 µs · 3.7 GB/s) ≈ 5.5 MB → 4 MiB.
        let big = r.chunk_kib_for(256 * 1024 * 1024);
        assert!((64..=16 * 1024).contains(&big), "big={big}");
        assert!(big.is_power_of_two());
        // 8× slower registration shrinks the sweet spot.
        let mut slow = Recalibrator::new(NetParams::sarteco25());
        slow.params.beta_register *= 8.0;
        let s = slow.chunk_kib_for(256 * 1024 * 1024);
        assert!(s <= big, "s={s} big={big}");
        // Tiny exposures stay unchunked.
        assert_eq!(r.chunk_kib_for(8 * 1024), 0);
    }

    #[test]
    fn chunk_hints_persist_per_structure() {
        let mut r = Recalibrator::new(NetParams::sarteco25());
        let a = r.note_chunk("xs", 256 * 1024 * 1024);
        let b = r.note_chunk("idx", 4 * 1024);
        assert_eq!(r.chunk_hints().get("xs"), Some(&a));
        assert_eq!(r.chunk_hints().get("idx"), Some(&b));
        assert_eq!(b, 0);
        assert_eq!(r.chunk_candidates(), vec![a]);
    }

    #[test]
    fn converge_at_requires_staying_below_tol() {
        let mut r = Recalibrator::new(NetParams::test_simple());
        for (obs, pred) in [(1.0, 0.5), (1.0, 0.9), (1.0, 1.3), (1.0, 0.95), (1.0, 1.01)] {
            let mut o = reg_obs(0.0, 0.0);
            o.reconf = obs;
            o.predicted = pred;
            r.observe(&o);
        }
        // errs = [0.5, 0.1, 0.3, 0.05, 0.01] → stays < 0.15 from #4.
        assert_eq!(r.converge_at(0.15), Some(4));
        assert_eq!(r.converge_at(0.6), Some(1));
        assert_eq!(r.converge_at(0.02), Some(5));
        assert_eq!(r.converge_at(0.005), None);
    }
}
