//! The RMA redistribution methods (§IV-B, §IV-C).
//!
//! * **RMA-Lock** (Algorithm 2): for each accessed source the drain
//!   opens a shared passive epoch (`Win_lock` + `MPI_MODE_NOCHECK`),
//!   posts its read, and later closes every epoch (`Win_unlock`).
//! * **RMA-Lockall** (Algorithm 3): a single epoch over all targets
//!   (`Win_lock_all` … `Win_unlock_all`).
//!
//! One dedicated window per registered data structure (§IV-B: exposing
//! several structures in one window complicates offset management).
//! Sources expose their local block; every other rank exposes an empty
//! buffer (`NULL`, Alg. 2 L3).  `Win_create` is collective and charges
//! the memory-registration cost of the exposed bytes — the overhead the
//! paper identifies as dominant (§V-B, §VI).
//!
//! For background redistribution the algorithms are split in two (§IV-C):
//! [`init_rma`] creates the windows and posts the reads as `Rget`s, and
//! the completion protocol (local `MPI_Testall`, global `MPI_Ibarrier`,
//! local frees) is driven by [`reconfig`](super::reconfig).

use crate::simmpi::{
    recv_buf_real, recv_buf_virtual, CommId, MpiProc, Payload, RecvBuf, ReqId, RmaSync, WinId,
};

use super::blockdist::{drain_plan, DrainPlan};
use super::reconfig::Roles;
use super::registry::Registry;
use super::schedcache::{RedistSchedule, SchedCache, SchedKey};
use super::winpool::{self, WinPoolPolicy};

/// Per-entry read bookkeeping on the drain side.
#[derive(Debug)]
pub struct DrainReads {
    pub plan: DrainPlan,
    pub buf: RecvBuf,
    pub real: bool,
}

impl DrainReads {
    /// Materialize the received block as a payload.
    pub fn into_payload(self) -> Payload {
        if self.real {
            let data = self.buf.lock().unwrap().take().expect("buffer vanished");
            debug_assert_eq!(data.len() as u64, self.plan.block.len());
            Payload::real(data)
        } else {
            Payload::virt(self.plan.block.len())
        }
    }
}

/// Per-redistribution knobs of the chunked RMA lifecycle pipeline
/// (`--rma-chunk`): segment size plus which halves of the window
/// lifecycle ride in the background.  `chunk_elems = 0` is the seed
/// unchunked path regardless of the other flags.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LifecycleOpts {
    /// Segment size in elements (0 = unchunked, the seed path).
    pub chunk_elems: u64,
    /// Pipelined deregistration (`--rma-dereg on`, the default for
    /// chunked runs): pool-off frees deregister per segment as the
    /// last reads land.  `false` reproduces the registration-only
    /// pipeline (the pre-teardown behaviour), bit for bit.
    pub dereg_pipeline: bool,
    /// Spawn-overlapped registration: background streams start at each
    /// rank's own fill end (set for chunked grows under
    /// `--spawn-strategy async`; `false` everywhere else).
    pub eager_reg: bool,
}

impl LifecycleOpts {
    /// The registration-only pipeline of a given chunk size (teardown
    /// blocking, streams starting at the collective exit).
    pub fn reg_only(chunk_elems: u64) -> LifecycleOpts {
        LifecycleOpts { chunk_elems, dereg_pipeline: false, eager_reg: false }
    }

    /// The full lifecycle pipeline of a given chunk size.
    pub fn full(chunk_elems: u64) -> LifecycleOpts {
        LifecycleOpts { chunk_elems, dereg_pipeline: true, eager_reg: false }
    }
}

/// State carried between `Init_RMA` and `Complete_RMA` (§IV-C).
pub struct RmaInit {
    /// One window per registry entry (all ranks).
    pub wins: Vec<WinId>,
    /// Outstanding `Rget` requests (drains; empty for source-only).
    pub reqs: Vec<ReqId>,
    /// Read bookkeeping per entry (drains; `None` for source-only).
    pub reads: Vec<Option<DrainReads>>,
    /// Epochs to close once reads complete: (window index, lockall?,
    /// first_source, last_source).
    pub epochs: Vec<(usize, bool, usize, usize)>,
    /// Window-pool policy the windows were acquired under — the frees
    /// in `Complete_RMA` must match it (§VI window pool).
    pub policy: WinPoolPolicy,
    /// Lifecycle pipeline the windows were opened under — the local
    /// frees in `Complete_RMA` mirror its teardown half.
    pub lifecycle: LifecycleOpts,
    /// Sync mode the reads were posted under: `Notify` leaves `epochs`
    /// empty and `Complete_RMA` gates teardown on per-segment notify
    /// counts instead of the confirmation barrier.
    pub sync: RmaSync,
    /// Total read operations this rank posted (the notified-completion
    /// flag charge at `Complete_RMA`; 0 for source-only ranks).
    pub n_reads: u64,
}

/// Wrap an already-computed drain plan (fresh or from a cached
/// schedule) with its receive buffer.
fn drain_reads(plan: DrainPlan, real: bool) -> DrainReads {
    let buf = if real {
        recv_buf_real(plan.block.len() as usize)
    } else {
        recv_buf_virtual()
    };
    DrainReads { plan, buf, real }
}

/// Allocate the drain-side receive buffer for one entry (Algorithm 1
/// also allocates the per-structure memory for each drain).
fn alloc_drain(total: u64, roles: &Roles, real: bool) -> DrainReads {
    drain_reads(drain_plan(total, roles.ns, roles.nd, roles.rank), real)
}

/// Post one drain's reads for one entry using blocking `Get`s
/// (Algorithms 2/3 L11-L15).  Epochs are assumed open.
fn post_gets(proc: &MpiProc, win: WinId, reads: &DrainReads) {
    let plan = &reads.plan;
    let mut first_index = plan.first_index;
    for i in plan.first_source..plan.last_source {
        proc.get(win, i, first_index, plan.counts[i], &reads.buf, plan.displs[i]);
        first_index = 0; // only the first window needs the offset (§IV-B)
    }
}

/// Post one drain's reads for one entry as `Rget`s (§IV-C background
/// path); returns the requests.
fn post_rgets(proc: &MpiProc, win: WinId, reads: &DrainReads) -> Vec<ReqId> {
    let plan = &reads.plan;
    let mut first_index = plan.first_index;
    let mut reqs = Vec::new();
    for i in plan.first_source..plan.last_source {
        reqs.push(proc.rget(win, i, first_index, plan.counts[i], &reads.buf, plan.displs[i]));
        first_index = 0;
    }
    reqs
}

/// Split one drain's read of `[pos, pos + count)` (target-local
/// elements) into per-segment sub-reads of `chunk` elements, invoking
/// `read(disp, take, dest_off)` once per touched segment.  Segment
/// boundaries are aligned to the target's exposure, so each sub-read
/// gates on exactly one segment of the registration stream — segment
/// `k+1` registers while segment `k`'s read is in flight, and reads
/// complete out of order per segment.
pub(crate) fn for_each_chunk(
    pos: u64,
    count: u64,
    dest_off: u64,
    chunk: u64,
    mut read: impl FnMut(u64, u64, u64),
) {
    debug_assert!(chunk > 0);
    let end = pos + count;
    let mut cur = pos;
    let mut dst = dest_off;
    while cur < end {
        let seg_end = (cur / chunk + 1) * chunk;
        let take = end.min(seg_end) - cur;
        read(cur, take, dst);
        cur += take;
        dst += take;
    }
}

/// Chunked variant of [`post_gets`]: one blocking `Get` per touched
/// segment of each accessed source.
fn post_gets_chunked(proc: &MpiProc, win: WinId, reads: &DrainReads, chunk: u64) {
    let plan = &reads.plan;
    let mut first_index = plan.first_index;
    for i in plan.first_source..plan.last_source {
        for_each_chunk(first_index, plan.counts[i], plan.displs[i], chunk, |disp, take, off| {
            proc.get(win, i, disp, take, &reads.buf, off);
        });
        first_index = 0;
    }
}

/// Chunked variant of [`post_rgets`]: one `Rget` per touched segment.
fn post_rgets_chunked(proc: &MpiProc, win: WinId, reads: &DrainReads, chunk: u64) -> Vec<ReqId> {
    let plan = &reads.plan;
    let mut first_index = plan.first_index;
    let mut reqs = Vec::new();
    for i in plan.first_source..plan.last_source {
        for_each_chunk(first_index, plan.counts[i], plan.displs[i], chunk, |disp, take, off| {
            reqs.push(proc.rget(win, i, disp, take, &reads.buf, off));
        });
        first_index = 0;
    }
    reqs
}

/// Build (or fetch from `cache`) the persistent schedule of entry `i`
/// for this resize.  Pure Rust-side bookkeeping — the simulated cost
/// of cold builds vs warm replays is charged separately through
/// `MpiProc::sched_acquire`.
fn schedule_for(
    roles: &Roles,
    registry: &Registry,
    i: usize,
    chunk_elems: u64,
    cache: Option<&mut SchedCache>,
) -> RedistSchedule {
    let e = registry.entry(i);
    let key = SchedKey {
        from: roles.ns,
        to: roles.nd,
        structure: winpool::pin_token(&e.name),
        total_elems: e.total_elems,
        chunk_elems,
    };
    match cache {
        Some(c) => c.get_or_build(key, roles.rank).clone(),
        None => RedistSchedule::build(key, roles.rank),
    }
}

/// Post one drain's reads for one entry from its precomputed schedule
/// (blocking `Get`s) — the same operations in the same order as
/// [`post_gets`]/[`post_gets_chunked`], without replanning.
fn post_sched_gets(proc: &MpiProc, win: WinId, sd: &RedistSchedule, reads: &DrainReads) {
    for r in &sd.reads {
        proc.get(win, r.target, r.disp, r.count, &reads.buf, r.dest_off);
    }
}

/// Schedule-driven `Rget` posting; returns the requests.
fn post_sched_rgets(
    proc: &MpiProc,
    win: WinId,
    sd: &RedistSchedule,
    reads: &DrainReads,
) -> Vec<ReqId> {
    sd.reads
        .iter()
        .map(|r| proc.rget(win, r.target, r.disp, r.count, &reads.buf, r.dest_off))
        .collect()
}

/// Options for the unified RMA redistribution entrypoints
/// ([`redistribute_with`] / [`init_rma_with`]) — the single knob set
/// the old `redistribute{_blocking,_pipelined,_lifecycle}` /
/// `init_rma{,_lifecycle}` sprawl spread over five signatures.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RedistOpts {
    /// Epoch style: one epoch over all targets (Algorithm 3,
    /// `Win_lock_all`) vs one per accessed target (Algorithm 2,
    /// `Win_lock`).
    pub lockall: bool,
    /// Window-pool policy (§VI) the windows are acquired — and must
    /// later be freed — under.
    pub policy: WinPoolPolicy,
    /// Chunked lifecycle pipeline (`--rma-chunk`); the default
    /// (`chunk_elems = 0`) is the seed unchunked path, bit for bit.
    pub lifecycle: LifecycleOpts,
    /// Completion-synchronization mode (`--rma-sync`): passive-target
    /// epochs + collective teardown (the default, bit-identical to the
    /// pre-schedule paths) or notified completion — per-segment
    /// notification counters, request-based drains, local teardown.
    pub sync: RmaSync,
    /// Route planning through the persistent-schedule machinery
    /// (`--sched-cache on`): charge the cold schedule build on first
    /// touch of a `(from, to, structure, chunk)` shape and only a
    /// validation handshake on every replay.  Off charges nothing —
    /// the seed recompute-every-time behaviour, bit for bit.
    pub sched: bool,
}

impl RedistOpts {
    /// Blocking redistribution under `policy`, seed lifecycle.
    pub fn new(lockall: bool, policy: WinPoolPolicy) -> RedistOpts {
        RedistOpts {
            lockall,
            policy,
            lifecycle: LifecycleOpts::default(),
            sync: RmaSync::Epoch,
            sched: false,
        }
    }

    /// Attach a chunked lifecycle pipeline.
    pub fn lifecycle(mut self, lifecycle: LifecycleOpts) -> RedistOpts {
        self.lifecycle = lifecycle;
        self
    }

    /// Select the completion-synchronization mode (`--rma-sync`).
    pub fn sync(mut self, sync: RmaSync) -> RedistOpts {
        self.sync = sync;
        self
    }

    /// Enable the persistent-schedule cache (`--sched-cache`).
    pub fn sched(mut self, sched: bool) -> RedistOpts {
        self.sched = sched;
        self
    }
}

/// Unified blocking RMA redistribution — Algorithm 2
/// (`opts.lockall = false`) or Algorithm 3 (`opts.lockall = true`),
/// including the final collective close.  `opts.lifecycle` selects the
/// chunked registration/deregistration pipeline (§VI):
/// `chunk_elems > 0` registers each window in segments — only the
/// first gates the collective `Win_create`, later segments register
/// while earlier segments' `Get`s are already on the wire, each drain
/// posts one `Get` per touched segment, `dereg_pipeline` unpins
/// segments as their last reads land, and `eager_reg` starts streams
/// at each rank's own fill end.  With the window pool, warm segments
/// skip registration entirely.  Returns the drain's new local payloads
/// (one per selected entry, in order; `None` for non-drain ranks).
pub fn redistribute_with(
    proc: &MpiProc,
    merged: CommId,
    roles: &Roles,
    registry: &Registry,
    which: &[usize],
    opts: RedistOpts,
) -> Vec<Option<Payload>> {
    redistribute_rma(proc, merged, roles, registry, which, opts, None)
}

/// [`redistribute_with`] backed by a persistent-schedule cache: plans
/// built for a `(from, to, structure, chunk)` shape are memoized
/// across resizes, and the simulated job replays warm schedules for
/// only a validation handshake (`--sched-cache on`).
pub fn redistribute_sched(
    proc: &MpiProc,
    merged: CommId,
    roles: &Roles,
    registry: &Registry,
    which: &[usize],
    opts: RedistOpts,
    cache: &mut SchedCache,
) -> Vec<Option<Payload>> {
    redistribute_rma(proc, merged, roles, registry, which, opts, Some(cache))
}

/// Blocking RMA redistribution (seed lifecycle).
#[deprecated(note = "use redistribute_with(.., RedistOpts::new(lockall, policy))")]
pub fn redistribute_blocking(
    proc: &MpiProc,
    merged: CommId,
    roles: &Roles,
    registry: &Registry,
    which: &[usize],
    lockall: bool,
    policy: WinPoolPolicy,
) -> Vec<Option<Payload>> {
    redistribute_with(proc, merged, roles, registry, which, RedistOpts::new(lockall, policy))
}

/// Chunked pipelined RMA redistribution (registration pipeline only).
#[deprecated(
    note = "use redistribute_with(.., RedistOpts::new(lockall, policy).lifecycle(LifecycleOpts::reg_only(chunk_elems)))"
)]
#[allow(clippy::too_many_arguments)]
pub fn redistribute_pipelined(
    proc: &MpiProc,
    merged: CommId,
    roles: &Roles,
    registry: &Registry,
    which: &[usize],
    lockall: bool,
    policy: WinPoolPolicy,
    chunk_elems: u64,
) -> Vec<Option<Payload>> {
    redistribute_with(
        proc,
        merged,
        roles,
        registry,
        which,
        RedistOpts::new(lockall, policy).lifecycle(LifecycleOpts::reg_only(chunk_elems)),
    )
}

/// Full-lifecycle chunked RMA redistribution.
#[deprecated(
    note = "use redistribute_with(.., RedistOpts::new(lockall, policy).lifecycle(opts))"
)]
#[allow(clippy::too_many_arguments)]
pub fn redistribute_lifecycle(
    proc: &MpiProc,
    merged: CommId,
    roles: &Roles,
    registry: &Registry,
    which: &[usize],
    lockall: bool,
    policy: WinPoolPolicy,
    opts: LifecycleOpts,
) -> Vec<Option<Payload>> {
    redistribute_with(
        proc,
        merged,
        roles,
        registry,
        which,
        RedistOpts::new(lockall, policy).lifecycle(opts),
    )
}

/// The one blocking RMA redistribution loop behind the entry points:
/// window acquisition, epochs and reads are identical — only the read
/// posting (whole-range vs per-segment) and the window-create flavour
/// switch on `lifecycle.chunk_elems`.
fn redistribute_rma(
    proc: &MpiProc,
    merged: CommId,
    roles: &Roles,
    registry: &Registry,
    which: &[usize],
    opts: RedistOpts,
    mut cache: Option<&mut SchedCache>,
) -> Vec<Option<Payload>> {
    let RedistOpts { lockall, policy, lifecycle, sync, sched } = opts;
    let chunk_elems = lifecycle.chunk_elems;
    let notify = sync == RmaSync::Notify;
    let create = crate::simmpi::WinCreateOpts::pipelined(chunk_elems).eager(lifecycle.eager_reg);
    let wins: Vec<WinId> = which
        .iter()
        .map(|&i| winpool::acquire_entry_window_with(proc, merged, roles, registry, i, policy, create))
        .collect();
    let mut out: Vec<Option<Payload>> = Vec::with_capacity(which.len());
    for (&i, win) in which.iter().zip(&wins) {
        let e = registry.entry(i);
        // Persistent schedule: cold builds charge planning, warm
        // replays only the validation handshake.  Notified sync always
        // materializes the schedule — its sync plan arms the counters.
        let sd = if sched || notify {
            let sd = schedule_for(roles, registry, i, chunk_elems, cache.as_deref_mut());
            if sched {
                proc.sched_acquire(merged, sd.key.hash64(), sd.price_targets());
            }
            if notify {
                proc.win_arm_notify(*win, sd.expected_here());
            }
            Some(sd)
        } else {
            None
        };
        if roles.is_drain() {
            let reads = match &sd {
                Some(s) => drain_reads(s.plan.clone().expect("drain without plan"), e.local.is_real()),
                None => alloc_drain(e.total_elems, roles, e.local.is_real()),
            };
            if notify {
                // Notified completion: no epochs.  Post the reads as
                // Rgets, wait on the requests, and charge the per-op
                // notification flags riding the data packets.
                let s = sd.as_ref().expect("notify without schedule");
                let reqs = post_sched_rgets(proc, *win, s, &reads);
                proc.req_waitall(&reqs);
                proc.rma_notify_charge(reqs.len() as u64);
            } else {
                let read = |proc: &MpiProc| match &sd {
                    Some(s) => post_sched_gets(proc, *win, s, &reads),
                    None if chunk_elems > 0 => post_gets_chunked(proc, *win, &reads, chunk_elems),
                    None => post_gets(proc, *win, &reads),
                };
                let plan = &reads.plan;
                if lockall {
                    // Algorithm 3: one epoch for everything.
                    proc.win_lock_all(*win);
                    read(proc);
                    proc.win_unlock_all(*win);
                } else {
                    // Algorithm 2: one epoch per accessed target.
                    for t in plan.first_source..plan.last_source {
                        proc.win_lock(*win, t);
                    }
                    read(proc);
                    for t in plan.first_source..plan.last_source {
                        proc.win_unlock(*win, t);
                    }
                }
            }
            out.push(Some(reads.into_payload()));
        } else {
            // Source-only ranks just create and free their window
            // (Alg. 2 L21-L23) — no epochs, no reads.
            out.push(None);
        }
    }
    if notify {
        // Notified teardown: each rank leaves as soon as its own
        // exposure's expected read count is reached — no closing
        // collective at all.
        winpool::close_windows_notified(proc, &wins, policy);
    } else {
        winpool::close_windows_with(
            proc,
            &wins,
            policy,
            winpool::CloseOpts::collective().pipelined(chunk_elems > 0 && lifecycle.dereg_pipeline),
        );
    }
    out
}

/// The paper's §VI future-work variant: a **single window** per rank
/// exposing every selected structure back to back (the "one dynamic
/// window with all memory attached" fix for the window-initialization
/// overhead).  One collective create + one collective free amortize
/// the per-window setup and synchronization across the k structures;
/// the registration bytes are unchanged — which is exactly what the
/// ablation measures.
pub fn redistribute_blocking_fused(
    proc: &MpiProc,
    merged: CommId,
    roles: &Roles,
    registry: &Registry,
    which: &[usize],
    lockall: bool,
) -> Vec<Option<Payload>> {
    // Expose one concatenated payload (sources) or nothing.
    let exposure = if roles.is_source() {
        let parts: Vec<Payload> = which.iter().map(|&i| registry.entry(i).local.clone()).collect();
        Payload::concat(&parts)
    } else if which.iter().any(|&i| registry.entry(i).local.is_real()) {
        Payload::real(Vec::new())
    } else {
        Payload::virt(0)
    };
    let win = proc.win_create_with(merged, exposure, crate::simmpi::WinCreateOpts::blocking());
    let mut out: Vec<Option<Payload>> = Vec::with_capacity(which.len());
    if roles.is_drain() {
        // Base offset of entry k inside *target*'s exposure = total of
        // the preceding entries' local blocks at that target.
        let base_of = |target: usize, upto: usize| -> u64 {
            which[..upto]
                .iter()
                .map(|&i| {
                    super::blockdist::block_of(registry.entry(i).total_elems, roles.ns, target)
                        .len()
                })
                .sum()
        };
        let mut all_reads = Vec::with_capacity(which.len());
        for (k, &i) in which.iter().enumerate() {
            let e = registry.entry(i);
            let reads = alloc_drain(e.total_elems, roles, e.local.is_real());
            let plan = reads.plan.clone();
            if !lockall {
                for t in plan.first_source..plan.last_source {
                    proc.win_lock(win, t);
                }
            } else if k == 0 {
                proc.win_lock_all(win);
            }
            let mut first_index = plan.first_index;
            for t in plan.first_source..plan.last_source {
                let disp = base_of(t, k) + first_index;
                proc.get(win, t, disp, plan.counts[t], &reads.buf, plan.displs[t]);
                first_index = 0;
            }
            if !lockall {
                for t in plan.first_source..plan.last_source {
                    proc.win_unlock(win, t);
                }
            }
            all_reads.push(reads);
        }
        if lockall {
            proc.win_unlock_all(win);
        }
        for reads in all_reads {
            out.push(Some(reads.into_payload()));
        }
    } else {
        for _ in which {
            out.push(None);
        }
    }
    proc.win_free(win);
    out
}

/// Unified `Init_RMA` (§IV-C, Fig. 1): per selected structure,
/// collectively create its window and — on drains — immediately open
/// the epoch and post the reads as `Rget`s before moving to the next
/// structure.  Interleaving reads with the successive window creations
/// is the behaviour the paper observes ("some reads are also started
/// during this creation […] many of them are already completed by the
/// time all windows are created", §V-C).  `opts.lifecycle` selects the
/// chunked pipeline exactly as in [`redistribute_with`]: spawn-
/// overlapped registration streams at init time, one `Rget` per
/// touched segment, pipelined deregistration at the `Complete_RMA`
/// local frees (`chunk_elems = 0` = the seed path, bit for bit).
/// Returns the in-flight state for `Complete_RMA`.
pub fn init_rma_with(
    proc: &MpiProc,
    merged: CommId,
    roles: &Roles,
    registry: &Registry,
    which: &[usize],
    opts: RedistOpts,
) -> RmaInit {
    init_rma_impl(proc, merged, roles, registry, which, opts, None)
}

/// [`init_rma_with`] backed by a persistent-schedule cache (the
/// background-redistribution counterpart of [`redistribute_sched`]).
pub fn init_rma_sched(
    proc: &MpiProc,
    merged: CommId,
    roles: &Roles,
    registry: &Registry,
    which: &[usize],
    opts: RedistOpts,
    cache: &mut SchedCache,
) -> RmaInit {
    init_rma_impl(proc, merged, roles, registry, which, opts, Some(cache))
}

fn init_rma_impl(
    proc: &MpiProc,
    merged: CommId,
    roles: &Roles,
    registry: &Registry,
    which: &[usize],
    opts: RedistOpts,
    mut cache: Option<&mut SchedCache>,
) -> RmaInit {
    let RedistOpts { lockall, policy, lifecycle, sync, sched } = opts;
    let chunk_elems = lifecycle.chunk_elems;
    let notify = sync == RmaSync::Notify;
    let create = crate::simmpi::WinCreateOpts::pipelined(chunk_elems).eager(lifecycle.eager_reg);
    let mut wins = Vec::with_capacity(which.len());
    let mut reqs = Vec::new();
    let mut reads = Vec::with_capacity(which.len());
    let mut epochs = Vec::new();
    let mut n_reads = 0u64;
    for (k, &i) in which.iter().enumerate() {
        let e = registry.entry(i);
        let win =
            winpool::acquire_entry_window_with(proc, merged, roles, registry, i, policy, create);
        wins.push(win);
        // Schedule + notify arming, as in the blocking path.
        let sd = if sched || notify {
            let sd = schedule_for(roles, registry, i, chunk_elems, cache.as_deref_mut());
            if sched {
                proc.sched_acquire(merged, sd.key.hash64(), sd.price_targets());
            }
            if notify {
                proc.win_arm_notify(win, sd.expected_here());
            }
            Some(sd)
        } else {
            None
        };
        if roles.is_drain() {
            let dr = match &sd {
                Some(s) => drain_reads(s.plan.clone().expect("drain without plan"), e.local.is_real()),
                None => alloc_drain(e.total_elems, roles, e.local.is_real()),
            };
            if notify {
                // Notified sync: Rgets without epochs; teardown gates
                // on the windows' notification counters instead.
                let s = sd.as_ref().expect("notify without schedule");
                let posted = post_sched_rgets(proc, win, s, &dr);
                n_reads += posted.len() as u64;
                reqs.extend(posted);
            } else {
                let plan = &dr.plan;
                if lockall {
                    proc.win_lock_all(win);
                } else {
                    for t in plan.first_source..plan.last_source {
                        proc.win_lock(win, t);
                    }
                }
                match &sd {
                    Some(s) => reqs.extend(post_sched_rgets(proc, win, s, &dr)),
                    None if chunk_elems > 0 => {
                        reqs.extend(post_rgets_chunked(proc, win, &dr, chunk_elems))
                    }
                    None => reqs.extend(post_rgets(proc, win, &dr)),
                }
                epochs.push((k, lockall, plan.first_source, plan.last_source));
            }
            reads.push(Some(dr));
        } else {
            reads.push(None);
        }
    }
    RmaInit { wins, reqs, reads, epochs, policy, lifecycle, sync, n_reads }
}

/// `Init_RMA` (registration pipeline only).
#[deprecated(
    note = "use init_rma_with(.., RedistOpts::new(lockall, policy).lifecycle(LifecycleOpts::reg_only(chunk_elems)))"
)]
#[allow(clippy::too_many_arguments)]
pub fn init_rma(
    proc: &MpiProc,
    merged: CommId,
    roles: &Roles,
    registry: &Registry,
    which: &[usize],
    lockall: bool,
    policy: WinPoolPolicy,
    chunk_elems: u64,
) -> RmaInit {
    init_rma_with(
        proc,
        merged,
        roles,
        registry,
        which,
        RedistOpts::new(lockall, policy).lifecycle(LifecycleOpts::reg_only(chunk_elems)),
    )
}

/// `Init_RMA` under a full [`LifecycleOpts`].
#[deprecated(note = "use init_rma_with(.., RedistOpts::new(lockall, policy).lifecycle(opts))")]
#[allow(clippy::too_many_arguments)]
pub fn init_rma_lifecycle(
    proc: &MpiProc,
    merged: CommId,
    roles: &Roles,
    registry: &Registry,
    which: &[usize],
    lockall: bool,
    policy: WinPoolPolicy,
    opts: LifecycleOpts,
) -> RmaInit {
    init_rma_with(proc, merged, roles, registry, which, RedistOpts::new(lockall, policy).lifecycle(opts))
}

/// Close the epochs opened by [`init_rma`] (called once the drain's
/// `Rget`s have completed — the unlocks are then cheap bookkeeping,
/// the paper's motivation for replacing `Get` with `Rget`, §IV-C).
pub fn close_epochs(proc: &MpiProc, init: &RmaInit) {
    for &(k, lockall, first, last) in &init.epochs {
        let win = init.wins[k];
        if lockall {
            proc.win_unlock_all(win);
        } else {
            for i in first..last {
                proc.win_unlock(win, i);
            }
        }
    }
}

/// Free every window locally (Wait-Drains path: the global barrier has
/// already synchronized, §IV-C).  Pool-acquired windows are released
/// back to the pool instead of deregistered; under the lifecycle
/// pipeline, pool-off frees charge only the dereg stream's residual
/// (segments have been unpinning since their last reads landed).
pub fn free_windows_local(proc: &MpiProc, init: &RmaInit) {
    if init.sync == RmaSync::Notify {
        // Notified teardown: gate on per-segment notify counts, not on
        // the (never-issued) confirmation barrier.
        winpool::close_windows_notified(proc, &init.wins, init.policy);
        return;
    }
    let piped = init.lifecycle.chunk_elems > 0 && init.lifecycle.dereg_pipeline;
    winpool::close_windows_with(
        proc,
        &init.wins,
        init.policy,
        winpool::CloseOpts::local_only().pipelined(piped),
    );
}

/// Are all of this rank's notified-teardown gates open?  (Poll used by
/// the Wait-Drains driver before the local frees; epoch-mode inits are
/// trivially ready — their gate is the confirmation barrier.)
pub fn notify_all_ready(proc: &MpiProc, init: &RmaInit) -> bool {
    init.sync != RmaSync::Notify || init.wins.iter().all(|w| proc.win_notify_ready(*w))
}

/// Turn completed drain reads into the new local payloads.
pub fn take_payloads(init: &mut RmaInit) -> Vec<Option<Payload>> {
    init.reads
        .iter_mut()
        .map(|r| r.take().map(DrainReads::into_payload))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mam::registry::DataKind;
    use crate::netmodel::{NetParams, Topology};
    use crate::simmpi::{MpiSim, WORLD};

    fn run_blocking(ns: usize, nd: usize, total: u64, lockall: bool) {
        let mut sim = MpiSim::new(Topology::new(2, 4), NetParams::test_simple());
        let p_count = ns.max(nd);
        sim.launch(p_count, move |p| {
            let r = p.rank(WORLD);
            let roles = Roles { ns, nd, rank: r };
            let local = if roles.is_source() {
                let b = super::super::blockdist::block_of(total, ns, r);
                Payload::real((b.ini..b.end).map(|i| i as f64).collect())
            } else {
                Payload::real(Vec::new())
            };
            let mut reg = Registry::new();
            reg.register("A", DataKind::Constant, total, local);
            let out =
                redistribute_with(&p, WORLD, &roles, &reg, &[0], RedistOpts::new(lockall, WinPoolPolicy::off()));
            if roles.is_drain() {
                let nb = super::super::blockdist::block_of(total, nd, r);
                let got = out[0].as_ref().unwrap().as_slice().unwrap().to_vec();
                let want: Vec<f64> = (nb.ini..nb.end).map(|i| i as f64).collect();
                assert_eq!(got, want, "drain {r} wrong block ({ns}->{nd})");
            } else {
                assert!(out[0].is_none());
            }
        });
        sim.run().unwrap();
    }

    #[test]
    fn blocking_lock_grow() {
        run_blocking(2, 5, 97, false);
    }

    #[test]
    fn blocking_lock_shrink() {
        run_blocking(6, 2, 103, false);
    }

    #[test]
    fn blocking_lockall_grow() {
        run_blocking(3, 7, 211, true);
    }

    #[test]
    fn blocking_lockall_shrink() {
        run_blocking(7, 3, 211, true);
    }

    #[test]
    fn blocking_same_size_is_local() {
        run_blocking(4, 4, 64, false);
        run_blocking(4, 4, 64, true);
    }

    #[test]
    fn init_rma_then_manual_completion() {
        // Drive the §IV-C split by hand: init, poll rgets, close, free.
        let total = 60u64;
        let mut sim = MpiSim::new(Topology::new(1, 4), NetParams::test_simple());
        sim.launch(3, move |p| {
            let r = p.rank(WORLD);
            let (ns, nd) = (2usize, 3usize);
            let roles = Roles { ns, nd, rank: r };
            let local = if roles.is_source() {
                let b = super::super::blockdist::block_of(total, ns, r);
                Payload::real((b.ini..b.end).map(|i| i as f64).collect())
            } else {
                Payload::real(Vec::new())
            };
            let mut reg = Registry::new();
            reg.register("A", DataKind::Constant, total, local);
            let mut init =
                init_rma_with(&p, WORLD, &roles, &reg, &[0], RedistOpts::new(false, WinPoolPolicy::off()));
            // Everyone is a drain here (nd=3 covers all ranks).
            while !p.req_testall(&init.reqs) {
                p.compute(1e-4);
            }
            close_epochs(&p, &init);
            let req = p.ibarrier(WORLD);
            while !p.req_test(req) {
                p.compute(1e-4);
            }
            free_windows_local(&p, &init);
            let out = take_payloads(&mut init);
            let nb = super::super::blockdist::block_of(total, nd, r);
            let got = out[0].as_ref().unwrap().as_slice().unwrap().to_vec();
            let want: Vec<f64> = (nb.ini..nb.end).map(|i| i as f64).collect();
            assert_eq!(got, want);
        });
        sim.run().unwrap();
    }

    #[test]
    fn pooled_rerun_is_warm_and_preserves_payloads() {
        // Two identical blocking RMA redistributions in one world: with
        // the pool on, the second run's acquires are all warm (zero
        // registration charged) and the payloads are byte-identical.
        let total = 97u64;
        let (ns, nd) = (2usize, 4usize);
        let mut sim = MpiSim::new(Topology::new(2, 4), NetParams::test_simple());
        sim.launch(4, move |p| {
            let r = p.rank(WORLD);
            let roles = Roles { ns, nd, rank: r };
            let local = if roles.is_source() {
                let b = super::super::blockdist::block_of(total, ns, r);
                Payload::real((b.ini..b.end).map(|i| i as f64).collect())
            } else {
                Payload::real(Vec::new())
            };
            let mut reg = Registry::new();
            reg.register("A", DataKind::Constant, total, local);
            let pool = WinPoolPolicy::on();
            let t0 = p.now();
            let first = redistribute_with(&p, WORLD, &roles, &reg, &[0], RedistOpts::new(true, pool));
            let cold_dt = p.now() - t0;
            let s1 = p.win_pool_stats();
            let t1 = p.now();
            let second = redistribute_with(&p, WORLD, &roles, &reg, &[0], RedistOpts::new(true, pool));
            let warm_dt = p.now() - t1;
            let s2 = p.win_pool_stats();
            assert_eq!(s2.cold_acquires, s1.cold_acquires, "second run must be all-warm");
            assert!(s2.warm_acquires > s1.warm_acquires);
            assert!(
                (s2.cold_reg_time - s1.cold_reg_time).abs() < 1e-15,
                "warm run charged registration time"
            );
            assert!(warm_dt < cold_dt, "warm={warm_dt} cold={cold_dt}");
            let nb = super::super::blockdist::block_of(total, nd, r);
            let want: Vec<f64> = (nb.ini..nb.end).map(|i| i as f64).collect();
            for out in [&first, &second] {
                let got = out[0].as_ref().unwrap().as_slice().unwrap().to_vec();
                assert_eq!(got, want, "drain {r} wrong block");
            }
        });
        sim.run().unwrap();
    }

    fn run_pipelined(ns: usize, nd: usize, total: u64, lockall: bool, chunk: u64) {
        let mut sim = MpiSim::new(Topology::new(2, 4), NetParams::test_simple());
        let p_count = ns.max(nd);
        sim.launch(p_count, move |p| {
            let r = p.rank(WORLD);
            let roles = Roles { ns, nd, rank: r };
            let local = if roles.is_source() {
                let b = super::super::blockdist::block_of(total, ns, r);
                Payload::real((b.ini..b.end).map(|i| i as f64).collect())
            } else {
                Payload::real(Vec::new())
            };
            let mut reg = Registry::new();
            reg.register("A", DataKind::Constant, total, local);
            let out = redistribute_with(
                &p,
                WORLD,
                &roles,
                &reg,
                &[0],
                RedistOpts::new(lockall, WinPoolPolicy::off())
                    .lifecycle(LifecycleOpts::reg_only(chunk)),
            );
            if roles.is_drain() {
                let nb = super::super::blockdist::block_of(total, nd, r);
                let got = out[0].as_ref().unwrap().as_slice().unwrap().to_vec();
                let want: Vec<f64> = (nb.ini..nb.end).map(|i| i as f64).collect();
                assert_eq!(got, want, "drain {r} wrong block ({ns}->{nd}, chunk {chunk})");
            } else {
                assert!(out[0].is_none());
            }
        });
        sim.run().unwrap();
    }

    #[test]
    fn pipelined_payloads_match_blocking_across_shapes() {
        // The chunked path must be a byte-identical repartition for
        // grow and shrink, both epoch styles, chunk sizes that divide
        // the blocks evenly and ones that straddle them.
        run_pipelined(2, 5, 97, false, 7);
        run_pipelined(2, 5, 97, true, 16);
        run_pipelined(6, 2, 103, true, 5);
        run_pipelined(6, 2, 103, false, 64);
        run_pipelined(3, 7, 211, true, 1);
    }

    #[test]
    fn pipelined_chunk_zero_is_bit_identical_to_blocking() {
        // chunk = 0 must route through redistribute_blocking — same
        // virtual end time, bit for bit.
        fn end_time(chunked: bool) -> f64 {
            let total = 50_000u64;
            let (ns, nd) = (3usize, 6usize);
            let mut sim = MpiSim::new(Topology::new(2, 4), NetParams::test_simple());
            sim.launch(6, move |p| {
                let r = p.rank(WORLD);
                let roles = Roles { ns, nd, rank: r };
                let b = super::super::blockdist::block_of(total, ns, r);
                let local = if roles.is_source() {
                    Payload::virt(b.len())
                } else {
                    Payload::virt(0)
                };
                let mut reg = Registry::new();
                reg.register("A", DataKind::Constant, total, local);
                let _ = if chunked {
                    redistribute_with(
                        &p,
                        WORLD,
                        &roles,
                        &reg,
                        &[0],
                        RedistOpts::new(true, WinPoolPolicy::off())
                            .lifecycle(LifecycleOpts::reg_only(0)),
                    )
                } else {
                    redistribute_with(
                        &p,
                        WORLD,
                        &roles,
                        &reg,
                        &[0],
                        RedistOpts::new(true, WinPoolPolicy::off()),
                    )
                };
            });
            sim.run().unwrap()
        }
        assert_eq!(end_time(false).to_bits(), end_time(true).to_bits());
    }

    #[test]
    fn pipelined_pooled_rerun_is_warm_and_streamless() {
        // Pool on: the first pipelined pass registers (cold, chunked);
        // register-on-receive style re-pins are the caller's job here,
        // so re-pin manually and verify the second pass is all-warm.
        let total = 40_000u64;
        let (ns, nd) = (2usize, 4usize);
        let mut sim = MpiSim::new(Topology::new(2, 4), NetParams::test_simple());
        sim.launch(4, move |p| {
            let r = p.rank(WORLD);
            let roles = Roles { ns, nd, rank: r };
            let b = super::super::blockdist::block_of(total, ns, r);
            let local = if roles.is_source() { Payload::virt(b.len()) } else { Payload::virt(0) };
            let mut reg = Registry::new();
            reg.register("A", DataKind::Constant, total, local);
            let pool = WinPoolPolicy::on();
            let chunk = 1000u64;
            let first = redistribute_with(
                &p,
                WORLD,
                &roles,
                &reg,
                &[0],
                RedistOpts::new(true, pool).lifecycle(LifecycleOpts::reg_only(chunk)),
            );
            let s1 = p.win_pool_stats();
            // Install the received block and pre-pin it (what
            // Mam::apply_locals does), so the re-exposure is warm.
            if let Some(new_local) = first.into_iter().next().flatten() {
                reg.entry_mut(0).local = new_local;
            }
            let roles2 = Roles { ns: nd, nd: ns, rank: r };
            p.pin_buffer(
                super::super::winpool::pin_token("A"),
                reg.entry(0).local.bytes(),
                0,
            );
            let _ = redistribute_with(
                &p,
                WORLD,
                &roles2,
                &reg,
                &[0],
                RedistOpts::new(true, pool).lifecycle(LifecycleOpts::reg_only(chunk)),
            );
            let s2 = p.win_pool_stats();
            assert!(
                s2.cold_acquires == s1.cold_acquires,
                "warm pipelined rerun went cold: {s2:?}"
            );
        });
        sim.run().unwrap();
    }

    fn run_notify(ns: usize, nd: usize, total: u64, chunk: u64, pool: bool) {
        let mut sim = MpiSim::new(Topology::new(2, 4), NetParams::test_simple());
        let p_count = ns.max(nd);
        sim.launch(p_count, move |p| {
            let r = p.rank(WORLD);
            let roles = Roles { ns, nd, rank: r };
            let local = if roles.is_source() {
                let b = super::super::blockdist::block_of(total, ns, r);
                Payload::real((b.ini..b.end).map(|i| i as f64).collect())
            } else {
                Payload::real(Vec::new())
            };
            let mut reg = Registry::new();
            reg.register("A", DataKind::Constant, total, local);
            let policy = if pool { WinPoolPolicy::on() } else { WinPoolPolicy::off() };
            let mut opts = RedistOpts::new(false, policy).sync(crate::simmpi::RmaSync::Notify);
            if chunk > 0 {
                opts = opts.lifecycle(LifecycleOpts::full(chunk));
            }
            let out = redistribute_with(&p, WORLD, &roles, &reg, &[0], opts);
            if roles.is_drain() {
                let nb = super::super::blockdist::block_of(total, nd, r);
                let got = out[0].as_ref().unwrap().as_slice().unwrap().to_vec();
                let want: Vec<f64> = (nb.ini..nb.end).map(|i| i as f64).collect();
                assert_eq!(got, want, "drain {r} wrong block ({ns}->{nd} notify chunk {chunk})");
            } else {
                assert!(out[0].is_none());
            }
            assert!(p.now().is_finite() && p.now() > 0.0);
        });
        sim.run().unwrap();
    }

    #[test]
    fn notify_payloads_match_epoch_across_shapes() {
        // Notified completion must be a byte-identical repartition for
        // grow, shrink, same-size, chunked and pooled variants.
        run_notify(2, 5, 97, 0, false);
        run_notify(6, 2, 103, 5, false);
        run_notify(3, 7, 211, 16, false);
        run_notify(2, 4, 97, 7, true);
        run_notify(4, 4, 64, 0, false);
    }

    #[test]
    fn sched_cache_replays_warm_with_identical_payloads() {
        // Same resize twice under --sched-cache on: the first pass
        // charges the cold schedule build on every rank, the replay
        // only the validation handshake — and the data is unchanged.
        let total = 97u64;
        let (ns, nd) = (2usize, 4usize);
        let mut sim = MpiSim::new(Topology::new(2, 4), NetParams::test_simple());
        sim.launch(4, move |p| {
            let r = p.rank(WORLD);
            let roles = Roles { ns, nd, rank: r };
            let local = if roles.is_source() {
                let b = super::super::blockdist::block_of(total, ns, r);
                Payload::real((b.ini..b.end).map(|i| i as f64).collect())
            } else {
                Payload::real(Vec::new())
            };
            let mut reg = Registry::new();
            reg.register("A", DataKind::Constant, total, local);
            let mut cache = SchedCache::new();
            let opts = RedistOpts::new(true, WinPoolPolicy::off()).sched(true);
            let first = redistribute_sched(&p, WORLD, &roles, &reg, &[0], opts, &mut cache);
            let s1 = p.sched_stats();
            let second = redistribute_sched(&p, WORLD, &roles, &reg, &[0], opts, &mut cache);
            let s2 = p.sched_stats();
            // The collective window close of pass 1 synchronized all
            // ranks past their sched_acquire, so s1 holds every cold
            // build; replays must add none.
            assert_eq!(s1.cold_builds, 4, "one cold build per rank");
            assert_eq!(s2.cold_builds, s1.cold_builds, "replay rebuilt a schedule");
            assert!(s2.warm_replays > s1.warm_replays);
            assert!(s2.build_time > 0.0 && s2.validate_time > 0.0);
            assert!(s2.validate_time < s2.build_time);
            assert_eq!((cache.hits, cache.misses), (1, 1), "Rust-side memo must hit on replay");
            if roles.is_drain() {
                let nb = super::super::blockdist::block_of(total, nd, r);
                let want: Vec<f64> = (nb.ini..nb.end).map(|i| i as f64).collect();
                for out in [&first, &second] {
                    assert_eq!(out[0].as_ref().unwrap().as_slice().unwrap().to_vec(), want);
                }
            }
        });
        sim.run().unwrap();
    }

    #[test]
    fn sched_off_and_epoch_are_bit_identical_to_plain_opts() {
        // The new knobs at their defaults add zero virtual-time charges
        // anywhere: same end time, bit for bit, as the pre-schedule
        // entry point.
        fn end_time(explicit_defaults: bool) -> f64 {
            let total = 50_000u64;
            let (ns, nd) = (3usize, 6usize);
            let mut sim = MpiSim::new(Topology::new(2, 4), NetParams::test_simple());
            sim.launch(6, move |p| {
                let r = p.rank(WORLD);
                let roles = Roles { ns, nd, rank: r };
                let b = super::super::blockdist::block_of(total, ns, r);
                let local = if roles.is_source() { Payload::virt(b.len()) } else { Payload::virt(0) };
                let mut reg = Registry::new();
                reg.register("A", DataKind::Constant, total, local);
                let opts = if explicit_defaults {
                    RedistOpts::new(true, WinPoolPolicy::off())
                        .sync(crate::simmpi::RmaSync::Epoch)
                        .sched(false)
                } else {
                    RedistOpts::new(true, WinPoolPolicy::off())
                };
                let _ = redistribute_with(&p, WORLD, &roles, &reg, &[0], opts);
                assert_eq!(p.sched_stats(), crate::simmpi::SchedStats::default());
            });
            sim.run().unwrap()
        }
        assert_eq!(end_time(false).to_bits(), end_time(true).to_bits());
    }

    #[test]
    fn init_rma_notified_completion_end_to_end() {
        // §IV-C split under --rma-sync notify: init posts epoch-less
        // Rgets, completion waits the requests, charges the notify
        // flags, and tears down through the notification gates.
        let total = 60u64;
        let mut sim = MpiSim::new(Topology::new(1, 4), NetParams::test_simple());
        sim.launch(3, move |p| {
            let r = p.rank(WORLD);
            let (ns, nd) = (2usize, 3usize);
            let roles = Roles { ns, nd, rank: r };
            let local = if roles.is_source() {
                let b = super::super::blockdist::block_of(total, ns, r);
                Payload::real((b.ini..b.end).map(|i| i as f64).collect())
            } else {
                Payload::real(Vec::new())
            };
            let mut reg = Registry::new();
            reg.register("A", DataKind::Constant, total, local);
            let opts = RedistOpts::new(false, WinPoolPolicy::off())
                .sync(crate::simmpi::RmaSync::Notify);
            let mut init = init_rma_with(&p, WORLD, &roles, &reg, &[0], opts);
            assert!(init.epochs.is_empty(), "notify sync must not open epochs");
            assert!(init.n_reads > 0, "every rank drains here");
            while !p.req_testall(&init.reqs) {
                p.compute(1e-4);
            }
            p.rma_notify_charge(init.n_reads);
            close_epochs(&p, &init); // no-op under notify
            while !notify_all_ready(&p, &init) {
                p.compute(1e-4);
            }
            free_windows_local(&p, &init);
            let out = take_payloads(&mut init);
            let nb = super::super::blockdist::block_of(total, nd, r);
            let got = out[0].as_ref().unwrap().as_slice().unwrap().to_vec();
            let want: Vec<f64> = (nb.ini..nb.end).map(|i| i as f64).collect();
            assert_eq!(got, want);
        });
        sim.run().unwrap();
    }

    #[test]
    fn multiple_structures_get_own_windows() {
        let mut sim = MpiSim::new(Topology::new(1, 4), NetParams::test_simple());
        sim.launch(2, move |p| {
            let r = p.rank(WORLD);
            let roles = Roles { ns: 2, nd: 2, rank: r };
            let mut reg = Registry::new();
            let b1 = super::super::blockdist::block_of(40, 2, r);
            let b2 = super::super::blockdist::block_of(10, 2, r);
            reg.register(
                "A",
                DataKind::Constant,
                40,
                Payload::real((b1.ini..b1.end).map(|i| i as f64).collect()),
            );
            reg.register(
                "x",
                DataKind::Constant,
                10,
                Payload::real((b2.ini..b2.end).map(|i| 100.0 + i as f64).collect()),
            );
            let out =
                redistribute_with(&p, WORLD, &roles, &reg, &[0, 1], RedistOpts::new(true, WinPoolPolicy::off()));
            assert_eq!(out.len(), 2);
            let a = out[0].as_ref().unwrap().as_slice().unwrap().to_vec();
            let x = out[1].as_ref().unwrap().as_slice().unwrap().to_vec();
            assert_eq!(a, (b1.ini..b1.end).map(|i| i as f64).collect::<Vec<_>>());
            assert_eq!(x, (b2.ini..b2.end).map(|i| 100.0 + i as f64).collect::<Vec<_>>());
        });
        sim.run().unwrap();
    }

    #[test]
    fn virtual_mode_moves_sizes_only() {
        let mut sim = MpiSim::new(Topology::new(2, 2), NetParams::test_simple());
        sim.launch(4, move |p| {
            let r = p.rank(WORLD);
            let (ns, nd) = (4usize, 2usize);
            let roles = Roles { ns, nd, rank: r };
            let total = 1_000_000u64;
            let b = super::super::blockdist::block_of(total, ns, r);
            let mut reg = Registry::new();
            reg.register("A", DataKind::Constant, total, Payload::virt(b.len()));
            let out =
                redistribute_with(&p, WORLD, &roles, &reg, &[0], RedistOpts::new(false, WinPoolPolicy::off()));
            if roles.is_drain() {
                let nb = super::super::blockdist::block_of(total, nd, r);
                assert_eq!(out[0].as_ref().unwrap().elems(), nb.len());
                assert!(!out[0].as_ref().unwrap().is_real());
            }
            assert!(p.now() > 0.0, "virtual redistribution must cost time");
        });
        sim.run().unwrap();
    }
}
