//! Proteo — the experiment runner implementing the paper's evaluation
//! methodology (§V).
//!
//! A *run* executes one reconfiguration `P = (NS → ND)` with one
//! version `V = (method, strategy)` on the simulated cluster:
//!
//! 1. launch `NS` ranks, register the SAM-CG data (§V-A),
//! 2. run warm-up iterations on `NS` ranks → per-iteration baseline
//!    `T_base`,
//! 3. call `MAM_Reconfigure(ND)`; background versions keep iterating
//!    with the consistent-stop protocol, counting the overlapped
//!    iterations `N_it` (Fig. 6/9) and their durations `T_bg`
//!    (→ ω = T_bg/T_base, Fig. 5/8),
//! 4. `MAM_Finish`, then post iterations on `ND` ranks → `T_it^{ND}`.
//!
//! [`analysis`] implements Equations (1)–(3): the per-pair maximum
//! iteration count `M^P`, the total cost
//! `f(V,P) = R + T_it^{ND}·(M^P − N_it)` and the arg-min choice.
//!
//! Runs are repeated `reps` times with derived seeds and the median is
//! reported, mirroring the paper's 20-repetition median (§V-A).

use std::sync::Arc;

use crate::mam::planner::{self, Objective, PlannerInputs, PlannerMode, ReconfigPlan};
use crate::mam::{
    is_valid_version, version_label, Mam, MamStatus, Method, ReconfigCfg, Registry,
    SpawnStrategy, Strategy, WinPoolPolicy,
};
use crate::netmodel::{NetParams, Topology};
use crate::sam::{Sam, SamConfig};
use crate::simmpi::{CommId, FaultPlan, FaultSpec, MpiProc, MpiSim, RmaSync, WORLD};
use crate::util::stats::median;

/// Full specification of one experimental run.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub ns: usize,
    pub nd: usize,
    pub method: Method,
    pub strategy: Strategy,
    pub sam: SamConfig,
    pub net: NetParams,
    /// Cores per node (the paper's testbed has 20).
    pub cores_per_node: usize,
    /// Warm-up iterations on NS ranks (measure `T_base`).
    pub warmup_iters: u64,
    /// Iterations on ND ranks after the resize (measure `T_it^{ND}`).
    pub post_iters: u64,
    pub spawn_cost: f64,
    /// Spawn strategy of the Merge grow path (`--spawn-strategy`):
    /// Sequential charges the single `spawn_cost` constant (seed
    /// behaviour); Parallel/Async use the decomposed spawn terms.
    pub spawn_strategy: SpawnStrategy,
    pub seed: u64,
    /// Persistent RMA window pool (§VI): `--win-pool on|off`.  Off is
    /// the paper's cold `Win_create` path.
    pub win_pool: WinPoolPolicy,
    /// Chunked pipelined RMA registration (`--rma-chunk`): segment
    /// size in KiB, 0 = off (the seed unchunked path, bit for bit).
    pub rma_chunk_kib: u64,
    /// Teardown half of the chunked lifecycle pipeline
    /// (`--rma-dereg`, default on): pool-off `Win_free`s deregister
    /// per segment as the last reads land.  `false` keeps the
    /// registration-only pipeline.  Ignored when `rma_chunk_kib == 0`.
    pub rma_dereg: bool,
    /// `--planner auto|fixed`: `Auto` lets the cost-model planner
    /// override method/strategy/spawn/pool for this pair (resolved
    /// once, before the simulation, with DES micro-probe refinement);
    /// `Fixed` (default) is bit-identical to the seed behaviour.
    pub planner: PlannerMode,
    /// `--recalib on|off`: online recalibration of the planner's
    /// `NetParams` from observed resizes (`mam::recalib`).  A single
    /// run has no observation history, so here the flag only seeds
    /// `ReconfigCfg::recalib` for the multi-resize harnesses
    /// (`scenario`, `experiments::drift`) that feed the estimator;
    /// `false` (default) is bit-identical to the pre-recalibration
    /// behaviour everywhere.
    pub recalib: bool,
    /// `--rma-sync epoch|notify`: RMA completion synchronization.
    /// `Epoch` (default) is the seed's passive epochs + collective
    /// teardown, bit for bit; `Notify` completes drains on per-segment
    /// notification counters and tears windows down locally.
    pub rma_sync: RmaSync,
    /// `--sched-cache on|off`: persistent redistribution schedules.
    /// Off (default) recomputes targets/read lists per resize (seed
    /// behaviour, bit for bit); on builds the schedule once per
    /// `(from, to, structure, chunk)` and replays it for a validation
    /// handshake on later resizes between the same sizes.
    pub sched_cache: bool,
    /// `--faults <spec>`: deterministic seeded fault injection
    /// (spawn failures with retry/backoff, slowed registration, lost
    /// notify counters, stragglers).  `None` (default) executes the
    /// healthy paths bit for bit.
    pub faults: Option<FaultSpec>,
}

impl RunSpec {
    /// The paper's setup (§V-A) for one pair and version.
    pub fn sarteco25(ns: usize, nd: usize, method: Method, strategy: Strategy) -> RunSpec {
        RunSpec {
            ns,
            nd,
            method,
            strategy,
            sam: SamConfig::sarteco25(),
            net: NetParams::sarteco25(),
            cores_per_node: 20,
            warmup_iters: 3,
            post_iters: 3,
            spawn_cost: 0.25,
            spawn_strategy: SpawnStrategy::Sequential,
            seed: 0xC0FFEE,
            win_pool: WinPoolPolicy::off(),
            rma_chunk_kib: 0,
            rma_dereg: true,
            planner: PlannerMode::Fixed,
            recalib: false,
            rma_sync: RmaSync::Epoch,
            sched_cache: false,
            faults: None,
        }
    }

    /// Nodes allocated: ⌈max(NS,ND)/cores⌉ (§V-A).
    pub fn nodes(&self) -> usize {
        self.ns.max(self.nd).div_ceil(self.cores_per_node)
    }

    /// The MaM configuration this spec implies (shared by source and
    /// drain bodies so they can never drift apart).
    pub fn mam_cfg(&self) -> ReconfigCfg {
        ReconfigCfg::version(self.method, self.strategy)
            .with_spawn(self.spawn_strategy, self.spawn_cost)
            .with_pool(self.win_pool)
            .with_chunk(self.rma_chunk_kib)
            .with_dereg(self.rma_dereg)
            .with_planner(self.planner)
            .with_recalib(self.recalib)
            .with_sync(self.rma_sync)
            .with_sched_cache(self.sched_cache)
    }

    pub fn label(&self) -> String {
        version_label(self.method, self.strategy)
    }
}

/// Measured outcome of one run (or the median of several).
#[derive(Clone, Debug)]
pub struct RunResult {
    pub label: String,
    pub ns: usize,
    pub nd: usize,
    /// Redistribution time R (start of stage 3 → last rank done).
    pub redist_time: f64,
    /// Full reconfiguration span (stage 2 + 3 + 4).
    pub reconf_total: f64,
    /// Overlapped iterations N_it (max over sources; 0 for blocking).
    pub n_it: f64,
    /// Baseline per-iteration time on NS ranks.
    pub t_base: f64,
    /// Per-iteration time while redistribution ran in background
    /// (NaN for blocking versions).
    pub t_bg: f64,
    /// Per-iteration time on ND ranks after the resize.
    pub t_it_nd: f64,
    /// ω = T_bg / T_base (Fig. 5/8; NaN for blocking).
    pub omega: f64,
    /// Virtual time at simulation end.
    pub virt_end: f64,
    /// DES events processed (simulator throughput diagnostics).
    pub events: u64,
}

/// Resolve `--planner auto` into a concrete version for this pair.
///
/// Plan resolution is a harness-level step: every rank — and every
/// spawned drain — must execute the same plan, so the choice is made
/// once, from rank-independent inputs (declared sizes, calibrated
/// parameters, iteration-time estimates), *before* the simulation
/// launches, and the resolved spec is what both `source_body` and
/// `drain_main` see.  Blocking candidates are refined with exact DES
/// micro-probes (see `mam::planner`), so the chosen version's
/// simulated reconfiguration time matches the best fixed version up
/// to ties.
pub fn resolve_spec(spec: &RunSpec) -> (RunSpec, Option<ReconfigPlan>) {
    if spec.planner == PlannerMode::Fixed {
        return (spec.clone(), None);
    }
    let sam = Sam::new(spec.sam.clone(), spec.seed, 0);
    let mut reg = Registry::new();
    sam.register_data(&mut reg, spec.ns, 0);
    let inp = PlannerInputs {
        decls: reg.decls(),
        ns: spec.ns,
        nd: spec.nd,
        cores_per_node: spec.cores_per_node,
        net: spec.net.clone(),
        spawn_cost: spec.spawn_cost,
        warm: false,
        t_iter_src: spec.sam.iter_compute(spec.ns),
        t_iter_dst: spec.sam.iter_compute(spec.nd),
        objective: Objective::ReconfTime,
        probe: true,
        extra_chunks_kib: Vec::new(),
        rma_sync: spec.rma_sync,
        sched_cache: spec.sched_cache,
        sched_warm: false,
        future_resizes: 0,
        fail_p: spec.faults.as_ref().map_or(0.0, |f| f.spawn_fail_p),
    };
    let plan = planner::plan(&inp);
    let mut resolved = spec.clone();
    resolved.planner = PlannerMode::Fixed;
    resolved.method = plan.choice.method;
    resolved.strategy = plan.choice.strategy;
    resolved.spawn_strategy = plan.choice.spawn_strategy;
    resolved.win_pool = plan.choice.win_pool;
    resolved.rma_chunk_kib = plan.choice.rma_chunk_kib;
    (resolved, Some(plan))
}

/// Execute one run.
pub fn run_once(spec: &RunSpec) -> RunResult {
    let (resolved, plan) = resolve_spec(spec);
    let spec = &resolved;
    assert!(
        is_valid_version(spec.method, spec.strategy),
        "invalid version {:?}×{:?}",
        spec.method,
        spec.strategy
    );
    // Cyclic layout: the job's allocation spans ⌈max(NS,ND)/20⌉ nodes
    // (§V-A) and both rank groups spread over every allocated node.
    let topo = Topology::new_cyclic(spec.nodes().max(1), spec.cores_per_node);
    let mut sim = MpiSim::new(topo, spec.net.clone());
    if let Some(f) = &spec.faults {
        sim.set_faults(FaultPlan::new(f.clone()));
    }
    let world = sim.world();
    let spec2 = spec.clone();
    sim.launch(spec.ns, move |p| source_body(&spec2, p));
    let virt_end = sim.run().expect("simulation failed");

    let w = world.lock().unwrap();
    let m = &w.metrics;
    let redist_time = m.span("mam.redist_start", "mam.redist_end").unwrap_or(f64::NAN);
    let reconf_total = m.span("mam.reconf_start", "mam.reconf_end").unwrap_or(f64::NAN);
    let t_base = m.series("sam.t_base").map_or(f64::NAN, median);
    let t_bg = m.series("sam.t_bg").map_or(f64::NAN, median);
    let t_it_nd = m.series("sam.t_nd").map_or(f64::NAN, median);
    let n_it = m.mark_at("sam.n_it_max").unwrap_or(0.0);
    RunResult {
        label: match &plan {
            Some(p) => format!("auto[{}]", p.label()),
            None => spec.label(),
        },
        ns: spec.ns,
        nd: spec.nd,
        redist_time,
        reconf_total,
        n_it,
        t_base,
        t_bg,
        t_it_nd,
        omega: t_bg / t_base,
        virt_end,
        events: m.counter("engine.events").unwrap_or(0.0) as u64,
    }
}

/// Median of `reps` runs with derived seeds (the paper uses 20 reps).
pub fn run_median(spec: &RunSpec, reps: usize) -> RunResult {
    assert!(reps >= 1);
    // Resolve the plan once for all repetitions (the planner inputs do
    // not depend on the derived seeds).
    let (resolved, plan) = resolve_spec(spec);
    let spec = &resolved;
    let runs: Vec<RunResult> = (0..reps)
        .map(|i| {
            let mut s = spec.clone();
            s.seed = spec.seed.wrapping_add(i as u64).wrapping_mul(0x9E37_79B9);
            run_once(&s)
        })
        .collect();
    let med = |f: fn(&RunResult) -> f64| {
        let vals: Vec<f64> = runs.iter().map(f).filter(|v| !v.is_nan()).collect();
        if vals.is_empty() {
            f64::NAN
        } else {
            median(&vals)
        }
    };
    RunResult {
        label: match &plan {
            Some(p) => format!("auto[{}]", p.label()),
            None => spec.label(),
        },
        ns: spec.ns,
        nd: spec.nd,
        redist_time: med(|r| r.redist_time),
        reconf_total: med(|r| r.reconf_total),
        n_it: med(|r| r.n_it),
        t_base: med(|r| r.t_base),
        t_bg: med(|r| r.t_bg),
        t_it_nd: med(|r| r.t_it_nd),
        omega: med(|r| r.omega),
        virt_end: med(|r| r.virt_end),
        events: runs.iter().map(|r| r.events).sum::<u64>() / reps as u64,
    }
}

/// The per-source-rank body: warm-up → reconfigure (+ overlap loop) →
/// finish → post iterations.
fn source_body(spec: &RunSpec, p: MpiProc) {
    let rank = p.rank(WORLD);
    let mut sam = Sam::new(spec.sam.clone(), spec.seed, p.gpid());
    let mut reg = Registry::new();
    sam.register_data(&mut reg, spec.ns, rank);
    let mut mam = Mam::new(reg, spec.mam_cfg());

    // ---- Warm-up on NS ranks: measure T_base.
    for _ in 0..spec.warmup_iters {
        let dur = sam.iteration(&p, WORLD);
        p.metrics(|m| m.push_series("sam.t_base", dur));
    }

    // ---- Reconfigure.
    let nd = spec.nd;
    let spec_d = spec.clone();
    let drain_body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
        Arc::new(move |dp: MpiProc, merged: CommId| {
            drain_main(&spec_d, dp, merged);
        });
    let status = mam.reconfigure(&p, WORLD, nd, drain_body);
    if status == MamStatus::Aborted {
        // `--faults`: spawn retries exhausted, the resize rolled back.
        // The run continues — and reports — on the original layout; no
        // redistribution marks are recorded, so R stays NaN.
        p.metrics(|m| {
            m.mark_max("sam.n_it_max", 0.0);
            m.push_series("sam.n_it", 0.0);
        });
        for _ in 0..spec.post_iters {
            let dur = sam.iteration(&p, WORLD);
            p.metrics(|m| m.push_series("sam.t_nd", dur));
        }
        return;
    }

    // ---- Overlap loop (background strategies): the application keeps
    // iterating; all ranks leave together via the flag allgather.
    let mut n_it = 0u64;
    if status == MamStatus::InProgress {
        let mut local_done = false;
        loop {
            let (dur, all_done) = sam.iteration_with_flag(&p, WORLD, local_done);
            if !local_done {
                n_it += 1;
                p.metrics(|m| m.push_series("sam.t_bg", dur));
                if mam.checkpoint(&p) == MamStatus::Completed {
                    local_done = true;
                }
            }
            if all_done {
                break;
            }
        }
    }
    p.metrics(|m| {
        m.mark_max("sam.n_it_max", n_it as f64);
        m.push_series("sam.n_it", n_it as f64);
    });

    // ---- Stage 4: switch communicators (and move variable data).
    let out = mam.finish(&p, WORLD);
    if let Some(comm) = out.app_comm {
        debug_assert!(mam.registry.verify_blocks(nd, p.rank(comm)).is_empty());
        for _ in 0..spec.post_iters {
            let dur = sam.iteration(&p, comm);
            p.metrics(|m| m.push_series("sam.t_nd", dur));
        }
    } else {
        debug_assert!(rank >= nd, "rank {rank} wrongly retired");
    }
}

/// Main function of spawned drain processes (grow only): mirror the
/// redistribution, then run the post iterations with everyone else.
fn drain_main(spec: &RunSpec, dp: MpiProc, merged: CommId) {
    let sam0 = Sam::new(spec.sam.clone(), spec.seed, dp.gpid());
    let mut reg = Registry::new();
    // Declarations are identical on every rank: rebuild from config.
    sam0.register_data(&mut reg, spec.ns, 0);
    let decls = reg.decls();
    let mam = Mam::drain_join(&dp, merged, spec.ns, spec.nd, &decls, spec.mam_cfg());
    debug_assert!(mam
        .registry
        .verify_blocks(spec.nd, dp.rank(merged))
        .is_empty());
    let mut sam = Sam::new(spec.sam.clone(), spec.seed, dp.gpid());
    for _ in 0..spec.post_iters {
        let dur = sam.iteration(&dp, merged);
        dp.metrics(|m| m.push_series("sam.t_nd", dur));
    }
}

pub mod analysis {
    //! Equations (1)–(3) of §V-C.

    use super::RunResult;

    /// Eq. (1): `M^P = max_V N_it^{V,P}`.
    pub fn eq1_max_iters(results: &[RunResult]) -> f64 {
        results.iter().map(|r| r.n_it).fold(0.0, f64::max)
    }

    /// Eq. (2): `f(V,P) = R^{V,P} + T_it^{ND} (M^P − N_it^{V,P})`.
    pub fn eq2_total(r: &RunResult, m_p: f64) -> f64 {
        r.redist_time + r.t_it_nd * (m_p - r.n_it)
    }

    /// Eq. (2) applied to a version set sharing one pair P.
    pub fn eq2_totals(results: &[RunResult]) -> Vec<f64> {
        let m_p = eq1_max_iters(results);
        results.iter().map(|r| eq2_total(r, m_p)).collect()
    }

    /// Eq. (3): index of the version minimizing the total cost.
    pub fn eq3_best(results: &[RunResult]) -> usize {
        let totals = eq2_totals(results);
        totals
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i)
            .expect("empty version set")
    }
}

/// The paper's 12 reconfiguration pairs: ordered pairs from
/// {20, 40, 80, 160} with NS ≠ ND (§V-A).
pub fn sarteco25_pairs() -> Vec<(usize, usize)> {
    let sizes = [20usize, 40, 80, 160];
    let mut out = Vec::new();
    for &ns in &sizes {
        for &nd in &sizes {
            if ns != nd {
                out.push((ns, nd));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_spec(method: Method, strategy: Strategy) -> RunSpec {
        let mut sam = SamConfig::sarteco25();
        // Shrink the problem so unit tests stay fast (same shape).
        sam.matrix_elems /= 100;
        sam.vector_elems /= 100;
        sam.flops_per_iter /= 100.0;
        RunSpec {
            ns: 6,
            nd: 3,
            method,
            strategy,
            sam,
            net: NetParams::sarteco25(),
            cores_per_node: 4,
            warmup_iters: 2,
            post_iters: 2,
            spawn_cost: 0.05,
            spawn_strategy: SpawnStrategy::Sequential,
            seed: 1,
            win_pool: WinPoolPolicy::off(),
            rma_chunk_kib: 0,
            rma_dereg: true,
            planner: PlannerMode::Fixed,
            recalib: false,
            rma_sync: RmaSync::Epoch,
            sched_cache: false,
            faults: None,
        }
    }

    #[test]
    fn pairs_match_paper() {
        let pairs = sarteco25_pairs();
        assert_eq!(pairs.len(), 12);
        assert!(pairs.contains(&(20, 160)));
        assert!(pairs.contains(&(160, 20)));
        assert!(!pairs.contains(&(20, 20)));
    }

    #[test]
    fn blocking_run_produces_metrics() {
        let r = run_once(&small_spec(Method::Collective, Strategy::Blocking));
        assert!(r.redist_time > 0.0, "R={}", r.redist_time);
        assert!(r.t_base > 0.0);
        assert!(r.t_it_nd > 0.0);
        assert_eq!(r.n_it, 0.0, "blocking must not overlap iterations");
        assert!(r.t_bg.is_nan());
    }

    #[test]
    fn wd_run_overlaps_iterations() {
        let r = run_once(&small_spec(Method::Collective, Strategy::WaitDrains));
        assert!(r.redist_time > 0.0);
        assert!(r.n_it >= 1.0, "WD should overlap ≥1 iteration, got {}", r.n_it);
        assert!(r.omega > 0.5, "omega={}", r.omega);
    }

    #[test]
    fn rma_wd_grow_works() {
        let mut spec = small_spec(Method::RmaLockall, Strategy::WaitDrains);
        spec.ns = 3;
        spec.nd = 6;
        let r = run_once(&spec);
        assert!(r.redist_time > 0.0);
        assert!(r.t_it_nd > 0.0);
    }

    #[test]
    fn threading_run_completes() {
        let r = run_once(&small_spec(Method::Collective, Strategy::Threading));
        assert!(r.redist_time > 0.0);
        assert!(r.t_it_nd > 0.0);
    }

    #[test]
    fn parallel_and_async_spawn_reduce_grow_totals() {
        // ≥8→16 grow, RMA-Lockall WD, the paper's 0.25 s sequential
        // spawn constant: the decomposed strategies must strictly
        // reduce the full reconfiguration span.
        let time_with = |ss: SpawnStrategy| -> RunResult {
            let mut spec = small_spec(Method::RmaLockall, Strategy::WaitDrains);
            spec.ns = 8;
            spec.nd = 16;
            spec.spawn_cost = 0.25;
            spec.spawn_strategy = ss;
            run_once(&spec)
        };
        let seq = time_with(SpawnStrategy::Sequential);
        let par = time_with(SpawnStrategy::Parallel);
        let asy = time_with(SpawnStrategy::Async);
        assert!(
            par.reconf_total < seq.reconf_total,
            "parallel {} !< sequential {}",
            par.reconf_total,
            seq.reconf_total
        );
        assert!(
            asy.reconf_total < seq.reconf_total,
            "async {} !< sequential {}",
            asy.reconf_total,
            seq.reconf_total
        );
        // All strategies yield the same post-resize iteration behaviour.
        assert!(par.t_it_nd > 0.0 && asy.t_it_nd > 0.0);
    }

    #[test]
    fn sequential_spawn_strategy_is_the_default_and_deterministic() {
        // Explicit Sequential must be indistinguishable from the
        // default-constructed spec (the PR-1 behaviour): same events,
        // same timings, bit for bit.
        let spec = small_spec(Method::RmaLock, Strategy::WaitDrains);
        let mut explicit = spec.clone();
        explicit.spawn_strategy = SpawnStrategy::Sequential;
        let a = run_once(&spec);
        let b = run_once(&explicit);
        assert_eq!(a.redist_time.to_bits(), b.redist_time.to_bits());
        assert_eq!(a.reconf_total.to_bits(), b.reconf_total.to_bits());
        assert_eq!(a.virt_end.to_bits(), b.virt_end.to_bits());
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn pooled_run_completes_and_is_deterministic() {
        let mut spec = small_spec(Method::RmaLockall, Strategy::WaitDrains);
        spec.win_pool = WinPoolPolicy::on();
        let a = run_once(&spec);
        let b = run_once(&spec);
        assert!(a.redist_time > 0.0 && a.t_it_nd > 0.0);
        assert_eq!(a.redist_time.to_bits(), b.redist_time.to_bits());
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn resolve_spec_fixed_is_the_identity() {
        // `--planner fixed` (the default) must leave the spec alone —
        // bit-identical seed behaviour, no planning work.
        let spec = small_spec(Method::RmaLock, Strategy::WaitDrains);
        let (r, plan) = resolve_spec(&spec);
        assert!(plan.is_none());
        assert_eq!(r.method, spec.method);
        assert_eq!(r.strategy, spec.strategy);
        assert_eq!(r.spawn_strategy, spec.spawn_strategy);
        assert_eq!(r.win_pool, spec.win_pool);
        assert_eq!(r.planner, PlannerMode::Fixed);
    }

    #[test]
    fn auto_run_completes_deterministically_and_labels_the_choice() {
        let mut spec = small_spec(Method::Collective, Strategy::Blocking);
        spec.planner = PlannerMode::Auto;
        let a = run_once(&spec);
        assert!(a.label.starts_with("auto["), "label: {}", a.label);
        assert!(a.redist_time > 0.0 && a.t_it_nd > 0.0);
        let b = run_once(&spec);
        assert_eq!(a.label, b.label, "plan choice must be deterministic");
        assert_eq!(a.redist_time.to_bits(), b.redist_time.to_bits());
        assert_eq!(a.virt_end.to_bits(), b.virt_end.to_bits());
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn notify_and_sched_cache_runs_complete_deterministically() {
        for (m, s) in [
            (Method::RmaLockall, Strategy::Blocking),
            (Method::RmaLock, Strategy::WaitDrains),
            (Method::RmaLockall, Strategy::Threading),
        ] {
            let mut spec = small_spec(m, s);
            spec.rma_sync = RmaSync::Notify;
            spec.sched_cache = true;
            let a = run_once(&spec);
            let b = run_once(&spec);
            assert!(a.redist_time > 0.0 && a.t_it_nd > 0.0, "{m:?}{s:?}: {a:?}");
            assert_eq!(a.virt_end.to_bits(), b.virt_end.to_bits());
            assert_eq!(a.events, b.events);
        }
    }

    #[test]
    fn sync_knob_is_inert_for_collective_runs() {
        // COL never opens windows: the sync mode must not perturb a
        // two-sided run in any observable way.
        let mut spec = small_spec(Method::Collective, Strategy::Blocking);
        spec.rma_sync = RmaSync::Notify;
        let n = run_once(&spec);
        let d = run_once(&small_spec(Method::Collective, Strategy::Blocking));
        assert_eq!(n.virt_end.to_bits(), d.virt_end.to_bits());
        assert_eq!(n.redist_time.to_bits(), d.redist_time.to_bits());
        assert_eq!(n.events, d.events);
    }

    #[test]
    fn faulty_run_recovers_and_unrecoverable_run_aborts_cleanly() {
        // Recoverable: first2 within the default retry budget — the
        // resize completes, payload identity checked by the body's
        // verify_blocks debug asserts.
        let mut rec = small_spec(Method::RmaLockall, Strategy::Blocking);
        rec.ns = 3;
        rec.nd = 6;
        rec.faults = Some(FaultSpec::parse("spawn=first2,mode=wave").unwrap());
        let r = run_once(&rec);
        assert!(r.redist_time > 0.0 && r.t_it_nd > 0.0, "{r:?}");
        // Unrecoverable: every attempt fails — abort-and-rollback, the
        // run finishes on the old layout with no redistribution marks.
        let mut bad = rec.clone();
        bad.faults = Some(FaultSpec::parse("spawn=1.0,mode=wave,retries=1").unwrap());
        let a = run_once(&bad);
        assert!(a.redist_time.is_nan(), "aborted resize must not redistribute: {a:?}");
        assert!(a.t_base > 0.0 && a.t_it_nd > 0.0, "app continues on the old layout");
        let b = run_once(&bad);
        assert_eq!(a.virt_end.to_bits(), b.virt_end.to_bits(), "faulty runs stay deterministic");
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn runs_are_deterministic() {
        let spec = small_spec(Method::RmaLock, Strategy::WaitDrains);
        let a = run_once(&spec);
        let b = run_once(&spec);
        assert_eq!(a.redist_time.to_bits(), b.redist_time.to_bits());
        assert_eq!(a.virt_end.to_bits(), b.virt_end.to_bits());
        assert_eq!(a.events, b.events);
    }

    #[test]
    fn median_aggregates_reps() {
        let spec = small_spec(Method::Collective, Strategy::NonBlocking);
        let r = run_median(&spec, 3);
        assert!(r.redist_time > 0.0);
        assert!(r.t_base > 0.0);
    }

    #[test]
    fn eq2_analysis_favors_fast_redistribution() {
        use analysis::*;
        let mk = |label: &str, r, n_it, t_nd| RunResult {
            label: label.into(),
            ns: 20,
            nd: 40,
            redist_time: r,
            reconf_total: r,
            n_it,
            t_base: 1.0,
            t_bg: 1.0,
            t_it_nd: t_nd,
            omega: 1.0,
            virt_end: 0.0,
            events: 0,
        };
        // Version A: fast R, few overlapped iters.  B: slow R, many.
        let a = mk("A", 10.0, 2.0, 1.0);
        let b = mk("B", 14.0, 8.0, 1.0);
        let set = vec![a, b];
        let m = eq1_max_iters(&set);
        assert_eq!(m, 8.0);
        let totals = eq2_totals(&set);
        // f(A) = 10 + (8-2) = 16 ; f(B) = 14 + 0 = 14 → B wins.
        assert_eq!(totals, vec![16.0, 14.0]);
        assert_eq!(eq3_best(&set), 1);
    }
}
