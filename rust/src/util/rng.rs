//! Seedable, dependency-free PRNG.
//!
//! `SplitMix64` is used to expand a user seed into the state of a
//! `xoshiro256**` generator (Blackman & Vigna).  Both are tiny, fast and
//! pass BigCrush; determinism across platforms is what the simulator
//! needs, not cryptographic strength.

/// SplitMix64 step — used for seeding and as a standalone cheap stream.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)` (empty range returns `lo`).
    #[inline]
    pub fn gen_range(&mut self, lo: usize, hi: usize) -> usize {
        if hi <= lo {
            return lo;
        }
        let span = (hi - lo) as u64;
        // Lemire's unbiased bounded sampling (rejection on the low word).
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(span as u128);
            let l = m as u64;
            if l >= span {
                return lo + (m >> 64) as usize;
            }
            let t = span.wrapping_neg() % span;
            if l >= t {
                return lo + (m >> 64) as usize;
            }
        }
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn gen_range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Bernoulli draw.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Pick a random element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty(), "choose on empty slice");
        &xs[self.gen_range(0, xs.len())]
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(0, i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork an independent stream (for per-rank determinism).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut seed = self.next_u64() ^ stream.wrapping_mul(0xA076_1D64_78BD_642F);
        Rng::new(splitmix64(&mut seed))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.gen_range(10, 20);
            assert!((10..20).contains(&x));
        }
        // Degenerate range.
        assert_eq!(r.gen_range(5, 5), 5);
    }

    #[test]
    fn gen_range_covers_all_values() {
        let mut r = Rng::new(11);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.gen_range(0, 8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn forked_streams_are_independent() {
        let mut root = Rng::new(123);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 3);
    }

    #[test]
    fn mean_is_roughly_half() {
        let mut r = Rng::new(5);
        let n = 100_000;
        let sum: f64 = (0..n).map(|_| r.next_f64()).sum();
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }
}
