//! Summary statistics used by the benchmark harness and the experiment
//! reports (the paper reports the *median of 20 repetitions* — §V-A).

/// Summary of a sample of f64 observations.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub median: f64,
    pub p05: f64,
    pub p95: f64,
    pub stddev: f64,
}

impl Summary {
    /// Compute a summary; panics on an empty sample.
    pub fn of(sample: &[f64]) -> Summary {
        assert!(!sample.is_empty(), "Summary::of on empty sample");
        let mut sorted: Vec<f64> = sample.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).expect("NaN in sample"));
        let n = sorted.len();
        let mean = sorted.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            sorted.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        Summary {
            n,
            min: sorted[0],
            max: sorted[n - 1],
            mean,
            median: percentile_sorted(&sorted, 50.0),
            p05: percentile_sorted(&sorted, 5.0),
            p95: percentile_sorted(&sorted, 95.0),
            stddev: var.sqrt(),
        }
    }
}

/// Linear-interpolated percentile of an already-sorted slice.
pub fn percentile_sorted(sorted: &[f64], pct: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = pct / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Median of an unsorted sample (the paper's representative statistic).
pub fn median(sample: &[f64]) -> f64 {
    Summary::of(sample).median
}

/// Geometric mean (used for aggregate speedups).
pub fn geomean(sample: &[f64]) -> f64 {
    assert!(!sample.is_empty());
    let log_sum: f64 = sample
        .iter()
        .map(|x| {
            assert!(*x > 0.0, "geomean needs positive values");
            x.ln()
        })
        .sum();
    (log_sum / sample.len() as f64).exp()
}

/// Format a duration given in seconds with an adaptive unit.
pub fn fmt_seconds(s: f64) -> String {
    if s < 0.0 {
        return format!("-{}", fmt_seconds(-s));
    }
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else if s < 120.0 {
        format!("{:.3} s", s)
    } else {
        format!("{:.1} min", s / 60.0)
    }
}

/// Format a byte count with an adaptive binary unit.
pub fn fmt_bytes(b: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{b} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_constant() {
        let s = Summary::of(&[3.0, 3.0, 3.0]);
        assert_eq!(s.min, 3.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.median, 3.0);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[1.0, 2.0, 3.0, 4.0]), 2.5);
        assert_eq!(median(&[5.0]), 5.0);
    }

    #[test]
    fn median_unsorted_input() {
        assert_eq!(median(&[9.0, 1.0, 5.0]), 5.0);
    }

    #[test]
    fn percentiles_interpolate() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 50.0), 5.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
    }

    #[test]
    fn stddev_matches_hand_computation() {
        // sample {2, 4, 4, 4, 5, 5, 7, 9}: mean 5, sample var 32/7.
        let s = Summary::of(&[2., 4., 4., 4., 5., 5., 7., 9.]);
        assert!((s.mean - 5.0).abs() < 1e-12);
        assert!((s.stddev - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn fmt_seconds_units() {
        assert!(fmt_seconds(2e-9).contains("ns"));
        assert!(fmt_seconds(3e-6).contains("µs"));
        assert!(fmt_seconds(5e-3).contains("ms"));
        assert!(fmt_seconds(1.5).contains(" s"));
        assert!(fmt_seconds(300.0).contains("min"));
    }

    #[test]
    fn fmt_bytes_units() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.00 KiB");
        assert!(fmt_bytes(64 * 1024 * 1024 * 1024).contains("GiB"));
    }
}
