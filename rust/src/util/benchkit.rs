//! Criterion-style benchmark harness (criterion is not available in the
//! offline vendor set).
//!
//! `cargo bench` targets use `harness = false` and drive this module:
//! warmup, timed repetitions, outlier-robust summaries, and aligned
//! table output so every paper figure prints as rows the way the paper
//! reports them.  Also supports *simulated-time* benchmarks, where the
//! measured quantity is the virtual clock of the DES rather than the
//! wall clock.

use crate::util::json::Json;
use crate::util::stats::{fmt_seconds, Summary};
use crate::util::wallclock::WallTimer;

/// Outcome of one bench-regression comparison.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchComparison {
    /// Human-readable per-entry notes (improvements, new entries, …).
    pub notes: Vec<String>,
    /// Entries whose current value regressed beyond the tolerance (or
    /// disappeared).  Non-empty ⇒ the gate fails.
    pub regressions: Vec<String>,
    /// Entries actually compared (present in both documents).
    pub compared: usize,
}

impl BenchComparison {
    pub fn passed(&self) -> bool {
        self.regressions.is_empty()
    }
}

/// Wall-clock keys are *soft* metrics: tracked, warned about, but
/// never a gate failure — CI runner speed is too noisy to gate on.
fn is_soft_metric(name: &str) -> bool {
    name == "wall_s" || name.ends_with(".wall_s")
}

/// Relative slowdown above which a soft (wall-clock) metric draws a
/// warning note from [`compare_bench`].
pub const WALL_SOFT_TOL: f64 = 0.25;

/// Compare a current bench-smoke document against a baseline: every
/// baseline entry must exist in `current` and must not exceed
/// `baseline * (1 + tol)`.  An empty baseline (`"entries": {}`) is the
/// bootstrap state and passes with a note — promote a CI-produced
/// `BENCH_pr.json` to arm the gate.  Entries only present in `current`
/// are noted, never failed, so adding benchmarks is painless.
/// Documents carrying mismatched `schema` or `mode` (quick vs full
/// workload) provenance are rejected outright — their virtual-time
/// values are not comparable.
///
/// Wall-clock keys (`wall_s`, whether the top-level document field or
/// any `*.wall_s` entry) are soft metrics: a slowdown beyond
/// [`WALL_SOFT_TOL`] (25%) is warned about in `notes`, but can never
/// fail the gate.
pub fn compare_bench(baseline: &Json, current: &Json, tol: f64) -> BenchComparison {
    let mut cmp = BenchComparison { notes: Vec::new(), regressions: Vec::new(), compared: 0 };
    if let (Some(bw), Some(cw)) = (
        baseline.get("wall_s").and_then(|v| v.as_f64()),
        current.get("wall_s").and_then(|v| v.as_f64()),
    ) {
        if cw > bw * (1.0 + WALL_SOFT_TOL) {
            cmp.notes.push(format!(
                "wall_s: {cw:.3} is more than {:.0}% over baseline {bw:.3} \
                 (soft metric, not gated)",
                WALL_SOFT_TOL * 100.0
            ));
        }
    }
    for key in ["schema", "mode"] {
        let (b, c) = (baseline.get(key), current.get(key));
        if let (Some(b), Some(c)) = (b, c) {
            if b != c {
                cmp.regressions
                    .push(format!("{key} mismatch: baseline {b} vs current {c}"));
            }
        }
    }
    if !cmp.regressions.is_empty() {
        return cmp;
    }
    let base = baseline.get("entries").and_then(|e| e.as_obj());
    let cur = current.get("entries").and_then(|e| e.as_obj());
    let (Some(base), Some(cur)) = (base, cur) else {
        cmp.regressions.push("malformed document: missing \"entries\" object".into());
        return cmp;
    };
    if base.is_empty() {
        cmp.notes.push(
            "baseline has no entries (bootstrap) — promote BENCH_pr.json to arm the gate".into(),
        );
    }
    for (name, bv) in base {
        let Some(bv) = bv.as_f64() else {
            cmp.regressions.push(format!("{name}: baseline value is not a number"));
            continue;
        };
        let soft = is_soft_metric(name);
        match cur.get(name).and_then(|v| v.as_f64()) {
            None if soft => cmp
                .notes
                .push(format!("{name}: missing from current run (soft metric, not gated)")),
            None => cmp.regressions.push(format!("{name}: missing from current run")),
            Some(cv) if soft => {
                cmp.compared += 1;
                if cv > bv * (1.0 + WALL_SOFT_TOL) {
                    cmp.notes.push(format!(
                        "{name}: {cv:.6} is more than {:.0}% over baseline {bv:.6} \
                         (soft metric, not gated)",
                        WALL_SOFT_TOL * 100.0
                    ));
                }
            }
            Some(cv) => {
                cmp.compared += 1;
                let limit = bv * (1.0 + tol);
                if cv > limit {
                    cmp.regressions.push(format!(
                        "{name}: {cv:.6} exceeds baseline {bv:.6} by more than {:.0}%",
                        tol * 100.0
                    ));
                } else if cv < bv * (1.0 - tol) {
                    cmp.notes.push(format!("{name}: improved {bv:.6} -> {cv:.6}"));
                }
            }
        }
    }
    for name in cur.keys() {
        if !base.contains_key(name) {
            cmp.notes.push(format!("{name}: new entry (not in baseline)"));
        }
    }
    cmp
}

/// One benchmark measurement series.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Wall-clock seconds per iteration.
    pub wall: Summary,
    /// Optional domain metric (e.g. simulated seconds, ops/s).
    pub metric: Option<(String, Summary)>,
    /// Deterministic observability counters from the last measured
    /// iteration (e.g. the engine's `events`/`peak_queue`/wakeup-batch
    /// counters), appended to the table row.
    pub extras: Vec<(String, f64)>,
}

/// Harness configuration.
#[derive(Clone, Debug)]
pub struct Bench {
    pub warmup_iters: usize,
    pub measure_iters: usize,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup_iters: 1, measure_iters: 5, results: Vec::new() }
    }
}

impl Bench {
    pub fn new() -> Self {
        // Honor the conventional quick-mode env var so `make bench` can be
        // tuned without recompiling.
        let mut b = Bench::default();
        if let Ok(v) = std::env::var("BENCH_ITERS") {
            if let Ok(n) = v.parse() {
                b.measure_iters = n;
            }
        }
        b
    }

    /// Benchmark a closure for wall-clock time.
    pub fn bench<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::with_capacity(self.measure_iters);
        for _ in 0..self.measure_iters {
            let t0 = WallTimer::start();
            f();
            samples.push(t0.elapsed_s());
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            wall: Summary::of(&samples),
            metric: None,
            extras: Vec::new(),
        });
        self.results.last().unwrap()
    }

    /// Benchmark a closure that *returns* a domain metric (e.g. the
    /// simulated redistribution time). Both wall time and the metric are
    /// recorded; the table prints the metric as the primary column.
    pub fn bench_metric<F: FnMut() -> f64>(
        &mut self,
        name: &str,
        metric_name: &str,
        mut f: F,
    ) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut wall = Vec::with_capacity(self.measure_iters);
        let mut met = Vec::with_capacity(self.measure_iters);
        for _ in 0..self.measure_iters {
            let t0 = WallTimer::start();
            let m = f();
            wall.push(t0.elapsed_s());
            met.push(m);
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            wall: Summary::of(&wall),
            metric: Some((metric_name.to_string(), Summary::of(&met))),
            extras: Vec::new(),
        });
        self.results.last().unwrap()
    }

    /// Like [`Self::bench_metric`], but the closure also returns
    /// observability counters (name → value); the last iteration's
    /// counters are attached to the row and printed after it.  The DES
    /// is deterministic, so the counters are identical across
    /// iterations — keeping one copy is lossless.
    pub fn bench_metric_counters<F: FnMut() -> (f64, Vec<(String, f64)>)>(
        &mut self,
        name: &str,
        metric_name: &str,
        mut f: F,
    ) -> &BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut wall = Vec::with_capacity(self.measure_iters);
        let mut met = Vec::with_capacity(self.measure_iters);
        let mut extras = Vec::new();
        for _ in 0..self.measure_iters {
            let t0 = WallTimer::start();
            let (m, e) = f();
            wall.push(t0.elapsed_s());
            met.push(m);
            extras = e;
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            wall: Summary::of(&wall),
            metric: Some((metric_name.to_string(), Summary::of(&met))),
            extras,
        });
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Render a report table.
    pub fn report(&self, title: &str) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n== {title} ==\n"));
        let name_w = self
            .results
            .iter()
            .map(|r| r.name.len())
            .max()
            .unwrap_or(8)
            .max(8);
        out.push_str(&format!(
            "{:<w$}  {:>12}  {:>12}  {:>12}  {:>10}\n",
            "bench", "median", "p05", "p95", "n",
            w = name_w
        ));
        for r in &self.results {
            // Metric rows still carry the wall clock per run —
            // informational (the gate compares virtual-time metrics
            // only), but simulator-speed regressions stay visible.
            let (med, p05, p95, label) = match &r.metric {
                Some((mname, m)) => (
                    m.median,
                    m.p05,
                    m.p95,
                    format!(" [{mname}] wall={}", fmt_seconds(r.wall.median)),
                ),
                None => (r.wall.median, r.wall.p05, r.wall.p95, String::new()),
            };
            let mut label = label;
            for (k, v) in &r.extras {
                label.push_str(&format!(" {k}={v}"));
            }
            out.push_str(&format!(
                "{:<w$}  {:>12}  {:>12}  {:>12}  {:>10}{}\n",
                r.name,
                fmt_seconds(med),
                fmt_seconds(p05),
                fmt_seconds(p95),
                r.wall.n,
                label,
                w = name_w
            ));
        }
        out
    }

    /// Print the report to stdout.
    pub fn print_report(&self, title: &str) {
        print!("{}", self.report(title));
    }
}

/// A grouped-bar table mirroring the paper's figures: one row per
/// process pair, one column per version, plus speedups vs. a baseline
/// column — exactly how Figs. 3, 4 and 7 annotate their bars.
pub struct FigureTable {
    pub title: String,
    pub row_label: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<f64>)>,
    /// Index of the baseline column speedups are computed against.
    pub baseline: usize,
    /// How cell values are formatted.
    pub unit: Unit,
    /// Annotate speedup columns (the paper only does so for the time
    /// figures 3, 4 and 7).
    pub show_speedup: bool,
}

/// Cell formatting of a [`FigureTable`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Unit {
    Seconds,
    /// Dimensionless ratio (ω figures).
    Ratio,
    /// Integer count (iteration figures).
    Count,
}

impl FigureTable {
    pub fn new(title: &str, row_label: &str, columns: &[&str], baseline: usize) -> Self {
        FigureTable {
            title: title.to_string(),
            row_label: row_label.to_string(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            baseline,
            unit: Unit::Seconds,
            show_speedup: true,
        }
    }

    /// Builder-style unit/speedup configuration.
    pub fn with_unit(mut self, unit: Unit, show_speedup: bool) -> Self {
        self.unit = unit;
        self.show_speedup = show_speedup;
        self
    }

    pub fn row(&mut self, label: &str, values: Vec<f64>) {
        assert_eq!(values.len(), self.columns.len(), "row width mismatch");
        self.rows.push((label.to_string(), values));
    }

    fn fmt_cell(&self, v: f64) -> String {
        match self.unit {
            Unit::Seconds => fmt_seconds(v),
            Unit::Ratio => format!("{v:.2}"),
            Unit::Count => format!("{v:.0}"),
        }
    }

    /// Render: value columns followed by speedup-vs-baseline columns.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        out.push_str(&format!("{:<12}", self.row_label));
        for c in &self.columns {
            out.push_str(&format!("{:>14}", c));
        }
        if self.show_speedup {
            for (i, c) in self.columns.iter().enumerate() {
                if i != self.baseline {
                    out.push_str(&format!("{:>14}", format!("S({c})")));
                }
            }
        }
        out.push('\n');
        for (label, vals) in &self.rows {
            out.push_str(&format!("{:<12}", label));
            for v in vals {
                out.push_str(&format!("{:>14}", self.fmt_cell(*v)));
            }
            if self.show_speedup {
                let base = vals[self.baseline];
                for (i, v) in vals.iter().enumerate() {
                    if i != self.baseline {
                        out.push_str(&format!("{:>14}", format!("{:.2}x", base / v)));
                    }
                }
            }
            out.push('\n');
        }
        out
    }

    /// Cell value at row `r`, column `col`.
    pub fn value(&self, r: usize, col: usize) -> f64 {
        self.rows[r].1[col]
    }

    /// Speedup of column `col` over the baseline, for row `r`.
    pub fn speedup(&self, r: usize, col: usize) -> f64 {
        let (_, vals) = &self.rows[r];
        vals[self.baseline] / vals[col]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_records_samples() {
        let mut b = Bench { warmup_iters: 0, measure_iters: 3, results: vec![] };
        b.bench("noop", || {});
        assert_eq!(b.results().len(), 1);
        assert_eq!(b.results()[0].wall.n, 3);
        assert!(b.results()[0].wall.median >= 0.0);
    }

    #[test]
    fn bench_metric_records_metric() {
        let mut b = Bench { warmup_iters: 0, measure_iters: 4, results: vec![] };
        let mut k = 0.0;
        b.bench_metric("m", "sim_s", || {
            k += 1.0;
            k
        });
        let (name, m) = b.results()[0].metric.clone().unwrap();
        assert_eq!(name, "sim_s");
        assert_eq!(m.n, 4);
        // warmup skipped, so samples are 1..=4 → median 2.5
        assert_eq!(m.median, 2.5);
    }

    #[test]
    fn report_contains_rows() {
        let mut b = Bench { warmup_iters: 0, measure_iters: 2, results: vec![] };
        b.bench("alpha", || {});
        b.bench("beta", || {});
        let rep = b.report("t");
        assert!(rep.contains("alpha"));
        assert!(rep.contains("beta"));
        assert!(rep.contains("median"));
    }

    #[test]
    fn metric_rows_report_wall_clock_too() {
        let mut b = Bench { warmup_iters: 0, measure_iters: 2, results: vec![] };
        b.bench_metric("m", "sim_s", || 1.0);
        let rep = b.report("t");
        assert!(rep.contains("[sim_s] wall="), "{rep}");
    }

    #[test]
    fn metric_counter_rows_report_extras() {
        let mut b = Bench { warmup_iters: 0, measure_iters: 2, results: vec![] };
        b.bench_metric_counters("m", "sim_s", || {
            (1.5, vec![("engine.events".to_string(), 42.0)])
        });
        let r = &b.results()[0];
        assert_eq!(r.extras, vec![("engine.events".to_string(), 42.0)]);
        let rep = b.report("t");
        assert!(rep.contains("engine.events=42"), "{rep}");
    }

    #[test]
    fn figure_table_speedups() {
        let mut t = FigureTable::new("fig", "pair", &["COL", "RMA1", "RMA2"], 0);
        t.row("20->40", vec![2.0, 4.0, 1.0]);
        assert!((t.speedup(0, 1) - 0.5).abs() < 1e-12);
        assert!((t.speedup(0, 2) - 2.0).abs() < 1e-12);
        let r = t.render();
        assert!(r.contains("0.50x"));
        assert!(r.contains("2.00x"));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn figure_table_rejects_bad_row() {
        let mut t = FigureTable::new("fig", "pair", &["a", "b"], 0);
        t.row("x", vec![1.0]);
    }

    fn doc(entries: &[(&str, f64)]) -> Json {
        Json::obj(vec![
            ("schema", Json::num(1.0)),
            (
                "entries",
                Json::Obj(
                    entries.iter().map(|&(k, v)| (k.to_string(), Json::Num(v))).collect(),
                ),
            ),
        ])
    }

    #[test]
    fn compare_passes_within_tolerance_and_fails_beyond() {
        let base = doc(&[("a", 1.0), ("b", 2.0)]);
        // 9% slower: within the 10% gate.
        let ok = doc(&[("a", 1.09), ("b", 2.0)]);
        let cmp = compare_bench(&base, &ok, 0.10);
        assert!(cmp.passed(), "{cmp:?}");
        assert_eq!(cmp.compared, 2);
        // 11% slower on one entry: the gate must fail and name it.
        let bad = doc(&[("a", 1.11), ("b", 2.0)]);
        let cmp = compare_bench(&base, &bad, 0.10);
        assert!(!cmp.passed());
        assert_eq!(cmp.regressions.len(), 1);
        assert!(cmp.regressions[0].contains('a'), "{:?}", cmp.regressions);
    }

    #[test]
    fn compare_flags_missing_entries_and_notes_new_ones() {
        let base = doc(&[("a", 1.0)]);
        let cur = doc(&[("b", 5.0)]);
        let cmp = compare_bench(&base, &cur, 0.10);
        assert!(!cmp.passed(), "a vanished — must fail");
        assert!(cmp.regressions[0].contains("missing"));
        assert!(cmp.notes.iter().any(|n| n.contains("new entry")), "{:?}", cmp.notes);
    }

    #[test]
    fn compare_bootstrap_baseline_passes() {
        let base = doc(&[]);
        let cur = doc(&[("a", 1.0)]);
        let cmp = compare_bench(&base, &cur, 0.10);
        assert!(cmp.passed());
        assert_eq!(cmp.compared, 0);
        assert!(cmp.notes.iter().any(|n| n.contains("bootstrap")), "{:?}", cmp.notes);
    }

    #[test]
    fn compare_rejects_mismatched_provenance() {
        // quick-vs-full documents are never comparable.
        let mut base = doc(&[("a", 1.0)]);
        let mut cur = doc(&[("a", 1.0)]);
        if let (Json::Obj(b), Json::Obj(c)) = (&mut base, &mut cur) {
            b.insert("mode".into(), Json::str("quick"));
            c.insert("mode".into(), Json::str("full"));
        }
        let cmp = compare_bench(&base, &cur, 0.1);
        assert!(!cmp.passed());
        assert!(cmp.regressions[0].contains("mode mismatch"), "{:?}", cmp.regressions);
        // A document without provenance still compares (back-compat).
        let cmp = compare_bench(&doc(&[("a", 1.0)]), &doc(&[("a", 1.0)]), 0.1);
        assert!(cmp.passed());
    }

    #[test]
    fn wall_clock_metrics_warn_but_never_gate() {
        // Top-level wall_s: a 2x slowdown draws a note, never a failure.
        let mut base = doc(&[("a", 1.0)]);
        let mut cur = doc(&[("a", 1.0)]);
        if let (Json::Obj(b), Json::Obj(c)) = (&mut base, &mut cur) {
            b.insert("wall_s".into(), Json::Num(1.0));
            c.insert("wall_s".into(), Json::Num(2.0));
        }
        let cmp = compare_bench(&base, &cur, 0.10);
        assert!(cmp.passed(), "{:?}", cmp.regressions);
        assert!(
            cmp.notes.iter().any(|n| n.contains("wall_s") && n.contains("soft")),
            "{:?}",
            cmp.notes
        );
        // Within the 25% soft tolerance: silent.
        if let Json::Obj(c) = &mut cur {
            c.insert("wall_s".into(), Json::Num(1.2));
        }
        let cmp = compare_bench(&base, &cur, 0.10);
        assert!(cmp.passed());
        assert!(!cmp.notes.iter().any(|n| n.contains("wall_s")), "{:?}", cmp.notes);
    }

    #[test]
    fn wall_clock_entries_are_soft_even_when_missing() {
        // `*.wall_s` entries regress or vanish without failing the gate;
        // hard entries alongside them still gate normally.
        let base = doc(&[("scenario.wall_s", 1.0), ("a", 1.0)]);
        let cur = doc(&[("scenario.wall_s", 10.0), ("a", 1.0)]);
        let cmp = compare_bench(&base, &cur, 0.10);
        assert!(cmp.passed(), "{:?}", cmp.regressions);
        assert_eq!(cmp.compared, 2);
        assert!(cmp.notes.iter().any(|n| n.contains("scenario.wall_s")), "{:?}", cmp.notes);
        let cmp = compare_bench(&base, &doc(&[("a", 1.0)]), 0.10);
        assert!(cmp.passed(), "{:?}", cmp.regressions);
        assert!(
            cmp.notes.iter().any(|n| n.contains("missing") && n.contains("soft")),
            "{:?}",
            cmp.notes
        );
        // A *hard* entry vanishing still fails.
        let cmp = compare_bench(&base, &doc(&[("scenario.wall_s", 1.0)]), 0.10);
        assert!(!cmp.passed());
    }

    #[test]
    fn compare_rejects_malformed_documents() {
        let cmp = compare_bench(&Json::Null, &doc(&[]), 0.1);
        assert!(!cmp.passed());
        // Improvements are notes, not failures.
        let base = doc(&[("a", 2.0)]);
        let cur = doc(&[("a", 1.0)]);
        let cmp = compare_bench(&base, &cur, 0.1);
        assert!(cmp.passed());
        assert!(cmp.notes.iter().any(|n| n.contains("improved")));
    }
}
