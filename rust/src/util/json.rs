//! Minimal JSON value type, recursive-descent parser and printer.
//!
//! Used for the config system (`config/`), the experiment reports
//! (`monitor/`), and the AOT artifact manifest emitted by
//! `python/compile/aot.py`.  Implements the full JSON grammar
//! (RFC 8259) with the usual restrictions: numbers are f64, object keys
//! are strings, input must be UTF-8.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset and message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    // ------------------------------------------------------ accessors

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|v| v as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Dotted-path lookup: `get_path("net.inter.alpha")`.
    pub fn get_path(&self, path: &str) -> Option<&Json> {
        let mut cur = self;
        for seg in path.split('.') {
            cur = cur.get(seg)?;
        }
        Some(cur)
    }

    // ---------------------------------------------------- constructors

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num<T: Into<f64>>(v: T) -> Json {
        Json::Num(v.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    // -------------------------------------------------------- parsing

    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    // ------------------------------------------------------- printing

    /// Compact single-line rendering.
    pub fn to_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty rendering with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{}", n));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_compact())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.keyword("true", Json::Bool(true)),
            Some(b'f') => self.keyword("false", Json::Bool(false)),
            Some(b'n') => self.keyword("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn keyword(&mut self, kw: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(kw.as_bytes()) {
            self.i += kw.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{kw}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e') | Some(b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+') | Some(b'-')) {
                self.i += 1;
            }
            while self.peek().is_some_and(|c| c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let cp = self.hex4()?;
                            // Handle surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.peek() == Some(b'\\') {
                                    self.i += 1;
                                    self.expect(b'u')?;
                                    let lo = self.hex4()?;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c).ok_or_else(|| self.err("bad surrogate"))?
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?
                            };
                            out.push(ch);
                            continue; // hex4 advanced i already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 char.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        if self.i + 4 > self.b.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.b[self.i..self.i + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get_path("a").unwrap().as_arr().unwrap()[0].as_f64(), Some(1.0));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" é 😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\nb\t\"q\" é 😀");
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src =
            r#"{"net":{"alpha":1.6e-06,"beta":8.6e-11},"nodes":8,"names":["a","b"],"on":true}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_compact()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn errors_are_reported() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse(r#""unterminated"#).is_err());
    }

    #[test]
    fn get_path_traverses() {
        let v = Json::parse(r#"{"a":{"b":{"c":7}}}"#).unwrap();
        assert_eq!(v.get_path("a.b.c").unwrap().as_f64(), Some(7.0));
        assert!(v.get_path("a.x.c").is_none());
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(5.0).to_compact(), "5");
        assert_eq!(Json::Num(5.25).to_compact(), "5.25");
    }

    #[test]
    fn as_usize_rejects_negatives_and_fractions() {
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
        assert_eq!(Json::Num(20.0).as_usize(), Some(20));
    }
}
