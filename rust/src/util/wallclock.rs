//! The one place in the crate allowed to read the host's wall clock.
//!
//! The simulator is byte-deterministic: every quantity that reaches
//! virtual time, counters, or report JSON must be a pure function of
//! the run's inputs.  Wall-clock reads (`std::time::Instant`,
//! `SystemTime`) are the easiest way to break that by accident, so the
//! `det::wall-clock-in-sim` lint in [`crate::analysis`] forbids them
//! everywhere *except* this module.  Harness code that wants a soft
//! `wall_s` metric (stripped from determinism comparisons, see
//! `strip_wall` in the tests) goes through [`WallTimer`]; sim-path
//! code must never need one — durations there come from virtual time.

use std::time::Instant;

/// A started wall-clock stopwatch.  Thin wrapper over
/// [`std::time::Instant`] so callers never name the std type directly.
#[derive(Clone, Copy, Debug)]
pub struct WallTimer(Instant);

impl WallTimer {
    /// Start a stopwatch now.
    pub fn start() -> WallTimer {
        WallTimer(Instant::now())
    }

    /// Seconds elapsed since [`WallTimer::start`].
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    /// Seconds elapsed, floored at 1 ns so soft `wall_s` metrics never
    /// hit the bench gate's divide-by-zero guard.
    pub fn elapsed_s_nonzero(&self) -> f64 {
        self.elapsed_s().max(1e-9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_advances_and_nonzero_floor_holds() {
        let t = WallTimer::start();
        let a = t.elapsed_s_nonzero();
        assert!(a >= 1e-9);
        assert!(t.elapsed_s() >= 0.0);
        assert!(t.elapsed_s_nonzero() >= a);
    }
}
