//! Tiny declarative command-line parser (clap is not available offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`
//! options, positionals, defaults and an auto-generated `--help`.

use std::collections::BTreeMap;

/// The one `on|off` toggle grammar, shared by CLI options and config
/// strings (e.g. `--win-pool on` / `"win_pool": "on"`) so the two
/// surfaces cannot drift.
pub fn parse_toggle(s: &str) -> Option<bool> {
    match s.to_ascii_lowercase().as_str() {
        "on" | "true" | "1" | "yes" => Some(true),
        "off" | "false" | "0" | "no" => Some(false),
        _ => None,
    }
}

/// Specification of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
    pub is_flag: bool,
}

/// A parsed argument set.
#[derive(Clone, Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    /// Option names the user actually passed (vs seeded defaults).
    explicit: Vec<String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    /// Like [`Args::get`], but only when the user passed the option
    /// explicitly — seeded defaults return `None`.  Lets presets like
    /// `--quick` keep their values unless actually overridden.
    pub fn get_explicit(&self, name: &str) -> Option<&str> {
        if self.explicit.iter().any(|k| k == name) {
            self.get(name)
        } else {
            None
        }
    }

    pub fn get_usize(&self, name: &str) -> Option<usize> {
        self.get(name).and_then(|s| s.parse().ok())
    }

    pub fn get_f64(&self, name: &str) -> Option<f64> {
        self.get(name).and_then(|s| s.parse().ok())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

/// A subcommand with its option specs.
#[derive(Debug)]
pub struct Command {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
}

impl Command {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Command { name, about, opts: Vec::new() }
    }

    pub fn opt(mut self, name: &'static str, default: &str, help: &'static str) -> Self {
        self.opts.push(OptSpec {
            name,
            help,
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    pub fn opt_required(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: false });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true });
        self
    }

    fn usage(&self, prog: &str) -> String {
        let mut s = format!("{} {} — {}\n\noptions:\n", prog, self.name, self.about);
        for o in &self.opts {
            let head = if o.is_flag {
                format!("  --{}", o.name)
            } else if let Some(d) = &o.default {
                format!("  --{} <val> (default {})", o.name, d)
            } else {
                format!("  --{} <val> (required)", o.name)
            };
            s.push_str(&format!("{head:<44}{}\n", o.help));
        }
        s
    }

    /// Parse this command's arguments (after the subcommand word).
    pub fn parse(&self, prog: &str, argv: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        // Seed defaults.
        for o in &self.opts {
            if let Some(d) = &o.default {
                args.values.insert(o.name.to_string(), d.clone());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(self.usage(prog));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage(prog)))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    args.flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| format!("--{key} needs a value"))?
                        }
                    };
                    args.explicit.push(key.clone());
                    args.values.insert(key, val);
                }
            } else {
                args.positionals.push(a.clone());
            }
            i += 1;
        }
        // Check required.
        for o in &self.opts {
            if !o.is_flag && o.default.is_none() && !args.values.contains_key(o.name) {
                return Err(format!("missing required --{}\n\n{}", o.name, self.usage(prog)));
            }
        }
        Ok(args)
    }
}

/// Top-level CLI: a set of subcommands.
pub struct Cli {
    pub prog: &'static str,
    pub about: &'static str,
    pub commands: Vec<Command>,
}

impl Cli {
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\ncommands:\n", self.prog, self.about);
        for c in &self.commands {
            s.push_str(&format!("  {:<24}{}\n", c.name, c.about));
        }
        s.push_str(&format!("\nrun `{} <command> --help` for details\n", self.prog));
        s
    }

    /// Dispatch: returns (command name, parsed args) or a usage/help message.
    pub fn parse(&self, argv: &[String]) -> Result<(&Command, Args), String> {
        let Some(cmd_name) = argv.first() else {
            return Err(self.usage());
        };
        if cmd_name == "--help" || cmd_name == "-h" || cmd_name == "help" {
            return Err(self.usage());
        }
        let cmd = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| format!("unknown command '{cmd_name}'\n\n{}", self.usage()))?;
        let args = cmd.parse(self.prog, &argv[1..])?;
        Ok((cmd, args))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    fn test_cli() -> Cli {
        Cli {
            prog: "proteo",
            about: "test",
            commands: vec![Command::new("run", "run it")
                .opt("pairs", "all", "which pairs")
                .opt("reps", "5", "repetitions")
                .opt_required("method", "method name")
                .flag("verbose", "more output")],
        }
    }

    #[test]
    fn parses_options_and_flags() {
        let cli = test_cli();
        let (cmd, args) = cli
            .parse(&sv(&["run", "--method", "col", "--reps=9", "--verbose"]))
            .unwrap();
        assert_eq!(cmd.name, "run");
        assert_eq!(args.get("method"), Some("col"));
        assert_eq!(args.get_usize("reps"), Some(9));
        assert_eq!(args.get("pairs"), Some("all")); // default
        assert!(args.flag("verbose"));
        assert!(!args.flag("quiet"));
    }

    #[test]
    fn toggle_values_parse() {
        // The shared on|off grammar behind `--win-pool` and config
        // strings, driven through a parsed option value.
        let cli = Cli {
            prog: "p",
            about: "t",
            commands: vec![Command::new("run", "r").opt("win-pool", "off", "pool toggle")],
        };
        let (_, a) = cli.parse(&sv(&["run"])).unwrap();
        assert_eq!(a.get("win-pool").and_then(parse_toggle), Some(false)); // default
        let (_, a) = cli.parse(&sv(&["run", "--win-pool", "on"])).unwrap();
        assert_eq!(a.get("win-pool").and_then(parse_toggle), Some(true));
        let (_, a) = cli.parse(&sv(&["run", "--win-pool=ON"])).unwrap();
        assert_eq!(a.get("win-pool").and_then(parse_toggle), Some(true));
        let (_, a) = cli.parse(&sv(&["run", "--win-pool", "sideways"])).unwrap();
        assert_eq!(a.get("win-pool").and_then(parse_toggle), None);
        assert_eq!(a.get("missing").and_then(parse_toggle), None);
    }

    #[test]
    fn rma_chunk_option_round_trips() {
        // The `--rma-chunk` grammar of `proteo run` / `proteo scenario`:
        // a non-negative KiB count, default 0 (off).
        let cli = Cli {
            prog: "p",
            about: "t",
            commands: vec![Command::new("run", "r")
                .opt("rma-chunk", "0", "pipelined RMA registration chunk (KiB; 0 = off)")],
        };
        let (_, a) = cli.parse(&sv(&["run"])).unwrap();
        assert_eq!(a.get("rma-chunk").and_then(|s| s.parse::<u64>().ok()), Some(0));
        let (_, a) = cli.parse(&sv(&["run", "--rma-chunk", "1024"])).unwrap();
        assert_eq!(a.get("rma-chunk").and_then(|s| s.parse::<u64>().ok()), Some(1024));
        let (_, a) = cli.parse(&sv(&["run", "--rma-chunk=256"])).unwrap();
        assert_eq!(a.get("rma-chunk").and_then(|s| s.parse::<u64>().ok()), Some(256));
        // Negative / non-numeric values fail the u64 parse (the command
        // layer turns this into the usage error).
        let (_, a) = cli.parse(&sv(&["run", "--rma-chunk", "-1"])).unwrap();
        assert_eq!(a.get("rma-chunk").and_then(|s| s.parse::<u64>().ok()), None);
    }

    #[test]
    fn explicit_options_are_distinguished_from_defaults() {
        let cli = test_cli();
        let (_, args) = cli.parse(&sv(&["run", "--method", "col"])).unwrap();
        // Seeded default: visible via get, invisible via get_explicit —
        // this is what keeps `--quick` presets from being overridden.
        assert_eq!(args.get("reps"), Some("5"));
        assert_eq!(args.get_explicit("reps"), None);
        assert_eq!(args.get_explicit("method"), Some("col"));
        let (_, args) = cli.parse(&sv(&["run", "--method", "col", "--reps=9"])).unwrap();
        assert_eq!(args.get_explicit("reps"), Some("9"));
    }

    #[test]
    fn missing_required_errors() {
        let cli = test_cli();
        let err = cli.parse(&sv(&["run"])).unwrap_err();
        assert!(err.contains("--method"));
    }

    #[test]
    fn unknown_option_errors() {
        let cli = test_cli();
        let err = cli.parse(&sv(&["run", "--method", "x", "--bogus", "1"])).unwrap_err();
        assert!(err.contains("bogus"));
    }

    #[test]
    fn unknown_command_shows_usage() {
        let cli = test_cli();
        let err = cli.parse(&sv(&["frob"])).unwrap_err();
        assert!(err.contains("unknown command"));
        assert!(err.contains("commands:"));
    }

    #[test]
    fn help_returns_usage() {
        let cli = test_cli();
        assert!(cli.parse(&sv(&[])).is_err());
        assert!(cli.parse(&sv(&["--help"])).unwrap_err().contains("commands:"));
        assert!(cli.parse(&sv(&["run", "--help"])).unwrap_err().contains("options:"));
    }

    #[test]
    fn positionals_collected() {
        let cli = test_cli();
        let (_, args) = cli.parse(&sv(&["run", "--method", "m", "a", "b"])).unwrap();
        assert_eq!(args.positionals(), &["a".to_string(), "b".to_string()]);
    }
}
