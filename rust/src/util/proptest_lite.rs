//! In-repo property-based testing kit (proptest is not in the offline
//! vendor set).
//!
//! Provides seeded generators, a configurable case count, and greedy
//! shrinking for the built-in strategies.  The API is deliberately
//! small: a `Strategy<T>` generates values from an [`Rng`] and can
//! propose smaller candidates for a failing value.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla rpath in this image)
//! use proteo::util::proptest_lite::*;
//! check("sum is commutative", usizes(0, 100).pair(usizes(0, 100)), |(a, b)| {
//!     a + b == b + a
//! });
//! ```

use crate::util::rng::Rng;

/// Number of cases per property (override with `PROPTEST_CASES`).
pub fn default_cases() -> usize {
    std::env::var("PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// A value generator + shrinker.
pub trait Strategy {
    type Value: Clone + std::fmt::Debug;
    fn generate(&self, rng: &mut Rng) -> Self::Value;
    /// Candidate "smaller" values to try when `v` fails; may be empty.
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }

    /// Combine with another strategy into a pair.
    fn pair<B: Strategy>(self, other: B) -> Pair<Self, B>
    where
        Self: Sized,
    {
        Pair(self, other)
    }

    /// Map the generated value (shrinking degrades to none).
    fn map_gen<U: Clone + std::fmt::Debug, F: Fn(Self::Value) -> U>(
        self,
        f: F,
    ) -> MapGen<Self, F>
    where
        Self: Sized,
    {
        MapGen(self, f)
    }
}

/// Run a property over `default_cases()` random cases; on failure,
/// greedily shrink and panic with the minimal counterexample.
pub fn check<S: Strategy>(name: &str, strat: S, prop: impl Fn(S::Value) -> bool) {
    check_seeded(name, strat, prop, 0xC0FFEE ^ fxhash(name));
}

/// `check` with an explicit seed (tests that need reproducibility).
pub fn check_seeded<S: Strategy>(
    name: &str,
    strat: S,
    prop: impl Fn(S::Value) -> bool,
    seed: u64,
) {
    let mut rng = Rng::new(seed);
    let cases = default_cases();
    for case in 0..cases {
        let v = strat.generate(&mut rng);
        if !prop(v.clone()) {
            let minimal = shrink_loop(&strat, v, &prop);
            panic!(
                "property '{name}' failed at case {case}/{cases} (seed {seed:#x}).\n\
                 minimal counterexample: {minimal:?}"
            );
        }
    }
}

fn shrink_loop<S: Strategy>(
    strat: &S,
    mut failing: S::Value,
    prop: &impl Fn(S::Value) -> bool,
) -> S::Value {
    // Greedy descent, bounded to avoid pathological loops.
    for _ in 0..1000 {
        let mut advanced = false;
        for cand in strat.shrink(&failing) {
            if !prop(cand.clone()) {
                failing = cand;
                advanced = true;
                break;
            }
        }
        if !advanced {
            break;
        }
    }
    failing
}

fn fxhash(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

// ------------------------------------------------------------------
// Built-in strategies
// ------------------------------------------------------------------

/// Uniform usize in `[lo, hi]` (inclusive), shrinking toward `lo`.
pub struct Usizes {
    lo: usize,
    hi: usize,
}

pub fn usizes(lo: usize, hi: usize) -> Usizes {
    assert!(lo <= hi);
    Usizes { lo, hi }
}

impl Strategy for Usizes {
    type Value = usize;
    fn generate(&self, rng: &mut Rng) -> usize {
        rng.gen_range(self.lo, self.hi + 1)
    }
    fn shrink(&self, v: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2);
            out.push(*v - 1);
        }
        out.dedup();
        out
    }
}

/// Uniform f64 in `[lo, hi)`, shrinking toward lo and round numbers.
pub struct F64s {
    lo: f64,
    hi: f64,
}

pub fn f64s(lo: f64, hi: f64) -> F64s {
    F64s { lo, hi }
}

impl Strategy for F64s {
    type Value = f64;
    fn generate(&self, rng: &mut Rng) -> f64 {
        rng.gen_range_f64(self.lo, self.hi)
    }
    fn shrink(&self, v: &f64) -> Vec<f64> {
        let mut out = Vec::new();
        if *v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (*v - self.lo) / 2.0);
            let r = v.round();
            if r != *v && r >= self.lo && r < self.hi {
                out.push(r);
            }
        }
        out
    }
}

/// Vec of a base strategy with length in `[min_len, max_len]`,
/// shrinking by halving the length then shrinking elements.
pub struct VecOf<S> {
    elem: S,
    min_len: usize,
    max_len: usize,
}

pub fn vec_of<S: Strategy>(elem: S, min_len: usize, max_len: usize) -> VecOf<S> {
    assert!(min_len <= max_len);
    VecOf { elem, min_len, max_len }
}

impl<S: Strategy> Strategy for VecOf<S> {
    type Value = Vec<S::Value>;
    fn generate(&self, rng: &mut Rng) -> Vec<S::Value> {
        let len = rng.gen_range(self.min_len, self.max_len + 1);
        (0..len).map(|_| self.elem.generate(rng)).collect()
    }
    fn shrink(&self, v: &Vec<S::Value>) -> Vec<Vec<S::Value>> {
        let mut out = Vec::new();
        // Try dropping halves / single elements.
        if v.len() > self.min_len {
            let half = self.min_len.max(v.len() / 2);
            out.push(v[..half].to_vec());
            let mut minus_last = v.clone();
            minus_last.pop();
            out.push(minus_last);
        }
        // Try shrinking each element (first few positions only).
        for i in 0..v.len().min(4) {
            for cand in self.elem.shrink(&v[i]) {
                let mut w = v.clone();
                w[i] = cand;
                out.push(w);
            }
        }
        out
    }
}

/// Choose uniformly from a fixed set.
pub struct OneOf<T> {
    items: Vec<T>,
}

pub fn one_of<T: Clone + std::fmt::Debug>(items: &[T]) -> OneOf<T> {
    assert!(!items.is_empty());
    OneOf { items: items.to_vec() }
}

impl<T: Clone + std::fmt::Debug> Strategy for OneOf<T> {
    type Value = T;
    fn generate(&self, rng: &mut Rng) -> T {
        self.items[rng.gen_range(0, self.items.len())].clone()
    }
}

/// Pair combinator.
pub struct Pair<A, B>(A, B);

impl<A: Strategy, B: Strategy> Strategy for Pair<A, B> {
    type Value = (A::Value, B::Value);
    fn generate(&self, rng: &mut Rng) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        for a in self.0.shrink(&v.0) {
            out.push((a, v.1.clone()));
        }
        for b in self.1.shrink(&v.1) {
            out.push((v.0.clone(), b));
        }
        out
    }
}

/// Map combinator (generation only).
pub struct MapGen<S, F>(S, F);

impl<S: Strategy, U: Clone + std::fmt::Debug, F: Fn(S::Value) -> U> Strategy for MapGen<S, F> {
    type Value = U;
    fn generate(&self, rng: &mut Rng) -> U {
        (self.1)(self.0.generate(rng))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add commutes", usizes(0, 1000).pair(usizes(0, 1000)), |(a, b)| {
            a + b == b + a
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimum() {
        let result = std::panic::catch_unwind(|| {
            check_seeded("x < 50", usizes(0, 1000), |x| x < 50, 1234);
        });
        let err = *result.unwrap_err().downcast::<String>().unwrap();
        // Greedy shrinking should land exactly on the boundary value 50.
        assert!(err.contains("counterexample: 50"), "got: {err}");
    }

    #[test]
    fn vec_strategy_respects_bounds() {
        let mut rng = Rng::new(1);
        let strat = vec_of(usizes(5, 9), 2, 6);
        for _ in 0..200 {
            let v = strat.generate(&mut rng);
            assert!((2..=6).contains(&v.len()));
            assert!(v.iter().all(|&x| (5..=9).contains(&x)));
        }
    }

    #[test]
    fn vec_shrinks_toward_shorter() {
        let strat = vec_of(usizes(0, 10), 0, 8);
        let v = vec![3, 7, 2, 9];
        let shrunk = strat.shrink(&v);
        assert!(shrunk.iter().any(|w| w.len() < v.len()));
    }

    #[test]
    fn one_of_only_produces_members() {
        let mut rng = Rng::new(2);
        let strat = one_of(&[10usize, 20, 30]);
        for _ in 0..100 {
            assert!([10, 20, 30].contains(&strat.generate(&mut rng)));
        }
    }

    #[test]
    fn f64_bounds_respected() {
        let mut rng = Rng::new(3);
        let strat = f64s(-2.0, 2.0);
        for _ in 0..500 {
            let x = strat.generate(&mut rng);
            assert!((-2.0..2.0).contains(&x));
        }
    }

    #[test]
    fn map_gen_applies() {
        let mut rng = Rng::new(4);
        let strat = usizes(1, 5).map_gen(|x| x * 10);
        for _ in 0..50 {
            let v = strat.generate(&mut rng);
            assert!(v % 10 == 0 && (10..=50).contains(&v));
        }
    }
}
