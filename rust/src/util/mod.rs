//! Small self-contained substrates that the rest of the crate builds on.
//!
//! The build environment is fully offline with **no** external crates
//! (the optional `xla` binding is feature-gated in [`crate::runtime`]),
//! so the usual ecosystem helpers (serde, clap, criterion, proptest,
//! rand, thiserror) are implemented here from scratch:
//!
//! * [`rng`]      — a seedable SplitMix64/xoshiro256** PRNG,
//! * [`stats`]    — summary statistics (median, percentiles, CI),
//! * [`json`]     — a JSON value type, parser and pretty-printer,
//! * [`cli`]      — a tiny declarative command-line parser,
//! * [`benchkit`] — a criterion-style benchmarking harness,
//! * [`proptest_lite`] — a property-testing kit with shrinking,
//! * [`wallclock`] — the sole wall-clock gateway (see
//!   `det::wall-clock-in-sim` in [`crate::analysis`]).

pub mod benchkit;
pub mod cli;
pub mod json;
pub mod proptest_lite;
pub mod rng;
pub mod stats;
pub mod wallclock;
