//! Ablations beyond the paper's evaluation, probing §VI's future-work
//! directions:
//!
//! * [`single_window`] — the proposed fix for the window-initialization
//!   overhead: one dynamic window per rank with all structures
//!   attached, versus MaM's one-window-per-structure design (§IV-B).
//! * [`registration_sweep`] — how the blocking RMA/COL ratio moves as
//!   the memory-registration rate varies: where RMA *would* overtake
//!   the collective, supporting the paper's conclusion that the
//!   initialization cost is the blocker.

use std::sync::Arc;

use crate::mam::{
    block_of, rma, DataKind, Method, Registry, Roles, SchedCache, Strategy, WinPoolPolicy,
};
use crate::netmodel::{NetParams, Topology};
use crate::proteo::run_median;
use crate::sam::{Sam, SamConfig};
use crate::simmpi::{MpiProc, MpiSim, WORLD};
use crate::util::benchkit::{FigureTable, Unit};

use super::FigOptions;

/// Time one blocking RMA redistribution (per-structure or fused
/// windows) over the merged group, without the application around it.
fn time_rma_blocking(
    ns: usize,
    nd: usize,
    sam: &SamConfig,
    net: &NetParams,
    fused: bool,
    lockall: bool,
) -> f64 {
    let n = ns.max(nd);
    let topo = Topology::new_cyclic(n.div_ceil(20).max(1), 20);
    let mut sim = MpiSim::new(topo, net.clone());
    let world = sim.world();
    let sam = sam.clone();
    sim.launch(n, move |p: MpiProc| {
        let rank = p.rank(WORLD);
        let roles = Roles { ns, nd, rank };
        let mut reg = Registry::new();
        // Sources carry their block; everyone registers the metadata.
        let s = Sam::new(sam.clone(), 7, p.gpid());
        if roles.is_source() {
            s.register_data(&mut reg, ns, rank);
        } else {
            for (name, total) in [
                ("A_vals", sam.matrix_elems),
                ("A_cols", sam.colind_elems),
                ("A_rowptr", sam.rowptr_elems),
            ] {
                reg.register(name, DataKind::Constant, total, crate::simmpi::Payload::virt(0));
            }
            reg.register(
                "x",
                DataKind::Variable,
                sam.vector_elems,
                crate::simmpi::Payload::virt(0),
            );
            let _ = block_of(1, 1, 0);
        }
        let which = reg.of_kind(DataKind::Constant);
        let t0 = p.now();
        let _ = if fused {
            rma::redistribute_blocking_fused(&p, WORLD, &roles, &reg, &which, lockall)
        } else {
            rma::redistribute_with(
                &p,
                WORLD,
                &roles,
                &reg,
                &which,
                rma::RedistOpts::new(lockall, WinPoolPolicy::off()),
            )
        };
        let dt = p.now() - t0;
        p.metrics(|m| m.mark_max("ablation.redist", dt));
    });
    sim.run().expect("ablation sim failed");
    let w = world.lock().unwrap();
    w.metrics.mark_at("ablation.redist").unwrap_or(f64::NAN)
}

/// Run the same blocking RMA-Lockall redistribution `passes` times in
/// one world under `policy`; returns each pass's redistribution time
/// (max over ranks).  With the pool on, the first pass registers cold
/// and later ones ride the pool — the §VI cold/warm comparison.
/// (The unchunked special case of [`time_rma_chunk_passes`]: chunk 0
/// delegates to the seed blocking path, bit for bit.)
fn time_rma_passes(
    ns: usize,
    nd: usize,
    sam: &SamConfig,
    net: &NetParams,
    policy: WinPoolPolicy,
    passes: u32,
) -> Vec<f64> {
    time_rma_chunk_passes(ns, nd, sam, net, policy, 0, passes)
}

/// Run the blocking RMA-Lockall redistribution `passes` times in one
/// world with chunked pipelined registration (`chunk_kib` KiB segments;
/// 0 = the seed unchunked path) under `policy`; returns each pass's
/// redistribution time.  Pass 1 is cold; with the pool on, pass 2 rides
/// the registration cache (warm) and the pipeline collapses.
fn time_rma_chunk_passes(
    ns: usize,
    nd: usize,
    sam: &SamConfig,
    net: &NetParams,
    policy: WinPoolPolicy,
    chunk_kib: u64,
    passes: u32,
) -> Vec<f64> {
    time_rma_lifecycle_passes(ns, nd, sam, net, policy, chunk_kib, true, passes)
}

/// [`time_rma_chunk_passes`] with the teardown pipeline explicit:
/// `dereg = true` is the full lifecycle (registration *and*
/// deregistration ride the wire), `dereg = false` the
/// registration-only pipeline (the pre-teardown chunked behaviour).
#[allow(clippy::too_many_arguments)]
fn time_rma_lifecycle_passes(
    ns: usize,
    nd: usize,
    sam: &SamConfig,
    net: &NetParams,
    policy: WinPoolPolicy,
    chunk_kib: u64,
    dereg: bool,
    passes: u32,
) -> Vec<f64> {
    let n = ns.max(nd);
    let topo = Topology::new_cyclic(n.div_ceil(20).max(1), 20);
    let mut sim = MpiSim::new(topo, net.clone());
    let world = sim.world();
    let sam = sam.clone();
    let chunk_elems = chunk_kib * 1024 / crate::simmpi::ELEM_BYTES;
    let opts = if dereg {
        rma::LifecycleOpts::full(chunk_elems)
    } else {
        rma::LifecycleOpts::reg_only(chunk_elems)
    };
    sim.launch(n, move |p: MpiProc| {
        let rank = p.rank(WORLD);
        let roles = Roles { ns, nd, rank };
        let mut reg = Registry::new();
        let s = Sam::new(sam.clone(), 7, p.gpid());
        if roles.is_source() {
            s.register_data(&mut reg, ns, rank);
        } else {
            for (name, total) in [
                ("A_vals", sam.matrix_elems),
                ("A_cols", sam.colind_elems),
                ("A_rowptr", sam.rowptr_elems),
            ] {
                reg.register(name, DataKind::Constant, total, crate::simmpi::Payload::virt(0));
            }
            reg.register(
                "x",
                DataKind::Variable,
                sam.vector_elems,
                crate::simmpi::Payload::virt(0),
            );
        }
        let which = reg.of_kind(DataKind::Constant);
        for pass in 1..=passes {
            let t0 = p.now();
            let _ = rma::redistribute_with(
                &p,
                WORLD,
                &roles,
                &reg,
                &which,
                rma::RedistOpts::new(true, policy).lifecycle(opts),
            );
            let dt = p.now() - t0;
            p.metrics(|m| m.mark_max(&format!("ablation.chunk{pass}"), dt));
        }
    });
    sim.run().expect("rma-chunk ablation sim failed");
    let w = world.lock().unwrap();
    (1..=passes)
        .map(|pass| w.metrics.mark_at(&format!("ablation.chunk{pass}")).unwrap_or(f64::NAN))
        .collect()
}

/// Run the blocking RMA-Lockall redistribution `passes` times in one
/// world, each rank carrying a persistent [`SchedCache`] across the
/// passes when `sched` is on; returns each pass's redistribution time.
/// Pass 1 builds every schedule cold; pass 2 replays the identical
/// `(from, to, structure, chunk)` shapes for a validation handshake.
fn time_rma_sched_passes(
    ns: usize,
    nd: usize,
    sam: &SamConfig,
    net: &NetParams,
    policy: WinPoolPolicy,
    sched: bool,
    passes: u32,
) -> Vec<f64> {
    let n = ns.max(nd);
    let topo = Topology::new_cyclic(n.div_ceil(20).max(1), 20);
    let mut sim = MpiSim::new(topo, net.clone());
    let world = sim.world();
    let sam = sam.clone();
    sim.launch(n, move |p: MpiProc| {
        let rank = p.rank(WORLD);
        let roles = Roles { ns, nd, rank };
        let mut reg = Registry::new();
        let s = Sam::new(sam.clone(), 7, p.gpid());
        if roles.is_source() {
            s.register_data(&mut reg, ns, rank);
        } else {
            for (name, total) in [
                ("A_vals", sam.matrix_elems),
                ("A_cols", sam.colind_elems),
                ("A_rowptr", sam.rowptr_elems),
            ] {
                reg.register(name, DataKind::Constant, total, crate::simmpi::Payload::virt(0));
            }
            reg.register(
                "x",
                DataKind::Variable,
                sam.vector_elems,
                crate::simmpi::Payload::virt(0),
            );
        }
        let which = reg.of_kind(DataKind::Constant);
        let mut cache = SchedCache::new();
        for pass in 1..=passes {
            let t0 = p.now();
            let opts = rma::RedistOpts::new(true, policy).sched(sched);
            let _ = if sched {
                rma::redistribute_sched(&p, WORLD, &roles, &reg, &which, opts, &mut cache)
            } else {
                rma::redistribute_with(&p, WORLD, &roles, &reg, &which, opts)
            };
            let dt = p.now() - t0;
            p.metrics(|m| m.mark_max(&format!("ablation.sched{pass}"), dt));
        }
    });
    sim.run().expect("sched-cache ablation sim failed");
    let w = world.lock().unwrap();
    (1..=passes)
        .map(|pass| w.metrics.mark_at(&format!("ablation.sched{pass}")).unwrap_or(f64::NAN))
        .collect()
}

/// Ablation: the persistent-schedule cache (`--sched-cache`).  Per
/// pair, the cache-off baseline, the cache's first (cold) pass — the
/// same redistribution plus the schedule build — and the replay pass,
/// which charges only the validation handshake.  The window pool stays
/// off so the columns isolate the schedule term from registration
/// warmth; the headline pair 20→160 is always included (its cold and
/// replay times are the bench-smoke `schedcache.20to160.*` metrics).
pub fn sched_cache(opts: &FigOptions) -> FigureTable {
    let mut t = FigureTable::new(
        "Ablation: schedule cache — off vs cold build vs warm replay, blocking RMA-Lockall",
        "NS->ND",
        &["cache-off", "cold", "replay"],
        0,
    );
    let mut pairs: Vec<(usize, usize)> = vec![(20, 160)];
    pairs.extend(opts.pairs().into_iter().filter(|&pr| pr != (20, 160)));
    for (ns, nd) in pairs {
        let spec = opts.spec(ns, nd, Method::RmaLockall, Strategy::Blocking);
        let off =
            time_rma_sched_passes(ns, nd, &spec.sam, &spec.net, WinPoolPolicy::off(), false, 1)[0];
        let cached =
            time_rma_sched_passes(ns, nd, &spec.sam, &spec.net, WinPoolPolicy::off(), true, 2);
        t.row(&format!("{ns}->{nd}"), vec![off, cached[0], cached[1]]);
    }
    t
}

/// Chunk sizes (KiB) swept by `proteo ablation rma-chunk`; index 0 is
/// the unchunked blocking baseline.  Shared with the planner's search
/// space so the ablation (and the `rmachunk.*` bench-gate metrics)
/// always cover the sizes `--planner auto` can actually pick.
pub use crate::mam::planner::CHUNK_CANDIDATES_KIB as RMA_CHUNK_SWEEP_KIB;

/// Ablation: chunked pipelined RMA registration (`--rma-chunk`).  Per
/// pair, a *cold* row (pool off: the paper's cold resize, where
/// pipelining hides registration behind the wire) and a *warm* row
/// (pool on, second pass: the pipeline collapses to pure wire time) —
/// one column per chunk size, with chunk=0 (the seed blocking path) as
/// the speedup baseline.  The cold sweet spot is the bench-smoke
/// `rmachunk.*.best` metric.
pub fn rma_chunk(opts: &FigOptions) -> FigureTable {
    let cols: Vec<String> = RMA_CHUNK_SWEEP_KIB
        .iter()
        .map(|&k| if k == 0 { "blocking".to_string() } else { format!("{k}KiB") })
        .collect();
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = FigureTable::new(
        "Ablation: chunked pipelined registration — cold vs warm, blocking RMA-Lockall",
        "NS->ND",
        &col_refs,
        0,
    );
    for (ns, nd) in opts.pairs() {
        let spec = opts.spec(ns, nd, Method::RmaLockall, Strategy::Blocking);
        let cold: Vec<f64> = RMA_CHUNK_SWEEP_KIB
            .iter()
            .map(|&k| {
                time_rma_chunk_passes(ns, nd, &spec.sam, &spec.net, WinPoolPolicy::off(), k, 1)[0]
            })
            .collect();
        let warm: Vec<f64> = RMA_CHUNK_SWEEP_KIB
            .iter()
            .map(|&k| {
                time_rma_chunk_passes(ns, nd, &spec.sam, &spec.net, WinPoolPolicy::on(), k, 2)[1]
            })
            .collect();
        t.row(&format!("{ns}->{nd} cold"), cold);
        t.row(&format!("{ns}->{nd} warm"), warm);
    }
    t
}

/// Ablation: the shrink-side teardown sweet spot (`--rma-dereg`).
/// Shrinks are where the serial `Win_free` teardown is the largest
/// remaining RMA term once registration is pipelined, so per shrink
/// pair this table shows two cold rows — the **full** lifecycle
/// pipeline (registration + deregistration riding the wire) and the
/// **reg-only** pipeline (the pre-teardown chunked behaviour, teardown
/// still serial) — one column per chunk size with the unchunked
/// blocking baseline first.  The gap between the rows is exactly what
/// the background `windereg-*` streams buy; the full row's minimum is
/// the shrink sweet spot fed to bench-smoke
/// (`rmachunk.160to20.best_cold`).  Grow pairs in the options are
/// ignored; the acceptance pair 160→20 is always included.
pub fn rma_chunk_shrink(opts: &FigOptions) -> FigureTable {
    let cols: Vec<String> = RMA_CHUNK_SWEEP_KIB
        .iter()
        .map(|&k| if k == 0 { "blocking".to_string() } else { format!("{k}KiB") })
        .collect();
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = FigureTable::new(
        "Ablation: shrink teardown pipeline — full lifecycle vs reg-only, blocking RMA-Lockall",
        "NS->ND",
        &col_refs,
        0,
    );
    let mut pairs: Vec<(usize, usize)> = vec![(160, 20)];
    pairs.extend(
        opts.pairs()
            .into_iter()
            .filter(|&(ns, nd)| ns > nd && (ns, nd) != (160, 20)),
    );
    for (ns, nd) in pairs {
        let spec = opts.spec(ns, nd, Method::RmaLockall, Strategy::Blocking);
        let time = |k: u64, dereg: bool| {
            time_rma_lifecycle_passes(
                ns,
                nd,
                &spec.sam,
                &spec.net,
                WinPoolPolicy::off(),
                k,
                dereg,
                1,
            )[0]
        };
        let full: Vec<f64> = RMA_CHUNK_SWEEP_KIB.iter().map(|&k| time(k, true)).collect();
        let reg_only: Vec<f64> = RMA_CHUNK_SWEEP_KIB.iter().map(|&k| time(k, false)).collect();
        t.row(&format!("{ns}->{nd} full"), full);
        t.row(&format!("{ns}->{nd} reg-only"), reg_only);
    }
    t
}

/// §VI ablation: the persistent window pool.  Per pair: the no-pool
/// redistribution time (seed behaviour), the pool's first (cold)
/// reconfiguration, and the repeat (warm) one — head-to-head.  The
/// cold column must match no-pool on the registration-dominated
/// critical path; the warm column is where "RMA loses on init cost"
/// becomes "RMA wins after the first resize".
pub fn win_pool(opts: &FigOptions) -> FigureTable {
    let mut t = FigureTable::new(
        "Ablation (§VI): persistent window pool — cold vs warm, blocking RMA-Lockall",
        "NS->ND",
        &["no-pool", "pool-cold", "pool-warm"],
        0,
    );
    for (ns, nd) in opts.pairs() {
        let spec = opts.spec(ns, nd, Method::RmaLockall, Strategy::Blocking);
        let no_pool = time_rma_passes(ns, nd, &spec.sam, &spec.net, WinPoolPolicy::off(), 1)[0];
        let pooled = time_rma_passes(ns, nd, &spec.sam, &spec.net, WinPoolPolicy::on(), 2);
        t.row(&format!("{ns}->{nd}"), vec![no_pool, pooled[0], pooled[1]]);
    }
    t
}

/// Spawn-strategy ablation (the other half of the initialization
/// cost): full reconfiguration span of grows under Sequential /
/// Parallel / Async spawning, for the blocking path, Wait Drains, and
/// pool-aware Wait Drains (warm registrations leave the spawn as the
/// dominant setup cost — exactly what Async hides inside the drain
/// window).  The acceptance pair 8→16 is always included.
pub fn spawn_strategies(opts: &FigOptions) -> FigureTable {
    let mut pairs: Vec<(usize, usize)> = vec![(8, 16)];
    pairs.extend(
        opts.pairs()
            .into_iter()
            .filter(|&(ns, nd)| nd > ns && (ns, nd) != (8, 16)),
    );
    let cols = super::spawn_strategy_cols();
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = FigureTable::new(
        "Ablation: grow reconfiguration time (s) by spawn strategy, RMA-Lockall",
        "NS->ND",
        &col_refs,
        0,
    );
    for (ns, nd) in pairs {
        for (suffix, strategy, pool) in [
            (" blk", Strategy::Blocking, WinPoolPolicy::off()),
            (" wd", Strategy::WaitDrains, WinPoolPolicy::off()),
            (" wd+pool", Strategy::WaitDrains, WinPoolPolicy::on()),
        ] {
            let row = super::spawn_strategy_row(opts, ns, nd, strategy, pool);
            t.row(&format!("{ns}->{nd}{suffix}"), row);
        }
    }
    t
}

/// §VI ablation: per-structure windows (the paper's design) vs one
/// fused window (the proposed fix), blocking RMA-Lockall.
pub fn single_window(opts: &FigOptions) -> FigureTable {
    let mut t = FigureTable::new(
        "Ablation (§VI): per-structure windows vs single fused window, blocking RMA-Lockall",
        "NS->ND",
        &["per-struct", "fused"],
        0,
    );
    for (ns, nd) in opts.pairs() {
        let spec = opts.spec(ns, nd, Method::RmaLockall, Strategy::Blocking);
        let a = time_rma_blocking(ns, nd, &spec.sam, &spec.net, false, true);
        let b = time_rma_blocking(ns, nd, &spec.sam, &spec.net, true, true);
        t.row(&format!("{ns}->{nd}"), vec![a, b]);
    }
    t
}

/// §VI ablation: blocking COL vs RMA-Lockall as the registration rate
/// varies — shows the rate beyond which one-sided redistribution wins.
pub fn registration_sweep(opts: &FigOptions, ns: usize, nd: usize) -> FigureTable {
    let rates: [f64; 5] = [0.5e9, 1.0e9, 2.0e9, 3.7e9, 8.0e9];
    let cols: Vec<String> = rates.iter().map(|r| format!("{:.1}GB/s", r / 1e9)).collect();
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = FigureTable::new(
        &format!("Ablation (§VI): RMA/COL blocking ratio at {ns}->{nd} vs registration rate"),
        "version",
        &col_refs,
        0,
    )
    .with_unit(Unit::Ratio, false);
    let mut col_row = Vec::new();
    let mut rma_row = Vec::new();
    for &rate in &rates {
        let mut spec = opts.spec(ns, nd, Method::Collective, Strategy::Blocking);
        spec.net.beta_register = 1.0 / rate;
        let col = run_median(&spec, opts.reps).redist_time;
        spec.method = Method::RmaLockall;
        let rma = run_median(&spec, opts.reps).redist_time;
        col_row.push(col);
        rma_row.push(rma);
    }
    // Report the speedup of RMA relative to COL per rate (>1 ⇒ RMA wins).
    let ratio: Vec<f64> = col_row.iter().zip(&rma_row).map(|(c, r)| c / r).collect();
    t.row("COL/RMA", ratio);
    t.row("COL (s)", col_row);
    t.row("RMA (s)", rma_row);
    t
}

/// DESIGN.md §6 ablation: blocking COL vs RMA-Lockall as the MPICH
/// eager→rendezvous switchover varies.  The rendezvous handshake taxes
/// every two-sided bulk message but no one-sided read, so a *lower*
/// threshold (more rendezvous traffic) shifts the balance toward RMA.
pub fn eager_sweep(opts: &FigOptions, ns: usize, nd: usize) -> FigureTable {
    let thresholds: [u64; 4] = [4 << 10, 64 << 10, 512 << 10, 8 << 20];
    let cols: Vec<String> = thresholds
        .iter()
        .map(|t| crate::util::stats::fmt_bytes(*t))
        .collect();
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = FigureTable::new(
        &format!("Ablation (§6): RMA/COL blocking ratio at {ns}->{nd} vs eager threshold"),
        "version",
        &col_refs,
        0,
    )
    .with_unit(Unit::Ratio, false);
    let mut col_row = Vec::new();
    let mut rma_row = Vec::new();
    for &thr in &thresholds {
        let mut spec = opts.spec(ns, nd, Method::Collective, Strategy::Blocking);
        spec.net.eager_threshold = thr;
        let col = run_median(&spec, opts.reps).redist_time;
        spec.method = Method::RmaLockall;
        let rma = run_median(&spec, opts.reps).redist_time;
        col_row.push(col);
        rma_row.push(rma);
    }
    let ratio: Vec<f64> = col_row.iter().zip(&rma_row).map(|(c, r)| c / r).collect();
    t.row("COL/RMA", ratio);
    t.row("COL (s)", col_row);
    t.row("RMA (s)", rma_row);
    t
}

/// Ablation: the static planner vs the online-recalibrating one on the
/// three drift scenarios (miscalibrated seed, heterogeneous NICs,
/// transient congestion).  One row per scenario — cumulative observed
/// reconfiguration cost of each arm's choices, the speedup column being
/// the recalibration win; the row label carries the resize index by
/// which the recalibrated predictions settled under the 15% error bar
/// (`K=…`, `K>n` when they never did).
pub fn recalib(opts: &FigOptions) -> FigureTable {
    // Drift scenarios fix their own shapes/sizes; the only knob taken
    // from the options is the quick-vs-full workload (quick presets set
    // scale > 1).
    let quick = opts.scale > 1;
    let mut t = FigureTable::new(
        "Ablation: static vs online-recalibrating planner, cumulative reconfiguration cost",
        "scenario",
        &["static", "recalib"],
        0,
    );
    for sc in super::drift::DriftScenario::all(quick) {
        let rep = super::drift::run_drift(&sc);
        let k = rep.converge_resizes();
        let label = if k > rep.recalib_arm.episodes.len() {
            format!("{} K>{}", rep.name, rep.recalib_arm.episodes.len())
        } else {
            format!("{} K={k}", rep.name)
        };
        t.row(&label, vec![rep.static_arm.cum_cost, rep.recalib_arm.cum_cost]);
    }
    t
}

// Arc is used by sibling experiment modules through re-export paths;
// silence the lint locally where the closure-based launchers need it.
#[allow(unused)]
fn _keep(_: Arc<()>) {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fused_window_is_never_slower() {
        let opts = FigOptions { pairs: vec![(8, 4)], scale: 10_000, ..FigOptions::quick() };
        let spec = opts.spec(8, 4, Method::RmaLockall, Strategy::Blocking);
        let a = time_rma_blocking(8, 4, &spec.sam, &spec.net, false, true);
        let b = time_rma_blocking(8, 4, &spec.sam, &spec.net, true, true);
        assert!(a.is_finite() && b.is_finite());
        // One collective create+free instead of three: must not lose.
        assert!(b <= a + 1e-9, "fused={b} per-struct={a}");
    }

    #[test]
    fn win_pool_warm_beats_cold() {
        let opts = FigOptions { pairs: vec![(8, 4)], scale: 10_000, ..FigOptions::quick() };
        let t = win_pool(&opts);
        let (no_pool, cold, warm) = (t.value(0, 0), t.value(0, 1), t.value(0, 2));
        assert!(no_pool.is_finite() && cold.is_finite() && warm.is_finite());
        // The §VI acceptance bar: warm-pool reconfiguration strictly
        // cheaper than the cold Win_create path.
        assert!(warm < cold, "warm={warm} cold={cold}");
        assert!(warm < no_pool, "warm={warm} no_pool={no_pool}");
        // Cold acquires charge exactly the seed registration cost; the
        // pool only skips the deregistration on release, so the cold
        // pass can never be slower than no-pool.
        assert!(cold <= no_pool + 1e-12, "cold={cold} no_pool={no_pool}");
    }

    #[test]
    fn win_pool_off_is_deterministic_and_stateless() {
        // Pool off = the seed path: repeating the whole experiment in a
        // fresh world reproduces both pass times bit-for-bit — no pool
        // state can leak into the cold path.
        let opts = FigOptions { pairs: vec![(6, 3)], scale: 10_000, ..FigOptions::quick() };
        let spec = opts.spec(6, 3, Method::RmaLockall, Strategy::Blocking);
        let off1 = time_rma_passes(6, 3, &spec.sam, &spec.net, WinPoolPolicy::off(), 2);
        let off2 = time_rma_passes(6, 3, &spec.sam, &spec.net, WinPoolPolicy::off(), 2);
        assert_eq!(off1[0].to_bits(), off2[0].to_bits(), "{off1:?} vs {off2:?}");
        assert_eq!(off1[1].to_bits(), off2[1].to_bits(), "{off1:?} vs {off2:?}");
        // And the pool-on first pass pays the same cold registration:
        // its redistribution may only get cheaper (release-side), never
        // slower.
        let on = time_rma_passes(6, 3, &spec.sam, &spec.net, WinPoolPolicy::on(), 1);
        assert!(on[0] <= off1[0] + 1e-12, "pool-cold={} no-pool={}", on[0], off1[0]);
    }

    #[test]
    fn spawn_ablation_parallel_and_async_strictly_reduce_grow_time() {
        // The acceptance criterion: on the 8→16 grow, Parallel and
        // Async spawning strictly undercut the Sequential constant in
        // `proteo ablation spawn` — on the blocking row, the WD row,
        // and the pool-aware WD row.
        let opts = FigOptions { pairs: vec![(8, 16)], scale: 10_000, ..FigOptions::quick() };
        let t = spawn_strategies(&opts);
        assert_eq!(t.rows.len(), 3, "blk, wd, wd+pool rows");
        for (r, label) in [(0usize, "blk"), (1, "wd"), (2, "wd+pool")] {
            let (seq, par, asy) = (t.value(r, 0), t.value(r, 1), t.value(r, 2));
            assert!(
                seq.is_finite() && par.is_finite() && asy.is_finite(),
                "{label}: {seq} {par} {asy}"
            );
            assert!(par < seq, "{label}: parallel {par} !< sequential {seq}");
            assert!(asy < seq, "{label}: async {asy} !< sequential {seq}");
        }
    }

    #[test]
    fn rma_chunk_chunk0_matches_blocking_and_warm_collapses() {
        let opts = FigOptions { pairs: vec![(8, 4)], scale: 10_000, ..FigOptions::quick() };
        let spec = opts.spec(8, 4, Method::RmaLockall, Strategy::Blocking);
        // chunk = 0 must be the plain blocking path, bit for bit.
        let plain = time_rma_passes(8, 4, &spec.sam, &spec.net, WinPoolPolicy::off(), 1)[0];
        let chunk0 =
            time_rma_chunk_passes(8, 4, &spec.sam, &spec.net, WinPoolPolicy::off(), 0, 1)[0];
        assert_eq!(plain.to_bits(), chunk0.to_bits());
        let t = rma_chunk(&opts);
        assert_eq!(t.rows.len(), 2, "cold + warm rows");
        for c in 0..RMA_CHUNK_SWEEP_KIB.len() {
            assert!(t.value(0, c).is_finite() && t.value(0, c) > 0.0);
            assert!(t.value(1, c).is_finite() && t.value(1, c) > 0.0);
            // Warm pass never loses to the cold pass of the same chunk.
            assert!(
                t.value(1, c) <= t.value(0, c) + 1e-9,
                "col {c}: warm={} cold={}",
                t.value(1, c),
                t.value(0, c)
            );
        }
    }

    #[test]
    fn rma_chunk_shrink_full_lifecycle_never_loses_to_reg_only() {
        let opts = FigOptions { pairs: vec![(8, 4)], scale: 10_000, ..FigOptions::quick() };
        let t = rma_chunk_shrink(&opts);
        // Rows: the forced 160->20 acceptance pair plus 8->4, full and
        // reg-only each.
        assert_eq!(t.rows.len(), 4, "two pairs x (full, reg-only)");
        for pair in 0..2 {
            let (full, reg_only) = (2 * pair, 2 * pair + 1);
            for c in 0..RMA_CHUNK_SWEEP_KIB.len() {
                let (f, r) = (t.value(full, c), t.value(reg_only, c));
                assert!(f.is_finite() && f > 0.0, "row {full} col {c}: {f}");
                assert!(
                    f <= r + 1e-9,
                    "pipelined teardown lost ground: full={f} reg-only={r} (col {c})"
                );
            }
            // The unchunked blocking baseline is identical in both rows
            // (the dereg flag is meaningless without segmentation).
            assert_eq!(t.value(full, 0).to_bits(), t.value(reg_only, 0).to_bits());
        }
        // 8->4 at quick scale segments under the 256 KiB chunk: the
        // teardown pipeline must buy a strictly positive saving there.
        assert!(
            t.value(2, 1) < t.value(3, 1),
            "no teardown saving at 8->4/256KiB: full={} reg-only={}",
            t.value(2, 1),
            t.value(3, 1)
        );
    }

    #[test]
    fn sched_cache_replay_undercuts_cold_build() {
        let opts = FigOptions { pairs: vec![(8, 4)], scale: 10_000, ..FigOptions::quick() };
        let t = sched_cache(&opts);
        assert_eq!(t.rows.len(), 2, "forced 20->160 plus 8->4");
        for r in 0..2 {
            let (off, cold, replay) = (t.value(r, 0), t.value(r, 1), t.value(r, 2));
            assert!(off.is_finite() && cold.is_finite() && replay.is_finite(), "row {r}");
            // The cold pass pays the schedule build on top of the
            // cache-off baseline; the replay keeps only the validation
            // handshake — strictly cheaper than cold, never cheaper
            // than off (pool off: registration repeats either way).
            assert!(cold > off, "row {r}: cold={cold} !> off={off}");
            assert!(replay < cold, "row {r}: replay={replay} !< cold={cold}");
            assert!(replay >= off, "row {r}: replay={replay} < off={off}");
        }
    }

    #[test]
    fn recalib_ablation_wins_on_every_drift_scenario() {
        let opts = FigOptions::quick();
        let t = recalib(&opts);
        assert_eq!(t.rows.len(), 3, "miscal, hetero, congest rows");
        for r in 0..3 {
            let (stat, rec) = (t.value(r, 0), t.value(r, 1));
            assert!(stat.is_finite() && rec.is_finite() && stat > 0.0 && rec > 0.0);
            assert!(rec < stat, "row {r}: recalib={rec} !< static={stat}");
            assert!(t.rows[r].0.contains("K="), "label: {}", t.rows[r].0);
        }
    }

    #[test]
    fn eager_sweep_runs_and_is_finite() {
        let opts =
            FigOptions { reps: 1, scale: 1000, pairs: vec![], seed: 4, ..FigOptions::default() };
        let t = eager_sweep(&opts, 8, 4);
        for c in 0..4 {
            assert!(t.value(0, c).is_finite() && t.value(0, c) > 0.0);
        }
    }

    #[test]
    fn registration_sweep_monotone() {
        let opts =
            FigOptions { reps: 1, scale: 1000, pairs: vec![], seed: 3, ..FigOptions::default() };
        let t = registration_sweep(&opts, 20, 40);
        // Faster registration → RMA relatively better (ratio grows).
        let first = t.value(0, 0);
        let last = t.value(0, 4);
        assert!(
            last > first,
            "RMA should gain as registration gets faster: {first} → {last}"
        );
    }
}
