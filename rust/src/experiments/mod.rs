//! Experiment harnesses — one generator per table/figure of §V, plus
//! the ablations motivated by §VI (future work).
//!
//! Every generator sweeps the paper's 12 reconfiguration pairs
//! (§V-A) for its version set and renders the same rows/series the
//! paper reports, including the speedups relative to the first bar.
//! The generators are used both by the `proteo exp figN` CLI and by
//! the `bench_figN_*` bench targets.
//!
//! [`FigOptions::quick`] shrinks the problem 100× and runs 1
//! repetition — same code path, CI-friendly runtime.

use crate::mam::{version_label, Method, SpawnStrategy, Strategy, WinPoolPolicy};
use crate::proteo::{analysis, run_median, sarteco25_pairs, RunResult, RunSpec};
use crate::util::benchkit::{FigureTable, Unit};

/// One column of a figure sweep: a (method, strategy) version plus the
/// window-pool toggle, so pooled variants can ride alongside the seed
/// versions in the same table (`--win-pool on` / `PROTEO_BENCH_WINPOOL`).
#[derive(Clone, Copy, Debug)]
pub struct VersionSpec {
    pub method: Method,
    pub strategy: Strategy,
    pub win_pool: WinPoolPolicy,
}

impl VersionSpec {
    pub fn new(method: Method, strategy: Strategy) -> VersionSpec {
        VersionSpec { method, strategy, win_pool: WinPoolPolicy::off() }
    }

    pub fn pooled(method: Method, strategy: Strategy) -> VersionSpec {
        VersionSpec { method, strategy, win_pool: WinPoolPolicy::on() }
    }

    /// Figure label, e.g. "RMA-Lockall-WD" or "RMA-Lockall-WD+pool".
    pub fn label(&self) -> String {
        let base = version_label(self.method, self.strategy);
        if self.win_pool.enabled {
            format!("{base}+pool")
        } else {
            base
        }
    }
}

/// Sweep options shared by all figure generators.
#[derive(Clone, Debug)]
pub struct FigOptions {
    /// Repetitions per point (paper: 20; default here: 3).
    pub reps: usize,
    /// Divide the problem size (structure elements and per-iteration
    /// flops) by this factor.
    pub scale: u64,
    /// Restrict to a subset of pairs (empty = all 12).
    pub pairs: Vec<(usize, usize)>,
    pub seed: u64,
    /// Add `+pool` variants of the RMA versions to every figure's
    /// version set (satellite of the §VI window-pool study).
    pub pool_variants: bool,
}

impl Default for FigOptions {
    fn default() -> Self {
        FigOptions { reps: 3, scale: 1, pairs: Vec::new(), seed: 0xC0FFEE, pool_variants: false }
    }
}

impl FigOptions {
    /// Options for the bench targets: full scale and all 12 pairs by
    /// default, tunable through `PROTEO_BENCH_REPS` / `_SCALE` /
    /// `_PAIRS` (e.g. `PROTEO_BENCH_PAIRS=20:160,160:20`).
    pub fn bench() -> FigOptions {
        let env_u64 = |k: &str, d: u64| {
            std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
        };
        let pairs = std::env::var("PROTEO_BENCH_PAIRS")
            .ok()
            .map(|v| {
                v.split(',')
                    .filter_map(|p| {
                        let (a, b) = p.split_once(':')?;
                        Some((a.trim().parse().ok()?, b.trim().parse().ok()?))
                    })
                    .collect()
            })
            .unwrap_or_default();
        let pool_variants = std::env::var("PROTEO_BENCH_WINPOOL")
            .ok()
            .and_then(|v| crate::util::cli::parse_toggle(&v))
            .unwrap_or(false);
        FigOptions {
            reps: env_u64("PROTEO_BENCH_REPS", 3) as usize,
            scale: env_u64("PROTEO_BENCH_SCALE", 1).max(1),
            pairs,
            seed: env_u64("PROTEO_BENCH_SEED", 0xC0FFEE),
            pool_variants,
        }
    }

    /// CI-sized sweep: 100× smaller problem, 1 rep, 4 corner pairs.
    pub fn quick() -> FigOptions {
        FigOptions {
            reps: 1,
            scale: 100,
            pairs: vec![(20, 160), (160, 20), (40, 80), (160, 40)],
            seed: 0xC0FFEE,
            pool_variants: false,
        }
    }

    pub fn pairs(&self) -> Vec<(usize, usize)> {
        if self.pairs.is_empty() {
            sarteco25_pairs()
        } else {
            self.pairs.clone()
        }
    }

    /// Build the run spec for one point of the sweep.
    pub fn spec(&self, ns: usize, nd: usize, m: Method, s: Strategy) -> RunSpec {
        let mut spec = RunSpec::sarteco25(ns, nd, m, s);
        spec.seed = self.seed;
        if self.scale > 1 {
            spec.sam.matrix_elems /= self.scale;
            spec.sam.colind_elems /= self.scale;
            spec.sam.rowptr_elems = (spec.sam.rowptr_elems / self.scale).max(16);
            spec.sam.vector_elems = (spec.sam.vector_elems / self.scale).max(16);
            spec.sam.flops_per_iter /= self.scale as f64;
        }
        spec
    }

    /// Build the run spec for one versioned column of the sweep.
    pub fn spec_v(&self, ns: usize, nd: usize, v: &VersionSpec) -> RunSpec {
        let mut spec = self.spec(ns, nd, v.method, v.strategy);
        spec.win_pool = v.win_pool;
        spec
    }

    /// Append pooled variants of the RMA versions when enabled — the
    /// figure then shows seed and pooled columns side by side.
    pub fn with_pool_variants(&self, mut versions: Vec<VersionSpec>) -> Vec<VersionSpec> {
        if self.pool_variants {
            let pooled: Vec<VersionSpec> = versions
                .iter()
                .filter(|v| v.method.is_rma())
                .map(|v| VersionSpec::pooled(v.method, v.strategy))
                .collect();
            versions.extend(pooled);
        }
        versions
    }

    /// Run one version set over the selected pairs.
    pub fn sweep(&self, versions: &[VersionSpec]) -> Vec<PairResults> {
        self.pairs()
            .into_iter()
            .map(|(ns, nd)| {
                let results = versions
                    .iter()
                    .map(|v| run_median(&self.spec_v(ns, nd, v), self.reps))
                    .collect();
                PairResults { ns, nd, results }
            })
            .collect()
    }
}

/// All versions' results for one pair P.
#[derive(Clone, Debug)]
pub struct PairResults {
    pub ns: usize,
    pub nd: usize,
    pub results: Vec<RunResult>,
}

impl PairResults {
    pub fn pair_label(&self) -> String {
        format!("{}->{}", self.ns, self.nd)
    }
}

/// The blocking version set (Fig. 3).
pub fn blocking_versions() -> Vec<VersionSpec> {
    vec![
        VersionSpec::new(Method::Collective, Strategy::Blocking),
        VersionSpec::new(Method::RmaLock, Strategy::Blocking),
        VersionSpec::new(Method::RmaLockall, Strategy::Blocking),
    ]
}

/// The NB + WD version set of §V-C (Figs. 4–6).
pub fn nbwd_versions() -> Vec<VersionSpec> {
    vec![
        VersionSpec::new(Method::Collective, Strategy::NonBlocking),
        VersionSpec::new(Method::Collective, Strategy::WaitDrains),
        VersionSpec::new(Method::RmaLock, Strategy::WaitDrains),
        VersionSpec::new(Method::RmaLockall, Strategy::WaitDrains),
    ]
}

/// The threading version set of §V-D (Figs. 7–9).
pub fn threading_versions() -> Vec<VersionSpec> {
    vec![
        VersionSpec::new(Method::Collective, Strategy::Threading),
        VersionSpec::new(Method::RmaLock, Strategy::Threading),
        VersionSpec::new(Method::RmaLockall, Strategy::Threading),
    ]
}

fn labels(versions: &[VersionSpec]) -> Vec<String> {
    versions.iter().map(|v| v.label()).collect()
}

fn table(
    title: &str,
    versions: &[VersionSpec],
    sweep: &[PairResults],
    value: impl Fn(&PairResults, usize) -> f64,
) -> FigureTable {
    let labels = labels(versions);
    let cols: Vec<&str> = labels.iter().map(|s| s.as_str()).collect();
    let mut t = FigureTable::new(title, "NS->ND", &cols, 0);
    for pr in sweep {
        let row: Vec<f64> = (0..versions.len()).map(|v| value(pr, v)).collect();
        t.row(&pr.pair_label(), row);
    }
    t
}

/// **Fig. 3** — reconfiguration time of the blocking versions, with
/// speedups relative to COL.
pub fn fig3_blocking(opts: &FigOptions) -> FigureTable {
    let versions = opts.with_pool_variants(blocking_versions());
    let sweep = opts.sweep(&versions);
    table(
        "Fig. 3: blocking redistribution time (s), speedup vs COL",
        &versions,
        &sweep,
        |pr, v| pr.results[v].redist_time,
    )
}

/// **Fig. 4** — total time after applying Eq. (2) to the NB/WD set,
/// with speedups relative to COL-NB.
pub fn fig4_nonblocking(opts: &FigOptions) -> FigureTable {
    let versions = opts.with_pool_variants(nbwd_versions());
    let sweep = opts.sweep(&versions);
    table(
        "Fig. 4: Eq.(2) total time (s), NB/WD versions, speedup vs COL-NB",
        &versions,
        &sweep,
        |pr, v| analysis::eq2_totals(&pr.results)[v],
    )
}

/// **Fig. 5** — ω = T_bg/T_base for the NB/WD set.
pub fn fig5_omega(opts: &FigOptions) -> FigureTable {
    let versions = opts.with_pool_variants(nbwd_versions());
    let sweep = opts.sweep(&versions);
    table(
        "Fig. 5: omega = T_bg/T_base, NB/WD versions",
        &versions,
        &sweep,
        |pr, v| pr.results[v].omega,
    )
    .with_unit(Unit::Ratio, false)
}

/// **Fig. 6** — iterations overlapped with the background
/// redistribution, NB/WD set.
pub fn fig6_iterations(opts: &FigOptions) -> FigureTable {
    let versions = opts.with_pool_variants(nbwd_versions());
    let sweep = opts.sweep(&versions);
    table(
        "Fig. 6: overlapped iterations, NB/WD versions",
        &versions,
        &sweep,
        |pr, v| pr.results[v].n_it,
    )
    .with_unit(Unit::Count, false)
}

/// **Fig. 7** — Eq. (2) totals for the threading set, speedup vs COL-T.
pub fn fig7_threading(opts: &FigOptions) -> FigureTable {
    let versions = opts.with_pool_variants(threading_versions());
    let sweep = opts.sweep(&versions);
    table(
        "Fig. 7: Eq.(2) total time (s), T versions, speedup vs COL-T",
        &versions,
        &sweep,
        |pr, v| analysis::eq2_totals(&pr.results)[v],
    )
}

/// **Fig. 8** — ω for the threading set.
pub fn fig8_omega_threading(opts: &FigOptions) -> FigureTable {
    let versions = opts.with_pool_variants(threading_versions());
    let sweep = opts.sweep(&versions);
    table(
        "Fig. 8: omega = T_bg/T_base, T versions",
        &versions,
        &sweep,
        |pr, v| pr.results[v].omega,
    )
    .with_unit(Unit::Ratio, false)
}

/// **Fig. 9** — overlapped iterations, threading set.
pub fn fig9_iterations_threading(opts: &FigOptions) -> FigureTable {
    let versions = opts.with_pool_variants(threading_versions());
    let sweep = opts.sweep(&versions);
    table(
        "Fig. 9: overlapped iterations, T versions",
        &versions,
        &sweep,
        |pr, v| pr.results[v].n_it,
    )
    .with_unit(Unit::Count, false)
}

/// Column labels of a spawn-strategy sweep (one per strategy).
pub(crate) fn spawn_strategy_cols() -> Vec<String> {
    SpawnStrategy::all().iter().map(|s| s.label().to_string()).collect()
}

/// One row of a spawn-strategy sweep: `reconf_total` of the
/// `ns`→`nd` grow for every strategy, with the given redistribution
/// strategy and pool policy.  Shared by `fig10_spawn` and
/// `ablation::spawn_strategies` so the two sweeps cannot drift.
pub(crate) fn spawn_strategy_row(
    opts: &FigOptions,
    ns: usize,
    nd: usize,
    strategy: Strategy,
    win_pool: WinPoolPolicy,
) -> Vec<f64> {
    SpawnStrategy::all()
        .iter()
        .map(|&ss| {
            let mut spec = opts.spec(ns, nd, Method::RmaLockall, strategy);
            spec.spawn_strategy = ss;
            spec.win_pool = win_pool;
            run_median(&spec, opts.reps).reconf_total
        })
        .collect()
}

/// **Fig. 10** (beyond the paper) — full reconfiguration span of a
/// grow under each spawn strategy, RMA-Lockall-WD: the spawn phase is
/// the other half of the initialization cost the paper identifies, and
/// parallel/async spawning bends it the way the window pool bends the
/// registration half.  Grow pairs only (shrinks never spawn); when the
/// selected pairs contain no grows, the paper's grow pairs are swept
/// instead of rendering an empty table.
pub fn fig10_spawn(opts: &FigOptions) -> FigureTable {
    let mut pairs: Vec<(usize, usize)> =
        opts.pairs().into_iter().filter(|&(ns, nd)| nd > ns).collect();
    if pairs.is_empty() {
        pairs = sarteco25_pairs().into_iter().filter(|&(ns, nd)| nd > ns).collect();
    }
    let cols = spawn_strategy_cols();
    let col_refs: Vec<&str> = cols.iter().map(|s| s.as_str()).collect();
    let mut t = FigureTable::new(
        "Fig. 10: grow reconfiguration time (s) by spawn strategy, RMA-Lockall-WD",
        "NS->ND",
        &col_refs,
        0,
    );
    for (ns, nd) in pairs {
        let row = spawn_strategy_row(opts, ns, nd, Strategy::WaitDrains, WinPoolPolicy::off());
        t.row(&format!("{ns}->{nd}"), row);
    }
    t
}

/// Dispatch a figure by id ("fig3".."fig10").
pub fn by_name(name: &str, opts: &FigOptions) -> Option<FigureTable> {
    Some(match name {
        "fig3" => fig3_blocking(opts),
        "fig4" => fig4_nonblocking(opts),
        "fig5" => fig5_omega(opts),
        "fig6" => fig6_iterations(opts),
        "fig7" => fig7_threading(opts),
        "fig8" => fig8_omega_threading(opts),
        "fig9" => fig9_iterations_threading(opts),
        "fig10" => fig10_spawn(opts),
        _ => return None,
    })
}

pub mod ablation;
pub mod chaos;
pub mod drift;
pub mod scenario;
pub mod smoke;
pub mod stress;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_fig3_has_expected_shape() {
        let t = fig3_blocking(&FigOptions::quick());
        let s = t.render();
        assert!(s.contains("COL"), "{s}");
        assert!(s.contains("RMA-Lock"), "{s}");
        assert!(s.contains("20->160"), "{s}");
        // RMA must be slower than COL where registration dominates
        // (growing from few sources), reproducing Fig. 3's band.
        let grow_speedup = t.speedup(0, 1); // row 0 = 20->160, col RMA-Lock
        assert!(
            grow_speedup < 1.0,
            "RMA should be slower than COL at 20->160: {grow_speedup}"
        );
    }

    #[test]
    fn quick_fig6_rma_overlaps_fewer_iterations_on_grow() {
        // Needs the paper-sized problem for the progress-model gap
        // between COL and RMA to show (small problems overlap roughly
        // equally); one rep of one pair stays under a second.
        let opts = FigOptions {
            pairs: vec![(20, 160)],
            scale: 1,
            ..FigOptions::quick()
        };
        let t = fig6_iterations(&opts);
        // columns: COL-NB, COL-WD, RMA-Lock-WD, RMA-Lockall-WD
        let col_nb = t.value(0, 0);
        let rma_wd = t.value(0, 2);
        assert!(
            rma_wd < col_nb,
            "RMA should overlap fewer iterations: rma={rma_wd} col={col_nb}"
        );
    }

    #[test]
    fn by_name_dispatches() {
        assert!(by_name("fig3", &FigOptions::quick()).is_some());
        assert!(by_name("fig42", &FigOptions::quick()).is_none());
    }

    #[test]
    fn pool_variants_add_pooled_rma_columns() {
        let mut opts = FigOptions::quick();
        opts.pairs = vec![(8, 4)];
        opts.scale = 10_000;
        opts.pool_variants = true;
        let t = fig3_blocking(&opts);
        // COL, RMA-Lock, RMA-Lockall + two pooled RMA variants.
        assert_eq!(t.columns.len(), 5, "{:?}", t.columns);
        assert_eq!(t.columns[3], "RMA-Lock+pool");
        assert_eq!(t.columns[4], "RMA-Lockall+pool");
        // A single (cold) pooled pass can only save the deregistration
        // on release — never lose to the seed version.
        assert!(t.value(0, 3) <= t.value(0, 1) + 1e-9);
        assert!(t.value(0, 4) <= t.value(0, 2) + 1e-9);
        // Flag off: seed columns only (the default figures unchanged).
        opts.pool_variants = false;
        assert_eq!(fig3_blocking(&opts).columns.len(), 3);
    }

    #[test]
    fn fig10_sweeps_grow_pairs_by_spawn_strategy() {
        let opts = FigOptions {
            pairs: vec![(8, 16), (16, 8)],
            scale: 10_000,
            ..FigOptions::quick()
        };
        let t = fig10_spawn(&opts);
        assert_eq!(t.columns, vec!["sequential", "parallel", "async"]);
        // Shrinks are filtered out — spawn strategies only act on grows.
        assert_eq!(t.rows.len(), 1);
        assert_eq!(t.rows[0].0, "8->16");
        let (seq, par, asy) = (t.value(0, 0), t.value(0, 1), t.value(0, 2));
        assert!(seq.is_finite() && par.is_finite() && asy.is_finite());
        // The acceptance bar: decomposed strategies strictly reduce the
        // modeled resize time on the 8→16 grow.
        assert!(par < seq, "parallel {par} !< sequential {seq}");
        assert!(asy < seq, "async {asy} !< sequential {seq}");
    }
}
