//! Experiment harnesses — one generator per table/figure of §V, plus
//! the ablations motivated by §VI (future work).
//!
//! Every generator sweeps the paper's 12 reconfiguration pairs
//! (§V-A) for its version set and renders the same rows/series the
//! paper reports, including the speedups relative to the first bar.
//! The generators are used both by the `proteo exp figN` CLI and by
//! the `bench_figN_*` bench targets.
//!
//! [`FigOptions::quick`] shrinks the problem 100× and runs 1
//! repetition — same code path, CI-friendly runtime.

use crate::mam::{version_label, Method, Strategy};
use crate::proteo::{analysis, run_median, sarteco25_pairs, RunResult, RunSpec};
use crate::util::benchkit::{FigureTable, Unit};

/// Sweep options shared by all figure generators.
#[derive(Clone, Debug)]
pub struct FigOptions {
    /// Repetitions per point (paper: 20; default here: 3).
    pub reps: usize,
    /// Divide the problem size (structure elements and per-iteration
    /// flops) by this factor.
    pub scale: u64,
    /// Restrict to a subset of pairs (empty = all 12).
    pub pairs: Vec<(usize, usize)>,
    pub seed: u64,
}

impl Default for FigOptions {
    fn default() -> Self {
        FigOptions { reps: 3, scale: 1, pairs: Vec::new(), seed: 0xC0FFEE }
    }
}

impl FigOptions {
    /// Options for the bench targets: full scale and all 12 pairs by
    /// default, tunable through `PROTEO_BENCH_REPS` / `_SCALE` /
    /// `_PAIRS` (e.g. `PROTEO_BENCH_PAIRS=20:160,160:20`).
    pub fn bench() -> FigOptions {
        let env_u64 = |k: &str, d: u64| {
            std::env::var(k).ok().and_then(|v| v.parse().ok()).unwrap_or(d)
        };
        let pairs = std::env::var("PROTEO_BENCH_PAIRS")
            .ok()
            .map(|v| {
                v.split(',')
                    .filter_map(|p| {
                        let (a, b) = p.split_once(':')?;
                        Some((a.trim().parse().ok()?, b.trim().parse().ok()?))
                    })
                    .collect()
            })
            .unwrap_or_default();
        FigOptions {
            reps: env_u64("PROTEO_BENCH_REPS", 3) as usize,
            scale: env_u64("PROTEO_BENCH_SCALE", 1).max(1),
            pairs,
            seed: env_u64("PROTEO_BENCH_SEED", 0xC0FFEE),
        }
    }

    /// CI-sized sweep: 100× smaller problem, 1 rep, 4 corner pairs.
    pub fn quick() -> FigOptions {
        FigOptions {
            reps: 1,
            scale: 100,
            pairs: vec![(20, 160), (160, 20), (40, 80), (160, 40)],
            seed: 0xC0FFEE,
        }
    }

    pub fn pairs(&self) -> Vec<(usize, usize)> {
        if self.pairs.is_empty() {
            sarteco25_pairs()
        } else {
            self.pairs.clone()
        }
    }

    /// Build the run spec for one point of the sweep.
    pub fn spec(&self, ns: usize, nd: usize, m: Method, s: Strategy) -> RunSpec {
        let mut spec = RunSpec::sarteco25(ns, nd, m, s);
        spec.seed = self.seed;
        if self.scale > 1 {
            spec.sam.matrix_elems /= self.scale;
            spec.sam.colind_elems /= self.scale;
            spec.sam.rowptr_elems = (spec.sam.rowptr_elems / self.scale).max(16);
            spec.sam.vector_elems = (spec.sam.vector_elems / self.scale).max(16);
            spec.sam.flops_per_iter /= self.scale as f64;
        }
        spec
    }

    /// Run one version set over the selected pairs.
    pub fn sweep(&self, versions: &[(Method, Strategy)]) -> Vec<PairResults> {
        self.pairs()
            .into_iter()
            .map(|(ns, nd)| {
                let results = versions
                    .iter()
                    .map(|&(m, s)| run_median(&self.spec(ns, nd, m, s), self.reps))
                    .collect();
                PairResults { ns, nd, results }
            })
            .collect()
    }
}

/// All versions' results for one pair P.
#[derive(Clone, Debug)]
pub struct PairResults {
    pub ns: usize,
    pub nd: usize,
    pub results: Vec<RunResult>,
}

impl PairResults {
    pub fn pair_label(&self) -> String {
        format!("{}->{}", self.ns, self.nd)
    }
}

/// The blocking version set (Fig. 3).
pub fn blocking_versions() -> Vec<(Method, Strategy)> {
    vec![
        (Method::Collective, Strategy::Blocking),
        (Method::RmaLock, Strategy::Blocking),
        (Method::RmaLockall, Strategy::Blocking),
    ]
}

/// The NB + WD version set of §V-C (Figs. 4–6).
pub fn nbwd_versions() -> Vec<(Method, Strategy)> {
    vec![
        (Method::Collective, Strategy::NonBlocking),
        (Method::Collective, Strategy::WaitDrains),
        (Method::RmaLock, Strategy::WaitDrains),
        (Method::RmaLockall, Strategy::WaitDrains),
    ]
}

/// The threading version set of §V-D (Figs. 7–9).
pub fn threading_versions() -> Vec<(Method, Strategy)> {
    vec![
        (Method::Collective, Strategy::Threading),
        (Method::RmaLock, Strategy::Threading),
        (Method::RmaLockall, Strategy::Threading),
    ]
}

fn labels(versions: &[(Method, Strategy)]) -> Vec<String> {
    versions.iter().map(|&(m, s)| version_label(m, s)).collect()
}

fn table(
    title: &str,
    versions: &[(Method, Strategy)],
    sweep: &[PairResults],
    value: impl Fn(&PairResults, usize) -> f64,
) -> FigureTable {
    let labels = labels(versions);
    let cols: Vec<&str> = labels.iter().map(|s| s.as_str()).collect();
    let mut t = FigureTable::new(title, "NS->ND", &cols, 0);
    for pr in sweep {
        let row: Vec<f64> = (0..versions.len()).map(|v| value(pr, v)).collect();
        t.row(&pr.pair_label(), row);
    }
    t
}

/// **Fig. 3** — reconfiguration time of the blocking versions, with
/// speedups relative to COL.
pub fn fig3_blocking(opts: &FigOptions) -> FigureTable {
    let versions = blocking_versions();
    let sweep = opts.sweep(&versions);
    table(
        "Fig. 3: blocking redistribution time (s), speedup vs COL",
        &versions,
        &sweep,
        |pr, v| pr.results[v].redist_time,
    )
}

/// **Fig. 4** — total time after applying Eq. (2) to the NB/WD set,
/// with speedups relative to COL-NB.
pub fn fig4_nonblocking(opts: &FigOptions) -> FigureTable {
    let versions = nbwd_versions();
    let sweep = opts.sweep(&versions);
    table(
        "Fig. 4: Eq.(2) total time (s), NB/WD versions, speedup vs COL-NB",
        &versions,
        &sweep,
        |pr, v| analysis::eq2_totals(&pr.results)[v],
    )
}

/// **Fig. 5** — ω = T_bg/T_base for the NB/WD set.
pub fn fig5_omega(opts: &FigOptions) -> FigureTable {
    let versions = nbwd_versions();
    let sweep = opts.sweep(&versions);
    table(
        "Fig. 5: omega = T_bg/T_base, NB/WD versions",
        &versions,
        &sweep,
        |pr, v| pr.results[v].omega,
    )
    .with_unit(Unit::Ratio, false)
}

/// **Fig. 6** — iterations overlapped with the background
/// redistribution, NB/WD set.
pub fn fig6_iterations(opts: &FigOptions) -> FigureTable {
    let versions = nbwd_versions();
    let sweep = opts.sweep(&versions);
    table(
        "Fig. 6: overlapped iterations, NB/WD versions",
        &versions,
        &sweep,
        |pr, v| pr.results[v].n_it,
    )
    .with_unit(Unit::Count, false)
}

/// **Fig. 7** — Eq. (2) totals for the threading set, speedup vs COL-T.
pub fn fig7_threading(opts: &FigOptions) -> FigureTable {
    let versions = threading_versions();
    let sweep = opts.sweep(&versions);
    table(
        "Fig. 7: Eq.(2) total time (s), T versions, speedup vs COL-T",
        &versions,
        &sweep,
        |pr, v| analysis::eq2_totals(&pr.results)[v],
    )
}

/// **Fig. 8** — ω for the threading set.
pub fn fig8_omega_threading(opts: &FigOptions) -> FigureTable {
    let versions = threading_versions();
    let sweep = opts.sweep(&versions);
    table(
        "Fig. 8: omega = T_bg/T_base, T versions",
        &versions,
        &sweep,
        |pr, v| pr.results[v].omega,
    )
    .with_unit(Unit::Ratio, false)
}

/// **Fig. 9** — overlapped iterations, threading set.
pub fn fig9_iterations_threading(opts: &FigOptions) -> FigureTable {
    let versions = threading_versions();
    let sweep = opts.sweep(&versions);
    table(
        "Fig. 9: overlapped iterations, T versions",
        &versions,
        &sweep,
        |pr, v| pr.results[v].n_it,
    )
    .with_unit(Unit::Count, false)
}

/// Dispatch a figure by id ("fig3".."fig9").
pub fn by_name(name: &str, opts: &FigOptions) -> Option<FigureTable> {
    Some(match name {
        "fig3" => fig3_blocking(opts),
        "fig4" => fig4_nonblocking(opts),
        "fig5" => fig5_omega(opts),
        "fig6" => fig6_iterations(opts),
        "fig7" => fig7_threading(opts),
        "fig8" => fig8_omega_threading(opts),
        "fig9" => fig9_iterations_threading(opts),
        _ => return None,
    })
}

pub mod ablation;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_fig3_has_expected_shape() {
        let t = fig3_blocking(&FigOptions::quick());
        let s = t.render();
        assert!(s.contains("COL"), "{s}");
        assert!(s.contains("RMA-Lock"), "{s}");
        assert!(s.contains("20->160"), "{s}");
        // RMA must be slower than COL where registration dominates
        // (growing from few sources), reproducing Fig. 3's band.
        let grow_speedup = t.speedup(0, 1); // row 0 = 20->160, col RMA-Lock
        assert!(
            grow_speedup < 1.0,
            "RMA should be slower than COL at 20->160: {grow_speedup}"
        );
    }

    #[test]
    fn quick_fig6_rma_overlaps_fewer_iterations_on_grow() {
        // Needs the paper-sized problem for the progress-model gap
        // between COL and RMA to show (small problems overlap roughly
        // equally); one rep of one pair stays under a second.
        let opts = FigOptions {
            pairs: vec![(20, 160)],
            scale: 1,
            ..FigOptions::quick()
        };
        let t = fig6_iterations(&opts);
        // columns: COL-NB, COL-WD, RMA-Lock-WD, RMA-Lockall-WD
        let col_nb = t.value(0, 0);
        let rma_wd = t.value(0, 2);
        assert!(
            rma_wd < col_nb,
            "RMA should overlap fewer iterations: rma={rma_wd} col={col_nb}"
        );
    }

    #[test]
    fn by_name_dispatches() {
        assert!(by_name("fig3", &FigOptions::quick()).is_some());
        assert!(by_name("fig42", &FigOptions::quick()).is_none());
    }
}
