//! Closed-loop RMS scenario harness: a job-trace simulation where the
//! [`Rms`](crate::rms::Rms) under [`Policy::Adaptive`] drives a
//! sequence of grows and shrinks on an iterative CG-style malleable
//! application, the cost-model planner (`--planner auto`) picks each
//! reconfiguration's `(method × strategy × spawn × pool)`, and the
//! metrics record predicted-vs-observed cost per resize plus the total
//! makespan — the dynamic-workload loop of the related RMS literature,
//! built from the `rms` + `mam::planner` + `netmodel::costmodel`
//! layers.
//!
//! The run has two phases:
//!
//! 1. **Scheduling** ([`schedule`]): the RMS replays the rigid-job
//!    arrival/departure trace at checkpoint granularity and emits the
//!    malleable job's resize decisions; each decision is resolved into
//!    a concrete [`ReconfigCfg`] — the configured fixed version, or
//!    the planner's per-resize choice (probe-refined, warmth-aware:
//!    once a pooled resize ran, later plans assume warm windows).
//!    This happens *before* the MPI simulation so every rank — and
//!    every spawned drain — executes the identical plan.
//! 2. **Execution** ([`run_scenario`]): the malleable application
//!    iterates on the simulated cluster; at each scheduled iteration
//!    count it reconfigures through MaM (background strategies keep
//!    iterating with the consistent-stop protocol), spawned drains
//!    join mid-flight and continue as regular ranks, shrunk ranks
//!    retire.  The virtual end time is the scenario makespan.
//!
//! Everything is deterministic (seeded jitter, bit-deterministic DES),
//! so scenario makespans feed the CI bench gate (`proteo bench-smoke`)
//! and `proteo scenario` output is reproducible byte for byte.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::mam::planner::{self, Candidate, Objective, PlannerInputs, PlannerMode};
use crate::mam::{
    DataDecl, Mam, MamStatus, Method, Observation, Recalibrator, ReconfigCfg, Registry,
    SpawnStrategy, Strategy, WinPoolPolicy,
};
use crate::monitor::Metrics;
use crate::netmodel::{costmodel, NetParams, Topology};
use crate::rms::{Policy, Rms};
use crate::sam::{Sam, SamConfig};
use crate::simmpi::{
    CommId, FaultPlan, FaultSpec, MpiProc, MpiSim, Payload, RmaSync, ELEM_BYTES, WORLD,
};
use crate::util::benchkit::FigureTable;
use crate::util::json::Json;
use crate::util::stats::fmt_seconds;

/// Fault-injection re-queue policy: an aborted resize is re-dispatched
/// by the RMS up to this many times before being abandoned…
const MAX_DISPATCHES: u64 = 3;
/// …and between dispatches the job breathes this many application
/// iterations on the layout it still owns.
const REQUEUE_ITERS: u64 = 2;

/// One rigid-job event of the trace, applied right before the RMS
/// checkpoint it is attached to.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// 1-based checkpoint index (checkpoint `k` fires at iteration
    /// `k × checkpoint_every`).
    pub at_checkpoint: usize,
    pub kind: TraceKind,
}

#[derive(Clone, Debug)]
pub enum TraceKind {
    /// A rigid job arrives (queued FIFO when it does not fit).
    Submit { name: String, cores: usize },
    /// A rigid job departs, freeing its cores.
    Finish { name: String },
}

/// Full specification of one closed-loop scenario.
#[derive(Clone, Debug)]
pub struct ScenarioSpec {
    pub name: String,
    pub total_cores: usize,
    /// Resize granularity (the paper resizes in node multiples).
    pub granularity: usize,
    pub cores_per_node: usize,
    /// Malleable job: initial size and resize bounds.
    pub start_cores: usize,
    pub min_cores: usize,
    pub max_cores: usize,
    /// Iterations between RMS checkpoints.
    pub checkpoint_every: u64,
    /// Total application iterations the job must complete (overlapped
    /// iterations count — they are real work).
    pub total_iters: u64,
    pub events: Vec<TraceEvent>,
    pub sam: SamConfig,
    pub net: NetParams,
    /// Fixed version executed when `planner` is `Fixed`.
    pub method: Method,
    pub strategy: Strategy,
    pub spawn_strategy: SpawnStrategy,
    pub win_pool: WinPoolPolicy,
    /// Fixed version's pipelined registration chunk (KiB; 0 = off).
    pub rma_chunk_kib: u64,
    /// RMA completion sync (`--rma-sync`): collective epochs, or
    /// per-segment notified completion.
    pub rma_sync: RmaSync,
    /// Persistent-schedule cache (`--sched-cache`): replayed resize
    /// pairs skip the cold schedule build for a validation handshake.
    pub sched_cache: bool,
    pub planner: PlannerMode,
    pub spawn_cost: f64,
    /// Online recalibration (`--recalib on`): under the Auto planner,
    /// every rank re-resolves each resize *in simulation* from a live
    /// [`Recalibrator`] belief fed by the previous resizes' observed
    /// spans and registration counters, instead of executing the
    /// statically scheduled plan.  `false` leaves the execution path
    /// bit-identical to the static harness.
    pub recalib: bool,
    pub seed: u64,
    /// Deterministic fault injection (`--faults`): spawn failures with
    /// retry/backoff at every grow, abort-and-rollback when the retry
    /// budget runs out (the RMS re-queues the resize, re-anchored at
    /// the size the job actually holds).  `None` (default) executes
    /// the healthy paths bit for bit.
    pub faults: Option<FaultSpec>,
}

impl ScenarioSpec {
    /// The default closed-loop trace: a 24-core cluster (6 nodes × 4),
    /// one malleable CG job (8 cores, bounds 4..16) and two rigid
    /// arrivals that force the Adaptive policy through the full resize
    /// repertoire — grow into idle space, shrink to admit a queued
    /// job, grow back when it departs:
    ///
    /// ```text
    /// ck1: 8→16   (FillIdle: cluster is empty)
    /// ck2: 16→8   (MakeRoom: rigid A/16 queued)  → A starts
    /// ck4: 8→16   (FillIdle: A finished)
    /// ck5: 16→12  (MakeRoom: rigid B/12 queued)  → B starts
    /// ck7: 12→16  (FillIdle: B finished)
    /// ```
    ///
    /// The repeated 8→16 grow is deliberate: with the window pool on,
    /// the second pass rides warm registrations (§VI), which is
    /// exactly the condition under which the planner should flip
    /// toward one-sided redistribution.
    pub fn rms_trace(quick: bool) -> ScenarioSpec {
        let mut sam = SamConfig::sarteco25();
        let scale: u64 = if quick { 10_000 } else { 100 };
        sam.matrix_elems /= scale;
        sam.colind_elems /= scale;
        sam.rowptr_elems = (sam.rowptr_elems / scale).max(16);
        sam.vector_elems = (sam.vector_elems / scale).max(16);
        sam.flops_per_iter /= scale as f64;
        let ev = |at_checkpoint: usize, kind: TraceKind| TraceEvent { at_checkpoint, kind };
        ScenarioSpec {
            name: "rms-adaptive".to_string(),
            total_cores: 24,
            granularity: 4,
            cores_per_node: 4,
            start_cores: 8,
            min_cores: 4,
            max_cores: 16,
            checkpoint_every: 6,
            total_iters: 60,
            events: vec![
                ev(2, TraceKind::Submit { name: "rigid-A".into(), cores: 16 }),
                ev(4, TraceKind::Finish { name: "rigid-A".into() }),
                ev(5, TraceKind::Submit { name: "rigid-B".into(), cores: 12 }),
                ev(7, TraceKind::Finish { name: "rigid-B".into() }),
            ],
            sam,
            net: NetParams::sarteco25(),
            method: Method::Collective,
            strategy: Strategy::Blocking,
            spawn_strategy: SpawnStrategy::Sequential,
            win_pool: WinPoolPolicy::off(),
            rma_chunk_kib: 0,
            rma_sync: RmaSync::Epoch,
            sched_cache: false,
            planner: PlannerMode::Auto,
            spawn_cost: 0.25,
            recalib: false,
            seed: 0xC0FFEE,
            faults: None,
        }
    }

    /// The oscillating headline trace: a 160-core cluster (8 nodes ×
    /// 20) where the malleable job ping-pongs between 20 and 160 cores
    /// as 140-core rigid jobs come and go:
    ///
    /// ```text
    /// ck1: 20→160  (FillIdle: cluster is empty)         — cold
    /// ck2: 160→20  (MakeRoom: rigid A/140 queued)       — cold
    /// ck4: 20→160  (FillIdle: A finished)               — replay
    /// ck5: 160→20  (MakeRoom: rigid B/140 queued)       — replay
    /// ck7: 20→160  (FillIdle: B finished)               — replay
    /// ```
    ///
    /// Every pair after its first occurrence replays the identical
    /// redistribution shape, which is exactly what the persistent
    /// schedule cache (`--sched-cache on`) monetizes: replays charge a
    /// validation handshake instead of a cold schedule build, and with
    /// the window pool on they ride warm registrations too.
    pub fn osc_trace(quick: bool) -> ScenarioSpec {
        let mut spec = ScenarioSpec::rms_trace(quick);
        spec.name = "osc-20x160".to_string();
        spec.total_cores = 160;
        spec.granularity = 20;
        spec.cores_per_node = 20;
        spec.start_cores = 20;
        spec.min_cores = 20;
        spec.max_cores = 160;
        spec.checkpoint_every = 4;
        spec.total_iters = 32;
        let ev = |at_checkpoint: usize, kind: TraceKind| TraceEvent { at_checkpoint, kind };
        spec.events = vec![
            ev(2, TraceKind::Submit { name: "rigid-A".into(), cores: 140 }),
            ev(4, TraceKind::Finish { name: "rigid-A".into() }),
            ev(5, TraceKind::Submit { name: "rigid-B".into(), cores: 140 }),
            ev(7, TraceKind::Finish { name: "rigid-B".into() }),
        ];
        spec
    }

    /// Column label of this configuration ("auto" or the fixed
    /// version's figure label).
    pub fn version_label(&self) -> String {
        if self.planner == PlannerMode::Auto {
            if self.recalib { "auto+recalib".to_string() } else { "auto".to_string() }
        } else {
            Candidate {
                method: self.method,
                strategy: self.strategy,
                spawn_strategy: self.spawn_strategy,
                win_pool: self.win_pool,
                rma_chunk_kib: self.rma_chunk_kib,
            }
            .label()
        }
    }

    /// Declarations of the registered CG data (rank-independent).
    fn decls(&self) -> Vec<DataDecl> {
        let sam = Sam::new(self.sam.clone(), self.seed, 0);
        let mut reg = Registry::new();
        sam.register_data(&mut reg, self.start_cores, 0);
        reg.decls()
    }
}

/// One scheduled (and resolved) resize of the scenario.
#[derive(Clone, Debug)]
pub struct PlannedResize {
    pub index: usize,
    /// The resize fires when the application's iteration count reaches
    /// this value.
    pub at_iter: u64,
    pub from: usize,
    pub to: usize,
    /// Fully resolved configuration (never `Auto` — resolution happens
    /// here, at the harness level, so spawned drains mirror it).
    pub cfg: ReconfigCfg,
    pub label: String,
    /// Closed-form predicted reconfiguration span (accuracy baseline).
    pub predicted_reconf: f64,
    /// Exact micro-probed span, when the planner probed the choice.
    pub probed_reconf: Option<f64>,
}

/// Stage 1: replay the RMS trace and resolve every resize.
///
/// The trace replay is separated from the resolution so each resize's
/// planner sees `future_resizes` — how many more resizes the trace
/// still holds — and prices warm-future investments (pool, schedule
/// cache) against the resizes that will actually collect them.
pub fn schedule(spec: &ScenarioSpec) -> Vec<PlannedResize> {
    let mut rms = Rms::new(spec.total_cores, spec.granularity, Policy::Adaptive);
    let malleable = rms.submit(&spec.name, spec.start_cores, spec.min_cores, spec.max_cores);
    let mut rigid_ids: BTreeMap<String, usize> = BTreeMap::new();
    let decls = spec.decls();
    let mut decisions: Vec<(u64, usize, usize)> = Vec::new();
    let every = spec.checkpoint_every.max(1);
    let mut ck = 0usize;
    loop {
        ck += 1;
        let at_iter = ck as u64 * every;
        if at_iter >= spec.total_iters {
            break;
        }
        for ev in spec.events.iter().filter(|e| e.at_checkpoint == ck) {
            match &ev.kind {
                TraceKind::Finish { name } => {
                    let id = rigid_ids
                        .remove(name)
                        .unwrap_or_else(|| panic!("trace finishes unknown job '{name}'"));
                    rms.finish(id);
                }
                TraceKind::Submit { name, cores } => {
                    let id = rms.submit(name, *cores, *cores, *cores);
                    rigid_ids.insert(name.clone(), id);
                }
            }
        }
        if let Some(d) = rms.checkpoint_decision(malleable) {
            rms.apply(d);
            decisions.push((at_iter, d.from, d.to));
        }
    }
    let mut out: Vec<PlannedResize> = Vec::new();
    let mut warm = false;
    // Pairs whose schedule a cache-carrying resize has already built:
    // a later identical pair replays warm.
    let mut built: std::collections::BTreeSet<(usize, usize)> = std::collections::BTreeSet::new();
    for (index, &(at_iter, from, to)) in decisions.iter().enumerate() {
        let sched_warm = spec.sched_cache && built.contains(&(from, to));
        let future_resizes = (decisions.len() - index - 1) as u32;
        let (cfg, label, predicted_reconf, probed_reconf) =
            resolve_resize(spec, &decls, from, to, warm, sched_warm, future_resizes);
        // Register-on-receive pins every continuing rank's new
        // block, so the *next* resize acquires warm windows — but
        // only if this resize pooled (a pool-off resize leaves the
        // sources' new blocks unpinned).
        warm = cfg.win_pool.enabled;
        if cfg.sched_cache && cfg.method.is_rma() {
            built.insert((from, to));
        }
        out.push(PlannedResize {
            index,
            at_iter,
            from,
            to,
            cfg,
            label,
            predicted_reconf,
            probed_reconf,
        });
    }
    out
}

/// Resolve one resize into a concrete configuration plus its
/// prediction (the closed-form span estimate is recorded for fixed
/// versions too, so planner accuracy is reportable for every column).
fn resolve_resize(
    spec: &ScenarioSpec,
    decls: &[DataDecl],
    from: usize,
    to: usize,
    warm: bool,
    sched_warm: bool,
    future_resizes: u32,
) -> (ReconfigCfg, String, f64, Option<f64>) {
    let inputs = PlannerInputs {
        decls: decls.to_vec(),
        ns: from,
        nd: to,
        cores_per_node: spec.cores_per_node,
        net: spec.net.clone(),
        spawn_cost: spec.spawn_cost,
        warm,
        t_iter_src: spec.sam.iter_compute(from),
        t_iter_dst: spec.sam.iter_compute(to),
        objective: Objective::ReconfTime,
        probe: spec.planner == PlannerMode::Auto,
        extra_chunks_kib: Vec::new(),
        rma_sync: spec.rma_sync,
        sched_cache: spec.sched_cache,
        sched_warm,
        future_resizes,
        fail_p: spec.faults.as_ref().map_or(0.0, |f| f.spawn_fail_p),
    };
    if spec.planner == PlannerMode::Auto {
        let plan = planner::plan(&inputs);
        let chosen = plan.candidates.iter().find(|cc| cc.candidate == plan.choice);
        let analytic =
            chosen.map(|cc| cc.predicted.reconf_time).unwrap_or(plan.predicted.reconf_time);
        let probed = chosen.and_then(|cc| cc.probed_reconf);
        let cfg = plan
            .choice
            .cfg(spec.spawn_cost)
            .with_sync(spec.rma_sync)
            .with_sched_cache(spec.sched_cache);
        (cfg, plan.label(), analytic, probed)
    } else {
        let cand = Candidate {
            method: spec.method,
            strategy: spec.strategy,
            spawn_strategy: spec.spawn_strategy,
            win_pool: spec.win_pool,
            rma_chunk_kib: spec.rma_chunk_kib,
        };
        // Fixed mode: warmth only materializes if the fixed version
        // itself pools.
        let mut inputs = inputs;
        inputs.warm = warm && spec.win_pool.enabled;
        let pred = planner::predict_candidate(&inputs, &cand);
        let cfg = cand
            .cfg(spec.spawn_cost)
            .with_sync(spec.rma_sync)
            .with_sched_cache(spec.sched_cache);
        (cfg, cand.label(), pred.reconf_time, None)
    }
}

/// Observed outcome of one resize.
#[derive(Clone, Debug)]
pub struct ResizeReport {
    pub index: usize,
    pub from: usize,
    pub to: usize,
    pub label: String,
    pub predicted_reconf: f64,
    pub observed_reconf: f64,
    /// Iterations the sources overlapped with a background
    /// redistribution (0 for blocking picks).
    pub n_it: f64,
    /// Bytes registered with the NIC during this resize (window
    /// creates, pipelined segment streams, register-on-receive pins).
    pub reg_bytes: f64,
    /// Virtual seconds of registration work those bytes cost, summed
    /// over ranks.
    pub reg_secs: f64,
    /// Non-wire setup seconds this resize charged, summed over ranks:
    /// schedule build/validation (`sched.time`), memory registration
    /// (`rma.reg_time`) and completion sync (`rma.sync_time`).  The
    /// schedule-cache acceptance metric: a replayed pair must charge
    /// measurably less here than its cold first occurrence.
    pub setup_secs: f64,
    /// The resize ran a version that *can* register (an RMA method, or
    /// any method with the window pool's register-on-receive) but
    /// registered zero bytes: every window acquire and pre-pin rode
    /// the registration cache.  Distinguishes "warm" from "never
    /// registers" (COL without the pool) in the report.
    pub warm: bool,
    /// Times the RMS dispatched this resize (1 when healthy; >1 when
    /// aborted dispatches forced re-queues; 0 when an earlier skipped
    /// resize already left the job at this target).
    pub dispatches: u64,
    /// The resize eventually went through (false: abandoned after the
    /// dispatch cap, or a no-op re-target).
    pub completed: bool,
}

impl ResizeReport {
    /// Relative prediction error (signed; + = model overestimates).
    pub fn rel_err(&self) -> f64 {
        (self.predicted_reconf - self.observed_reconf) / self.observed_reconf
    }

    /// Observed aggregate registration throughput
    /// (`bytes_registered / reg_span`, B/s) — the measurement hook for
    /// online `NetParams::beta_register` recalibration.  `None` when
    /// the resize registered nothing: either fully warm
    /// ([`ResizeReport::warm`]) or a version that never registers (COL
    /// without the pool).  Rendering a throughput of `0.00` for these
    /// would be misleading — there was no registration to measure.
    pub fn reg_throughput(&self) -> Option<f64> {
        if self.reg_secs > 0.0 {
            Some(self.reg_bytes / self.reg_secs)
        } else {
            None
        }
    }
}

/// Fault-injection outcome of a scenario (`--faults`): how the
/// recovery machinery fared across the whole trace.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultSummary {
    /// Resizes that aborted and rolled back (caches poisoned, app
    /// resumed on the old communicator) — summed over re-dispatches.
    pub rollbacks: u64,
    /// Failed spawn attempts that were retried within a dispatch.
    pub spawn_retries: u64,
    /// Resizes that eventually went through.
    pub completed_resizes: u64,
    /// Resizes the RMS trace scheduled.
    pub scheduled_resizes: u64,
}

/// Full scenario outcome.
#[derive(Clone, Debug)]
pub struct ScenarioReport {
    pub name: String,
    pub label: String,
    /// Virtual time at which the last rank finished.
    pub makespan: f64,
    pub total_iters: u64,
    pub resizes: Vec<ResizeReport>,
    pub events: u64,
    /// Engine observability counters (`engine.*`), in a fixed order.
    pub engine: Vec<(String, u64)>,
    /// Present only when fault injection was active — the healthy
    /// report (text and JSON) stays byte-identical to the fault-free
    /// build.
    pub faults: Option<FaultSummary>,
}

impl ScenarioReport {
    /// Deterministic text rendering (per-resize predicted vs observed,
    /// then the makespan line).
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "\n== Scenario {} [{}]: per-resize predicted vs observed ==\n",
            self.name, self.label
        ));
        out.push_str(&format!(
            "{:<4}{:<10}{:<26}{:>12}{:>12}{:>9}{:>6}{:>10}\n",
            "idx", "pair", "version", "predicted", "observed", "err%", "n_it", "reg GB/s"
        ));
        for r in &self.resizes {
            let reg = match r.reg_throughput() {
                Some(t) => format!("{:.2}", t / 1e9),
                None if r.warm => "warm".to_string(),
                None => "-".to_string(),
            };
            out.push_str(&format!(
                "r{:<3}{:<10}{:<26}{:>12}{:>12}{:>8.1}%{:>6.0}{:>10}\n",
                r.index,
                format!("{}->{}", r.from, r.to),
                r.label,
                fmt_seconds(r.predicted_reconf),
                fmt_seconds(r.observed_reconf),
                100.0 * r.rel_err(),
                r.n_it,
                reg,
            ));
        }
        out.push_str(&format!(
            "makespan: {} over {} iterations, {} resizes\n",
            fmt_seconds(self.makespan),
            self.total_iters,
            self.resizes.len()
        ));
        if let Some(f) = &self.faults {
            out.push_str(&format!(
                "faults: {} rollback(s), {} spawn retrie(s), {}/{} resizes completed\n",
                f.rollbacks, f.spawn_retries, f.completed_resizes, f.scheduled_resizes
            ));
        }
        out
    }

    /// JSON export (CI artifacts, determinism checks).
    pub fn to_json(&self) -> Json {
        let mut top = vec![
            ("name", Json::str(self.name.clone())),
            ("label", Json::str(self.label.clone())),
            ("makespan_s", Json::num(self.makespan)),
            ("total_iters", Json::num(self.total_iters as f64)),
        ];
        if let Some(f) = &self.faults {
            top.push((
                "faults",
                Json::obj(vec![
                    ("rollbacks", Json::num(f.rollbacks as f64)),
                    ("spawn_retries", Json::num(f.spawn_retries as f64)),
                    ("completed_resizes", Json::num(f.completed_resizes as f64)),
                    ("scheduled_resizes", Json::num(f.scheduled_resizes as f64)),
                ]),
            ));
        }
        top.extend(vec![
            (
                "engine",
                Json::Obj(
                    self.engine
                        .iter()
                        .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                        .collect(),
                ),
            ),
            (
                "resizes",
                Json::Arr(
                    self.resizes
                        .iter()
                        .map(|r| {
                            let mut fields = vec![
                                ("index", Json::num(r.index as f64)),
                                ("from", Json::num(r.from as f64)),
                                ("to", Json::num(r.to as f64)),
                                ("version", Json::str(r.label.clone())),
                                ("predicted_s", Json::num(r.predicted_reconf)),
                                ("observed_s", Json::num(r.observed_reconf)),
                                ("n_it", Json::num(r.n_it)),
                                ("reg_bytes", Json::num(r.reg_bytes)),
                                ("reg_time_s", Json::num(r.reg_secs)),
                                ("setup_s", Json::num(r.setup_secs)),
                            ];
                            // No registration → no throughput to report:
                            // the key is absent (a 0.00 would read as a
                            // measured rate), and fully-warm resizes say
                            // so explicitly.
                            if let Some(t) = r.reg_throughput() {
                                fields.push(("reg_gbps", Json::num(t / 1e9)));
                            } else if r.warm {
                                fields.push(("reg_gbps", Json::str("warm")));
                            }
                            // Dispatch accounting exists only under
                            // fault injection: the healthy JSON stays
                            // byte-identical to the fault-free build.
                            if self.faults.is_some() {
                                fields.push(("dispatches", Json::num(r.dispatches as f64)));
                                fields.push(("completed", Json::Bool(r.completed)));
                            }
                            Json::obj(fields)
                        })
                        .collect(),
                ),
            ),
        ]);
        Json::obj(top)
    }
}

/// Shared context of the simulated application ranks.
struct ScenCtx {
    sam: SamConfig,
    seed: u64,
    total_iters: u64,
    decls: Vec<DataDecl>,
    resizes: Vec<PlannedResize>,
    cores_per_node: usize,
    spawn_cost: f64,
    /// Seed belief the in-sim recalibrators start from (the spec's
    /// calibration — in the closed loop the environment and the seed
    /// belief coincide, so the error trajectory measures pure model
    /// residue, not drift).
    net: NetParams,
    /// Live in-sim re-resolution is armed (recalib on + Auto planner).
    recalib_live: bool,
    /// Sync/cache knobs the live re-resolution must carry into its
    /// choices (the belief replaces the plan, not the configuration).
    rma_sync: RmaSync,
    sched_cache: bool,
    /// Spawn-failure probability the planner prices retries with
    /// (0.0 when faults are off — the healthy planner, bit for bit).
    fail_p: f64,
}

/// Resolve one resize analytically from a live belief (no probes —
/// this runs *inside* the simulation, identically on every rank, so it
/// must be a pure function of the belief and the shape).
#[allow(clippy::too_many_arguments)]
fn live_resolve(
    ctx: &ScenCtx,
    net: &NetParams,
    decls: &[DataDecl],
    from: usize,
    to: usize,
    extra_chunks_kib: Vec<u64>,
) -> (ReconfigCfg, String, f64) {
    let inp = PlannerInputs {
        decls: decls.to_vec(),
        ns: from,
        nd: to,
        cores_per_node: ctx.cores_per_node,
        net: net.clone(),
        spawn_cost: ctx.spawn_cost,
        warm: false,
        t_iter_src: ctx.sam.iter_compute(from),
        t_iter_dst: ctx.sam.iter_compute(to),
        objective: Objective::ReconfTime,
        probe: false,
        extra_chunks_kib,
        rma_sync: ctx.rma_sync,
        sched_cache: ctx.sched_cache,
        // The live belief re-resolves from scratch each resize; warm
        // credit stays with the static schedule, which knows the trace.
        sched_warm: false,
        future_resizes: 0,
        fail_p: ctx.fail_p,
    };
    let plan = planner::plan(&inp);
    let cfg = plan
        .choice
        .cfg(ctx.spawn_cost)
        .with_sync(ctx.rma_sync)
        .with_sched_cache(ctx.sched_cache);
    (cfg, plan.label(), plan.predicted_reconf)
}

/// Reconstruct resize `index`'s calibration observation from the
/// (final) global metric marks.  Callable both in-sim — after the
/// post-resize barrier every mark of the resize is final, so all ranks
/// read identical values and the replicated recalibrator beliefs stay
/// bit-identical — and post-run, to replay the belief trajectory for
/// reporting.
fn observation_from(
    m: &Metrics,
    index: usize,
    from: usize,
    to: usize,
    cores_per_node: usize,
    decls: &[DataDecl],
) -> Observation {
    let delta = |a: String, b: String| m.span(&a, &b).unwrap_or(0.0).max(0.0);
    let reconf = m
        .span(&format!("scen.r{index}.start"), &format!("scen.r{index}.end"))
        .unwrap_or(0.0)
        .max(0.0);
    let predicted = m.mark_at(&format!("scen.r{index}.live_pred")).unwrap_or(reconf);
    let total: u64 = decls.iter().map(|d| d.total_elems * ELEM_BYTES).sum();
    Observation {
        ns: from,
        nd: to,
        reconf,
        predicted,
        // The closed loop drives the DES with the same spawn constants
        // the belief carries, so there is no spawn drift to learn:
        // leave the spawn axis out of the residual entirely.
        spawn_block: 0.0,
        predicted_spawn_block: 0.0,
        spawn_waves: None,
        reg_bytes: delta(
            format!("scen.r{index}.reg_bytes0"),
            format!("scen.r{index}.reg_bytes1"),
        ),
        reg_secs: delta(
            format!("scen.r{index}.reg_time0"),
            format!("scen.r{index}.reg_time1"),
        ),
        wire_slope: costmodel::wire_slope(total, from, to, cores_per_node),
    }
}

/// Feed resize `index`'s observation (and per-structure chunk hints)
/// into a recalibrator — the single shared definition of "one step of
/// the belief", used by the in-sim loop, by drains replaying the
/// resizes they missed, and by the post-run report replay.
fn feed_observation(
    rc: &mut Recalibrator,
    m: &Metrics,
    index: usize,
    from: usize,
    to: usize,
    cores_per_node: usize,
    decls: &[DataDecl],
) {
    let obs = observation_from(m, index, from, to, cores_per_node, decls);
    rc.observe(&obs);
    for d in decls {
        rc.note_chunk(&d.name, d.total_elems * ELEM_BYTES / (to.max(1) as u64));
    }
}

/// Stage 2: execute the scenario on the simulated cluster.
pub fn run_scenario(spec: &ScenarioSpec) -> ScenarioReport {
    let resizes = schedule(spec);
    let peak = resizes
        .iter()
        .map(|r| r.from.max(r.to))
        .max()
        .unwrap_or(spec.start_cores)
        .max(spec.start_cores);
    let cpn = spec.cores_per_node.max(1);
    let topo = Topology::new_cyclic(peak.div_ceil(cpn).max(1), cpn);
    let mut sim = MpiSim::new(topo, spec.net.clone());
    if let Some(f) = &spec.faults {
        sim.set_faults(FaultPlan::new(f.clone()));
    }
    let world = sim.world();
    let recalib_live = spec.recalib && spec.planner == PlannerMode::Auto;
    let ctx = Arc::new(ScenCtx {
        sam: spec.sam.clone(),
        seed: spec.seed,
        total_iters: spec.total_iters,
        decls: spec.decls(),
        resizes: resizes.clone(),
        cores_per_node: cpn,
        spawn_cost: spec.spawn_cost,
        net: spec.net.clone(),
        recalib_live,
        rma_sync: spec.rma_sync,
        sched_cache: spec.sched_cache,
        fail_p: spec.faults.as_ref().map_or(0.0, |f| f.spawn_fail_p),
    });
    let base_cfg = ReconfigCfg::version(spec.method, spec.strategy)
        .with_spawn(spec.spawn_strategy, spec.spawn_cost)
        .with_pool(spec.win_pool)
        .with_chunk(spec.rma_chunk_kib)
        .with_sync(spec.rma_sync)
        .with_sched_cache(spec.sched_cache)
        .with_recalib(spec.recalib);
    let start = spec.start_cores;
    let ctx2 = ctx.clone();
    sim.launch(start, move |p: MpiProc| {
        let rank = p.rank(WORLD);
        let sam = Sam::new(ctx2.sam.clone(), ctx2.seed, p.gpid());
        let mut reg = Registry::new();
        sam.register_data(&mut reg, start, rank);
        let mam = Mam::new(reg, base_cfg.clone());
        let recal =
            if ctx2.recalib_live { Some(Recalibrator::new(ctx2.net.clone())) } else { None };
        app_loop(&ctx2, &p, WORLD, mam, sam, 0, 0, recal);
    });
    let makespan = sim.run().expect("scenario simulation failed");
    let w = world.lock().unwrap();
    let m = &w.metrics;
    // Under live recalibration the executed version is not the
    // scheduled one: replay the belief trajectory against the final
    // metrics (the exact sequence of pure-function steps every rank
    // performed in-sim) to recover each resize's live choice.
    let live: Option<Vec<(ReconfigCfg, String)>> = if recalib_live {
        let mut rc = Recalibrator::new(spec.net.clone());
        Some(
            resizes
                .iter()
                .map(|r| {
                    let (cfg, label, _pred) = live_resolve(
                        &ctx,
                        rc.params(),
                        &ctx.decls,
                        r.from,
                        r.to,
                        rc.chunk_candidates(),
                    );
                    feed_observation(&mut rc, m, r.index, r.from, r.to, cpn, &ctx.decls);
                    (cfg, format!("live[{label}]"))
                })
                .collect(),
        )
    } else {
        None
    };
    let reports: Vec<ResizeReport> = resizes
        .iter()
        .map(|r| {
            let reg_secs = m
                .span(
                    &format!("scen.r{}.reg_time0", r.index),
                    &format!("scen.r{}.reg_time1", r.index),
                )
                .unwrap_or(0.0)
                .max(0.0);
            let setup_secs = m
                .span(&format!("scen.r{}.setup0", r.index), &format!("scen.r{}.setup1", r.index))
                .unwrap_or(0.0)
                .max(0.0);
            let (exec_cfg, label) = match &live {
                Some(v) => (&v[r.index].0, v[r.index].1.clone()),
                None => (&r.cfg, r.label.clone()),
            };
            // The version registers (RMA windows, or register-on-receive
            // pre-pins under the pool) but charged nothing: fully warm.
            let registers = exec_cfg.method.is_rma() || exec_cfg.win_pool.enabled;
            ResizeReport {
                index: r.index,
                from: r.from,
                to: r.to,
                label,
                predicted_reconf: m
                    .mark_at(&format!("scen.r{}.live_pred", r.index))
                    .unwrap_or(r.predicted_reconf),
                observed_reconf: m
                    .span(&format!("scen.r{}.start", r.index), &format!("scen.r{}.end", r.index))
                    .unwrap_or(f64::NAN),
                n_it: m.mark_at(&format!("scen.r{}.n_it", r.index)).unwrap_or(0.0),
                reg_bytes: m
                    .span(
                        &format!("scen.r{}.reg_bytes0", r.index),
                        &format!("scen.r{}.reg_bytes1", r.index),
                    )
                    .unwrap_or(0.0)
                    .max(0.0),
                reg_secs,
                setup_secs,
                warm: registers && reg_secs == 0.0,
                dispatches: m
                    .mark_at(&format!("scen.r{}.dispatches", r.index))
                    .unwrap_or(1.0) as u64,
                completed: m.mark_at(&format!("scen.r{}.completed", r.index)).is_some(),
            }
        })
        .collect();
    let engine = [
        "engine.events",
        "engine.peak_queue",
        "engine.wakeup_batches",
        "engine.wakeup_ranks",
        "engine.wakeup_max",
        "engine.sweep_direct",
        "engine.rollbacks",
        "engine.snapshots",
    ]
    .iter()
    .map(|k| (k.to_string(), m.counter(k).unwrap_or(0.0) as u64))
    .collect::<Vec<_>>();
    let faults = spec.faults.as_ref().filter(|f| f.is_active()).map(|_| FaultSummary {
        rollbacks: m.counter("faults.rollbacks").unwrap_or(0.0) as u64,
        spawn_retries: m.counter("faults.spawn_retries").unwrap_or(0.0) as u64,
        completed_resizes: reports.iter().filter(|r| r.completed).count() as u64,
        scheduled_resizes: reports.len() as u64,
    });
    ScenarioReport {
        name: spec.name.clone(),
        label: spec.version_label(),
        makespan,
        total_iters: spec.total_iters,
        resizes: reports,
        events: m.counter("engine.events").unwrap_or(0.0) as u64,
        engine,
        faults,
    }
}

/// The malleable application's main loop, shared by the launch ranks
/// and every spawned drain: iterate, and when the iteration count hits
/// the next scheduled resize, reconfigure through MaM (overlapping
/// iterations under background strategies with the consistent-stop
/// protocol).  Returns when the rank retires (shrink) or the work
/// budget is done.
fn app_loop(
    ctx: &Arc<ScenCtx>,
    p: &MpiProc,
    mut comm: CommId,
    mut mam: Mam,
    mut sam: Sam,
    mut count: u64,
    mut next: usize,
    mut recal: Option<Recalibrator>,
) {
    loop {
        if next < ctx.resizes.len() && count >= ctx.resizes[next].at_iter {
            let r = &ctx.resizes[next];
            // Fault-aware re-anchoring: an earlier abandoned resize
            // leaves the job on a stale size, so each dispatch starts
            // from the size the job actually holds — and a resize whose
            // target the job already holds is a no-op.  Fault-free runs
            // always see `from_now == r.from`.
            let from_now = p.size(comm);
            if from_now == r.to {
                p.metrics(|m| {
                    m.mark_min(&format!("scen.r{}.start", r.index), p.now());
                    m.mark_max(&format!("scen.r{}.end", r.index), p.now());
                    m.mark_max(&format!("scen.r{}.dispatches", r.index), 0.0);
                });
                next += 1;
                continue;
            }
            // Live re-resolution: the belief — replicated bit-identically
            // on every rank — replaces the statically scheduled plan.
            let (exec_cfg, live_pred) = match recal.as_ref() {
                Some(rc) => {
                    let (cfg, _label, pred) = live_resolve(
                        ctx,
                        rc.params(),
                        &ctx.decls,
                        from_now,
                        r.to,
                        rc.chunk_candidates(),
                    );
                    (cfg, Some(pred))
                }
                None => (r.cfg.clone(), None),
            };
            p.metrics(|m| {
                m.mark_min(&format!("scen.r{}.start", r.index), p.now());
                if let Some(pred) = live_pred {
                    m.mark_min(&format!("scen.r{}.live_pred", r.index), pred);
                }
                // Registration-throughput hook: snapshot the cumulative
                // registration counters before the resize (no rank has
                // registered anything for it yet), so the post-resize
                // delta is this resize's observed registration work.
                let rb = m.counter("rma.reg_bytes").unwrap_or(0.0);
                let rt = m.counter("rma.reg_time").unwrap_or(0.0);
                m.mark_min(&format!("scen.r{}.reg_bytes0", r.index), rb);
                m.mark_min(&format!("scen.r{}.reg_time0", r.index), rt);
                // Non-wire setup snapshot: schedule work + registration
                // + completion sync, so the post-resize delta isolates
                // what the schedule cache and notified sync save.
                let setup = rt
                    + m.counter("sched.time").unwrap_or(0.0)
                    + m.counter("rma.sync_time").unwrap_or(0.0);
                m.mark_min(&format!("scen.r{}.setup0", r.index), setup);
            });
            let mut dispatch: u64 = 0;
            let outcome = loop {
                mam.cfg = exec_cfg.clone();
                mam.set_fault_ctx(r.index as u64, dispatch);
                let ctx3 = ctx.clone();
                let ridx = next;
                let body_cfg = exec_cfg.clone();
                let body: Arc<dyn Fn(MpiProc, CommId) + Send + Sync> =
                    Arc::new(move |dp: MpiProc, merged: CommId| {
                        drain_entry(&ctx3, dp, merged, ridx, from_now, body_cfg.clone());
                    });
                let status = mam.reconfigure(p, comm, r.to, body);
                if status == MamStatus::Aborted {
                    // Rollback: the schedule/window caches are poisoned
                    // and the app still owns the old communicator.  The
                    // RMS re-queues the resize after a breather, up to
                    // the dispatch cap.
                    dispatch += 1;
                    if dispatch >= MAX_DISPATCHES {
                        break None;
                    }
                    for _ in 0..REQUEUE_ITERS {
                        let _ = sam.iteration(p, comm);
                        count += 1;
                    }
                    continue;
                }
                let mut n_it = 0u64;
                if status == MamStatus::InProgress {
                    let mut local_done = false;
                    loop {
                        let (_dur, all_done) = sam.iteration_with_flag(p, comm, local_done);
                        if !local_done {
                            count += 1;
                            n_it += 1;
                            if mam.checkpoint(p) == MamStatus::Completed {
                                local_done = true;
                            }
                        }
                        if all_done {
                            break;
                        }
                    }
                }
                break Some((mam.finish(p, comm), n_it));
            };
            let Some((out, n_it)) = outcome else {
                // Abandoned after the dispatch cap: record the failed
                // dispatches and move on — the job keeps the layout it
                // owns, and later resizes re-anchor on it.
                p.metrics(|m| {
                    m.mark_max(&format!("scen.r{}.dispatches", r.index), dispatch as f64);
                    m.mark_max(&format!("scen.r{}.end", r.index), p.now());
                });
                next += 1;
                continue;
            };
            let Some(c) = out.app_comm else {
                return; // retired by the shrink
            };
            comm = c;
            // Every continuing rank adopts the sources' iteration count
            // (spawned drains join at 0).
            count = sync_count(p, comm, count);
            p.metrics(|m| {
                m.mark_max(&format!("scen.r{}.end", r.index), p.now());
                m.mark_max(&format!("scen.r{}.n_it", r.index), n_it as f64);
                m.mark_max(&format!("scen.r{}.dispatches", r.index), (dispatch + 1) as f64);
                m.mark_max(&format!("scen.r{}.completed", r.index), 1.0);
                let rb = m.counter("rma.reg_bytes").unwrap_or(0.0);
                let rt = m.counter("rma.reg_time").unwrap_or(0.0);
                m.mark_max(&format!("scen.r{}.reg_bytes1", r.index), rb);
                m.mark_max(&format!("scen.r{}.reg_time1", r.index), rt);
                let setup = rt
                    + m.counter("sched.time").unwrap_or(0.0)
                    + m.counter("rma.sync_time").unwrap_or(0.0);
                m.mark_max(&format!("scen.r{}.setup1", r.index), setup);
            });
            if let Some(rc) = recal.as_mut() {
                // Mark-finality barrier: every continuing rank (sources
                // and fresh drains alike) has written its end/counter
                // marks before any rank reads them, so the observation
                // below is the same bit pattern everywhere.
                let _ = sync_count(p, comm, 0);
                p.metrics(|m| {
                    feed_observation(rc, m, r.index, r.from, r.to, ctx.cores_per_node, &ctx.decls);
                });
            }
            next += 1;
            continue;
        }
        if count >= ctx.total_iters {
            break;
        }
        let _ = sam.iteration(p, comm);
        count += 1;
    }
}

/// Entry point of drains spawned at resize `ridx`: mirror the
/// redistribution (under the same configuration the sources executed —
/// captured in the drain body, since a live-resolved choice is not the
/// scheduled one), adopt the iteration count, continue as a regular
/// rank (possibly through further resizes).
fn drain_entry(
    ctx: &Arc<ScenCtx>,
    dp: MpiProc,
    merged: CommId,
    ridx: usize,
    from: usize,
    cfg: ReconfigCfg,
) {
    let r = &ctx.resizes[ridx];
    let mam = Mam::drain_join(&dp, merged, from, r.to, &ctx.decls, cfg);
    let sam = Sam::new(ctx.sam.clone(), ctx.seed, dp.gpid());
    let count = sync_count(&dp, merged, 0);
    dp.metrics(|m| {
        m.mark_max(&format!("scen.r{}.end", r.index), dp.now());
        let rb = m.counter("rma.reg_bytes").unwrap_or(0.0);
        let rt = m.counter("rma.reg_time").unwrap_or(0.0);
        m.mark_max(&format!("scen.r{}.reg_bytes1", r.index), rb);
        m.mark_max(&format!("scen.r{}.reg_time1", r.index), rt);
        let setup = rt
            + m.counter("sched.time").unwrap_or(0.0)
            + m.counter("rma.sync_time").unwrap_or(0.0);
        m.mark_max(&format!("scen.r{}.setup1", r.index), setup);
    });
    let recal = if ctx.recalib_live {
        // Rebuild the belief a continuing source holds at this point:
        // replay the resizes this drain missed (their marks are final —
        // each was sealed by its own post-resize barrier before the
        // next resize, and this drain exists because resize `ridx`
        // started), then join the sources' barrier and observe `ridx`
        // with everyone else.
        let mut rc = Recalibrator::new(ctx.net.clone());
        let _ = sync_count(&dp, merged, 0);
        dp.metrics(|m| {
            for j in 0..=ridx {
                let rj = &ctx.resizes[j];
                let (cpn, decls) = (ctx.cores_per_node, &ctx.decls);
                feed_observation(&mut rc, m, rj.index, rj.from, rj.to, cpn, decls);
            }
        });
        Some(rc)
    } else {
        None
    };
    app_loop(ctx, &dp, merged, mam, sam, count, ridx + 1, recal);
}

/// Post-resize count agreement: allgather each rank's iteration count
/// and take the maximum (identical collective position on every
/// continuing rank, sources and fresh drains alike).
fn sync_count(p: &MpiProc, comm: CommId, count: u64) -> u64 {
    let got = p.allgather(comm, Payload::real(vec![count as f64]));
    got.iter()
        .filter_map(|b| b.as_slice().and_then(|s| s.first().copied()))
        .fold(0.0, f64::max) as u64
}

/// Makespan comparison: the planner against the fixed anchor versions,
/// one `run_scenario` per column.
pub fn makespan_comparison(base: &ScenarioSpec) -> FigureTable {
    let fixed: [(Method, Strategy, WinPoolPolicy, u64); 6] = [
        (Method::Collective, Strategy::Blocking, WinPoolPolicy::off(), 0),
        (Method::RmaLockall, Strategy::Blocking, WinPoolPolicy::off(), 0),
        (Method::RmaLockall, Strategy::Blocking, WinPoolPolicy::off(), 1024),
        (Method::RmaLockall, Strategy::Blocking, WinPoolPolicy::on(), 0),
        (Method::Collective, Strategy::WaitDrains, WinPoolPolicy::off(), 0),
        (Method::RmaLockall, Strategy::WaitDrains, WinPoolPolicy::on(), 0),
    ];
    let mut specs: Vec<ScenarioSpec> = Vec::new();
    let mut auto = base.clone();
    auto.planner = PlannerMode::Auto;
    specs.push(auto);
    for (m, s, pool, chunk) in fixed {
        let mut sp = base.clone();
        sp.planner = PlannerMode::Fixed;
        sp.method = m;
        sp.strategy = s;
        sp.win_pool = pool;
        sp.rma_chunk_kib = chunk;
        sp.spawn_strategy = SpawnStrategy::Sequential;
        specs.push(sp);
    }
    let labels: Vec<String> = specs.iter().map(|s| s.version_label()).collect();
    let cols: Vec<&str> = labels.iter().map(|s| s.as_str()).collect();
    let mut t = FigureTable::new(
        "Scenario makespan (s): planner vs fixed versions, speedup vs auto",
        "trace",
        &cols,
        0,
    );
    let row: Vec<f64> = specs.iter().map(|s| run_scenario(s).makespan).collect();
    t.row(&base.name, row);
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_reproduces_the_adaptive_trace() {
        // The default trace must exercise the full repertoire: grow
        // into idle space, shrink for a queued arrival (FIFO), grow
        // back on departure — closing the loop over the fixed RMS
        // bugs (FIFO submit, per-job plan state is irrelevant here but
        // the Adaptive path is).
        let spec = ScenarioSpec::rms_trace(true);
        let resizes = schedule(&spec);
        let pairs: Vec<(usize, usize)> = resizes.iter().map(|r| (r.from, r.to)).collect();
        assert_eq!(pairs, vec![(8, 16), (16, 8), (8, 16), (16, 12), (12, 16)]);
        let at: Vec<u64> = resizes.iter().map(|r| r.at_iter).collect();
        assert_eq!(at, vec![6, 12, 24, 30, 42]);
        for r in &resizes {
            assert_eq!(r.cfg.planner, PlannerMode::Fixed, "plans must be resolved");
            assert!(r.predicted_reconf.is_finite() && r.predicted_reconf > 0.0);
            assert!(!r.label.is_empty());
        }
    }

    #[test]
    fn fixed_scenario_runs_deterministically() {
        let mut spec = ScenarioSpec::rms_trace(true);
        spec.planner = PlannerMode::Fixed;
        let a = run_scenario(&spec);
        let b = run_scenario(&spec);
        assert!(a.makespan.is_finite() && a.makespan > 0.0);
        assert_eq!(a.resizes.len(), 5);
        assert_eq!(
            a.to_json().to_pretty(),
            b.to_json().to_pretty(),
            "scenario output must be byte-deterministic"
        );
        for r in &a.resizes {
            assert!(r.observed_reconf.is_finite() && r.observed_reconf > 0.0, "{r:?}");
        }
        // The render contains the full accuracy table.
        let s = a.render();
        assert!(s.contains("predicted"), "{s}");
        assert!(s.contains("makespan"), "{s}");
    }

    #[test]
    fn auto_scenario_plans_every_resize_and_completes() {
        let spec = ScenarioSpec::rms_trace(true); // planner: Auto
        let a = run_scenario(&spec);
        assert_eq!(a.label, "auto");
        assert_eq!(a.resizes.len(), 5);
        assert!(a.makespan.is_finite() && a.makespan > 0.0);
        for r in &a.resizes {
            assert!(!r.label.is_empty());
            assert!(r.observed_reconf.is_finite() && r.observed_reconf > 0.0, "{r:?}");
            assert!(r.predicted_reconf > 0.0);
        }
        // Determinism across repetitions (probes included).
        let b = run_scenario(&spec);
        assert_eq!(a.to_json().to_pretty(), b.to_json().to_pretty());
    }

    #[test]
    fn scenario_reports_registration_throughput_for_rma() {
        // Fixed RMA version: every resize registers windows, so the
        // observed registration throughput is reportable per resize —
        // the online-NetParams-recalibration input hook.
        let mut spec = ScenarioSpec::rms_trace(true);
        spec.planner = PlannerMode::Fixed;
        spec.method = Method::RmaLockall;
        spec.strategy = Strategy::Blocking;
        let rep = run_scenario(&spec);
        for r in &rep.resizes {
            assert!(r.reg_bytes > 0.0, "resize {} registered nothing: {r:?}", r.index);
            assert!(r.reg_secs > 0.0, "{r:?}");
            let thr = r.reg_throughput().unwrap();
            assert!(thr.is_finite() && thr > 0.0, "{r:?}");
        }
        // COL without the pool never registers: the column stays empty.
        let mut col = ScenarioSpec::rms_trace(true);
        col.planner = PlannerMode::Fixed;
        let rep = run_scenario(&col);
        for r in &rep.resizes {
            assert_eq!(r.reg_throughput(), None, "{r:?}");
        }
        // The render carries the column either way.
        assert!(rep.render().contains("reg GB/s"));
    }

    #[test]
    fn fully_warm_resizes_render_warm_not_zero() {
        // Pooled RMA: the first resize registers cold and
        // register-on-receive pins every new block, so later no-spawn
        // resizes ride the cache end to end — they must render "warm"
        // (and mark the JSON throughput as such), never a misleading
        // "0.00 reg GB/s".
        let mut spec = ScenarioSpec::rms_trace(true);
        spec.planner = PlannerMode::Fixed;
        spec.method = Method::RmaLockall;
        spec.strategy = Strategy::Blocking;
        spec.win_pool = WinPoolPolicy::on();
        let rep = run_scenario(&spec);
        assert!(
            rep.resizes[0].reg_secs > 0.0,
            "first resize must register cold: {:?}",
            rep.resizes[0]
        );
        assert!(!rep.resizes[0].warm);
        let warm: Vec<&ResizeReport> = rep.resizes.iter().filter(|r| r.warm).collect();
        assert!(!warm.is_empty(), "no fully-warm resize in the pooled trace: {:?}", rep.resizes);
        for r in &warm {
            assert_eq!(r.reg_throughput(), None, "{r:?}");
            assert_eq!(r.reg_bytes, 0.0, "{r:?}");
        }
        let txt = rep.render();
        assert!(txt.contains("warm"), "{txt}");
        let j = rep.to_json().to_pretty();
        assert!(j.contains("\"warm\""), "{j}");
        // COL without the pool never registers: no "warm", and the
        // throughput key stays absent rather than zero.
        let mut col = ScenarioSpec::rms_trace(true);
        col.planner = PlannerMode::Fixed;
        let rep = run_scenario(&col);
        assert!(rep.resizes.iter().all(|r| !r.warm), "{:?}", rep.resizes);
        assert!(!rep.to_json().to_pretty().contains("reg_gbps"));
        assert!(!rep.render().contains("warm"));
    }

    #[test]
    fn chunked_fixed_scenario_runs_deterministically() {
        let mut spec = ScenarioSpec::rms_trace(true);
        spec.planner = PlannerMode::Fixed;
        spec.method = Method::RmaLockall;
        spec.strategy = Strategy::Blocking;
        spec.rma_chunk_kib = 1; // tiny quick-mode blocks: force segmentation
        assert!(spec.version_label().contains("+c1k"), "{}", spec.version_label());
        let a = run_scenario(&spec);
        let b = run_scenario(&spec);
        assert!(a.makespan.is_finite() && a.makespan > 0.0);
        assert_eq!(a.to_json().to_pretty(), b.to_json().to_pretty());
    }

    #[test]
    fn recalib_scenario_resolves_live_and_runs_deterministically() {
        let mut spec = ScenarioSpec::rms_trace(true); // planner: Auto
        spec.recalib = true;
        let a = run_scenario(&spec);
        assert_eq!(a.label, "auto+recalib");
        assert_eq!(a.resizes.len(), 5);
        assert!(a.makespan.is_finite() && a.makespan > 0.0);
        for r in &a.resizes {
            // The reported choice is the live resolution, not the
            // static schedule, and its in-sim prediction mark is the
            // accuracy baseline.
            assert!(r.label.starts_with("live["), "{r:?}");
            assert!(r.predicted_reconf.is_finite() && r.predicted_reconf > 0.0, "{r:?}");
            assert!(r.observed_reconf.is_finite() && r.observed_reconf > 0.0, "{r:?}");
        }
        // The replicated-belief protocol (per-rank recalibrators plus
        // drain replay) must stay bit-deterministic across runs.
        let b = run_scenario(&spec);
        assert_eq!(a.to_json().to_pretty(), b.to_json().to_pretty());
    }

    #[test]
    fn recalib_off_leaves_the_auto_scenario_label_and_plan_static() {
        // The off path never marks live predictions and reports the
        // scheduled labels — the recalib field rides along inert.
        let spec = ScenarioSpec::rms_trace(true);
        assert!(!spec.recalib);
        let rep = run_scenario(&spec);
        assert_eq!(rep.label, "auto");
        for r in &rep.resizes {
            assert!(!r.label.starts_with("live["), "{r:?}");
        }
    }

    #[test]
    fn osc_schedule_oscillates_between_20_and_160() {
        // The headline oscillation: every pair after its first
        // occurrence is a replay of an identical redistribution shape.
        let mut spec = ScenarioSpec::osc_trace(true);
        spec.planner = PlannerMode::Fixed;
        let resizes = schedule(&spec);
        let pairs: Vec<(usize, usize)> = resizes.iter().map(|r| (r.from, r.to)).collect();
        assert_eq!(pairs, vec![(20, 160), (160, 20), (20, 160), (160, 20), (20, 160)]);
        let at: Vec<u64> = resizes.iter().map(|r| r.at_iter).collect();
        assert_eq!(at, vec![4, 8, 16, 20, 28]);
    }

    #[test]
    fn sched_cache_replays_cut_nonwire_setup_by_30_percent() {
        // The PR's acceptance bar: on the oscillating trace with the
        // schedule cache (and pool + notified sync) on, every replayed
        // resize charges at least 30% less non-wire setup — schedule
        // build + registration + completion sync — than the cold first
        // occurrence of its pair.
        let mut spec = ScenarioSpec::osc_trace(true);
        spec.planner = PlannerMode::Fixed;
        spec.method = Method::RmaLockall;
        spec.strategy = Strategy::Blocking;
        spec.win_pool = WinPoolPolicy::on();
        spec.sched_cache = true;
        spec.rma_sync = RmaSync::Notify;
        let rep = run_scenario(&spec);
        assert_eq!(rep.resizes.len(), 5);
        let mut first: BTreeMap<(usize, usize), f64> = BTreeMap::new();
        let mut replays = 0;
        for r in &rep.resizes {
            assert!(r.setup_secs.is_finite() && r.setup_secs > 0.0, "{r:?}");
            match first.get(&(r.from, r.to)) {
                None => {
                    first.insert((r.from, r.to), r.setup_secs);
                }
                Some(&cold) => {
                    replays += 1;
                    assert!(
                        r.setup_secs <= 0.7 * cold,
                        "resize {} ({}->{}): replay setup {} !<= 70% of cold {}",
                        r.index,
                        r.from,
                        r.to,
                        r.setup_secs,
                        cold
                    );
                }
            }
        }
        assert_eq!(replays, 3, "the trace must replay three resizes");
        // The setup metric rides the JSON export for CI artifacts.
        assert!(rep.to_json().to_pretty().contains("setup_s"));
    }

    #[test]
    fn background_fixed_scenario_overlaps_iterations() {
        let mut spec = ScenarioSpec::rms_trace(true);
        spec.planner = PlannerMode::Fixed;
        spec.method = Method::RmaLockall;
        spec.strategy = Strategy::WaitDrains;
        let rep = run_scenario(&spec);
        assert!(rep.makespan.is_finite() && rep.makespan > 0.0);
        assert_eq!(rep.resizes.len(), 5);
        // Wait Drains keeps the sources iterating: every resize must
        // overlap at least one application iteration.
        for r in &rep.resizes {
            assert!(r.n_it >= 1.0, "resize {} overlapped nothing: {r:?}", r.index);
        }
    }

    #[test]
    fn recoverable_faults_complete_every_resize_and_report_retries() {
        // Every grow's first spawn attempt fails; the second succeeds
        // within the default retry budget, so no resize rolls back.
        let mut spec = ScenarioSpec::rms_trace(true);
        spec.planner = PlannerMode::Fixed;
        spec.faults = Some(FaultSpec::parse("spawn=first1,mode=wave").unwrap());
        let rep = run_scenario(&spec);
        let f = rep.faults.clone().expect("fault summary must be present when faults are on");
        assert_eq!(f.scheduled_resizes, 5);
        assert_eq!(f.completed_resizes, 5, "{rep:?}");
        assert_eq!(f.rollbacks, 0, "{f:?}");
        assert!(f.spawn_retries > 0, "{f:?}");
        for r in &rep.resizes {
            assert_eq!(r.dispatches, 1, "{r:?}");
            assert!(r.completed, "{r:?}");
        }
        let j = rep.to_json().to_pretty();
        assert!(j.contains("\"rollbacks\"") && j.contains("\"dispatches\""), "{j}");
        // Faults off: no fault keys anywhere — the JSON shape is the
        // fault-free build's, byte for byte.
        let mut off = ScenarioSpec::rms_trace(true);
        off.planner = PlannerMode::Fixed;
        let rep = run_scenario(&off);
        assert!(rep.faults.is_none());
        let j = rep.to_json().to_pretty();
        assert!(!j.contains("rollbacks") && !j.contains("dispatches"), "{j}");
    }

    #[test]
    fn unrecoverable_faults_requeue_retarget_and_the_job_still_finishes() {
        // Every spawn attempt of every dispatch fails: each grow aborts
        // and rolls back MAX_DISPATCHES times, then is abandoned; the
        // shrink to a size the job already holds becomes a no-op; the
        // job completes its whole iteration budget on the layout it
        // owns.  No panic, no deadlock, deterministic output.
        let mut spec = ScenarioSpec::rms_trace(true);
        spec.planner = PlannerMode::Fixed;
        spec.faults = Some(FaultSpec::parse("spawn=1.0,mode=wave,retries=1").unwrap());
        let a = run_scenario(&spec);
        assert!(a.makespan.is_finite() && a.makespan > 0.0);
        let f = a.faults.clone().unwrap();
        assert!(f.rollbacks > 0, "{f:?}");
        assert_eq!(f.completed_resizes, 0, "nothing can spawn: {f:?}");
        assert_eq!(f.scheduled_resizes, 5);
        // r0 (8→16) is dispatched up to the cap, each dispatch rolls
        // back; r1 (16→8) finds the job already at 8 and is a no-op.
        assert_eq!(a.resizes[0].dispatches, MAX_DISPATCHES, "{:?}", a.resizes[0]);
        assert!(!a.resizes[0].completed);
        assert_eq!(a.resizes[1].dispatches, 0, "{:?}", a.resizes[1]);
        // The abandoned resize's span covers its failed dispatches:
        // that is the rollback tax the report carries.
        assert!(a.resizes[0].observed_reconf > 0.0, "{:?}", a.resizes[0]);
        let b = run_scenario(&spec);
        assert_eq!(
            a.to_json().to_pretty(),
            b.to_json().to_pretty(),
            "faulty scenarios must stay byte-deterministic"
        );
    }
}
