//! Million-rank engine stress: a resize-shaped workload at a scale
//! where thread-per-activity is physically impossible (10⁶ OS threads)
//! but thread-less [`LiteStep`] activities are routine (~200 bytes of
//! arena slot each, bounded memory).
//!
//! The workload models the hot loop of a huge malleable job:
//!
//! 1. `NS` member ranks iterate — per-rank jittered compute, then a
//!    barrier-style arrival at a coordinator,
//! 2. at the middle round the coordinator performs the *resize
//!    commit*: one batched collective wakeup releases all `ND` ranks —
//!    the `ND − NS` standby ranks (modeling freshly spawned drains)
//!    and the `NS` existing ones — in a single engine event,
//! 3. the grown job iterates to the end, and a final batched release
//!    retires everyone.
//!
//! The demo (`proteo engine-stress`, default ND = 2²⁰ > 10⁶ ranks)
//! prints the engine's observability counters; the batched-wakeup
//! counter `wakeup_max` must equal `ND` — the resize commit really is
//! one event, not `ND` queue operations.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::simcluster::{ActivityId, Engine, EngineStats, LiteCtx, LiteStep};
use crate::util::rng::splitmix64;
use crate::util::wallclock::WallTimer;

/// Outcome of one stress run.
#[derive(Clone, Copy, Debug)]
pub struct StressReport {
    pub ns: usize,
    pub nd: usize,
    pub rounds: u64,
    /// Virtual completion time.
    pub virt_end: f64,
    /// Wall-clock seconds for the whole simulation.
    pub wall_s: f64,
    pub stats: EngineStats,
}

impl StressReport {
    /// Deterministic-except-wall text rendering.
    pub fn render(&self) -> String {
        let s = &self.stats;
        format!(
            "engine-stress: {} -> {} ranks, {} rounds\n\
             \x20 virtual end      {:.6} s\n\
             \x20 events           {}\n\
             \x20 peak queue       {}\n\
             \x20 wakeup batches   {} ({} ranks total, max {})\n\
             \x20 direct sweeps    {}\n\
             \x20 wall             {:.2} s ({:.2}M events/s)\n",
            self.ns,
            self.nd,
            self.rounds,
            self.virt_end,
            s.events,
            s.peak_queue,
            s.wakeup_batches,
            s.wakeup_batched,
            s.wakeup_max_batch,
            s.direct_sweeps,
            self.wall_s,
            s.events as f64 / self.wall_s / 1e6,
        )
    }
}

/// Per-member lite state machine phase.
const FRESH: u8 = 0;
const COMPUTED: u8 = 1;
const PARKED: u8 = 2;

/// Run the resize-shaped stress workload: `ns` ranks grow to `nd` at
/// the middle round, `rounds` barrier rounds in total.
pub fn engine_stress(ns: usize, nd: usize, rounds: u64) -> StressReport {
    assert!(1 <= ns && ns <= nd, "need 1 <= ns <= nd");
    assert!(rounds >= 2, "need at least a pre- and post-resize round");
    let t0 = WallTimer::start();
    let mut e = Engine::new();

    let arrivals = Arc::new(AtomicUsize::new(0));
    let active = Arc::new(AtomicUsize::new(ns));
    let stopping = Arc::new(AtomicBool::new(false));
    // Members are spawned after the coordinator (their ids are not
    // known yet), so the coordinator reads them through this cell; it
    // is filled before `run` and only read during it.
    let members: Arc<Mutex<Vec<ActivityId>>> = Arc::new(Mutex::new(Vec::new()));
    let grow_round = rounds / 2;

    let coord = {
        let (arrivals, active, stopping, members) =
            (arrivals.clone(), active.clone(), stopping.clone(), members.clone());
        let mut round = 0u64;
        let mut fresh = true;
        move |ctx: &mut LiteCtx| -> LiteStep {
            if fresh {
                fresh = false;
                return LiteStep::Park;
            }
            round += 1;
            let ids = members.lock().unwrap();
            let now = ctx.now();
            if round == rounds {
                stopping.store(true, Ordering::SeqCst);
                ctx.unpark_batch(ids.iter().map(|&id| (id, now)).collect());
                return LiteStep::Done;
            }
            arrivals.store(0, Ordering::SeqCst);
            let release = if round == grow_round {
                // The resize commit: one batched wakeup releases every
                // rank of the grown job — standbys included.
                active.store(ids.len(), Ordering::SeqCst);
                &ids[..]
            } else {
                &ids[..active.load(Ordering::SeqCst)]
            };
            ctx.unpark_batch(release.iter().map(|&id| (id, now)).collect());
            LiteStep::Park
        }
    };
    let coord_id = e.spawn_lite_at(0.0, "coordinator", coord);

    let ids: Vec<ActivityId> = (0..nd)
        .map(|rank| {
            let (arrivals, active, stopping) =
                (arrivals.clone(), active.clone(), stopping.clone());
            let standby = rank >= ns;
            let mut phase = FRESH;
            let mut seed = 0x9E3779B97F4A7C15u64 ^ rank as u64;
            e.spawn_lite_at(0.0, format!("rank{rank}"), move |ctx| match phase {
                FRESH => {
                    if standby {
                        phase = PARKED;
                        return LiteStep::Park;
                    }
                    // Per-rank jittered compute: members arrive spread
                    // out, exercising the calendar queue's rotation.
                    phase = COMPUTED;
                    let jitter = splitmix64(&mut seed) as f64 / u64::MAX as f64;
                    LiteStep::AdvanceUntil(ctx.now() + 0.5 + 0.5 * jitter)
                }
                COMPUTED => {
                    // Arrived: last one in wakes the coordinator.
                    phase = PARKED;
                    if arrivals.fetch_add(1, Ordering::SeqCst) + 1
                        == active.load(Ordering::SeqCst)
                    {
                        ctx.unpark_at(coord_id, ctx.now());
                    }
                    LiteStep::Park
                }
                _ => {
                    // Woken: next round, or retire.
                    if stopping.load(Ordering::SeqCst) {
                        return LiteStep::Done;
                    }
                    phase = COMPUTED;
                    let jitter = splitmix64(&mut seed) as f64 / u64::MAX as f64;
                    LiteStep::AdvanceUntil(ctx.now() + 0.5 + 0.5 * jitter)
                }
            })
        })
        .collect();
    *members.lock().unwrap() = ids;

    let virt_end = e.run().expect("stress run must complete");
    StressReport {
        ns,
        nd,
        rounds,
        virt_end,
        wall_s: t0.elapsed_s_nonzero(),
        stats: e.stats(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stress_is_deterministic_and_batches_the_resize() {
        // Scaled-down shape of the million-rank demo.
        let a = engine_stress(512, 2048, 4);
        let b = engine_stress(512, 2048, 4);
        assert_eq!(a.virt_end.to_bits(), b.virt_end.to_bits());
        assert_eq!(a.stats.events, b.stats.events);
        // The resize commit (and the final retire) release all ND
        // ranks as ONE batched event.
        assert_eq!(a.stats.wakeup_max_batch, 2048);
        assert!(a.stats.wakeup_batches >= 4, "{:?}", a.stats);
        // Queue depth stays bounded by the rank count (arena-bounded
        // memory), never the event count.
        assert!(a.stats.peak_queue <= 2048 + 2, "{:?}", a.stats);
        assert!(a.stats.events > 0 && a.virt_end > 0.0);
    }

    #[test]
    fn standbys_do_not_run_before_the_resize_commit() {
        // With ns == nd there are no standbys; virtual end must not
        // change when standbys exist but contribute no pre-resize work.
        let grown = engine_stress(64, 128, 4);
        let flat = engine_stress(128, 128, 4);
        // Same post-resize population ⇒ both end after round 4's
        // releases; the grown run has standbys parked for half the run.
        assert_eq!(grown.nd, flat.nd);
        assert!(grown.stats.events < flat.stats.events, "standbys must idle");
    }
}
