//! Deterministic bench-smoke metrics for the CI regression gate.
//!
//! The DES is bit-deterministic, so *virtual-time* results are stable
//! across machines and runs — unlike wall-clock benchmarks, they can
//! gate a CI job without flaking.  `proteo bench-smoke` collects the
//! key modeled quantities (window-pool cold/warm, spawn strategies,
//! one end-to-end redistribution) into a flat `{name: seconds}` JSON;
//! `proteo bench-compare` fails when any entry regresses more than the
//! tolerance against the committed `BENCH_baseline.json`.
//!
//! Baseline lifecycle: the committed baseline starts with an empty
//! `entries` object (bootstrap — the gate passes and uploads
//! `BENCH_pr.json` as an artifact); promoting a CI-produced
//! `BENCH_pr.json` to `BENCH_baseline.json` arms the gate.

use crate::mam::{Method, PlannerMode, SpawnStrategy, Strategy, WinPoolPolicy};
use crate::proteo::run_once;
use crate::util::json::Json;
use crate::util::wallclock::WallTimer;

use super::{ablation, scenario, FigOptions};

/// Schema version of the smoke-metrics JSON.
pub const SCHEMA: u64 = 1;

/// Elapsed wall seconds, clamped away from zero so the finiteness
/// checks (`v > 0`) hold even on coarse clocks.
fn wall_s(t0: WallTimer) -> f64 {
    t0.elapsed_s_nonzero()
}

fn opts(quick: bool) -> FigOptions {
    FigOptions {
        reps: 1,
        // Quick mode shrinks the workload 10000×; the full smoke uses
        // the CI-friendly 100× figure scale.
        scale: if quick { 10_000 } else { 100 },
        pairs: vec![(8, 4)],
        seed: 0xC0FFEE,
        pool_variants: false,
    }
}

/// Collect the deterministic smoke metrics (virtual seconds), plus
/// `engine.*.wall_s` wall-clock rows tracking simulator speed itself.
/// Wall-clock entries are *soft* metrics (see `util::benchkit`):
/// bench-compare warns past 25% but never gates on them, and the
/// determinism tests strip them before comparing documents.
pub fn collect(quick: bool) -> Json {
    let o = opts(quick);
    let mut entries: Vec<(String, f64)> = Vec::new();
    let t_all = WallTimer::start();

    // Window pool: no-pool vs cold vs warm on the 8→4 shrink.
    let t0 = WallTimer::start();
    let wp = ablation::win_pool(&o);
    for (c, name) in ["no_pool", "cold", "warm"].iter().enumerate() {
        entries.push((format!("winpool.8to4.{name}"), wp.value(0, c)));
    }
    entries.push(("engine.winpool_sweep.wall_s".to_string(), wall_s(t0)));

    // Spawn strategies: the 8→16 grow, blocking / WD / pool-aware WD.
    let sp = ablation::spawn_strategies(&FigOptions { pairs: vec![(8, 16)], ..o.clone() });
    for (r, row) in ["blk", "wd", "wd_pool"].iter().enumerate() {
        for (c, ss) in SpawnStrategy::all().iter().enumerate() {
            entries.push((format!("spawn.8to16.{row}.{}", ss.label()), sp.value(r, c)));
        }
    }

    // Chunked pipelined registration: the 8→4 shrink's unchunked
    // blocking baseline, the best chunked cold time over the sweep,
    // and the best warm time — so the merge-base bench gate guards the
    // pipelined path end to end.
    let ck = ablation::rma_chunk(&o);
    let chunk_cols = ablation::RMA_CHUNK_SWEEP_KIB.len();
    let best = |row: usize| (1..chunk_cols).map(|c| ck.value(row, c)).fold(f64::INFINITY, f64::min);
    entries.push(("rmachunk.8to4.blocking".to_string(), ck.value(0, 0)));
    entries.push(("rmachunk.8to4.best_cold".to_string(), best(0)));
    entries.push(("rmachunk.8to4.best_warm".to_string(), best(1)));

    // Shrink-direction lifecycle pipeline: the 160→20 acceptance pair's
    // unchunked baseline, the best full-lifecycle cold time, and the
    // best registration-only time (teardown still serial) — the gap
    // between the last two is the teardown pipeline's contribution,
    // guarded end to end by the merge-base bench gate.
    let cks = ablation::rma_chunk_shrink(&FigOptions { pairs: vec![(160, 20)], ..o.clone() });
    let bestk = |row: usize| {
        (1..chunk_cols).map(|c| cks.value(row, c)).fold(f64::INFINITY, f64::min)
    };
    entries.push(("rmachunk.160to20.blocking".to_string(), cks.value(0, 0)));
    entries.push(("rmachunk.160to20.best_cold".to_string(), bestk(0)));
    entries.push(("rmachunk.160to20.reg_only".to_string(), bestk(1)));

    // Persistent-schedule cache: the headline 20→160 grow's cold
    // build and warm replay — the gate's guard on the schedule-cache
    // pricing (replay must keep undercutting the cold build).
    let t0 = WallTimer::start();
    let sc = ablation::sched_cache(&FigOptions { pairs: vec![], ..o.clone() });
    entries.push(("schedcache.20to160.cold".to_string(), sc.value(0, 1)));
    entries.push(("schedcache.20to160.replay".to_string(), sc.value(0, 2)));
    entries.push(("engine.schedcache.wall_s".to_string(), wall_s(t0)));

    // One end-to-end run per method family (redistribution time), at
    // the larger fig-sweep pair — the wall-clock row is the simulator
    // throughput tripwire for the engine itself.
    let t0 = WallTimer::start();
    for (name, m, s) in [
        ("col.blocking", Method::Collective, Strategy::Blocking),
        ("rma_lockall.wd", Method::RmaLockall, Strategy::WaitDrains),
    ] {
        let mut spec = o.spec(20, 40, m, s);
        spec.win_pool = WinPoolPolicy::off();
        let r = run_once(&spec);
        entries.push((format!("run.20to40.{name}.redist"), r.redist_time));
        entries.push((format!("run.20to40.{name}.total"), r.reconf_total));
    }
    entries.push(("engine.run_20to40.wall_s".to_string(), wall_s(t0)));

    // Closed-loop RMS scenario: total makespan under the planner and
    // two fixed anchors — the gate's planner-regression tripwire.
    let t0 = WallTimer::start();
    let base = scenario::ScenarioSpec::rms_trace(quick);
    for (name, planner, m, s) in [
        ("auto", PlannerMode::Auto, Method::Collective, Strategy::Blocking),
        ("col_blocking", PlannerMode::Fixed, Method::Collective, Strategy::Blocking),
        ("rma_lockall_wd", PlannerMode::Fixed, Method::RmaLockall, Strategy::WaitDrains),
    ] {
        let mut sp = base.clone();
        sp.planner = planner;
        sp.method = m;
        sp.strategy = s;
        let rep = scenario::run_scenario(&sp);
        entries.push((format!("scenario.rms.{name}.makespan"), rep.makespan));
    }
    entries.push(("engine.scenario_rms.wall_s".to_string(), wall_s(t0)));

    // The same trace with the in-sim online recalibrator on: the
    // replicated-belief protocol and its live re-planning stay under
    // the gate alongside the static planner.
    {
        let mut sp = base.clone();
        sp.planner = PlannerMode::Auto;
        sp.recalib = true;
        let rep = scenario::run_scenario(&sp);
        entries.push(("scenario.rms.auto_recalib.makespan".to_string(), rep.makespan));
    }

    // Oscillating 20↔160 trace: the pooled RMA makespan without and
    // with the schedule cache + notified completion — the end-to-end
    // tripwire for the persistent-schedule machinery.
    let t0 = WallTimer::start();
    {
        let mut sp = scenario::ScenarioSpec::osc_trace(quick);
        sp.planner = PlannerMode::Fixed;
        sp.method = Method::RmaLockall;
        sp.strategy = Strategy::Blocking;
        sp.win_pool = WinPoolPolicy::on();
        let rep = scenario::run_scenario(&sp);
        entries.push(("scenario.osc.rma_pool.makespan".to_string(), rep.makespan));
        let mut sp2 = sp.clone();
        sp2.sched_cache = true;
        sp2.rma_sync = crate::simmpi::RmaSync::Notify;
        let rep2 = scenario::run_scenario(&sp2);
        entries.push(("scenario.osc.rma_pool_sched_notify.makespan".to_string(), rep2.makespan));
    }
    entries.push(("engine.scenario_osc.wall_s".to_string(), wall_s(t0)));

    // Drift benchmarks: cumulative reconfiguration cost of the static
    // and recalibrating arms, plus the episode index at which the
    // recalibrated predictions settle under the 15% error bar.
    entries.extend(super::drift::drift_bench_entries(quick));

    // Chaos benchmarks: the fault-injection recovery headlines — the
    // completed-resize rate and faulty makespan under healed spawn
    // failures, and the rollback count of the unrecoverable cell.
    entries.extend(super::chaos::chaos_bench_entries(quick));
    entries.push(("engine.smoke_total.wall_s".to_string(), wall_s(t_all)));

    let obj: Vec<(&str, Json)> = vec![
        ("schema", Json::num(SCHEMA as f64)),
        // Workload provenance: bench-compare refuses to compare
        // documents produced at different scales.
        ("mode", Json::str(if quick { "quick" } else { "full" })),
        (
            "entries",
            Json::Obj(entries.into_iter().map(|(k, v)| (k, Json::Num(v))).collect()),
        ),
    ];
    Json::obj(obj)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Drop the soft `*.wall_s` entries: wall clocks differ run to run
    /// by design, only the virtual-time entries are bit-deterministic.
    fn strip_wall(doc: &Json) -> Json {
        let mut d = doc.clone();
        if let Json::Obj(top) = &mut d {
            if let Some(Json::Obj(entries)) = top.get_mut("entries") {
                entries.retain(|k, _| !k.ends_with(".wall_s"));
            }
        }
        d
    }

    #[test]
    fn collect_is_deterministic_and_finite() {
        let a = collect(true);
        let b = collect(true);
        assert_eq!(
            strip_wall(&a),
            strip_wall(&b),
            "smoke metrics must be bit-deterministic"
        );
        let entries = a.get("entries").and_then(|e| e.as_obj()).unwrap();
        assert!(entries.len() >= 15, "got {} entries", entries.len());
        for (k, v) in entries {
            let v = v.as_f64().unwrap();
            assert!(v.is_finite() && v > 0.0, "{k} = {v}");
        }
        assert_eq!(a.get("schema").unwrap().as_u64(), Some(SCHEMA));
        // The engine wall-clock rows ride along as soft metrics.
        for key in [
            "engine.winpool_sweep.wall_s",
            "engine.run_20to40.wall_s",
            "engine.scenario_rms.wall_s",
            "engine.smoke_total.wall_s",
        ] {
            assert!(entries.contains_key(key), "missing {key}");
        }
        // The scenario makespans feed the gate too.
        for key in [
            "scenario.rms.auto.makespan",
            "scenario.rms.col_blocking.makespan",
            "scenario.rms.rma_lockall_wd.makespan",
            "scenario.rms.auto_recalib.makespan",
            "scenario.osc.rma_pool.makespan",
            "scenario.osc.rma_pool_sched_notify.makespan",
            "schedcache.20to160.cold",
            "schedcache.20to160.replay",
        ] {
            assert!(entries.contains_key(key), "missing {key}");
        }
        // Drift benchmarks: both arms and the convergence index per
        // scenario, with every recalib arm converging within the gate.
        for name in ["miscal", "hetero", "congest"] {
            assert!(entries.contains_key(&format!("drift.{name}.static")), "{name}");
            assert!(entries.contains_key(&format!("drift.{name}.recalib")), "{name}");
            let k = entries
                .get(&format!("recalib.{name}.converge_resizes"))
                .and_then(|v| v.as_f64())
                .unwrap();
            assert!((1.0..=5.0).contains(&k), "{name}: converge_resizes {k}");
        }
        // Chaos headlines: recovery rate, rollback count, faulty
        // makespan (the soft chaos.wall_s rides along too).
        for key in [
            "chaos.spawnfail.completed_rate",
            "chaos.spawnfail.rollbacks",
            "scenario.faulty.makespan",
            "chaos.wall_s",
        ] {
            assert!(entries.contains_key(key), "missing {key}");
        }
    }

    #[test]
    fn collect_reflects_the_acceptance_orderings() {
        let j = collect(true);
        // Note: entry names contain dots, so index the object directly
        // rather than via `get_path`.
        let e = |k: &str| j.get("entries").unwrap().get(k).unwrap().as_f64().unwrap();
        // Warm pool beats cold; parallel/async spawn beat sequential.
        assert!(e("winpool.8to4.warm") < e("winpool.8to4.cold"));
        assert!(e("spawn.8to16.blk.parallel") < e("spawn.8to16.blk.sequential"));
        assert!(e("spawn.8to16.wd.async") < e("spawn.8to16.wd.sequential"));
        // The chunked sweep's best warm pass never loses to its cold
        // pass, and all three pipelined-path entries are present for
        // the gate.
        assert!(e("rmachunk.8to4.best_warm") <= e("rmachunk.8to4.best_cold") + 1e-12);
        assert!(e("rmachunk.8to4.blocking") > 0.0);
        // Shrink lifecycle: the full pipeline never loses to the
        // registration-only one, and both beat nothing (finite).
        assert!(e("rmachunk.160to20.best_cold") <= e("rmachunk.160to20.reg_only") + 1e-12);
        assert!(e("rmachunk.160to20.blocking") > 0.0);
        // Schedule cache: the warm replay keeps only the validation
        // handshake, strictly under the cold build.
        assert!(e("schedcache.20to160.replay") < e("schedcache.20to160.cold"));
    }
}
