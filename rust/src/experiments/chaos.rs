//! `proteo chaos` — fault-injection sweep over the closed-loop RMS
//! scenario.
//!
//! Each cell of the fault matrix runs the [`scenario`] trace under one
//! deterministic [`FaultSpec`] (seeded spawn failures with
//! retry/backoff, hung attempts, slowed registration streams, lost
//! notify counters, stragglers) and reports how the recovery machinery
//! fared against the healthy baseline: completed-resize rate, rollback
//! count, spawn retries, and the makespan the faults added.  Everything
//! is bit-deterministic — the same seed produces the same failures,
//! the same recoveries and the same byte-identical report — so the
//! headline cells feed the CI bench gate (`proteo bench-smoke`).

use crate::mam::{Method, PlannerMode, Strategy};
use crate::simmpi::{FaultSpec, RmaSync};
use crate::util::json::Json;
use crate::util::stats::fmt_seconds;

use super::scenario::{run_scenario, ScenarioSpec};

/// The fault matrix: `(cell name, fault spec)`.  Quick mode keeps the
/// three headline cells; the full sweep adds per-rank, notify-loss and
/// straggler-only columns.
pub fn fault_matrix(quick: bool) -> Vec<(&'static str, &'static str)> {
    let mut m = vec![
        // Every grow's first spawn attempt fails and the retry heals it:
        // the recovery path with zero rollbacks.
        ("spawnfail", "spawn=first1,mode=wave"),
        // Every spawn attempt of every dispatch fails: each grow aborts
        // and rolls back until the RMS abandons it.
        ("spawnfail_hard", "spawn=1.0,mode=wave,retries=1"),
        // Compound weather: a healed spawn failure detected via hang
        // timeout, every registration stream slowed 2x, and half the
        // sources straggling into the resize.
        ("mixed", "spawn=first1,mode=wave,kind=hang,reg=1.0x2.0,straggler=0.5@0.02"),
    ];
    if !quick {
        m.push(("rankfail", "spawn=0.5,mode=rank"));
        m.push(("notifyloss", "notify=1.0"));
        m.push(("stragglers", "straggler=1.0@0.05"));
    }
    m
}

/// One cell's outcome.
#[derive(Clone, Debug)]
pub struct CellReport {
    pub name: String,
    /// Canonical spec string (provenance).
    pub spec: String,
    pub makespan: f64,
    /// Makespan delta against the healthy baseline (can be negative:
    /// an abandoned grow also skips the redistribution it priced).
    pub added_makespan: f64,
    /// Completed / scheduled resizes.
    pub completed_rate: f64,
    pub rollbacks: u64,
    pub spawn_retries: u64,
}

/// Full sweep outcome.
#[derive(Clone, Debug)]
pub struct ChaosReport {
    /// Healthy (faults-off) makespan of the same trace.
    pub baseline_makespan: f64,
    pub cells: Vec<CellReport>,
}

impl ChaosReport {
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "\n== Chaos sweep: RMS trace under fault injection (healthy makespan {}) ==\n",
            fmt_seconds(self.baseline_makespan)
        ));
        out.push_str(&format!(
            "{:<16}{:>12}{:>12}{:>11}{:>11}{:>9}\n",
            "cell", "makespan", "added", "completed", "rollbacks", "retries"
        ));
        for c in &self.cells {
            out.push_str(&format!(
                "{:<16}{:>12}{:>12}{:>10.0}%{:>11}{:>9}\n",
                c.name,
                fmt_seconds(c.makespan),
                fmt_seconds(c.added_makespan),
                100.0 * c.completed_rate,
                c.rollbacks,
                c.spawn_retries,
            ));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("baseline_makespan_s", Json::num(self.baseline_makespan)),
            (
                "cells",
                Json::Arr(
                    self.cells
                        .iter()
                        .map(|c| {
                            Json::obj(vec![
                                ("name", Json::str(c.name.clone())),
                                ("faults", Json::str(c.spec.clone())),
                                ("makespan_s", Json::num(c.makespan)),
                                ("added_makespan_s", Json::num(c.added_makespan)),
                                ("completed_rate", Json::num(c.completed_rate)),
                                ("rollbacks", Json::num(c.rollbacks as f64)),
                                ("spawn_retries", Json::num(c.spawn_retries as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// The base trace every cell runs: the fixed RMA version, so spawn,
/// registration and sync faults all land on exercised paths.
fn base_spec(quick: bool) -> ScenarioSpec {
    let mut spec = ScenarioSpec::rms_trace(quick);
    spec.planner = PlannerMode::Fixed;
    spec.method = Method::RmaLockall;
    spec.strategy = Strategy::Blocking;
    spec
}

/// Run the whole matrix (plus the healthy baseline).
pub fn run_chaos(quick: bool) -> ChaosReport {
    let base = base_spec(quick);
    let healthy = run_scenario(&base);
    let cells = fault_matrix(quick)
        .into_iter()
        .map(|(name, s)| {
            let faults = FaultSpec::parse(s).expect("built-in fault matrix spec");
            let mut sp = base.clone();
            // Lost notify counters only exist under notified sync.
            if name == "notifyloss" {
                sp.rma_sync = RmaSync::Notify;
            }
            sp.faults = Some(faults.clone());
            let rep = run_scenario(&sp);
            let f = rep.faults.expect("active faults must produce a summary");
            CellReport {
                name: name.to_string(),
                spec: faults.to_spec_string(),
                makespan: rep.makespan,
                added_makespan: rep.makespan - healthy.makespan,
                completed_rate: f.completed_resizes as f64 / f.scheduled_resizes.max(1) as f64,
                rollbacks: f.rollbacks,
                spawn_retries: f.spawn_retries,
            }
        })
        .collect();
    ChaosReport { baseline_makespan: healthy.makespan, cells }
}

/// Bench-smoke entries: the recovery headline (every resize completes
/// under a healed spawn failure), the rollback headline (the hard cell
/// rolls back), the faulty makespan, and a soft wall-clock row.
pub fn chaos_bench_entries(quick: bool) -> Vec<(String, f64)> {
    let t0 = crate::util::wallclock::WallTimer::start();
    let rep = run_chaos(quick);
    let cell = |n: &str| {
        rep.cells.iter().find(|c| c.name == n).expect("headline cell missing from the matrix")
    };
    vec![
        ("chaos.spawnfail.completed_rate".to_string(), cell("spawnfail").completed_rate),
        ("chaos.spawnfail.rollbacks".to_string(), cell("spawnfail_hard").rollbacks as f64),
        ("scenario.faulty.makespan".to_string(), cell("spawnfail").makespan),
        ("chaos.wall_s".to_string(), t0.elapsed_s_nonzero()),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_specs_parse_active_and_unique() {
        for quick in [true, false] {
            let m = fault_matrix(quick);
            let names: std::collections::BTreeSet<&str> = m.iter().map(|(n, _)| *n).collect();
            assert_eq!(names.len(), m.len(), "duplicate cell names");
            for (n, s) in m {
                let spec = FaultSpec::parse(s).unwrap_or_else(|e| panic!("{n}: {e}"));
                assert!(spec.is_active(), "{n}: inactive spec injects nothing");
            }
        }
    }

    #[test]
    fn quick_sweep_recovers_where_it_can_and_rolls_back_where_it_cannot() {
        let a = run_chaos(true);
        assert!(a.baseline_makespan.is_finite() && a.baseline_makespan > 0.0);
        let cell = |n: &str| a.cells.iter().find(|c| c.name == n).unwrap();
        // Healed spawn failures: all resizes complete, retries charged,
        // nothing rolled back — and the recovery is not free.
        let heal = cell("spawnfail");
        assert_eq!(heal.completed_rate, 1.0, "{heal:?}");
        assert_eq!(heal.rollbacks, 0, "{heal:?}");
        assert!(heal.spawn_retries > 0, "{heal:?}");
        assert!(heal.added_makespan > 0.0, "{heal:?}");
        // Unrecoverable failures: rollbacks, nothing completes.
        let hard = cell("spawnfail_hard");
        assert!(hard.rollbacks > 0, "{hard:?}");
        assert_eq!(hard.completed_rate, 0.0, "{hard:?}");
        // Deterministic byte for byte.
        let b = run_chaos(true);
        assert_eq!(a.to_json().to_pretty(), b.to_json().to_pretty());
    }
}
