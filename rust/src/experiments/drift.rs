//! Drift scenarios: environments where the static planner's seed
//! calibration picks the wrong version, and the online recalibrator
//! ([`crate::mam::Recalibrator`]) converges to the right one within a
//! few resizes.
//!
//! Each scenario is a sequence of isolated reconfiguration *episodes*
//! (grows, cold windows).  Two arms run over the identical episode
//! sequence:
//!
//! * **static** — plans every episode with the frozen seed belief;
//! * **recalib** — plans with a live belief, then feeds the episode's
//!   observed span, spawn block and registration counters back into
//!   the estimator.
//!
//! Both arms pick the argmin over the same candidate set by DES
//! micro-probe *under their own belief* (probes are exact, so once the
//! belief matches the environment the prediction error collapses to
//! the DES's own reproducibility: zero).  The environment executes the
//! chosen candidate under the *true* drifted parameters.  The spawn
//! axis makes the comparison provable: for blocking spawn strategies
//! the redistribution is bit-identical regardless of the spawn choice,
//! so a wrong spawn argmin costs exactly the spawn-block gap, every
//! episode, until the belief catches up.
//!
//! The three drifts (ISSUE/ROADMAP PR-6):
//!
//! * `miscal` — the seed constants are simply ~2× optimistic
//!   (`spawn_launch`, `spawn_per_proc`, `beta_register`): the belief
//!   says parallel spawning beats the sequential constant; the real
//!   machine says otherwise.
//! * `hetero` — heterogeneous-NIC nodes: registration throughput 8×
//!   worse and per-process startup 5× worse than the seed (slow
//!   firmware path), flipping both the spawn argmin and the
//!   chunk-size sweet spot.
//! * `congest` — a congested-network transient: the first episode
//!   really is 4× slower on the wire (and the belief was calibrated
//!   then, with a panicked 20×-merge estimate); afterwards the fabric
//!   drains and the static belief keeps over-charging parallel spawns
//!   and the wire forever.

use std::collections::BTreeMap;

use crate::mam::planner::{self, Candidate, Objective, PlannerInputs};
use crate::mam::{
    DataDecl, DataKind, Method, Observation, Recalibrator, SpawnStrategy, Strategy,
    WinPoolPolicy,
};
use crate::netmodel::{costmodel, NetParams};
use crate::simmpi::ELEM_BYTES;
use crate::util::json::Json;
use crate::util::stats::fmt_seconds;

/// One drift scenario: an episode sequence, the true (drifted)
/// environment of each episode, the (mis)calibrated seed belief and
/// the candidate set both arms choose from.
#[derive(Clone, Debug)]
pub struct DriftScenario {
    pub name: &'static str,
    pub title: &'static str,
    /// Seed belief both arms start from (the static arm keeps it).
    pub belief0: NetParams,
    /// True environment parameters, one entry per episode (transients
    /// like the congestion ramp vary them over the sequence).
    pub env: Vec<NetParams>,
    /// Episode resize shapes `(ns, nd)` — grows only.
    pub shapes: Vec<(usize, usize)>,
    /// Global bytes of the single redistributed structure.
    pub total_bytes: u64,
    pub candidates: Vec<Candidate>,
    pub cores_per_node: usize,
    /// Sequential-spawn constant (not a `NetParams` term — exact under
    /// drift by construction, which is what makes it the safe harbor
    /// the recalibrated planner falls back to).
    pub spawn_cost: f64,
}

fn cand(method: Method, chunk_kib: u64, ss: SpawnStrategy) -> Candidate {
    Candidate {
        method,
        strategy: Strategy::Blocking,
        spawn_strategy: ss,
        win_pool: WinPoolPolicy::off(),
        rma_chunk_kib: chunk_kib,
    }
}

impl DriftScenario {
    /// ~2× miscalibrated seed constants.
    pub fn miscal(quick: bool) -> DriftScenario {
        let episodes = if quick { 6 } else { 12 };
        let bytes: u64 = if quick { 16 << 20 } else { 128 << 20 };
        let env = NetParams::sarteco25().with(|p| {
            p.spawn_launch *= 2.0;
            p.spawn_per_proc *= 2.0;
            p.beta_register *= 2.0;
        });
        DriftScenario {
            name: "miscal",
            title: "2x-optimistic seed constants",
            belief0: NetParams::sarteco25(),
            env: vec![env; episodes],
            shapes: (0..episodes).map(|k| if k % 2 == 0 { (2, 16) } else { (4, 16) }).collect(),
            total_bytes: bytes,
            candidates: vec![
                cand(Method::Collective, 0, SpawnStrategy::Sequential),
                cand(Method::Collective, 0, SpawnStrategy::Parallel),
                cand(Method::RmaLockall, 1024, SpawnStrategy::Sequential),
                cand(Method::RmaLockall, 1024, SpawnStrategy::Parallel),
            ],
            cores_per_node: 8,
            spawn_cost: 0.25,
        }
    }

    /// Heterogeneous-NIC nodes: slow registration/startup path.
    pub fn hetero(quick: bool) -> DriftScenario {
        let episodes = if quick { 6 } else { 10 };
        let bytes: u64 = if quick { 32 << 20 } else { 512 << 20 };
        let env = NetParams::sarteco25().with(|p| {
            p.beta_register *= 8.0;
            p.spawn_per_proc *= 5.0;
            p.spawn_launch *= 1.5;
        });
        DriftScenario {
            name: "hetero",
            title: "heterogeneous-NIC nodes (8x slower registration)",
            belief0: NetParams::sarteco25(),
            env: vec![env; episodes],
            shapes: (0..episodes).map(|k| if k % 2 == 0 { (4, 16) } else { (2, 16) }).collect(),
            total_bytes: bytes,
            candidates: vec![
                cand(Method::RmaLockall, 0, SpawnStrategy::Sequential),
                cand(Method::RmaLockall, 0, SpawnStrategy::Parallel),
                cand(Method::RmaLockall, 1024, SpawnStrategy::Sequential),
                cand(Method::RmaLockall, 1024, SpawnStrategy::Parallel),
            ],
            cores_per_node: 8,
            spawn_cost: 0.25,
        }
    }

    /// Congested-network calibration transient: the belief was taken
    /// during the congestion (4× wire, panicked merge estimate); the
    /// congestion clears after the first episode.
    pub fn congest(quick: bool) -> DriftScenario {
        let episodes = if quick { 5 } else { 8 };
        let bytes: u64 = if quick { 32 << 20 } else { 256 << 20 };
        let congested = NetParams::sarteco25().with(|p| p.beta_inter *= 4.0);
        let clear = NetParams::sarteco25();
        let belief0 = NetParams::sarteco25().with(|p| {
            p.beta_inter *= 4.0;
            p.merge_round = 0.04;
        });
        let env: Vec<NetParams> = (0..episodes)
            .map(|k| if k == 0 { congested.clone() } else { clear.clone() })
            .collect();
        DriftScenario {
            name: "congest",
            title: "congested-network calibration transient",
            belief0,
            env,
            shapes: vec![(4, 16); episodes],
            total_bytes: bytes,
            candidates: vec![
                cand(Method::RmaLockall, 1024, SpawnStrategy::Sequential),
                cand(Method::RmaLockall, 1024, SpawnStrategy::Parallel),
            ],
            cores_per_node: 8,
            spawn_cost: 0.25,
        }
    }

    pub fn all(quick: bool) -> Vec<DriftScenario> {
        vec![Self::miscal(quick), Self::hetero(quick), Self::congest(quick)]
    }

    pub fn by_name(name: &str, quick: bool) -> Option<DriftScenario> {
        match name {
            "miscal" => Some(Self::miscal(quick)),
            "hetero" => Some(Self::hetero(quick)),
            "congest" => Some(Self::congest(quick)),
            _ => None,
        }
    }

    /// The single redistributed structure (names are stable so chunk
    /// hints persist across episodes).
    fn decls(&self) -> Vec<DataDecl> {
        vec![DataDecl {
            name: "A".into(),
            kind: DataKind::Constant,
            total_elems: (self.total_bytes / ELEM_BYTES).max(1),
            real: false,
        }]
    }

    fn inputs(&self, net: &NetParams, ns: usize, nd: usize, extra: Vec<u64>) -> PlannerInputs {
        PlannerInputs {
            decls: self.decls(),
            ns,
            nd,
            cores_per_node: self.cores_per_node,
            net: net.clone(),
            spawn_cost: self.spawn_cost,
            warm: false,
            t_iter_src: 0.0,
            t_iter_dst: 0.0,
            objective: Objective::ReconfTime,
            probe: false,
            extra_chunks_kib: extra,
            rma_sync: crate::simmpi::RmaSync::Epoch,
            sched_cache: false,
            sched_warm: false,
            future_resizes: 0,
            fail_p: 0.0,
        }
    }
}

/// What one episode's environment execution measured.
#[derive(Clone, Copy, Debug)]
struct EpisodeMeasurement {
    /// Full reconfiguration span under the true parameters.
    reconf: f64,
    /// Spawn-block portion (reconfigure entry → redistribution start).
    spawn_block: f64,
    reg_bytes: f64,
    reg_secs: f64,
}

/// Execute one episode under `env`: the same isolated-DES body as the
/// planner's micro-probe, read back with the registration counters and
/// the spawn/redistribution split the recalibrator needs.
fn run_episode(
    sc: &DriftScenario,
    env: &NetParams,
    cand: &Candidate,
    ns: usize,
    nd: usize,
) -> EpisodeMeasurement {
    let inp = sc.inputs(env, ns, nd, Vec::new());
    let (reconf, extras) = planner::probe_reconfiguration_extras(&inp, cand);
    EpisodeMeasurement {
        reconf,
        spawn_block: extras.spawn_block,
        reg_bytes: extras.reg_bytes,
        reg_secs: extras.reg_secs,
    }
}

/// One episode of one arm, as reported.
#[derive(Clone, Debug)]
pub struct EpisodeReport {
    pub index: usize,
    pub ns: usize,
    pub nd: usize,
    pub choice: String,
    /// The arm's belief-probe prediction for its choice.
    pub predicted: f64,
    /// The environment's true span for that choice.
    pub observed: f64,
}

impl EpisodeReport {
    /// Unsigned relative prediction error.
    pub fn rel_err(&self) -> f64 {
        if self.observed > 0.0 {
            ((self.predicted - self.observed) / self.observed).abs()
        } else {
            0.0
        }
    }
}

/// One arm's full trajectory.
#[derive(Clone, Debug)]
pub struct ArmReport {
    pub label: &'static str,
    pub episodes: Vec<EpisodeReport>,
    /// Sum of observed episode spans: the cumulative reconfiguration
    /// cost this arm's choices actually paid.
    pub cum_cost: f64,
}

/// Static-vs-recalibrating comparison on one drift scenario.
#[derive(Clone, Debug)]
pub struct DriftReport {
    pub name: String,
    pub title: String,
    pub static_arm: ArmReport,
    pub recalib_arm: ArmReport,
}

/// Convergence tolerance: per-episode predicted-vs-observed error the
/// recalibrated planner must fall (and stay) below.
pub const CONVERGE_TOL: f64 = 0.15;

impl DriftReport {
    /// Fraction of the static arm's cumulative cost the recalibrating
    /// arm saved.
    pub fn win_frac(&self) -> f64 {
        if self.static_arm.cum_cost > 0.0 {
            1.0 - self.recalib_arm.cum_cost / self.static_arm.cum_cost
        } else {
            0.0
        }
    }

    /// First episode (1-based) from which every subsequent recalib-arm
    /// prediction error stays below [`CONVERGE_TOL`]; `episodes + 1`
    /// when the trajectory never settles.
    pub fn converge_resizes(&self) -> usize {
        let errs: Vec<f64> = self.recalib_arm.episodes.iter().map(|e| e.rel_err()).collect();
        let mut k = errs.len();
        while k > 0 && errs[k - 1] < CONVERGE_TOL {
            k -= 1;
        }
        k + 1
    }

    pub fn render(&self, per_episode: bool) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n== Drift {} ({}) ==\n", self.name, self.title));
        if per_episode {
            out.push_str(&format!(
                "{:<4}{:<8}{:<26}{:>10}{:<26}{:>10}{:>10}{:>8}\n",
                "ep", "pair", "static choice", "obs", "recalib choice", "pred", "obs", "err%"
            ));
            for (s, r) in self.static_arm.episodes.iter().zip(&self.recalib_arm.episodes) {
                out.push_str(&format!(
                    "e{:<3}{:<8}{:<26}{:>10}{:<26}{:>10}{:>10}{:>7.1}%\n",
                    r.index,
                    format!("{}->{}", r.ns, r.nd),
                    s.choice,
                    fmt_seconds(s.observed),
                    r.choice,
                    fmt_seconds(r.predicted),
                    fmt_seconds(r.observed),
                    100.0 * r.rel_err(),
                ));
            }
        }
        out.push_str(&format!(
            "cumulative: static {} recalib {} win {:.1}% converge@{} of {} episodes\n",
            fmt_seconds(self.static_arm.cum_cost),
            fmt_seconds(self.recalib_arm.cum_cost),
            100.0 * self.win_frac(),
            self.converge_resizes(),
            self.recalib_arm.episodes.len(),
        ));
        out
    }

    pub fn to_json(&self) -> Json {
        let arm = |a: &ArmReport| {
            Json::obj(vec![
                ("cum_cost_s", Json::num(a.cum_cost)),
                (
                    "episodes",
                    Json::Arr(
                        a.episodes
                            .iter()
                            .map(|e| {
                                Json::obj(vec![
                                    ("index", Json::num(e.index as f64)),
                                    ("from", Json::num(e.ns as f64)),
                                    ("to", Json::num(e.nd as f64)),
                                    ("choice", Json::str(e.choice.clone())),
                                    ("predicted_s", Json::num(e.predicted)),
                                    ("observed_s", Json::num(e.observed)),
                                    ("rel_err", Json::num(e.rel_err())),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ])
        };
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("title", Json::str(self.title.clone())),
            ("static", arm(&self.static_arm)),
            ("recalib", arm(&self.recalib_arm)),
            ("win_frac", Json::num(self.win_frac())),
            ("converge_resizes", Json::num(self.converge_resizes() as f64)),
        ])
    }
}

/// Run one arm over the episode sequence.
fn run_arm(sc: &DriftScenario, recalib: bool) -> ArmReport {
    let mut rc = Recalibrator::new(sc.belief0.clone());
    let mut episodes: Vec<EpisodeReport> = Vec::new();
    let mut cum = 0.0;
    // The static arm's belief never moves, so its probe-argmin per
    // shape is a constant: memoize it.
    let mut static_memo: BTreeMap<(usize, usize), (usize, Vec<(Candidate, f64)>)> =
        BTreeMap::new();
    for (k, &(ns, nd)) in sc.shapes.iter().enumerate() {
        let (choice_i, probed): (usize, Vec<(Candidate, f64)>) = if !recalib {
            static_memo
                .entry((ns, nd))
                .or_insert_with(|| pick(sc, &sc.belief0, Vec::new(), ns, nd))
                .clone()
        } else {
            pick(sc, &rc.params().clone(), rc.chunk_candidates(), ns, nd)
        };
        let mut choice_i = choice_i;
        // One deterministic exploration step: on the very first
        // episode, with no spawn observations yet, a Sequential argmin
        // may just reflect an over-charged parallel-spawn belief (the
        // congest transient).  Trying the best-believed Parallel
        // candidate once bounds the regret by a single episode and
        // hands the estimator the spawn terms it cannot otherwise see.
        if recalib && k == 0 && probed[choice_i].0.spawn_strategy == SpawnStrategy::Sequential {
            if let Some((i, _)) = probed
                .iter()
                .enumerate()
                .filter(|(_, (c, _))| c.spawn_strategy == SpawnStrategy::Parallel)
                .min_by(|(_, (_, a)), (_, (_, b))| a.total_cmp(b))
            {
                choice_i = i;
            }
        }
        let (choice, predicted) = probed[choice_i].clone();
        let meas = run_episode(sc, &sc.env[k], &choice, ns, nd);
        cum += meas.reconf;
        if recalib {
            let n_new = nd - ns;
            let sched =
                choice.spawn_strategy.schedule(rc.params(), ns, n_new, nd, sc.spawn_cost);
            let spawn_waves = match choice.spawn_strategy {
                SpawnStrategy::Sequential => None,
                SpawnStrategy::Parallel => {
                    let waves = n_new.div_ceil(ns.max(1));
                    let rounds = usize::BITS - (nd.max(2) - 1).leading_zeros();
                    Some((waves as f64, rounds as f64))
                }
                SpawnStrategy::Async => Some((0.0, 0.0)),
            };
            let obs = Observation {
                ns,
                nd,
                reconf: meas.reconf,
                predicted,
                spawn_block: meas.spawn_block,
                predicted_spawn_block: sched.source_block,
                spawn_waves,
                reg_bytes: meas.reg_bytes,
                reg_secs: meas.reg_secs,
                wire_slope: costmodel::wire_slope(sc.total_bytes, ns, nd, sc.cores_per_node),
            };
            rc.observe(&obs);
            rc.note_chunk("A", sc.total_bytes / ns.max(1) as u64);
        }
        episodes.push(EpisodeReport {
            index: k,
            ns,
            nd,
            choice: choice.label(),
            predicted,
            observed: meas.reconf,
        });
    }
    ArmReport { label: if recalib { "recalib" } else { "static" }, episodes, cum_cost: cum }
}

/// Belief-probe argmin over the candidate set (plus the
/// recalibrator's measured chunk variants): returns the chosen index
/// and every candidate's probed belief cost, in enumeration order.
fn pick(
    sc: &DriftScenario,
    belief: &NetParams,
    extra_chunks: Vec<u64>,
    ns: usize,
    nd: usize,
) -> (usize, Vec<(Candidate, f64)>) {
    let mut set = sc.candidates.clone();
    for &kib in &extra_chunks {
        for c in &sc.candidates {
            if c.method.is_rma() {
                let mut v = *c;
                v.rma_chunk_kib = kib;
                if !set.contains(&v) {
                    set.push(v);
                }
            }
        }
    }
    let inp = sc.inputs(belief, ns, nd, Vec::new());
    let probed: Vec<(Candidate, f64)> = set
        .into_iter()
        .map(|c| {
            let cost = planner::probe_reconfiguration(&inp, &c).reconf_time;
            (c, cost)
        })
        .collect();
    let choice = probed
        .iter()
        .enumerate()
        .min_by(|(_, (_, a)), (_, (_, b))| a.total_cmp(b))
        .map(|(i, _)| i)
        .unwrap_or(0);
    (choice, probed)
}

/// Run both arms on one scenario.
pub fn run_drift(sc: &DriftScenario) -> DriftReport {
    DriftReport {
        name: sc.name.to_string(),
        title: sc.title.to_string(),
        static_arm: run_arm(sc, false),
        recalib_arm: run_arm(sc, true),
    }
}

/// Bench-smoke entries: cumulative costs of both arms plus the
/// convergence episode count, per drift scenario.
pub fn drift_bench_entries(quick: bool) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for sc in DriftScenario::all(quick) {
        let rep = run_drift(&sc);
        out.push((format!("drift.{}.static", sc.name), rep.static_arm.cum_cost));
        out.push((format!("drift.{}.recalib", sc.name), rep.recalib_arm.cum_cost));
        out.push((
            format!("recalib.{}.converge_resizes", sc.name),
            rep.converge_resizes() as f64,
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scenario_constructors_are_consistent() {
        for quick in [true, false] {
            for sc in DriftScenario::all(quick) {
                assert_eq!(sc.env.len(), sc.shapes.len(), "{}", sc.name);
                assert!(!sc.candidates.is_empty());
                for &(ns, nd) in &sc.shapes {
                    assert!(nd > ns, "{}: drift episodes are grows", sc.name);
                }
                assert!(DriftScenario::by_name(sc.name, quick).is_some());
            }
        }
        assert!(DriftScenario::by_name("nope", true).is_none());
    }

    #[test]
    fn quick_miscal_recalibration_beats_the_static_arm() {
        // The spawn-axis separability argument in miniature: the env
        // doubles the decomposed spawn terms, the belief says Parallel,
        // the machine says Sequential; once the estimator sees one
        // parallel spawn it must flip — and the flip is worth the
        // spawn-block gap per remaining episode.
        let rep = run_drift(&DriftScenario::miscal(true));
        assert_eq!(rep.static_arm.episodes.len(), rep.recalib_arm.episodes.len());
        assert!(
            rep.recalib_arm.cum_cost < rep.static_arm.cum_cost,
            "recalib {} !< static {}",
            rep.recalib_arm.cum_cost,
            rep.static_arm.cum_cost
        );
        // Both arms start from the same belief, so episode 0 costs the
        // same (no exploration fires: the miscalibrated belief's
        // argmin is already Parallel).
        let s0 = &rep.static_arm.episodes[0];
        let r0 = &rep.recalib_arm.episodes[0];
        assert_eq!(s0.choice, r0.choice);
        assert_eq!(s0.observed.to_bits(), r0.observed.to_bits());
    }

    #[test]
    fn quick_congest_exploration_fires_once_and_only_there() {
        // The congest belief over-charges parallel spawning, so its
        // argmin is Sequential: without the one-shot exploration the
        // estimator would never observe the spawn terms.  The first
        // recalib episode must be a Parallel pick.
        let rep = run_drift(&DriftScenario::congest(true));
        assert!(
            rep.recalib_arm.episodes[0].choice.contains("parallel"),
            "{:?}",
            rep.recalib_arm.episodes[0]
        );
        assert!(
            rep.static_arm.episodes[0].choice.contains("parallel") == false,
            "{:?}",
            rep.static_arm.episodes[0]
        );
    }

    #[test]
    fn drift_runs_are_deterministic() {
        let sc = DriftScenario::congest(true);
        let a = run_drift(&sc);
        let b = run_drift(&sc);
        assert_eq!(a.to_json().to_pretty(), b.to_json().to_pretty());
        assert!(a.render(true).contains("cumulative"));
    }

    #[test]
    fn converge_index_is_the_last_excursion_plus_one() {
        let ep = |i: usize, pred: f64, obs: f64| EpisodeReport {
            index: i,
            ns: 4,
            nd: 16,
            choice: "x".into(),
            predicted: pred,
            observed: obs,
        };
        let mk = |errs: &[f64]| DriftReport {
            name: "t".into(),
            title: "t".into(),
            static_arm: ArmReport { label: "static", episodes: Vec::new(), cum_cost: 1.0 },
            recalib_arm: ArmReport {
                label: "recalib",
                episodes: errs
                    .iter()
                    .enumerate()
                    .map(|(i, &e)| ep(i, 1.0 + e, 1.0))
                    .collect(),
                cum_cost: 1.0,
            },
        };
        // Errors [0.5, 0.05, 0.3, 0.01, 0.02] → settles at episode 4.
        assert_eq!(mk(&[0.5, 0.05, 0.3, 0.01, 0.02]).converge_resizes(), 4);
        // Immediately accurate → 1.
        assert_eq!(mk(&[0.01, 0.02]).converge_resizes(), 1);
        // Never settles → episodes + 1.
        assert_eq!(mk(&[0.5, 0.4]).converge_resizes(), 3);
    }
}
