//! SAM — the Synthetic Application Module (§III).
//!
//! SAM emulates an iterative MPI application from user-defined
//! parameters.  For this paper the emulated application is the
//! **Conjugate Gradient** solver of §V-A: a sparse matrix of
//! 72,067,110 rows with 5,414,538,962 non-zeros (≈ 64 GB), distributed
//! block-wise by rows.  One CG iteration is modeled as
//!
//! * a compute phase — SpMV (2·nnz flops) plus vector updates
//!   (≈ 10·n flops), perfectly strong-scaled over the N ranks at a
//!   calibrated effective per-core rate (SpMV is memory-bound), and
//! * a small collective — the dot-product reduction, posted as
//!   `MPI_Allgather` (the first collective the paper names in §V-D).
//!
//! The registered data mirrors MaM's classification (§III): the matrix
//! is **constant** (redistributable in the background), the solution
//! vector is **variable** (must move while the app is blocked).
//!
//! Per-iteration compute jitter (seeded, reproducible) models the
//! system noise that makes the paper repeat every experiment 20 times
//! and take the median.

use crate::mam::{block_of, DataKind, Registry};
use crate::simmpi::{CommId, MpiProc, Payload};
use crate::util::rng::Rng;

/// Parameters of the emulated application.
#[derive(Clone, Debug)]
pub struct SamConfig {
    /// Global element counts (8-byte units) of the *constant* CSR
    /// structures, in registration order: values, column indices,
    /// row pointers.  Each gets its own registry entry — and hence its
    /// own RMA window (§IV-B), which is what lets reads of structure k
    /// overlap the window creation of structure k+1 (§V-C).
    pub matrix_elems: u64,
    pub colind_elems: u64,
    pub rowptr_elems: u64,
    /// Global element count of the variable structure (the vector).
    pub vector_elems: u64,
    /// Total floating-point work of one iteration.
    pub flops_per_iter: f64,
    /// Effective per-core rate for this workload (memory-bound SpMV).
    pub flops_per_core: f64,
    /// Per-rank block of the per-iteration `MPI_Allgather` (elements).
    pub allgather_elems: u64,
    /// Carry real `Vec<f64>` payloads (small problems only; virtual
    /// payloads move modeled bytes instead — same control flow).
    pub real: bool,
    /// Relative compute-time jitter (uniform ±jitter), seeded.
    pub jitter: f64,
}

impl SamConfig {
    /// The paper's CG emulation (§V-A): 72M×72M, 5.4G nnz, ≈64 GB.
    pub fn sarteco25() -> SamConfig {
        let nnz = 5_414_538_962u64;
        let n = 72_067_110u64;
        SamConfig {
            // CSR storage: values f64 (43.3 GB), column indices i32
            // (21.7 GB, expressed in 8-byte units), row pointers i64.
            matrix_elems: nnz,
            colind_elems: nnz / 2,
            rowptr_elems: n + 1,
            vector_elems: n,
            // SpMV (2 flops/nnz) + ~10 vector ops per row.
            flops_per_iter: 2.0 * nnz as f64 + 10.0 * n as f64,
            // Effective per-core rate of the memory-bound CG sweep.
            flops_per_core: 2.0e9,
            allgather_elems: 2, // dot products: scalars per rank
            real: false,
            jitter: 0.01,
        }
    }

    /// A small, real-payload configuration for correctness tests.
    pub fn tiny_real() -> SamConfig {
        SamConfig {
            matrix_elems: 4_096,
            colind_elems: 2_048,
            rowptr_elems: 257,
            vector_elems: 256,
            flops_per_iter: 1.0e6,
            flops_per_core: 1.0e9,
            allgather_elems: 2,
            real: true,
            jitter: 0.0,
        }
    }

    /// Ideal per-iteration compute time on `n` ranks (no jitter).
    pub fn iter_compute(&self, n: usize) -> f64 {
        self.flops_per_iter / (n as f64 * self.flops_per_core)
    }

    /// Total registered bytes (diagnostics / reports).
    pub fn total_bytes(&self) -> u64 {
        (self.matrix_elems + self.colind_elems + self.rowptr_elems + self.vector_elems)
            * crate::simmpi::ELEM_BYTES
    }
}

/// The emulated application: owns the config and the per-rank RNG.
pub struct Sam {
    pub cfg: SamConfig,
    rng: Rng,
}

impl Sam {
    pub fn new(cfg: SamConfig, seed: u64, gpid: usize) -> Sam {
        Sam { cfg, rng: Rng::new(seed ^ (gpid as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)) }
    }

    /// Register the CG data for rank `rank` of `n` (called once at
    /// startup; MaM redistributes the registry automatically later):
    /// the three constant CSR arrays plus the variable vector.
    pub fn register_data(&self, reg: &mut Registry, n: usize, rank: usize) {
        let mk = |total: u64, salt: f64| {
            let b = block_of(total, n, rank);
            if self.cfg.real {
                Payload::real((b.ini..b.end).map(|i| i as f64 + salt).collect())
            } else {
                Payload::virt(b.len())
            }
        };
        let (mv, cv, rv) = (self.cfg.matrix_elems, self.cfg.colind_elems, self.cfg.rowptr_elems);
        reg.register("A_vals", DataKind::Constant, mv, mk(mv, 0.0));
        reg.register("A_cols", DataKind::Constant, cv, mk(cv, 0.25));
        reg.register("A_rowptr", DataKind::Constant, rv, mk(rv, 0.5));
        let vb = block_of(self.cfg.vector_elems, n, rank);
        let vector = if self.cfg.real {
            Payload::real((vb.ini..vb.end).map(|i| (i as f64).sin()).collect())
        } else {
            Payload::virt(vb.len())
        };
        reg.register("x", DataKind::Variable, self.cfg.vector_elems, vector);
    }

    /// Execute one emulated CG iteration on `comm`; returns its
    /// duration in virtual seconds.
    pub fn iteration(&mut self, proc: &MpiProc, comm: CommId) -> f64 {
        let t0 = proc.now();
        let n = proc.size(comm);
        let mut dt = self.cfg.iter_compute(n);
        if self.cfg.jitter > 0.0 {
            dt *= 1.0 + self.rng.gen_range_f64(-self.cfg.jitter, self.cfg.jitter);
        }
        proc.compute(dt);
        // Dot-product reduction (small, latency-bound collective).
        let _ = proc.allgather(comm, Payload::virt(self.cfg.allgather_elems));
        proc.iter_tick();
        proc.now() - t0
    }

    /// Iteration that also allgathers this rank's `flag` and returns
    /// whether *every* rank's flag was set — the consistent-stop
    /// protocol the application loop uses while a background
    /// redistribution is in flight (all ranks must leave the iteration
    /// loop at the same iteration or their collectives would
    /// cross-match).
    pub fn iteration_with_flag(&mut self, proc: &MpiProc, comm: CommId, flag: bool) -> (f64, bool) {
        let t0 = proc.now();
        let n = proc.size(comm);
        let mut dt = self.cfg.iter_compute(n);
        if self.cfg.jitter > 0.0 {
            dt *= 1.0 + self.rng.gen_range_f64(-self.cfg.jitter, self.cfg.jitter);
        }
        proc.compute(dt);
        let got = proc.allgather(comm, Payload::real(vec![if flag { 1.0 } else { 0.0 }]));
        proc.iter_tick();
        let all = got
            .iter()
            .all(|p| p.as_slice().is_some_and(|s| s.first() == Some(&1.0)));
        (proc.now() - t0, all)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::netmodel::{NetParams, Topology};
    use crate::simmpi::{MpiSim, WORLD};
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn sarteco_config_matches_paper() {
        let c = SamConfig::sarteco25();
        // ≈ 64 GB of constant CSR data (vals + cols + rowptr).
        let csr_bytes = (c.matrix_elems + c.colind_elems + c.rowptr_elems) * 8;
        assert!(
            (60.0e9..70.0e9).contains(&(csr_bytes as f64)),
            "csr={csr_bytes}"
        );
        assert_eq!(c.matrix_elems, 5_414_538_962); // paper's nnz
        assert_eq!(c.vector_elems, 72_067_110);
        // Iteration time scales inversely with ranks.
        let t20 = c.iter_compute(20);
        let t160 = c.iter_compute(160);
        assert!((t20 / t160 - 8.0).abs() < 1e-9);
        // Plausible regime: hundreds of ms at 20 ranks.
        assert!(t20 > 0.05 && t20 < 5.0, "t20={t20}");
    }

    #[test]
    fn register_data_creates_blocks() {
        let sam = Sam::new(SamConfig::tiny_real(), 1, 0);
        let mut reg = Registry::new();
        sam.register_data(&mut reg, 4, 1);
        assert_eq!(reg.len(), 4);
        assert!(reg.verify_blocks(4, 1).is_empty());
        assert_eq!(reg.by_name("A_vals").unwrap().kind, DataKind::Constant);
        assert_eq!(reg.by_name("A_cols").unwrap().kind, DataKind::Constant);
        assert_eq!(reg.by_name("A_rowptr").unwrap().kind, DataKind::Constant);
        assert_eq!(reg.by_name("x").unwrap().kind, DataKind::Variable);
        assert_eq!(reg.of_kind(DataKind::Constant).len(), 3);
    }

    #[test]
    fn iteration_advances_time_and_counts() {
        let mut sim = MpiSim::new(Topology::new(1, 4), NetParams::test_simple());
        sim.launch(4, |p| {
            let mut sam = Sam::new(SamConfig::tiny_real(), 7, p.gpid());
            let d1 = sam.iteration(&p, WORLD);
            let d2 = sam.iteration(&p, WORLD);
            assert!(d1 > 0.0 && d2 > 0.0);
            assert_eq!(p.iters_done(), 2);
        });
        sim.run().unwrap();
    }

    #[test]
    fn jitter_is_seeded_and_reproducible() {
        fn durations(seed: u64) -> Vec<f64> {
            let out = Arc::new(std::sync::Mutex::new(Vec::new()));
            let o = out.clone();
            let mut sim = MpiSim::new(Topology::new(1, 2), NetParams::test_simple());
            sim.launch(1, move |p| {
                let mut cfg = SamConfig::tiny_real();
                cfg.jitter = 0.2;
                let mut sam = Sam::new(cfg, seed, p.gpid());
                for _ in 0..5 {
                    o.lock().unwrap().push(sam.iteration(&p, WORLD));
                }
            });
            sim.run().unwrap();
            let v = out.lock().unwrap().clone();
            v
        }
        assert_eq!(durations(42), durations(42));
        assert_ne!(durations(42), durations(43));
    }

    #[test]
    fn flag_iteration_reaches_consensus() {
        let mut sim = MpiSim::new(Topology::new(1, 4), NetParams::test_simple());
        let stops = Arc::new(AtomicUsize::new(0));
        let s = stops.clone();
        sim.launch(3, move |p| {
            let r = p.rank(WORLD);
            let mut sam = Sam::new(SamConfig::tiny_real(), 3, p.gpid());
            // Rank r sets its flag from iteration r+1 onward.
            let mut iters = 0u64;
            loop {
                iters += 1;
                let flag = iters > r as u64;
                let (_, all) = sam.iteration_with_flag(&p, WORLD, flag);
                if all {
                    break;
                }
                assert!(iters < 100);
            }
            // All ranks leave at the same iteration: the first where
            // every flag is set (iteration 3: rank 2 sets it at iter 3).
            assert_eq!(iters, 3, "rank {r} left at iteration {iters}");
            s.fetch_add(1, Ordering::SeqCst);
        });
        sim.run().unwrap();
        assert_eq!(stops.load(Ordering::SeqCst), 3);
    }
}
